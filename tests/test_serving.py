"""Serving engine over packed QTensor weights: end-to-end decode through
qmm -> interpret-mode Pallas kernels, weight packing invariants, the empty-
prompt regression, and packed-weight checkpointing."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import qtensor
from repro.core.qgemm import QuantConfig
from repro.models.base import (ArchConfig, PROJECTION_KEYS, build_model,
                               pack_projections)
from repro.serving.engine import Request, ServeEngine


@pytest.fixture(scope="module")
def small_cfg():
    return ArchConfig(name="serve-test", family="dense", n_layers=2,
                      d_model=64, n_heads=2, n_kv_heads=2, d_ff=128,
                      vocab=64, attn_chunk=64,
                      quant=QuantConfig(method="mixfp4"))


@pytest.fixture(scope="module")
def engine(small_cfg):
    model = build_model(small_cfg)
    params, _ = model.init(jax.random.PRNGKey(0))
    return ServeEngine(small_cfg, params, batch_size=2, max_len=32)


def _collect_projection_leaves(node, out):
    if isinstance(node, dict):
        for k, v in node.items():
            if k in PROJECTION_KEYS:
                out.append((k, v))
            else:
                _collect_projection_leaves(v, out)
    return out


def test_projections_held_only_as_qtensors(engine):
    """Acceptance: projection weights live ONLY as packed QTensors — no
    dense bf16 copies retained in the engine's parameter tree."""
    leaves = _collect_projection_leaves(engine.params, [])
    assert leaves, "no projection leaves found"
    for k, v in leaves:
        assert isinstance(v, qtensor.QTensor), f"{k} is dense: {type(v)}"
        assert v.payload.dtype == jnp.uint8
    assert engine.compression > 3.5  # ~3.97x for 2-D 16x16 tiles vs bf16
    assert engine.packed_bytes < engine.dense_bytes / 3.5


def test_serve_end_to_end_from_packed_weights(engine):
    rng = np.random.RandomState(0)
    reqs = [Request(uid=i, prompt=rng.randint(0, 64, 4).astype(np.int32),
                    max_new_tokens=3) for i in range(2)]
    for r in reqs:
        assert engine.add_request(r)
    tokens = []
    for _ in range(8):
        out = engine.step()
        tokens.extend(out)
        if not any(s is not None for s in engine.slots):
            break
    assert len(tokens) == 6  # 2 requests x 3 new tokens
    assert all(0 <= t < 64 for _, t in tokens)


def test_empty_prompt_rejected(small_cfg):
    """Regression: an empty prompt used to hit UnboundLocalError on
    `logits` inside _prefill_slot; it must be rejected up front."""
    model = build_model(small_cfg)
    params, _ = model.init(jax.random.PRNGKey(1))
    eng = ServeEngine(small_cfg, params, batch_size=1, max_len=8,
                      pack_weights=False)
    with pytest.raises(ValueError, match="empty prompt"):
        eng.add_request(Request(uid=0, prompt=np.zeros((0,), np.int32)))
    # the slot must not have been consumed by the failed admission
    assert eng.slots == [None]


def test_packed_weights_checkpoint_roundtrip(small_cfg, engine, tmp_path):
    engine.save_weights(str(tmp_path))
    model = build_model(small_cfg)
    params2, _ = model.init(jax.random.PRNGKey(42))  # different weights
    eng2 = ServeEngine(small_cfg, params2, batch_size=2, max_len=32)
    eng2.load_weights(str(tmp_path))
    a = jax.tree.leaves(engine.params)
    b = jax.tree.leaves(eng2.params)
    assert len(a) == len(b)
    for x, y in zip(a, b):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))
    # and it still decodes
    assert eng2.add_request(
        Request(uid=9, prompt=np.array([1, 2], np.int32), max_new_tokens=1))
    assert len(eng2.step()) == 1


def test_ssm_family_serves_from_packed_weights():
    """PROJECTION_KEYS covers the Mamba blocks too (in/x/dt/out_proj):
    the SSM family also decodes through qmm from packed QTensors."""
    cfg = ArchConfig(name="ssm-serve", family="ssm", n_layers=2, d_model=64,
                     vocab=64, ssm_state=8, ssm_expand=2,
                     quant=QuantConfig(method="mixfp4"))
    model = build_model(cfg)
    params, _ = model.init(jax.random.PRNGKey(3))
    eng = ServeEngine(cfg, params, batch_size=1, max_len=16)
    assert eng.packed_bytes > 0 and eng.compression > 3.0
    leaves = _collect_projection_leaves(eng.params, [])
    assert any(isinstance(v, qtensor.QTensor) for _, v in leaves)
    eng.add_request(Request(uid=0, prompt=np.array([3, 4, 5], np.int32),
                            max_new_tokens=2))
    out = eng.step() + eng.step()
    assert len(out) == 2 and all(0 <= t < 64 for _, t in out)


def _serve_one(eng, prompt, n_new):
    eng.add_request(Request(uid=0, prompt=np.asarray(prompt, np.int32),
                            max_new_tokens=n_new))
    toks = []
    while any(s is not None for s in eng.slots):
        toks.extend(t for _, t in eng.step())
    return toks


def test_slot_reuse_no_contamination(small_cfg):
    """Regression: a request admitted into a freed slot used to prefill at
    the dead request's cache offset and attend to its stale K/V.  The slot
    must now reset to position 0, so a reused-slot serve is bit-identical
    to a fresh engine."""
    model = build_model(small_cfg)
    params, _ = model.init(jax.random.PRNGKey(7))
    eng = ServeEngine(small_cfg, params, batch_size=1, max_len=32)
    _serve_one(eng, [9, 8, 7, 6, 5], 6)        # occupies + frees slot 0
    reused = _serve_one(eng, [1, 2, 3], 4)     # admitted into the freed slot

    fresh_eng = ServeEngine(small_cfg, params, batch_size=1, max_len=32)
    fresh = _serve_one(fresh_eng, [1, 2, 3], 4)
    assert reused == fresh


def test_concurrent_requests_match_solo(small_cfg):
    """Regression: per-slot cache positions — slot B's prefill must not
    clobber slot A's written K/V, and each slot decodes at its own length.

    Checks the exact invariant (A's written cache region is untouched by
    B's prefill) plus numeric equivalence of the concurrent next-token
    logits against solo engines; greedy token chains are NOT compared —
    a random-weight model is chaotic enough that benign batch-shape
    compile differences (~1e-7) can flip an argmax."""
    model = build_model(small_cfg)
    params, _ = model.init(jax.random.PRNGKey(11))
    eng = ServeEngine(small_cfg, params, batch_size=2, max_len=32)
    pa = np.array([3, 1, 4, 1, 5], np.int32)
    pb = np.array([2, 7, 1, 8, 2, 8, 1], np.int32)   # different length too
    ra = Request(uid=0, prompt=pa, max_new_tokens=4)
    rb = Request(uid=1, prompt=pb, max_new_tokens=4)
    assert eng.add_request(ra)
    ka = np.asarray(eng.cache["k"])[:, 0, :len(pa)].copy()
    va = np.asarray(eng.cache["v"])[:, 0, :len(pa)].copy()
    assert eng.add_request(rb)
    assert list(eng.lengths) == [len(pa), len(pb)]
    # B's prefill wrote only slot 1 (and slot 0's not-yet-valid position)
    np.testing.assert_array_equal(
        ka, np.asarray(eng.cache["k"])[:, 0, :len(pa)])
    np.testing.assert_array_equal(
        va, np.asarray(eng.cache["v"])[:, 0, :len(pa)])

    # next-token logits of the concurrent batch == solo engines' (each slot
    # attends only to its own history, at its own cache position); feed a
    # fixed probe token so the check is independent of prefill argmaxes
    logits2, _ = eng._decode(eng.params, jnp.array([7, 7], jnp.int32),
                             eng.cache, jnp.asarray(eng.lengths))
    for prompt, row in ((pa, 0), (pb, 1)):
        solo = ServeEngine(small_cfg, params, batch_size=1, max_len=32)
        solo.add_request(Request(uid=9, prompt=prompt, max_new_tokens=4))
        logits1, _ = solo._decode(solo.params, jnp.array([7], jnp.int32),
                                  solo.cache, jnp.asarray(solo.lengths))
        np.testing.assert_allclose(np.asarray(logits2[row]),
                                   np.asarray(logits1[0]), atol=1e-4)


def test_engine_emits_greedy_continuation(small_cfg):
    """Regression: the prefill's argmax used to be fed back but never
    emitted, shifting the output stream by one token.  The engine's stream
    must equal the raw greedy continuation of the prompt."""
    model = build_model(small_cfg)
    params, _ = model.init(jax.random.PRNGKey(21))
    prompt = [9, 8, 7]
    eng = ServeEngine(small_cfg, params, batch_size=1, max_len=32)
    got = _serve_one(eng, prompt, 4)

    ref_eng = ServeEngine(small_cfg, params, batch_size=1, max_len=32)
    cache, want = ref_eng.cache, []
    seq = list(prompt)
    for t in range(len(prompt) + 3):
        tok = seq[t] if t < len(seq) else want[-1]
        logits, cache = ref_eng._decode(
            ref_eng.params, jnp.array([tok], jnp.int32), cache,
            jnp.array([t], jnp.int32))
        if t >= len(prompt) - 1:
            want.append(int(jnp.argmax(logits[0])))
    assert got == want


def test_admission_invisible_to_active_ssm_slot():
    """Regression: Mamba's recurrent h/conv state advances for EVERY batch
    row each decode step, so another slot's prefill used to irreversibly
    corrupt an active slot's state (dummy token-0 steps are not overwritten
    like KV rows).  The engine must snapshot/restore other active slots
    around a prefill — an admission is bitwise-invisible to batchmates."""
    cfg = ArchConfig(name="ssm-serve2", family="ssm", n_layers=2, d_model=64,
                     vocab=64, ssm_state=8, ssm_expand=2,
                     quant=QuantConfig(method="mixfp4"))
    model = build_model(cfg)
    params, _ = model.init(jax.random.PRNGKey(3))
    eng = ServeEngine(cfg, params, batch_size=2, max_len=16)
    ra = Request(uid=0, prompt=np.array([3, 4, 5], np.int32),
                 max_new_tokens=8)
    eng.add_request(ra)
    eng.step()                                   # A is mid-generation
    before = {k: np.asarray(v).copy() for k, v in eng.cache.items()}
    eng.add_request(Request(uid=1, prompt=np.array([9, 8, 7, 6], np.int32),
                            max_new_tokens=2))
    for k in before:
        # slot 0's rows (batch axis 1) must be untouched by B's admission
        np.testing.assert_array_equal(
            before[k][:, 0], np.asarray(eng.cache[k])[:, 0],
            err_msg=f"cache[{k}] slot 0 mutated by another admission")


def test_request_exceeding_max_len_rejected(small_cfg):
    model = build_model(small_cfg)
    params, _ = model.init(jax.random.PRNGKey(0))
    eng = ServeEngine(small_cfg, params, batch_size=1, max_len=8,
                      pack_weights=False)
    with pytest.raises(ValueError, match="max_len"):
        eng.add_request(Request(uid=0, prompt=np.arange(6, dtype=np.int32),
                                max_new_tokens=4))
    assert eng.slots == [None]
    # boundary: the final token is never fed back, so prompt 6 + 3 new fits
    # exactly in max_len=8 (highest position written is 7)
    fits = Request(uid=1, prompt=np.arange(6, dtype=np.int32),
                   max_new_tokens=3)
    assert eng.add_request(fits)
    while any(s is not None for s in eng.slots):
        eng.step()
    assert len(fits.generated) == 3


def test_cold_restore_recomputes_stats(small_cfg, tmp_path):
    """A cold engine (pack_weights=False) that load_weights a packed
    checkpoint must report the restored tree's real storage stats."""
    model = build_model(small_cfg)
    params, _ = model.init(jax.random.PRNGKey(0))
    warm = ServeEngine(small_cfg, params, batch_size=1, max_len=16)
    warm.save_weights(str(tmp_path))
    cold = ServeEngine(small_cfg, params, batch_size=1, max_len=16,
                       pack_weights=False)
    assert cold.packed_bytes == 0 and cold.compression == 1.0
    cold.load_weights(str(tmp_path))
    assert cold.packed_bytes == warm.packed_bytes
    assert cold.dense_bytes == warm.dense_bytes
    assert cold.compression == pytest.approx(warm.compression)


def test_moe_family_serves_from_packed_experts():
    """Scan-stacked MoE expert weights ((n_layers, E, K, N), 4-D) must be
    packed too — the engine's 'projections held only as QTensors' contract
    covers the dominant weight term of a MoE model."""
    from repro import configs
    cfg = configs.smoke_config("qwen3-moe-30b-a3b").replace(
        quant=QuantConfig(method="mixfp4"))
    model = build_model(cfg)
    params, _ = model.init(jax.random.PRNGKey(5))
    eng = ServeEngine(cfg, params, batch_size=1, max_len=16)
    leaves = dict(_collect_projection_leaves(eng.params, []))
    for name in ("w_up", "w_gate", "w_down"):
        assert isinstance(leaves[name], qtensor.QTensor), name
    # expert stacks carry (n_layers, E) lead dims on the packed children
    assert leaves["w_up"].payload.ndim == 4
    out = _serve_one(eng, [3, 4, 5], 2)
    assert len(out) == 2 and all(0 <= t < cfg.vocab for t in out)


def test_step_on_unprefilled_request_raises(small_cfg):
    """Regression: ``_next`` used to be injected dynamically by the prefill,
    so step() on a slot holding a hand-constructed (never-admitted) request
    died with AttributeError.  It is now a real Request field; the engine
    raises a clear error instead."""
    model = build_model(small_cfg)
    params, _ = model.init(jax.random.PRNGKey(0))
    eng = ServeEngine(small_cfg, params, batch_size=1, max_len=8,
                      pack_weights=False)
    req = Request(uid=0, prompt=np.array([1, 2], np.int32))
    assert req._next is None  # declared field, not injected
    eng.slots[0] = req        # bypass add_request on purpose
    with pytest.raises(RuntimeError, match="never .*prefilled"):
        eng.step()


@pytest.mark.parametrize("kv_quant", [None, "mixfp4"])
def test_batched_prefill_bitwise_matches_replay(small_cfg, kv_quant):
    """The batched prefill_slot entry must write bit-identical cache rows
    and produce the identical first token as the historical token-by-token
    decode replay — for the bf16 cache AND the packed cache (whose rows
    quantize identically whether written one at a time or as one slab)."""
    model = build_model(small_cfg)
    params, _ = model.init(jax.random.PRNGKey(7))
    prompt = np.array([9, 8, 7, 3, 1], np.int32)

    eng = ServeEngine(small_cfg, params, batch_size=2, max_len=32,
                      kv_quant=kv_quant)
    eng.add_request(Request(uid=0, prompt=prompt, max_new_tokens=1))
    batched_first = eng.slots[0]._next if eng.slots[0] else \
        eng.step()[0][1]  # max_new=1: slot may already have been freed
    batched_cache = eng.cache

    replay = ServeEngine(small_cfg, params, batch_size=2, max_len=32,
                         kv_quant=kv_quant)
    cache = replay.model.reset_slot(replay.cache, 0)
    lengths = np.zeros((2,), np.int32)
    logits = None
    for tok in prompt:
        toks = np.zeros((2,), np.int32)
        toks[0] = tok
        logits, cache = replay._decode(replay.params, jnp.asarray(toks),
                                       cache, jnp.asarray(lengths.copy()))
        lengths[0] += 1
    replay_first = int(jnp.argmax(logits[0]))

    assert batched_first == replay_first

    def slot0_rows(c):
        rows = {}
        for name, leaf in c.items():
            if isinstance(leaf, qtensor.QTensor):
                rows[f"{name}.payload"] = \
                    np.asarray(leaf.payload)[:, 0, :len(prompt)]
                rows[f"{name}.scales"] = \
                    np.asarray(leaf.scales)[:, 0, :len(prompt)]
            else:
                rows[name] = np.asarray(leaf)[:, 0, :len(prompt)]
        return rows

    got, want = slot0_rows(batched_cache), slot0_rows(cache)
    for name in want:
        np.testing.assert_array_equal(got[name], want[name],
                                      err_msg=f"cache[{name}] rows differ")


def test_packed_kv_cache_is_qtensor_and_small(small_cfg):
    """Acceptance: with kv_quant='mixfp4' the engine's KV cache is held as
    1-D-blocked QTensors (uint8 wire children, never a dense bf16 tensor)
    at <= 0.3x the bf16 cache bytes."""
    model = build_model(small_cfg)
    params, _ = model.init(jax.random.PRNGKey(0))
    packed = ServeEngine(small_cfg, params, batch_size=2, max_len=32,
                         kv_quant="mixfp4")
    dense = ServeEngine(small_cfg, params, batch_size=2, max_len=32)
    for name in ("k", "v"):
        leaf = packed.cache[name]
        assert isinstance(leaf, qtensor.QTensor)
        assert leaf.payload.dtype == jnp.uint8
        assert leaf.scales.dtype == jnp.uint8
        assert isinstance(leaf.layout, qtensor.BlockLayout1D)
    assert packed.kv_cache_bytes() <= 0.3 * dense.kv_cache_bytes()
    # decode leaves the cache packed (still QTensors after steps)
    packed.add_request(Request(uid=0, prompt=np.array([1, 2, 3], np.int32),
                               max_new_tokens=2))
    packed.step()
    packed.step()
    assert isinstance(packed.cache["k"], qtensor.QTensor)


def test_packed_kv_tokens_match_bf16_engine(small_cfg):
    """Greedy output streams of the packed-KV engine vs the bf16-cache
    engine (same packed weights).  KV quantization error is real but small;
    on these pinned seeds/prompts the argmax chain is identical."""
    model = build_model(small_cfg)
    for seed, prompt in [(0, [3, 1, 4, 1, 5]), (5, [9, 8, 7]),
                         (2, [2, 7, 1, 8])]:
        params, _ = model.init(jax.random.PRNGKey(seed))
        streams = {}
        for kv in ("bf16", "mixfp4"):
            eng = ServeEngine(small_cfg, params, batch_size=1, max_len=32,
                              kv_quant=kv)
            streams[kv] = _serve_one(eng, prompt, 5)
        assert streams["mixfp4"] == streams["bf16"], (seed, streams)


def test_packed_kv_slot_reuse_no_contamination(small_cfg):
    """Slot reuse on the packed cache: reset_slot zeroes the slot's packed
    bytes, so a reused-slot serve is bit-identical to a fresh engine."""
    model = build_model(small_cfg)
    params, _ = model.init(jax.random.PRNGKey(7))
    eng = ServeEngine(small_cfg, params, batch_size=1, max_len=32,
                      kv_quant="mixfp4")
    _serve_one(eng, [9, 8, 7, 6, 5], 6)        # occupies + frees slot 0
    reused = _serve_one(eng, [1, 2, 3], 4)     # admitted into the freed slot

    fresh = ServeEngine(small_cfg, params, batch_size=1, max_len=32,
                        kv_quant="mixfp4")
    assert reused == _serve_one(fresh, [1, 2, 3], 4)


def test_packed_kv_concurrent_matches_solo(small_cfg):
    """Per-slot packed decode at ragged lengths: the concurrent batch's
    next-token logits equal solo packed engines' (each slot reads only its
    own packed rows, at its own cache position)."""
    model = build_model(small_cfg)
    params, _ = model.init(jax.random.PRNGKey(11))
    eng = ServeEngine(small_cfg, params, batch_size=2, max_len=32,
                      kv_quant="mixfp4")
    pa = np.array([3, 1, 4, 1, 5], np.int32)
    pb = np.array([2, 7, 1, 8, 2, 8, 1], np.int32)
    eng.add_request(Request(uid=0, prompt=pa, max_new_tokens=4))
    eng.add_request(Request(uid=1, prompt=pb, max_new_tokens=4))
    logits2, _ = eng._decode(eng.params, jnp.array([7, 7], jnp.int32),
                             eng.cache, jnp.asarray(eng.lengths))
    for prompt, row in ((pa, 0), (pb, 1)):
        solo = ServeEngine(small_cfg, params, batch_size=1, max_len=32,
                           kv_quant="mixfp4")
        solo.add_request(Request(uid=9, prompt=prompt, max_new_tokens=4))
        logits1, _ = solo._decode(solo.params, jnp.array([7], jnp.int32),
                                  solo.cache, jnp.asarray(solo.lengths))
        np.testing.assert_allclose(np.asarray(logits2[row]),
                                   np.asarray(logits1[0]), atol=1e-4)


def test_packed_kv_odd_dh_block_count():
    """dh=48 (three 16-lane blocks per row) serves through the fused
    packed-KV path end to end."""
    cfg = ArchConfig(name="serve-dh48", family="dense", n_layers=2,
                     d_model=96, n_heads=2, n_kv_heads=2, d_ff=128,
                     vocab=64, attn_chunk=64,
                     quant=QuantConfig(method="mixfp4"))
    model = build_model(cfg)
    params, _ = model.init(jax.random.PRNGKey(1))
    eng = ServeEngine(cfg, params, batch_size=1, max_len=16,
                      kv_quant="mixfp4")
    assert eng.cache["k"].payload.shape[-1] == 24   # dh//2
    assert eng.cache["k"].scales.shape[-1] == 3     # dh//16
    out = _serve_one(eng, [3, 4, 5], 3)
    assert len(out) == 3 and all(0 <= t < 64 for t in out)


def test_packed_kv_validation():
    """kv_quant gating: non-transformer families and dh % 16 != 0 are
    rejected up front with clear errors."""
    ssm = ArchConfig(name="ssm-kv", family="ssm", n_layers=1, d_model=64,
                     vocab=64, ssm_state=8, ssm_expand=2,
                     quant=QuantConfig(method="mixfp4"))
    model = build_model(ssm)
    params, _ = model.init(jax.random.PRNGKey(0))
    with pytest.raises(ValueError, match="kv_quant"):
        ServeEngine(ssm, params, batch_size=1, max_len=8, kv_quant="mixfp4")

    dense = ArchConfig(name="dh-odd", family="dense", n_layers=1,
                       d_model=48, n_heads=2, n_kv_heads=2, d_ff=64,
                       vocab=64, quant=QuantConfig(method="mixfp4"))
    m2 = build_model(dense)
    p2, _ = m2.init(jax.random.PRNGKey(0))
    with pytest.raises(ValueError, match="head_dim"):
        ServeEngine(dense, p2, batch_size=1, max_len=8, kv_quant="mixfp4")

    with pytest.raises(ValueError, match="kv_quant"):
        ServeEngine(dense, p2, batch_size=1, max_len=8, kv_quant="int3")


def test_ssm_prefill_awkward_prompt_length():
    """Regression: the batched SSM prefill runs the chunked selective scan,
    which requires p_len % ssm_chunk == 0 once p_len exceeds the chunk —
    prefill_slot must fall back to one unchunked block for awkward prompt
    lengths (the replay path decoded at s=1 and never hit this)."""
    cfg = ArchConfig(name="ssm-chunk", family="ssm", n_layers=2, d_model=64,
                     vocab=64, ssm_state=8, ssm_expand=2, ssm_chunk=4,
                     quant=QuantConfig(method="mixfp4"))
    model = build_model(cfg)
    params, _ = model.init(jax.random.PRNGKey(3))
    eng = ServeEngine(cfg, params, batch_size=1, max_len=16)
    out = _serve_one(eng, [3, 1, 4, 1, 5, 9], 2)   # 6 % 4 != 0
    assert len(out) == 2 and all(0 <= t < 64 for t in out)


def test_single_prefill_dispatch_per_admission(small_cfg):
    """Acceptance: an admission costs exactly ONE prefill jit dispatch (the
    historical replay cost O(prompt_len) decode dispatches)."""
    model = build_model(small_cfg)
    params, _ = model.init(jax.random.PRNGKey(0))
    eng = ServeEngine(small_cfg, params, batch_size=2, max_len=32)
    for uid, prompt in enumerate(([5, 4, 3, 2, 1, 0], [1, 2])):
        eng.add_request(Request(uid=uid, prompt=np.asarray(prompt, np.int32),
                                max_new_tokens=2))
    assert eng.admissions == 2
    assert eng.prefill_dispatches == eng.admissions


def test_serving_bench_emits_expected_json(tmp_path):
    """The serving benchmark must emit BENCH_serving.json with the schema
    the CI smoke leg (and the perf trajectory) rely on — including the
    W4A16-vs-W4A4 section when --act-quant mixfp4 is passed."""
    import json
    from benchmarks import serving_bench
    out = tmp_path / "BENCH_serving.json"
    results = serving_bench.bench_serving(str(out), tiny=True,
                                          act_quant="mixfp4")
    on_disk = json.loads(out.read_text())
    assert on_disk.keys() == results.keys()
    for key in ("config", "cache_bytes", "decode_step_us", "prefill",
                "act_quant", "kv_pool"):
        assert key in on_disk, key
    assert set(on_disk["decode_step_us"]) == {"bf16", "mixfp4"}
    assert on_disk["cache_bytes"]["ratio"] <= 0.3
    assert on_disk["prefill"]["dispatches_per_admission"] == 1
    aq = on_disk["act_quant"]
    assert set(aq["decode_step_us"]) == {"w4a16", "w4a4", "w4a4_2pass"}
    assert 0.0 <= aq["token_agreement"] <= 1.0
    assert aq["logit_max_abs_delta"] >= 0.0
    # the fused path must match the two-dispatch composition and cost ONE
    # GEMM-path dispatch per projection (the composition costs two)
    assert aq["fused_matches_2pass"] is True
    assert aq["gemm_dispatches_per_projection"]["w4a16"] == 1.0
    assert aq["gemm_dispatches_per_projection"]["w4a4"] == 1.0
    assert aq["gemm_dispatches_per_projection"]["w4a4_2pass"] == 2.0
    # per-row scale32 / serve-time RHT accuracy section (CI smoke leg
    # asserts the full schema; here just the acceptance bits)
    ar = on_disk["act_rowscale"]
    assert set(ar["families"]) == {"dense", "moe", "ssm", "hybrid"}
    assert ar["all_families_not_worse"] is True, ar
    assert all(f["per_row_batch_invariant"] for f in
               ar["families"].values()), ar
    # the paged pool section: paged==fixed streams, real prefix hits
    kp = on_disk["kv_pool"]
    assert kp["paged_matches_fixed"] is True
    assert kp["max_concurrent_requests"] >= 1
    assert kp["prefix_hit_rate"] > 0.0
    assert kp["cache_hit_tokens_per_s"] > 0.0
    assert kp["pool"]["pages_active"] == 0


# ---------------------------------------------------------------------------
# W4A4 serving (act_quant="mixfp4"): quantized activations through the
# full FP4 MMA path (docs/serving.md)
# ---------------------------------------------------------------------------
def _family_cfg(family: str):
    """Tiny per-family configs + a pinned seed each (the oracle equality
    below is an argmax-chain comparison, so seeds are pinned the same way
    test_packed_kv_tokens_match_bf16_engine pins them)."""
    if family == "dense":
        return ArchConfig(name="w4a4-dense", family="dense", n_layers=2,
                          d_model=64, n_heads=2, n_kv_heads=2, d_ff=128,
                          vocab=64, attn_chunk=64,
                          quant=QuantConfig(method="mixfp4")), 0
    if family == "moe":
        from repro import configs
        return configs.smoke_config("qwen3-moe-30b-a3b").replace(
            quant=QuantConfig(method="mixfp4")), 5
    if family == "ssm":
        return ArchConfig(name="w4a4-ssm", family="ssm", n_layers=2,
                          d_model=64, vocab=64, ssm_state=8, ssm_expand=2,
                          quant=QuantConfig(method="mixfp4")), 3
    if family == "hybrid":
        return ArchConfig(name="w4a4-hyb", family="hybrid", n_layers=2,
                          d_model=64, vocab=64, n_heads=2, n_kv_heads=2,
                          d_ff=128, ssm_state=8, ssm_expand=2,
                          ssm_version=2, ssm_head_dim=32, attn_period=2,
                          attn_chunk=64,
                          quant=QuantConfig(method="mixfp4")), 2
    raise ValueError(family)


@pytest.mark.parametrize("family", ["dense", "moe", "ssm", "hybrid"])
def test_w4a4_stream_matches_dequantize_oracle(family):
    """Each W4A4 spelling is pinned against its wire-compatible oracle,
    per model family: the fused per-row path ('mixfp4') against the
    'mixfp4-2pass-rowscale' composition (quantize_rows(per_row=True) ->
    W4A4 kernel — SAME per-row bytes, independent dispatch structure), and
    the legacy per-tensor composition ('mixfp4-2pass') against the
    dequantize-then-W4A16 oracle ('mixfp4-qdq' — SAME per-tensor bytes,
    decoded in the kernel's factored-scale form through the W4A16 kernel).
    The per-row and per-tensor pairs quantize with DIFFERENT scale32
    policies, so only within-pair equality is exact."""
    cfg, seed = _family_cfg(family)
    params, _ = build_model(cfg).init(jax.random.PRNGKey(seed))
    streams = {}
    for aq in ("mixfp4", "mixfp4-2pass-rowscale", "mixfp4-2pass",
               "mixfp4-qdq"):
        eng = ServeEngine(cfg, params, batch_size=1, max_len=16,
                          act_quant=aq)
        streams[aq] = _serve_one(eng, [3, 4, 5], 4)
    assert streams["mixfp4"] == streams["mixfp4-2pass-rowscale"], \
        (family, streams)
    assert streams["mixfp4-2pass"] == streams["mixfp4-qdq"], \
        (family, streams)
    assert all(0 <= t < cfg.vocab for t in streams["mixfp4"])


def test_w4a4_concurrent_ragged_matches_oracle(small_cfg):
    """W4A4 continuous batching at per-slot ragged lengths: each slot's
    activations quantize at its own cache position, and the concurrent
    fused streams equal the per-row composition oracle's bitwise (same
    admissions, same batch shapes, same per-row wire bytes).  The old
    per-tensor batch-coupling caveat that used to live here is gone: a
    row's scale32 is derived from that row alone, so ragged batchmates
    cannot move anyone's bytes (see
    test_w4a4_stream_invariant_to_batchmates for the direct pin)."""
    model = build_model(small_cfg)
    params, _ = model.init(jax.random.PRNGKey(11))
    pa = np.array([3, 1, 4, 1, 5], np.int32)
    pb = np.array([2, 7, 1, 8, 2, 8, 1], np.int32)   # ragged lengths

    def both(aq):
        eng = ServeEngine(small_cfg, params, batch_size=2, max_len=32,
                          act_quant=aq)
        eng.add_request(Request(uid=0, prompt=pa, max_new_tokens=4))
        eng.add_request(Request(uid=1, prompt=pb, max_new_tokens=4))
        out = {0: [], 1: []}
        while any(s is not None for s in eng.slots):
            for uid, tok in eng.step():
                out[uid].append(tok)
        return out

    got, want = both("mixfp4"), both("mixfp4-2pass-rowscale")
    assert got == want
    assert all(len(v) == 4 for v in got.values()), got


def test_w4a4_stream_invariant_to_batchmates(small_cfg):
    """THE serving-level batch-independence pin: the same request, served
    under act_quant='mixfp4' next to two DIFFERENT batchmates (different
    content and length, one with a deliberately outlier-heavy prompt
    embedding path), emits the bitwise-identical token stream.  Under the
    old per-tensor activation scale this diverged — the batchmate's
    activation range moved the shared scale32 and with it the victim's
    wire bytes."""
    model = build_model(small_cfg)
    params, _ = model.init(jax.random.PRNGKey(13))
    victim = np.array([3, 1, 4, 1, 5], np.int32)

    def stream_of_victim(mate_prompt):
        eng = ServeEngine(small_cfg, params, batch_size=2, max_len=32,
                          act_quant="mixfp4")
        eng.add_request(Request(uid=0, prompt=victim, max_new_tokens=5))
        eng.add_request(Request(uid=1, prompt=mate_prompt,
                                max_new_tokens=5))
        out = {0: [], 1: []}
        while any(s is not None for s in eng.slots):
            for uid, tok in eng.step():
                out[uid].append(tok)
        assert len(out[0]) == 5
        return out[0]

    a = stream_of_victim(np.array([2, 7, 1, 8], np.int32))
    b = stream_of_victim(np.array([60, 61, 62, 63, 1, 2, 3], np.int32))
    assert a == b, (a, b)


def test_w4a4_composes_with_packed_kv(small_cfg):
    """The two packed hot paths compose: act_quant='mixfp4' +
    kv_quant='mixfp4' serves projections W4A4 AND reads the packed KV
    cache through the fused attention kernel, still matching the oracle
    run under the same cache format."""
    model = build_model(small_cfg)
    params, _ = model.init(jax.random.PRNGKey(7))
    streams = {}
    for aq in ("mixfp4", "mixfp4-2pass-rowscale"):
        eng = ServeEngine(small_cfg, params, batch_size=1, max_len=32,
                          kv_quant="mixfp4", act_quant=aq)
        assert isinstance(eng.cache["k"], qtensor.QTensor)
        streams[aq] = _serve_one(eng, [9, 8, 7], 5)
    assert streams["mixfp4"] == streams["mixfp4-2pass-rowscale"], streams


def test_w4a4_validation(small_cfg):
    """act_quant gating: unknown values and the packless combination are
    rejected up front with clear errors."""
    model = build_model(small_cfg)
    params, _ = model.init(jax.random.PRNGKey(0))
    with pytest.raises(ValueError, match="act_quant"):
        ServeEngine(small_cfg, params, batch_size=1, max_len=8,
                    act_quant="int4")
    with pytest.raises(ValueError, match="packed weights"):
        ServeEngine(small_cfg, params, batch_size=1, max_len=8,
                    act_quant="mixfp4", pack_weights=False)


def test_pack_projections_skips_non_projection_leaves():
    tree = {"layers": {"wq": jnp.ones((2, 32, 32)),
                       "ln_attn": jnp.ones((2, 32)),
                       "embed_like": jnp.ones((64, 32))},
            "embed": jnp.ones((64, 32))}
    packed, pb, db = pack_projections(tree)
    assert isinstance(packed["layers"]["wq"], qtensor.QTensor)
    assert isinstance(packed["layers"]["ln_attn"], jax.Array)
    assert isinstance(packed["embed"], jax.Array)
    assert pb > 0 and db == 2 * 32 * 32 * 2


# ---------------------------------------------------------------------------
# Fused quantize+GEMM serving (act_quant="mixfp4" -> one dispatch per
# projection) and prompt-length bucketing (PR-5)
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("family", ["dense", "moe"])
def test_w4a4_fused_stream_matches_two_dispatch(family):
    """act_quant='mixfp4' (fused per-row prologue) must emit the IDENTICAL
    token stream to 'mixfp4-2pass-rowscale' (quantize_rows(per_row=True)
    -> W4A4 kernel): the kernels are bitwise-identical, so even the argmax
    chain cannot diverge."""
    cfg, seed = _family_cfg(family)
    params, _ = build_model(cfg).init(jax.random.PRNGKey(seed))
    streams = {}
    for aq in ("mixfp4", "mixfp4-2pass-rowscale"):
        eng = ServeEngine(cfg, params, batch_size=1, max_len=16,
                          act_quant=aq)
        streams[aq] = _serve_one(eng, [3, 4, 5], 4)
    assert streams["mixfp4"] == streams["mixfp4-2pass-rowscale"], \
        (family, streams)


def test_w4a4_fused_one_dispatch_per_projection(small_cfg):
    """Tracing one decode step must count exactly ONE GEMM-path kernel
    entry per projection on the fused path, and two on the composition."""
    from repro.kernels import ops
    model = build_model(small_cfg)
    params, _ = model.init(jax.random.PRNGKey(0))

    def counts(aq):
        eng = ServeEngine(small_cfg, params, batch_size=2, max_len=16,
                          act_quant=aq)
        toks = jnp.zeros((2,), jnp.int32)
        lens = jnp.zeros((2,), jnp.int32)
        with ops.count_dispatches() as c:
            jax.eval_shape(
                lambda p, t, cc, l: eng.model.decode_step(
                    p, t, eng.ctx, cc, l),
                eng.params, toks, eng.cache, lens)
        return dict(c)

    c16 = counts(None)           # W4A16: one kernel per projection
    n_proj = sum(c16.values())
    assert set(c16) == {"gemm_w4a16"} and n_proj > 0, c16
    c_fused = counts("mixfp4")
    assert c_fused == {"gemm_w4a4_fused": n_proj}, (c_fused, n_proj)
    c_two = counts("mixfp4-2pass")
    assert c_two == {"quantize_rows": n_proj, "gemm_w4a4": n_proj}, c_two
    # the per-row composition has the same dispatch structure as the
    # legacy per-tensor one — only the scale32 shape differs
    c_rs = counts("mixfp4-2pass-rowscale")
    assert c_rs == {"quantize_rows": n_proj, "gemm_w4a4": n_proj}, c_rs


def test_prefill_bucketing_stream_bitwise_and_compile_reuse(small_cfg):
    """Bucketed prefill (W4A16 engine) must emit bitwise-identical streams
    to the unbucketed engine, while nearby prompt lengths share ONE
    compiled prefill shape (the compile-cache counters prove it)."""
    model = build_model(small_cfg)
    params, _ = model.init(jax.random.PRNGKey(3))
    prompts = [[3, 4, 5], [1, 2, 3, 4, 5], [9, 8, 7, 6], [2, 2]]

    def run(buckets):
        eng = ServeEngine(small_cfg, params, batch_size=2, max_len=32,
                          prefill_buckets=buckets)
        streams = {}
        pending = [Request(uid=i, prompt=np.array(p, np.int32),
                           max_new_tokens=4)
                   for i, p in enumerate(prompts)]
        while pending or any(s is not None for s in eng.slots):
            while pending and eng.add_request(pending[0]):
                pending.pop(0)
            for uid, tok in eng.step():
                streams.setdefault(uid, []).append(tok)
        return streams, eng

    bucketed, eng_b = run("pow2-64")
    plain, eng_p = run("off")
    assert bucketed == plain
    # lengths 3, 5, 4, 2 all bucket to 8: one compiled shape, three hits
    assert eng_b.prefill_compiles == 1, eng_b.prefill_compiles
    assert eng_b.prefill_cache_hits == 3, eng_b.prefill_cache_hits
    assert eng_p.prefill_compiles == 4   # one shape per distinct length
    assert eng_b.prefill_dispatches == eng_b.admissions == 4


def test_w4a4_act_rht_stream_matches_composition(small_cfg):
    """Serve-time RHT (``act_rht=True``): the fused engine (in-prologue
    grouped FWHT) must emit bitwise the per-row two-dispatch engine's
    stream — both rotate activations with ``hadamard.serve_signs`` on the
    packed K grid and serve weights rotated with the SAME signs at pack
    time, so the transform cancels in every dot product."""
    model = build_model(small_cfg)
    params, _ = model.init(jax.random.PRNGKey(19))
    streams = {}
    for aq in ("mixfp4", "mixfp4-2pass-rowscale"):
        eng = ServeEngine(small_cfg, params, batch_size=1, max_len=16,
                          act_quant=aq, act_rht=True)
        assert eng.act_rht
        streams[aq] = _serve_one(eng, [3, 4, 5], 4)
    assert streams["mixfp4"] == streams["mixfp4-2pass-rowscale"], streams
    # and the validation surface: RHT rides the per-row modes only
    with pytest.raises(ValueError, match="act_rht"):
        ServeEngine(small_cfg, params, batch_size=1, max_len=16,
                    act_quant="mixfp4-2pass", act_rht=True)
    with pytest.raises(ValueError, match="act_rht"):
        ServeEngine(small_cfg, params, batch_size=1, max_len=16,
                    act_rht=True)


def test_w4a4_prefill_bucketing_stream_bitwise(small_cfg):
    """Bucketed prefill under act_quant='mixfp4' must emit BITWISE the
    unbucketed engine's streams.  This is the regression the per-row
    activation scale32 fixes: with the old per-tensor scale the bucket's
    zero-padded suffix rows sat in the same amax reduction as the real
    prompt rows, so padding a prompt from 5 to 8 rows could move every
    real row's wire bytes.  Per-row scales make a padded row's existence
    invisible to its neighbours — exact equality, no tolerance."""
    model = build_model(small_cfg)
    params, _ = model.init(jax.random.PRNGKey(3))
    prompts = [[3, 4, 5], [1, 2, 3, 4, 5], [9, 8, 7, 6], [2, 2]]

    def run(buckets):
        eng = ServeEngine(small_cfg, params, batch_size=2, max_len=32,
                          act_quant="mixfp4", prefill_buckets=buckets)
        streams = {}
        pending = [Request(uid=i, prompt=np.array(p, np.int32),
                           max_new_tokens=4)
                   for i, p in enumerate(prompts)]
        while pending or any(s is not None for s in eng.slots):
            while pending and eng.add_request(pending[0]):
                pending.pop(0)
            for uid, tok in eng.step():
                streams.setdefault(uid, []).append(tok)
        return streams, eng

    bucketed, eng_b = run("pow2-64")
    exact, _ = run("off")
    assert bucketed == exact, (bucketed, exact)
    # the buckets really did pad: 3, 5, 4, 2 all share ONE compiled shape
    assert eng_b.prefill_compiles == 1, eng_b.prefill_compiles


def test_w4a4_chunked_prefill_matches_whole_prompt(small_cfg):
    """Chunked prefill under act_quant='mixfp4' must emit BITWISE the
    whole-prompt engine's streams: each chunk's rows quantize with their
    own per-row scales, so neither the chunk boundary placement nor the
    final chunk's padding can move a real row's bytes (with the per-tensor
    scale the per-chunk amax differed from the whole-prompt amax, so
    chunked W4A4 was only same-schedule deterministic)."""
    model = build_model(small_cfg)
    params, _ = model.init(jax.random.PRNGKey(17))
    prompts = [np.array([9, 8, 7, 3, 1], np.int32),
               (np.arange(30, dtype=np.int32) * 7 + 1) % small_cfg.vocab,
               np.array([1, 2], np.int32)]

    def drive(prefill_chunk):
        eng = ServeEngine(small_cfg, params, batch_size=2, max_len=48,
                          act_quant="mixfp4", prefill_chunk=prefill_chunk)
        reqs = [Request(uid=i, prompt=p, max_new_tokens=4)
                for i, p in enumerate(prompts)]
        eng.add_request(reqs[0])
        eng.add_request(reqs[1])      # chunked while req 0 decodes
        eng.step()
        eng.submit(reqs[2])           # queued behind the full batch
        guard = 0
        while any(len(r.generated) < 4 for r in reqs):
            eng.step()
            guard += 1
            assert guard < 200, "engine made no progress"
        return {r.uid: list(r.generated) for r in reqs}

    assert drive(4) == drive(None)


def test_prefill_bucketing_composes_with_packed_kv_and_w4a4(small_cfg):
    """Bucketing + packed KV + fused W4A4 compose: both engines bucket
    identically, so the fused stream still matches the per-row 2pass
    oracle."""
    model = build_model(small_cfg)
    params, _ = model.init(jax.random.PRNGKey(5))
    streams = {}
    for aq in ("mixfp4", "mixfp4-2pass-rowscale"):
        eng = ServeEngine(small_cfg, params, batch_size=1, max_len=32,
                          kv_quant="mixfp4", act_quant=aq,
                          prefill_buckets="pow2-64")
        streams[aq] = _serve_one(eng, [9, 8, 7], 5)
    assert streams["mixfp4"] == streams["mixfp4-2pass-rowscale"], streams


def test_bucket_len_ladder():
    assert ServeEngine.bucket_len(1, 512) == 8
    assert ServeEngine.bucket_len(8, 512) == 8
    assert ServeEngine.bucket_len(9, 512) == 16
    assert ServeEngine.bucket_len(33, 512) == 64
    assert ServeEngine.bucket_len(65, 512) == 128
    assert ServeEngine.bucket_len(130, 512) == 192   # 64-step above 64
    assert ServeEngine.bucket_len(100, 96) == 96     # clamped to max_len


def test_bucketing_rejected_for_recurrent_families():
    """Explicit bucketing on an SSM family must be rejected (padded suffix
    tokens advance the recurrent state); 'auto' silently disables it."""
    cfg = ArchConfig(name="b-ssm", family="ssm", n_layers=2, d_model=64,
                     vocab=64, ssm_state=8, ssm_expand=2,
                     quant=QuantConfig(method="mixfp4"))
    params, _ = build_model(cfg).init(jax.random.PRNGKey(0))
    with pytest.raises(ValueError, match="transformer"):
        ServeEngine(cfg, params, batch_size=1, max_len=16,
                    prefill_buckets="pow2-64")
    eng = ServeEngine(cfg, params, batch_size=1, max_len=16)
    assert eng.prefill_buckets is None
    assert _serve_one(eng, [3, 4, 5], 3)   # still serves fine, unbucketed


def test_act_quant_2pass_accepted_and_validated(small_cfg):
    params, _ = build_model(small_cfg).init(jax.random.PRNGKey(0))
    with pytest.raises(ValueError, match="act_quant"):
        ServeEngine(small_cfg, params, batch_size=1, max_len=8,
                    act_quant="mixfp4-3pass")
    with pytest.raises(ValueError, match="packed weights"):
        ServeEngine(small_cfg, params, batch_size=1, max_len=8,
                    act_quant="mixfp4-2pass", pack_weights=False)
    with pytest.raises(ValueError, match="prefill_buckets"):
        ServeEngine(small_cfg, params, batch_size=1, max_len=8,
                    prefill_buckets="pow3")

# ---------------------------------------------------------------------------
# Paged packed-KV pool: block-table serving, COW prefix caching (PR-6,
# serving.kvpool + docs/serving.md)
# ---------------------------------------------------------------------------
def _run_streams(eng, prompts, n_new=4):
    """Admit prompts as capacity frees up (continuous batching) and
    collect each request's full token stream."""
    pending = [Request(uid=i, prompt=np.asarray(p, np.int32),
                       max_new_tokens=n_new) for i, p in enumerate(prompts)]
    streams = {r.uid: [] for r in pending}
    guard = 0
    while pending or any(s is not None for s in eng.slots):
        while pending and eng.add_request(pending[0]):
            pending.pop(0)
        for uid, tok in eng.step():
            streams[uid].append(tok)
        guard += 1
        assert guard < 500, "engine made no progress"
    return streams


@pytest.mark.parametrize("family", ["dense", "moe", "hybrid"])
def test_paged_stream_matches_fixed(family):
    """Acceptance: the paged engine (block tables + pool + prefix caching)
    must emit token streams IDENTICAL to the fixed-slot packed-KV engine
    for every family with a KV cache.  The fixed-slot path is the bitwise
    oracle: the paged kernel reads the same wire bytes through block-table
    indirection, and suffix-only prefill after a prefix hit lands on the
    same logits (pinned KV_SCALE32 makes pages write-order independent)."""
    cfg, seed = _family_cfg(family)
    params, _ = build_model(cfg).init(jax.random.PRNGKey(seed))
    rng = np.random.RandomState(seed)
    shared = rng.randint(0, cfg.vocab, 20).tolist()
    prompts = [shared + rng.randint(0, cfg.vocab, 5).tolist(),
               shared + rng.randint(0, cfg.vocab, 3).tolist(),
               rng.randint(0, cfg.vocab, 9).tolist(),
               shared + rng.randint(0, cfg.vocab, 7).tolist()]
    fixed = ServeEngine(cfg, params, batch_size=2, max_len=64,
                        kv_quant="mixfp4")
    paged = ServeEngine(cfg, params, batch_size=2, max_len=64,
                        kv_quant="mixfp4", kv_pool=24, kv_page_len=16)
    sf = _run_streams(fixed, prompts)
    sp = _run_streams(paged, prompts)
    assert sf == sp, (family, sf, sp)
    rep = paged.pool_report()
    assert rep["pages_active"] == 0          # clean release of every page
    assert paged.max_concurrent == 2
    if family == "dense":
        assert rep["prefix_hits"] > 0 and rep["prefix_hit_tokens"] > 0
    else:
        # prefix sharing needs row-independent prefill: the hybrid's SSM
        # state recurs over the whole prompt, and MoE's capacity router
        # couples rows (cap = f(token count)) — both pools are plain
        # allocators, so every admission prefills in full and the stream
        # equality above is exact
        assert rep["prefix_hits"] == 0


def test_paged_prefix_sharing_ragged_concurrent(small_cfg):
    """Prefix sharing under ragged CONCURRENT admissions: requests of
    different lengths sharing an off-page-boundary prefix are admitted
    into both lanes at once, so shared pages are read by one lane while
    the other decodes.  Streams must still equal the fixed-slot engine,
    and the off-boundary tail must take the eager-COW path."""
    params, _ = build_model(small_cfg).init(jax.random.PRNGKey(13))
    rng = np.random.RandomState(13)
    shared = rng.randint(0, small_cfg.vocab, 17).tolist()   # 1 page + 1 row
    prompts = [shared + rng.randint(0, small_cfg.vocab, k).tolist()
               for k in (6, 2, 9, 4)]
    fixed = ServeEngine(small_cfg, params, batch_size=2, max_len=64,
                        kv_quant="mixfp4")
    paged = ServeEngine(small_cfg, params, batch_size=2, max_len=64,
                        kv_quant="mixfp4", kv_pool=32, kv_page_len=16)
    assert _run_streams(fixed, prompts) == _run_streams(paged, prompts)
    rep = paged.pool_report()
    assert rep["prefix_hit_tokens"] > 0
    assert rep["cow_copies"] > 0      # 17-token prefix: partial-page hits
    assert rep["pages_active"] == 0


def test_paged_admission_defers_until_pages_free(small_cfg):
    """A pool too small for two concurrent requests must DEFER the second
    admission (add_request -> False, nothing consumed) instead of failing,
    then admit it once the first request's pages release."""
    params, _ = build_model(small_cfg).init(jax.random.PRNGKey(4))
    # 3 usable pages; each request needs 2 (prompt 20 + 4 new -> 23 rows)
    eng = ServeEngine(small_cfg, params, batch_size=2, max_len=32,
                      kv_quant="mixfp4", kv_pool=4, kv_page_len=16)
    rng = np.random.RandomState(4)
    prompts = [rng.randint(0, small_cfg.vocab, 20) for _ in range(2)]
    streams = _run_streams(eng, prompts, n_new=4)
    assert all(len(v) == 4 for v in streams.values()), streams
    assert eng.kv_pool.alloc_failures > 0    # second admission deferred
    assert eng.max_concurrent == 1           # never actually concurrent
    assert eng.pool_report()["pages_active"] == 0


def test_paged_composes_with_w4a4_and_buckets(small_cfg):
    """kv_pool + act_quant='mixfp4' + bucketed prefill compose: the fused
    W4A4 stream over the paged cache still matches its 2pass oracle."""
    params, _ = build_model(small_cfg).init(jax.random.PRNGKey(7))
    streams = {}
    for aq in ("mixfp4", "mixfp4-2pass"):
        eng = ServeEngine(small_cfg, params, batch_size=1, max_len=32,
                          kv_quant="mixfp4", act_quant=aq,
                          kv_pool=8, kv_page_len=16,
                          prefill_buckets="pow2-64")
        streams[aq] = _serve_one(eng, [9, 8, 7], 5)
    assert streams["mixfp4"] == streams["mixfp4-2pass"], streams


def test_paged_validation(small_cfg):
    params, _ = build_model(small_cfg).init(jax.random.PRNGKey(0))
    with pytest.raises(ValueError, match="kv_quant"):
        ServeEngine(small_cfg, params, batch_size=1, max_len=32, kv_pool=8)
    with pytest.raises(ValueError, match="multiple of 16"):
        ServeEngine(small_cfg, params, batch_size=1, max_len=32,
                    kv_quant="mixfp4", kv_pool=8, kv_page_len=8)
    with pytest.raises(ValueError, match="multiple of 16"):
        ServeEngine(small_cfg, params, batch_size=1, max_len=40,
                    kv_quant="mixfp4", kv_pool=8, kv_page_len=16)
    ssm_cfg = ArchConfig(name="pv-ssm", family="ssm", n_layers=1,
                         d_model=64, vocab=64, ssm_state=8, ssm_expand=2,
                         quant=QuantConfig(method="mixfp4"))
    ssm_params, _ = build_model(ssm_cfg).init(jax.random.PRNGKey(0))
    with pytest.raises(ValueError, match="family"):
        ServeEngine(ssm_cfg, ssm_params, batch_size=1, max_len=32,
                    kv_quant="mixfp4", kv_pool=8)


def test_engine_prepads_weights_to_tuner_grid(small_cfg):
    """Satellite: packed projections are prepadded to the tile tuner's
    (k_pad, n_pad) grid at engine init, so off-grid shapes stop re-padding
    inside every jitted call.  prepad_for_tiles must be a fixed point on
    the engine's weights, and the streams above prove bitwise safety."""
    from repro.serving.engine import _prepad_group, _prepad_tree
    params, _ = build_model(small_cfg).init(jax.random.PRNGKey(0))
    eng = ServeEngine(small_cfg, params, batch_size=2, max_len=32)
    again = _prepad_tree(eng.params, _prepad_group(eng.act_quant),
                         eng.batch_size)
    for a, b in zip(jax.tree.leaves(eng.params), jax.tree.leaves(again)):
        assert a is b    # prepad is idempotent: second pass is a no-op
