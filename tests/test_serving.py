"""Serving engine over packed QTensor weights: end-to-end decode through
qmm -> interpret-mode Pallas kernels, weight packing invariants, the empty-
prompt regression, and packed-weight checkpointing."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import qtensor
from repro.core.qgemm import QuantConfig
from repro.models.base import (ArchConfig, PROJECTION_KEYS, build_model,
                               pack_projections)
from repro.serving.engine import Request, ServeEngine


@pytest.fixture(scope="module")
def small_cfg():
    return ArchConfig(name="serve-test", family="dense", n_layers=2,
                      d_model=64, n_heads=2, n_kv_heads=2, d_ff=128,
                      vocab=64, attn_chunk=64,
                      quant=QuantConfig(method="mixfp4"))


@pytest.fixture(scope="module")
def engine(small_cfg):
    model = build_model(small_cfg)
    params, _ = model.init(jax.random.PRNGKey(0))
    return ServeEngine(small_cfg, params, batch_size=2, max_len=32)


def _collect_projection_leaves(node, out):
    if isinstance(node, dict):
        for k, v in node.items():
            if k in PROJECTION_KEYS:
                out.append((k, v))
            else:
                _collect_projection_leaves(v, out)
    return out


def test_projections_held_only_as_qtensors(engine):
    """Acceptance: projection weights live ONLY as packed QTensors — no
    dense bf16 copies retained in the engine's parameter tree."""
    leaves = _collect_projection_leaves(engine.params, [])
    assert leaves, "no projection leaves found"
    for k, v in leaves:
        assert isinstance(v, qtensor.QTensor), f"{k} is dense: {type(v)}"
        assert v.payload.dtype == jnp.uint8
    assert engine.compression > 3.5  # ~3.97x for 2-D 16x16 tiles vs bf16
    assert engine.packed_bytes < engine.dense_bytes / 3.5


def test_serve_end_to_end_from_packed_weights(engine):
    rng = np.random.RandomState(0)
    reqs = [Request(uid=i, prompt=rng.randint(0, 64, 4).astype(np.int32),
                    max_new_tokens=3) for i in range(2)]
    for r in reqs:
        assert engine.add_request(r)
    tokens = []
    for _ in range(8):
        out = engine.step()
        tokens.extend(out)
        if not any(s is not None for s in engine.slots):
            break
    assert len(tokens) == 6  # 2 requests x 3 new tokens
    assert all(0 <= t < 64 for _, t in tokens)


def test_empty_prompt_rejected(small_cfg):
    """Regression: an empty prompt used to hit UnboundLocalError on
    `logits` inside _prefill_slot; it must be rejected up front."""
    model = build_model(small_cfg)
    params, _ = model.init(jax.random.PRNGKey(1))
    eng = ServeEngine(small_cfg, params, batch_size=1, max_len=8,
                      pack_weights=False)
    with pytest.raises(ValueError, match="empty prompt"):
        eng.add_request(Request(uid=0, prompt=np.zeros((0,), np.int32)))
    # the slot must not have been consumed by the failed admission
    assert eng.slots == [None]


def test_packed_weights_checkpoint_roundtrip(small_cfg, engine, tmp_path):
    engine.save_weights(str(tmp_path))
    model = build_model(small_cfg)
    params2, _ = model.init(jax.random.PRNGKey(42))  # different weights
    eng2 = ServeEngine(small_cfg, params2, batch_size=2, max_len=32)
    eng2.load_weights(str(tmp_path))
    a = jax.tree.leaves(engine.params)
    b = jax.tree.leaves(eng2.params)
    assert len(a) == len(b)
    for x, y in zip(a, b):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))
    # and it still decodes
    assert eng2.add_request(
        Request(uid=9, prompt=np.array([1, 2], np.int32), max_new_tokens=1))
    assert len(eng2.step()) == 1


def test_ssm_family_serves_from_packed_weights():
    """PROJECTION_KEYS covers the Mamba blocks too (in/x/dt/out_proj):
    the SSM family also decodes through qmm from packed QTensors."""
    cfg = ArchConfig(name="ssm-serve", family="ssm", n_layers=2, d_model=64,
                     vocab=64, ssm_state=8, ssm_expand=2,
                     quant=QuantConfig(method="mixfp4"))
    model = build_model(cfg)
    params, _ = model.init(jax.random.PRNGKey(3))
    eng = ServeEngine(cfg, params, batch_size=1, max_len=16)
    assert eng.packed_bytes > 0 and eng.compression > 3.0
    leaves = _collect_projection_leaves(eng.params, [])
    assert any(isinstance(v, qtensor.QTensor) for _, v in leaves)
    eng.add_request(Request(uid=0, prompt=np.array([3, 4, 5], np.int32),
                            max_new_tokens=2))
    out = eng.step() + eng.step()
    assert len(out) == 2 and all(0 <= t < 64 for _, t in out)


def _serve_one(eng, prompt, n_new):
    eng.add_request(Request(uid=0, prompt=np.asarray(prompt, np.int32),
                            max_new_tokens=n_new))
    toks = []
    while any(s is not None for s in eng.slots):
        toks.extend(t for _, t in eng.step())
    return toks


def test_slot_reuse_no_contamination(small_cfg):
    """Regression: a request admitted into a freed slot used to prefill at
    the dead request's cache offset and attend to its stale K/V.  The slot
    must now reset to position 0, so a reused-slot serve is bit-identical
    to a fresh engine."""
    model = build_model(small_cfg)
    params, _ = model.init(jax.random.PRNGKey(7))
    eng = ServeEngine(small_cfg, params, batch_size=1, max_len=32)
    _serve_one(eng, [9, 8, 7, 6, 5], 6)        # occupies + frees slot 0
    reused = _serve_one(eng, [1, 2, 3], 4)     # admitted into the freed slot

    fresh_eng = ServeEngine(small_cfg, params, batch_size=1, max_len=32)
    fresh = _serve_one(fresh_eng, [1, 2, 3], 4)
    assert reused == fresh


def test_concurrent_requests_match_solo(small_cfg):
    """Regression: per-slot cache positions — slot B's prefill must not
    clobber slot A's written K/V, and each slot decodes at its own length.

    Checks the exact invariant (A's written cache region is untouched by
    B's prefill) plus numeric equivalence of the concurrent next-token
    logits against solo engines; greedy token chains are NOT compared —
    a random-weight model is chaotic enough that benign batch-shape
    compile differences (~1e-7) can flip an argmax."""
    model = build_model(small_cfg)
    params, _ = model.init(jax.random.PRNGKey(11))
    eng = ServeEngine(small_cfg, params, batch_size=2, max_len=32)
    pa = np.array([3, 1, 4, 1, 5], np.int32)
    pb = np.array([2, 7, 1, 8, 2, 8, 1], np.int32)   # different length too
    ra = Request(uid=0, prompt=pa, max_new_tokens=4)
    rb = Request(uid=1, prompt=pb, max_new_tokens=4)
    assert eng.add_request(ra)
    ka = np.asarray(eng.cache["k"])[:, 0, :len(pa)].copy()
    va = np.asarray(eng.cache["v"])[:, 0, :len(pa)].copy()
    assert eng.add_request(rb)
    assert list(eng.lengths) == [len(pa), len(pb)]
    # B's prefill wrote only slot 1 (and slot 0's not-yet-valid position)
    np.testing.assert_array_equal(
        ka, np.asarray(eng.cache["k"])[:, 0, :len(pa)])
    np.testing.assert_array_equal(
        va, np.asarray(eng.cache["v"])[:, 0, :len(pa)])

    # next-token logits of the concurrent batch == solo engines' (each slot
    # attends only to its own history, at its own cache position); feed a
    # fixed probe token so the check is independent of prefill argmaxes
    logits2, _ = eng._decode(eng.params, jnp.array([7, 7], jnp.int32),
                             eng.cache, jnp.asarray(eng.lengths))
    for prompt, row in ((pa, 0), (pb, 1)):
        solo = ServeEngine(small_cfg, params, batch_size=1, max_len=32)
        solo.add_request(Request(uid=9, prompt=prompt, max_new_tokens=4))
        logits1, _ = solo._decode(solo.params, jnp.array([7], jnp.int32),
                                  solo.cache, jnp.asarray(solo.lengths))
        np.testing.assert_allclose(np.asarray(logits2[row]),
                                   np.asarray(logits1[0]), atol=1e-4)


def test_engine_emits_greedy_continuation(small_cfg):
    """Regression: the prefill's argmax used to be fed back but never
    emitted, shifting the output stream by one token.  The engine's stream
    must equal the raw greedy continuation of the prompt."""
    model = build_model(small_cfg)
    params, _ = model.init(jax.random.PRNGKey(21))
    prompt = [9, 8, 7]
    eng = ServeEngine(small_cfg, params, batch_size=1, max_len=32)
    got = _serve_one(eng, prompt, 4)

    ref_eng = ServeEngine(small_cfg, params, batch_size=1, max_len=32)
    cache, want = ref_eng.cache, []
    seq = list(prompt)
    for t in range(len(prompt) + 3):
        tok = seq[t] if t < len(seq) else want[-1]
        logits, cache = ref_eng._decode(
            ref_eng.params, jnp.array([tok], jnp.int32), cache,
            jnp.array([t], jnp.int32))
        if t >= len(prompt) - 1:
            want.append(int(jnp.argmax(logits[0])))
    assert got == want


def test_admission_invisible_to_active_ssm_slot():
    """Regression: Mamba's recurrent h/conv state advances for EVERY batch
    row each decode step, so another slot's prefill used to irreversibly
    corrupt an active slot's state (dummy token-0 steps are not overwritten
    like KV rows).  The engine must snapshot/restore other active slots
    around a prefill — an admission is bitwise-invisible to batchmates."""
    cfg = ArchConfig(name="ssm-serve2", family="ssm", n_layers=2, d_model=64,
                     vocab=64, ssm_state=8, ssm_expand=2,
                     quant=QuantConfig(method="mixfp4"))
    model = build_model(cfg)
    params, _ = model.init(jax.random.PRNGKey(3))
    eng = ServeEngine(cfg, params, batch_size=2, max_len=16)
    ra = Request(uid=0, prompt=np.array([3, 4, 5], np.int32),
                 max_new_tokens=8)
    eng.add_request(ra)
    eng.step()                                   # A is mid-generation
    before = {k: np.asarray(v).copy() for k, v in eng.cache.items()}
    eng.add_request(Request(uid=1, prompt=np.array([9, 8, 7, 6], np.int32),
                            max_new_tokens=2))
    for k in before:
        # slot 0's rows (batch axis 1) must be untouched by B's admission
        np.testing.assert_array_equal(
            before[k][:, 0], np.asarray(eng.cache[k])[:, 0],
            err_msg=f"cache[{k}] slot 0 mutated by another admission")


def test_request_exceeding_max_len_rejected(small_cfg):
    model = build_model(small_cfg)
    params, _ = model.init(jax.random.PRNGKey(0))
    eng = ServeEngine(small_cfg, params, batch_size=1, max_len=8,
                      pack_weights=False)
    with pytest.raises(ValueError, match="max_len"):
        eng.add_request(Request(uid=0, prompt=np.arange(6, dtype=np.int32),
                                max_new_tokens=4))
    assert eng.slots == [None]
    # boundary: the final token is never fed back, so prompt 6 + 3 new fits
    # exactly in max_len=8 (highest position written is 7)
    fits = Request(uid=1, prompt=np.arange(6, dtype=np.int32),
                   max_new_tokens=3)
    assert eng.add_request(fits)
    while any(s is not None for s in eng.slots):
        eng.step()
    assert len(fits.generated) == 3


def test_cold_restore_recomputes_stats(small_cfg, tmp_path):
    """A cold engine (pack_weights=False) that load_weights a packed
    checkpoint must report the restored tree's real storage stats."""
    model = build_model(small_cfg)
    params, _ = model.init(jax.random.PRNGKey(0))
    warm = ServeEngine(small_cfg, params, batch_size=1, max_len=16)
    warm.save_weights(str(tmp_path))
    cold = ServeEngine(small_cfg, params, batch_size=1, max_len=16,
                       pack_weights=False)
    assert cold.packed_bytes == 0 and cold.compression == 1.0
    cold.load_weights(str(tmp_path))
    assert cold.packed_bytes == warm.packed_bytes
    assert cold.dense_bytes == warm.dense_bytes
    assert cold.compression == pytest.approx(warm.compression)


def test_moe_family_serves_from_packed_experts():
    """Scan-stacked MoE expert weights ((n_layers, E, K, N), 4-D) must be
    packed too — the engine's 'projections held only as QTensors' contract
    covers the dominant weight term of a MoE model."""
    from repro import configs
    cfg = configs.smoke_config("qwen3-moe-30b-a3b").replace(
        quant=QuantConfig(method="mixfp4"))
    model = build_model(cfg)
    params, _ = model.init(jax.random.PRNGKey(5))
    eng = ServeEngine(cfg, params, batch_size=1, max_len=16)
    leaves = dict(_collect_projection_leaves(eng.params, []))
    for name in ("w_up", "w_gate", "w_down"):
        assert isinstance(leaves[name], qtensor.QTensor), name
    # expert stacks carry (n_layers, E) lead dims on the packed children
    assert leaves["w_up"].payload.ndim == 4
    out = _serve_one(eng, [3, 4, 5], 2)
    assert len(out) == 2 and all(0 <= t < cfg.vocab for t in out)


def test_pack_projections_skips_non_projection_leaves():
    tree = {"layers": {"wq": jnp.ones((2, 32, 32)),
                       "ln_attn": jnp.ones((2, 32)),
                       "embed_like": jnp.ones((64, 32))},
            "embed": jnp.ones((64, 32))}
    packed, pb, db = pack_projections(tree)
    assert isinstance(packed["layers"]["wq"], qtensor.QTensor)
    assert isinstance(packed["layers"]["ln_attn"], jax.Array)
    assert isinstance(packed["embed"], jax.Array)
    assert pb > 0 and db == 2 * 32 * 32 * 2
