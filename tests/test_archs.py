"""Per-architecture smoke tests: reduced config, one forward + train step on
CPU, asserting output shapes and finiteness (deliverable f)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.core.qgemm import QuantConfig
from repro.models.base import Ctx, build_model, param_count

ALL_ARCHS = configs.ARCH_IDS + configs.PAPER_IDS

# The full arch matrix takes 30-75s per cell on CPU; the fast tier keeps one
# representative per entry point and the rest run under `pytest -m slow`.
# Decode keeps one cell per FAMILY with a distinct decode path (dense KV,
# encdec cross-attention; ssm/moe decode is covered by test_serving.py) —
# the slow marker only gates redundant breadth, never unique coverage.
FAST_TRAIN_ARCHS = {"mixfp4_114m"}
FAST_DECODE_ARCHS = {"gemma2_2b", "seamless_m4t_medium"}


def _tiered(archs, fast: set):
    return [a if a in fast else pytest.param(a, marks=pytest.mark.slow)
            for a in archs]


def _smoke_batch(cfg, key, b=2, s=32):
    ks = jax.random.split(key, 3)
    tok = jax.random.randint(ks[0], (b, s), 0, cfg.vocab)
    batch = {"tokens": tok, "labels": jnp.roll(tok, -1, axis=1)}
    if cfg.family == "encdec":
        batch["src_embeds"] = jax.random.normal(
            ks[1], (b, s, cfg.d_model), jnp.bfloat16)
    if cfg.n_prefix_embeds:
        batch["prefix"] = jax.random.normal(
            ks[2], (b, cfg.n_prefix_embeds, cfg.d_model), jnp.bfloat16)
    return batch


@pytest.mark.parametrize("arch", _tiered(ALL_ARCHS, FAST_TRAIN_ARCHS))
def test_smoke_forward_and_train_step(arch):
    cfg = configs.smoke_config(arch)
    model = build_model(cfg)
    key = jax.random.PRNGKey(0)
    params, specs = model.init(key)
    assert jax.tree.structure(params) == jax.tree.structure(specs)
    assert param_count(params) > 0

    batch = _smoke_batch(cfg, key)
    ctx = Ctx(jax.random.PRNGKey(1), cfg.quant)

    logits, aux = jax.jit(lambda p, b: model.forward(p, b, ctx))(params, batch)
    exp_s = batch["tokens"].shape[1]
    assert logits.shape == (2, exp_s, cfg.vocab)
    assert np.isfinite(np.asarray(logits, np.float32)).all()

    loss, grads = jax.jit(jax.value_and_grad(
        lambda p, b: model.loss(p, b, ctx)))(params, batch)
    assert np.isfinite(float(loss))
    flat = jax.tree.leaves(grads)
    assert all(np.isfinite(np.asarray(g, np.float32)).all() for g in flat)
    assert any(float(jnp.abs(g).max()) > 0 for g in flat)


@pytest.mark.parametrize("arch", _tiered(
    ["gemma2_2b", "falcon_mamba_7b", "zamba2_1_2b", "seamless_m4t_medium",
     "qwen3_moe_30b_a3b"], FAST_DECODE_ARCHS))
def test_smoke_decode_path(arch):
    """Prefill then one decode step; decode logits finite and consistent."""
    cfg = configs.smoke_config(arch)
    model = build_model(cfg)
    key = jax.random.PRNGKey(0)
    params, _ = model.init(key)
    ctx = Ctx(jax.random.PRNGKey(1), cfg.quant)

    b, s, max_len = 2, 16, 32
    batch = _smoke_batch(cfg, key, b=b, s=s)
    batch.pop("labels")
    cache = model.init_cache(b, max_len)
    logits, cache = jax.jit(
        lambda p, bt, c: model.prefill(p, bt, ctx, c))(params, batch, cache)
    assert logits.shape == (b, cfg.vocab)
    assert np.isfinite(np.asarray(logits, np.float32)).all()

    prefill_len = s + (cfg.n_prefix_embeds or 0)
    next_tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    logits2, cache = jax.jit(
        lambda p, t, c, l: model.decode_step(p, t, ctx, c, l))(
        params, next_tok, cache, jnp.int32(prefill_len))
    assert logits2.shape == (b, cfg.vocab)
    assert np.isfinite(np.asarray(logits2, np.float32)).all()


def test_decode_matches_forward_logits():
    """Teacher-forced decode must reproduce full-forward logits (gemma2 incl.
    local/global masks + softcaps).  bf16 isolates cache/mask correctness —
    under MixFP4 the per-tensor activation scale legitimately differs between
    a 1-token decode call and a full-sequence call (quantization noise, not a
    cache bug), which test_decode_quant_noise_bounded covers."""
    cfg = configs.smoke_config("gemma2_2b").replace(
        quant=QuantConfig(method="bf16"))
    model = build_model(cfg)
    params, _ = model.init(jax.random.PRNGKey(0))
    ctx = Ctx(jax.random.PRNGKey(1), cfg.quant)
    b, s = 1, 8
    tok = jax.random.randint(jax.random.PRNGKey(2), (b, s), 0, cfg.vocab)

    full_logits, _ = model.forward(params, {"tokens": tok}, ctx)

    cache = model.init_cache(b, s + 4)
    _, cache = model.prefill(params, {"tokens": tok[:, :4]}, ctx, cache)
    logits_steps = [full_logits[:, 3]]
    for i in range(4, s):
        lg, cache = model.decode_step(params, tok[:, i], ctx, cache,
                                      jnp.int32(i))
        if i < s - 1:
            logits_steps.append(lg)
    # positions 4..s-1 of the full forward vs decode steps
    dec = jnp.stack(logits_steps[1:], axis=1)
    ref = full_logits[:, 4:s - 1]
    np.testing.assert_allclose(np.asarray(dec), np.asarray(ref),
                               rtol=2e-2, atol=2e-2)


def test_decode_quant_noise_bounded():
    """Under MixFP4 the decode/forward divergence is bounded quantization
    noise: top-1 predictions agree and logit RMSE stays small relative to
    the logit scale."""
    cfg = configs.smoke_config("mixfp4_114m")
    model = build_model(cfg)
    params, _ = model.init(jax.random.PRNGKey(0))
    ctx = Ctx(jax.random.PRNGKey(1), cfg.quant)
    b, s = 1, 8
    tok = jax.random.randint(jax.random.PRNGKey(2), (b, s), 0, cfg.vocab)
    full_logits, _ = model.forward(params, {"tokens": tok}, ctx)
    cache = model.init_cache(b, s)
    _, cache = model.prefill(params, {"tokens": tok[:, :4]}, ctx, cache)
    lg, _ = model.decode_step(params, tok[:, 4], ctx, cache, jnp.int32(4))
    ref = full_logits[:, 4]
    scale = float(jnp.abs(ref).max()) + 1e-6
    rmse = float(jnp.sqrt(jnp.mean((lg - ref) ** 2))) / scale
    assert rmse < 0.25, f"decode quant noise too large: {rmse}"
    # random-init logits are near-tied; require decode's top-1 to sit in the
    # reference top-5 rather than an exact (noise-flippable) argmax match
    top5 = jax.lax.top_k(ref[0], 5)[1]
    assert int(jnp.argmax(lg)) in [int(i) for i in top5]


def test_full_configs_match_brief():
    """Spot-check the exact published numbers of the full configs."""
    c = configs.full_config("qwen3_moe_30b_a3b")
    assert (c.n_layers, c.d_model, c.n_heads, c.n_kv_heads) == (48, 2048, 32, 4)
    assert (c.n_experts, c.top_k, c.d_ff_expert, c.vocab) == (128, 8, 768, 151936)
    c = configs.full_config("phi3_medium_14b")
    assert (c.n_layers, c.d_model, c.n_heads, c.n_kv_heads, c.d_ff) == \
        (40, 5120, 40, 10, 17920)
    c = configs.full_config("falcon_mamba_7b")
    assert (c.n_layers, c.d_model, c.ssm_state, c.vocab) == (64, 4096, 16, 65024)
    c = configs.full_config("gemma2_2b")
    assert (c.softcap_attn, c.softcap_final, c.window) == (50.0, 30.0, 4096)
    c = configs.full_config("zamba2_1_2b")
    assert (c.n_layers, c.ssm_state, c.ssm_version) == (38, 64, 2)
    c = configs.full_config("starcoder2_15b")
    assert (c.d_model, c.n_heads, c.n_kv_heads, c.d_ff) == (6144, 48, 4, 24576)
