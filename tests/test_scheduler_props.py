"""Property tests (hypothesis): chunked prefill is bitwise whole-prompt.

The chunked-prefill scheduler (serving.scheduler) leans on two pinned
invariants — ``prefill_slot(start_pos=)`` writes only rows
``[start_pos, start_pos + true_len)`` and the packed KV cache quantizes
rows against the pinned per-layer KV_SCALE32, so writes are
write-order-independent.  If either regresses, chunking a prompt would
change the cache bytes or the decoded stream.  These properties drive
ONE request (no decode interleaving, so no junk scatters land during the
prefill) through a chunked engine and a whole-prompt oracle engine over
random prompt lengths and chunk budgets, and demand

* bitwise-identical KV cache rows ``[0, p_len)`` (raw payload/scale
  bytes for the packed cache, raw bf16 for the dense cache, gathered
  through the block table for the paged pool), and
* the identical greedy token stream (first token included).

Gated behind importorskip so a bare environment still runs the
deterministic suite in test_scheduler.py / test_server.py.
"""
import itertools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import qtensor  # noqa: E402
from repro.core.qgemm import QuantConfig  # noqa: E402
from repro.models.base import ArchConfig, build_model  # noqa: E402
from repro.serving.engine import (Request, RequestState,  # noqa: E402
                                  ServeEngine)

MAX_LEN = 32
N_NEW = 2
PAGE_LEN = 16

_CFG = ArchConfig(name="sched-props", family="dense", n_layers=2,
                  d_model=64, n_heads=2, n_kv_heads=2, d_ff=128,
                  vocab=64, attn_chunk=64,
                  quant=QuantConfig(method="mixfp4"))

# Engines are cached across hypothesis examples (slot reuse after drain is
# already pinned by test_serving.py::test_slot_reuse_no_contamination) so
# each (kv_quant, chunk) pair compiles its prefill executable exactly once.
_STATE: dict = {}
_uid = itertools.count(1)


def _params():
    if "params" not in _STATE:
        _STATE["params"] = build_model(_CFG).init(jax.random.PRNGKey(0))[0]
    return _STATE["params"]


def _engine(kv_quant, chunk, paged=False):
    key = (kv_quant, chunk, paged)
    if key not in _STATE:
        kw = {}
        if paged:
            kw.update(kv_pool=2 * (MAX_LEN // PAGE_LEN) * 2 + 1,
                      kv_page_len=PAGE_LEN)
        _STATE[key] = ServeEngine(_CFG, _params(), batch_size=2,
                                  max_len=MAX_LEN, kv_quant=kv_quant,
                                  prefill_chunk=chunk, **kw)
    return _STATE[key]


def _drive_one(eng, prompt):
    """Serve a single request to completion; return its greedy stream."""
    req = Request(uid=next(_uid), prompt=np.asarray(prompt, np.int32),
                  max_new_tokens=N_NEW)
    eng.add_request(req)
    toks, guard = [], 0
    while eng.has_work():
        toks.extend(t for _, t in eng.step())
        guard += 1
        assert guard < 500, "single-request drive wedged"
    assert req.state is RequestState.FINISHED, req.state
    return toks


def _fixed_rows(eng, p_len):
    """Slot-0 cache rows [0, p_len) as raw bytes (fixed-slot layouts)."""
    rows = {}
    for name, leaf in eng.cache.items():
        if isinstance(leaf, qtensor.QTensor):
            rows[f"{name}.payload"] = np.asarray(leaf.payload)[:, 0, :p_len]
            rows[f"{name}.scales"] = np.asarray(leaf.scales)[:, 0, :p_len]
        else:
            rows[name] = np.asarray(leaf)[:, 0, :p_len]
    return rows


def _paged_rows(eng, p_len):
    """Slot-0 logical rows [0, p_len) gathered through the block table."""
    bt = np.asarray(eng.cache["pages"])[0]
    pages = bt[(np.arange(p_len)) // PAGE_LEN]
    offs = np.arange(p_len) % PAGE_LEN
    rows = {}
    for name, leaf in eng.cache.items():
        if name == "pages":
            continue
        for part, arr in (("payload", leaf.payload), ("scales", leaf.scales)):
            slab = np.asarray(arr)                 # (L, P, page_len, Hkv, .)
            rows[f"{name}.{part}"] = slab[:, pages, offs]
    return rows


def _assert_rows_equal(got, want, label):
    assert got.keys() == want.keys()
    for name in want:
        np.testing.assert_array_equal(
            got[name], want[name],
            err_msg=f"[{label}] cache[{name}] rows differ")


@pytest.mark.parametrize("kv_quant", [None, "mixfp4"])
@settings(max_examples=6, deadline=None)
@given(p_len=st.integers(min_value=1, max_value=MAX_LEN - N_NEW - 1),
       chunk=st.sampled_from([1, 2, 3, 5, 8, 16]),
       seed=st.integers(min_value=0, max_value=2**20))
def test_chunked_prefill_bitwise_fixed_slot(kv_quant, p_len, chunk, seed):
    """Random (prompt length, chunk budget): the chunked engine's cache
    rows and stream are bitwise the whole-prompt oracle's — dense bf16
    cache and packed fixed-slot cache alike."""
    prompt = np.random.RandomState(seed).randint(
        0, _CFG.vocab, p_len).astype(np.int32)
    chunked = _engine(kv_quant, chunk)
    oracle = _engine(kv_quant, None)
    got_stream = _drive_one(chunked, prompt)
    got_rows = _fixed_rows(chunked, p_len)
    want_stream = _drive_one(oracle, prompt)
    want_rows = _fixed_rows(oracle, p_len)
    assert got_stream == want_stream, (p_len, chunk, seed)
    assert got_stream[0] == want_stream[0]   # first token, explicitly
    _assert_rows_equal(got_rows, want_rows,
                       f"kv={kv_quant} p_len={p_len} chunk={chunk}")


@settings(max_examples=6, deadline=None)
@given(p_len=st.integers(min_value=1, max_value=MAX_LEN - N_NEW - 1),
       chunk=st.sampled_from([3, 5, 8]),
       seed=st.integers(min_value=0, max_value=2**20))
def test_chunked_prefill_bitwise_paged(p_len, chunk, seed):
    """Same property through the paged pool: chunk writes land in the
    slot's private pages via the block table, and (because engines are
    reused across examples) later prompts can prefix-hit earlier ones —
    exercising the start_pos=shared_len suffix-chunk path too."""
    prompt = np.random.RandomState(seed).randint(
        0, _CFG.vocab, p_len).astype(np.int32)
    chunked = _engine("mixfp4", chunk, paged=True)
    oracle = _engine("mixfp4", None, paged=True)
    got_stream = _drive_one(chunked, prompt)
    got_rows = _paged_rows(chunked, p_len)
    want_stream = _drive_one(oracle, prompt)
    want_rows = _paged_rows(oracle, p_len)
    assert got_stream == want_stream, (p_len, chunk, seed)
    _assert_rows_equal(got_rows, want_rows,
                       f"paged p_len={p_len} chunk={chunk}")
    for eng in (chunked, oracle):
        assert eng.pool_report()["pages_active"] == 0


@pytest.mark.parametrize("family,kwargs", [
    ("ssm", dict(ssm_state=8, ssm_expand=2)),
    ("hybrid", dict(n_heads=2, n_kv_heads=2, d_ff=128, ssm_state=8,
                    ssm_expand=2, ssm_version=2, ssm_head_dim=32,
                    attn_period=2, attn_chunk=64)),
])
def test_ssm_hybrid_chunking_rejected(family, kwargs):
    """SSM/hybrid admissions cannot be chunked (the recurrent state has no
    start_pos resume path): the engine rejects prefill_chunk= with a typed
    error, and the model-level start_pos= entry is equally typed."""
    cfg = ArchConfig(name=f"sched-props-{family}", family=family,
                     n_layers=2, d_model=64, vocab=64,
                     quant=QuantConfig(method="mixfp4"), **kwargs)
    model = build_model(cfg)
    params, _ = model.init(jax.random.PRNGKey(0))
    with pytest.raises(ValueError, match="recurrent state.*no start_pos"):
        ServeEngine(cfg, params, batch_size=1, max_len=16, prefill_chunk=4)
    with pytest.raises(ValueError, match="start_pos.*transformer-only"):
        model.prefill_slot(params, jnp.zeros((1, 4), jnp.int32), None,
                           None, 0, start_pos=4)
