"""Appendix A reproduction + crest/QSNR utilities."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import analysis


def test_appendix_a_crossover_exact():
    """Eq. 31-33: kappa* = 2.224277301764024, R* = 0.007888089150418761,
    QSNR* = 21.03028189684982 dB."""
    kstar, rstar, qstar = analysis.qsnr_crossover()
    assert kstar == pytest.approx(2.224277301764024, abs=1e-12)
    assert rstar == pytest.approx(0.007888089150418761, rel=1e-10)
    assert qstar == pytest.approx(21.03028189684982, abs=1e-8)


def test_crossover_direction():
    """Below kappa*: NVINT4 better (lower R); above: NVFP4 better (App. A)."""
    kstar, _, _ = analysis.qsnr_crossover()
    assert analysis.r_nvint4(kstar - 0.5) < analysis.r_nvfp4(kstar - 0.5)
    assert analysis.r_nvint4(kstar + 0.5) > analysis.r_nvfp4(kstar + 0.5)


def test_crest_factor_basics():
    # constant-magnitude block: peak == rms -> kappa = 1
    x = jnp.ones((1, 16))
    assert float(analysis.crest_factor(x).squeeze()) == pytest.approx(1.0)
    # single spike: peak / rms = sqrt(16)
    y = jnp.zeros((1, 16)).at[0, 0].set(4.0)
    assert float(analysis.crest_factor(y).squeeze()) == pytest.approx(4.0)


def test_qsnr_scale_invariant():
    import jax
    x = jax.random.normal(jax.random.PRNGKey(0), (128,))
    noise = jax.random.normal(jax.random.PRNGKey(1), (128,)) * 0.01
    a = float(analysis.qsnr(x, x + noise))
    b = float(analysis.qsnr(10 * x, 10 * (x + noise)))
    assert a == pytest.approx(b, abs=1e-4)


def test_selection_fractions_sum_to_one():
    import jax
    x = jax.random.normal(jax.random.PRNGKey(2), (64, 128))
    f = analysis.selection_fractions(x, "mixfp4_e3")
    assert f.shape == (3,)
    assert f.sum() == pytest.approx(1.0)
