import os

# Tests run on the single real CPU device.  The 512-device override belongs
# ONLY to launch/dryrun.py (see system design); never set it here.
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import jax

jax.config.update("jax_enable_x64", False)
