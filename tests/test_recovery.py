"""Crash recovery, graceful drain, and the step watchdog — through the
real engine.

The storage contract is pinned host-side in tests/test_journal.py; here
the claims run end-to-end:

* **bitwise resume** — kill-and-recover at strided step boundaries
  (``crash_restart_sweep``) across fixed-slot, paged, chunked-prefill and
  per-row W4A4 engines: pre-crash tokens ++ post-recovery tokens must
  equal the fault-free oracle stream exactly, every request must reach a
  terminal state, and a paged pool must end with zero active pages,
* **drain** — ``begin_drain()`` closes admissions with the typed
  ``draining`` rejection while in-flight work finishes; the ledger
  snapshot is journaled durably; a blown drain deadline leaves survivors
  non-terminal in the journal for the NEXT process to recover (and that
  hand-off is itself bitwise),
* **watchdog** — sustained injected-slow steps on the virtual clock walk
  the degradation ladder deterministically: first strike degrades (the
  fused W4A4 engine drops to its bitwise 2-pass composition), the
  ``fail_after``-th consecutive strike fails the most starved request
  with the typed ``watchdog_timeout`` reason,
* **checkpoint pinning** — a journal that pins packed weights refuses to
  resume on an engine that never restored them (or restored different
  bytes), because bitwise resume is only promised under the same weights,
* **fail-open journaling** — a fatal ``journal_write`` fault disables the
  journal and keeps serving (counter, not outage).
"""
import os

import numpy as np
import pytest

import jax

from repro.core.qgemm import QuantConfig
from repro.models.base import ArchConfig, build_model
from repro.serving.engine import (EngineDrainingError, JournalError,
                                  Request, ServeEngine)
from repro.serving.faults import (FaultInjector, FaultRule, VirtualClock,
                                  crash_restart_sweep, drive)
from repro.serving.journal import RequestJournal, replay, scan_journal


@pytest.fixture(scope="module")
def small_cfg():
    return ArchConfig(name="recovery-test", family="dense", n_layers=2,
                      d_model=64, n_heads=2, n_kv_heads=2, d_ff=128,
                      vocab=64, attn_chunk=64,
                      quant=QuantConfig(method="mixfp4"))


@pytest.fixture(scope="module")
def params(small_cfg):
    return build_model(small_cfg).init(jax.random.PRNGKey(0))[0]


PROMPTS = [[1, 2, 3, 4], [5, 6, 7]]

# engine-shape configurations the bitwise-resume property must hold on:
# fixed-slot, paged, chunked-prefill, and the per-row W4A4 activation
# paths (fused and explicit 2-pass) — the recovery re-prefill must land
# byte-identical KV rows under every cache and quantization layout
CONFIGS = {
    "fixed": {},
    "paged": dict(kv_quant="mixfp4", kv_pool=9, kv_page_len=16),
    "chunked": dict(prefill_chunk=4),
    "w4a4-fused": dict(act_quant="mixfp4"),
    "w4a4-paged-chunked": dict(act_quant="mixfp4-2pass-rowscale",
                               kv_quant="mixfp4", kv_pool=9,
                               kv_page_len=16, prefill_chunk=4),
}


def _make_engine_factory(cfg, params, **kw):
    kw.setdefault("batch_size", 2)
    kw.setdefault("max_len", 32)

    def make_engine(faults=None, journal_dir=None):
        return ServeEngine(cfg, params, faults=faults,
                           journal_dir=journal_dir,
                           journal_sync="always", **kw)

    return make_engine


# ---------------------------------------------------------------------------
# tentpole: kill-and-recover bitwise, across engine configurations
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("config", sorted(CONFIGS))
def test_crash_recover_bitwise(small_cfg, params, tmp_path, config):
    """SIGKILL-equivalent crashes at strided step boundaries, then
    recovery over the same journal: every stream must be bitwise the
    uninterrupted oracle's, every request terminal, no leaked pool
    pages.  ``crash_restart_sweep`` raises listing violations."""
    make_engine = _make_engine_factory(small_cfg, params,
                                       **CONFIGS[config])
    rep = crash_restart_sweep(make_engine, PROMPTS,
                              journal_root=str(tmp_path),
                              max_new_tokens=4, crash_stride=2,
                              max_crashes=3)
    ran = [c for c in rep["crashes"] if not c.get("skipped")]
    assert ran, rep
    assert all(c["recovered"] + c["finalized"] >= 1 for c in ran), ran


def test_recover_finalizes_request_with_lost_terminal(small_cfg, params,
                                                      tmp_path):
    """A request whose token records already reach max_new_tokens but
    whose terminal record was lost in the unsynced tail is finalized
    FINISHED at recovery WITHOUT re-admission (re-decoding it would
    emit a duplicate stream to a client that already saw the end)."""
    j = RequestJournal(str(tmp_path), sync="always")
    j.append({"t": "submit", "uid": 5, "prompt": [1, 2, 3],
              "max_new_tokens": 3})
    for t in (7, 8, 9):
        j.append({"t": "token", "uid": 5, "tok": t})
    j.close()                        # note: no terminal record
    eng = ServeEngine(small_cfg, params, batch_size=2, max_len=32)
    rep = eng.recover(str(tmp_path))
    assert rep == {**rep, "requests": 1, "resumed": 0, "finalized": 1}
    req = eng.requests[5]
    assert str(req.state) == "FINISHED"
    assert req.finish_reason == "max_new_tokens"
    assert req.generated == [7, 8, 9]
    assert not eng.has_work()
    # ...and the finalization itself was journaled: a second recovery
    # sees the request terminal
    assert replay(scan_journal(j.path)[0]).requests[5].terminal


def test_recover_empty_journal_is_cold_start(small_cfg, params, tmp_path):
    eng = ServeEngine(small_cfg, params, batch_size=2, max_len=32)
    rep = eng.recover(str(tmp_path))
    assert rep["requests"] == rep["resumed"] == rep["finalized"] == 0
    # the engine is fully serviceable afterwards
    got = drive(eng, PROMPTS, max_new_tokens=3)
    oracle = drive(ServeEngine(small_cfg, params, batch_size=2,
                               max_len=32), PROMPTS, max_new_tokens=3)
    assert got["streams"] == oracle["streams"]


# ---------------------------------------------------------------------------
# graceful drain
# ---------------------------------------------------------------------------
def test_drain_rejects_typed_and_journals_ledger(small_cfg, params,
                                                 tmp_path):
    eng = ServeEngine(small_cfg, params, batch_size=2, max_len=32,
                      journal_dir=str(tmp_path), journal_sync="batch")
    for i, p in enumerate(PROMPTS):
        eng.submit(Request(uid=i, prompt=np.asarray(p, np.int32),
                           max_new_tokens=3))
    eng.step()
    eng.begin_drain()
    with pytest.raises(EngineDrainingError):
        eng.submit(Request(uid=99, prompt=np.asarray([1], np.int32),
                           max_new_tokens=1))
    assert eng.counters["rejected:draining"] == 1
    rep = eng.drain()
    assert rep["drained"] and rep["survivors"] == []
    assert rep["completed"] == len(PROMPTS)
    assert all(str(r.state) == "FINISHED" for r in eng.requests.values())
    # the ledger snapshot hit disk durably (forced fsync under 'batch')
    recs, _ = scan_journal(os.path.join(str(tmp_path),
                                        "requests.journal"))
    ledgers = [r for r in recs if r["t"] == "ledger"]
    assert len(ledgers) == 1
    assert ledgers[0]["survivors"] == []
    assert set(ledgers[0]["requests"]) == {"0", "1"}
    assert eng.journal.fsyncs >= 1


def test_drain_deadline_survivors_recovered_bitwise(small_cfg, params,
                                                    tmp_path):
    """A drain that blows its deadline leaves the stragglers non-terminal
    in the journal; the NEXT process recovers them and the stitched
    streams are still bitwise the uninterrupted run — the deploy-under-
    load story end to end."""
    oracle = drive(ServeEngine(small_cfg, params, batch_size=2,
                               max_len=32), PROMPTS, max_new_tokens=5)
    eng = ServeEngine(small_cfg, params, batch_size=2, max_len=32,
                      journal_dir=str(tmp_path), journal_sync="always")
    pre: dict = {i: [] for i in range(len(PROMPTS))}
    for i, p in enumerate(PROMPTS):
        eng.submit(Request(uid=i, prompt=np.asarray(p, np.int32),
                           max_new_tokens=5))
    for uid, tok in eng.step():
        pre[uid].append(tok)
    rep = eng.drain(deadline_ms=0.0)     # expires before another step
    assert not rep["drained"]
    assert sorted(rep["survivors"]) == [0, 1]
    # the dead process's ledger names the survivors for the next one
    recs, _ = scan_journal(os.path.join(str(tmp_path),
                                        "requests.journal"))
    assert [r for r in recs if r["t"] == "ledger"][-1]["survivors"] \
        == rep["survivors"]
    eng2 = ServeEngine(small_cfg, params, batch_size=2, max_len=32,
                       journal_dir=str(tmp_path), journal_sync="always")
    rec = eng2.recover()
    assert rec["resumed"] == 2
    post: dict = {i: [] for i in pre}
    while eng2.has_work():
        for uid, tok in eng2.step():
            post[uid].append(tok)
    for uid in pre:
        assert pre[uid] + post[uid] == oracle["streams"][uid], uid
        assert str(eng2.requests[uid].state) == "FINISHED"


# ---------------------------------------------------------------------------
# step watchdog
# ---------------------------------------------------------------------------
def test_watchdog_degrades_then_fails_deterministically(small_cfg, params):
    """Injected slow steps on the virtual clock: one overrun degrades
    (the fused W4A4 engine drops to the bitwise 2-pass composition),
    sustained overruns fail ONE request with the typed
    ``watchdog_timeout`` reason — and the survivor still finishes."""
    clock = VirtualClock()
    inj = FaultInjector(0, [FaultRule("decode", "slow", at=(1, 2, 3),
                                      delay_ms=500.0)], clock=clock)
    eng = ServeEngine(small_cfg, params, batch_size=2, max_len=32,
                      act_quant="mixfp4", faults=inj, clock=clock,
                      hung_step_budget_ms=100.0, watchdog_fail_after=2)
    got = drive(eng, PROMPTS, max_new_tokens=6)
    assert eng.counters["watchdog_degrades"] >= 1
    assert eng.act_quant == "mixfp4-2pass-rowscale"   # ladder rung fired
    assert eng.counters["watchdog_fails"] == 1
    assert eng.counters["failed:watchdog_timeout"] == 1
    states = sorted(str(s) for s in got["states"].values())
    assert states == ["FAILED", "FINISHED"]
    wd = eng.watchdog.report()
    assert wd["overruns"] == 3 and wd["fails"] == 1
    # degradation preserved the survivor's stream bitwise (fused and
    # 2-pass per-row W4A4 are the same bytes by construction)
    oracle = drive(ServeEngine(small_cfg, params, batch_size=2,
                               max_len=32, act_quant="mixfp4"),
                   PROMPTS, max_new_tokens=6)
    fin = next(u for u, s in got["states"].items()
               if str(s) == "FINISHED")
    assert got["streams"][fin] == oracle["streams"][fin]


def test_watchdog_quiet_run_never_fires(small_cfg, params):
    clock = VirtualClock()
    eng = ServeEngine(small_cfg, params, batch_size=2, max_len=32,
                      clock=clock, hung_step_budget_ms=100.0)
    drive(eng, PROMPTS, max_new_tokens=3)
    wd = eng.watchdog.report()
    assert wd["beats"] > 0 and wd["overruns"] == 0
    assert eng.counters["watchdog_degrades"] == 0
    assert eng.counters["watchdog_fails"] == 0


# ---------------------------------------------------------------------------
# journal <-> packed-checkpoint pinning
# ---------------------------------------------------------------------------
def test_recover_refuses_unpinned_and_mismatched_weights(small_cfg, params,
                                                         tmp_path):
    jdir = str(tmp_path / "journal")
    ckpt = str(tmp_path / "ckpt")
    eng = ServeEngine(small_cfg, params, batch_size=2, max_len=32,
                      journal_dir=jdir, journal_sync="always")
    eng.save_weights(ckpt, step=3)
    eng.submit(Request(uid=0, prompt=np.asarray([1, 2, 3], np.int32),
                       max_new_tokens=4))
    eng.step()
    # crash: abandon un-flushed.  A fresh engine that never restored the
    # pinned checkpoint must refuse to resume...
    cold = ServeEngine(small_cfg, params, batch_size=2, max_len=32)
    with pytest.raises(JournalError, match="never restored"):
        cold.recover(jdir)
    # ...one that restored a DIFFERENT step must refuse too...
    wrong = ServeEngine(small_cfg, params, batch_size=2, max_len=32)
    wrong.save_weights(str(tmp_path / "other"), step=9)
    with pytest.raises(JournalError, match="step"):
        wrong.recover(jdir)
    # ...and one that load_weights() the pinned step resumes bitwise.
    good = ServeEngine(small_cfg, params, batch_size=2, max_len=32)
    good.load_weights(ckpt, step=3)
    rep = good.recover(jdir)
    assert rep["resumed"] == 1
    stream = list(eng.requests[0].generated)
    while good.has_work():
        for uid, tok in good.step():
            stream.append(tok)
    oracle = drive(ServeEngine(small_cfg, params, batch_size=2,
                               max_len=32), [[1, 2, 3]],
                   max_new_tokens=4)
    assert stream == oracle["streams"][0]


# ---------------------------------------------------------------------------
# fail-open journaling
# ---------------------------------------------------------------------------
def test_journal_write_fault_fails_open(small_cfg, params, tmp_path):
    """A fatal journal-append fault disables journaling and keeps
    serving: durability loss is a counter, not an outage, and the
    streams stay bitwise the un-journaled run."""
    inj = FaultInjector(0, [FaultRule("journal_write", "error", at=(0,))])
    eng = ServeEngine(small_cfg, params, batch_size=2, max_len=32,
                      faults=inj, journal_dir=str(tmp_path),
                      journal_sync="always")
    got = drive(eng, PROMPTS, max_new_tokens=3)
    assert eng.journal is None
    assert eng.counters["journal_disabled"] == 1
    assert eng.counters["journal_write_failed"] >= 1
    assert all(str(s) == "FINISHED" for s in got["states"].values())
    oracle = drive(ServeEngine(small_cfg, params, batch_size=2,
                               max_len=32), PROMPTS, max_new_tokens=3)
    assert got["streams"] == oracle["streams"]
