"""Packed-checkpoint integrity: per-array payload/scale checksums and the
0x80 negative-zero-E4M3 scale-plane scan.

The MixFP4 format bit lives in the SIGN of the E4M3 scale byte, and the
packers canonicalize zero-magnitude scales to +0.0 (0x00) — so a 0x80
byte in a restored scale plane proves corruption even when every digest
verifies (the digest of corrupt bytes is self-consistent).  ``save_packed``
records per-array digests in the manifest; ``restore_packed`` recomputes
and compares them, and scans every scale plane for the non-canonical
byte, naming the offending array either way.
"""
import hashlib
import json
import os

import jax
import numpy as np
import pytest

from repro.checkpoint.manager import (CheckpointManager, packed_checksums,
                                      verify_packed_tree)
from repro.core import qtensor


@pytest.fixture()
def packed_tree():
    w = jax.random.normal(jax.random.PRNGKey(0), (64, 48)) * 0.3
    v = jax.random.normal(jax.random.PRNGKey(1), (32, 32)) * 0.2
    # dict keys flatten sorted, and each QTensor contributes
    # (payload, scales, scale32): head/v owns leaves 0..2, layer/w 3..5
    return {"layer": {"w": qtensor.quantize(w)},
            "head": {"v": qtensor.quantize(v)}}


def _manifest_path(tmp_path):
    return os.path.join(str(tmp_path), "step_0000000000", "manifest.json")


def _tamper_leaf(tmp_path, leaf_index, mutate):
    """Apply ``mutate(flat_uint8) -> flat_uint8`` to one on-disk leaf and
    fix up its per-leaf sha so the generic leaf verification still passes
    — simulating corruption that happened BEFORE checksumming (in host
    memory during the save).  Returns the corrupted bytes' sha16."""
    d = os.path.dirname(_manifest_path(tmp_path))
    path = os.path.join(d, f"leaf_{leaf_index:05d}.npy")
    raw = mutate(np.load(path).copy())
    np.save(path, raw)
    digest = hashlib.sha256(raw.tobytes()).hexdigest()[:16]
    with open(_manifest_path(tmp_path)) as f:
        manifest = json.load(f)
    manifest["leaves"][leaf_index]["sha"] = digest
    with open(_manifest_path(tmp_path), "w") as f:
        json.dump(manifest, f)
    return digest


def _patch_packed_checksum(tmp_path, array, plane, digest):
    with open(_manifest_path(tmp_path)) as f:
        manifest = json.load(f)
    manifest["extra"]["packed_checksums"][array][plane] = digest
    with open(_manifest_path(tmp_path), "w") as f:
        json.dump(manifest, f)


def test_manifest_records_per_array_checksums(packed_tree, tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    mgr.save_packed(0, packed_tree, blocking=True)
    with open(_manifest_path(tmp_path)) as f:
        sums = json.load(f)["extra"]["packed_checksums"]
    assert set(sums) == {"layer/w", "head/v"}
    for entry in sums.values():
        assert set(entry) >= {"payload", "scales"}
        assert all(len(d) == 16 for d in entry.values())
    # and they match a fresh recomputation over the live tree
    assert sums == packed_checksums(packed_tree)


def test_roundtrip_verifies_clean(packed_tree, tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    mgr.save_packed(0, packed_tree, blocking=True)
    restored, extra = mgr.restore_packed()      # verify_packed=True default
    assert "packed_checksums" not in extra      # consumed by verification
    for x, y in zip(jax.tree.leaves(packed_tree),
                    jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


def test_restore_rejects_negative_zero_scale_byte(packed_tree, tmp_path):
    """A 0x80 scale byte must be rejected BY THE SCAN, not the digests:
    here every checksum in the manifest (leaf shas AND the per-array
    packed digests) is made consistent with the corrupted bytes, so only
    the non-canonical-byte invariant can catch it."""
    mgr = CheckpointManager(str(tmp_path))
    mgr.save_packed(0, packed_tree, blocking=True)

    def poison(flat):
        assert flat[0] != 0x80          # packers never emit negative zero
        flat[0] = 0x80
        return flat

    digest = _tamper_leaf(tmp_path, 1, poison)    # head/v scales plane
    _patch_packed_checksum(tmp_path, "head/v", "scales", digest)
    with pytest.raises(ValueError, match=r"head/v.+0x80"):
        mgr.restore_packed()
    # the scan can be bypassed explicitly for forensics
    restored, _ = mgr.restore_packed(verify_packed=False)
    bad = np.asarray(restored["head"]["v"].scales)
    assert bad.dtype == np.uint8 and bad.flat[0] == 0x80


def test_restore_rejects_checksum_mismatch(packed_tree, tmp_path):
    """A corrupted PAYLOAD byte (leaf sha fixed up, per-array digests
    stale) must raise naming the array and the plane — 0x11 keeps both
    nibbles valid FP4 codes, so nothing structural can catch it."""
    mgr = CheckpointManager(str(tmp_path))
    mgr.save_packed(0, packed_tree, blocking=True)

    def flip(flat):
        flat[0] ^= 0x11
        return flat

    _tamper_leaf(tmp_path, 0, flip)               # head/v payload plane
    with pytest.raises(IOError, match=r"head/v.+payload"):
        mgr.restore_packed()


def test_verify_packed_tree_direct(packed_tree):
    verify_packed_tree(packed_tree, packed_checksums(packed_tree))
    # tampered digest: the error names array + plane
    sums = packed_checksums(packed_tree)
    sums["layer/w"]["scales"] = "0" * 16
    with pytest.raises(IOError, match=r"layer/w.+scales"):
        verify_packed_tree(packed_tree, sums)
    # arrays absent from the checksum dict are skipped (forward compat:
    # a tree that grew an array after the checkpoint was cut)
    del sums["layer/w"]
    sums["head/v"] = packed_checksums(packed_tree)["head/v"]
    verify_packed_tree(packed_tree, sums)
