"""Unit tests for the host-side paged-KV pool bookkeeping (serving.kvpool).

Pure Python/numpy — no jax.  The device-side halves (page-slab cache
layout, block-table scatter/gather, the paged attention kernel) are
covered by tests/test_serving.py and tests/test_attn_kernel.py; here we
pin the allocator contract the engine relies on: refcounts, eager COW,
the >=1-suffix rule, LRU eviction of tree leaves, and rollback on
allocation failure.
"""
import pytest

from repro.serving.kvpool import Admission, KVPool


def test_ctor_validation():
    with pytest.raises(ValueError):
        KVPool(1, 4)  # page 0 is the trash page; need >= 2
    with pytest.raises(ValueError):
        KVPool(4, 0)


def test_pages_needed_counts_highest_written_row():
    pool = KVPool(8, 4)
    # rows written: prompt + max_new - 1 (the last sampled token is never
    # written back)
    assert pool.pages_needed(4, 1) == 1
    assert pool.pages_needed(4, 2) == 2   # row 4 spills into page 2
    assert pool.pages_needed(5, 4) == 2   # rows 0..7
    assert pool.pages_needed(1, 0) == 1   # max_new clamped to >= 1


def test_alloc_free_refcount_roundtrip():
    pool = KVPool(6, 4, enable_prefix=False)
    adm = pool.acquire([1, 2, 3, 4, 5], 4)  # rows 0..7 -> 2 pages
    assert adm == Admission(pages=[1, 2], shared_len=0, cow=None)
    assert pool.pages_active == 2 and pool.pages_free == 3
    pool.release(adm.pages)
    # enable_prefix=False never tree-registers, so release -> free list
    assert pool.pages_active == 0 and pool.pages_free == 5
    assert pool.pages_cached == 0
    # double release trips the refcount assertion
    with pytest.raises(AssertionError):
        pool.release(adm.pages)


def test_full_chunk_prefix_hit_shares_pages():
    pool = KVPool(8, 4)
    prompt = list(range(10))
    a = pool.acquire(prompt, 1)
    assert a.shared_len == 0 and a.pages == [1, 2, 3]
    pool.insert(prompt, a.pages)
    # same-prefix admission while A is still live: full chunks shared,
    # refcount 2 on the shared pages
    b = pool.acquire(prompt[:8] + [97, 98], 1)
    assert b.pages[:2] == [1, 2] and b.shared_len == 8
    assert b.cow is None  # tail diverges at the page boundary
    assert pool._ref[1] == 2 and pool._ref[2] == 2
    assert pool.prefix_hits == 2 and pool.prefix_hit_tokens == 8
    pool.release(a.pages)
    # shared pages still pinned by B
    assert pool._ref[1] == 1 and pool.pages_cached == 1  # page 3 -> LRU
    pool.release(b.pages)
    assert pool.pages_active == 0


def test_partial_hit_takes_eager_cow():
    pool = KVPool(6, 4)
    prompt = list(range(11))
    a = pool.acquire(prompt, 1)
    pool.insert(prompt, a.pages)   # pages 1,2 full chunks; 3 partial (8,9,10)
    pool.release(a.pages)
    # B shares a 9-token prefix: 2 full pages + 1 row of the partial page.
    # The partial hit COWs page 3's bytes into the fresh page 4.
    b = pool.acquire(prompt[:9] + [90, 91], 1)
    assert b == Admission(pages=[1, 2, 4], shared_len=9, cow=(3, 4))
    assert pool.cow_copies == 1
    # source page stays parked in the LRU (readable by future admissions),
    # the COW destination is owned by B alone
    assert pool._ref[3] == 0 and pool._ref[4] == 1
    pool.release(b.pages)
    assert pool.pages_active == 0


def test_one_suffix_token_always_prefills():
    pool = KVPool(8, 4)
    prompt = list(range(8))  # exactly two full chunks
    a = pool.acquire(prompt, 1)
    pool.insert(prompt, a.pages)
    pool.release(a.pages)
    # identical prompt: the match is capped at len-1 so the admission has
    # at least one token to prefill (logits to sample from).  Chunk 1 is a
    # full hit; chunk 2 can only match 3 of its 4 rows, so it COWs.
    b = pool.acquire(prompt, 1)
    assert b.shared_len < len(prompt)
    assert b == Admission(pages=[1, 3], shared_len=7, cow=(2, 3))
    pool.release(b.pages)


def test_lru_evicts_leaf_first_and_misses_recompute():
    pool = KVPool(5, 4)  # 4 usable pages
    p1 = list(range(8))          # chain: page1 -> page2
    a = pool.acquire(p1, 1)
    pool.insert(p1, a.pages)
    pool.release(a.pages)        # both parked in LRU
    assert pool.pages_cached == 2 and pool.pages_free == 2
    # a 4-page admission must evict; the chain leaf (page 2) goes first,
    # the parent (page 1) only once it too is a leaf
    b = pool.acquire([50 + i for i in range(13)], 4)
    assert b is not None and b.shared_len == 0
    assert pool.evictions == 2 and pool.pages_cached == 0
    pool.release(b.pages)
    # the evicted prefix now misses: full re-prefill
    c = pool.acquire(p1 + [99], 1)
    assert c.shared_len == 0


def test_acquire_failure_rolls_back_everything():
    pool = KVPool(5, 4)
    prompt = list(range(8))
    a = pool.acquire(prompt, 1)
    pool.insert(prompt, a.pages)
    # A still live: its 2 pages are pinned, 2 free remain.  A same-prefix
    # request needing 2 shared + 3 fresh pages cannot be covered even by
    # eviction (nothing evictable), and must consume NOTHING.
    before = (set(pool._free), list(pool._ref))
    b = pool.acquire(prompt + list(range(100, 107)), 4)
    assert b is None and pool.alloc_failures == 1
    assert (set(pool._free), list(pool._ref)) == before
    pool.release(a.pages)


def test_insert_existing_nodes_win():
    pool = KVPool(8, 4)
    prompt = list(range(9))
    # two identical prompts admitted concurrently, BEFORE either insert:
    # both get fully fresh pages (no tree yet)
    a = pool.acquire(prompt, 1)
    b = pool.acquire(prompt, 1)
    assert b.shared_len == 0 and not set(a.pages) & set(b.pages)
    pool.insert(prompt, a.pages)
    # B registers second: its (root, chunk) is already claimed by A's page,
    # so B's duplicates stay untracked and free on release
    pool.insert(prompt, b.pages)
    pool.release(b.pages)
    assert all(pool._ref[p] == 0 and p not in pool._parent
               for p in b.pages)
    assert all(p in pool._free for p in b.pages)
    pool.release(a.pages)
    assert pool.pages_active == 0
    # A's pages survive as servable prefix cache
    c = pool.acquire(prompt[:8] + [55], 1)
    assert c.shared_len == 8 and c.pages[:2] == a.pages[:2]
    pool.release(c.pages)


def test_stats_shape():
    pool = KVPool(6, 16)
    s = pool.stats()
    assert s["pages_total"] == 5 and s["page_len"] == 16
    for key in ("pages_free", "pages_cached", "pages_active", "occupancy",
                "prefix_hits", "prefix_hit_tokens", "evictions",
                "cow_copies", "alloc_failures"):
        assert key in s
    assert s["occupancy"] == 0.0
