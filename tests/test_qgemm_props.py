"""Property-based W4A4 GEMM tests (hypothesis): ``qmm(qt_x, qt_w)`` — both
operands on the wire format — against the ``kernels/ref.py`` E2M2-decode
oracle, over random shapes/padding, both micro-formats, and row/K blocks
straddling the kernel's tile boundaries.  Gated behind importorskip so a
bare environment still collects and runs the deterministic W4A4 tests in
test_qtensor.py / test_kernels.py."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import qtensor  # noqa: E402
from repro.core.qtensor import (BlockLayout2D, QuantSpec,  # noqa: E402
                                quantize)
from repro.kernels import ops, ref  # noqa: E402


def _operands(seed, m, k, n, method, mixed_rows=False):
    kx, kw = jax.random.split(jax.random.PRNGKey(seed))
    x = jax.random.normal(kx, (m, k)) * 2.0
    if mixed_rows:
        # Deterministic dual-format rows (random draws can land all-one-
        # type on small block counts).  Even rows tile {7,5,3,1}: every
        # block's absmax is 7, the E1M2 scale rounds to an exact power-
        # of-two multiple and the integer lattice represents the block
        # exactly, while E2M1 (scale 7/6) cannot — E1M2 wins the argmin.
        # Odd rows tile {6,.5,1.5,3}: exactly the E2M1 lattice at scale 1
        # (blockmax 6), while the E1M2 scale (6/7) misses — E2M1 wins.
        # The win margins are large, so the per-tensor scale's f32
        # rounding cannot flip either argmin.
        reps = (k + 3) // 4
        e1 = jnp.tile(jnp.array([7.0, 5.0, 3.0, 1.0]), reps)[:k]
        e2 = jnp.tile(jnp.array([6.0, 0.5, 1.5, 3.0]), reps)[:k]
        x = jnp.where((jnp.arange(m) % 2 == 0)[:, None],
                      e1[None, :], e2[None, :])
    w = jax.random.normal(kw, (k, n)) * 0.3
    qw = quantize(w, QuantSpec(method, BlockLayout2D()))
    qx = qtensor.quantize_rows(x, pad_to=2 * qw.payload.shape[0],
                               interpret=True)
    return qx, qw


def _assert_matches_oracle(y, qx, qw, n):
    """Format-ULP bound: the kernel and the oracle share the exact Fig. 9
    dual-codebook decode; they differ only in bf16 operand rounding of the
    scale32-folded activation (<= 2^-8 relative) and f32 accumulation
    order, so 2e-2 of the output range is the established kernel-vs-oracle
    tolerance (tests/test_kernels.py)."""
    want = ref.ref_gemm_w4a4(qx.payload, qx.scales, qx.scale32,
                             qw.payload, qw.scales, qw.scale32)[:, :n]
    scale = float(jnp.abs(want).max()) + 1e-6
    np.testing.assert_allclose(np.asarray(y) / scale,
                               np.asarray(want) / scale, atol=2e-2)


@settings(max_examples=12, deadline=None)
@given(st.integers(0, 10_000),
       st.integers(1, 33),        # M: incl. 1-row decode and prime rows
       st.integers(1, 70),        # K: mostly NOT multiples of 16 (padding)
       st.integers(1, 40),        # N: padded to 16-lane tiles
       st.sampled_from(["mixfp4", "nvfp4"]))
def test_w4a4_random_shapes_match_oracle(seed, m, k, n, method):
    """Random (M, K, N) incl. K/N padding onto the packed grid: qmm's
    dispatcher pads/tiles internally and slices back to logical shape."""
    qx, qw = _operands(seed, m, k, n, method)
    y = qtensor.qmm(qx, qw, interpret=True)
    assert y.shape == (m, n)
    _assert_matches_oracle(y, qx, qw, n)


@settings(max_examples=10, deadline=None)
@given(st.integers(0, 10_000),
       st.sampled_from([8, 16, 32]),     # bm: row tiles straddled by M=32
       st.sampled_from([16, 32, 64]),    # bk: 16-lane blocks per K tile
       st.sampled_from([16, 32]))        # bn
def test_w4a4_tile_sweep_matches_oracle(seed, bm, bk, bn):
    """Explicit kernel tilings with multi-tile grids in every dimension:
    activation row blocks straddle the (bm, bk) tile boundaries and the
    output block is revisited across the K loop."""
    m, k, n = 32, 64, 32
    qx, qw = _operands(seed, m, k, n, "mixfp4")
    y = ops.gemm_w4a4(qx.payload, qx.scales, qx.scale32,
                      qw.payload, qw.scales, qw.scale32,
                      bm=bm, bk=bk, bn=bn, interpret=True)
    _assert_matches_oracle(y, qx, qw, n)


@settings(max_examples=12, deadline=None)
@given(st.integers(0, 10_000),
       st.integers(2, 16),        # batch rows
       st.integers(8, 48),        # K incl. non-multiples of 16
       st.integers(0, 15))
def test_w4a4_per_row_batch_independence(seed, m, k, row_seed):
    """THE serving W4A4 contract (per-row scale32): a row's wire bytes and
    its GEMM output row are a pure function of that row — replacing every
    OTHER row in the batch, including with a 1000x outlier that would move
    a per-tensor amax by orders of magnitude, changes nothing.  Bitwise,
    not approximate.  The legacy per-tensor path provably violates this on
    the same inputs (its scale32 moves), which is the regression this
    property pins against."""
    i = row_seed % m
    kx, kr = jax.random.split(jax.random.PRNGKey(seed))
    x_a = jax.random.normal(kx, (m, k)) * 2.0
    other = jax.random.normal(kr, (m, k)) * 2.0
    other = other.at[m // 2, 0].set(1000.0)  # outlier in a non-victim row
    x_b = other.at[i].set(x_a[i])
    if i == m // 2:
        x_b = x_b.at[(i + 1) % m, 0].set(1000.0)
    _, qw = _operands(seed, m, k, 24, "mixfp4")
    pad = 2 * qw.payload.shape[0]
    qa = qtensor.quantize_rows(x_a, pad_to=pad, per_row=True, interpret=True)
    qb = qtensor.quantize_rows(x_b, pad_to=pad, per_row=True, interpret=True)
    assert qa.scale32.shape == (m,)
    np.testing.assert_array_equal(np.asarray(qa.payload[i]),
                                  np.asarray(qb.payload[i]))
    np.testing.assert_array_equal(np.asarray(qa.scales[i]),
                                  np.asarray(qb.scales[i]))
    np.testing.assert_array_equal(np.asarray(qa.scale32[i]),
                                  np.asarray(qb.scale32[i]))
    y_a = qtensor.qmm(qa, qw, interpret=True)
    y_b = qtensor.qmm(qb, qw, interpret=True)
    np.testing.assert_array_equal(np.asarray(y_a[i]), np.asarray(y_b[i]))
    # and the legacy per-tensor quantizer is batch-coupled on these exact
    # inputs: the injected outlier moves the shared scale32
    ta = qtensor.quantize_rows(x_a, pad_to=pad, interpret=True)
    tb = qtensor.quantize_rows(x_b, pad_to=pad, interpret=True)
    assert float(ta.scale32) != float(tb.scale32)


def test_w4a4_per_row_outlier_row_does_not_degrade_neighbors():
    """Accuracy motivation for per-row scale32.  The two-level format
    shields per-tensor mode from moderate outliers (the uint8 E4M3 block
    scales absorb ~2^8 of dynamic range), but an extreme spiky row pushes
    every quiet row's block scale into E4M3 underflow and their codes
    collapse toward zero.  Per-row scales are immune BY CONSTRUCTION: the
    quiet rows' wire bytes — and therefore their GEMM output rows — are
    bit-identical with and without the spike (their solo-quantization
    accuracy), while the per-tensor error blows up.  Weight error is shared
    by both paths (same qw), so the gap isolates the activation scale
    policy."""
    kx, kw_ = jax.random.split(jax.random.PRNGKey(7))
    m, k, n = 8, 64, 32
    x_quiet = jax.random.normal(kx, (m, k)) * 2.0
    x = x_quiet.at[0].multiply(1e6)
    w = jax.random.normal(kw_, (k, n)) * 0.3
    qw = quantize(w, QuantSpec("mixfp4", BlockLayout2D()))
    pad = 2 * qw.payload.shape[0]
    y_true = jnp.asarray(x, jnp.float32) @ qw.dequantize()
    q_t = qtensor.quantize_rows(x, pad_to=pad, interpret=True)
    q_r = qtensor.quantize_rows(x, pad_to=pad, per_row=True, interpret=True)
    q_solo = qtensor.quantize_rows(x_quiet, pad_to=pad, per_row=True,
                                   interpret=True)
    quiet = np.s_[1:]  # rows that did NOT spike
    # bitwise: the spike moved nothing in the quiet rows' per-row bytes
    np.testing.assert_array_equal(np.asarray(q_r.payload[quiet]),
                                  np.asarray(q_solo.payload[quiet]))
    np.testing.assert_array_equal(np.asarray(q_r.scales[quiet]),
                                  np.asarray(q_solo.scales[quiet]))
    np.testing.assert_array_equal(np.asarray(q_r.scale32[quiet]),
                                  np.asarray(q_solo.scale32[quiet]))
    y_t = qtensor.qmm(q_t, qw, interpret=True)
    y_r = qtensor.qmm(q_r, qw, interpret=True)
    y_solo = qtensor.qmm(q_solo, qw, interpret=True)
    np.testing.assert_array_equal(np.asarray(y_r[quiet]),
                                  np.asarray(y_solo[quiet]))
    ref_scale = float(jnp.abs(y_true[quiet]).max()) + 1e-6
    err_t = float(jnp.abs(y_t[quiet] - y_true[quiet]).max()) / ref_scale
    err_r = float(jnp.abs(y_r[quiet] - y_true[quiet]).max()) / ref_scale
    assert err_r < 0.1, err_r           # quiet rows keep 4-bit accuracy
    assert err_r < 0.5 * err_t, (err_r, err_t)


@settings(max_examples=10, deadline=None)
@given(st.integers(0, 10_000), st.integers(2, 24), st.integers(1, 60))
def test_w4a4_both_microformats_appear_and_match(seed, m, k):
    """Interleaved E1M2-winning and E2M1-winning rows force both type
    bits into the SAME activation tensor (guaranteed by construction, see
    _operands); the kernel's branch-free dual decode must still match the
    oracle — the dual-format selection is the paper's core claim, and a
    test that never sees an E1M2 block proves nothing."""
    qx, qw = _operands(seed, m, k, 32, "mixfp4", mixed_rows=True)
    types = np.asarray(qx.scales) >> 7
    # every FULL 16-lane block of an even row is E1M2 (a partial tail
    # block can degenerate — e.g. a lone 7 is exact under BOTH formats
    # and the tie prefers E2M1); every odd-row block is E2M1.
    nfull = k // 16
    if nfull:
        assert types[0::2, :nfull].min() == 1, types
    assert types[1::2].max() == 0, types
    y = qtensor.qmm(qx, qw, interpret=True)
    _assert_matches_oracle(y, qx, qw, 32)
