"""Property-based W4A4 GEMM tests (hypothesis): ``qmm(qt_x, qt_w)`` — both
operands on the wire format — against the ``kernels/ref.py`` E2M2-decode
oracle, over random shapes/padding, both micro-formats, and row/K blocks
straddling the kernel's tile boundaries.  Gated behind importorskip so a
bare environment still collects and runs the deterministic W4A4 tests in
test_qtensor.py / test_kernels.py."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import qtensor  # noqa: E402
from repro.core.qtensor import (BlockLayout2D, QuantSpec,  # noqa: E402
                                quantize)
from repro.kernels import ops, ref  # noqa: E402


def _operands(seed, m, k, n, method, mixed_rows=False):
    kx, kw = jax.random.split(jax.random.PRNGKey(seed))
    x = jax.random.normal(kx, (m, k)) * 2.0
    if mixed_rows:
        # Deterministic dual-format rows (random draws can land all-one-
        # type on small block counts).  Even rows tile {7,5,3,1}: every
        # block's absmax is 7, the E1M2 scale rounds to an exact power-
        # of-two multiple and the integer lattice represents the block
        # exactly, while E2M1 (scale 7/6) cannot — E1M2 wins the argmin.
        # Odd rows tile {6,.5,1.5,3}: exactly the E2M1 lattice at scale 1
        # (blockmax 6), while the E1M2 scale (6/7) misses — E2M1 wins.
        # The win margins are large, so the per-tensor scale's f32
        # rounding cannot flip either argmin.
        reps = (k + 3) // 4
        e1 = jnp.tile(jnp.array([7.0, 5.0, 3.0, 1.0]), reps)[:k]
        e2 = jnp.tile(jnp.array([6.0, 0.5, 1.5, 3.0]), reps)[:k]
        x = jnp.where((jnp.arange(m) % 2 == 0)[:, None],
                      e1[None, :], e2[None, :])
    w = jax.random.normal(kw, (k, n)) * 0.3
    qw = quantize(w, QuantSpec(method, BlockLayout2D()))
    qx = qtensor.quantize_rows(x, pad_to=2 * qw.payload.shape[0],
                               interpret=True)
    return qx, qw


def _assert_matches_oracle(y, qx, qw, n):
    """Format-ULP bound: the kernel and the oracle share the exact Fig. 9
    dual-codebook decode; they differ only in bf16 operand rounding of the
    scale32-folded activation (<= 2^-8 relative) and f32 accumulation
    order, so 2e-2 of the output range is the established kernel-vs-oracle
    tolerance (tests/test_kernels.py)."""
    want = ref.ref_gemm_w4a4(qx.payload, qx.scales, qx.scale32,
                             qw.payload, qw.scales, qw.scale32)[:, :n]
    scale = float(jnp.abs(want).max()) + 1e-6
    np.testing.assert_allclose(np.asarray(y) / scale,
                               np.asarray(want) / scale, atol=2e-2)


@settings(max_examples=12, deadline=None)
@given(st.integers(0, 10_000),
       st.integers(1, 33),        # M: incl. 1-row decode and prime rows
       st.integers(1, 70),        # K: mostly NOT multiples of 16 (padding)
       st.integers(1, 40),        # N: padded to 16-lane tiles
       st.sampled_from(["mixfp4", "nvfp4"]))
def test_w4a4_random_shapes_match_oracle(seed, m, k, n, method):
    """Random (M, K, N) incl. K/N padding onto the packed grid: qmm's
    dispatcher pads/tiles internally and slices back to logical shape."""
    qx, qw = _operands(seed, m, k, n, method)
    y = qtensor.qmm(qx, qw, interpret=True)
    assert y.shape == (m, n)
    _assert_matches_oracle(y, qx, qw, n)


@settings(max_examples=10, deadline=None)
@given(st.integers(0, 10_000),
       st.sampled_from([8, 16, 32]),     # bm: row tiles straddled by M=32
       st.sampled_from([16, 32, 64]),    # bk: 16-lane blocks per K tile
       st.sampled_from([16, 32]))        # bn
def test_w4a4_tile_sweep_matches_oracle(seed, bm, bk, bn):
    """Explicit kernel tilings with multi-tile grids in every dimension:
    activation row blocks straddle the (bm, bk) tile boundaries and the
    output block is revisited across the K loop."""
    m, k, n = 32, 64, 32
    qx, qw = _operands(seed, m, k, n, "mixfp4")
    y = ops.gemm_w4a4(qx.payload, qx.scales, qx.scale32,
                      qw.payload, qw.scales, qw.scale32,
                      bm=bm, bk=bk, bn=bn, interpret=True)
    _assert_matches_oracle(y, qx, qw, n)


@settings(max_examples=10, deadline=None)
@given(st.integers(0, 10_000), st.integers(2, 24), st.integers(1, 60))
def test_w4a4_both_microformats_appear_and_match(seed, m, k):
    """Interleaved E1M2-winning and E2M1-winning rows force both type
    bits into the SAME activation tensor (guaranteed by construction, see
    _operands); the kernel's branch-free dual decode must still match the
    oracle — the dual-format selection is the paper's core claim, and a
    test that never sees an E1M2 block proves nothing."""
    qx, qw = _operands(seed, m, k, 32, "mixfp4", mixed_rows=True)
    types = np.asarray(qx.scales) >> 7
    # every FULL 16-lane block of an even row is E1M2 (a partial tail
    # block can degenerate — e.g. a lone 7 is exact under BOTH formats
    # and the tie prefers E2M1); every odd-row block is E2M1.
    nfull = k // 16
    if nfull:
        assert types[0::2, :nfull].min() == 1, types
    assert types[1::2].max() == 0, types
    y = qtensor.qmm(qx, qw, interpret=True)
    _assert_matches_oracle(y, qx, qw, 32)
