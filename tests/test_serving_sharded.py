"""Sharded packed serving on a REAL >=2-device host mesh (ISSUE 3
acceptance): projection weights — including the 4-D scan-stacked MoE
expert stacks — held as sharded packed QTensors with model-axis
NamedShardings on payload/scales, no dense bf16 weight materialization,
decode bitwise-identical to the single-device packed path, and packed
checkpoints restoring straight into the sharded layout.

Multi-device CPU needs ``--xla_force_host_platform_device_count`` set
before jax initializes, so these run in a subprocess (same pattern as the
elastic-restore test) and are slow-tier; the degenerate 1-device versions
of the same invariants run in the fast tier (tests/test_sharding.py)."""
import os
import subprocess
import sys

import pytest

_COMMON = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=2"
os.environ.setdefault("JAX_PLATFORMS", "cpu")
import jax, jax.numpy as jnp, numpy as np
from repro.core import qtensor
from repro.core.qgemm import QuantConfig
from repro.launch.mesh import make_host_mesh
from repro.models.base import ArchConfig, build_model
from repro.serving.engine import Request, ServeEngine

assert jax.device_count() == 2
mesh = make_host_mesh(model=2)

def serve(eng, prompt, n):
    eng.add_request(Request(uid=0, prompt=np.asarray(prompt, np.int32),
                            max_new_tokens=n))
    toks = []
    while any(s is not None for s in eng.slots):
        toks.extend(t for _, t in eng.step())
    return toks

def assert_sharded_packed(eng):
    from repro.models.base import PROJECTION_KEYS
    n_model_sharded = 0
    def walk(node):
        nonlocal n_model_sharded
        for k, v in node.items():
            if k in PROJECTION_KEYS:
                assert isinstance(v, qtensor.QTensor), (k, type(v))
                assert v.payload.dtype == jnp.uint8
                spec = v.payload.sharding.spec
                assert v.payload.sharding == v.scales.sharding  # co-sharded
                if "model" in str(spec):
                    assert len(v.payload.sharding.device_set) == 2
                    n_model_sharded += 1
            elif isinstance(v, dict):
                walk(v)
    walk(eng.params)
    assert n_model_sharded > 0, "no projection carries a model-axis sharding"
"""


def _run(body: str, timeout: int = 600) -> str:
    env = dict(os.environ, PYTHONPATH="src")
    env.pop("JAX_PLATFORMS", None)
    out = subprocess.run([sys.executable, "-c", _COMMON + body],
                         capture_output=True, text=True, env=env,
                         cwd=os.path.dirname(os.path.dirname(
                             os.path.abspath(__file__))),
                         timeout=timeout)
    assert out.returncode == 0, out.stdout[-2000:] + out.stderr[-4000:]
    return out.stdout


@pytest.mark.slow
def test_sharded_serve_bitwise_dense_and_packed_kv():
    """Dense family, bf16 + packed-mixfp4 KV cache: the 2-device sharded
    engine's greedy stream AND raw decode logits are bitwise-identical to
    the single-device packed engine."""
    body = """
cfg = ArchConfig(name="shard-e2e", family="dense", n_layers=2, d_model=64,
                 n_heads=2, n_kv_heads=2, d_ff=128, vocab=64, attn_chunk=64,
                 quant=QuantConfig(method="mixfp4"))
params, _ = build_model(cfg).init(jax.random.PRNGKey(0))
for kv in (None, "mixfp4"):
    ref = ServeEngine(cfg, params, batch_size=1, max_len=32, kv_quant=kv)
    eng = ServeEngine(cfg, params, batch_size=1, max_len=32, kv_quant=kv,
                      mesh=mesh)
    assert_sharded_packed(eng)
    a = serve(ref, [3, 1, 4, 1, 5], 5)
    b = serve(eng, [3, 1, 4, 1, 5], 5)
    assert a == b, (kv, a, b)
    l0, _ = ref._decode(ref.params, jnp.array([7], jnp.int32), ref.cache,
                        jnp.asarray(ref.lengths))
    with mesh:
        l1, _ = eng._decode(eng.params, jnp.array([7], jnp.int32),
                            eng.cache, jnp.asarray(eng.lengths))
    np.testing.assert_array_equal(np.asarray(l0), np.asarray(l1))
print("SHARDED_BITWISE_OK")
"""
    assert "SHARDED_BITWISE_OK" in _run(body)


@pytest.mark.slow
def test_sharded_serve_moe_expert_stacks():
    """The 4-D scan-stacked MoE expert weights serve as sharded packed
    QTensors (whole experts per device, shipped packed through shard_map)
    with a bitwise-identical stream.  capacity_factor is raised so no
    token drops: per-shard capacity differs from single-device, and a
    drop on one path but not the other is the one legitimate divergence
    of the EP layout (docs/sharding.md)."""
    body = """
from repro import configs
cfg = configs.smoke_config("qwen3-moe-30b-a3b").replace(
    quant=QuantConfig(method="mixfp4"), capacity_factor=8.0)
params, _ = build_model(cfg).init(jax.random.PRNGKey(5))
ref = ServeEngine(cfg, params, batch_size=1, max_len=16)
eng = ServeEngine(cfg, params, batch_size=1, max_len=16, mesh=mesh)
assert_sharded_packed(eng)
wu = eng.params["layers"]["moe"]["w_up"]
assert wu.payload.ndim == 4                      # (L, E, Kp/2, Np)
assert "model" in str(wu.payload.sharding.spec)  # expert dim sharded
a = serve(ref, [3, 4, 5], 3)
b = serve(eng, [3, 4, 5], 3)
assert a == b, (a, b)
print("SHARDED_MOE_OK")
"""
    assert "SHARDED_MOE_OK" in _run(body)


@pytest.mark.slow
def test_sharded_w4a4_bitwise_single_device():
    """W4A4 on the 2-device mesh (ISSUE 4 acceptance): the engine's
    column-parallel default layout quantizes the replicated activation
    rows once, runs the W4A4 kernel per shard, and the greedy stream AND
    raw decode logits are bitwise-identical to the single-device W4A4
    engine.  Row-parallel (K-sharded) W4A4 splits the packed bytes at
    16-lane block granularity and psums in f32 — checked allclose against
    the single-device kernel (the psum reassociates the K reduction, so
    bitwise is not the contract there; docs/sharding.md)."""
    body = """
from jax.sharding import PartitionSpec as P
cfg = ArchConfig(name="shard-w4a4", family="dense", n_layers=2, d_model=64,
                 n_heads=2, n_kv_heads=2, d_ff=128, vocab=64, attn_chunk=64,
                 quant=QuantConfig(method="mixfp4"))
params, _ = build_model(cfg).init(jax.random.PRNGKey(0))
ref = ServeEngine(cfg, params, batch_size=1, max_len=32, act_quant="mixfp4")
eng = ServeEngine(cfg, params, batch_size=1, max_len=32, act_quant="mixfp4",
                  mesh=mesh)
assert_sharded_packed(eng)
a = serve(ref, [3, 1, 4, 1, 5], 5)
b = serve(eng, [3, 1, 4, 1, 5], 5)
assert a == b, (a, b)
l0, _ = ref._decode(ref.params, jnp.array([7], jnp.int32), ref.cache,
                    jnp.asarray(ref.lengths))
with mesh:
    l1, _ = eng._decode(eng.params, jnp.array([7], jnp.int32),
                        eng.cache, jnp.asarray(eng.lengths))
np.testing.assert_array_equal(np.asarray(l0), np.asarray(l1))

# row-parallel W4A4: packed activation bytes split along K at block
# granularity, partials psum in f32 — allclose to the unsharded kernel
x = jax.random.normal(jax.random.PRNGKey(1), (4, 64))
w = jax.random.normal(jax.random.PRNGKey(2), (64, 32)) * 0.3
qw = qtensor.quantize(w, qtensor.QuantSpec("mixfp4",
                                           qtensor.BlockLayout2D()))
qx = qtensor.quantize_rows(x, pad_to=2 * qw.payload.shape[0])
y0 = np.asarray(qtensor.qmm(qx, qw))
y_col = np.asarray(qtensor.qmm_sharded(
    qx, qw.with_sharding(mesh, P(None, "model")), mesh=mesh))
np.testing.assert_array_equal(y0, y_col)    # column-parallel: bitwise
y_row = np.asarray(qtensor.qmm_sharded(
    qx, qw.with_sharding(mesh, P("model", None)), mesh=mesh))
np.testing.assert_allclose(y_row, y0, rtol=1e-5, atol=1e-5)
print("SHARDED_W4A4_OK")
"""
    assert "SHARDED_W4A4_OK" in _run(body)


@pytest.mark.slow
def test_sharded_w4a4_moe_expert_stacks():
    """W4A4 through the sharded MoE path: the per-expert FFNs rebuild the
    serving activation format inside the EP shard_map (only the PRNG key
    ships across the boundary) and quantize each expert's token buffer;
    the stream matches the single-device W4A4 engine.  capacity_factor is
    raised so no token drops (the one legitimate EP divergence)."""
    body = """
from repro import configs
cfg = configs.smoke_config("qwen3-moe-30b-a3b").replace(
    quant=QuantConfig(method="mixfp4"), capacity_factor=8.0)
params, _ = build_model(cfg).init(jax.random.PRNGKey(5))
ref = ServeEngine(cfg, params, batch_size=1, max_len=16, act_quant="mixfp4")
eng = ServeEngine(cfg, params, batch_size=1, max_len=16, act_quant="mixfp4",
                  mesh=mesh)
assert_sharded_packed(eng)
a = serve(ref, [3, 4, 5], 3)
b = serve(eng, [3, 4, 5], 3)
assert a == b, (a, b)
print("SHARDED_W4A4_MOE_OK")
"""
    assert "SHARDED_W4A4_MOE_OK" in _run(body)


@pytest.mark.slow
def test_sharded_checkpoint_restores_into_layout(tmp_path):
    """A packed checkpoint restores STRAIGHT into the sharded layout
    (per-child NamedShardings derived from the manifest spec before any
    leaf bytes are read), leaves bit-identical, and still decodes; a
    single-device engine can read the same checkpoint."""
    body = f"""
d = {str(tmp_path)!r}
cfg = ArchConfig(name="shard-ckpt", family="dense", n_layers=2, d_model=64,
                 n_heads=2, n_kv_heads=2, d_ff=128, vocab=64, attn_chunk=64,
                 quant=QuantConfig(method="mixfp4"))
params, _ = build_model(cfg).init(jax.random.PRNGKey(0))
warm = ServeEngine(cfg, params, batch_size=1, max_len=16, mesh=mesh)
warm.save_weights(d)
cold = ServeEngine(cfg, params, batch_size=1, max_len=16, mesh=mesh)
cold.load_weights(d)
assert_sharded_packed(cold)
for x, y in zip(jax.tree.leaves(warm.params), jax.tree.leaves(cold.params)):
    np.testing.assert_array_equal(np.asarray(x), np.asarray(y))
assert serve(cold, [1, 2], 2) == serve(warm, [1, 2], 2)
single = ServeEngine(cfg, params, batch_size=1, max_len=16)
single.load_weights(d)
print("SHARDED_RESTORE_OK")
"""
    assert "SHARDED_RESTORE_OK" in _run(body)


@pytest.mark.slow
def test_docs_smoke_runner():
    """The CI docs-smoke leg's exact entry point: every fenced Python
    block in docs/*.md executes on the faked 2-device host."""
    env = dict(os.environ, PYTHONPATH="src")
    env.pop("JAX_PLATFORMS", None)
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    out = subprocess.run(
        [sys.executable, os.path.join(root, "tools", "docs_smoke.py")],
        capture_output=True, text=True, env=env, cwd=root, timeout=600)
    assert out.returncode == 0, out.stdout[-2000:] + out.stderr[-4000:]
    assert "0 failures" in out.stdout
