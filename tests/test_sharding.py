"""The QTensor PartitionSpec contract (docs/sharding.md) and the dense
spec-hygiene helpers: child-spec derivation, payload/scales co-sharding,
16-lane block-granularity rejection, serve-layout derivation, and the
``sanitize_specs`` edge cases (rank mismatch, non-divisible dims, tuple
axes).  Multi-device execution lives in tests/test_serving_sharded.py
(subprocess, forced host devices); everything here is pure spec logic
plus 1-device placement, so it stays in the fast tier."""
import types

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.core import qtensor
from repro.core.qtensor import BlockLayout1D, BlockLayout2D, QuantSpec
from repro.distributed import sharding as dsh
from repro.launch.mesh import make_host_mesh


def _fake_mesh(**shape):
    """sanitize_specs / serve_packed_specs only read ``mesh.shape`` — a
    namespace stands in for a real (device-backed) mesh."""
    return types.SimpleNamespace(shape=shape)


def _qt2d(k=64, n=96, seed=0):
    w = jax.random.normal(jax.random.PRNGKey(seed), (k, n)) * 0.1
    return qtensor.quantize(w, QuantSpec("mixfp4", BlockLayout2D()))


# ---------------------------------------------------------------------------
# sanitize_specs edge cases
# ---------------------------------------------------------------------------
def test_sanitize_specs_rank_mismatch():
    mesh = _fake_mesh(data=2, model=2)
    sds = {"w": jax.ShapeDtypeStruct((8, 4), jnp.float32)}
    # over-long: trailing entries beyond the rank are dropped
    out = dsh.sanitize_specs({"w": P("data", None, "model")}, sds, mesh)
    assert out["w"] == P("data", None)
    # short: right-padded with None
    out = dsh.sanitize_specs({"w": P("data")}, sds, mesh)
    assert out["w"] == P("data", None)
    # None spec -> fully replicated
    out = dsh.sanitize_specs({"w": None}, sds, mesh)
    assert out["w"] == P()


def test_sanitize_specs_non_divisible_replicates():
    mesh = _fake_mesh(data=4, model=3)
    sds = {"w": jax.ShapeDtypeStruct((8, 7), jnp.float32)}
    out = dsh.sanitize_specs({"w": P("data", "model")}, sds, mesh)
    assert out["w"] == P("data", None)  # 7 % 3 != 0 -> replicated dim


def test_sanitize_specs_tuple_axes():
    mesh = _fake_mesh(pod=2, data=4, model=2)
    sds = {"w": jax.ShapeDtypeStruct((16, 6), jnp.float32)}
    # ('pod','data') divides 16 (8 shards); ('pod','data') on 6 does not
    out = dsh.sanitize_specs(
        {"w": P(("pod", "data"), "model")}, sds, mesh)
    assert out["w"] == P(("pod", "data"), "model")
    sds2 = {"w": jax.ShapeDtypeStruct((6, 16), jnp.float32)}
    out2 = dsh.sanitize_specs(
        {"w": P(("pod", "data"), "model")}, sds2, mesh)
    assert out2["w"] == P(None, "model")


# ---------------------------------------------------------------------------
# QTensor.spec: child derivation + co-sharding invariant
# ---------------------------------------------------------------------------
def test_spec_2d_cosharded_children():
    qt = _qt2d()
    sp = qt.spec(P(None, "model"))
    assert sp["payload"] == sp["scales"] == P(None, "model")
    assert sp["scale32"] == P()
    sp = qt.spec(P("model", None))
    assert sp["payload"] == sp["scales"] == P("model", None)


def test_spec_short_and_overlong():
    qt = _qt2d()
    assert qt.spec(P("model"))["payload"] == P("model", None)
    assert qt.spec(None)["payload"] == P(None, None)
    with pytest.raises(ValueError, match="entries"):
        qt.spec(P(None, None, "model"))


def test_spec_stacked_batch_dims():
    """A scan-stacked weight (lead layer dim) maps its batch entry onto
    every child, incl. scale32."""
    qt = _qt2d(64, 96, 1)
    stacked = qtensor.stack([qt, qt])
    sp = stacked.spec(P(None, None, "model"))
    assert sp["payload"] == P(None, None, "model")
    assert sp["scales"] == P(None, None, "model")
    assert sp["scale32"] == P(None)
    # expert-style batch sharding
    sp = stacked.spec(P("model", None, None))
    assert sp["payload"] == P("model", None, None)
    assert sp["scale32"] == P("model")


def test_spec_1d_blocked_axis_moves_last():
    """BlockLayout1D specs are written in LOGICAL axis order; the blocked
    axis entry lands on the packed last dim of the children."""
    x = jax.random.normal(jax.random.PRNGKey(2), (8, 64))
    qt = qtensor.quantize(x, QuantSpec("mixfp4", BlockLayout1D(axis=-1)))
    sp = qt.spec(P("data", "model"), axis_sizes={"data": 2, "model": 2})
    assert sp["payload"] == P("data", "model")
    assert sp["scales"] == P("data", "model")


def test_spec_block_granularity_rejection():
    """Acceptance (ISSUE 3): a spec that would split a 16-lane scale block
    is rejected — for 2-D K and N dims and for the 1-D blocked axis."""
    qt = _qt2d(64, 96)
    with pytest.raises(ValueError, match="scale block"):
        qt.spec(P("model", None), axis_sizes={"model": 3})  # 64 % 48 != 0
    with pytest.raises(ValueError, match="scale block"):
        qt.spec(P(None, "model"), axis_sizes={"model": 4})  # 96 % 64 != 0
    # divisible sizes pass
    qt.spec(P("model", "model2"), axis_sizes={"model": 2, "model2": 2})

    x = jax.random.normal(jax.random.PRNGKey(3), (8, 32))
    q1 = qtensor.quantize(x, QuantSpec("mixfp4", BlockLayout1D(axis=-1)))
    with pytest.raises(ValueError, match="scale block"):
        q1.spec(P(None, "model"), axis_sizes={"model": 4})  # 32 % 64 != 0
    q1.spec(P("model", None), axis_sizes={"model": 4})  # lead dim: free


def test_spec_tuple_axes_granularity():
    qt = _qt2d(64, 96)
    # ('a','b') = 6 shards on N=96: 96 % (6*16) == 0 -> ok
    sp = qt.spec(P(None, ("a", "b")), axis_sizes={"a": 2, "b": 3})
    assert sp["payload"] == P(None, ("a", "b"))
    # 8 shards on N=96: 96 % (8*16) != 0 -> a block would split
    with pytest.raises(ValueError, match="scale block"):
        qt.spec(P(None, ("a", "b")), axis_sizes={"a": 4, "b": 2})
    with pytest.raises(ValueError, match="mesh has"):
        qt.spec(P(None, "ghost"), axis_sizes={"model": 2})


# ---------------------------------------------------------------------------
# with_sharding + mesh-aware qmm on the 1-device host mesh (fast tier:
# exercises the full dispatch path; real >=2-device runs are slow-tier)
# ---------------------------------------------------------------------------
def test_with_sharding_records_normalized_pspec():
    mesh = make_host_mesh(model=1)
    qt = _qt2d()
    sh = qt.with_sharding(mesh, P(None, "model"))
    assert sh.pspec == P(None, "model")
    assert qtensor.kn_partitions(sh) == (None, "model")
    assert "model" in str(sh.payload.sharding.spec)
    assert sh.payload.sharding == sh.scales.sharding
    np.testing.assert_array_equal(np.asarray(sh.dequantize()),
                                  np.asarray(qt.dequantize()))


def test_kn_partitions_survive_scan_slicing():
    """The logical pspec is static aux: scan slicing the stacked children
    keeps it, and the trailing (K, N) entries still read correctly."""
    mesh = make_host_mesh(model=1)
    stacked = qtensor.stack([_qt2d(), _qt2d(k=64, n=96, seed=9)])
    sh = stacked.with_sharding(mesh, P(None, None, "model"))

    def body(c, qt_layer):
        assert qtensor.kn_partitions(qt_layer) == (None, "model")
        return c, None

    jax.lax.scan(body, 0, sh)


@pytest.mark.parametrize("pspec", [P(None, "model"), P("model", None)])
def test_qmm_sharded_matches_qmm(pspec):
    mesh = make_host_mesh(model=1)
    qt = _qt2d(48, 96, 5)  # padded K: 48 -> 48 (16-mult), N 96
    sh = qt.with_sharding(mesh, pspec)
    x = jax.random.normal(jax.random.PRNGKey(6), (4, 48))
    y0 = qtensor.qmm(x, qt, interpret=True)
    y1 = qtensor.qmm_sharded(x, sh, mesh=mesh, interpret=True)
    np.testing.assert_allclose(np.asarray(y0), np.asarray(y1), rtol=1e-6)
    if pspec == P(None, "model"):  # column-parallel: bitwise contract
        np.testing.assert_array_equal(np.asarray(y0), np.asarray(y1))


def test_qmm_sharded_replicated_pspec_falls_through():
    mesh = make_host_mesh(model=1)
    qt = _qt2d()
    sh = qt.with_sharding(mesh, P())
    x = jax.random.normal(jax.random.PRNGKey(7), (4, 64))
    y = qtensor.qmm_sharded(x, sh, mesh=mesh, interpret=True)
    np.testing.assert_array_equal(
        np.asarray(y), np.asarray(qtensor.qmm(x, qt, interpret=True)))


@pytest.mark.parametrize("pspec", [P(None, "model"), P("model", None)])
def test_qmm_sharded_w4a4_matches_qmm(pspec):
    """qmm_sharded with a QTensor activation (W4A4): both operands packed
    inside the shard body; column-parallel is the bitwise contract, and a
    K spec ships payload/scale bytes split at block granularity."""
    mesh = make_host_mesh(model=1)
    qt = _qt2d(40, 96, 5)  # padded K: 40 -> 48 exercises the pad_to grid
    sh = qt.with_sharding(mesh, pspec)
    x = jax.random.normal(jax.random.PRNGKey(6), (4, 40))
    qx = qtensor.quantize_rows(x, pad_to=2 * qt.payload.shape[0],
                               interpret=True)
    y0 = qtensor.qmm(qx, qt, interpret=True)
    y1 = qtensor.qmm_sharded(qx, sh, mesh=mesh, interpret=True)
    np.testing.assert_allclose(np.asarray(y0), np.asarray(y1), rtol=1e-6)
    if pspec == P(None, "model"):  # column-parallel: bitwise contract
        np.testing.assert_array_equal(np.asarray(y0), np.asarray(y1))


def test_qmm_sharded_w4a4_rejects_off_grid_activation():
    """A QTensor activation NOT on the weight's packed Kp grid (e.g.
    quantized without pad_to against a padded weight) must be rejected,
    not silently contracted over mismatched lanes."""
    mesh = make_host_mesh(model=1)
    qt = _qt2d(40, 96, 5)                       # Kp = 48
    sh = qt.with_sharding(mesh, P(None, "model"))
    with pytest.raises(ValueError, match="packed K grid"):
        qtensor.qmm_sharded(
            qtensor.quantize_rows(
                jax.random.normal(jax.random.PRNGKey(8), (4, 32)),
                interpret=True),                # Kp = 32 != 48
            sh, mesh=mesh, interpret=True)




# ---------------------------------------------------------------------------
# serve layout derivation + placement helpers
# ---------------------------------------------------------------------------
def _packed_smoke_tree():
    from repro.models.base import pack_projections
    tree = {"layers": {
        "attn": {"wq": jnp.ones((2, 32, 64)),      # (L, K, N) stack
                 "ln": jnp.ones((2, 32))},
        "moe": {"w_up": jnp.ones((2, 4, 32, 64))}  # (L, E, K, N) experts
    }}
    packed, _, _ = pack_projections(tree)
    return packed


def test_serve_packed_specs_layout():
    packed = _packed_smoke_tree()
    specs = dsh.serve_packed_specs(packed, _fake_mesh(data=1, model=2))
    # 2-D stacks: column-parallel N-sharding
    assert specs["layers"]["attn"]["wq"] == P(None, None, "model")
    # expert stacks: whole experts over the model axis
    assert specs["layers"]["moe"]["w_up"] == P(None, "model", None, None)
    # dense leaves replicate
    assert specs["layers"]["attn"]["ln"] == P()


def test_serve_packed_specs_falls_back_to_replication():
    """Dims the axis cannot divide at block granularity replicate rather
    than error (the engine must come up on any mesh)."""
    packed = _packed_smoke_tree()
    specs = dsh.serve_packed_specs(packed, _fake_mesh(data=1, model=3))
    assert specs["layers"]["attn"]["wq"] == P()   # 64 % (3*16) != 0
    assert specs["layers"]["moe"]["w_up"] == P()  # 4 % 3 != 0


def test_shard_packed_tree_places_and_stamps():
    packed = _packed_smoke_tree()
    mesh = make_host_mesh(model=1)
    specs = dsh.serve_packed_specs(packed, mesh)
    placed = dsh.shard_packed_tree(packed, specs, mesh)
    wq = placed["layers"]["attn"]["wq"]
    assert wq.pspec == P(None, None, "model")
    assert "model" in str(wq.payload.sharding.spec)
    # dense leaves replicated, values untouched
    np.testing.assert_array_equal(
        np.asarray(placed["layers"]["attn"]["ln"]),
        np.asarray(packed["layers"]["attn"]["ln"]))


def test_packed_restore_shardings_from_tree_like():
    """The checkpoint skeleton (tree_like of a tree_spec) carries child
    ShapeDtypeStructs, enough to derive per-child NamedShardings without
    reading any leaf bytes."""
    from jax.sharding import NamedSharding
    packed = _packed_smoke_tree()
    spec_json = qtensor.tree_spec(packed)
    like = qtensor.tree_like(spec_json)
    wq = like["layers"]["attn"]["wq"]
    assert isinstance(wq.payload, jax.ShapeDtypeStruct)
    assert wq.payload.shape == packed["layers"]["attn"]["wq"].payload.shape
    mesh = make_host_mesh(model=1)
    specs = dsh.serve_packed_specs(like, mesh)
    shardings = dsh.packed_restore_shardings(like, specs, mesh)
    sh = shardings["layers"]["attn"]["wq"]
    assert isinstance(sh.payload, NamedSharding)
    assert "model" in str(sh.payload.spec)
    # leaf-for-leaf alignment with the value tree (what restore relies on)
    assert len(jax.tree.leaves(shardings)) == len(jax.tree.leaves(packed))


def test_tree_spec_roundtrips_pspec():
    mesh = make_host_mesh(model=1)
    qt = _qt2d().with_sharding(mesh, P(None, "model"))
    like = qtensor.tree_like(qtensor.tree_spec({"w": qt}))
    assert like["w"].pspec == P(None, "model")


def test_engine_sharded_matches_single_device_bitwise():
    """Fast-tier acceptance slice: the mesh engine (1-device host mesh —
    full qmm_sharded/shard_map dispatch, degenerate sharding) emits the
    same greedy stream as the single-device packed engine.  The >=2-device
    version of this invariant runs in tests/test_serving_sharded.py."""
    from repro.core.qgemm import QuantConfig
    from repro.models.base import ArchConfig, build_model
    from repro.serving.engine import Request, ServeEngine

    cfg = ArchConfig(name="shard-fast", family="dense", n_layers=2,
                     d_model=64, n_heads=2, n_kv_heads=2, d_ff=128,
                     vocab=64, attn_chunk=64,
                     quant=QuantConfig(method="mixfp4"))
    params, _ = build_model(cfg).init(jax.random.PRNGKey(0))

    def serve(eng):
        eng.add_request(Request(uid=0, prompt=np.array([3, 1, 4], np.int32),
                                max_new_tokens=4))
        toks = []
        while any(s is not None for s in eng.slots):
            toks.extend(t for _, t in eng.step())
        return toks

    ref = ServeEngine(cfg, params, batch_size=1, max_len=16)
    eng = ServeEngine(cfg, params, batch_size=1, max_len=16,
                      mesh=make_host_mesh(model=1))
    wq = eng.params["layers"]["attn"]["wq"]
    assert isinstance(wq, qtensor.QTensor) and wq.pspec is not None
    assert serve(ref) == serve(eng)


def test_engine_mesh_requires_packed():
    from repro.core.qgemm import QuantConfig
    from repro.models.base import ArchConfig, build_model
    from repro.serving.engine import ServeEngine

    cfg = ArchConfig(name="shard-nopack", family="dense", n_layers=1,
                     d_model=32, n_heads=2, n_kv_heads=2, d_ff=64,
                     vocab=32, quant=QuantConfig(method="mixfp4"))
    params, _ = build_model(cfg).init(jax.random.PRNGKey(0))
    with pytest.raises(ValueError, match="pack_weights"):
        ServeEngine(cfg, params, batch_size=1, max_len=8,
                    pack_weights=False, mesh=make_host_mesh(model=1))
