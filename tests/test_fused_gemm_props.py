"""Property-based fused quantize+GEMM tests (hypothesis): the W4A4 kernel
with the row quantizer fused into its prologue must be BITWISE-identical to
the two-dispatch ``quantize_rows(pad_to=Kp) -> qmm`` composition — over
random shapes/padding, random explicit tile choices, and activations that
force BOTH micro-formats (E2M1 and E1M2 blocks) through the prologue.

The composition is the oracle: it runs the independently-tested row
quantizer kernel and the packed-operand W4A4 kernel, so a bitwise match
proves the prologue reproduces the exact wire values (not just close
ones).  Gated behind importorskip so a bare environment still collects the
deterministic fused tests in test_kernels.py.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import qtensor  # noqa: E402
from repro.core.qtensor import (BlockLayout2D, QuantSpec,  # noqa: E402
                                quantize)
from repro.kernels import ops  # noqa: E402


def _operands(seed, m, k, n, method, mixed_rows=False):
    kx, kw = jax.random.split(jax.random.PRNGKey(seed))
    x = jax.random.normal(kx, (m, k)) * 2.0
    if mixed_rows:
        # Deterministic dual-format rows (see test_qgemm_props._operands):
        # even rows tile {7,5,3,1} — the E1M2 integer lattice wins the
        # argmin; odd rows tile {6,.5,1.5,3} — exactly the E2M1 lattice.
        reps = (k + 3) // 4
        e1 = jnp.tile(jnp.array([7.0, 5.0, 3.0, 1.0]), reps)[:k]
        e2 = jnp.tile(jnp.array([6.0, 0.5, 1.5, 3.0]), reps)[:k]
        x = jnp.where((jnp.arange(m) % 2 == 0)[:, None],
                      e1[None, :], e2[None, :])
    w = jax.random.normal(kw, (k, n)) * 0.3
    qw = quantize(w, QuantSpec(method, BlockLayout2D()))
    return x, qw


def _compose(x, qw):
    qx = qtensor.quantize_rows(x, pad_to=2 * qw.payload.shape[0],
                               interpret=True)
    return qtensor.qmm(qx, qw, interpret=True)


@settings(max_examples=12, deadline=None)
@given(st.integers(0, 10_000),
       st.integers(1, 33),        # M: incl. 1-row decode and prime rows
       st.integers(1, 70),        # K: mostly NOT multiples of 16 (padding)
       st.integers(1, 40),        # N: padded to 16-lane tiles
       st.sampled_from(["mixfp4", "nvfp4"]))
def test_fused_bitwise_random_shapes(seed, m, k, n, method):
    """Random (M, K, N) incl. K/N padding onto the packed grid: the fused
    dispatcher pads the dense rows where the composition pads packed
    bytes — both decode to the same exact zeros, and the shared tuner key
    guarantees the same grid, so the outputs are bit-equal f32."""
    x, qw = _operands(seed, m, k, n, method)
    y_fused = qtensor.qmm(x, qw, fuse_act_quant=True, interpret=True)
    assert y_fused.shape == (m, n)
    np.testing.assert_array_equal(np.asarray(y_fused),
                                  np.asarray(_compose(x, qw)))


@settings(max_examples=10, deadline=None)
@given(st.integers(0, 10_000),
       st.sampled_from([8, 16, 32]),     # bm: row tiles straddled by M=32
       st.sampled_from([16, 32, 64]),    # bk: 16-lane blocks per K tile
       st.sampled_from([16, 32]))        # bn
def test_fused_bitwise_tile_sweep(seed, bm, bk, bn):
    """Explicit kernel tilings with multi-tile grids in every dimension:
    the fused prologue re-quantizes the x tile for every N tile, which
    must not perturb a single bit vs quantizing once up front."""
    m, k, n = 32, 64, 32
    x, qw = _operands(seed, m, k, n, "mixfp4")
    xp, xs, xs32 = ops.quantize_rows(x, interpret=True)
    y_two = ops.gemm_w4a4(xp, xs, xs32, qw.payload, qw.scales, qw.scale32,
                          bm=bm, bk=bk, bn=bn, interpret=True)
    y_fused = ops.gemm_w4a4_fused(x, xs32, qw.payload, qw.scales,
                                  qw.scale32, bm=bm, bk=bk, bn=bn,
                                  interpret=True)
    np.testing.assert_array_equal(np.asarray(y_fused), np.asarray(y_two))


@settings(max_examples=10, deadline=None)
@given(st.integers(0, 10_000), st.integers(2, 24), st.integers(1, 60))
def test_fused_both_microformats_appear_and_match(seed, m, k):
    """Interleaved E1M2-winning and E2M1-winning rows force both type bits
    through the fused prologue's dual-candidate argmin; the prologue's
    byte-level selection is checked against the standalone quantizer and
    the GEMM output against the composition."""
    x, qw = _operands(seed, m, k, 32, "mixfp4", mixed_rows=True)
    qx = qtensor.quantize_rows(x, pad_to=2 * qw.payload.shape[0],
                               interpret=True)
    types = np.asarray(qx.scales) >> 7
    nfull = k // 16
    if nfull:
        assert types[0::2, :nfull].min() == 1, types
    assert types[1::2].max() == 0, types
    y_fused = qtensor.qmm(x, qw, fuse_act_quant=True, interpret=True)
    np.testing.assert_array_equal(
        np.asarray(y_fused),
        np.asarray(qtensor.qmm(qx, qw, interpret=True)))


@settings(max_examples=12, deadline=None)
@given(st.integers(0, 10_000),
       st.integers(1, 33),        # M: incl. 1-row decode and prime rows
       st.integers(1, 70),        # K: mostly NOT multiples of 16 (padding)
       st.integers(1, 40),        # N: padded to 16-lane tiles
       st.sampled_from(["mixfp4", "nvfp4"]))
def test_fused_per_row_bitwise_random_shapes(seed, m, k, n, method):
    """The serving default (per-row scale32): the fused prologue's
    (bm,) scale slab must reproduce ``quantize_rows(per_row=True)`` ->
    W4A4 kernel bit for bit over random shapes and padding — including
    padded rows, which ride under the all-zero guard scale 1.0 in both
    paths."""
    x, qw = _operands(seed, m, k, n, method)
    y_fused = qtensor.qmm(x, qw, fuse_act_quant=True, per_row_act=True,
                          interpret=True)
    qx = qtensor.quantize_rows(x, pad_to=2 * qw.payload.shape[0],
                               per_row=True, interpret=True)
    assert qx.scale32.shape == (m,)
    np.testing.assert_array_equal(
        np.asarray(y_fused),
        np.asarray(qtensor.qmm(qx, qw, interpret=True)))


@settings(max_examples=8, deadline=None)
@given(st.integers(0, 10_000),
       st.integers(1, 17),               # M
       st.sampled_from([16, 32, 48, 64]),  # K on the packed grid (RHT
                                           # needs K % group == 0)
       st.integers(1, 40))               # N
def test_fused_rht_prologue_bitwise_and_cancels(seed, m, k, n):
    """Serve-time RHT (``act_rht=``): the fused kernel's grouped-FWHT
    pre-quantization stage must equal ``ops.rht_rows`` -> per-row
    ``quantize_rows`` -> W4A4 kernel bitwise (shared ``fwht_rows_math``
    body, f32 elementwise, no contraction).  And because the weight was
    rotated with the SAME signs at pack time, the two rotations cancel in
    the dot product — the output stays a 4-bit-accurate estimate of
    x @ w, which would fail loudly if either side used different signs."""
    from repro.core import hadamard
    kx, kw = jax.random.split(jax.random.PRNGKey(seed))
    x = jax.random.normal(kx, (m, k)) * 2.0
    w = jax.random.normal(kw, (k, n)) * 0.3
    signs = hadamard.serve_signs(k)
    w_rot = hadamard.rht(w, signs, axis=0, group=16)
    qw = quantize(w_rot, QuantSpec("mixfp4", BlockLayout2D()))
    y_fused = qtensor.qmm(x, qw, fuse_act_quant=True, per_row_act=True,
                          act_rht_signs=signs, interpret=True)
    xr = ops.rht_rows(x, signs, group=16, interpret=True)
    qx = qtensor.quantize_rows(xr, pad_to=2 * qw.payload.shape[0],
                               per_row=True, interpret=True)
    np.testing.assert_array_equal(
        np.asarray(y_fused),
        np.asarray(qtensor.qmm(qx, qw, interpret=True)))
    want = np.asarray(x @ w)
    scale = np.abs(want).max() + 1e-6
    assert np.abs(np.asarray(y_fused) - want).max() / scale < 0.5


@settings(max_examples=6, deadline=None)
@given(st.integers(0, 10_000))
def test_fused_pinned_scale32_matches_pinned_composition(seed):
    """act_scale32 pinning (the sharded row-parallel contract): the fused
    prologue under a pinned per-tensor scale equals quantize_rows under
    the same pin."""
    x, qw = _operands(seed, 6, 48, 32, "mixfp4")
    pin = jnp.float32(0.125)
    qx = qtensor.quantize_rows(x, pad_to=2 * qw.payload.shape[0],
                               scale32=pin, interpret=True)
    y_fused = qtensor.qmm(x, qw, fuse_act_quant=True, act_scale32=pin,
                          interpret=True)
    np.testing.assert_array_equal(
        np.asarray(y_fused),
        np.asarray(qtensor.qmm(qx, qw, interpret=True)))
