"""Chunked-prefill scheduler (serving.scheduler): deterministic tests.

The pure-Python job ledger, the engine validation surface, and the two
serving-level contracts the scheduler exists for — decode fairness (no
in-flight decode is ever delayed by more than the chunk token budget,
counted in per-step token ledgers, never wall-clock) and mid-prefill
cancellation (the job, the slot, and every pool page come back).  The
bitwise chunked==whole-prompt property is in test_scheduler_props.py
(hypothesis); a concrete multi-request stream-equality case rides here
so bare environments still pin it.
"""
import numpy as np
import pytest

import jax

from repro.core.qgemm import QuantConfig
from repro.models.base import ArchConfig, build_model
from repro.serving.engine import Request, RequestState, ServeEngine
from repro.serving.scheduler import ChunkedPrefillScheduler


@pytest.fixture(scope="module")
def small_cfg():
    return ArchConfig(name="sched-test", family="dense", n_layers=2,
                      d_model=64, n_heads=2, n_kv_heads=2, d_ff=128,
                      vocab=64, attn_chunk=64,
                      quant=QuantConfig(method="mixfp4"))


@pytest.fixture(scope="module")
def params(small_cfg):
    return build_model(small_cfg).init(jax.random.PRNGKey(0))[0]


def _drain(eng, reqs, guard=2000):
    streams = {r.uid: [] for r in reqs}
    n = 0
    while eng.has_work():
        for uid, tok in eng.step():
            streams[uid].append(tok)
        n += 1
        assert n < guard, "engine made no progress"
    return streams


# ---------------------------------------------------------------------------
# pure-Python job ledger
# ---------------------------------------------------------------------------
def test_scheduler_job_lifecycle():
    s = ChunkedPrefillScheduler(4)
    s.enqueue(7, slot=0, req=object(), p_len=10)
    job = s.head()
    assert job.uid == 7 and job.remaining == 10
    assert s.advance(job, 4) is False and job.cursor == 4
    assert s.advance(job, 4) is False and job.remaining == 2
    assert s.advance(job, 2) is True          # job completed and removed
    assert s.head() is None and s.pending_jobs == 0
    rep = s.report()
    assert rep["jobs_completed"] == 1
    assert rep["chunks_run"] == 3
    assert rep["tokens_prefilled"] == 10


def test_scheduler_fifo_drop_restart():
    s = ChunkedPrefillScheduler(8)
    s.enqueue(1, slot=0, req=None, p_len=20)
    s.enqueue(2, slot=1, req=None, p_len=5, start_pos=3)
    assert s.head().uid == 1                  # FIFO: first admitted first
    assert s.get(2).cursor == 3               # suffix job resumes at prefix
    s.advance(s.head(), 8)
    s.drop(1)
    assert s.head().uid == 2
    s.restart(2, start_pos=0)
    assert s.get(2).cursor == 0
    assert s.backlog_tokens() == 5
    s.drop(2)
    assert s.pending_jobs == 0 and s.backlog_tokens() == 0


def test_scheduler_step_ledger():
    s = ChunkedPrefillScheduler(4)
    s.enqueue(1, slot=0, req=None, p_len=6)
    s.note_step(4, 2)
    s.note_step(2, 2)
    s.note_step(0, 2)
    assert [e["prefill_tokens"] for e in s.step_log] == [4, 2, 0]
    assert all(e["decode_rows"] == 2 for e in s.step_log)
    assert s.max_prefill_tokens_per_step() == 4


def test_scheduler_validation():
    with pytest.raises(ValueError, match="chunk budget"):
        ChunkedPrefillScheduler(0)
    s = ChunkedPrefillScheduler(4)
    s.enqueue(1, slot=0, req=None, p_len=4)
    with pytest.raises(ValueError, match="already"):
        s.enqueue(1, slot=1, req=None, p_len=4)


# ---------------------------------------------------------------------------
# engine validation surface
# ---------------------------------------------------------------------------
def test_engine_prefill_chunk_validation(small_cfg, params):
    with pytest.raises(ValueError, match="must be >= 1"):
        ServeEngine(small_cfg, params, batch_size=1, max_len=16,
                    prefill_chunk=0)
    with pytest.raises(ValueError, match="prefill_buckets"):
        ServeEngine(small_cfg, params, batch_size=1, max_len=16,
                    prefill_chunk=4, prefill_buckets="pow2-64")


# ---------------------------------------------------------------------------
# decode fairness: per-step token ledgers, no wall-clock anywhere
# ---------------------------------------------------------------------------
def test_long_admission_never_stalls_decode(small_cfg, params):
    """One near-max-length admission lands while a full decode batch is
    in flight: with the scheduler on, NO step spends more than the chunk
    budget on prefill, and every chunk-spending step still decodes the
    in-flight rows.  The whole-prompt engine provably does stall (its
    worst step spends the full prompt length) — asserted as the control
    so this test keeps meaning if prefill ever gets cheaper."""
    chunk, long_len = 4, 40
    long_prompt = np.arange(long_len, dtype=np.int32) % small_cfg.vocab

    def drive(prefill_chunk):
        eng = ServeEngine(small_cfg, params, batch_size=2, max_len=64,
                          kv_quant="mixfp4", prefill_chunk=prefill_chunk)
        short = Request(uid=0, prompt=np.array([5, 4, 3], np.int32),
                        max_new_tokens=24)
        eng.add_request(short)
        eng.step()                    # short req decoding (full decode lane)
        long = Request(uid=1, prompt=long_prompt, max_new_tokens=2)
        eng.add_request(long)
        _drain(eng, [short, long])
        assert short.state is RequestState.FINISHED
        assert long.state is RequestState.FINISHED
        return eng

    eng = drive(chunk)
    log = eng.scheduler.step_log
    spending = [e for e in log if e["prefill_tokens"] > 0]
    assert len(spending) >= long_len // chunk
    assert all(e["prefill_tokens"] <= chunk for e in spending)
    assert all(e["decode_rows"] >= 1 for e in spending), \
        "a chunk-spending step starved the in-flight decode"
    assert eng.max_prefill_tokens_per_step <= chunk
    rep = eng.scheduler.report()
    assert rep["jobs_completed"] == 2 and rep["pending_jobs"] == 0

    control = drive(None)
    assert control.max_prefill_tokens_per_step >= long_len


def test_chunked_streams_match_unchunked_under_load(small_cfg, params):
    """Concrete (non-hypothesis) stream oracle: three staggered requests
    through a chunked engine emit bitwise the whole-prompt engine's
    streams — decode junk-row scatters during an in-flight chunked
    prefill land at the job cursor and are overwritten by the next
    chunk, so concurrency cannot perturb the packed cache."""
    prompts = [np.array([9, 8, 7, 3, 1], np.int32),
               (np.arange(30, dtype=np.int32) * 7 + 1) % small_cfg.vocab,
               np.array([1, 2], np.int32)]

    def drive(prefill_chunk):
        eng = ServeEngine(small_cfg, params, batch_size=2, max_len=48,
                          kv_quant="mixfp4", prefill_chunk=prefill_chunk)
        reqs = [Request(uid=i, prompt=p, max_new_tokens=4)
                for i, p in enumerate(prompts)]
        eng.add_request(reqs[0])
        eng.add_request(reqs[1])      # chunked while req 0 decodes
        eng.step()
        eng.submit(reqs[2])           # queued behind the full batch
        _drain(eng, reqs)
        assert all(r.state is RequestState.FINISHED for r in reqs)
        return {r.uid: list(r.generated) for r in reqs}

    assert drive(4) == drive(None)


# ---------------------------------------------------------------------------
# mid-prefill cancellation releases everything
# ---------------------------------------------------------------------------
def test_cancel_mid_chunked_prefill_releases_slot_and_pages(small_cfg,
                                                            params):
    """cancel(uid) while the admission is still chunking: the job leaves
    the scheduler, the slot frees, every pool page comes back, and the
    prefix tree is untouched (insert() is deferred to prefill completion,
    so a cancelled prompt must never become a reusable prefix)."""
    eng = ServeEngine(small_cfg, params, batch_size=2, max_len=64,
                      kv_quant="mixfp4", prefill_chunk=4,
                      kv_pool=9, kv_page_len=16)
    prompt = np.arange(40, dtype=np.int32) % small_cfg.vocab
    req = Request(uid=3, prompt=prompt, max_new_tokens=4)
    eng.add_request(req)
    eng.step()
    eng.step()                                   # two chunks in: mid-prefill
    assert req.state is RequestState.PREFILLING
    assert eng.scheduler.get(3).cursor == 8
    assert eng.pool_report()["pages_active"] > 0

    assert eng.cancel(3) is True
    assert req.state is RequestState.CANCELLED
    assert eng.scheduler.pending_jobs == 0
    assert eng.slots == [None, None]
    pool = eng.pool_report()
    assert pool["pages_active"] == 0
    assert pool["pages_cached"] == 0             # nothing entered the tree
    assert eng.counters["cancelled:user_cancel"] == 1
    assert eng.metrics_report()["counters"]["cancelled:user_cancel"] == 1

    # the pool is fully reusable: a fresh admission of the same prompt is
    # a cold miss (no prefix hit off the cancelled remnant) and finishes
    req2 = Request(uid=4, prompt=prompt, max_new_tokens=2)
    eng.add_request(req2)
    _drain(eng, [req2])
    assert req2.state is RequestState.FINISHED
    assert eng.kv_pool.prefix_hits == 0
    assert eng.pool_report()["pages_active"] == 0
