"""Algorithm 1 behaviour + block machinery properties.

Property-based (hypothesis) companions live in test_quantize_props.py so
this module collects on environments without hypothesis installed.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import analysis, pack, quantize as Q, scaling


def _rand(shape, seed=0, scale=1.0):
    return jax.random.normal(jax.random.PRNGKey(seed), shape) * scale


def test_mixfp4_never_worse_than_either_branch():
    """Per-block MSE argmin => tensor MSE <= each single-format tensor MSE."""
    x = _rand((128, 256), 1, 2.0)
    e_mix = float(jnp.mean((Q.qdq(x, "mixfp4") - x) ** 2))
    e_fp = float(jnp.mean((Q.qdq(x, "nvfp4") - x) ** 2))
    e_int = float(jnp.mean((Q.qdq(x, "nvint4") - x) ** 2))
    assert e_mix <= e_fp + 1e-12
    assert e_mix <= e_int + 1e-12


def test_format_ordering_matches_paper():
    """Fig. 4 qualitative ordering on Gaussian data: mixfp4 <= four_six <= nvfp4
    (adding E1M2 helps more than adaptive max-scale alone)."""
    x = _rand((256, 512), 3, 1.7)
    errs = {m: float(jnp.mean((Q.qdq(x, m) - x) ** 2))
            for m in ["nvfp4", "four_six", "mixfp4", "mixfp4_e3"]}
    assert errs["mixfp4"] <= errs["four_six"] <= errs["nvfp4"]
    # E3M0 adds only marginal gains (paper §2.4)
    assert errs["mixfp4_e3"] <= errs["mixfp4"] + 1e-12
    rel_gain_e3 = (errs["mixfp4"] - errs["mixfp4_e3"]) / errs["mixfp4"]
    rel_gain_e1 = (errs["nvfp4"] - errs["mixfp4"]) / errs["nvfp4"]
    assert rel_gain_e3 < 0.5 * rel_gain_e1


def test_selection_follows_crest_factor():
    """Blocks with low crest factor should prefer E1M2 (INT-like), high crest
    blocks E2M1 — the Appendix-A crossover at kappa* ~ 2.224."""
    key = jax.random.PRNGKey(0)
    flat = jax.random.uniform(key, (512, 16), minval=-1.0, maxval=1.0)  # low crest
    spiky = jax.random.normal(key, (512, 16)) ** 3                      # heavy tails
    bq_flat, _, _ = Q.block_quantize_1d(flat, "mixfp4")
    bq_spiky, _, _ = Q.block_quantize_1d(spiky, "mixfp4")
    frac_flat = float(bq_flat.type_bits.mean())
    frac_spiky = float(bq_spiky.type_bits.mean())
    assert frac_flat > 0.85      # uniform blocks -> INT-like
    assert frac_spiky < frac_flat - 0.3


def test_empirical_crossover_near_kappa_star():
    """Generate Gaussian blocks, bucket by crest factor, and check the
    empirical NVFP4-vs-NVINT4 preference flips near kappa* = 2.224 (App. A)."""
    kstar, _, _ = analysis.qsnr_crossover()
    x = _rand((4096, 16), 7)
    kappa = np.asarray(analysis.crest_factor(x).ravel())
    bq, _, _ = Q.block_quantize_1d(x, "mixfp4")
    t = np.asarray(bq.type_bits).ravel()  # 1 = INT-like chosen
    lo = t[kappa < kstar - 0.35]
    hi = t[kappa > kstar + 0.35]
    assert lo.mean() > 0.5 > hi.mean()


def test_type_bit_packing_zero_overhead():
    x = _rand((64, 128), 2)
    bq, n, ax = Q.block_quantize_1d(x, "mixfp4")
    p = pack.pack_blocks(bq)
    # 4 bits/value + 8 bits/block of 16 = 4.5 bits/value (+4B tensor scale)
    bits = (pack.packed_nbytes(p) - 4) * 8
    assert bits == x.size * 4 + (x.size // 16) * 8
    np.testing.assert_allclose(np.asarray(pack.unpack_blocks(p)),
                               np.asarray(bq.dequantize()), rtol=0, atol=0)


def test_dequant_respects_scale_hierarchy():
    """Alg.1 line 4: the per-tensor scale maps max|X| to 2688; block scales
    to the format max."""
    x = _rand((4, 160), 5, 100.0)
    bq, n, ax = Q.block_quantize_1d(x, "nvfp4")
    assert float(bq.scale32) == pytest.approx(float(jnp.abs(x).max()) / 2688.0)
    # every |quantized level| <= 6 on the E2M1 branch
    assert float(jnp.abs(bq.values).max()) <= 6.0


def test_2d_tiles_shared_by_transpose():
    """Fig. 7: 2-D weight tiles => Q(W)^T == Q(W^T) (with transposed tiling)."""
    w = _rand((64, 96), 6)
    a = Q.qdq_2d(w, "mixfp4")
    b = Q.qdq_2d(w.T, "mixfp4").T
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=0, rtol=0)


def test_padding_roundtrip():
    x = _rand((3, 37), 8)  # 37 not divisible by 16
    out = Q.qdq(x, "mixfp4")
    assert out.shape == x.shape
    assert np.isfinite(np.asarray(out)).all()


def test_axis_handling():
    x = _rand((32, 48), 9)
    a = Q.qdq(x, "mixfp4", axis=0)
    b = Q.qdq(x.T, "mixfp4", axis=-1).T
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=0, atol=0)


def test_all_zero_tensor():
    x = jnp.zeros((8, 32))
    out = Q.qdq(x, "mixfp4")
    np.testing.assert_array_equal(np.asarray(out), 0.0)


def test_zero_block_within_tensor():
    x = jnp.concatenate([jnp.zeros((1, 16)), jnp.full((1, 16), 5.0)], axis=1)
    out = Q.qdq(x, "mixfp4")
    assert np.isfinite(np.asarray(out)).all()
    np.testing.assert_array_equal(np.asarray(out[:, :16]), 0.0)


def test_sr_unbiased():
    g = jnp.full((64, 64), 0.3)
    est = np.mean([
        float(Q.qdq(g, "nvint4", rounding="sr", key=jax.random.PRNGKey(i)).mean())
        for i in range(100)
    ])
    assert abs(est - 0.3) < 0.01
