"""Cost-model autotuner unit tests (kernels/tuning.py).

Pins the contracts the GEMM dispatcher and the sharded path rely on:
VMEM budget respected for every choice, padded dims never collapse below
64 lanes, bk independent of N (the qmm_sharded column-parallel bitwise
contract), the bm row ladder (decode-batch churn fix), and the
process-cache / on-disk-profile round trip.
"""
import pytest

from repro.kernels import tuning


@pytest.fixture(autouse=True)
def _fresh_cache():
    tuning.clear_cache()
    yield
    tuning.clear_cache()


SHAPES = [
    (1, 64, 64), (1, 4096, 4096), (4, 256, 256), (17, 272, 272),
    (32, 304, 4096), (128, 8192, 1024), (513, 16384, 16384),
    (1, 16, 16), (7, 48, 32), (64, 1088, 272),
]


@pytest.mark.parametrize("path", ["w4a16", "w4a4", "w4a4_fused"])
@pytest.mark.parametrize("m,kp,np_", SHAPES)
def test_vmem_budget_respected(path, m, kp, np_):
    ch = tuning.select_tiles(path, m, kp, np_)
    assert tuning.vmem_footprint(path, ch.bm, ch.bn, ch.bk) \
        <= tuning.VMEM_BUDGET, ch
    # tiles divide the padded problem exactly
    assert ch.m_pad % ch.bm == 0 and ch.m_pad >= m
    assert ch.k_pad % ch.bk == 0 and ch.k_pad >= kp
    assert ch.n_pad % ch.bn == 0 and ch.n_pad >= np_
    assert ch.bk % 16 == 0 and ch.bn % 16 == 0


@pytest.mark.parametrize("kp,np_", [(272, 272), (304, 304), (272, 4096),
                                    (4096, 304), (1088, 1088),
                                    (4112, 4112)])
def test_padded_dims_never_collapse_below_64(kp, np_):
    """Prime-ish K/N (17*16, 19*16, 257*16...) used to degrade to 16-wide
    divisor tiles; the cost model must keep every tile >= 64 lanes when
    the dim itself is >= 64."""
    for path in ("w4a16", "w4a4"):
        ch = tuning.select_tiles(path, 8, kp, np_)
        assert ch.bk >= tuning.MIN_WIDE, (path, ch)
        assert ch.bn >= tuning.MIN_WIDE, (path, ch)
        # and the divisor rule really did collapse (documents the fix)
        if kp % 64:
            assert tuning.divisor_tile(kp, 256) == 16


def test_round_shapes_unpadded():
    """Round dims must not pick up padding (no regression on the shapes
    the divisor rule already handled well)."""
    for m, kp, np_ in [(4, 256, 256), (32, 512, 512), (128, 4096, 4096)]:
        ch = tuning.select_tiles("w4a16", m, kp, np_)
        assert ch.k_pad == kp and ch.n_pad == np_, ch


def test_bk_independent_of_n():
    """The K tile must not depend on N: a column-parallel shard (local
    N = global N / shards) keeps the single-device K tiling, which is what
    makes qmm_sharded bitwise-identical to the single-device kernel."""
    for path in ("w4a16", "w4a4"):
        bks = {tuning.select_tiles(path, 8, 4096, n).bk
               for n in (64, 256, 272, 2048, 16384)}
        assert len(bks) == 1, (path, bks)


def test_row_ladder_kills_decode_batch_churn():
    """m = 3, 4, 5 ... must land on ONE padded M (and so one compiled
    kernel); the ladder is the fixed BM_LADDER."""
    assert tuning.round_up_rows(1) == 8
    assert tuning.round_up_rows(3) == 8
    assert tuning.round_up_rows(9) == 16
    assert tuning.round_up_rows(100) == 128
    assert tuning.round_up_rows(1000) == 128
    pads = {tuning.select_tiles("w4a16", m, 256, 256).m_pad
            for m in (1, 2, 3, 5, 8)}
    assert pads == {8}, pads
    # above the cap, M pads to the cap multiple
    ch = tuning.select_tiles("w4a16", 300, 256, 256)
    assert ch.bm == 128 and ch.m_pad == 384


def test_w4a4_and_fused_share_tiles():
    """The fused prologue and the two-dispatch composition must run the
    SAME grid — that is what makes them bitwise-comparable."""
    a = tuning.select_tiles("w4a4", 5, 272, 144)
    b = tuning.select_tiles("w4a4_fused", 5, 272, 144)
    assert a == b
    info = tuning.cache_info()
    assert info["entries"] == 1 and info["hits"] == 1, info


def test_unknown_path_and_unaligned_dims_rejected():
    with pytest.raises(ValueError, match="unknown path"):
        tuning.select_tiles("w8a8", 1, 256, 256)
    with pytest.raises(ValueError, match="16-aligned"):
        tuning.select_tiles("w4a16", 1, 250, 256)


def test_profile_roundtrip(tmp_path):
    p = str(tmp_path / "profile.json")
    a = tuning.select_tiles("w4a16", 4, 272, 272)
    bs = tuning.select_attn_key_block(1000, 2, 64)
    tuning.save_profile(p)
    tuning.clear_cache()
    tuning.load_profile(p)
    info0 = tuning.cache_info()
    assert tuning.select_tiles("w4a16", 4, 272, 272) == a
    assert tuning.select_attn_key_block(1000, 2, 64) == bs
    info1 = tuning.cache_info()
    # both lookups were served from the loaded profile, not re-scored
    assert info1["hits"] == info0["hits"] + 2
    assert info1["misses"] == info0["misses"]


def test_attn_key_block_contracts():
    """Key-block sizing: multiple-of-16, VMEM model respected, small S
    never gets a block wider than its own padding would justify."""
    for s, hkv, dh in [(16, 2, 64), (128, 2, 64), (4096, 8, 128),
                      (32768, 2, 256)]:
        bs = tuning.select_attn_key_block(s, hkv, dh)
        assert bs % 16 == 0
        assert tuning.attn_vmem_footprint(bs, hkv, dh) <= tuning.VMEM_BUDGET
    assert tuning.select_attn_key_block(16, 2, 64) <= 32
    # long caches get large blocks (fewer flash steps)
    assert tuning.select_attn_key_block(32768, 2, 64) >= 256
