"""Async streaming front-end (serving.server): HTTP-level contracts.

Everything runs over a real loopback socket against the real asyncio
server — the worker thread owns the engine, requests stream as SSE
frames, and the chaos sweep extends THROUGH the HTTP layer: an injected
mid-stream fault must surface as exactly one typed error frame on the
poisoned stream while concurrent survivors stay bitwise identical to the
fault-free run (W4A16 decode is row-independent).  Client disconnects
must translate into ``cancel(uid)`` and release the slot and every pool
page.
"""
import json
import threading

import numpy as np
import pytest

import jax

from repro.core.qgemm import QuantConfig
from repro.models.base import ArchConfig, build_model
from repro.serving.engine import Request, ServeEngine
from repro.serving.faults import FaultInjector, FaultRule
from repro.serving.server import (ServingServer, get_json, resume_stream,
                                  scrape_metrics, stream_generate)


@pytest.fixture(scope="module")
def small_cfg():
    return ArchConfig(name="server-test", family="dense", n_layers=2,
                      d_model=64, n_heads=2, n_kv_heads=2, d_ff=128,
                      vocab=64, attn_chunk=64,
                      quant=QuantConfig(method="mixfp4"))


@pytest.fixture(scope="module")
def params(small_cfg):
    return build_model(small_cfg).init(jax.random.PRNGKey(0))[0]


def _engine(small_cfg, params, **kw):
    kw.setdefault("batch_size", 2)
    kw.setdefault("max_len", 32)
    return ServeEngine(small_cfg, params, **kw)


def _tokens(frames):
    return [f["token"] for f in frames if f["type"] == "token"]


def _serve_direct(eng, prompt, n_new):
    """Oracle: drive an engine without the HTTP layer."""
    req = Request(uid=0, prompt=np.asarray(prompt, np.int32),
                  max_new_tokens=n_new)
    eng.add_request(req)
    toks = []
    while eng.has_work():
        toks.extend(t for _, t in eng.step())
    return toks


def test_stream_matches_direct_drive(small_cfg, params):
    """One request over HTTP: token frames in order, ONE terminal frame
    with the typed finish reason, and the stream is bitwise the direct
    engine drive's."""
    prompt, n_new = [1, 2, 3, 4, 5, 6, 7, 8], 6
    with ServingServer(_engine(small_cfg, params,
                               prefill_chunk=4)) as srv:
        frames = list(stream_generate(srv.host, srv.port, prompt,
                                      max_new_tokens=n_new))
    terminal = [f for f in frames if f["type"] in ("done", "error")]
    assert len(terminal) == 1 and frames[-1] is terminal[0]
    assert terminal[0]["type"] == "done"
    assert terminal[0]["finish_reason"] == "max_new_tokens"
    assert terminal[0]["state"] == "FINISHED"
    assert terminal[0]["n_tokens"] == n_new
    assert [f["index"] for f in frames[:-1]] == list(range(n_new))
    toks = _tokens(frames)
    assert toks == _serve_direct(_engine(small_cfg, params), prompt, n_new)


def test_concurrent_streams_and_metrics_scrape(small_cfg, params):
    """Two concurrent HTTP streams share the decode batch; /metrics
    renders the registry (TTFT/ITL summaries, gauges) mid-flight."""
    prompts = {10: [5, 4, 3], 11: [9, 8, 7, 6]}
    got: dict = {}

    def client(uid):
        got[uid] = list(stream_generate(srv.host, srv.port, prompts[uid],
                                        max_new_tokens=8, uid=uid))

    with ServingServer(_engine(small_cfg, params)) as srv:
        threads = [threading.Thread(target=client, args=(u,))
                   for u in prompts]
        for t in threads:
            t.start()
        for t in threads:
            t.join(timeout=120)
        text = scrape_metrics(srv.host, srv.port)
    for uid in prompts:
        assert got[uid][-1]["type"] == "done", got[uid][-1]
        assert len(_tokens(got[uid])) == 8
    assert "mixfp4_ttft_ms_count 2" in text
    assert "mixfp4_itl_ms" in text
    assert "mixfp4_queue_depth" in text
    assert 'mixfp4_ttft_ms{quantile="0.99"}' in text
    # W4A16 row independence: each stream is bitwise its solo drive
    for uid in prompts:
        solo = _serve_direct(_engine(small_cfg, params), prompts[uid], 8)
        assert _tokens(got[uid]) == solo, uid


def test_chaos_through_http_one_error_frame_survivors_bitwise(small_cfg,
                                                              params):
    """Chaos THROUGH the HTTP layer: a decode-site nan pinned to one uid
    fails exactly that stream with ONE typed error frame; the concurrent
    survivor's stream is bitwise the fault-free run (W4A16)."""
    victim, survivor = 40, 41
    prompts = {victim: [3, 1, 4, 1, 5], survivor: [2, 7, 1, 8]}
    inj = FaultInjector(0, [FaultRule("decode", "nan", prob=1.0,
                                      uid=victim)])
    got: dict = {}

    def client(uid):
        got[uid] = list(stream_generate(srv.host, srv.port, prompts[uid],
                                        max_new_tokens=6, uid=uid))

    with ServingServer(_engine(small_cfg, params, faults=inj)) as srv:
        threads = [threading.Thread(target=client, args=(u,))
                   for u in (victim, survivor)]
        for t in threads:
            t.start()
        for t in threads:
            t.join(timeout=120)

    verr = [f for f in got[victim] if f["type"] == "error"]
    assert len(verr) == 1 and got[victim][-1] is verr[0]
    assert verr[0]["finish_reason"] == "nan_logits"
    assert verr[0]["state"] == "FAILED"
    assert not any(f["type"] == "error" for f in got[survivor])
    assert got[survivor][-1]["finish_reason"] == "max_new_tokens"
    fault_free = _serve_direct(_engine(small_cfg, params),
                               prompts[survivor], 6)
    assert _tokens(got[survivor]) == fault_free


def test_disconnect_mid_stream_cancels_and_releases(small_cfg, params):
    """Satellite regression: the client hangs up after the first token;
    the server must turn the EOF into ``cancel(uid)`` — slot freed, every
    pool page released, and the registry counts the ``user_cancel``
    finish exactly once."""
    eng = _engine(small_cfg, params, max_len=64, kv_quant="mixfp4",
                  prefill_chunk=4, kv_pool=9, kv_page_len=16)
    prompt = list(range(1, 24))
    with ServingServer(eng) as srv:
        frames = list(stream_generate(srv.host, srv.port, prompt,
                                      max_new_tokens=30, abort_after=1))
        # the abort closes the socket with the request still decoding —
        # wait (bounded) for the worker to observe the EOF and cancel
        deadline = 200
        while eng.counters.get("cancelled:user_cancel", 0) == 0:
            deadline -= 1
            assert deadline > 0, "disconnect never became cancel(uid)"
            threading.Event().wait(0.05)
    assert all(f["type"] == "token" for f in frames)   # hung up pre-terminal
    assert eng.counters["cancelled:user_cancel"] == 1
    assert eng.slots == [None, None]
    pool = eng.pool_report()
    assert pool["pages_active"] == 0
    rep = eng.metrics_report()
    assert rep["counters"]["cancelled:user_cancel"] == 1
    assert rep["gauges"]["active_slots"] == 0.0


def test_validation_error_is_a_typed_frame(small_cfg, params):
    """An invalid request (empty prompt) must come back as ONE typed
    error frame over the stream — not a hung connection."""
    with ServingServer(_engine(small_cfg, params)) as srv:
        frames = list(stream_generate(srv.host, srv.port, [],
                                      max_new_tokens=4))
    assert len(frames) == 1
    assert frames[0]["type"] == "error"
    assert frames[0]["finish_reason"] == "empty_prompt"
    assert frames[0]["state"] == "REJECTED"


def test_healthz_and_404(small_cfg, params):
    import http.client
    with ServingServer(_engine(small_cfg, params)) as srv:
        conn = http.client.HTTPConnection(srv.host, srv.port, timeout=30)
        conn.request("GET", "/healthz")
        r = conn.getresponse()
        assert r.status == 200 and json.loads(r.read())["ok"] is True
        conn2 = http.client.HTTPConnection(srv.host, srv.port, timeout=30)
        conn2.request("GET", "/nope")
        assert conn2.getresponse().status == 404


# ---------------------------------------------------------------------------
# PR 10: bounded sinks, readiness phases, stream resume
# ---------------------------------------------------------------------------
def _parse_sse_blob(blob: bytes):
    """Decode every SSE frame out of a raw captured byte stream (HTTP
    header and chunk-size lines carry no ``data:`` prefix, so they fall
    out naturally)."""
    frames = []
    for raw in blob.split(b"\n\n"):
        i = raw.find(b"data: ")
        if i >= 0:
            frames.append(json.loads(raw[i + len(b"data: "):]))
    return frames


def test_slow_client_hits_bounded_sink_and_is_cancelled(small_cfg, params):
    """A client that stops reading must not wedge the engine or grow the
    sink queue without bound: past ``max_sink_frames`` the request is
    cancelled with the typed ``slow_client`` reason, exactly ONE error
    terminal goes on the wire, and the slot (and its tokens/frames
    backlog) is released while the engine keeps stepping."""
    import socket
    import time

    eng = _engine(small_cfg, params, max_len=128)
    # tiny kernel buffers on BOTH ends so ~100 frames overflow them, and
    # a tiny sink bound so the overflow trips fast
    with ServingServer(eng, max_sink_frames=8, sndbuf=512) as srv:
        body = json.dumps({"prompt": [1, 2, 3, 4], "uid": 77,
                           "max_new_tokens": 120}).encode()
        sock = socket.socket(socket.AF_INET, socket.SOCK_STREAM)
        try:
            # RCVBUF must shrink BEFORE connect: the TCP window is
            # negotiated at the handshake
            sock.setsockopt(socket.SOL_SOCKET, socket.SO_RCVBUF, 512)
            sock.settimeout(120.0)
            sock.connect((srv.host, srv.port))
            sock.sendall(b"POST /generate HTTP/1.1\r\nHost: t\r\n"
                         b"Content-Type: application/json\r\n"
                         b"Content-Length: " + str(len(body)).encode()
                         + b"\r\n\r\n" + body)
            # ...and then never read: the engine must cancel us, not hang
            deadline = time.time() + 120.0
            while time.time() < deadline:
                counters = srv.worker.call(lambda e: dict(e.counters),
                                           timeout=30.0)
                if counters.get("cancelled:slow_client"):
                    break
                time.sleep(0.05)
            assert counters.get("cancelled:slow_client") == 1, counters
            # the stalled request's slot is free again
            active = srv.worker.call(
                lambda e: sum(s is not None for s in e.slots),
                timeout=30.0)
            assert active == 0
            # NOW read what the server managed to send: buffered token
            # frames, then exactly one typed error terminal
            blob = b""
            sock.settimeout(10.0)
            while True:
                try:
                    data = sock.recv(65536)
                except TimeoutError:
                    break
                if not data:
                    break
                blob += data
        finally:
            sock.close()
    frames = _parse_sse_blob(blob)
    terminal = [f for f in frames if f["type"] in ("done", "error")]
    assert len(terminal) == 1 and frames[-1] is terminal[0], frames[-2:]
    assert terminal[0]["type"] == "error"
    assert terminal[0]["state"] == "CANCELLED"
    assert terminal[0]["finish_reason"] == "slow_client"
    assert len(_tokens(frames)) < 120


def test_readyz_phases_and_gauges(small_cfg, params):
    """/healthz is pure liveness (200 in every phase); /readyz flips
    ready -> draining and carries the queue/slot/pool gauges."""
    with ServingServer(_engine(small_cfg, params)) as srv:
        assert srv.worker.ready.wait(60.0)
        code, body = get_json(srv.host, srv.port, "/readyz")
        assert code == 200 and body["ready"] is True
        assert body["phase"] == "ready"
        assert body["queue_depth"] == 0 and body["active_slots"] == 0
        assert body["batch_size"] == 2 and body["pool"] is None
        srv.worker.call(lambda e: e.begin_drain())
        code, body = get_json(srv.host, srv.port, "/readyz")
        assert code == 503 and body["ready"] is False
        assert body["phase"] == "draining"
        code, body = get_json(srv.host, srv.port, "/healthz")
        assert code == 200 and body["ok"] is True      # still alive
        assert body["phase"] == "draining"
        rep = srv.drain()
        assert rep["drained"] and rep["survivors"] == []


def test_readyz_reports_pool_gauges(small_cfg, params):
    eng = _engine(small_cfg, params, kv_quant="mixfp4", kv_pool=9,
                  kv_page_len=16)
    with ServingServer(eng) as srv:
        code, body = get_json(srv.host, srv.port, "/readyz")
    assert code == 200
    assert body["pool"]["pages_total"] > 0
    assert body["pool"]["pages_free"] == body["pool"]["pages_total"]
    assert body["pool"]["pages_active"] == 0


def test_resume_replays_finished_stream_bitwise(small_cfg, params):
    """GET /resume/{uid} after the stream finished: every token comes
    back flagged ``replayed`` with its original index, then the original
    terminal — the reconnect path a crashed client (or a recovered
    server's clients) uses."""
    prompt, n_new = [1, 2, 3, 4], 6
    with ServingServer(_engine(small_cfg, params)) as srv:
        live = list(stream_generate(srv.host, srv.port, prompt, uid=21,
                                    max_new_tokens=n_new))
        again = list(resume_stream(srv.host, srv.port, 21))
        missing = list(resume_stream(srv.host, srv.port, 999))
    assert _tokens(again) == _tokens(live)
    tok_frames = [f for f in again if f["type"] == "token"]
    assert all(f.get("replayed") for f in tok_frames)
    assert [f["index"] for f in tok_frames] == list(range(n_new))
    assert again[-1]["type"] == "done"
    assert again[-1]["finish_reason"] == "max_new_tokens"
    assert len(missing) == 1 and missing[0]["type"] == "http_error"
    assert "404" in missing[0]["status"]
