"""Substrate tests: data pipeline, optimizer, checkpointing (incl. elastic
restore), gradient compression, train-loop E2E."""
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import CheckpointManager
from repro.data import DataConfig, make_stream
from repro.distributed.gradcomp import compressed_grad_reduce, gradcomp_init
from repro.optim import AdamWConfig, adamw_init, adamw_update, warmup_cosine


# ---------------------------------------------------------------------------
# data pipeline
# ---------------------------------------------------------------------------
def test_data_deterministic_and_resumable():
    cfg = DataConfig(vocab=512, seq_len=64, batch_per_shard=4, seed=7)
    s1, s2 = make_stream(cfg), make_stream(cfg)
    b1, b2 = s1.batch(13), s2.batch(13)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    # different steps / shards decorrelate
    assert not np.array_equal(s1.batch(14)["tokens"], b1["tokens"])
    s3 = make_stream(DataConfig(vocab=512, seq_len=64, batch_per_shard=4,
                                seed=7, shard=1, n_shards=2))
    assert not np.array_equal(s3.batch(13)["tokens"], b1["tokens"])


def test_data_labels_shifted():
    cfg = DataConfig(vocab=128, seq_len=32, batch_per_shard=2)
    b = make_stream(cfg).batch(0)
    np.testing.assert_array_equal(b["labels"][:, :-1], b["tokens"][:, 1:])
    assert (b["labels"][:, -1] == -1).all()


def test_prefetcher():
    from repro.data.pipeline import Prefetcher
    cfg = DataConfig(vocab=128, seq_len=16, batch_per_shard=2)
    pf = Prefetcher(make_stream(cfg), start_step=5, depth=2)
    step, batch = pf.next()
    assert step == 5 and batch["tokens"].shape == (2, 16)
    step2, _ = pf.next()
    assert step2 == 6
    pf.close()


# ---------------------------------------------------------------------------
# optimizer
# ---------------------------------------------------------------------------
def test_adamw_converges_quadratic():
    cfg = AdamWConfig(weight_decay=0.0, clip_norm=0.0)
    target = jnp.asarray([1.5, -2.0, 0.5])
    params = {"w": jnp.zeros(3)}
    state = adamw_init(params)
    loss = lambda p: jnp.sum((p["w"] - target) ** 2)
    for _ in range(300):
        g = jax.grad(loss)(params)
        params, state, _ = adamw_update(cfg, params, state, g, 0.05)
    assert float(loss(params)) < 1e-3


def test_adamw_clips():
    cfg = AdamWConfig(clip_norm=1.0)
    params = {"w": jnp.zeros(4)}
    state = adamw_init(params)
    g = {"w": jnp.full((4,), 100.0)}
    _, _, gnorm = adamw_update(cfg, params, state, g, 0.1)
    assert float(gnorm) == pytest.approx(200.0)  # pre-clip norm reported


def test_warmup_cosine():
    lr0 = float(warmup_cosine(0, max_lr=1e-3, warmup=10, total=100))
    lrw = float(warmup_cosine(10, max_lr=1e-3, warmup=10, total=100))
    lre = float(warmup_cosine(100, max_lr=1e-3, warmup=10, total=100))
    assert lr0 == 0.0 and lrw == pytest.approx(1e-3)
    assert lre == pytest.approx(1e-4, rel=1e-3)  # min ratio 0.1 (paper)


# ---------------------------------------------------------------------------
# checkpointing
# ---------------------------------------------------------------------------
def test_checkpoint_roundtrip(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2)
    tree = {"a": jnp.arange(10.0), "b": {"c": jnp.ones((3, 3), jnp.bfloat16)}}
    mgr.save(5, tree, extra={"note": "x"}, blocking=True)
    restored, extra = mgr.restore(5, tree)
    np.testing.assert_array_equal(np.asarray(restored["a"]),
                                  np.asarray(tree["a"]))
    assert extra == {"note": "x"}
    assert restored["b"]["c"].dtype == jnp.bfloat16


def test_checkpoint_keep_n_and_latest(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2)
    tree = {"a": jnp.zeros(4)}
    for s in [1, 2, 3]:
        mgr.save(s, tree, blocking=True)
    assert mgr.latest_step() == 3
    steps = sorted(n for n in os.listdir(tmp_path) if n.startswith("step_"))
    assert len(steps) == 2  # GC keeps 2


def test_checkpoint_atomicity(tmp_path):
    """A .tmp dir without manifest must be invisible to latest_step."""
    mgr = CheckpointManager(str(tmp_path), keep=2)
    tree = {"a": jnp.zeros(2)}
    mgr.save(1, tree, blocking=True)
    os.makedirs(tmp_path / "step_0000000002.tmp")
    assert mgr.latest_step() == 1


def test_checkpoint_corruption_detected(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2)
    tree = {"a": jnp.arange(8.0)}
    mgr.save(1, tree, blocking=True)
    leaf = tmp_path / "step_0000000001" / "leaf_00000.npy"
    arr = np.load(leaf)  # stored as flat uint8
    arr[0] ^= 0xFF
    np.save(leaf, arr)
    with pytest.raises(IOError):
        mgr.restore(1, tree)


@pytest.mark.slow
def test_elastic_restore_different_mesh(tmp_path):
    """Save under a 2-way DP mesh, restore under a (2, 1) DP x TP mesh —
    leaves identical.  (Shrunk from the original 4-device / two-axis
    variant: forcing 4 host-platform devices plus two full mesh compiles
    blew the 300 s subprocess budget on slow CPU runners; 2 devices and a
    tiny leaf cover the same elastic-restore contract — a checkpoint is
    mesh-agnostic and resharding happens at restore.)"""
    script = f"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=2"
os.environ.setdefault("JAX_PLATFORMS", "cpu")
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.checkpoint import CheckpointManager

tree = {{"w": jnp.arange(8.0).reshape(4, 2)}}
mgr = CheckpointManager({str(tmp_path)!r}, keep=2)

mesh_dp = jax.make_mesh((2,), ("data",))
sh_dp = {{"w": NamedSharding(mesh_dp, P("data", None))}}
tree_dp = jax.tree.map(jax.device_put, tree, sh_dp)
mgr.save(1, tree_dp, blocking=True)

mesh_tp = jax.make_mesh((2, 1), ("data", "model"))
sh_tp = {{"w": NamedSharding(mesh_tp, P("data", "model"))}}
restored, _ = mgr.restore(1, tree, shardings=sh_tp)
np.testing.assert_array_equal(np.asarray(restored["w"]),
                              np.asarray(tree["w"]))
assert restored["w"].sharding.num_devices == 2
print("ELASTIC_OK")
"""
    env = dict(os.environ, PYTHONPATH="src")
    env.pop("JAX_PLATFORMS", None)
    out = subprocess.run([sys.executable, "-c", script], capture_output=True,
                         text=True, env=env, cwd="/root/repo", timeout=300)
    assert "ELASTIC_OK" in out.stdout, out.stderr[-2000:]


# ---------------------------------------------------------------------------
# gradient compression (MixFP4 wire format + error feedback)
# ---------------------------------------------------------------------------
def test_gradcomp_error_feedback_preserves_signal():
    key = jax.random.PRNGKey(0)
    grads = {"w": jax.random.normal(key, (64, 64))}
    state = gradcomp_init(grads)
    acc_q = jnp.zeros((64, 64))
    acc_t = jnp.zeros((64, 64))
    for i in range(30):
        g = {"w": jax.random.normal(jax.random.PRNGKey(i), (64, 64))}
        gq, state = compressed_grad_reduce(
            g, state, jax.random.PRNGKey(100 + i))
        acc_q = acc_q + gq["w"]
        acc_t = acc_t + g["w"]
    # error feedback: accumulated compressed grads track the true sum
    rel = float(jnp.linalg.norm(acc_q - acc_t) / jnp.linalg.norm(acc_t))
    assert rel < 0.05, rel


def test_gradcomp_wire_bits():
    from repro.distributed.gradcomp import WIRE_BITS_PER_VALUE
    assert WIRE_BITS_PER_VALUE == 4.5  # 4-bit payload + 8-bit scale / 16


def test_gradcomp_sgd_converges():
    """Toy convergence: SGD with compressed grads + EF reaches the optimum."""
    target = jax.random.normal(jax.random.PRNGKey(3), (32,))
    w = {"p": jnp.zeros(32)}
    state = gradcomp_init(w)
    for i in range(200):
        g = jax.grad(lambda p: jnp.sum((p["p"] - target) ** 2))(w)
        gq, state = compressed_grad_reduce(g, state, jax.random.PRNGKey(i))
        w = jax.tree.map(lambda p, q: p - 0.05 * q, w, gq)
    assert float(jnp.linalg.norm(w["p"] - target)) < 0.05


# ---------------------------------------------------------------------------
# train driver E2E (CPU, tiny config) + restart continuity
# ---------------------------------------------------------------------------
@pytest.mark.slow
def test_train_driver_checkpoint_restart(tmp_path):
    env = dict(os.environ, PYTHONPATH="src")
    env.pop("JAX_PLATFORMS", None)
    common = [sys.executable, "-m", "repro.launch.train",
              "--arch", "mixfp4_114m", "--batch", "2", "--seq", "32",
              "--ckpt-dir", str(tmp_path), "--ckpt-every", "3",
              "--log-every", "1"]
    args = common + ["--steps", "6"]
    out1 = subprocess.run(common + ["--steps", "4"],
                          capture_output=True, text=True, env=env,
                          cwd="/root/repo", timeout=900)
    assert "checkpointed" in out1.stdout, out1.stderr[-2000:]
    out2 = subprocess.run(args, capture_output=True, text=True, env=env,
                          cwd="/root/repo", timeout=900)
    assert "resumed from step" in out2.stdout, \
        out2.stdout[-1000:] + out2.stderr[-1000:]
