"""Request-lifecycle hardening + seeded fault injection (serving.faults).

Covers the explicit request state machine (bounded queue, cancel,
deadlines), the typed-rejection validation ordering (no pool/prefix-tree
state touched by a rejected request), capped-backoff retries at the
prefill/decode/checkpoint_read boundaries, poison-request isolation for
all four model families (N-1 surviving streams bitwise-identical to the
fault-free oracle under W4A16; same-schedule batch-determinism under
W4A4), and both rungs of the degradation ladder (fused W4A4 -> 2-pass,
paged -> fixed-slot) preserving the emitted streams.
"""
import jax
import numpy as np
import pytest

from repro.core.qgemm import QuantConfig
from repro.models.base import ArchConfig, build_model
from repro.serving import faults as flt
from repro.serving.engine import (REASON_CANCELLED, REASON_DEADLINE,
                                  REASON_MAX_NEW, REASON_NAN_LOGITS,
                                  REASON_RETRIES, REASON_TTFT,
                                  QueueFullError, Request,
                                  RequestState, RequestValidationError,
                                  ServeEngine)
from repro.serving.faults import (FaultInjector, FaultRule, InjectedFault,
                                  VirtualClock, parse_faults)


# ---------------------------------------------------------------------------
# injector unit tests (no engine, no jax dispatch)
# ---------------------------------------------------------------------------
def test_fault_rule_validation():
    with pytest.raises(ValueError, match="fault site"):
        FaultRule("warp_drive", "error")
    with pytest.raises(ValueError, match="fault kind"):
        FaultRule("decode", "bogus")
    with pytest.raises(ValueError, match="deny"):
        FaultRule("decode", "deny")      # deny only makes sense at the pool
    FaultRule("pool_acquire", "deny")    # and there it is fine


def test_injector_is_deterministic():
    """Same seed + rules + fire sequence -> identical event logs (the
    basis of every bitwise chaos assertion)."""
    def run(seed):
        inj = FaultInjector(seed, [
            FaultRule("decode", "nan", prob=0.5),
            FaultRule("decode", "slow", prob=0.5, delay_ms=10.0),
            FaultRule("prefill", "error", at=(1,)),
        ])
        for n in range(6):
            inj.fire("decode", active_uids=(0, 1, 2))
            inj.fire("prefill", uid=n)
        return [(e["site"], e["occurrence"], e["kind"], e["uid"])
                for e in inj.log]
    assert run(3) == run(3)
    assert len(run(3)) > 0


def test_injector_times_cap_and_victim_scoping():
    inj = FaultInjector(0, [FaultRule("decode", "nan", prob=1.0, times=1)])
    a1 = inj.fire("decode", active_uids=(7, 8))
    a2 = inj.fire("decode", active_uids=(7, 8))
    assert len(a1.poison_uids) == 1 and set(a1.poison_uids) <= {7, 8}
    assert not a2.poison_uids            # times=1 spent
    assert inj.fatal_victims() == set(a1.poison_uids)


def test_slow_faults_advance_the_virtual_clock():
    inj = FaultInjector(0, [FaultRule("decode", "slow", prob=1.0,
                                      delay_ms=10.0)])
    for _ in range(3):
        inj.fire("decode")
    assert inj.clock() == pytest.approx(0.030)


def test_parse_faults_grammar():
    inj = parse_faults("7:decode=nan@3,decode=slow:25@p0.2,"
                       "pool_acquire=deny@p0.1,prefill=transient@0#4")
    assert inj.seed == 7
    by = {(r.site, r.kind): r for r in inj.rules}
    assert by[("decode", "nan")].at == (3,)
    assert by[("decode", "slow")].prob == 0.2
    assert by[("decode", "slow")].delay_ms == 25.0
    assert by[("pool_acquire", "deny")].prob == 0.1
    assert by[("prefill", "transient")].uid == 4
    # an omitted @when means "every occurrence"
    assert parse_faults("0:decode=slow").rules[0].prob == 1.0


def test_parse_faults_rejects_malformed_specs():
    with pytest.raises(ValueError, match="fault spec"):
        parse_faults("decode=nan")           # no seed
    with pytest.raises(ValueError, match="fault kind"):
        parse_faults("7:decode=bogus")
    with pytest.raises(ValueError, match="fault site"):
        parse_faults("7:warp=nan")
    with pytest.raises(ValueError, match="fault rule"):
        parse_faults("7:decode")             # no kind at all


# ---------------------------------------------------------------------------
# engine fixtures
# ---------------------------------------------------------------------------
@pytest.fixture(scope="module")
def dense_cfg():
    return ArchConfig(name="faults-dense", family="dense", n_layers=2,
                      d_model=64, n_heads=2, n_kv_heads=2, d_ff=128,
                      vocab=64, attn_chunk=64,
                      quant=QuantConfig(method="mixfp4"))


@pytest.fixture(scope="module")
def dense_params(dense_cfg):
    params, _ = build_model(dense_cfg).init(jax.random.PRNGKey(0))
    return params


def _prompts(vocab, lens, seed=0):
    rng = np.random.RandomState(seed)
    return [rng.randint(0, vocab, n).astype(np.int32) for n in lens]


# ---------------------------------------------------------------------------
# state machine / bounded queue / cancel / deadlines
# ---------------------------------------------------------------------------
def test_state_machine_happy_path(dense_cfg, dense_params):
    eng = ServeEngine(dense_cfg, dense_params, batch_size=2, max_len=32,
                      clock=VirtualClock())
    req = Request(uid=0, prompt=_prompts(64, [4])[0], max_new_tokens=2)
    assert req.state is RequestState.QUEUED and not req.state.terminal
    eng.submit(req)
    assert req.submitted_at is not None
    streams = []
    while eng.has_work():
        streams.extend(eng.step())
    assert req.state is RequestState.FINISHED and req.state.terminal
    assert req.finish_reason == REASON_MAX_NEW
    assert len(streams) == 2
    assert req.ttft_ms() is not None and req.ttft_ms() >= 0.0
    assert eng.counters["submitted"] == 1
    assert eng.counters[f"finished:{REASON_MAX_NEW}"] == 1
    assert eng.robustness_report()["request_states"] == {"FINISHED": 1}


def test_bounded_queue_backpressure(dense_cfg, dense_params):
    eng = ServeEngine(dense_cfg, dense_params, batch_size=1, max_len=32,
                      max_queue=1, clock=VirtualClock())
    p = _prompts(64, [3, 3, 3])
    eng.submit(Request(uid=0, prompt=p[0], max_new_tokens=1))
    with pytest.raises(QueueFullError, match="queue is full"):
        eng.submit(Request(uid=1, prompt=p[1], max_new_tokens=1))
    assert eng.counters["rejected:queue_full"] == 1
    # the rejected request never entered the engine's books
    assert 1 not in eng.requests and len(eng.queue) == 1
    # draining frees the queue for a later submit
    while eng.has_work():
        eng.step()
    eng.submit(Request(uid=2, prompt=p[2], max_new_tokens=1))
    while eng.has_work():
        eng.step()
    assert eng.requests[2].state is RequestState.FINISHED


def test_cancel_queued_and_running(dense_cfg, dense_params):
    eng = ServeEngine(dense_cfg, dense_params, batch_size=1, max_len=32,
                      clock=VirtualClock())
    p = _prompts(64, [3, 3])
    a = Request(uid=0, prompt=p[0], max_new_tokens=8)
    b = Request(uid=1, prompt=p[1], max_new_tokens=8)
    eng.submit(a)
    eng.submit(b)                       # waits behind a (batch_size=1)
    assert eng.cancel(1)                # cancelled while QUEUED
    assert b.state is RequestState.CANCELLED
    assert b.finish_reason == REASON_CANCELLED
    eng.step()                          # admits + first token for a
    assert a.state is RequestState.RUNNING
    assert eng.cancel(0)                # cancelled while RUNNING
    assert a.state is RequestState.CANCELLED
    assert eng.slots == [None]          # slot quarantined/released
    assert not eng.cancel(0)            # already terminal
    assert not eng.cancel(99)           # unknown uid
    assert eng.counters[f"cancelled:{REASON_CANCELLED}"] == 2
    assert not eng.has_work()


def test_deadline_and_ttft_expiry(dense_cfg, dense_params):
    clk = VirtualClock()
    eng = ServeEngine(dense_cfg, dense_params, batch_size=2, max_len=32,
                      clock=clk)
    p = _prompts(64, [3, 3, 4])
    # queued expiry: both budgets checked before any admission work
    a = Request(uid=0, prompt=p[0], max_new_tokens=4, deadline_ms=50.0)
    b = Request(uid=1, prompt=p[1], max_new_tokens=4, ttft_budget_ms=20.0)
    eng.submit(a)
    eng.submit(b)
    clk.advance(0.1)                    # 100 ms > both budgets
    eng.step()
    assert a.state is RequestState.EXPIRED
    assert a.finish_reason == REASON_DEADLINE
    assert b.state is RequestState.EXPIRED
    assert b.finish_reason == REASON_TTFT
    assert eng.counters[f"expired:{REASON_DEADLINE}"] == 1
    assert eng.counters[f"expired:{REASON_TTFT}"] == 1
    # in-flight expiry: the slot is freed, the stream stops
    c = Request(uid=2, prompt=p[2], max_new_tokens=16, deadline_ms=200.0)
    eng.submit(c)
    eng.step()                          # admitted, first token emitted
    assert c.state is RequestState.RUNNING and len(c.generated) >= 1
    clk.advance(0.5)
    eng.step()
    assert c.state is RequestState.EXPIRED
    assert c.finish_reason == REASON_DEADLINE
    assert eng.slots == [None, None] and not eng.has_work()
    # a request that GOT its first token in time is not TTFT-expired
    assert c.ttft_ms() is not None and c.ttft_ms() <= 200.0


# ---------------------------------------------------------------------------
# validation ordering: a rejected request touches NO engine state
# ---------------------------------------------------------------------------
def test_rejections_leave_pool_and_slots_untouched(dense_cfg, dense_params):
    """Regression for the validation-ordering fix: every typed rejection
    must fire BEFORE any pool page / prefix-tree / slot state is touched
    (the over-pool-capacity case used to be discovered inside
    ``kv_pool.acquire``, after walking the prefix tree)."""
    eng = ServeEngine(dense_cfg, dense_params, batch_size=2, max_len=32,
                      kv_quant="mixfp4", kv_pool=2, kv_page_len=16,
                      clock=VirtualClock())
    assert eng.kv_pool.pages_total == 1      # page 0 is the trash page
    before = eng.pool_report()
    cases = [
        (Request(uid=0, prompt=np.zeros((0,), np.int32)),
         "empty_prompt"),
        (Request(uid=1, prompt=np.array([1], np.int32), max_new_tokens=0),
         "bad_max_new_tokens"),
        (Request(uid=2, prompt=np.arange(40, dtype=np.int32) % 8,
                 max_new_tokens=4),
         "too_long"),
        # 15 prompt + 4 new - 1 = 18 positions = 2 pages > pages_total=1:
        # no amount of draining can ever satisfy it -> typed rejection,
        # not an admission-deferral livelock
        (Request(uid=3, prompt=np.arange(15, dtype=np.int32) % 8,
                 max_new_tokens=4),
         "over_pool_capacity"),
    ]
    for req, reason in cases:
        with pytest.raises(RequestValidationError) as ei:
            eng.submit(req)
        assert ei.value.reason == reason
        assert eng.counters[f"rejected:{reason}"] == 1
        assert eng.pool_report() == before, reason
    assert eng.slots == [None, None]
    assert not eng.queue and not eng.requests
    # RequestValidationError subclasses ValueError: historical callers'
    # except-clauses keep working
    with pytest.raises(ValueError, match="empty prompt"):
        eng.add_request(Request(uid=4, prompt=np.zeros((0,), np.int32)))


# ---------------------------------------------------------------------------
# retries: capped exponential backoff at the fault boundaries
# ---------------------------------------------------------------------------
def test_prefill_transient_retries_then_succeeds(dense_cfg, dense_params):
    inj = FaultInjector(0, [FaultRule("prefill", "transient", at=(0, 1))])
    eng = ServeEngine(dense_cfg, dense_params, batch_size=1, max_len=32,
                      faults=inj)
    req = Request(uid=0, prompt=_prompts(64, [4])[0], max_new_tokens=2)
    eng.submit(req)
    while eng.has_work():
        eng.step()
    assert req.state is RequestState.FINISHED
    assert eng.counters["retries:prefill"] == 2
    assert "retries_exhausted:prefill" not in eng.counters
    # backoff ran on the injector's virtual clock: 10ms + 20ms
    assert inj.clock() == pytest.approx(0.030)


def test_prefill_retries_exhausted_fails_typed(dense_cfg, dense_params):
    inj = FaultInjector(0, [FaultRule("prefill", "transient", prob=1.0)])
    eng = ServeEngine(dense_cfg, dense_params, batch_size=1, max_len=32,
                      faults=inj, retry_max=2)
    req = Request(uid=0, prompt=_prompts(64, [4])[0], max_new_tokens=2)
    eng.submit(req)
    eng.step()
    assert req.state is RequestState.FAILED
    assert req.finish_reason == REASON_RETRIES
    assert isinstance(req.error, InjectedFault) and req.error.transient
    assert eng.counters["retries:prefill"] == 2
    assert eng.counters["retries_exhausted:prefill"] == 1
    assert eng.slots == [None] and not eng.has_work()


def test_checkpoint_read_transient_retried(dense_cfg, dense_params,
                                           tmp_path):
    src = ServeEngine(dense_cfg, dense_params, batch_size=1, max_len=16)
    src.save_weights(str(tmp_path))
    inj = FaultInjector(0, [FaultRule("checkpoint_read", "transient",
                                      at=(0,))])
    eng = ServeEngine(dense_cfg, dense_params, batch_size=1, max_len=16,
                      faults=inj)
    eng.load_weights(str(tmp_path))
    assert eng.counters["retries:checkpoint_read"] == 1
    for x, y in zip(jax.tree.leaves(src.params), jax.tree.leaves(eng.params)):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


# ---------------------------------------------------------------------------
# poison isolation: every family, survivors bitwise vs the fault-free run
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("family", ["dense", "moe", "ssm", "hybrid"])
def test_poison_isolation_per_family(family):
    """A NaN-poisoned request quarantines ITS slot only: under W4A16
    decode is row-independent, so the N-1 surviving streams must be
    bitwise-identical to the fault-free oracle's for every family.  (MoE
    rides at batch 2, below the capacity-coupling threshold.)"""
    cfg, seed = flt._family_cfg(family)
    params, _ = build_model(cfg).init(jax.random.PRNGKey(seed))
    prompts = _prompts(cfg.vocab, [4, 5], seed=seed)

    def mk(faults=None):
        return ServeEngine(cfg, params, batch_size=2, max_len=32,
                           faults=faults)

    oracle = flt.drive(mk(), prompts, max_new_tokens=4)
    inj = FaultInjector(seed, [FaultRule("decode", "nan", at=(1,))])
    got = flt.drive(mk(faults=inj), prompts, max_new_tokens=4)
    victims = inj.fatal_victims()
    assert len(victims) == 1
    (victim,) = victims
    assert got["states"][victim] is RequestState.FAILED
    assert got["reasons"][victim] == REASON_NAN_LOGITS
    # the victim's stream is a strict prefix (no token from the poisoned
    # step), every survivor's is bitwise the oracle's
    assert got["streams"][victim] == \
        oracle["streams"][victim][:len(got["streams"][victim])]
    assert len(got["streams"][victim]) < len(oracle["streams"][victim])
    for uid in got["streams"]:
        if uid == victim:
            continue
        assert got["states"][uid] is RequestState.FINISHED
        assert got["streams"][uid] == oracle["streams"][uid], family


def test_w4a4_same_schedule_is_batch_deterministic(dense_cfg, dense_params):
    """Under W4A4 the quantized activation bytes couple batchmates
    (per-tensor scales), so survivors are NOT promised bitwise identity
    with the fault-free run — the promise is determinism: replaying the
    same seeded schedule reproduces every stream and terminal state."""
    prompts = _prompts(64, [4, 5])
    rules = lambda: [FaultRule("decode", "nan", at=(1,)),
                     FaultRule("decode", "slow", prob=0.3, delay_ms=5.0)]

    def run():
        eng = ServeEngine(dense_cfg, dense_params, batch_size=2, max_len=32,
                          act_quant="mixfp4",
                          faults=FaultInjector(7, rules()))
        return flt.drive(eng, prompts, max_new_tokens=4)

    a, b = run(), run()
    assert a["streams"] == b["streams"]
    assert a["states"] == b["states"]
    assert a["reasons"] == b["reasons"]
    assert sum(s is RequestState.FAILED for s in a["states"].values()) == 1


# ---------------------------------------------------------------------------
# graceful degradation: both rungs preserve the emitted streams
# ---------------------------------------------------------------------------
def test_fused_dispatch_degrades_to_2pass_bitwise(dense_cfg, dense_params):
    prompts = _prompts(64, [4, 5])

    def mk(faults=None):
        return ServeEngine(dense_cfg, dense_params, batch_size=2,
                           max_len=32, act_quant="mixfp4", faults=faults)

    oracle = flt.drive(mk(), prompts, max_new_tokens=4)
    inj = FaultInjector(0, [FaultRule("decode", "dispatch", at=(1,),
                                      times=1)])
    eng = mk(faults=inj)
    got = flt.drive(eng, prompts, max_new_tokens=4)
    # the fused kernel is bitwise-identical to the 2-pass composition
    # (shared tuner group + prepadded storage), so mid-stream fallback
    # changes dispatch count only — never a token
    assert got["streams"] == oracle["streams"]
    assert all(s is RequestState.FINISHED for s in got["states"].values())
    assert eng.act_quant == "mixfp4-2pass-rowscale"
    assert eng.counters["degraded_fused_to_2pass"] == 1


def test_pool_exhaustion_degrades_to_fixed_slot(dense_cfg, dense_params):
    """Admissions deferred past the budget abandon the paged pool: every
    in-flight request migrates by re-prefilling its token history, which
    greedy decode makes stream-preserving (the replay-bitwise property),
    and the deferred request admits on the fixed-slot path."""
    prompts = _prompts(64, [15, 15])

    def fixed():
        return ServeEngine(dense_cfg, dense_params, batch_size=2,
                           max_len=32, kv_quant="mixfp4",
                           clock=VirtualClock())

    oracle = flt.drive(fixed(), prompts, max_new_tokens=4)
    # 15 prompt + 4 new - 1 = 18 positions = 2 pages each; the pool holds
    # 2 usable pages, so the second admission defers while the first runs
    eng = ServeEngine(dense_cfg, dense_params, batch_size=2, max_len=32,
                      kv_quant="mixfp4", kv_pool=3, kv_page_len=16,
                      degrade_after_deferrals=1, clock=VirtualClock())
    a = Request(uid=0, prompt=prompts[0], max_new_tokens=4)
    b = Request(uid=1, prompt=prompts[1], max_new_tokens=4)
    eng.submit(a)
    streams = {0: [], 1: []}
    for _ in range(2):                   # a generates mid-flight tokens
        for uid, tok in eng.step():
            streams[uid].append(tok)
    eng.submit(b)
    guard = 0
    while eng.has_work():
        for uid, tok in eng.step():
            streams[uid].append(tok)
        guard += 1
        assert guard < 50
    assert eng.counters["degraded_paged_to_fixed"] == 1
    assert eng.kv_pool is None           # pool abandoned
    assert streams == oracle["streams"]
    assert a.state is RequestState.FINISHED
    assert b.state is RequestState.FINISHED


# ---------------------------------------------------------------------------
# chaos harness smoke: the sweep's own invariants hold on the paged engine
# ---------------------------------------------------------------------------
def test_chaos_sweep_paged_dense_smoke(dense_cfg, dense_params):
    prompts = _prompts(64, [4, 5, 6])

    def mk(faults=None):
        return ServeEngine(dense_cfg, dense_params, batch_size=2,
                           max_len=32, kv_quant="mixfp4", kv_pool=9,
                           kv_page_len=16, faults=faults)

    report = flt.chaos_sweep(mk, prompts, seeds=(0,), max_new_tokens=3)
    assert report["ok"]
    (sched,) = report["schedules"]
    assert sched["events"] >= 1 and not sched["violations"]
    # every injected fatal fault resolved to a typed terminal counter
    assert any(k.startswith(("failed:", "finished:"))
               for k in sched["counters"])
