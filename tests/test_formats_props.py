"""Property-based format tests (hypothesis).  Gated behind importorskip so a
bare environment still collects and runs the deterministic suite in
test_formats.py."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import formats  # noqa: E402


@settings(max_examples=50, deadline=None)
@given(st.floats(min_value=-440.0, max_value=440.0, allow_nan=False))
def test_e4m3_rounding_is_nearest(v):
    """Property: round_to_e4m3 returns one of the two bracketing E4M3 values
    and never the farther one."""
    all_vals = np.asarray(
        formats.bits_to_e4m3(jnp.arange(0x7F, dtype=jnp.uint8))
    ).astype(np.float64)
    all_vals = np.sort(np.unique(np.concatenate([all_vals, -all_vals])))
    r = float(formats.round_to_e4m3(jnp.float32(v)))
    err = abs(r - v)
    best = np.min(np.abs(all_vals - v))
    assert err <= best + 1e-7


@settings(max_examples=30, deadline=None)
@given(st.integers(min_value=0, max_value=2**31 - 1))
def test_sr_stays_on_lattice(seed):
    key = jax.random.PRNGKey(seed)
    x = jax.random.normal(key, (64,)) * 3
    q = formats.stochastic_round_to_codebook(x, formats.E2M1, key)
    lv = np.array(formats.E2M1.levels)
    assert np.all(np.isin(np.asarray(jnp.abs(q)), lv))
    # SR never moves past the bracketing levels
    assert np.all(np.abs(np.asarray(q)) <= 6.0)
