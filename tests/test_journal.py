"""Request journal (serving.journal): CRC framing, torn-tail and
corruption semantics, fsync policy, and record replay.

Pure host-side tests — no jax, no engine.  The recovery-through-the-
engine properties (bitwise resume, drain, watchdog) live in
tests/test_recovery.py; this file pins the storage contract they stand
on:

* a torn tail (crash mid-append) is silently truncated to the committed
  prefix on the next open,
* mid-record corruption (a COMPLETE record whose CRC mismatches) names
  the bad record and recovers exactly the good prefix — with
  ``repair=False`` it raises instead,
* an empty or missing journal is a clean cold start,
* ``replay`` folds submit/token/terminal/ckpt records into per-request
  states, dropping dangling tokens whose submit record was lost.
"""
import json
import os
import struct
import zlib

import pytest

from repro.serving.journal import (JOURNAL_NAME, JournalCorruption,
                                   JournalError, ReplayedRequest,
                                   RequestJournal, replay, scan_journal)


def _records(n=5, uid=1):
    recs = [{"t": "submit", "uid": uid, "prompt": [1, 2, 3],
             "max_new_tokens": n}]
    recs += [{"t": "token", "uid": uid, "tok": 10 + i} for i in range(n)]
    return recs


def _write(tmp_path, recs, sync="always"):
    j = RequestJournal(str(tmp_path), sync=sync)
    for r in recs:
        j.append(r)
    j.close()
    return os.path.join(str(tmp_path), JOURNAL_NAME)


# ---------------------------------------------------------------------------
# round trip + cold start
# ---------------------------------------------------------------------------
def test_round_trip(tmp_path):
    recs = _records()
    path = _write(tmp_path, recs)
    got, stats = scan_journal(path)
    assert got == recs
    assert stats["records"] == len(recs)
    assert stats["torn_tail_bytes"] == 0
    assert stats["valid_bytes"] == stats["bytes"] == os.path.getsize(path)


def test_missing_and_empty_journal_are_clean_cold_starts(tmp_path):
    path = os.path.join(str(tmp_path), JOURNAL_NAME)
    got, stats = scan_journal(path)          # missing file
    assert got == [] and stats["records"] == 0
    open(path, "wb").close()                 # empty file
    got, stats = scan_journal(path)
    assert got == [] and stats["records"] == 0
    j = RequestJournal(str(tmp_path))        # writer over the empty file
    assert j.records == []
    j.append({"t": "submit", "uid": 0, "prompt": [1], "max_new_tokens": 1})
    j.close()
    assert scan_journal(path)[0][0]["uid"] == 0


def test_reopen_appends_after_committed_prefix(tmp_path):
    _write(tmp_path, _records(3))
    j = RequestJournal(str(tmp_path))
    assert len(j.records) == 4               # 1 submit + 3 tokens
    j.append({"t": "terminal", "uid": 1, "state": "FINISHED",
              "reason": "max_new_tokens"})
    j.close()
    got, _ = scan_journal(os.path.join(str(tmp_path), JOURNAL_NAME))
    assert len(got) == 5 and got[-1]["t"] == "terminal"


# ---------------------------------------------------------------------------
# torn tail: crash mid-append
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("cut", ["header", "payload"])
def test_torn_tail_truncated_on_open(tmp_path, cut):
    """Chop the final record mid-header or mid-payload: scanning stops at
    the last complete record and reopening truncates the dangling bytes,
    so the NEXT append lands after a clean prefix."""
    recs = _records(4)
    path = _write(tmp_path, recs)
    size = os.path.getsize(path)
    # the last record's payload is small; cutting 2 bytes tears payload,
    # cutting (payload+6) tears into the header
    last_payload = len(json.dumps(recs[-1],
                                  separators=(",", ":")).encode())
    torn = 2 if cut == "payload" else last_payload + 6
    with open(path, "r+b") as f:
        f.truncate(size - torn)
    got, stats = scan_journal(path)
    assert got == recs[:-1]
    assert stats["torn_tail_bytes"] > 0
    j = RequestJournal(str(tmp_path))
    assert j.records == recs[:-1]
    assert j.stats["truncated_bytes"] == stats["torn_tail_bytes"]
    j.append(recs[-1])                       # append after repair
    j.close()
    assert scan_journal(path)[0] == recs


# ---------------------------------------------------------------------------
# mid-record corruption: CRC mismatch on a complete record
# ---------------------------------------------------------------------------
def _corrupt_record(path, index):
    """Flip one payload byte of record ``index`` in place."""
    with open(path, "r+b") as f:
        blob = f.read()
        off = 0
        for _ in range(index):
            length, _crc = struct.unpack_from("<II", blob, off)
            off += 8 + length
        length, _crc = struct.unpack_from("<II", blob, off)
        f.seek(off + 8)
        f.write(bytes([blob[off + 8] ^ 0xFF]))
    return off


def test_corruption_names_record_and_recovers_prefix(tmp_path):
    recs = _records(5)
    path = _write(tmp_path, recs)
    off = _corrupt_record(path, 3)
    with pytest.raises(JournalCorruption) as ei:
        scan_journal(path)
    err = ei.value
    assert err.index == 3 and err.offset == off
    assert err.records == recs[:3]
    assert "crc32 mismatch" in str(err)
    # repair=True (the serving posture): truncate to the good prefix
    j = RequestJournal(str(tmp_path))
    assert j.records == recs[:3]
    assert j.stats["corrupt_record_index"] == 3
    assert j.stats["truncated_bytes"] > 0
    j.close()
    assert scan_journal(path)[0] == recs[:3]


def test_corruption_strict_posture_raises(tmp_path):
    path = _write(tmp_path, _records(3))
    _corrupt_record(path, 1)
    with pytest.raises(JournalCorruption):
        RequestJournal(str(tmp_path), repair=False)


def test_valid_crc_bad_json_is_corruption(tmp_path):
    """A record whose CRC verifies but whose payload is not JSON is still
    corruption (a torn overwrite can do this) — never silently skipped."""
    path = os.path.join(str(tmp_path), JOURNAL_NAME)
    payload = b"\xff not json"
    with open(path, "wb") as f:
        f.write(struct.pack("<II", len(payload),
                            zlib.crc32(payload) & 0xFFFFFFFF))
        f.write(payload)
    with pytest.raises(JournalCorruption, match="does not parse"):
        scan_journal(path)


# ---------------------------------------------------------------------------
# fsync policy
# ---------------------------------------------------------------------------
def test_sync_modes_and_flush_accounting(tmp_path):
    with pytest.raises(JournalError, match="journal_sync"):
        RequestJournal(str(tmp_path), sync="sometimes")
    j = RequestJournal(str(tmp_path / "a"), sync="always")
    j.append({"t": "token", "uid": 0, "tok": 1})
    j.append({"t": "token", "uid": 0, "tok": 2})
    assert j.fsyncs == 2                     # one per append
    j.close()
    j = RequestJournal(str(tmp_path / "b"), sync="batch", sync_every=4)
    for i in range(8):
        j.append({"t": "token", "uid": 0, "tok": i})
        j.flush()
    assert j.fsyncs == 2                     # every 4th flush
    j.close()
    j = RequestJournal(str(tmp_path / "c"), sync="off")
    j.append({"t": "token", "uid": 0, "tok": 1})
    j.flush()
    assert j.fsyncs == 0                     # OS-buffered
    j.flush(force_sync=True)                 # the drain ledger path
    assert j.fsyncs == 1
    j.close()


# ---------------------------------------------------------------------------
# replay folding
# ---------------------------------------------------------------------------
def test_replay_folds_lifecycles():
    recs = [
        {"t": "submit", "uid": 1, "prompt": [1, 2], "max_new_tokens": 4,
         "deadline_ms": 500.0},
        {"t": "submit", "uid": 2, "prompt": [3], "max_new_tokens": 2},
        {"t": "token", "uid": 1, "tok": 7},
        {"t": "token", "uid": 2, "tok": 8},
        {"t": "token", "uid": 1, "tok": 9},
        {"t": "terminal", "uid": 2, "state": "CANCELLED",
         "reason": "slow_client"},
        {"t": "ckpt", "dir": "/w", "step": 3, "fp": "abc"},
        {"t": "ledger", "counters": {}},
        {"t": "from_the_future", "x": 1},    # unknown kind: skipped
    ]
    st = replay(recs)
    assert list(st.requests) == [1, 2]       # submission order
    r1, r2 = st.requests[1], st.requests[2]
    assert isinstance(r1, ReplayedRequest)
    assert r1.tokens == [7, 9] and not r1.terminal
    assert r1.deadline_ms == 500.0
    assert r2.tokens == [8] and r2.terminal
    assert r2.state == "CANCELLED" and r2.reason == "slow_client"
    assert st.checkpoint == {"dir": "/w", "step": 3, "fingerprint": "abc"}
    assert st.ledgers == 1
    assert [r.uid for r in st.live()] == [1]


def test_replay_drops_dangling_tokens():
    """Token/terminal records for a uid with no submit record (the submit
    was lost to a truncated prefix) are counted and dropped — the prompt
    is gone, so the request cannot be rebuilt."""
    st = replay([{"t": "token", "uid": 9, "tok": 1},
                 {"t": "token", "uid": 9, "tok": 2},
                 {"t": "terminal", "uid": 9, "state": "FINISHED"}])
    assert st.requests == {}
    assert st.dangling_tokens == 2
