"""RHT properties: orthogonality, GEMM exactness, kernel parity."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import hadamard


@pytest.mark.parametrize("n", [2, 8, 16, 64, 128])
def test_fwht_involution(n):
    x = jax.random.normal(jax.random.PRNGKey(0), (4, n))
    y = hadamard.fwht(hadamard.fwht(x))
    np.testing.assert_allclose(np.asarray(y), np.asarray(x), atol=1e-4)


def test_fwht_energy_preserving():
    x = jax.random.normal(jax.random.PRNGKey(1), (8, 64))
    y = hadamard.fwht(x)
    np.testing.assert_allclose(float(jnp.sum(y * y)), float(jnp.sum(x * x)),
                               rtol=1e-5)


def test_fwht_matches_matrix():
    n = 16
    import scipy.linalg
    H = scipy.linalg.hadamard(n) / np.sqrt(n)
    x = np.random.RandomState(0).randn(3, n).astype(np.float32)
    np.testing.assert_allclose(np.asarray(hadamard.fwht(jnp.asarray(x))),
                               x @ H.T, atol=1e-5)


def test_rht_gemm_exactness():
    """(HDx)^T (HDy) == x^T y — Fig. 7's WGRAD transform is exact pre-quant."""
    k = jax.random.PRNGKey(2)
    s = hadamard.rht_signs(k, 128)
    a = jax.random.normal(jax.random.PRNGKey(3), (128, 16))
    b = jax.random.normal(jax.random.PRNGKey(4), (128, 24))
    ra = hadamard.rht(a, s, axis=0)
    rb = hadamard.rht(b, s, axis=0)
    np.testing.assert_allclose(np.asarray(ra.T @ rb), np.asarray(a.T @ b),
                               atol=2e-4)


def test_rht_reduces_crest_of_spiky_blocks():
    """Paper §2.3: Hadamard mixing spreads outliers, lowering crest factors."""
    from repro.core import analysis
    x = jnp.zeros((256, 16)).at[:, 3].set(8.0)  # max-crest blocks
    s = hadamard.rht_signs(jax.random.PRNGKey(5), 16)
    xr = hadamard.rht(x.reshape(256, 16), s, axis=-1, group=16)
    c0 = float(analysis.crest_factor(x).mean())
    c1 = float(analysis.crest_factor(xr).mean())
    assert c1 < c0 * 0.5


def test_fwht_kernel_matches_ref():
    from repro.kernels import ops, ref
    for m, k, g in [(8, 64, 16), (16, 128, 16), (4, 256, 64), (32, 32, 32)]:
        x = jax.random.normal(jax.random.PRNGKey(m * k), (m, k), jnp.float32)
        s = hadamard.rht_signs(jax.random.PRNGKey(g), k)
        out_k = ops.rht_rows(x, s, group=g, bm=min(8, m))
        out_r = ref.ref_fwht_rows(x, s, group=g)
        np.testing.assert_allclose(np.asarray(out_k), np.asarray(out_r),
                                   atol=1e-5)


@pytest.mark.parametrize("m", [1, 3, 7])
@pytest.mark.parametrize("g", [8, 16, 32])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_fwht_kernel_matches_rht_bitwise(m, g, dtype):
    """Kernel vs ``hadamard.rht`` parity — BITWISE, not approximate.

    ``fwht_rows_math`` mirrors ``rht`` stage for stage (same elementwise
    adds/subs, same ``group ** -0.5`` multiply, no reductions), and the
    kernel evaluates it in f32 regardless of input dtype before casting
    back — so the comparison is exact equality against the f32 reference
    cast to the input dtype.  This is the guarantee the serve-time RHT
    (``act_rht=``) leans on: the fused GEMM prologue and the out-of-kernel
    per-row scale derivation must see identical transformed values.  Odd
    row counts exercise the kernel's bm fallback to 1-row tiles; the group
    count 3 per row is deliberately not a power of two.
    """
    from repro.kernels import ops
    k = 3 * g
    x = jax.random.normal(jax.random.PRNGKey(m * 31 + g), (m, k)).astype(dtype)
    s = hadamard.serve_signs(k)
    out_k = ops.rht_rows(x, s, group=g)
    want = hadamard.rht(x.astype(jnp.float32), s, axis=-1,
                        group=g).astype(dtype)
    assert out_k.dtype == dtype
    np.testing.assert_array_equal(np.asarray(out_k), np.asarray(want))


def test_fwht_kernel_rejects_bad_group_and_signs():
    """A non-power-of-two group has no butterfly factorization: the kernel
    must refuse rather than silently compute a partial transform (same
    contract as ``hadamard.fwht``).  Shape mismatches likewise fail fast."""
    from repro.kernels import ops
    x = jnp.ones((4, 48), jnp.float32)
    with pytest.raises(ValueError, match="power of two"):
        ops.rht_rows(x, jnp.ones((48,)), group=12)
    with pytest.raises(ValueError, match="not divisible"):
        ops.rht_rows(x, jnp.ones((48,)), group=32)
    with pytest.raises(ValueError, match="signs"):
        ops.rht_rows(x, jnp.ones((16,)), group=16)
    with pytest.raises(ValueError, match="power of two"):
        hadamard.fwht(jnp.ones((2, 12)))
