"""Property-based quantizer tests (hypothesis).  Gated behind importorskip
so a bare environment still collects and runs the deterministic suite in
test_quantize.py."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import quantize as Q  # noqa: E402


@settings(max_examples=25, deadline=None)
@given(st.integers(0, 10_000),
       st.sampled_from(["nvfp4", "nvint4", "mixfp4", "four_six"]))
def test_property_bounded_error(seed, method):
    """Block error is bounded by half the largest lattice step times the block
    scale (RNE, no saturation beyond absmax by construction)."""
    x = jax.random.normal(jax.random.PRNGKey(seed), (16, 64)) * (
        10.0 ** jax.random.uniform(jax.random.PRNGKey(seed + 1), (),
                                   minval=-3, maxval=3))
    bq, n, ax = Q.block_quantize_1d(x, method)
    deq = Q.dequantize_1d(bq, n, ax)
    err = jnp.abs(deq - x)
    # bound: (max step on any candidate lattice)/2 * s8 * s32, plus the e4m3
    # scale rounding slack (<= 2^-3 relative)
    step = 2.0  # largest E2M1 gap
    bound = (step / 2) * bq.scale8[..., None] * bq.scale32 * (1 + 2.0**-3) + 1e-6
    assert bool(jnp.all(err.reshape(bq.values.shape) <= bound))


@settings(max_examples=20, deadline=None)
@given(st.integers(0, 10_000))
def test_property_idempotent(seed):
    """qdq(qdq(x)) == qdq(x): quantized points are fixed points."""
    x = jax.random.normal(jax.random.PRNGKey(seed), (8, 48))
    once = Q.qdq(x, "mixfp4")
    twice = Q.qdq(once, "mixfp4")
    np.testing.assert_allclose(np.asarray(twice), np.asarray(once),
                               rtol=1e-6, atol=1e-6)
