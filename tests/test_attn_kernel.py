"""Fused packed-KV decode-attention kernel vs the dequantized reference
(interpret mode): ragged per-slot lengths, GQA grouping, odd dh block
counts, sliding windows, softcaps, and S-padding inside the ops entry."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref
from repro.models import base


def _packed_kv(key, b, s, hkv, dh, scale=1.0):
    kv = jax.random.normal(key, (b, s, hkv, dh), jnp.float32) * scale
    payload, scales = base.quantize_kv_rows(kv)
    return kv, payload, scales


CASES = [
    # (b, s, hkv, group, dh, window, softcap)
    (2, 32, 2, 2, 32, 0, 0.0),       # GQA, full causal
    (3, 24, 1, 4, 48, 0, 0.0),       # odd dh block count (3 blocks of 16)
    (2, 130, 2, 1, 32, 7, 30.0),     # S padded to the key tile + SWA + cap
    (1, 16, 3, 2, 16, 5, 0.0),       # window, single block of 16 lanes
]


@pytest.mark.parametrize("case", CASES)
def test_attn_decode_matches_dequant_reference(case):
    b, s, hkv, g, dh, window, softcap = case
    h = hkv * g
    keys = jax.random.split(jax.random.PRNGKey(int(sum(case))), 3)
    q = jax.random.normal(keys[0], (b, h, dh), jnp.float32)
    _, kp, ks = _packed_kv(keys[1], b, s, hkv, dh)
    _, vp, vs = _packed_kv(keys[2], b, s, hkv, dh)
    lengths = jnp.asarray(
        np.random.RandomState(s).randint(1, s + 1, (b,)), jnp.int32)
    out = ops.attn_decode_packed(q, kp, ks, vp, vs, lengths,
                                 window=window, softcap=softcap,
                                 interpret=True, bs=16)
    want = ref.ref_attn_decode_packed(q, kp, ks, vp, vs, lengths,
                                      window=window, softcap=softcap)
    assert out.shape == (b, h, dh) and out.dtype == jnp.float32
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), atol=1e-5)


def test_attn_decode_ref_matches_dense_attention():
    """The packed reference itself must agree with the model-side masked
    attention over the dequantized cache (same decode semantics: query at
    position lengths-1, kv_valid_len=lengths)."""
    b, s, hkv, g, dh = 2, 24, 2, 2, 32
    h = hkv * g
    keys = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(keys[0], (b, h, dh), jnp.float32)
    _, kp, ks = _packed_kv(keys[1], b, s, hkv, dh)
    _, vp, vs = _packed_kv(keys[2], b, s, hkv, dh)
    lengths = jnp.asarray([5, 17], jnp.int32)
    got = ref.ref_attn_decode_packed(q, kp, ks, vp, vs, lengths)
    k = ref.ref_dequant_kv(kp, ks)
    v = ref.ref_dequant_kv(vp, vs)
    want = base.attention(q[:, None].astype(jnp.float32), k, v,
                          causal_offset=lengths - 1,
                          kv_valid_len=lengths)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want[:, 0]),
                               atol=1e-5)


def test_attn_decode_full_vs_length_one():
    """lengths=1 attends only to the single valid row: the output is that
    row's V (softmax over one key), for every head group."""
    b, s, hkv, dh = 1, 16, 2, 32
    keys = jax.random.split(jax.random.PRNGKey(3), 3)
    q = jax.random.normal(keys[0], (b, 2 * hkv, dh), jnp.float32)
    _, kp, ks = _packed_kv(keys[1], b, s, hkv, dh)
    v, vp, vs = _packed_kv(keys[2], b, s, hkv, dh)
    out = ops.attn_decode_packed(q, kp, ks, vp, vs,
                                 jnp.ones((b,), jnp.int32), interpret=True)
    vrow = np.asarray(ref.ref_dequant_kv(vp, vs))[:, 0]  # (b, hkv, dh)
    want = np.repeat(vrow, 2, axis=1)                    # groups share kv
    np.testing.assert_allclose(np.asarray(out), want, atol=1e-5)


def test_quantize_kv_rows_pinned_scale32_roundtrip():
    """Incremental writes: quantizing rows one at a time under the shared
    KV_SCALE32 must produce the exact bytes of quantizing them all at
    once (that is what makes batched prefill == replay on packed rows)."""
    kv = jax.random.normal(jax.random.PRNGKey(5), (1, 6, 2, 32)) * 0.8
    p_all, s_all = base.quantize_kv_rows(kv)
    for t in range(6):
        p_t, s_t = base.quantize_kv_rows(kv[:, t:t + 1])
        np.testing.assert_array_equal(np.asarray(p_all[:, t:t + 1]),
                                      np.asarray(p_t))
        np.testing.assert_array_equal(np.asarray(s_all[:, t:t + 1]),
                                      np.asarray(s_t))
