"""Fused packed-KV decode-attention kernel vs the dequantized reference
(interpret mode): ragged per-slot lengths, GQA grouping, odd dh block
counts, sliding windows, softcaps, and S-padding inside the ops entry."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref
from repro.models import base


def _packed_kv(key, b, s, hkv, dh, scale=1.0):
    kv = jax.random.normal(key, (b, s, hkv, dh), jnp.float32) * scale
    payload, scales = base.quantize_kv_rows(kv)
    return kv, payload, scales


CASES = [
    # (b, s, hkv, group, dh, window, softcap)
    (2, 32, 2, 2, 32, 0, 0.0),       # GQA, full causal
    (3, 24, 1, 4, 48, 0, 0.0),       # odd dh block count (3 blocks of 16)
    (2, 130, 2, 1, 32, 7, 30.0),     # S padded to the key tile + SWA + cap
    (1, 16, 3, 2, 16, 5, 0.0),       # window, single block of 16 lanes
]


@pytest.mark.parametrize("case", CASES)
def test_attn_decode_matches_dequant_reference(case):
    b, s, hkv, g, dh, window, softcap = case
    h = hkv * g
    keys = jax.random.split(jax.random.PRNGKey(int(sum(case))), 3)
    q = jax.random.normal(keys[0], (b, h, dh), jnp.float32)
    _, kp, ks = _packed_kv(keys[1], b, s, hkv, dh)
    _, vp, vs = _packed_kv(keys[2], b, s, hkv, dh)
    lengths = jnp.asarray(
        np.random.RandomState(s).randint(1, s + 1, (b,)), jnp.int32)
    out = ops.attn_decode_packed(q, kp, ks, vp, vs, lengths,
                                 window=window, softcap=softcap,
                                 interpret=True, bs=16)
    want = ref.ref_attn_decode_packed(q, kp, ks, vp, vs, lengths,
                                      window=window, softcap=softcap)
    assert out.shape == (b, h, dh) and out.dtype == jnp.float32
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), atol=1e-5)


def test_attn_decode_ref_matches_dense_attention():
    """The packed reference itself must agree with the model-side masked
    attention over the dequantized cache (same decode semantics: query at
    position lengths-1, kv_valid_len=lengths)."""
    b, s, hkv, g, dh = 2, 24, 2, 2, 32
    h = hkv * g
    keys = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(keys[0], (b, h, dh), jnp.float32)
    _, kp, ks = _packed_kv(keys[1], b, s, hkv, dh)
    _, vp, vs = _packed_kv(keys[2], b, s, hkv, dh)
    lengths = jnp.asarray([5, 17], jnp.int32)
    got = ref.ref_attn_decode_packed(q, kp, ks, vp, vs, lengths)
    k = ref.ref_dequant_kv(kp, ks)
    v = ref.ref_dequant_kv(vp, vs)
    want = base.attention(q[:, None].astype(jnp.float32), k, v,
                          causal_offset=lengths - 1,
                          kv_valid_len=lengths)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want[:, 0]),
                               atol=1e-5)


def test_attn_decode_full_vs_length_one():
    """lengths=1 attends only to the single valid row: the output is that
    row's V (softmax over one key), for every head group."""
    b, s, hkv, dh = 1, 16, 2, 32
    keys = jax.random.split(jax.random.PRNGKey(3), 3)
    q = jax.random.normal(keys[0], (b, 2 * hkv, dh), jnp.float32)
    _, kp, ks = _packed_kv(keys[1], b, s, hkv, dh)
    v, vp, vs = _packed_kv(keys[2], b, s, hkv, dh)
    out = ops.attn_decode_packed(q, kp, ks, vp, vs,
                                 jnp.ones((b,), jnp.int32), interpret=True)
    vrow = np.asarray(ref.ref_dequant_kv(vp, vs))[:, 0]  # (b, hkv, dh)
    want = np.repeat(vrow, 2, axis=1)                    # groups share kv
    np.testing.assert_allclose(np.asarray(out), want, atol=1e-5)


def test_quantize_kv_rows_pinned_scale32_roundtrip():
    """Incremental writes: quantizing rows one at a time under the shared
    KV_SCALE32 must produce the exact bytes of quantizing them all at
    once (that is what makes batched prefill == replay on packed rows)."""
    kv = jax.random.normal(jax.random.PRNGKey(5), (1, 6, 2, 32)) * 0.8
    p_all, s_all = base.quantize_kv_rows(kv)
    for t in range(6):
        p_t, s_t = base.quantize_kv_rows(kv[:, t:t + 1])
        np.testing.assert_array_equal(np.asarray(p_all[:, t:t + 1]),
                                      np.asarray(p_t))
        np.testing.assert_array_equal(np.asarray(s_all[:, t:t + 1]),
                                      np.asarray(s_t))


# ---------------------------------------------------------------------------
# Paged decode: block-table pool slabs vs the fixed-slot kernel (PR-6)
# ---------------------------------------------------------------------------
def _page_slabs(payload, scales, page_len, seed):
    """Scatter a fixed (B, S, ...) packed cache into randomly-permuted pool
    slabs (P, page_len, ...) + the block tables that map them back.  Page 0
    stays zeroed — the pool's trash page."""
    b, s = payload.shape[:2]
    mp = s // page_len
    bt = 1 + np.random.RandomState(seed).permutation(b * mp).reshape(b, mp)
    slab_p = np.zeros((1 + b * mp, page_len) + payload.shape[2:],
                      np.asarray(payload).dtype)
    slab_s = np.zeros((1 + b * mp, page_len) + scales.shape[2:],
                      np.asarray(scales).dtype)
    for i in range(b):
        for j in range(mp):
            sl = slice(j * page_len, (j + 1) * page_len)
            slab_p[bt[i, j]] = payload[i, sl]
            slab_s[bt[i, j]] = scales[i, sl]
    return jnp.asarray(slab_p), jnp.asarray(slab_s), jnp.asarray(bt, jnp.int32)


PAGED_CASES = [
    # (b, s, hkv, group, dh, window, softcap, page_len, bs)
    (2, 64, 2, 2, 32, 0, 0.0, 16, 16),      # bs == page_len
    (2, 64, 2, 2, 32, 0, 0.0, 32, 16),      # bs < page_len: sub-page blocks
    (2, 64, 1, 4, 48, 7, 30.0, 16, 32),     # bs > page_len + SWA + softcap
    (1, 32, 2, 1, 32, 0, 0.0, 16, None),    # tuner-default key block
]


@pytest.mark.parametrize("case", PAGED_CASES)
def test_attn_decode_paged_bitwise_matches_fixed(case):
    """Acceptance: the paged kernel over permuted pool slabs must be
    BITWISE-identical to the fixed-slot kernel on the same logical rows —
    the block-table gather happens in BlockSpec index maps, the flash body
    is shared, and a matched key-block size means the same reduction
    order."""
    b, s, hkv, g, dh, window, softcap, page_len, bs = case
    h = hkv * g
    keys = jax.random.split(jax.random.PRNGKey(int(sum(case[:7]))), 3)
    q = jax.random.normal(keys[0], (b, h, dh), jnp.float32)
    _, kp, ks = _packed_kv(keys[1], b, s, hkv, dh)
    _, vp, vs = _packed_kv(keys[2], b, s, hkv, dh)
    lengths = jnp.asarray(
        np.random.RandomState(s).randint(1, s + 1, (b,)), jnp.int32)
    fixed = ops.attn_decode_packed(q, kp, ks, vp, vs, lengths,
                                   window=window, softcap=softcap,
                                   interpret=True, bs=bs)
    kpp, kps, bt = _page_slabs(kp, ks, page_len, seed=s)
    vpp, vps, bt2 = _page_slabs(vp, vs, page_len, seed=s)
    np.testing.assert_array_equal(np.asarray(bt), np.asarray(bt2))
    paged = ops.attn_decode_paged(q, kpp, kps, vpp, vps, bt, lengths,
                                  window=window, softcap=softcap,
                                  interpret=True, bs=bs)
    np.testing.assert_array_equal(np.asarray(paged), np.asarray(fixed))


def test_attn_decode_paged_matches_ref_gather():
    """The paged reference (gather logical view, then the dequant oracle)
    agrees with the paged kernel to f32 tolerance — an independent check
    that the index maps really read the pages the table names."""
    b, s, hkv, g, dh, page_len = 2, 48, 2, 2, 32, 16
    h = hkv * g
    keys = jax.random.split(jax.random.PRNGKey(9), 3)
    q = jax.random.normal(keys[0], (b, h, dh), jnp.float32)
    _, kp, ks = _packed_kv(keys[1], b, s, hkv, dh)
    _, vp, vs = _packed_kv(keys[2], b, s, hkv, dh)
    kpp, kps, bt = _page_slabs(kp, ks, page_len, seed=7)
    vpp, vps, _ = _page_slabs(vp, vs, page_len, seed=7)
    lengths = jnp.asarray([33, 48], jnp.int32)
    got = ops.attn_decode_paged(q, kpp, kps, vpp, vps, bt, lengths,
                                interpret=True, bs=16)
    want = ref.ref_attn_decode_packed(q, kpp, kps, vpp, vps, lengths,
                                      block_tables=bt)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-5)


def test_attn_decode_paged_trash_page_masked():
    """Rows in trailing trash-page table entries (page 0) must never leak
    into the output: a table whose tail columns point at a garbage-filled
    page 0 gives the same result as one pointing at real-but-masked
    pages."""
    b, s, hkv, dh, page_len = 1, 32, 2, 32, 16
    keys = jax.random.split(jax.random.PRNGKey(11), 3)
    q = jax.random.normal(keys[0], (b, 2 * hkv, dh), jnp.float32)
    _, kp, ks = _packed_kv(keys[1], b, s, hkv, dh)
    _, vp, vs = _packed_kv(keys[2], b, s, hkv, dh)
    kpp, kps, bt = _page_slabs(kp, ks, page_len, seed=3)
    vpp, vps, _ = _page_slabs(vp, vs, page_len, seed=3)
    lengths = jnp.asarray([13], jnp.int32)   # only page 1 of 2 is valid
    base_out = ops.attn_decode_paged(q, kpp, kps, vpp, vps, bt, lengths,
                                     interpret=True, bs=16)
    # fill the trash page with junk WIRE bytes (an unrelated quantized
    # cache: inactive-lane scatters write real encoder output, never
    # arbitrary bit patterns) and point the tail column at it
    _, jp, js = _packed_kv(jax.random.PRNGKey(99), 1, page_len, hkv, dh,
                           scale=3.0)
    kpp = kpp.at[0].set(jp[0])
    vpp = vpp.at[0].set(jp[0])
    kps = kps.at[0].set(js[0])
    vps = vps.at[0].set(js[0])
    bt_trash = jnp.asarray(np.array([[int(bt[0, 0]), 0]]), jnp.int32)
    out = ops.attn_decode_paged(q, kpp, kps, vpp, vps, bt_trash, lengths,
                                interpret=True, bs=16)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(base_out))
