"""Quantized GEMM boundary (Fig. 7) forward/backward tests."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import quantize as Q
from repro.core.qgemm import QuantConfig, qgemm


KEY = jax.random.PRNGKey(0)
X = jax.random.normal(jax.random.PRNGKey(1), (2, 24, 64), jnp.float32)
W = jax.random.normal(jax.random.PRNGKey(2), (64, 48), jnp.float32) * 0.2


def test_bf16_path_is_plain_matmul():
    cfg = QuantConfig(method="bf16")
    y = qgemm(cfg, X, W, KEY)
    ref = (X.astype(jnp.bfloat16) @ W.astype(jnp.bfloat16)).astype(jnp.float32)
    np.testing.assert_allclose(np.asarray(y), np.asarray(ref), rtol=1e-2,
                               atol=1e-2)


def test_fprop_matches_qdq_composition():
    """FPROP must equal Q(X) @ Q_2D(bf16(W)) exactly (same quantizers; the
    boundary casts the f32 master to bf16 before quantizing so FSDP gathers
    move bf16 — see qgemm._fwd_quantize)."""
    cfg = QuantConfig(method="mixfp4")
    y = qgemm(cfg, X, W, KEY)
    xq = Q.qdq(X, "mixfp4")
    wq = Q.qdq_2d(W.astype(jnp.bfloat16), "mixfp4")
    ref = jax.lax.dot_general(
        xq.astype(jnp.bfloat16), wq.astype(jnp.bfloat16),
        (((2,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    np.testing.assert_allclose(np.asarray(y), np.asarray(ref), rtol=1e-6,
                               atol=1e-6)


@pytest.mark.parametrize("method", ["mixfp4", "nvfp4", "four_six", "nvint4"])
def test_grad_close_to_bf16(method):
    loss = lambda cfg: (lambda x, w: jnp.sum(qgemm(cfg, x, w, KEY) ** 2))
    gq = jax.grad(loss(QuantConfig(method=method)), argnums=1)(X, W)
    gb = jax.grad(loss(QuantConfig(method="bf16")), argnums=1)(X, W)
    cos = float(jnp.sum(gq * gb) /
                (jnp.linalg.norm(gq) * jnp.linalg.norm(gb)))
    assert cos > 0.97, f"{method}: grad cosine {cos}"


def test_grads_deterministic_given_key():
    cfg = QuantConfig(method="mixfp4")
    f = jax.grad(lambda x, w, k: jnp.sum(qgemm(cfg, x, w, k)), argnums=(0, 1))
    g1 = f(X, W, KEY)
    g2 = f(X, W, KEY)
    for a, b in zip(g1, g2):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_sr_varies_with_key():
    cfg = QuantConfig(method="mixfp4", grad_rounding="sr")
    f = jax.grad(lambda x, w, k: jnp.sum(qgemm(cfg, x, w, k) ** 2), argnums=1)
    g1 = f(X, W, jax.random.PRNGKey(10))
    g2 = f(X, W, jax.random.PRNGKey(11))
    assert not np.allclose(np.asarray(g1), np.asarray(g2))


def test_rht_wgrad_consistency():
    """With RHT off vs on, WGRAD should agree to quantization noise (exact in
    infinite precision)."""
    f = lambda cfg: jax.grad(
        lambda x, w, k: jnp.sum(qgemm(cfg, x, w, k) ** 2), argnums=1)
    g_rht = f(QuantConfig(method="mixfp4", wgrad_rht=True,
                          grad_rounding="rne"))(X, W, KEY)
    g_no = f(QuantConfig(method="mixfp4", wgrad_rht=False,
                         grad_rounding="rne"))(X, W, KEY)
    cos = float(jnp.sum(g_rht * g_no) /
                (jnp.linalg.norm(g_rht) * jnp.linalg.norm(g_no)))
    assert cos > 0.99


def test_jit_and_vmap():
    cfg = QuantConfig(method="mixfp4")
    y = jax.jit(lambda x, w, k: qgemm(cfg, x, w, k))(X, W, KEY)
    assert y.shape == (2, 24, 48)
    # vmap over an expert dimension (MoE pattern)
    we = jnp.stack([W, W * 0.5, W * 2.0])
    ye = jax.vmap(lambda w: qgemm(cfg, X[0], w, KEY))(we)
    assert ye.shape == (3, 24, 48)
    assert np.isfinite(np.asarray(ye)).all()


def test_non_divisible_token_count():
    """WGRAD RHT pads the token axis to the Hadamard group."""
    x = jax.random.normal(jax.random.PRNGKey(5), (1, 13, 64))
    cfg = QuantConfig(method="mixfp4")
    g = jax.grad(lambda w: jnp.sum(qgemm(cfg, x, w, KEY) ** 2))(W)
    assert np.isfinite(np.asarray(g)).all()
