"""Per-kernel shape/dtype sweeps against the pure-jnp oracles (interpret mode)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import quantize as Q
from repro.kernels import ops, ref
from repro.kernels.mixfp4_quant import mixfp4_quant_rows


QUANT_SHAPES = [(8, 32), (16, 128), (64, 64), (128, 256), (4, 1024)]


@pytest.mark.parametrize("shape", QUANT_SHAPES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_quant_kernel_bit_exact(shape, dtype):
    x = (jax.random.normal(jax.random.PRNGKey(shape[0] * shape[1]), shape)
         * 3.0).astype(dtype)
    p_k, s_k, s32_k = mixfp4_quant_rows(x.astype(jnp.float32),
                                        interpret=True)
    p_r, s_r, s32_r = ref.ref_quant_pack_rows(x.astype(jnp.float32), "mixfp4")
    np.testing.assert_array_equal(np.asarray(p_k), np.asarray(p_r))
    np.testing.assert_array_equal(np.asarray(s_k), np.asarray(s_r))
    np.testing.assert_allclose(float(s32_k), float(s32_r), rtol=1e-6)


@pytest.mark.parametrize("tile", [(8, 16, 16), (16, 32, 64)])
@pytest.mark.parametrize("mkn", [(16, 64, 32), (32, 128, 64), (64, 256, 128)])
def test_gemm_w4a16_sweep(mkn, tile):
    m, k, n = mkn
    bm, bn, bk = tile
    if m % bm or n % bn or k % bk:
        pytest.skip("tile must divide problem")
    x = jax.random.normal(jax.random.PRNGKey(1), (m, k), jnp.float32)
    w = jax.random.normal(jax.random.PRNGKey(2), (k, n), jnp.float32) * 0.3
    qw = ops.pack_weight_qt(w)
    payload, scales, s32 = qw.payload, qw.scales, qw.scale32
    y_k = ops.gemm_w4a16(x, payload, scales, s32, bm=bm, bn=bn, bk=bk,
                         interpret=True)
    # f32 oracle (no bf16 tile rounding): dequantized weight matmul
    wd = ref.ref_dequant_weight_kn(payload, scales, s32)
    y_f32 = x @ wd
    # tolerance: bf16 operand rounding ~2^-8 relative
    scale = float(jnp.abs(y_f32).max()) + 1e-6
    np.testing.assert_allclose(np.asarray(y_k) / scale,
                               np.asarray(y_f32) / scale, atol=2e-2)


def test_gemm_w4a16_dequant_matches_qdq2d():
    """The packed weight path must represent exactly qdq_2d's values."""
    w = jax.random.normal(jax.random.PRNGKey(3), (96, 48)) * 0.5
    qw = ops.pack_weight_qt(w)
    wd = ref.ref_dequant_weight_kn(qw.payload, qw.scales, qw.scale32)
    wq = Q.qdq_2d(w, "mixfp4")
    np.testing.assert_allclose(np.asarray(wd), np.asarray(wq), rtol=0, atol=0)


@pytest.mark.parametrize("mkn", [(16, 64, 32), (32, 128, 64)])
def test_gemm_w4a4_sweep(mkn):
    m, k, n = mkn
    x = jax.random.normal(jax.random.PRNGKey(4), (m, k), jnp.float32)
    w = jax.random.normal(jax.random.PRNGKey(5), (k, n), jnp.float32) * 0.3
    qw = ops.pack_weight_qt(w)
    payload, scales, s32 = qw.payload, qw.scales, qw.scale32
    xp, xs, xs32 = ops.quantize_rows(x, interpret=True)
    y_k = ops.gemm_w4a4(xp, xs, xs32, payload, scales, s32,
                        bm=8, bn=16, bk=32, interpret=True)
    y_r = ref.ref_gemm_w4a4(xp, xs, xs32, payload, scales, s32)
    scale = float(jnp.abs(y_r).max()) + 1e-6
    np.testing.assert_allclose(np.asarray(y_k) / scale,
                               np.asarray(y_r) / scale, atol=2e-2)


@pytest.mark.parametrize("mkn", [(16, 64, 32), (5, 40, 24), (8, 272, 144)])
def test_gemm_w4a4_fused_bitwise_vs_composition(mkn):
    """The fused quantize+GEMM prologue must reproduce the two-dispatch
    ``quantize_rows -> qmm`` composition BIT FOR BIT (same tuner grid,
    exact encode/decode round trip in the prologue) — incl. K/N padding
    onto the packed grid and non-round dims the tuner pads further."""
    from repro.core import qtensor
    m, k, n = mkn
    x = jax.random.normal(jax.random.PRNGKey(m + k), (m, k)) * 2.0
    w = jax.random.normal(jax.random.PRNGKey(n), (k, n)) * 0.3
    qw = ops.pack_weight_qt(w)
    qx = qtensor.quantize_rows(x, pad_to=2 * qw.payload.shape[0],
                               interpret=True)
    y_two = qtensor.qmm(qx, qw, interpret=True)
    y_fused = qtensor.qmm(x, qw, fuse_act_quant=True, interpret=True)
    assert y_fused.shape == (m, n)
    np.testing.assert_array_equal(np.asarray(y_fused), np.asarray(y_two))


def test_gemm_w4a4_fused_explicit_tiles():
    """Direct kernel entry with multi-tile grids in every dimension: the
    prologue re-quantizes the x tile per N tile without perturbing a bit
    vs quantizing once up front."""
    m, k, n = 32, 64, 32
    x = jax.random.normal(jax.random.PRNGKey(44), (m, k), jnp.float32) * 2.0
    w = jax.random.normal(jax.random.PRNGKey(45), (k, n)) * 0.3
    qw = ops.pack_weight_qt(w)
    xp, xs, xs32 = ops.quantize_rows(x, interpret=True)
    for bm, bk, bn in [(8, 16, 16), (16, 32, 32), (32, 64, 16)]:
        y_two = ops.gemm_w4a4(xp, xs, xs32, qw.payload, qw.scales,
                              qw.scale32, bm=bm, bk=bk, bn=bn,
                              interpret=True)
        y_fused = ops.gemm_w4a4_fused(x, xs32, qw.payload, qw.scales,
                                      qw.scale32, bm=bm, bk=bk, bn=bn,
                                      interpret=True)
        np.testing.assert_array_equal(np.asarray(y_fused),
                                      np.asarray(y_two),
                                      err_msg=f"tiles {(bm, bk, bn)}")


def test_gemm_w4a4_fused_flag_validation():
    """fuse_act_quant must refuse operands it cannot honor rather than
    silently changing dispatch count or numerics: a packed activation
    (already quantized) and a non-kernel weight (would fall back to the
    dense qdq path) both raise."""
    from repro.core import qtensor
    from repro.core.qtensor import BlockLayout1D, QuantSpec, quantize
    x = jax.random.normal(jax.random.PRNGKey(52), (4, 32))
    qw = ops.pack_weight_qt(
        jax.random.normal(jax.random.PRNGKey(53), (32, 16)) * 0.3)
    qx = qtensor.quantize_rows(x, interpret=True)
    with pytest.raises(ValueError, match="already\\s+packed"):
        qtensor.qmm(qx, qw, fuse_act_quant=True, interpret=True)
    qw_1d = quantize(jax.random.normal(jax.random.PRNGKey(54), (32, 16)),
                     QuantSpec("mixfp4", BlockLayout1D(0)))
    with pytest.raises(ValueError, match="kernel-dispatchable"):
        qtensor.qmm(x, qw_1d, fuse_act_quant=True, interpret=True)


def test_dispatch_counter_counts_gemm_path():
    """ops.count_dispatches: the fused path is ONE kernel entry where the
    composition is two (quantize_rows + gemm_w4a4)."""
    from repro.core import qtensor
    x = jax.random.normal(jax.random.PRNGKey(50), (4, 64))
    qw = ops.pack_weight_qt(
        jax.random.normal(jax.random.PRNGKey(51), (64, 32)) * 0.3)
    with ops.count_dispatches() as fused_counts:
        jax.eval_shape(
            lambda a: qtensor.qmm(a, qw, fuse_act_quant=True,
                                  interpret=True), x)
    with ops.count_dispatches() as two_counts:
        jax.eval_shape(
            lambda a: qtensor.qmm(
                qtensor.quantize_rows(a, pad_to=64, interpret=True), qw,
                interpret=True), x)
    assert fused_counts == {"gemm_w4a4_fused": 1}, fused_counts
    assert two_counts == {"quantize_rows": 1, "gemm_w4a4": 1}, two_counts


def test_gemm_w4a16_serving_bytes():
    """Memory win: packed weight is ~3.55x smaller than bf16."""
    k, n = 256, 256
    w = jax.random.normal(jax.random.PRNGKey(6), (k, n))
    qw = ops.pack_weight_qt(w)
    assert k * n * 2 / qw.nbytes > 3.5


def test_quant_kernel_odd_rows():
    """Grid handles M not divisible by the row tile (bm auto-shrink)."""
    x = jax.random.normal(jax.random.PRNGKey(7), (12, 64), jnp.float32)
    p_k, s_k, _ = mixfp4_quant_rows(x, interpret=True, bm=4)
    p_r, s_r, _ = ref.ref_quant_pack_rows(x, "mixfp4")
    np.testing.assert_array_equal(np.asarray(p_k), np.asarray(p_r))


def test_quant_rows_zero_rows_pinned_scale32_canonical_scale_bytes():
    """Regression (type-in-sign safety): all-zero rows — incl. negative
    zeros, and under a pinned ``scale32=`` as the packed KV cache and the
    W4A4 path use — must emit canonical POSITIVE scale bytes.  A
    negative-zero E4M3 scale byte (0x80) has its type bit set, so the
    Fig. 9 decoder would read the dead block as E1M2; the branch guards
    map all-zero blocks to scale 1.0 (byte 0x38), and ``_pack_scale`` now
    structurally forbids a zero-magnitude byte from carrying the type
    bit."""
    for fill in (0.0, -0.0):
        x = jnp.full((2, 64), fill, jnp.float32)
        for kw in ({}, {"scale32": 1.0}, {"scale32": jnp.float32(0.25)}):
            p, s, _ = ops.quantize_rows(x, interpret=True, **kw)
            s_np, p_np = np.asarray(s), np.asarray(p)
            assert (s_np & 0x80 == 0).all(), (fill, kw, s_np)   # E2M1 type
            assert (s_np == 0x38).all(), (fill, kw, s_np)       # scale 1.0
            assert (p_np == 0).all()
            np.testing.assert_array_equal(
                np.asarray(ref.ref_dequant_kv(p, s, 1.0)), 0.0)
    # mixed row: the zero block keeps its canonical byte next to live ones
    x = jnp.zeros((1, 32), jnp.float32).at[0, 16:].set(3.0)
    _, s, _ = ops.quantize_rows(x, interpret=True, scale32=1.0)
    assert int(np.asarray(s)[0, 0]) == 0x38
    # the canonicalization itself: even if a zero-magnitude scale met a
    # set type bit, the packed byte must drop the bit (0x00, never 0x80)
    from repro.core import scaling
    from repro.kernels.mixfp4_quant import _pack_scale
    b = _pack_scale(jnp.zeros((1, 1)), jnp.ones((1, 1), jnp.uint8))
    assert int(np.asarray(b)[0, 0]) == 0x00
    b2 = scaling.pack_scale_with_type(jnp.zeros((1,)),
                                      jnp.ones((1,), jnp.uint8))
    assert int(np.asarray(b2)[0]) == 0x00
