"""Bit-level format tests: Table 1 codebooks, encode/decode, type-in-scale.

Property-based (hypothesis) companions live in test_formats_props.py so this
module collects on environments without hypothesis installed.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import formats, scaling


def test_table1_codebooks():
    # Table 1 exact values
    assert formats.E2M1.levels == (0.0, 0.5, 1.0, 1.5, 2.0, 3.0, 4.0, 6.0)
    # stored E1M2 magnitudes x2-remapped -> exact INT4 lattice (Fig. 6)
    assert formats.E1M2.levels == tuple(float(i) for i in range(8))
    assert formats.INT4.levels == tuple(float(i) for i in range(8))
    assert formats.E3M0.levels == (0.0, 0.25, 0.5, 1.0, 2.0, 4.0, 8.0, 16.0)
    # Table 1 numeric anchors
    assert formats.E2M1.max_level == 6.0      # S.11.1 = 1.5 * 2^2
    assert formats.E1M2.max_level == 7.0      # S.1.11 = 1.75 * 2 -> x2 = 7
    assert formats.PER_TENSOR_DENOM == 6 * 448 == 7 * 384


def test_e2m1_bit_layout():
    # payload index == [e1 e0 m]; decode must match Table 1 exactly
    nibbles = jnp.arange(16, dtype=jnp.uint8)
    vals = formats.e2m1_decode(nibbles)
    expect = np.array([0.0, 0.5, 1.0, 1.5, 2.0, 3.0, 4.0, 6.0] * 2)
    expect[8:] *= -1
    np.testing.assert_array_equal(np.asarray(vals), expect)


def test_e1m2_bit_layout():
    nibbles = jnp.arange(16, dtype=jnp.uint8)
    vals = formats.e1m2_decode(nibbles)
    expect = np.array([float(i) for i in range(8)] * 2)
    expect[8:] *= -1
    np.testing.assert_array_equal(np.asarray(vals), expect)


def test_encode_decode_roundtrip():
    for enc, dec, fmt in [
        (formats.e2m1_encode, formats.e2m1_decode, formats.E2M1),
        (formats.e1m2_encode, formats.e1m2_decode, formats.E1M2),
    ]:
        lv = np.array(fmt.levels)
        signed = np.concatenate([lv, -lv[1:]])
        out = dec(enc(jnp.asarray(signed)))
        np.testing.assert_array_equal(np.asarray(out), signed)


def test_decode_to_e2m2_unification():
    """Fig. 9: one decoder, two paths, selected by block-shared T."""
    nib = jnp.arange(16, dtype=jnp.uint8)
    v0 = formats.decode_to_e2m2(nib, jnp.zeros((), jnp.uint8))
    v1 = formats.decode_to_e2m2(nib, jnp.ones((), jnp.uint8))
    np.testing.assert_array_equal(np.asarray(v0), np.asarray(formats.e2m1_decode(nib)))
    np.testing.assert_array_equal(np.asarray(v1), np.asarray(formats.e1m2_decode(nib)))


def test_rne_ties_to_even():
    # E2M1 ties: 2.5 -> 2 (even mantissa), 1.75 -> 2, 5.0 -> 4
    x = jnp.array([2.5, -2.5, 1.75, 5.0, 0.25, 0.75])
    q = formats.quantize_to_codebook(x, formats.E2M1)
    np.testing.assert_array_equal(np.asarray(q), [2.0, -2.0, 2.0, 4.0, 0.0, 1.0])
    # INT lattice ties to even integer
    xi = jnp.array([0.5, 1.5, 2.5, 6.5])
    qi = formats.quantize_to_codebook(xi, formats.INT4)
    np.testing.assert_array_equal(np.asarray(qi), [0.0, 2.0, 2.0, 6.0])


def test_saturation():
    x = jnp.array([100.0, -100.0, 7.5, 16.5])
    assert float(formats.quantize_to_codebook(x, formats.E2M1)[0]) == 6.0
    assert float(formats.quantize_to_codebook(x, formats.INT4)[2]) == 7.0
    assert float(formats.quantize_to_codebook(x, formats.E3M0)[3]) == 16.0


def test_e4m3_bits_roundtrip():
    # every positive finite e4m3 pattern (0..0x7E) must round-trip via pack
    bits = jnp.arange(0x7F, dtype=jnp.uint8)
    vals = formats.bits_to_e4m3(bits)
    back = formats.e4m3_to_bits(vals)
    np.testing.assert_array_equal(np.asarray(back), np.asarray(bits))
    assert float(vals.max()) == 448.0


@pytest.mark.parametrize("t", [0, 1])
def test_scale_type_packing(t):
    scales = formats.bits_to_e4m3(jnp.arange(1, 0x7F, dtype=jnp.uint8))
    tb = jnp.full(scales.shape, t, jnp.uint8)
    packed = scaling.pack_scale_with_type(scales, tb)
    s2, t2 = scaling.unpack_scale_and_type(packed)
    np.testing.assert_array_equal(np.asarray(s2), np.asarray(scales))
    assert np.all(np.asarray(t2) == t)
    # zero extra storage: the packed scale is exactly one byte
    assert packed.dtype == jnp.uint8
