"""QTensor API: wire-format equivalence vs the legacy paths, pytree/jit
behaviour, and the qmm dispatcher."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import CheckpointManager
from repro.core import formats, pack, quantize as Q, qtensor
from repro.core.qtensor import (BlockLayout1D, BlockLayout2D, QTensor,
                                QuantSpec, qmm, quantize)
from repro.kernels import ref
from repro.kernels.mixfp4_gemm import _decode_nibbles


def _rand(shape, seed=0, scale=1.0):
    return jax.random.normal(jax.random.PRNGKey(seed), shape) * scale


# ---------------------------------------------------------------------------
# wire-format equivalence: new API must be bit-identical to the old
# block_quantize_* -> pack_blocks -> unpack_blocks round trips
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("shape,axis", [((8, 64), -1), ((8, 37), -1),
                                        ((24, 16), 0), ((4, 5, 48), -1)])
@pytest.mark.parametrize("method", ["mixfp4", "nvfp4"])
def test_1d_roundtrip_matches_legacy_path(shape, axis, method):
    x = _rand(shape, seed=sum(shape), scale=2.0)
    qt = quantize(x, QuantSpec(method, BlockLayout1D(axis)))
    bq, n, ax = Q.block_quantize_1d(x, method, axis=axis)
    legacy = Q._from_blocks_1d(pack.unpack_blocks(pack.pack_blocks(bq)), n, ax)
    np.testing.assert_array_equal(np.asarray(qt.dequantize()),
                                  np.asarray(legacy))
    assert qt.shape == tuple(x.shape)


@pytest.mark.parametrize("shape", [(64, 48), (40, 24), (16, 16)])
def test_2d_roundtrip_matches_qdq2d(shape):
    w = _rand(shape, seed=shape[0], scale=0.5)
    qt = quantize(w, QuantSpec("mixfp4", BlockLayout2D()))
    np.testing.assert_array_equal(np.asarray(qt.dequantize()),
                                  np.asarray(Q.qdq_2d(w, "mixfp4")))


def test_2d_matches_ref_pack_weight_kn():
    w = _rand((64, 48), 3, 0.4)
    qt = quantize(w, QuantSpec("mixfp4", BlockLayout2D()))
    p, s, s32 = ref.ref_pack_weight_kn(w)
    np.testing.assert_array_equal(np.asarray(qt.payload), np.asarray(p))
    np.testing.assert_array_equal(np.asarray(qt.scales), np.asarray(s))
    np.testing.assert_allclose(float(qt.scale32), float(s32), rtol=0)


def test_kernel_decoder_matches_fig9_reference():
    """The Pallas in-VMEM decoder must match formats.decode_to_e2m2 for all
    16 nibbles x both type bits (the Fig. 9 contract)."""
    nib = jnp.arange(16, dtype=jnp.uint8)
    for t in (0, 1):
        t_full = jnp.full((16,), t, jnp.uint8)
        got = _decode_nibbles(nib, t_full)
        want = formats.decode_to_e2m2(nib, jnp.uint8(t))
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_wire_bits_and_nbytes():
    x = _rand((64, 128), 2)
    qt = quantize(x, QuantSpec("mixfp4", BlockLayout1D(-1)))
    # 4 bits/value + 8 bits per 16-block (+4B tensor scale)
    assert (qt.nbytes - 4) * 8 == x.size * 4 + (x.size // 16) * 8
    assert qt.bits_per_value == pytest.approx(4.5, abs=0.01)


def test_unpackable_methods_rejected():
    x = _rand((8, 32))
    for m in ["mixfp4_e3", "nvfp4_e3", "four_six", "nvint4"]:
        with pytest.raises(ValueError):
            quantize(x, QuantSpec(m, BlockLayout1D(-1)))


# ---------------------------------------------------------------------------
# pytree behaviour
# ---------------------------------------------------------------------------
def test_pytree_flatten_preserves_metadata():
    qt = quantize(_rand((32, 48)), QuantSpec("mixfp4", BlockLayout2D()))
    leaves, treedef = jax.tree.flatten(qt)
    assert len(leaves) == 3
    qt2 = jax.tree.unflatten(treedef, leaves)
    assert (qt2.method, qt2.layout, qt2.shape, qt2.dtype) == \
        (qt.method, qt.layout, qt.shape, qt.dtype)
    np.testing.assert_array_equal(np.asarray(qt2.payload),
                                  np.asarray(qt.payload))


def test_jit_through_qtensor():
    qt = quantize(_rand((32, 48), 1), QuantSpec("mixfp4", BlockLayout2D()))
    f = jax.jit(lambda q: q.dequantize().sum())
    a = float(f(qt))
    b = float(qt.dequantize().sum())
    assert a == pytest.approx(b, rel=1e-6)


def test_scan_slices_stacked_qtensor():
    """A vmap-quantized per-layer weight stack is one QTensor whose children
    scan slices layer-by-layer (the serving params layout)."""
    wstack = _rand((3, 32, 48), 7, 0.3)
    spec = QuantSpec("mixfp4", BlockLayout2D())
    qts = jax.vmap(lambda m: quantize(m, spec))(wstack)
    x = _rand((4, 32), 8)

    def body(c, qt_layer):
        return c + qmm(x, qt_layer, interpret=True), None

    tot, _ = jax.lax.scan(body, jnp.zeros((4, 48)), qts)
    want = sum(qmm(x, quantize(wstack[i], spec), interpret=True)
               for i in range(3))
    np.testing.assert_allclose(np.asarray(tot), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# qmm dispatch
# ---------------------------------------------------------------------------
def test_qmm_w4a16_matches_dequant_matmul():
    x = _rand((5, 40), 4)           # padded K path (40 -> 48)
    w = _rand((40, 24), 5, 0.3)
    qt = quantize(w, QuantSpec("mixfp4", BlockLayout2D()))
    y = qmm(x, qt, interpret=True)
    want = jax.lax.dot(x.astype(jnp.bfloat16),
                       qt.dequantize().astype(jnp.bfloat16),
                       preferred_element_type=jnp.float32)
    scale = float(jnp.abs(want).max()) + 1e-6
    np.testing.assert_allclose(np.asarray(y) / scale,
                               np.asarray(want) / scale, atol=2e-2)
    assert y.shape == (5, 24)


def test_qmm_prime_m_pads_instead_of_degrading():
    """M with no divisor near the tile cap (e.g. prime 131 > 128) must be
    padded to a tile multiple, not served with 1-row grid tiles."""
    x = _rand((131, 32), 18)
    qt = quantize(_rand((32, 16), 19, 0.3), QuantSpec("mixfp4",
                                                      BlockLayout2D()))
    y = qmm(x, qt, interpret=True)
    assert y.shape == (131, 16)
    want = jax.lax.dot(x.astype(jnp.bfloat16),
                       qt.dequantize().astype(jnp.bfloat16),
                       preferred_element_type=jnp.float32)
    scale = float(jnp.abs(want).max()) + 1e-6
    np.testing.assert_allclose(np.asarray(y) / scale,
                               np.asarray(want) / scale, atol=2e-2)


def test_qmm_nd_activations():
    x = _rand((2, 3, 32), 6)
    qt = quantize(_rand((32, 48), 7, 0.3), QuantSpec("mixfp4",
                                                     BlockLayout2D()))
    y = qmm(x, qt, interpret=True)
    assert y.shape == (2, 3, 48)
    y2 = qmm(x.reshape(6, 32), qt, interpret=True).reshape(2, 3, 48)
    np.testing.assert_array_equal(np.asarray(y), np.asarray(y2))


def test_qmm_w4a4_matches_oracle():
    x = _rand((8, 64), 8)
    w = _rand((64, 32), 9, 0.3)
    qx = qtensor.quantize_rows(x, interpret=True)
    qw = quantize(w, QuantSpec("mixfp4", BlockLayout2D()))
    y = qmm(qx, qw, interpret=True)
    want = ref.ref_gemm_w4a4(qx.payload, qx.scales, qx.scale32,
                             qw.payload, qw.scales, qw.scale32)
    scale = float(jnp.abs(want).max()) + 1e-6
    np.testing.assert_allclose(np.asarray(y) / scale,
                               np.asarray(want) / scale, atol=2e-2)


def test_stack_matches_vmap_quantize():
    """qtensor.stack of per-layer QTensors == the vmap-quantized stack, and
    mismatched metadata is rejected."""
    wstack = _rand((3, 32, 48), 13, 0.3)
    spec = QuantSpec("mixfp4", BlockLayout2D())
    stacked = qtensor.stack([quantize(wstack[i], spec) for i in range(3)])
    vmapped = jax.vmap(lambda m: quantize(m, spec))(wstack)
    np.testing.assert_array_equal(np.asarray(stacked.payload),
                                  np.asarray(vmapped.payload))
    np.testing.assert_array_equal(np.asarray(stacked.scales),
                                  np.asarray(vmapped.scales))
    assert (stacked.method, stacked.layout, stacked.shape) == \
        (vmapped.method, vmapped.layout, vmapped.shape)

    def body(c, qt_layer):
        return c + qt_layer.dequantize().sum(), None

    tot, _ = jax.lax.scan(body, jnp.float32(0.0), stacked)
    want = sum(float(quantize(wstack[i], spec).dequantize().sum())
               for i in range(3))
    assert float(tot) == pytest.approx(want, rel=1e-5)

    other = quantize(_rand((16, 16), 14), spec)
    with pytest.raises(ValueError, match="identical QTensor metadata"):
        qtensor.stack([quantize(wstack[0], spec), other])


def test_ops_pack_weight_qt_matches_quantize():
    """The kernels-side producer must stay bit-identical to the real path
    it fronts (the deprecated pack_weight_kn triple shim is REMOVED; only
    pack_weight_qt remains — docs/qtensor.md migration table)."""
    from repro.kernels import ops
    assert not hasattr(ops, "pack_weight_kn")
    w = _rand((32, 48), 17, 0.3)
    a = ops.pack_weight_qt(w)
    b = quantize(w, QuantSpec("mixfp4", BlockLayout2D()))
    np.testing.assert_array_equal(np.asarray(a.payload), np.asarray(b.payload))
    np.testing.assert_array_equal(np.asarray(a.scales), np.asarray(b.scales))
    assert (a.method, a.layout, a.shape, a.dtype) == \
        (b.method, b.layout, b.shape, b.dtype)


def test_quantize_rows_pad_to_preserves_real_lane_bytes():
    """pad_to zero-pads K onto a wider packed grid (the W4A4 activation
    producer: quantize straight onto a packed weight's Kp grid) without
    perturbing the real lanes' payload/scale bytes — a zero tail never
    moves a block's absmax — and the tail blocks decode to exact zeros."""
    x = _rand((5, 64), 9, 2.0)
    q0 = qtensor.quantize_rows(x, interpret=True)
    q1 = qtensor.quantize_rows(x, pad_to=96, interpret=True)
    assert q1.payload.shape == (5, 48) and q1.scales.shape == (5, 6)
    assert q1.shape == (5, 64)                  # logical shape unchanged
    np.testing.assert_array_equal(np.asarray(q1.payload)[:, :32],
                                  np.asarray(q0.payload))
    np.testing.assert_array_equal(np.asarray(q1.scales)[:, :4],
                                  np.asarray(q0.scales))
    np.testing.assert_allclose(float(q1.scale32), float(q0.scale32), rtol=0)
    np.testing.assert_array_equal(np.asarray(q1.payload)[:, 32:], 0)
    np.testing.assert_array_equal(
        np.asarray(q1.dequantize()), np.asarray(q0.dequantize()))
    with pytest.raises(ValueError, match="pad_to"):
        qtensor.quantize_rows(x, pad_to=40, interpret=True)   # not 16-mult


def test_qmm_w4a4_padded_k_via_pad_to():
    """W4A4 with K not a multiple of 16: quantize_rows(pad_to=Kp) puts the
    activation on the weight's packed grid and qmm contracts only the
    logical lanes (padded lanes decode to exact zeros on both operands)."""
    x = _rand((5, 40), 21)
    w = _rand((40, 24), 22, 0.3)
    qw = quantize(w, QuantSpec("mixfp4", BlockLayout2D()))     # Kp = 48
    qx = qtensor.quantize_rows(x, pad_to=2 * qw.payload.shape[0],
                               interpret=True)
    y = qmm(qx, qw, interpret=True)
    assert y.shape == (5, 24)
    want = ref.ref_gemm_w4a4(qx.payload, qx.scales, qx.scale32,
                             qw.payload, qw.scales, qw.scale32)[:, :24]
    scale = float(jnp.abs(want).max()) + 1e-6
    np.testing.assert_allclose(np.asarray(y) / scale,
                               np.asarray(want) / scale, atol=2e-2)


def test_qmm_w4a4_logical_k_mismatch_raises():
    """Operands that pad to the same grid but disagree on logical K must
    raise, not silently contract over the padded lanes."""
    qx = qtensor.quantize_rows(_rand((4, 32), 15), interpret=True)  # Kp=32
    qw = quantize(_rand((20, 16), 16, 0.3),                         # Kp=32
                  QuantSpec("mixfp4", BlockLayout2D()))
    with pytest.raises(ValueError, match="K="):
        qmm(qx, qw, interpret=True)


def test_qmm_fallback_for_1d_weight():
    """1-D-blocked weights are not kernel-servable; qmm must fall back to
    the qdq-simulated path rather than fail."""
    x = _rand((4, 32), 10)
    qw = quantize(_rand((32, 16), 11, 0.3), QuantSpec("mixfp4",
                                                      BlockLayout1D(0)))
    y = qmm(x, qw, interpret=True)
    want = jax.lax.dot(x.astype(jnp.bfloat16),
                       qw.dequantize().astype(jnp.bfloat16),
                       preferred_element_type=jnp.float32)
    np.testing.assert_array_equal(np.asarray(y), np.asarray(want))


# ---------------------------------------------------------------------------
# packed checkpointing
# ---------------------------------------------------------------------------
def test_checkpoint_roundtrip_packed_tree(tmp_path):
    tree = {
        "layers": {"wq": jax.vmap(
            lambda m: quantize(m, QuantSpec("mixfp4", BlockLayout2D())))(
                _rand((2, 32, 32), 12, 0.3))},
        "ln": jnp.ones((32,)),
    }
    mgr = CheckpointManager(str(tmp_path))
    mgr.save_packed(3, tree)
    restored, extra = mgr.restore_packed()
    qt, qt0 = restored["layers"]["wq"], tree["layers"]["wq"]
    assert isinstance(qt, qtensor.QTensor)
    assert (qt.method, qt.layout, qt.shape) == (qt0.method, qt0.layout,
                                                qt0.shape)
    np.testing.assert_array_equal(np.asarray(qt.payload),
                                  np.asarray(qt0.payload))
    np.testing.assert_array_equal(np.asarray(qt.scales),
                                  np.asarray(qt0.scales))
    np.testing.assert_array_equal(np.asarray(restored["ln"]),
                                  np.asarray(tree["ln"]))
    np.testing.assert_array_equal(
        np.asarray(qt.dequantize()), np.asarray(qt0.dequantize()))


# ---------------------------------------------------------------------------
# prepad_for_tiles: cache padded operands at pack time (PR-6 satellite)
# ---------------------------------------------------------------------------
def test_prepad_for_tiles_reaches_tuner_fixed_point():
    """Off-grid (K, N) storage must be padded until the tuner's
    (k_pad, n_pad) choice equals the storage itself — so qmm stops
    re-padding inside every jitted call — while the logical shape and the
    wire bytes of the logical region are untouched."""
    from repro.kernels import tuning
    w = _rand((40, 24), 21, 0.3)      # off-grid both dims
    qt = quantize(w, QuantSpec("mixfp4", BlockLayout2D()))
    pp = qtensor.prepad_for_tiles(qt, "w4a16", 8)
    assert pp.shape == qt.shape       # logical shape preserved
    kp, np_ = 2 * pp.payload.shape[0], pp.payload.shape[1]
    ch = tuning.select_tiles("w4a16", 8, kp, np_)
    assert (ch.k_pad, ch.n_pad) == (kp, np_)   # fixed point reached
    # original bytes live unchanged in the top-left region; padding is 0
    op, os_ = np.asarray(qt.payload), np.asarray(qt.scales)
    np.testing.assert_array_equal(
        np.asarray(pp.payload)[:op.shape[0], :op.shape[1]], op)
    np.testing.assert_array_equal(
        np.asarray(pp.scales)[:os_.shape[0], :os_.shape[1]], os_)
    assert np.all(np.asarray(pp.payload)[op.shape[0]:] == 0)
    # a second pass is a no-op (the engine re-prepads after load_weights)
    assert qtensor.prepad_for_tiles(pp, "w4a16", 8) is pp


def test_prepad_for_tiles_preserves_qmm_bitwise():
    """qmm over the prepadded weight must be BITWISE what qmm computes
    over the original (it pads to the same tuner grid internally)."""
    x = _rand((8, 40), 22)
    qt = quantize(_rand((40, 24), 23, 0.3),
                  QuantSpec("mixfp4", BlockLayout2D()))
    pp = qtensor.prepad_for_tiles(qt, "w4a16", 8)
    np.testing.assert_array_equal(
        np.asarray(qmm(x, qt, interpret=True)),
        np.asarray(qmm(x, pp, interpret=True)))


def test_prepad_for_tiles_passes_through_non_2d():
    """Stacked (scan) QTensors and 1-D row layouts are not tile-padded:
    they pass through untouched."""
    stacked = qtensor.stack([
        quantize(_rand((32, 16), i, 0.3), QuantSpec("mixfp4",
                                                    BlockLayout2D()))
        for i in range(2)])
    assert qtensor.prepad_for_tiles(stacked, "w4a16", 4) is stacked
    rows = qtensor.quantize_rows(_rand((4, 32), 3), interpret=True)
    assert qtensor.prepad_for_tiles(rows, "w4a4", 4) is rows
