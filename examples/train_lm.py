"""End-to-end pretraining comparison (paper Fig. 10, scaled to this host).

Trains the paper's Qwen3-style model under BF16 / NVFP4 / 4-over-6 / MixFP4
from identical init and data, with the full Fig. 7 recipe (SR on grads, RHT
on WGRAD, 2-D weight blocks), reporting the late-stage loss gap.

Defaults are CPU-friendly (~2M params, 60 steps).  On a real cluster:
  --arch mixfp4-114m --steps 38000 --seq 2048 --batch 256   (the paper run)

Run:  PYTHONPATH=src python examples/train_lm.py [--steps 60] [--methods ...]
"""
import argparse

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.core.qgemm import QuantConfig
from repro.data import DataConfig, make_stream
from repro.models.base import ArchConfig, Ctx, build_model, param_count
from repro.optim import AdamWConfig, adamw_init, adamw_update, warmup_cosine


def train_one(cfg, steps, seq, batch, lr, seed=0):
    model = build_model(cfg)
    params, _ = model.init(jax.random.PRNGKey(seed))
    opt_cfg = AdamWConfig()
    opt = adamw_init(params)
    stream = make_stream(DataConfig(vocab=cfg.vocab, seq_len=seq,
                                    batch_per_shard=batch, seed=42))

    @jax.jit
    def step(params, opt, batch_, k, i):
        c = Ctx(k, cfg.quant)
        loss, g = jax.value_and_grad(
            lambda p: model.loss(p, batch_, c))(params)
        lr_i = warmup_cosine(i, max_lr=lr, warmup=max(steps // 10, 1),
                             total=steps)
        params, opt, gn = adamw_update(opt_cfg, params, opt, g, lr_i)
        return params, opt, loss, gn

    losses = []
    for i in range(steps):
        b = {k: jnp.asarray(v) for k, v in stream.batch(i).items()}
        params, opt, loss, gn = step(params, opt, b,
                                     jax.random.PRNGKey(9000 + i),
                                     jnp.int32(i))
        losses.append(float(loss))
        if i % max(steps // 10, 1) == 0:
            print(f"    step {i:4d} loss {losses[-1]:.4f}")
    return losses


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None,
                    help="config id; default = tiny qwen3-style")
    ap.add_argument("--steps", type=int, default=60)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--methods", default="bf16,nvfp4,four_six,mixfp4")
    args = ap.parse_args()

    if args.arch:
        base_cfg = configs.full_config(args.arch)
    else:
        base_cfg = ArchConfig(name="qwen3-tiny", family="dense", n_layers=2,
                              d_model=128, n_heads=4, n_kv_heads=2, d_ff=256,
                              vocab=256, qk_norm=True, attn_chunk=128)

    tails = {}
    for m in args.methods.split(","):
        cfg = base_cfg.replace(quant=QuantConfig(method=m))
        n = param_count(build_model(cfg).init(jax.random.PRNGKey(0))[0])
        print(f"[{m}] training {n/1e6:.1f}M params, {args.steps} steps")
        losses = train_one(cfg, args.steps, args.seq, args.batch, args.lr)
        tails[m] = float(np.mean(losses[-max(args.steps // 8, 1):]))
        print(f"[{m}] tail loss {tails[m]:.4f}")

    print("\n=== late-stage loss (paper Fig. 10b ordering) ===")
    for m, v in sorted(tails.items(), key=lambda kv: kv[1]):
        print(f"  {m:10s} {v:.4f}")
    if {"mixfp4", "nvfp4"} <= tails.keys():
        print(f"MixFP4 - NVFP4 gap: {tails['nvfp4'] - tails['mixfp4']:+.4f} "
              f"(positive = MixFP4 better, as in the paper)")


if __name__ == "__main__":
    main()
