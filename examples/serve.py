"""Batched serving with packed MixFP4 weights (deliverable b, serving kind).

Brings up a small LM, packs its weights into the paper's 4.5-bit wire
format, and serves a stream of batched requests through the continuous-
batching engine (greedy decode, slot reuse), reporting tokens/s and the
weight-memory compression.

Run:  PYTHONPATH=src python examples/serve.py [--requests 6] [--new-tokens 8]
"""
import argparse
import time

import jax
import numpy as np

from repro.core.qgemm import QuantConfig
from repro.models.base import ArchConfig, Ctx, build_model, param_count
from repro.serving.engine import Request, ServeEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--new-tokens", type=int, default=8)
    ap.add_argument("--batch", type=int, default=4)
    args = ap.parse_args()

    cfg = ArchConfig(name="serve-demo", family="dense", n_layers=2,
                     d_model=128, n_heads=4, n_kv_heads=2, d_ff=256,
                     vocab=256, attn_chunk=128,
                     quant=QuantConfig(method="mixfp4"))
    model = build_model(cfg)
    params, _ = model.init(jax.random.PRNGKey(0))
    print(f"model: {param_count(params)/1e6:.2f}M params")

    engine = ServeEngine(cfg, params, batch_size=args.batch, max_len=64)
    del params  # projections now live ONLY as packed QTensors in the engine
    print(f"packed MixFP4 QTensor weights: {engine.compression:.2f}x smaller "
          f"than bf16 ({engine.packed_bytes/1024:.0f} KiB vs "
          f"{engine.dense_bytes/1024:.0f} KiB), decode via qmm -> W4A16")

    rng = np.random.RandomState(0)
    pending = [Request(uid=i,
                       prompt=rng.randint(0, cfg.vocab, size=6).astype(np.int32),
                       max_new_tokens=args.new_tokens)
               for i in range(args.requests)]

    t0 = time.time()
    done_tokens = 0
    active = 0
    while pending or active:
        while pending and engine.add_request(pending[0]):
            print(f"  admitted request {pending[0].uid}")
            pending.pop(0)
        out = engine.step()
        done_tokens += len(out)
        # a fresh slot's first step can emit two tokens for one uid (the
        # prefill token + a decode token), so dedupe before reporting and
        # recompute occupancy from the slots themselves
        finished = {u for u, _ in out
                    if all(s is None or s.uid != u for s in engine.slots)}
        for u in sorted(finished):
            print(f"  request {u} finished")
        active = sum(s is not None for s in engine.slots)
        if not out and not pending:
            break
    dt = time.time() - t0
    print(f"\nserved {args.requests} requests, {done_tokens} tokens "
          f"in {dt:.1f}s ({done_tokens/dt:.1f} tok/s on CPU interpret mode)")


if __name__ == "__main__":
    main()
