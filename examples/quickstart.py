"""Quickstart: MixFP4 in five minutes.

1. Quantize a tensor with Algorithm 1 and inspect the per-block format
   choices (the paper's core idea),
2. pack it to the bit-exact wire format (zero-metadata type-in-scale),
3. run a quantized GEMM with the Fig. 7 training boundary and take grads,
4. run the Pallas kernels (interpret mode on CPU, native on TPU).

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp

from repro.core import analysis, pack, quantize as Q
from repro.core.qgemm import QuantConfig, qgemm
from repro.kernels import ops


def main():
    key = jax.random.PRNGKey(0)

    # --- 1. Algorithm 1: adaptive per-block E2M1 / E1M2 selection --------
    x = jax.random.normal(key, (64, 256)) * 2.0
    bq, n, ax = Q.block_quantize_1d(x, "mixfp4")
    frac_int = float(bq.type_bits.mean())
    print(f"blocks choosing INT-like E1M2: {frac_int:.1%}")
    for m in ["nvfp4", "nvint4", "four_six", "mixfp4"]:
        q = float(analysis.qsnr(x, Q.qdq(x, m)))
        print(f"  {m:10s} QSNR = {q:6.2f} dB")

    # --- 2. bit-exact packing: 4.5 bits/value, type bit in the scale sign -
    p = pack.pack_blocks(bq)
    bits = (pack.packed_nbytes(p) - 4) * 8 / x.size
    assert float(jnp.max(jnp.abs(pack.unpack_blocks(p)
                                 - bq.dequantize()))) == 0.0
    print(f"wire format: {bits:.3f} bits/value (payload+scales), "
          f"decode bit-exact")

    # --- 3. training GEMM boundary (FPROP/DGRAD/WGRAD of Fig. 7) ---------
    cfg = QuantConfig(method="mixfp4")
    w = jax.random.normal(jax.random.PRNGKey(1), (256, 128)) * 0.05
    loss = lambda w: jnp.sum(qgemm(cfg, x, w, key) ** 2)
    g = jax.grad(loss)(w)
    print(f"quantized GEMM loss={loss(w):.2f}, |dW|={float(jnp.abs(g).mean()):.4f}")

    # --- 4. Pallas kernels ------------------------------------------------
    payload, scales, s32 = ops.pack_weight_kn(w)
    y = ops.gemm_w4a16(x, payload, scales, s32, bm=64, bn=128, bk=128)
    print(f"packed W4A16 GEMM out: {y.shape}, "
          f"weight bytes {payload.size + scales.size} vs bf16 {w.size * 2}")


if __name__ == "__main__":
    main()
