"""Quickstart: MixFP4 in five minutes.

1. Quantize a tensor with Algorithm 1 and inspect the per-block format
   choices (the paper's core idea),
2. pack it to the bit-exact wire format (zero-metadata type-in-scale),
3. run a quantized GEMM with the Fig. 7 training boundary and take grads,
4. run the Pallas kernels (interpret mode on CPU, native on TPU),
5. shard the packed tensor over a host mesh (docs/sharding.md) —
   payload/scales co-sharded over the model axis, GEMM per shard.

Run:  PYTHONPATH=src python examples/quickstart.py
For a real 2-way model axis in step 5 on CPU, fake two host devices:
      XLA_FLAGS=--xla_force_host_platform_device_count=2 \
          PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp

from jax.sharding import PartitionSpec as P

from repro.core import analysis, quantize as Q, qtensor
from repro.core.qgemm import QuantConfig, qgemm
from repro.launch.mesh import make_host_mesh


def main():
    key = jax.random.PRNGKey(0)

    # --- 1. Algorithm 1: adaptive per-block E2M1 / E1M2 selection --------
    x = jax.random.normal(key, (64, 256)) * 2.0
    frac = analysis.selection_fractions(x, "mixfp4")
    print(f"blocks choosing INT-like E1M2: {frac[1]:.1%}")
    for m in ["nvfp4", "nvint4", "four_six", "mixfp4"]:
        q = float(analysis.qsnr(x, Q.qdq(x, m)))
        print(f"  {m:10s} QSNR = {q:6.2f} dB")

    # --- 2. the QTensor wire format: 4.5 bits/value, type in the scale sign
    qt = qtensor.quantize(x, qtensor.QuantSpec("mixfp4",
                                               qtensor.BlockLayout1D(-1)))
    err = float(jnp.max(jnp.abs(qt.dequantize() - Q.qdq(x, "mixfp4"))))
    assert err == 0.0, "packed round trip must be bit-exact vs simulated qdq"
    print(f"QTensor wire format: {qt.bits_per_value:.3f} bits/value "
          f"({qt.nbytes} B), decode bit-exact")

    # --- 3. training GEMM boundary (FPROP/DGRAD/WGRAD of Fig. 7) ---------
    cfg = QuantConfig(method="mixfp4")
    w = jax.random.normal(jax.random.PRNGKey(1), (256, 128)) * 0.05
    loss = lambda w: jnp.sum(qgemm(cfg, x, w, key) ** 2)
    g = jax.grad(loss)(w)
    print(f"quantized GEMM loss={loss(w):.2f}, |dW|={float(jnp.abs(g).mean()):.4f}")

    # --- 4. Pallas kernels through the qmm dispatcher ---------------------
    qw = qtensor.quantize(w, qtensor.QuantSpec("mixfp4",
                                               qtensor.BlockLayout2D()))
    y = qtensor.qmm(x, qw)
    print(f"packed W4A16 GEMM out: {y.shape}, "
          f"weight bytes {qw.nbytes} vs bf16 {w.size * 2}")

    # --- 5. sharded packed weights on a host mesh (docs/sharding.md) ------
    # QTensor.with_sharding derives co-sharded NamedShardings for the
    # payload/scale bytes from ONE logical spec — here column-parallel TP
    # over the 'model' axis — and qmm_sharded runs the W4A16 kernel per
    # shard, never gathering or dequantizing the full weight.  On a
    # 1-device host the mesh degenerates gracefully; fake 2 devices (see
    # module docstring) to watch the bytes actually split.
    tp = 2 if jax.device_count() % 2 == 0 and jax.device_count() >= 2 else 1
    mesh = make_host_mesh(model=tp)
    qw_sh = qw.with_sharding(mesh, P(None, "model"))
    y_sh = qtensor.qmm_sharded(x, qw_sh, mesh=mesh)
    assert bool(jnp.all(y == y_sh)), "column-parallel TP is bitwise exact"
    print(f"sharded packed GEMM on {dict(mesh.shape)}: payload sharding "
          f"{qw_sh.payload.sharding.spec}, bitwise equal to single-device")


if __name__ == "__main__":
    main()
