"""Restart smoke: SIGKILL a journaled HTTP serving process mid-stream,
restart it with ``--recover``, and assert the resumed streams are BITWISE
the uninterrupted control run (the CI ``restart-smoke`` leg).

This is the end-to-end proof of the crash-safe serving claim, driven over
real process boundaries rather than in-process fault injection:

1. a CONTROL server runs two requests to completion and records their
   full token streams (greedy decode makes them the deterministic oracle);
2. a VICTIM server with ``--journal-dir`` gets the same two requests,
   and the moment each stream has produced a few tokens the process is
   SIGKILLed — no atexit, no flush, exactly what a crash looks like;
3. a RECOVERY server starts over the same journal with ``--recover``;
   the client re-attaches at ``GET /resume/{uid}`` and reads each full
   stream (replayed prefix + live continuation);
4. the resumed streams must equal the control streams token-for-token,
   and the recovery server must report journal recovery on stdout.

Tokens the victim emitted after the journal's last committed fsync are
allowed to be lost on disk — recovery re-derives them bitwise (greedy
decode), which is exactly why the assertion is on the FULL stream, not on
what the journal happened to hold.

Run locally:  PYTHONPATH=src python tools/restart_smoke.py
"""
import os
import pathlib
import signal
import subprocess
import sys
import tempfile
import time

os.environ.setdefault("JAX_PLATFORMS", "cpu")

REPO = pathlib.Path(__file__).resolve().parent.parent
SRC = str(REPO / "src")
sys.path.insert(0, SRC)

from repro.serving.server import (get_json, resume_stream,  # noqa: E402
                                  stream_generate)

ARCH = "gemma2-2b"
NEW_TOKENS = 12
PROMPTS = {7: [1, 2, 3, 4], 8: [5, 6, 7]}


def _spawn(extra, port_file_env=None):
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    cmd = [sys.executable, "-m", "repro.launch.serve", "--arch", ARCH,
           "--smoke", "--batch", "2", "--max-len", "64",
           "--http-port", "0"] + extra
    return subprocess.Popen(cmd, env=env, cwd=str(REPO),
                            stdout=subprocess.PIPE,
                            stderr=subprocess.STDOUT, text=True)


def _wait_port(proc, timeout=240.0):
    """Parse the bound ephemeral port off the serve banner."""
    deadline = time.time() + timeout
    buf = []
    while time.time() < deadline:
        if proc.poll() is not None:
            raise RuntimeError("serve process died during startup:\n"
                               + "".join(buf))
        line = proc.stdout.readline()
        if not line:
            time.sleep(0.05)
            continue
        buf.append(line)
        if "HTTP front-end on http://127.0.0.1:" in line:
            port = int(line.split("http://127.0.0.1:", 1)[1].split()[0])
            return port, buf
    raise RuntimeError("serve process never bound a port:\n" + "".join(buf))


def _wait_ready(port, timeout=120.0):
    deadline = time.time() + timeout
    while time.time() < deadline:
        try:
            code, body = get_json("127.0.0.1", port, "/readyz", timeout=5.0)
            if code == 200:
                return body
        except OSError:
            pass
        time.sleep(0.1)
    raise RuntimeError(f"/readyz never went 200 on port {port}")


def main() -> int:
    with tempfile.TemporaryDirectory() as tmp:
        journal = os.path.join(tmp, "journal")

        # -- 1. control run: the uninterrupted oracle streams -----------
        ctrl = _spawn([])
        try:
            port, _ = _wait_port(ctrl)
            _wait_ready(port)
            oracle = {}
            for uid, prompt in PROMPTS.items():
                frames = list(stream_generate(
                    "127.0.0.1", port, prompt, uid=uid,
                    max_new_tokens=NEW_TOKENS))
                assert frames[-1]["type"] == "done", frames[-1]
                oracle[uid] = [f["token"] for f in frames
                               if f["type"] == "token"]
                assert len(oracle[uid]) == NEW_TOKENS
        finally:
            ctrl.kill()
            ctrl.wait()
        print(f"[restart-smoke] control streams recorded: "
              f"{ {u: len(t) for u, t in oracle.items()} }")

        # -- 2. victim: journaled, SIGKILLed mid-stream ------------------
        victim = _spawn(["--journal-dir", journal,
                         "--journal-sync", "always"])
        try:
            port, _ = _wait_port(victim)
            _wait_ready(port)
            # read a few tokens from each stream concurrently-ish: start
            # both, pull ~3 frames from each, then SIGKILL with both
            # requests mid-decode
            gens = {uid: stream_generate("127.0.0.1", port, prompt,
                                         uid=uid, max_new_tokens=NEW_TOKENS)
                    for uid, prompt in PROMPTS.items()}
            seen: dict = {uid: [] for uid in PROMPTS}
            for uid, gen in gens.items():
                for frame in gen:
                    if frame["type"] == "token":
                        seen[uid].append(frame["token"])
                        if len(seen[uid]) >= 3:
                            break
                    elif frame["type"] in ("done", "error"):
                        raise AssertionError(
                            f"victim stream {uid} terminated before the "
                            f"kill: {frame}")
            assert all(len(t) >= 3 for t in seen.values()), seen
            os.kill(victim.pid, signal.SIGKILL)
        finally:
            victim.kill()
            victim.wait()
        for uid, toks in seen.items():
            assert toks == oracle[uid][:len(toks)], \
                f"pre-kill stream {uid} diverged: {toks} vs {oracle[uid]}"
        print(f"[restart-smoke] victim SIGKILLed mid-stream with "
              f"{ {u: len(t) for u, t in seen.items()} } tokens out")

        # -- 3. recovery: restart over the journal, re-attach ------------
        rec = _spawn(["--journal-dir", journal, "--journal-sync", "always",
                      "--recover"])
        try:
            port, banner = _wait_port(rec)
            assert any("journal recovery" in ln for ln in banner), banner
            _wait_ready(port)
            for uid, want in oracle.items():
                frames = list(resume_stream("127.0.0.1", port, uid))
                toks = [f["token"] for f in frames
                        if f["type"] == "token"]
                assert frames and frames[-1]["type"] == "done", \
                    (uid, frames[-2:])
                assert toks == want, (
                    f"resumed stream {uid} NOT bitwise the control: "
                    f"{toks} vs {want}")
                n_replayed = sum(1 for f in frames if f.get("replayed"))
                print(f"[restart-smoke] uid {uid}: {n_replayed} replayed "
                      f"+ {len(toks) - n_replayed} live tokens == control")
            # graceful exit exercises the SIGTERM drain path too
            rec.send_signal(signal.SIGTERM)
            try:
                rec.wait(timeout=60)
            except subprocess.TimeoutExpired:
                raise AssertionError("SIGTERM drain never exited")
        finally:
            rec.kill()
            rec.wait()
    print("[restart-smoke] OK: resumed streams bitwise the "
          "uninterrupted control")
    return 0


if __name__ == "__main__":
    sys.exit(main())
