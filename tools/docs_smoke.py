"""Docs smoke: extract and run the fenced Python blocks from docs/*.md.

The guides' examples are executable by contract — this runner is what the
CI ``docs-smoke`` leg executes, so a doc edit that breaks its own example
fails CI instead of rotting silently (ISSUE 3 satellite).

Semantics:

* every ` ```python ` fenced block is executed; blocks within one file
  share a namespace, in file order, so an early block can import/set up
  for later ones (doctest-session style),
* blocks run on a faked 2-device CPU host — the XLA_FLAGS override below
  MUST precede any jax import, which is why this is a standalone script —
  so host-mesh examples (docs/sharding.md) exercise real >=2-way sharding,
* a failure reports file + block index + the offending source and exits
  nonzero.

Run locally:  PYTHONPATH=src python tools/docs_smoke.py [docs/sharding.md]
"""
import os

os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=2 "
                           + os.environ.get("XLA_FLAGS", ""))
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import pathlib
import re
import sys
import traceback

_BLOCK = re.compile(r"```python\n(.*?)```", re.S)


def run_file(path: pathlib.Path) -> int:
    blocks = _BLOCK.findall(path.read_text())
    ns = {"__name__": f"docs_smoke::{path.stem}"}
    for i, src in enumerate(blocks):
        label = f"{path}::block{i}"
        try:
            exec(compile(src, label, "exec"), ns)
        except Exception:
            print(f"[docs-smoke] FAIL {label}\n{'-' * 60}\n{src}{'-' * 60}")
            traceback.print_exc()
            return 1
        print(f"[docs-smoke] ok {label}")
    return 0


def main(argv=None) -> int:
    argv = sys.argv[1:] if argv is None else argv
    root = pathlib.Path(__file__).resolve().parent.parent
    paths = ([pathlib.Path(a) for a in argv] if argv
             else sorted((root / "docs").glob("*.md")))
    failures = sum(run_file(p) for p in paths)
    n_blocks = sum(len(_BLOCK.findall(p.read_text())) for p in paths)
    print(f"[docs-smoke] {len(paths)} files, {n_blocks} blocks, "
          f"{failures} failures")
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
