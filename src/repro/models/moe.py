"""Mixture-of-Experts layer with real expert parallelism.

Dispatch is sort-based (MegaBlocks-style), NOT the GShard dense-dispatch
einsum — at qwen3-moe scale the (T,E,C) one-hot einsum costs ~100x the expert
FFN FLOPs, so it would poison the roofline.  Layout:

  1. route (outside shard_map, f32): top-k over router logits; gates
     renormalised; Switch-style load-balance aux loss,
  2. EP mode ('expert', experts sharded over the model axis): tokens are
     re-sharded over (data x model) so every chip dispatches a distinct
     token slice.  Choices are sorted by expert; rank-within-expert gives a
     slot in a per-(source, expert) capacity buffer (cap =
     ceil(T_loc*k*cf/E); overflow drops — GShard policy).  ONE expert-major
     all_to_all ships (E, cap+1, D) -> (E_loc, M*(cap+1), D): each chip
     receives exactly its experts' tokens from every source, runs the
     quantized expert FFNs, and the reverse all_to_all returns outputs.
  3. FFN-TP mode ('ffn', for expert counts not divisible by the mesh, e.g.
     qwen2-moe's 60): tokens stay on their data shard (replicated over
     model); expert weights are sharded on d_ff and the down-projection
     psums over the model axis.  Dispatch work is duplicated M-fold but is
     O(T log T) sort + gathers — negligible next to the FFN.
  4. combine: gather outputs per choice, weight by gates, segment-sum over k.

Without a mesh (CPU smoke tests) the identical local math runs directly.
"""
from __future__ import annotations

import math
from functools import partial

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.core import qtensor
from repro.distributed.sharding import shard_map
from repro.models import base
from repro.models.base import ArchConfig, Ctx, Param, qlinear

__all__ = ["moe_init", "moe_apply"]


def stored_experts(cfg: ArchConfig) -> int:
    """Expert rows as stored: padded to a multiple of 16 so the EP dim is
    always shardable on the production mesh (qwen2: 60 -> 64; dummy experts
    are zero-init and receive no tokens)."""
    if cfg.ep_mode != "expert":
        return cfg.n_experts
    return -(-cfg.n_experts // 16) * 16


def moe_init(key, cfg: ArchConfig) -> dict:
    e, d, f = cfg.n_experts, cfg.d_model, cfg.d_ff_expert
    e_store = stored_experts(cfg)
    ks = jax.random.split(key, 5)
    s = 1.0 / math.sqrt(d)
    if cfg.ep_mode == "expert":
        wspec_in = P("model", None, None)
        wspec_out = P("model", None, None)
    else:  # ffn-TP
        wspec_in = P(None, None, "model")
        wspec_out = P(None, "model", None)

    def w(k, shape, scale):
        arr = jax.random.normal(k, shape, jnp.float32) * scale
        if e_store != e:
            arr = arr.at[e:].set(0.0)
        return arr

    p = {
        "router": Param(
            jax.random.normal(ks[0], (d, e), jnp.float32) * s, P(None, None)),
        "w_up": Param(w(ks[1], (e_store, d, f), s), wspec_in),
        "w_gate": Param(w(ks[2], (e_store, d, f), s), wspec_in),
        "w_down": Param(w(ks[3], (e_store, f, d), 1 / math.sqrt(f)),
                        wspec_out),
    }
    if cfg.shared_expert_ff:
        p["shared"] = base.mlp_init(ks[4], cfg, d_ff=cfg.shared_expert_ff)
    return p


def _route(x, wr, cfg: ArchConfig):
    """Router in f32: top-k gates (renormalised), indices, aux loss."""
    logits = jnp.einsum("td,de->te", x.astype(jnp.float32), wr)
    probs = jax.nn.softmax(logits, axis=-1)
    gates, idx = jax.lax.top_k(probs, cfg.top_k)            # (T,k)
    gates = gates / jnp.sum(gates, axis=-1, keepdims=True)
    e = cfg.n_experts
    ohot = jax.nn.one_hot(idx[:, 0], e, dtype=jnp.float32)  # primary choice
    aux = e * jnp.sum(jnp.mean(ohot, axis=0) * jnp.mean(probs, axis=0))
    return gates, idx, aux


def _dispatch_indices(idx, e: int, cap: int):
    """Sort choices by expert; rank-within-expert -> capacity slot.

    Returns flat arrays of length T*k; slot==cap marks a dropped choice
    (writes land in the discard slot of an (E, cap+1, D) buffer)."""
    t, k = idx.shape
    e_f = idx.reshape(-1)
    tok_f = jnp.repeat(jnp.arange(t), k)
    order = jnp.argsort(e_f, stable=True)
    e_s = e_f[order]
    tok_s = tok_f[order]
    starts = jnp.searchsorted(e_s, jnp.arange(e), side="left")
    pos = jnp.arange(t * k) - starts[e_s]
    keep = pos < cap
    slot = jnp.where(keep, pos, cap)
    return tok_s, e_s, slot, keep, order


def _n_experts(w) -> int:
    """Stored expert count of a dense (E, K, N) stack or a packed QTensor
    whose children carry the expert dim ahead of the tile grid."""
    return (w.payload.shape[0] if isinstance(w, qtensor.QTensor)
            else w.shape[0])


def _expert_ffn(wu, wg, wd, h, key, cfg: ArchConfig, psum_axis=None,
                act_quant: str = "bf16"):
    """Quantized per-expert FFN over (E_loc, C, D) buffers.

    Dense expert stacks vmap; packed QTensor stacks go through ``lax.map``
    instead — the map slices each expert's payload/scales out of the pytree
    so ``qmm`` sees concrete 2-D operands for the Pallas kernels (vmap would
    hand the kernels batched tracers).  ``act_quant`` rebuilds the serving
    activation format inside the per-expert Ctx (the engine's Ctx does not
    cross the shard_map boundary — only ``key`` ships), so W4A4 serving
    quantizes each expert's token buffer and runs the W4A4 kernel."""

    def one(i, wu_i, wg_i, wd_i, h_i):
        c = Ctx(jax.random.fold_in(key, 1000 + i), cfg.quant,
                act_quant=act_quant)
        up = qlinear(h_i, wu_i, c, 4)
        gate = jax.nn.silu(qlinear(h_i, wg_i, c, 5))
        return qlinear(gate * up, wd_i, c, 6)

    if isinstance(wu, qtensor.QTensor):
        out = jax.lax.map(lambda a: one(*a),
                          (jnp.arange(_n_experts(wu)), wu, wg, wd, h))
    else:
        out = jax.vmap(one)(jnp.arange(wu.shape[0]), wu, wg, wd, h)
    if psum_axis is not None:
        out = jax.lax.psum(out, psum_axis)
    return out


def _moe_local(x, gates, idx, key, wu, wg, wd, *, cfg: ArchConfig,
               m: int, ep: bool, model_axis: str, has_mesh: bool,
               e_pad: int | None = None, packed_metas=None,
               act_quant: str = "bf16"):
    """Per-shard MoE body.  x: (T_loc, D).  ``e_pad`` >= n_experts rounds the
    buffer's expert dim up to a multiple of the model axis (dummy experts
    receive no tokens; qwen2-moe pads 60 -> 64).

    ``packed_metas`` marks packed expert stacks shipped through shard_map
    as raw ``(payload, scales, scale32)`` child tuples (shard_map in_specs
    are per-array): each is rebuilt into a QTensor here from its static
    ``(method, layout, shape, dtype)`` meta, so the quantized expert FFNs
    run straight off each device's local packed expert bytes."""
    if packed_metas is not None:
        wu, wg, wd = (qtensor.QTensor(*children, *meta)
                      for children, meta in zip((wu, wg, wd), packed_metas))
    t, d = x.shape
    e = cfg.n_experts
    e_pad = e_pad or e
    cap = max(int(math.ceil(t * cfg.top_k * cfg.capacity_factor / e)), 4)

    tok_s, e_s, slot, keep, order = _dispatch_indices(idx, e, cap)
    gate_f = gates.reshape(-1)[order]

    buf = jnp.zeros((e_pad, cap + 1, d), x.dtype)
    buf = buf.at[e_s, slot].set(x[tok_s] * keep[:, None].astype(x.dtype))

    if ep and m > 1:
        recv = jax.lax.all_to_all(
            buf, model_axis, split_axis=0, concat_axis=1, tiled=True)
        out = _expert_ffn(wu, wg, wd, recv, key, cfg, act_quant=act_quant)
        back = jax.lax.all_to_all(
            out, model_axis, split_axis=1, concat_axis=0, tiled=True)
    else:
        psum_axis = model_axis if (not ep and has_mesh) else None
        back = _expert_ffn(wu, wg, wd, buf, key, cfg, psum_axis=psum_axis,
                           act_quant=act_quant)

    per_choice = back[e_s, slot] * (gate_f * keep)[:, None].astype(x.dtype)
    return jnp.zeros_like(x).at[tok_s].add(per_choice)


def moe_apply(p: dict, x: jax.Array, ctx: Ctx, cfg: ArchConfig):
    """x: (B, S, D) -> (out, aux_loss)."""
    b, s, d = x.shape
    xt = x.reshape(b * s, d)
    gates, idx, aux = _route(xt, p["router"], cfg)
    ep = cfg.ep_mode == "expert"
    t = b * s

    if ctx.mesh is None:
        out = _moe_local(xt, gates.astype(x.dtype), idx, ctx.key,
                         p["w_up"], p["w_gate"], p["w_down"],
                         cfg=cfg, m=1, ep=ep, model_axis=ctx.model_axis,
                         has_mesh=False, e_pad=_n_experts(p["w_up"]),
                         act_quant=ctx.act_quant)
    else:
        dta, mdl = ctx.data_axes, ctx.model_axis
        msize = ctx.model_size
        wu, wg, wd = p["w_up"], p["w_gate"], p["w_down"]
        packed = isinstance(wu, qtensor.QTensor)
        if packed and cfg.ep_mode != "expert":
            # ffn-TP splits the expert matrices along d_ff (row-parallel
            # w_down), which the packed shard_map path does not cover yet
            # (ROADMAP); serve this mode dense under a mesh
            wu, wg, wd = wu.dequantize(), wg.dequantize(), wd.dequantize()
            packed = False
        e_pad = None
        packed_metas = None
        if ep:
            # weights are stored pre-padded to a multiple of 16 (moe_init);
            # pad further only if the mesh demands it.  Packed stacks pad
            # their child bytes: zero payload/scales/scale32 decode (and
            # qmm) to exact zeros, so dummy experts stay inert.
            e_store = _n_experts(wu)
            e_pad = -(-e_store // msize) * msize
            if e_pad != e_store:
                padn = e_pad - e_store
                if packed:
                    wu, wg, wd = (
                        qtensor.QTensor(
                            jnp.pad(w_.payload, ((0, padn),) + ((0, 0),) * 2),
                            jnp.pad(w_.scales, ((0, padn),) + ((0, 0),) * 2),
                            jnp.pad(w_.scale32, ((0, padn),)),
                            w_.method, w_.layout, w_.shape, w_.dtype)
                        for w_ in (wu, wg, wd))
                else:
                    wu = jnp.pad(wu, ((0, padn), (0, 0), (0, 0)))
                    wg = jnp.pad(wg, ((0, padn), (0, 0), (0, 0)))
                    wd = jnp.pad(wd, ((0, padn), (0, 0), (0, 0)))
            # tokens re-shard over every chip: each dispatches a distinct
            # slice; pad T to the shard count (pads route to expert 0 with
            # zero gate).
            tok_axes = tuple(dict.fromkeys([*dta, mdl]))
            shards = 1
            for a in tok_axes:
                shards *= ctx.mesh.shape[a]
            pad = (-t) % shards
            if pad:
                xt = jnp.pad(xt, ((0, pad), (0, 0)))
                gates = jnp.pad(gates, ((0, pad), (0, 0)))
                idx = jnp.pad(idx, ((0, pad), (0, 0)))
            tok_spec = P(tok_axes, None)
            if packed:
                # ship the packed children (shard_map in_specs are
                # per-array): whole experts shard over the model axis —
                # E is a QTensor batch dim, so payload/scales/scale32 all
                # shard on dim 0 and K/N tiles stay intact per expert
                packed_metas = tuple(
                    (w_.method, w_.layout, w_.shape, w_.dtype)
                    for w_ in (wu, wg, wd))
                wu, wg, wd = ((w_.payload, w_.scales, w_.scale32)
                              for w_ in (wu, wg, wd))
                wspec = (P(mdl, None, None), P(mdl, None, None), P(mdl))
            else:
                wspec = P(mdl, None, None)
            in_specs = (tok_spec, tok_spec, tok_spec, P(),
                        wspec, wspec, wspec)
            out_spec = tok_spec
        else:
            # ffn-TP: tokens stay on their data shard, replicated over model
            # (the model axis carries d_ff; exclude it from the token axes)
            dta = tuple(a for a in dta if a != mdl) or ("data",)
            tok_spec = P(dta, None)
            in_specs = (tok_spec, tok_spec, tok_spec, P(),
                        P(None, None, mdl), P(None, None, mdl),
                        P(None, mdl, None))
            out_spec = tok_spec

        body = partial(_moe_local, cfg=cfg, m=msize, ep=ep,
                       model_axis=mdl, has_mesh=True, e_pad=e_pad,
                       packed_metas=packed_metas, act_quant=ctx.act_quant)
        out = shard_map(
            body, mesh=ctx.mesh, in_specs=in_specs, out_specs=out_spec,
        )(xt, gates.astype(x.dtype), idx, ctx.key, wu, wg, wd)
        out = out[:t]

    if "shared" in p:
        out = out + base.mlp(p["shared"], xt[:t], ctx, cfg)
    return out.reshape(b, s, d), aux * cfg.router_aux_coef
