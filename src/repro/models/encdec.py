"""Encoder-decoder backbone (seamless-m4t-medium).

The audio frontend is a STUB per the brief: ``input_specs()`` provides
precomputed frame embeddings (B, S_src, D) as the encoder input.  The decoder
is a standard causal transformer with cross-attention into the encoder
memory.  All projections (self-attn, cross-attn, FFN, both sides) run through
the MixFP4 GEMM boundary.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.models import base
from repro.models.base import (ArchConfig, Ctx, attention, qlinear, rms_norm,
                               shard, unzip_params)


class EncDecLM:
    def __init__(self, cfg: ArchConfig):
        assert cfg.n_dec_layers > 0
        self.cfg = cfg

    # ------------------------------------------------------------------
    def _enc_layer_init(self, key):
        cfg = self.cfg
        k1, k2 = jax.random.split(key)
        return {
            "ln_attn": base.norm_init(cfg.d_model),
            "attn": base.attn_init(k1, cfg),
            "ln_mlp": base.norm_init(cfg.d_model),
            "mlp": base.mlp_init(k2, cfg),
        }

    def _dec_layer_init(self, key):
        cfg = self.cfg
        k1, k2, k3 = jax.random.split(key, 3)
        return {
            "ln_self": base.norm_init(cfg.d_model),
            "self_attn": base.attn_init(k1, cfg),
            "ln_cross": base.norm_init(cfg.d_model),
            "cross_attn": base.attn_init(k2, cfg),
            "ln_mlp": base.norm_init(cfg.d_model),
            "mlp": base.mlp_init(k3, cfg),
        }

    def init(self, key):
        cfg = self.cfg
        ke, k1, k2 = jax.random.split(key, 3)
        _, esp = unzip_params(self._enc_layer_init(k1))
        _, dsp = unzip_params(self._dec_layer_init(k2))
        enc_specs = jax.tree.map(lambda s: P(None, *s), esp)
        dec_specs = jax.tree.map(lambda s: P(None, *s), dsp)
        ekeys = jax.random.split(k1, cfg.n_layers)
        dkeys = jax.random.split(k2, cfg.n_dec_layers)
        values = {
            "embed": jax.random.normal(ke, (base.padded_vocab(cfg.vocab), cfg.d_model),
                                       jnp.float32) * 0.02,
            "enc_layers": jax.vmap(
                lambda k: unzip_params(self._enc_layer_init(k))[0])(ekeys),
            "dec_layers": jax.vmap(
                lambda k: unzip_params(self._dec_layer_init(k))[0])(dkeys),
            "ln_enc": jnp.ones((cfg.d_model,), jnp.float32),
            "ln_dec": jnp.ones((cfg.d_model,), jnp.float32),
        }
        specs = {
            "embed": P("model", None),
            "enc_layers": enc_specs,
            "dec_layers": dec_specs,
            "ln_enc": P(None),
            "ln_dec": P(None),
        }
        return values, specs

    # ------------------------------------------------------------------
    def encode(self, params, src_embeds, ctx: Ctx):
        """src_embeds: (B, S_src, D) precomputed frame embeddings (stub)."""
        cfg = self.cfg
        x = shard(src_embeds.astype(jnp.bfloat16), "data", None, None)
        positions = jnp.arange(x.shape[1])[None, :]
        lkeys = jax.random.split(jax.random.fold_in(ctx.key, 1), cfg.n_layers)

        def body(x, xs):
            lp, lk = xs
            lctx = ctx.with_key(lk)
            h = rms_norm(x, lp["ln_attn"], cfg.norm_eps)
            a, _ = base.attn_apply(lp["attn"], h, lctx.fold(1), cfg,
                                   positions=positions, window=0,
                                   causal=False)
            x = x + a
            h = rms_norm(x, lp["ln_mlp"], cfg.norm_eps)
            x = x + base.mlp(lp["mlp"], h, lctx.fold(2), cfg)
            return shard(x, "data", None, "model"), None

        body_fn = jax.checkpoint(body)
        x, _ = jax.lax.scan(body_fn, x, (params["enc_layers"], lkeys))
        return rms_norm(x, params["ln_enc"], cfg.norm_eps)

    def _cross_attn(self, p, x, memory, ctx: Ctx, cfg):
        b, s, _ = x.shape
        dh = cfg.dh
        q = qlinear(x, p["wq"], ctx, 0).reshape(b, s, cfg.n_heads, dh)
        k = qlinear(memory, p["wk"], ctx, 1).reshape(
            b, memory.shape[1], cfg.n_kv_heads, dh)
        v = qlinear(memory, p["wv"], ctx, 2).reshape(
            b, memory.shape[1], cfg.n_kv_heads, dh)
        o = attention(q, k, v, causal=False, chunk=cfg.attn_chunk)
        return qlinear(o.reshape(b, s, -1), p["wo"], ctx, 3)

    def _decoder(self, params, tokens, memory, ctx: Ctx, *,
                 kv_cache=None, cache_len=None, positions=None):
        cfg = self.cfg
        x = params["embed"][tokens].astype(jnp.bfloat16)
        x = shard(x, "data", None, None)
        if positions is None:
            positions = jnp.arange(x.shape[1])[None, :]
        lkeys = jax.random.split(jax.random.fold_in(ctx.key, 2),
                                 cfg.n_dec_layers)
        use_cache = kv_cache is not None

        def body(carry, xs):
            x = carry
            if use_cache:
                lp, lk, ck, cv = xs
            else:
                lp, lk = xs
                ck = cv = None
            lctx = ctx.with_key(lk)
            h = rms_norm(x, lp["ln_self"], cfg.norm_eps)
            a, ncache = base.attn_apply(
                lp["self_attn"], h, lctx.fold(1), cfg, positions=positions,
                window=0, kv_cache=(ck, cv) if use_cache else None,
                cache_len=cache_len)
            x = x + a
            h = rms_norm(x, lp["ln_cross"], cfg.norm_eps)
            x = x + self._cross_attn(lp["cross_attn"], h, memory,
                                     lctx.fold(2), cfg)
            h = rms_norm(x, lp["ln_mlp"], cfg.norm_eps)
            x = x + base.mlp(lp["mlp"], h, lctx.fold(3), cfg)
            x = shard(x, "data", None, "model")
            return x, ncache if use_cache else None

        body_fn = jax.checkpoint(body)
        xs = ((params["dec_layers"], lkeys, kv_cache[0], kv_cache[1])
              if use_cache else (params["dec_layers"], lkeys))
        x, caches = jax.lax.scan(body_fn, x, xs)
        x = rms_norm(x, params["ln_dec"], cfg.norm_eps)
        return x, caches

    # ------------------------------------------------------------------
    def hidden(self, params, batch, ctx: Ctx):
        memory = self.encode(params, batch["src_embeds"], ctx)
        x, _ = self._decoder(params, batch["tokens"], memory, ctx)
        return x, 0.0

    def forward(self, params, batch, ctx: Ctx):
        """batch: src_embeds (B,S,D), tokens (B,T), labels (B,T)."""
        x, aux = self.hidden(params, batch, ctx)
        logits = base.lm_logits(x, params["embed"], self.cfg.softcap_final)
        return base.shard(logits, "data", None, "model"), aux

    def loss(self, params, batch, ctx: Ctx):
        x, aux = self.hidden(params, batch, ctx)
        return base.fused_lm_loss(x, params["embed"], batch["labels"],
                                  self.cfg.softcap_final,
                                  self.cfg.vocab) + aux

    # ------------------------------------------------------------------
    def init_cache(self, batch_size: int, max_len: int, dtype=jnp.bfloat16):
        cfg = self.cfg
        shape = (cfg.n_dec_layers, batch_size, max_len, cfg.n_kv_heads,
                 cfg.dh)
        return {
            "k": jnp.zeros(shape, dtype),
            "v": jnp.zeros(shape, dtype),
            "memory": jnp.zeros((batch_size, max_len, cfg.d_model), dtype),
        }

    def cache_specs(self):
        spec = P(None, "data", "model", None, None)
        return {"k": spec, "v": spec,
                "memory": P("data", "model", None)}

    def prefill(self, params, batch, ctx: Ctx, cache):
        """Encode source; prefill decoder on the target prefix."""
        cfg = self.cfg
        memory = self.encode(params, batch["src_embeds"], ctx)
        mem_len = memory.shape[1]
        mem_buf = jax.lax.dynamic_update_slice_in_dim(
            cache["memory"], memory.astype(cache["memory"].dtype), 0, axis=1)
        x, (nk, nv) = self._decoder(
            params, batch["tokens"], memory, ctx,
            kv_cache=(cache["k"], cache["v"]), cache_len=0)
        logits = base.lm_logits(x[:, -1], params["embed"], cfg.softcap_final, vocab=cfg.vocab)
        return logits, {"k": nk, "v": nv, "memory": mem_buf}

    def reset_slot(self, cache, i: int):
        """Zero slot ``i``'s decoder K/V rows and encoder memory.  NOTE:
        ServeEngine has no source-encoding path (requests carry tokens
        only), so serving an encdec model through it cross-attends a zero
        memory; callers must run ``prefill`` with ``src_embeds`` themselves
        before decode makes sense."""
        return {"k": cache["k"].at[:, i].set(0),
                "v": cache["v"].at[:, i].set(0),
                "memory": cache["memory"].at[i].set(0)}

    def slot_state(self, cache, i: int):
        return {"k": cache["k"][:, i], "v": cache["v"][:, i],
                "memory": cache["memory"][i]}

    def write_slot(self, cache, i: int, state):
        return {"k": cache["k"].at[:, i].set(state["k"]),
                "v": cache["v"].at[:, i].set(state["v"]),
                "memory": cache["memory"].at[i].set(state["memory"])}

    def decode_step(self, params, tokens, ctx: Ctx, cache, cache_len):
        cfg = self.cfg
        positions = base.decode_positions(cache_len, tokens.shape[0])
        x, (nk, nv) = self._decoder(
            params, tokens[:, None], cache["memory"].astype(jnp.bfloat16),
            ctx, kv_cache=(cache["k"], cache["v"]), cache_len=cache_len,
            positions=positions)
        logits = base.lm_logits(x[:, 0], params["embed"], cfg.softcap_final, vocab=cfg.vocab)
        return logits, {"k": nk, "v": nv, "memory": cache["memory"]}
