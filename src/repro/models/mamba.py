"""Mamba-1 (falcon-mamba) and Mamba-2/SSD + shared-attention hybrid (zamba2).

MixFP4 applies to the projection GEMMs (in/out/x/dt projections — see
DESIGN.md §Arch-applicability); the SSM recurrences themselves are not GEMMs
and stay in high precision, mirroring the paper's treatment of attention and
nonlinearities.  At serve time the same boundary carries the W4A4 mode:
``Ctx(act_quant="mixfp4")`` makes every packed-weight ``qlinear`` (and the
hybrid's shared-attention projections) quantize its activation rows and run
the W4A4 kernel — the recurrent state stays f32 throughout
(docs/serving.md).

Selective scans are *chunked*: the (B, chunk, d_inner, N) state tensor is the
only materialisation (Mamba-1), or the SSD chunked form with its (B, c, c, H)
intra-chunk decay matrix (Mamba-2) — both bounded by cfg.ssm_chunk and
sharded over the model axis on channels/heads.  Decode is the same math at
chunk length 1 with O(1) carried state — which is what makes the SSM archs
the `long_500k` candidates.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.core import qtensor
from repro.models import base
from repro.models.base import ArchConfig, Ctx, Param, qlinear, rms_norm, shard, unzip_params


def _app_take(c, aidx):
    """Slice attention-app ``aidx`` off a stacked KV carry.  The carry is
    either a dense (na, B, S, H, dh) array or a packed QTensor whose
    children lead with the app axis — scan carries can't be sliced as xs
    because only every ``attn_period``-th layer applies the shared block."""
    take = lambda a: jax.lax.dynamic_index_in_dim(a, aidx, 0, keepdims=False)
    if isinstance(c, qtensor.QTensor):
        return qtensor.QTensor(take(c.payload), take(c.scales),
                               take(c.scale32), c.method, c.layout,
                               c.shape, c.dtype)
    return take(c)


def _app_put(c, new, aidx):
    """Write app ``aidx``'s updated KV back into the stacked carry."""
    put = lambda a, n: jax.lax.dynamic_update_index_in_dim(
        a, n.astype(a.dtype), aidx, 0)
    if isinstance(c, qtensor.QTensor):
        # scale32 is pinned (base.KV_SCALE32) and shared across apps
        return qtensor.QTensor(put(c.payload, new.payload),
                               put(c.scales, new.scales), c.scale32,
                               c.method, c.layout, c.shape, c.dtype)
    return put(c, new)


# ---------------------------------------------------------------------------
# shared scan helpers
# ---------------------------------------------------------------------------
def _assoc_combine(c1, c2):
    a1, b1 = c1
    a2, b2 = c2
    return a2 * a1, a2 * b1 + b2


def selective_scan_m1(x, dt, A, Bm, Cm, h0, chunk: int):
    """Mamba-1 selective scan, chunked.

    x, dt: (B,S,Di); A: (Di,N); Bm, Cm: (B,S,N); h0: (B,Di,N) f32.
    Returns (y (B,S,Di), hT)."""
    b, s, di = x.shape
    n = A.shape[-1]
    chunk = min(chunk, s)
    assert s % chunk == 0
    nc = s // chunk

    def step(h, inp):
        xc, dtc, bc, cc = inp                    # (B,c,Di) / (B,c,N)
        a = jnp.exp(dtc[..., None] * A)          # (B,c,Di,N)
        bx = (dtc * xc)[..., None] * bc[:, :, None, :]
        aa, bb = jax.lax.associative_scan(_assoc_combine, (a, bx), axis=1)
        h_all = aa * h[:, None] + bb             # (B,c,Di,N)
        y = jnp.einsum("bcdn,bcn->bcd", h_all, cc)
        return h_all[:, -1], y

    xs = jax.tree.map(
        lambda t: t.reshape(b, nc, chunk, *t.shape[2:]).swapaxes(0, 1),
        (x.astype(jnp.float32), dt.astype(jnp.float32),
         Bm.astype(jnp.float32), Cm.astype(jnp.float32)))
    step_fn = jax.checkpoint(step) if nc > 1 else step
    hT, ys = jax.lax.scan(step_fn, h0, xs)
    y = ys.swapaxes(0, 1).reshape(b, s, di)
    return y, hT


def ssd_scan_m2(x, dt, A, Bm, Cm, h0, chunk: int):
    """Mamba-2 SSD chunked scan.

    x: (B,S,H,P); dt: (B,S,H); A: (H,) (negative); Bm, Cm: (B,S,N);
    h0: (B,H,P,N).  Returns (y (B,S,H,P), hT)."""
    b, s, h, p = x.shape
    n = Bm.shape[-1]
    chunk = min(chunk, s)
    assert s % chunk == 0
    nc = s // chunk

    def step(hst, inp):
        xc, dtc, bc, cc = inp                    # (B,c,H,P) (B,c,H) (B,c,N)
        la = dtc * A                             # log decay per step (B,c,H)
        lcum = jnp.cumsum(la, axis=1)            # l_t
        # intra-chunk: y[t] += sum_{s<=t} exp(l_t - l_s) dt_s (C_t.B_s) x_s
        decay = jnp.exp(lcum[:, :, None, :] - lcum[:, None, :, :])  # (B,c,c,H)
        tri = jnp.tril(jnp.ones((chunk, chunk), bool))
        decay = jnp.where(tri[None, :, :, None], decay, 0.0)
        scores = jnp.einsum("btn,bsn->bts", cc, bc)          # (B,c,c)
        g = scores[..., None] * decay                        # (B,c,c,H)
        y_in = jnp.einsum("btsh,bsh,bshp->bthp", g, dtc, xc)
        # inter-chunk: y[t] += exp(l_t) C_t . h0
        y_x = jnp.einsum("btn,bhpn->bthp", cc, hst) * jnp.exp(lcum)[..., None]
        # state update: h' = exp(l_last) h0 + sum_s exp(l_last-l_s) dt_s x_s B_s
        w = jnp.exp(lcum[:, -1:, :] - lcum) * dtc            # (B,c,H)
        h_new = (hst * jnp.exp(lcum[:, -1])[:, :, None, None]
                 + jnp.einsum("bsh,bshp,bsn->bhpn", w, xc, bc))
        return h_new, y_in + y_x

    xs = jax.tree.map(
        lambda t: t.reshape(b, nc, chunk, *t.shape[2:]).swapaxes(0, 1),
        (x.astype(jnp.float32), dt.astype(jnp.float32),
         Bm.astype(jnp.float32), Cm.astype(jnp.float32)))
    step_fn = jax.checkpoint(step) if nc > 1 else step
    hT, ys = jax.lax.scan(step_fn, h0, xs)
    y = ys.swapaxes(0, 1).reshape(b, s, h, p)
    return y, hT


def causal_conv(x, w, bias, state=None):
    """Depthwise causal conv along S.  x: (B,S,C); w: (K,C); state: (B,K-1,C)
    carries the last K-1 inputs for decode.  Returns (y, new_state)."""
    k = w.shape[0]
    if state is None:
        pad = jnp.zeros((x.shape[0], k - 1, x.shape[2]), x.dtype)
    else:
        pad = state.astype(x.dtype)
    xp = jnp.concatenate([pad, x], axis=1)
    y = sum(xp[:, i:i + x.shape[1]] * w[i] for i in range(k))
    new_state = xp[:, -(k - 1):] if k > 1 else None
    return y + bias, new_state


# ---------------------------------------------------------------------------
# model
# ---------------------------------------------------------------------------
class MambaLM:
    """families: 'ssm' (mamba1 stack) and 'hybrid' (mamba2 + shared attn)."""

    def __init__(self, cfg: ArchConfig):
        self.cfg = cfg
        self.d_inner = cfg.ssm_expand * cfg.d_model
        self.dt_rank = max(cfg.d_model // 16, 1)
        if cfg.ssm_version == 2:
            self.n_ssm_heads = self.d_inner // cfg.ssm_head_dim

    # -- layer params ---------------------------------------------------
    def _layer_init(self, key):
        cfg = self.cfg
        di, n = self.d_inner, cfg.ssm_state
        ks = jax.random.split(key, 6)
        s = 1.0 / math.sqrt(cfg.d_model)
        p = {"ln": base.norm_init(cfg.d_model)}
        if cfg.ssm_version == 1:
            r = self.dt_rank
            p.update({
                "in_proj": base.linear_init(ks[0], cfg.d_model, 2 * di),
                "conv_w": Param(jax.random.normal(ks[1], (cfg.ssm_conv, di),
                                                  jnp.float32) * 0.2,
                                P(None, "model")),
                "conv_b": Param(jnp.zeros((di,)), P("model")),
                "x_proj": base.linear_init(ks[2], di, r + 2 * n,
                                           spec=P("model", None)),
                "dt_proj": base.linear_init(ks[3], r, di,
                                            spec=P(None, "model")),
                "dt_bias": Param(jnp.log(jnp.expm1(
                    jnp.clip(jax.random.uniform(ks[4], (di,)) * 0.1,
                             1e-3, None))), P("model")),
                "A_log": Param(jnp.log(jnp.tile(
                    jnp.arange(1, n + 1, dtype=jnp.float32), (di, 1))),
                    P("model", None)),
                "Dskip": Param(jnp.ones((di,)), P("model")),
                "out_proj": base.linear_init(ks[5], di, cfg.d_model,
                                             spec=P("model", None)),
            })
        else:
            h = self.n_ssm_heads
            d_in = 2 * di + 2 * n + h    # [z, x, B, C, dt]
            p.update({
                "in_proj": base.linear_init(ks[0], cfg.d_model, d_in),
                "conv_w": Param(jax.random.normal(
                    ks[1], (cfg.ssm_conv, di + 2 * n), jnp.float32) * 0.2,
                    P(None, "model")),
                "conv_b": Param(jnp.zeros((di + 2 * n,)), P("model")),
                "dt_bias": Param(jnp.full((h,), -2.0), P("model")),
                "A_log": Param(jnp.zeros((h,)), P("model")),
                "Dskip": Param(jnp.ones((h,)), P("model")),
                "ssm_norm": base.norm_init(di),
                "out_proj": base.linear_init(ks[5], di, cfg.d_model,
                                             spec=P("model", None)),
            })
        return p

    def _shared_attn_init(self, key):
        """Zamba2-style shared transformer block on concat(x, x_embed)."""
        cfg = self.cfg
        d2 = 2 * cfg.d_model
        ks = jax.random.split(key, 3)
        acfg = cfg.replace(qk_norm=False)
        return {
            "ln_attn": base.norm_init(d2),
            "attn": base.attn_init(ks[0], acfg, d_in=d2),
            "ln_mlp": base.norm_init(d2),
            "mlp": base.mlp_init(ks[1], cfg, d_ff=cfg.d_ff, d_in=d2),
        }

    def init(self, key):
        cfg = self.cfg
        ke, kl, ka = jax.random.split(key, 3)
        proto = self._layer_init(kl)
        _, lsp = unzip_params(proto)
        layer_specs = jax.tree.map(lambda sp: P(None, *sp), lsp)
        lkeys = jax.random.split(kl, cfg.n_layers)
        layer_values = jax.vmap(
            lambda k: unzip_params(self._layer_init(k))[0])(lkeys)
        values = {
            "embed": jax.random.normal(ke, (base.padded_vocab(cfg.vocab), cfg.d_model),
                                       jnp.float32) * 0.02,
            "layers": layer_values,
            "ln_f": jnp.ones((cfg.d_model,), jnp.float32),
        }
        specs = {"embed": P("model", None), "layers": layer_specs,
                 "ln_f": P(None)}
        if cfg.attn_period:
            sa_v, sa_s = unzip_params(self._shared_attn_init(ka))
            values["shared_attn"] = sa_v
            specs["shared_attn"] = sa_s
        return values, specs

    # -- SSM block forward ------------------------------------------------
    def _block(self, lp, x, ctx: Ctx, h0, conv0):
        """x: (B,S,D).  Returns (out, hT, convT)."""
        cfg = self.cfg
        di, n = self.d_inner, cfg.ssm_state
        h = rms_norm(x, lp["ln"], cfg.norm_eps)
        if cfg.ssm_version == 1:
            xz = qlinear(h, lp["in_proj"], ctx, 0)
            xz = shard(xz, "data", None, "model")
            xs, z = jnp.split(xz, 2, axis=-1)
            xs, convT = causal_conv(xs, lp["conv_w"], lp["conv_b"], conv0)
            xs = jax.nn.silu(xs)
            proj = qlinear(xs, lp["x_proj"], ctx, 1)
            dt_raw, bm, cm = jnp.split(
                proj, [self.dt_rank, self.dt_rank + n], axis=-1)
            dt = jax.nn.softplus(
                qlinear(dt_raw, lp["dt_proj"], ctx, 2) + lp["dt_bias"])
            A = -jnp.exp(lp["A_log"].astype(jnp.float32))
            y, hT = selective_scan_m1(xs, dt, A, bm, cm, h0, cfg.ssm_chunk)
            y = (y + xs.astype(jnp.float32) * lp["Dskip"]).astype(x.dtype)
            y = y * jax.nn.silu(z)
            out = qlinear(y, lp["out_proj"], ctx, 3)
        else:
            nh = self.n_ssm_heads
            zxbcdt = qlinear(h, lp["in_proj"], ctx, 0)
            zxbcdt = shard(zxbcdt, "data", None, "model")
            z, xbc, dt_raw = jnp.split(zxbcdt, [di, 2 * di + 2 * n], axis=-1)
            xbc, convT = causal_conv(xbc, lp["conv_w"], lp["conv_b"], conv0)
            xbc = jax.nn.silu(xbc)
            xs, bm, cm = jnp.split(xbc, [di, di + n], axis=-1)
            dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + lp["dt_bias"])
            A = -jnp.exp(lp["A_log"].astype(jnp.float32))
            xh = xs.reshape(*xs.shape[:2], nh, cfg.ssm_head_dim)
            y, hT = ssd_scan_m2(xh, dt, A, bm, cm, h0, cfg.ssm_chunk)
            y = y + xh.astype(jnp.float32) * lp["Dskip"][:, None]
            y = y.reshape(*xs.shape).astype(x.dtype)
            y = rms_norm(y * jax.nn.silu(z), lp["ssm_norm"], cfg.norm_eps)
            out = qlinear(y, lp["out_proj"], ctx, 3)
        return x + out, hT, convT

    def _shared_block(self, sp, x, x0, ctx: Ctx, *, positions,
                      kv_cache=None, cache_len=None, block_tables=None):
        """Zamba2 shared attn+MLP on concat(x, x_embed); output added to x."""
        cfg = self.cfg
        d2 = 2 * cfg.d_model
        acfg = cfg.replace(qk_norm=False)
        h2 = jnp.concatenate([x, x0], axis=-1)
        hn = rms_norm(h2, sp["ln_attn"], cfg.norm_eps)
        attn_out, new_cache = base.attn_apply(
            sp["attn"], hn, ctx.fold(7), acfg, positions=positions,
            window=0, kv_cache=kv_cache, cache_len=cache_len,
            block_tables=block_tables)
        x = x + attn_out
        h2 = jnp.concatenate([x, x0], axis=-1)
        hn = rms_norm(h2, sp["ln_mlp"], cfg.norm_eps)
        x = x + base.mlp(sp["mlp"], hn, ctx.fold(8), cfg)
        return x, new_cache

    # -- layer-stack drivers ----------------------------------------------
    def _attn_flags(self):
        cfg = self.cfg
        flags = np.zeros((cfg.n_layers,), bool)
        if cfg.attn_period:
            flags[0::cfg.attn_period] = True
        return flags, np.maximum(np.cumsum(flags) - 1, 0).astype(np.int32)

    def n_attn_apps(self) -> int:
        return int(self._attn_flags()[0].sum())

    def _init_states(self, batch: int):
        cfg = self.cfg
        di, n = self.d_inner, cfg.ssm_state
        if cfg.ssm_version == 1:
            h = jnp.zeros((cfg.n_layers, batch, di, n), jnp.float32)
        else:
            h = jnp.zeros((cfg.n_layers, batch, self.n_ssm_heads,
                           cfg.ssm_head_dim, n), jnp.float32)
        cw = di if cfg.ssm_version == 1 else di + 2 * n
        conv = jnp.zeros((cfg.n_layers, batch, cfg.ssm_conv - 1, cw),
                         jnp.bfloat16)
        return h, conv

    def _run_layers(self, params, x, ctx: Ctx, h0s, conv0s, *, positions,
                    kv_cache=None, cache_len=None, block_tables=None):
        cfg = self.cfg
        flags, app_idx = self._attn_flags()
        lkeys = jax.random.split(ctx.key, cfg.n_layers)
        x0 = x
        sp = params.get("shared_attn")
        use_cache = kv_cache is not None

        def body(carry, xs_in):
            x, kc, vc = carry
            lp, lk, h0, c0, flag, aidx = xs_in
            lctx = ctx.with_key(lk)
            x, hT, convT = self._block(lp, x, lctx, h0, c0)
            x = shard(x, "data", None, "model")  # D-sharded residual carry

            if sp is not None:
                def with_attn(x):
                    if use_cache:
                        kci = _app_take(kc, aidx)
                        vci = _app_take(vc, aidx)
                        xo, ncache = self._shared_block(
                            sp, x, x0, lctx, positions=positions,
                            kv_cache=(kci, vci), cache_len=cache_len,
                            block_tables=block_tables)
                        nkc = _app_put(kc, ncache[0], aidx)
                        nvc = _app_put(vc, ncache[1], aidx)
                        return xo, nkc, nvc
                    xo, _ = self._shared_block(sp, x, x0, lctx,
                                               positions=positions)
                    return xo, kc, vc

                x, kc, vc = jax.lax.cond(
                    flag, with_attn, lambda x: (x, kc, vc), x)
            return (x, kc, vc), (hT, convT)

        body_fn = jax.checkpoint(body) if cfg.n_layers > 1 else body
        kc0 = kv_cache[0] if use_cache else jnp.zeros((1,), jnp.bfloat16)
        vc0 = kv_cache[1] if use_cache else jnp.zeros((1,), jnp.bfloat16)
        (x, kc, vc), (hTs, convTs) = jax.lax.scan(
            body_fn, (x, kc0, vc0),
            (params["layers"], lkeys, h0s, conv0s,
             jnp.asarray(flags), jnp.asarray(app_idx)))
        return x, hTs, convTs, (kc, vc)

    # -- public API ---------------------------------------------------------
    def hidden(self, params, batch, ctx: Ctx):
        cfg = self.cfg
        x = params["embed"][batch["tokens"]].astype(jnp.bfloat16)
        x = shard(x, "data", None, "model")
        b, s = batch["tokens"].shape
        h0s, conv0s = self._init_states(b)
        positions = jnp.arange(s)[None, :]
        x, _, _, _ = self._run_layers(params, x, ctx, h0s, conv0s,
                                      positions=positions)
        return rms_norm(x, params["ln_f"], cfg.norm_eps), 0.0

    def forward(self, params, batch, ctx: Ctx):
        x, aux = self.hidden(params, batch, ctx)
        logits = base.lm_logits(x, params["embed"], self.cfg.softcap_final)
        return base.shard(logits, "data", None, "model"), aux

    def loss(self, params, batch, ctx: Ctx):
        x, aux = self.hidden(params, batch, ctx)
        return base.fused_lm_loss(x, params["embed"], batch["labels"],
                                  self.cfg.softcap_final,
                                  self.cfg.vocab) + aux

    def init_cache(self, batch_size: int, max_len: int, dtype=jnp.bfloat16,
                   kv_quant: str | None = None,
                   pages: tuple[int, int] | None = None):
        """Recurrent h/conv state plus (hybrid) the shared-attention KV
        cache.  ``kv_quant="mixfp4"`` packs the KV exactly like the
        transformer families — QTensor children with a leading *app* axis
        ((na, B, S, H, dh//2) payload + scale bytes) that ``_app_take``
        slices per shared-block application — and ``pages=(num_pages,
        page_len)`` swaps the per-slot stripes for pool page slabs
        ((na, P, page_len, H, ...)) plus a ``"pages"`` block table, so the
        hybrid rides the same serving.kvpool as the transformers.  The
        h/conv state stays dense f32/bf16 per slot either way: SSM state
        is not attention history and cannot be paged or prefix-shared."""
        cfg = self.cfg
        h, conv = self._init_states(batch_size)
        cache = {"h": h, "conv": conv}
        if pages is not None and not cfg.attn_period:
            raise ValueError("paged KV (pages=) needs a hybrid arch with "
                             "shared attention (cfg.attn_period)")
        if not cfg.attn_period:
            return cache
        na = self.n_attn_apps()
        if kv_quant is None or kv_quant == "bf16":
            if pages is not None:
                raise ValueError("paged KV (pages=) requires "
                                 f"kv_quant='mixfp4', got {kv_quant!r}")
            shape = (na, batch_size, max_len, cfg.n_heads, cfg.dh)
            cache["k"] = jnp.zeros(shape, dtype)
            cache["v"] = jnp.zeros(shape, dtype)
            return cache
        if kv_quant != "mixfp4":
            raise ValueError(f"unknown kv_quant {kv_quant!r} "
                             "(expected None, 'bf16' or 'mixfp4')")
        if cfg.dh % 16:
            raise ValueError(
                f"kv_quant='mixfp4' needs head_dim % 16 == 0, got {cfg.dh}")
        if pages is not None:
            num_pages, page_len = pages
            if page_len % 16 or max_len % page_len:
                raise ValueError(
                    f"page_len={page_len} must be a multiple of 16 and "
                    f"divide max_len={max_len}")
            rows = (num_pages, page_len, cfg.n_heads)
        else:
            rows = (batch_size, max_len, cfg.n_heads)

        def packed():
            return qtensor.QTensor(
                jnp.zeros((na, *rows, cfg.dh // 2), jnp.uint8),
                jnp.zeros((na, *rows, cfg.dh // 16), jnp.uint8),
                jnp.full((na,), base.KV_SCALE32, jnp.float32),
                method="mixfp4", layout=qtensor.BlockLayout1D(-1, 16),
                shape=(*rows, cfg.dh), dtype="float32")

        cache["k"] = packed()
        cache["v"] = packed()
        if pages is not None:
            cache["pages"] = jnp.zeros(
                (batch_size, max_len // page_len), jnp.int32)
        return cache

    def cache_specs(self):
        cfg = self.cfg
        specs = {
            "h": P(None, "data", "model", None) if cfg.ssm_version == 1
            else P(None, "data", "model", None, None),
            "conv": P(None, "data", None, "model"),
        }
        if cfg.attn_period:
            # zamba2 shared-attn cache shards over HEADS (32 % 16 == 0):
            # a 1-token dynamic-update on a seq-sharded dim would force
            # GSPMD to gather the 500k cache
            specs["k"] = P(None, "data", None, "model", None)
            specs["v"] = P(None, "data", None, "model", None)
        return specs

    def prefill(self, params, batch, ctx: Ctx, cache, block_tables=None):
        cfg = self.cfg
        x = params["embed"][batch["tokens"]].astype(jnp.bfloat16)
        x = shard(x, "data", None, None)
        b, s = batch["tokens"].shape
        positions = jnp.arange(s)[None, :]
        kv = (cache["k"], cache["v"]) if cfg.attn_period else None
        x, hTs, convTs, kvT = self._run_layers(
            params, x, ctx, cache["h"], cache["conv"],
            positions=positions, kv_cache=kv, cache_len=0 if kv else None,
            block_tables=block_tables)
        x = rms_norm(x, params["ln_f"], cfg.norm_eps)
        logits = base.lm_logits(x[:, -1], params["embed"], cfg.softcap_final, vocab=cfg.vocab)
        new_cache = {"h": hTs, "conv": convTs}
        if cfg.attn_period:
            new_cache["k"], new_cache["v"] = kvT
        return logits, new_cache

    def reset_slot(self, cache, i: int):
        """Zero slot ``i``'s recurrent SSM state, conv window and (hybrid)
        K/V rows — for the SSM a zeroed state IS the fresh-request state.
        Paged caches zero only the slot's h/conv rows and block-table row
        (all entries -> trash page 0); pool pages belong to the pool."""
        if isinstance(cache, dict) and "pages" in cache:
            return dict(cache,
                        h=cache["h"].at[:, i].set(0),
                        conv=cache["conv"].at[:, i].set(0),
                        pages=cache["pages"].at[i].set(0))
        return base._map_slot_arrays(lambda a: a.at[:, i].set(0), cache)

    def slot_state(self, cache, i: int):
        """Snapshot slot ``i``'s rows (fixed-slot caches only).  Unlike KV
        rows, the recurrent h/conv state advances for EVERY batch row each
        decode step, so the engine must restore other active slots after a
        prefill — a dummy step is irreversible for an SSM."""
        assert "pages" not in cache, "paged caches have no per-slot KV rows"
        return base._map_slot_arrays(lambda a: a[:, i], cache)

    def write_slot(self, cache, i: int, state):
        assert "pages" not in cache, "paged caches have no per-slot KV rows"
        return base._map_slot_arrays(
            lambda a, s: a.at[:, i].set(s.astype(a.dtype)), cache, state)

    def prefill_slot(self, params, tokens, ctx: Ctx, cache, slot,
                     true_len=None, start_pos=None):
        """Batched single-slot prefill: slice the cache to the slot's batch
        row, run the whole prompt through the chunked-scan prefill in ONE
        call, and scatter the row back.  Only slot ``slot``'s recurrent
        state advances — the dummy-step corruption that forced the engine's
        snapshot/restore dance around admissions cannot happen.  Returns
        (last-position logits (1, V), updated full cache)."""
        if true_len is not None:
            raise ValueError(
                "prompt-length bucketing (true_len) is transformer-only: "
                "the SSM recurrent state advances for every padded suffix "
                "token, so a bucketed prompt would corrupt the slot state")
        if start_pos is not None:
            raise ValueError(
                "chunked/suffix prefill (start_pos) is transformer-only: "
                "resuming an SSM prompt mid-way needs the recurrent state "
                "checkpointed at the chunk boundary, which this cache does "
                "not carry (ROADMAP carry-over) — prefill hybrids from "
                "position 0 in one call")
        cfg = self.cfg
        p_len = tokens.shape[1]
        # chunked scans/attention need p_len % chunk == 0 once p_len exceeds
        # the chunk; awkward prompt lengths fall back to one unchunked block
        # (p_len is a static shape — each length compiles its own prefill)
        cfg2 = cfg
        if p_len > cfg.ssm_chunk and p_len % cfg.ssm_chunk:
            cfg2 = cfg2.replace(ssm_chunk=p_len)
        if cfg.attn_period and p_len > cfg.attn_chunk \
                and p_len % cfg.attn_chunk:
            cfg2 = cfg2.replace(attn_chunk=p_len)
        model = self if cfg2 is cfg else MambaLM(cfg2)
        if isinstance(cache, dict) and "pages" in cache:
            # paged: slice only the slot's recurrent state; the KV pool
            # stays whole and the slot's block-table row routes the writes.
            # No start_pos/prefix sharing for hybrids: the SSM state needs
            # the full prompt run regardless, so engines admit hybrids with
            # prefix caching disabled and always prefill from position 0.
            recur = {"h": cache["h"], "conv": cache["conv"]}
            small = base.slot_take(recur, slot)
            small["k"], small["v"] = cache["k"], cache["v"]
            btrow = jax.lax.dynamic_slice_in_dim(cache["pages"], slot, 1,
                                                 axis=0)
            logits, new_small = model.prefill(
                params, {"tokens": tokens}, ctx, small, block_tables=btrow)
            out = base.slot_put(
                recur, {"h": new_small["h"], "conv": new_small["conv"]},
                slot)
            return logits, {"h": out["h"], "conv": out["conv"],
                            "k": new_small["k"], "v": new_small["v"],
                            "pages": cache["pages"]}
        small = base.slot_take(cache, slot)
        logits, new_small = model.prefill(
            params, {"tokens": tokens}, ctx, small)
        return logits, base.slot_put(cache, new_small, slot)

    def decode_step(self, params, tokens, ctx: Ctx, cache, cache_len):
        cfg = self.cfg
        x = params["embed"][tokens[:, None]].astype(jnp.bfloat16)
        positions = base.decode_positions(cache_len, x.shape[0])
        kv = (cache["k"], cache["v"]) if cfg.attn_period else None
        bt = cache.get("pages") if isinstance(cache, dict) else None
        x, hTs, convTs, kvT = self._run_layers(
            params, x, ctx, cache["h"], cache["conv"],
            positions=positions, kv_cache=kv,
            cache_len=cache_len if kv else None, block_tables=bt)
        x = rms_norm(x, params["ln_f"], cfg.norm_eps)
        logits = base.lm_logits(x[:, 0], params["embed"], cfg.softcap_final, vocab=cfg.vocab)
        new_cache = {"h": hTs, "conv": convTs}
        if cfg.attn_period:
            new_cache["k"], new_cache["v"] = kvT
        if bt is not None:
            new_cache["pages"] = cache["pages"]
        return logits, new_cache
