from repro.models.base import ArchConfig, build_model
