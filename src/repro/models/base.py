"""Model-zoo foundation: configs, parameter/spec pytrees, shared layers.

Parameters are plain nested dicts whose leaves are ``Param(value, spec)``
pairs built at init; ``unzip_params`` splits them into a value tree (what the
optimizer/train step carry) and a PartitionSpec tree (what pjit shards).  The
single source of truth for sharding is therefore the init code itself.

Sharding convention on the production mesh (see launch/mesh.py):
  "data"  — batch / tokens (+ "pod" prepended for multi-pod via spec rewrite)
  "model" — TP: attention heads, FFN hidden, vocab; EP: experts

GSPMD pads non-divisible dims (e.g. phi3's 40 heads on a 16-way model axis);
we accept activation padding but never let it touch the large persistent
buffers (KV caches shard over sequence instead — see serving/).
"""
from __future__ import annotations

import dataclasses
import math
from dataclasses import dataclass, field
from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.core import hadamard, qtensor
from repro.core.qgemm import QuantConfig, qgemm

__all__ = [
    "ArchConfig",
    "Param",
    "unzip_params",
    "param_count",
    "PROJECTION_KEYS",
    "is_packable_projection",
    "pack_projections",
    "decode_positions",
    "rms_norm",
    "apply_rope",
    "qlinear",
    "linear_init",
    "embed_init",
    "attention",
    "mlp",
    "mlp_init",
    "attn_init",
    "build_model",
    "shard",
    "KV_SCALE32",
    "quantize_kv_rows",
    "slot_take",
    "slot_put",
]


# ---------------------------------------------------------------------------
# Config
# ---------------------------------------------------------------------------
@dataclass(frozen=True)
class ArchConfig:
    name: str = "model"
    family: str = "dense"        # dense|moe|ssm|hybrid|encdec|vlm
    n_layers: int = 4
    d_model: int = 256
    n_heads: int = 4
    n_kv_heads: int = 4
    d_ff: int = 1024
    vocab: int = 512
    head_dim: int = 0            # 0 -> d_model // n_heads
    mlp_type: str = "swiglu"     # swiglu|gelu|geglu
    rope_theta: float = 10_000.0
    qk_norm: bool = False
    softcap_attn: float = 0.0
    softcap_final: float = 0.0
    window: int = 0              # sliding-window attention (0 = full)
    local_global_period: int = 0 # gemma2: local except every p-th layer global
    attn_chunk: int = 1024       # query-chunked attention block
    # --- MoE ---
    n_experts: int = 0
    top_k: int = 0
    d_ff_expert: int = 0
    shared_expert_ff: int = 0
    ep_mode: str = "expert"      # 'expert' (EP over model) | 'ffn' (TP over d_ff)
    capacity_factor: float = 1.25
    router_aux_coef: float = 0.01
    # --- SSM ---
    ssm_state: int = 0
    ssm_conv: int = 4
    ssm_expand: int = 2
    ssm_version: int = 1         # 1 = Mamba-1, 2 = Mamba-2 (SSD)
    ssm_head_dim: int = 64       # Mamba-2 P
    ssm_chunk: int = 128
    attn_period: int = 0         # hybrid (zamba2): shared attn every k layers
    # --- encoder-decoder ---
    n_dec_layers: int = 0        # >0 => enc-dec; n_layers = encoder depth
    # --- modality stubs ---
    n_prefix_embeds: int = 0     # VLM patches / audio frames prepended
    frontend: str = ""           # 'vision'|'audio'|''
    # --- numerics ---
    quant: QuantConfig = field(default_factory=lambda: QuantConfig(method="mixfp4"))
    norm_eps: float = 1e-5
    emb_scale: bool = False      # gemma-style sqrt(d) embedding scaling

    @property
    def dh(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    def replace(self, **kw) -> "ArchConfig":
        return dataclasses.replace(self, **kw)


# ---------------------------------------------------------------------------
# Param/spec machinery
# ---------------------------------------------------------------------------
class Param(NamedTuple):
    value: jax.Array
    spec: Any  # PartitionSpec


def _is_param(x) -> bool:
    return isinstance(x, Param)


def unzip_params(tree):
    """Param tree -> (value tree, spec tree)."""
    values = jax.tree.map(lambda p: p.value, tree, is_leaf=_is_param)
    specs = jax.tree.map(lambda p: p.spec, tree, is_leaf=_is_param)
    return values, specs


def param_count(values) -> int:
    return sum(int(np.prod(v.shape)) for v in jax.tree.leaves(values))


def _active_mesh():
    """The mesh from the enclosing `with mesh:` context, or None."""
    try:  # newer JAX
        from jax._src import mesh as mesh_lib
        m = mesh_lib.thread_resources.env.physical_mesh
    except Exception:
        try:  # deprecated alias
            m = jax.interpreters.pxla.thread_resources.env.physical_mesh
        except Exception:
            return None
    try:
        return m if (m.axis_names and not m.empty) else None
    except Exception:
        return None


# Global sharding regime.
#  'fsdp' (train shapes): the logical 'data' axis spans data x model (x pod)
#   — batch shards over every chip, weights stay model-sharded in HBM and
#   are gathered per layer (ZeRO-3 pattern); 'model' constraints on
#   activations are dropped (the axis is busy with batch).
#  'sp' (prefill shapes): batch over data, SEQUENCE over model — projections
#   are token-local (no row-parallel psums of (B, 32k, D) activations);
#   attention gathers the (small, GQA) K/V per layer; weights model-sharded
#   with FSDP-style gathers.
#  default 'tp' (decode): 'data' = data (x pod), 'model' = TP.
_STATE = {"fsdp": False, "sp": False}


def set_fsdp(on: bool):
    _STATE["fsdp"] = bool(on)


def set_sp(on: bool):
    _STATE["sp"] = bool(on)


def batch_axes(mesh=None) -> tuple:
    m = mesh or _active_mesh()
    names = m.axis_names if m is not None else ("data",)
    ax = (("pod",) if "pod" in names else ()) + ("data",)
    if _STATE["fsdp"] and "model" in names:
        ax = ax + ("model",)
    return ax


def shard(x: jax.Array, *spec) -> jax.Array:
    """with_sharding_constraint against the ambient mesh; no-op without one.

    The logical 'data' axis resolves per the active regime (see _STATE);
    'model' activation constraints are dropped under FSDP."""
    m = _active_mesh()
    if m is None:
        return x
    names = m.axis_names
    bax = batch_axes(m)
    if _STATE["sp"]:
        # sequence-parallel serving: (B, S, ...) -> batch over data,
        # sequence over model; drop all other activation constraints
        if len(spec) >= 2 and spec[0] == "data":
            parts = [bax if len(bax) > 1 else "data", "model"] + \
                [None] * (len(spec) - 2)
            return jax.lax.with_sharding_constraint(x, P(*parts))
        return x
    parts = []
    for p in spec:
        if p == "data":
            parts.append(bax if len(bax) > 1 else "data")
        elif p == "model" and _STATE["fsdp"]:
            parts.append(None)
        elif p is None or isinstance(p, tuple) or p in names:
            parts.append(p)
        else:
            return x  # unknown axis for this mesh: skip the constraint
    return jax.lax.with_sharding_constraint(x, P(*parts))


def linear_init(key, d_in: int, d_out: int, spec=P(None, "model"),
                scale: float | None = None) -> Param:
    s = scale if scale is not None else 1.0 / math.sqrt(d_in)
    w = jax.random.normal(key, (d_in, d_out), jnp.float32) * s
    return Param(w, spec)


def padded_vocab(vocab: int, multiple: int = 256) -> int:
    """Vocab rows padded for clean TP sharding (standard practice; the
    logical vocab is unchanged — lm_logits slices back)."""
    return ((vocab + multiple - 1) // multiple) * multiple


def embed_init(key, vocab: int, d: int) -> Param:
    w = jax.random.normal(key, (padded_vocab(vocab), d), jnp.float32) * 0.02
    return Param(w, P("model", None))


def norm_init(d: int) -> Param:
    return Param(jnp.ones((d,), jnp.float32), P(None))


# ---------------------------------------------------------------------------
# Elementwise / norm / rope
# ---------------------------------------------------------------------------
def rms_norm(x: jax.Array, g: jax.Array, eps: float = 1e-5) -> jax.Array:
    x32 = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
    return ((x32 * jax.lax.rsqrt(var + eps)) * g).astype(x.dtype)


def _rope_freqs(dh: int, theta: float):
    return 1.0 / (theta ** (jnp.arange(0, dh, 2, dtype=jnp.float32) / dh))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (B, S, H, dh); positions: (B, S) or (S,)."""
    dh = x.shape[-1]
    freqs = _rope_freqs(dh, theta)
    ang = positions[..., None].astype(jnp.float32) * freqs  # (B,S,dh/2)
    cos = jnp.cos(ang)[..., None, :]
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Quantized linear (the paper's GEMM boundary)
# ---------------------------------------------------------------------------
def qlinear(x: jax.Array, w, ctx: "Ctx", tag: int) -> jax.Array:
    """All projection GEMMs route through the quantized boundary.

    Dense ``w`` (training): the Fig. 7 qdq-simulated ``qgemm`` with SR/RHT
    on the backward pass.  Packed ``QTensor`` ``w`` (serving): ``qmm``
    serves straight from the 4.5-bit wire format through the W4A16 kernel —
    no dense copy of the weight ever exists.  A packed weight that carries
    a logical ``pspec`` (``QTensor.with_sharding``) under an active mesh
    dispatches to ``qmm_sharded``: the kernel runs per model-axis shard
    under ``shard_map``, keeping the operands packed AND sharded
    (docs/sharding.md).

    With ``ctx.act_quant == "mixfp4"`` (W4A4 serving, docs/serving.md) the
    dense activation is quantized on the fly in the W4A4 kernel's fused
    prologue (``qmm(x, w, fuse_act_quant=True)`` — ONE Pallas dispatch per
    projection; under a mesh, ``qmm_sharded`` with the fused flag) using
    the same type-in-sign E4M3 block-scale wire encoding as every other
    wire tensor, under the PER-ROW level-2 scale contract: each token
    row's bytes — and therefore its output row — are a pure function of
    that row, independent of batchmates and padding.
    ``"mixfp4-2pass-rowscale"`` is the explicit two-dispatch composition
    the fused path is bitwise-identical to — ``quantize_rows(per_row=True)``
    onto the weight's packed ``Kp`` grid, then the per-row W4A4 kernel —
    kept as the serving-level oracle and for A/B benchmarks.
    ``ctx.act_rht`` layers the grouped random Hadamard transform ahead of
    the quantizer on both spellings (fused in the same VMEM pass;
    ``ops.rht_rows`` for the composition) — the packed weight must carry
    the matching transform (``pack_projections(act_rht=True)``).
    ``"mixfp4-2pass"`` is the legacy PER-TENSOR two-dispatch spelling
    (Alg. 1 line 4 verbatim, batch-coupled), kept as the A/B baseline;
    ``"mixfp4-qdq"`` is its debugging oracle: the SAME per-tensor wire
    bytes are decoded back to dense rows and served W4A16 — what the
    W4A4 kernel computes, minus its fused in-VMEM decode.
    """
    if isinstance(w, qtensor.QTensor):
        m = _active_mesh()
        kernel_w = (isinstance(w.layout, qtensor.BlockLayout2D)
                    and w.payload.ndim == 2)
        sharded = (m is not None and w.pspec is not None and kernel_w
                   and qtensor.kn_partitions(w) != (None, None))
        aq = ctx.act_quant
        if (aq == "mixfp4" and kernel_w
                and not isinstance(x, qtensor.QTensor)):
            lead, k = x.shape[:-1], x.shape[-1]
            x2 = x.reshape(-1, k)
            signs = (hadamard.serve_signs(2 * w.payload.shape[0])
                     if ctx.act_rht else None)
            y = (qtensor.qmm_sharded(x2, w, mesh=m, fuse_act_quant=True,
                                     per_row_act=True, act_rht_signs=signs)
                 if sharded else
                 qtensor.qmm(x2, w, fuse_act_quant=True, per_row_act=True,
                             act_rht_signs=signs))
            return y.reshape(*lead, y.shape[-1]).astype(x.dtype)
        if (aq in ("mixfp4-2pass", "mixfp4-2pass-rowscale", "mixfp4-qdq")
                and kernel_w and not isinstance(x, qtensor.QTensor)):
            from repro.kernels import ops  # deferred: kernels import core
            kp = 2 * w.payload.shape[0]
            lead, k = x.shape[:-1], x.shape[-1]
            per_row = aq == "mixfp4-2pass-rowscale"
            x2 = x.reshape(-1, k)
            if per_row and ctx.act_rht:
                # transform on the packed Kp grid BEFORE quantizing — the
                # same grid/signs the fused prologue and the pack-time
                # weight transform use, so H/D cancel in the dot product
                x2f = x2.astype(jnp.float32)
                if kp != k:
                    x2f = jnp.pad(x2f, ((0, 0), (0, kp - k)))
                x2 = ops.rht_rows(x2f, hadamard.serve_signs(kp))
            qx = qtensor.quantize_rows(x2, pad_to=kp, per_row=per_row)
            if aq != "mixfp4-qdq":
                y = (qtensor.qmm_sharded(qx, w, mesh=m) if sharded
                     else qtensor.qmm(qx, w))
            else:
                # Oracle: decode the SAME wire bytes in the kernel's
                # factored-scale form (Eq. 35) — value x block-scale rows
                # (exact in bf16: <= 7 significand bits), per-tensor scale
                # applied to the f32 output — and serve them W4A16.
                xd = qtensor.QTensor(
                    qx.payload, qx.scales, jnp.ones((), jnp.float32),
                    qx.method, qx.layout, qx.shape, "float32").dequantize()
                y = (qtensor.qmm_sharded(xd, w, mesh=m) if sharded
                     else qtensor.qmm(xd, w)) * qx.scale32
            return y.reshape(*lead, y.shape[-1]).astype(x.dtype)
        if sharded and not isinstance(x, qtensor.QTensor):
            return qtensor.qmm_sharded(x, w, mesh=m).astype(x.dtype)
        return qtensor.qmm(x, w).astype(x.dtype)
    return qgemm(ctx.quant, x, w, jax.random.fold_in(ctx.key, tag))


# Projection-weight leaves consumed through qlinear — exactly the GEMMs the
# paper quantizes (embeddings, norms and the LM head stay high-precision per
# the paper's exclusions).  attn/mlp names from attn_init/mlp_init below;
# in/x/dt/out_proj from the Mamba blocks (models/mamba.py).
PROJECTION_KEYS = frozenset(
    {"wq", "wk", "wv", "wo", "w_up", "w_down", "w_gate",
     "in_proj", "x_proj", "dt_proj", "out_proj"})


def is_packable_projection(key: str, leaf) -> bool:
    """One predicate for "does ServeEngine pack this leaf" — shared with the
    dryrun HBM accounting so report and engine can't drift.  Matches any
    projection-named leaf whose trailing (K, N) matrix fills at least one
    16x16 tile; leading dims (scan layer stacking, MoE expert dims) ride
    along as QTensor batch dimensions."""
    return (key in PROJECTION_KEYS and getattr(leaf, "ndim", 0) >= 2
            and min(leaf.shape[-2:]) >= 16)


def pack_projections(params, method: str = "mixfp4",
                     block: tuple[int, int] = (16, 16),
                     act_rht: bool = False):
    """Replace every projection-weight leaf of a parameter value tree with a
    packed 2-D-tiled :class:`~repro.core.qtensor.QTensor`.

    Leaves with leading batch dims — ``(n_layers, K, N)`` from the
    ``lax.scan`` layout, ``(n_layers, E, K, N)`` for scan-stacked MoE
    experts — are quantized per trailing matrix under ``vmap``; the result is
    one QTensor whose children carry the leading dims, which scan/``lax.map``
    slice transparently.  Returns ``(packed_tree, packed_bytes, dense_bytes)``
    where the byte counts cover the converted leaves (dense at bf16 rates).

    ``act_rht=True`` applies the serve-time grouped random Hadamard
    transform along each projection's K axis BEFORE quantizing (signs from
    ``hadamard.serve_signs`` — the deterministic diagonal ``qlinear``'s
    fused prologue applies to activations, so ``(HDx)·(HDW) = x·W`` up to
    quantization), and records the diagonals in a top-level
    ``"rht_signs"`` entry of the returned tree ``{str(K): (K,) f32}`` so
    checkpoints carry the exact ``D`` alongside the transformed bytes.
    Requires every projection K to be a multiple of the transform group
    (16) — the transform must live on the same padded grid as the packed
    payload.
    """
    spec = qtensor.QuantSpec(method, qtensor.BlockLayout2D(*block))
    stats = {"packed": 0, "dense": 0}
    signs_used: dict[str, jax.Array] = {}

    def convert(w):
        if act_rht:
            k_ax = w.shape[-2]
            if k_ax % 16:
                raise ValueError(
                    f"pack_projections(act_rht=True): projection K={k_ax} "
                    f"must be a multiple of the RHT group (16)")
            signs = hadamard.serve_signs(k_ax)
            signs_used[str(k_ax)] = signs
            w = hadamard.rht(w, signs, axis=-2, group=16)
        lead = w.shape[:-2]
        if lead:
            flat = w.reshape((-1,) + w.shape[-2:])
            qt = jax.vmap(lambda m: qtensor.quantize(m, spec))(flat)
            if len(lead) > 1:
                qt = qtensor.QTensor(
                    qt.payload.reshape(lead + qt.payload.shape[1:]),
                    qt.scales.reshape(lead + qt.scales.shape[1:]),
                    qt.scale32.reshape(lead + qt.scale32.shape[1:]),
                    qt.method, qt.layout, qt.shape, qt.dtype)
        else:
            qt = qtensor.quantize(w, spec)
        stats["packed"] += qt.nbytes
        stats["dense"] += w.size * 2
        return qt

    def walk(node):
        if isinstance(node, dict):
            return {k: (convert(v) if is_packable_projection(k, v)
                        else walk(v))
                    for k, v in node.items()}
        return node

    packed = walk(params)
    if act_rht and isinstance(packed, dict):
        packed = dict(packed)
        packed["rht_signs"] = signs_used
    return packed, stats["packed"], stats["dense"]


def decode_positions(cache_len, b: int) -> jax.Array:
    """(B, 1) absolute positions for a single-token decode step from a
    scalar or per-sequence ``(B,)`` cache length."""
    cl = jnp.asarray(cache_len)
    if cl.ndim:
        cl = cl[:, None]
    return cl + jnp.zeros((b, 1), jnp.int32)


@dataclass(frozen=True)
class Ctx:
    """Per-call context: PRNG key for SR/RHT, quant config, the active
    mesh (None = single-device; MoE then skips its collectives), and the
    serving activation format: ``act_quant="mixfp4"`` makes every
    packed-weight ``qlinear`` run the fused quantize+GEMM W4A4 kernel in
    one dispatch under PER-ROW activation scales
    (``"mixfp4-2pass-rowscale"`` = the explicit
    quantize_rows(per_row=True) -> W4A4 two-dispatch composition it is
    bitwise-identical to; ``"mixfp4-2pass"`` = the legacy per-tensor
    two-dispatch baseline; ``"mixfp4-qdq"`` = its dequantize-then-W4A16
    oracle; anything else = dense bf16 activations, W4A16).
    ``act_rht=True`` (with the per-row spellings) applies the grouped
    random Hadamard transform to activations ahead of the quantizer —
    fused into the same GEMM prologue — against RHT-transformed packed
    weights (``pack_projections(act_rht=True)``)."""
    key: jax.Array
    quant: QuantConfig
    mesh: Any = None
    data_axes: tuple = ("data",)      # ("pod","data") on the multi-pod mesh
    model_axis: str = "model"
    act_quant: str = "bf16"
    act_rht: bool = False

    def fold(self, i: int) -> "Ctx":
        return dataclasses.replace(self, key=jax.random.fold_in(self.key, i))

    def with_key(self, key: jax.Array) -> "Ctx":
        return dataclasses.replace(self, key=key)

    @property
    def model_size(self) -> int:
        return 1 if self.mesh is None else self.mesh.shape[self.model_axis]

    @property
    def data_size(self) -> int:
        if self.mesh is None:
            return 1
        n = 1
        for a in self.data_axes:
            n *= self.mesh.shape[a]
        return n


# ---------------------------------------------------------------------------
# Attention (GQA + RoPE + SWA + softcap + qk-norm), query-chunked
# ---------------------------------------------------------------------------
def attn_init(key, cfg: ArchConfig, d_in: int | None = None) -> dict:
    d = d_in or cfg.d_model
    dh = cfg.dh
    ks = jax.random.split(key, 4)
    p = {
        "wq": linear_init(ks[0], d, cfg.n_heads * dh),
        "wk": linear_init(ks[1], d, cfg.n_kv_heads * dh),
        "wv": linear_init(ks[2], d, cfg.n_kv_heads * dh),
        "wo": linear_init(ks[3], cfg.n_heads * dh, cfg.d_model,
                          spec=P("model", None)),
    }
    if cfg.qk_norm:
        p["q_norm"] = norm_init(dh)
        p["k_norm"] = norm_init(dh)
    return p


def _attn_scores_block(q, k, scale, softcap):
    # q: (B,C,Hkv,G,dh)  k: (B,S,Hkv,dh) -> (B,Hkv,G,C,S)
    s = jnp.einsum("bchgd,bshd->bhgcs", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    if softcap:
        s = softcap * jnp.tanh(s / softcap)
    return s


def attention(
    q: jax.Array,                # (B, Sq, H, dh)
    k: jax.Array,                # (B, Sk, Hkv, dh)
    v: jax.Array,                # (B, Sk, Hkv, dh)
    *,
    causal_offset: jax.Array | int = 0,   # absolute position of q[0];
                                          # (B,) => per-sequence (decode)
    window: jax.Array | int = 0,          # 0 => full causal
    softcap: float = 0.0,
    chunk: int = 1024,
    kv_valid_len: jax.Array | None = None,  # for decode with preallocated
                                            # cache; (B,) => per-sequence
    causal: bool = True,                    # False: bidirectional / cross-attn
) -> jax.Array:
    b, sq, h, dh = q.shape
    sk = k.shape[1]
    hkv = k.shape[2]
    g = h // hkv
    scale = dh ** -0.5
    qr = q.reshape(b, sq, hkv, g, dh)
    kpos = jnp.arange(sk)
    window = jnp.asarray(window)
    kv_limit = sk if kv_valid_len is None else jnp.asarray(kv_valid_len)
    offset = jnp.asarray(causal_offset)

    def block(qc, qpos):
        # qpos: (C,) or, for per-sequence decode offsets, (B, C)
        s = _attn_scores_block(qc, k, scale, softcap)      # (B,Hkv,G,C,Sk)
        if causal:
            cmask = kpos <= qpos[..., None]
            in_window = jnp.where(window > 0,
                                  kpos > qpos[..., None] - window, True)
        else:
            cmask = jnp.ones(qpos.shape + (sk,), bool)
            in_window = True
        valid = (kpos[None, :] < kv_limit[:, None, None]
                 if getattr(kv_limit, "ndim", 0) == 1 else kpos < kv_limit)
        mask = cmask & in_window & valid            # (C, Sk) or (B, C, Sk)
        if mask.ndim == 2:
            mask = mask[None]
        s = jnp.where(mask[:, None, None], s, -1e30)
        p = jax.nn.softmax(s, axis=-1)
        o = jnp.einsum("bhgcs,bshd->bchgd", p, v.astype(jnp.float32))
        return o.reshape(b, -1, h, dh).astype(q.dtype)

    if sq <= chunk:
        return block(qr, offset[..., None] + jnp.arange(sq))

    assert sq % chunk == 0, f"Sq={sq} not divisible by attn chunk {chunk}"
    nc = sq // chunk

    def chunk_fn(i):
        qc = jax.lax.dynamic_slice_in_dim(qr, i * chunk, chunk, axis=1)
        qpos = offset[..., None] + i * chunk + jnp.arange(chunk)
        return block(qc, qpos)

    out = jax.lax.map(chunk_fn, jnp.arange(nc))            # (nc,B,C,H,dh)
    return jnp.moveaxis(out, 0, 1).reshape(b, sq, h, dh)


# ---------------------------------------------------------------------------
# Packed MixFP4 KV cache (the decode_32k traffic term; docs/serving.md)
# ---------------------------------------------------------------------------
# Per-tensor scale shared by every KV row.  Rows are quantized incrementally
# (one per decode step), so the level-2 scale cannot be data-dependent — it
# must be identical for rows written at different times.  RoPE'd K and raw V
# are O(1); with s32=1 the per-block E4M3 scale alone covers blockmaxes up
# to 6*448 = 2688 before clipping, the same headroom the paper's per-tensor
# rule (max|X|/2688) grants a tensor whose absmax IS 2688.
KV_SCALE32 = 1.0


def quantize_kv_rows(kv: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Quantize KV rows into the wire format under the shared KV_SCALE32.

    kv (..., dh) -> (payload (..., dh//2) u8, scales (..., dh//16) u8) via
    the fused Pallas row quantizer; 1-D g=16 blocks along the head dim.
    Replaces the historical ``serving.quantize_kv`` loose triple (which
    derived a per-call scale32 and so could not serve incremental writes).
    """
    from repro.kernels import ops  # deferred: kernels import core

    shape = kv.shape
    flat = kv.reshape(-1, shape[-1]).astype(jnp.float32)
    payload, scales, _ = ops.quantize_rows(flat, scale32=KV_SCALE32)
    return (payload.reshape(*shape[:-1], shape[-1] // 2),
            scales.reshape(*shape[:-1], shape[-1] // 16))


def _map_slot_arrays(fn, *trees):
    """tree.map over cache trees whose leaves may be QTensors: ``fn`` is
    applied to dense leaves and to QTensor payload/scales children, while
    scale32 (no per-slot batch axis — it is shared by construction) passes
    through from the first tree untouched."""
    is_qt = lambda x: isinstance(x, qtensor.QTensor)

    def one(leaf, *rest):
        if is_qt(leaf):
            return qtensor.QTensor(
                fn(leaf.payload, *[r.payload for r in rest]),
                fn(leaf.scales, *[r.scales for r in rest]),
                leaf.scale32, leaf.method, leaf.layout, leaf.shape,
                leaf.dtype)
        return fn(leaf, *rest)

    return jax.tree.map(one, *trees, is_leaf=is_qt)


def slot_take(cache, slot):
    """Slice slot ``slot``'s batch row (axis 1 of every (L, B, ...) cache
    leaf) into a batch-1 cache — the single-slot prefill view."""
    return _map_slot_arrays(
        lambda a: jax.lax.dynamic_slice_in_dim(a, slot, 1, axis=1), cache)


def slot_put(cache, small, slot):
    """Scatter a batch-1 cache (from :func:`slot_take` + a prefill) back
    into slot ``slot`` — only that batch row is written, so an admission is
    invisible to every other slot without any snapshot/restore."""
    return _map_slot_arrays(
        lambda a, s: jax.lax.dynamic_update_slice_in_dim(
            a, s.astype(a.dtype), slot, axis=1), cache, small)


def _attn_packed_cached(q, knew, vnew, kv_cache, cache_len, window,
                        cfg: ArchConfig):
    """Attention over the packed QTensor KV cache.

    Decode (s == 1): quantize the new K/V row, scatter its packed bytes
    into the cache at each slot's position, and run the fused Pallas
    decode-attention kernel straight over the packed arrays — no dense
    bf16 copy of the cache is ever materialized.

    Prefill (s > 1, scalar ``cache_len``): quantize all prompt rows at
    once, write the packed slab, and attend over the *dequantized* rows —
    bit-identical values to what later decode steps will read back, so a
    batched prefill and a token-by-token replay see the same quantized
    history.
    """
    from repro.kernels import ops  # deferred: kernels import core

    b, s, _, _ = q.shape
    ck, cv = kv_cache
    cl = jnp.asarray(cache_len)
    kp, ks = quantize_kv_rows(knew)
    vp, vs = quantize_kv_rows(vnew)
    if s == 1:
        cl_vec = cl if cl.ndim else jnp.broadcast_to(cl, (b,))
        rows = jnp.arange(b)
        ckp = ck.payload.at[rows, cl_vec].set(kp[:, 0])
        cks = ck.scales.at[rows, cl_vec].set(ks[:, 0])
        cvp = cv.payload.at[rows, cl_vec].set(vp[:, 0])
        cvs = cv.scales.at[rows, cl_vec].set(vs[:, 0])
        o = ops.attn_decode_packed(
            q[:, 0], ckp, cks, cvp, cvs, cl_vec + 1,
            window=window, softcap=cfg.softcap_attn,
            k_scale32=ck.scale32, v_scale32=cv.scale32)
        o = o[:, None].astype(q.dtype)
    else:
        assert cl.ndim == 0, \
            "packed-KV prefill requires a scalar cache_len (whole-prompt " \
            "writes start at one position)"
        ckp = jax.lax.dynamic_update_slice_in_dim(ck.payload, kp, cl, axis=1)
        cks = jax.lax.dynamic_update_slice_in_dim(ck.scales, ks, cl, axis=1)
        cvp = jax.lax.dynamic_update_slice_in_dim(cv.payload, vp, cl, axis=1)
        cvs = jax.lax.dynamic_update_slice_in_dim(cv.scales, vs, cl, axis=1)
        k = qtensor.from_packed_rows(ckp, cks, ck.scale32).dequantize()
        v = qtensor.from_packed_rows(cvp, cvs, cv.scale32).dequantize()
        o = attention(q, k, v, causal_offset=cl, window=window,
                      softcap=cfg.softcap_attn, chunk=cfg.attn_chunk,
                      kv_valid_len=cl + s)
    new_k = qtensor.QTensor(ckp, cks, ck.scale32, ck.method, ck.layout,
                            ck.shape, ck.dtype)
    new_v = qtensor.QTensor(cvp, cvs, cv.scale32, cv.method, cv.layout,
                            cv.shape, cv.dtype)
    return o, (new_k, new_v)


def _attn_paged_cached(q, knew, vnew, kv_cache, cache_len, block_tables,
                       window, cfg: ArchConfig):
    """Attention over the *paged* packed KV pool (serving.kvpool).

    The cache children are physical page slabs (P, page_len, Hkv, ...) and
    ``block_tables`` (B, max_pages) int32 maps each sequence's logical page
    order to slab rows — logical position ``t`` lives at
    ``(block_tables[b, t // page_len], t % page_len)``.

    Decode (s == 1): quantize the new row, scatter its packed bytes through
    the page translation, and run the paged flash kernel over the slabs +
    table.  Inactive lanes scatter into page 0 (the pool's trash page,
    where zeroed table rows point); every read of it is masked by lengths.

    Prefill (s > 1, scalar ``cache_len`` = the suffix start): scatter all
    rows through the translation, then gather the sequence's pages into the
    logical (1, max_pages*page_len, ...) view and attend over the
    *dequantized* rows exactly as the fixed-slot prefill does — gathered
    bytes equal the fixed path's in every valid position and junk rows
    beyond ``kv_valid_len`` are masked identically, so the logits are
    bitwise the fixed path's.
    """
    from repro.kernels import ops  # deferred: kernels import core

    b, s, _, _ = q.shape
    ck, cv = kv_cache
    page_len = ck.payload.shape[1]
    cl = jnp.asarray(cache_len)
    kp, ks = quantize_kv_rows(knew)
    vp, vs = quantize_kv_rows(vnew)
    if s == 1:
        cl_vec = cl if cl.ndim else jnp.broadcast_to(cl, (b,))
        rows = jnp.arange(b)
        phys = block_tables[rows, cl_vec // page_len]
        off = cl_vec % page_len
        ckp = ck.payload.at[phys, off].set(kp[:, 0])
        cks = ck.scales.at[phys, off].set(ks[:, 0])
        cvp = cv.payload.at[phys, off].set(vp[:, 0])
        cvs = cv.scales.at[phys, off].set(vs[:, 0])
        o = ops.attn_decode_paged(
            q[:, 0], ckp, cks, cvp, cvs, block_tables, cl_vec + 1,
            window=window, softcap=cfg.softcap_attn,
            k_scale32=ck.scale32, v_scale32=cv.scale32)
        o = o[:, None].astype(q.dtype)
    else:
        assert cl.ndim == 0, \
            "paged prefill requires a scalar cache_len (the suffix start)"
        assert b == 1, "paged prefill is a single-request view (b == 1)"
        pos = cl + jnp.arange(s)
        phys = block_tables[0, pos // page_len]
        off = pos % page_len
        ckp = ck.payload.at[phys, off].set(kp[0])
        cks = ck.scales.at[phys, off].set(ks[0])
        cvp = cv.payload.at[phys, off].set(vp[0])
        cvs = cv.scales.at[phys, off].set(vs[0])

        def logical(a):  # (P, page_len, Hkv, x) -> (1, S_logical, Hkv, x)
            g = a[block_tables[0]]
            return g.reshape(1, -1, *g.shape[2:])

        k = qtensor.from_packed_rows(
            logical(ckp), logical(cks), ck.scale32).dequantize()
        v = qtensor.from_packed_rows(
            logical(cvp), logical(cvs), cv.scale32).dequantize()
        o = attention(q, k, v, causal_offset=cl, window=window,
                      softcap=cfg.softcap_attn, chunk=cfg.attn_chunk,
                      kv_valid_len=cl + s)
    new_k = qtensor.QTensor(ckp, cks, ck.scale32, ck.method, ck.layout,
                            ck.shape, ck.dtype)
    new_v = qtensor.QTensor(cvp, cvs, cv.scale32, cv.method, cv.layout,
                            cv.shape, cv.dtype)
    return o, (new_k, new_v)


def attn_apply(p: dict, x: jax.Array, ctx: Ctx, cfg: ArchConfig, *,
               positions: jax.Array, window, kv_cache=None,
               cache_len=None, causal: bool = True, block_tables=None,
               ) -> tuple[jax.Array, tuple | None]:
    """Full attention sub-layer.  When ``kv_cache=(K, V)`` is given, new K/V
    are written at ``cache_len`` and attention runs over the cache (decode).
    A cache of packed QTensors routes through the fused packed-KV path;
    with ``block_tables`` the QTensors are paged pool slabs and writes/reads
    go through the page translation (serving.kvpool)."""
    b, s, _ = x.shape
    dh = cfg.dh
    q = qlinear(x, p["wq"], ctx, 0).reshape(b, s, cfg.n_heads, dh)
    knew = qlinear(x, p["wk"], ctx, 1).reshape(b, s, cfg.n_kv_heads, dh)
    vnew = qlinear(x, p["wv"], ctx, 2).reshape(b, s, cfg.n_kv_heads, dh)
    if cfg.qk_norm:
        q = rms_norm(q, p["q_norm"], cfg.norm_eps)
        knew = rms_norm(knew, p["k_norm"], cfg.norm_eps)
    q = apply_rope(q, positions, cfg.rope_theta)
    knew = apply_rope(knew, positions, cfg.rope_theta)

    # TP layout for attention: shard heads over 'model' when divisible;
    # otherwise shard K/V over the *key sequence* (flash-decoding style:
    # every chip scores a key slice, softmax reductions psum over model).
    # Indivisible explicit constraints would trigger involuntary full
    # rematerialisation in SPMD, so never emit those.
    m = _active_mesh()
    msize = m.shape["model"] if (m is not None and "model" in m.axis_names) else 1
    heads_div = cfg.n_heads % msize == 0 and cfg.n_kv_heads % msize == 0
    if heads_div:
        q = shard(q, "data", None, "model", None)
        knew = shard(knew, "data", None, "model", None)
        vnew = shard(vnew, "data", None, "model", None)
    else:
        q = shard(q, "data", None, None, None)
        if knew.shape[1] % msize == 0:
            knew = shard(knew, "data", "model", None, None)
            vnew = shard(vnew, "data", "model", None, None)

    if kv_cache is not None and isinstance(kv_cache[0], qtensor.QTensor):
        if block_tables is not None:
            o, new_cache = _attn_paged_cached(
                q, knew, vnew, kv_cache, cache_len, block_tables, window,
                cfg)
        else:
            o, new_cache = _attn_packed_cached(
                q, knew, vnew, kv_cache, cache_len, window, cfg)
        out = qlinear(o.reshape(b, s, cfg.n_heads * dh), p["wo"], ctx, 3)
        return out, new_cache

    new_cache = None
    if kv_cache is None:
        k, v = knew, vnew
        causal_offset = 0
        kv_valid = None
    else:
        ck, cv = kv_cache
        cl = jnp.asarray(cache_len)
        if cl.ndim == 1:
            # per-sequence cache positions (continuous batching: each slot
            # decodes at its own length) — single-token scatter per row
            assert s == 1, "per-sequence cache_len requires single-token steps"
            rows = jnp.arange(b)
            ck = ck.at[rows, cl].set(knew[:, 0].astype(ck.dtype))
            cv = cv.at[rows, cl].set(vnew[:, 0].astype(cv.dtype))
        else:
            ck = jax.lax.dynamic_update_slice_in_dim(
                ck, knew.astype(ck.dtype), cache_len, axis=1)
            cv = jax.lax.dynamic_update_slice_in_dim(
                cv, vnew.astype(cv.dtype), cache_len, axis=1)
        k, v = ck, cv
        causal_offset = cl
        kv_valid = cl + s
        new_cache = (ck, cv)

    o = attention(q, k, v, causal_offset=causal_offset, window=window,
                  softcap=cfg.softcap_attn, chunk=cfg.attn_chunk,
                  kv_valid_len=kv_valid, causal=causal)
    out = qlinear(o.reshape(b, s, cfg.n_heads * dh), p["wo"], ctx, 3)
    return out, new_cache


# ---------------------------------------------------------------------------
# MLP (SwiGLU / GeLU / GeGLU)
# ---------------------------------------------------------------------------
def mlp_init(key, cfg: ArchConfig, d_ff: int | None = None,
             d_in: int | None = None) -> dict:
    d = d_in or cfg.d_model
    f = d_ff or cfg.d_ff
    ks = jax.random.split(key, 3)
    p = {"w_up": linear_init(ks[0], d, f),
         "w_down": linear_init(ks[1], f, cfg.d_model, spec=P("model", None))}
    if cfg.mlp_type in ("swiglu", "geglu"):
        p["w_gate"] = linear_init(ks[2], d, f)
    return p


def mlp(p: dict, x: jax.Array, ctx: Ctx, cfg: ArchConfig) -> jax.Array:
    mid = (None,) * (x.ndim - 2)  # rank-adaptive: (B,S,D) or (T,D) inputs
    up = qlinear(x, p["w_up"], ctx, 4)
    up = shard(up, "data", *mid, "model")
    if cfg.mlp_type == "swiglu":
        gate = jax.nn.silu(qlinear(x, p["w_gate"], ctx, 5))
        h = shard(gate, "data", *mid, "model") * up
    elif cfg.mlp_type == "geglu":
        gate = jax.nn.gelu(qlinear(x, p["w_gate"], ctx, 5))
        h = shard(gate, "data", *mid, "model") * up
    else:  # gelu
        h = jax.nn.gelu(up)
    return qlinear(h, p["w_down"], ctx, 6)


# ---------------------------------------------------------------------------
# Shared LM head / loss
# ---------------------------------------------------------------------------
def lm_logits(x: jax.Array, embed: jax.Array, softcap: float = 0.0,
              vocab: int | None = None) -> jax.Array:
    """Tied-embedding LM head (bf16 inputs, f32 logits), optional softcap.
    ``vocab`` slices off the TP padding rows of the embedding."""
    logits = jnp.einsum("...d,vd->...v", x.astype(jnp.float32),
                        embed.astype(jnp.float32))
    if softcap:
        logits = softcap * jnp.tanh(logits / softcap)
    if vocab is not None and logits.shape[-1] != vocab:
        logits = logits[..., :vocab]
    return logits


def xent_loss(logits: jax.Array, labels: jax.Array,
              valid_vocab: int | None = None) -> jax.Array:
    """Mean next-token cross entropy; labels < 0 are masked.

    ``valid_vocab`` masks TP-padding columns out of the logsumexp so the
    loss over a padded-vocab logits tensor is exact — logits stay
    vocab-sharded all the way into the reduction (no all-gather)."""
    if valid_vocab is not None and logits.shape[-1] != valid_vocab:
        col = jnp.arange(logits.shape[-1])
        logits = jnp.where(col < valid_vocab, logits, -1e30)
    mask = (labels >= 0).astype(jnp.float32)
    lse = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(
        logits, jnp.maximum(labels, 0)[..., None], axis=-1)[..., 0]
    return jnp.sum((lse - gold) * mask) / jnp.maximum(jnp.sum(mask), 1.0)


def fused_lm_loss(x: jax.Array, embed: jax.Array, labels: jax.Array,
                  softcap: float, valid_vocab: int,
                  chunk: int = 1024) -> jax.Array:
    """Sequence-chunked LM head + cross entropy (never materialises the full
    (B, S, V) logits — the dominant temp of big-vocab training).  The scan
    body is rematerialised in the backward pass, bounding live logits to one
    chunk."""
    b, s, d = x.shape
    chunk = min(chunk, s)
    if s % chunk:
        chunk = s  # fall back (smoke shapes)
    nc = s // chunk

    def body(acc, i):
        xc = jax.lax.dynamic_slice_in_dim(x, i * chunk, chunk, axis=1)
        lc = jax.lax.dynamic_slice_in_dim(labels, i * chunk, chunk, axis=1)
        logits = lm_logits(xc, embed, softcap)
        logits = shard(logits, "data", None, "model")
        if logits.shape[-1] != valid_vocab:
            col = jnp.arange(logits.shape[-1])
            logits = jnp.where(col < valid_vocab, logits, -1e30)
        mask = (lc >= 0).astype(jnp.float32)
        lse = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(
            logits, jnp.maximum(lc, 0)[..., None], axis=-1)[..., 0]
        nll, cnt = acc
        return (nll + jnp.sum((lse - gold) * mask),
                cnt + jnp.sum(mask)), None

    body_fn = jax.checkpoint(body) if nc > 1 else body
    (nll, cnt), _ = jax.lax.scan(
        body_fn, (jnp.float32(0.0), jnp.float32(0.0)), jnp.arange(nc))
    return nll / jnp.maximum(cnt, 1.0)


# ---------------------------------------------------------------------------
# Model registry
# ---------------------------------------------------------------------------
def build_model(cfg: ArchConfig):
    """Return the module implementing ``cfg.family``; each module exposes
    init / forward / loss / init_cache / prefill / decode_step."""
    from repro.models import encdec, mamba, transformer

    if cfg.family in ("dense", "moe", "vlm"):
        return transformer.TransformerLM(cfg)
    if cfg.family in ("ssm", "hybrid"):
        return mamba.MambaLM(cfg)
    if cfg.family == "encdec":
        return encdec.EncDecLM(cfg)
    raise ValueError(f"unknown family {cfg.family!r}")
