"""Decoder-only transformer LM covering the dense / moe / vlm families.

Features (per assigned arch): GQA + RoPE, SwiGLU/GeLU/GeGLU MLPs, MoE with
shared experts, sliding-window and local/global alternating attention,
attention/final logit softcaps, QK-norm, sandwich norms, VLM/audio prefix
embeddings (stub frontends per the brief).  Layers run under lax.scan with
optional remat; every projection GEMM goes through the Fig. 7 quantized
boundary (embeddings/LM head stay bf16, per the paper's exclusions).

Serving: ``decode_step``/``prefill_slot`` inherit the activation format
from the engine's ``Ctx`` — with ``act_quant="mixfp4"`` every ``qlinear``
(attention q/k/v/o, MLP up/gate/down, MoE experts) quantizes its rows on
the fly and runs the W4A4 kernel against the packed weight; no per-family
plumbing, the flag rides the Ctx through the layer scan (docs/serving.md).
"""
from __future__ import annotations

import math
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.core import qtensor
from repro.models import base, moe as moe_lib
from repro.models.base import ArchConfig, Ctx, Param, shard, unzip_params


class TransformerLM:
    def __init__(self, cfg: ArchConfig):
        self.cfg = cfg

    # ------------------------------------------------------------------
    # init
    # ------------------------------------------------------------------
    def _layer_init(self, key):
        cfg = self.cfg
        k1, k2 = jax.random.split(key)
        p = {
            "ln_attn": base.norm_init(cfg.d_model),
            "attn": base.attn_init(k1, cfg),
            "ln_mlp": base.norm_init(cfg.d_model),
        }
        if cfg.n_experts:
            p["moe"] = moe_lib.moe_init(k2, cfg)
        else:
            p["mlp"] = base.mlp_init(k2, cfg)
        return p

    def init(self, key):
        cfg = self.cfg
        ke, kl, kf = jax.random.split(key, 3)
        proto = self._layer_init(kl)
        _, layer_specs = unzip_params(proto)
        layer_specs = jax.tree.map(lambda s: P(None, *s), layer_specs)
        lkeys = jax.random.split(kl, cfg.n_layers)
        layer_values = jax.vmap(
            lambda k: unzip_params(self._layer_init(k))[0])(lkeys)

        values = {
            "embed": jax.random.normal(
                ke, (base.padded_vocab(cfg.vocab), cfg.d_model),
                jnp.float32) * 0.02,
            "layers": layer_values,
            "ln_f": jnp.ones((cfg.d_model,), jnp.float32),
        }
        specs = {
            "embed": P("model", None),
            "layers": layer_specs,
            "ln_f": P(None),
        }
        return values, specs

    # ------------------------------------------------------------------
    # per-layer windows (gemma2 local/global; SWA)
    # ------------------------------------------------------------------
    def layer_windows(self) -> np.ndarray:
        cfg = self.cfg
        w = np.zeros((cfg.n_layers,), np.int32)
        if cfg.window and cfg.local_global_period:
            # local (windowed) except every p-th layer which is global
            w[:] = cfg.window
            w[cfg.local_global_period - 1::cfg.local_global_period] = 0
        elif cfg.window:
            w[:] = cfg.window
        return w

    # ------------------------------------------------------------------
    # forward
    # ------------------------------------------------------------------
    def _embed_in(self, params, batch):
        cfg = self.cfg
        x = params["embed"][batch["tokens"]].astype(jnp.bfloat16)
        if cfg.emb_scale:
            x = x * math.sqrt(cfg.d_model)
        if cfg.n_prefix_embeds:
            x = jnp.concatenate(
                [batch["prefix"].astype(jnp.bfloat16), x], axis=1)
        return shard(x, "data", None, "model")

    def _layer_apply(self, lp, x, ctx: Ctx, window, *, positions,
                     kv_cache=None, cache_len=None, block_tables=None):
        cfg = self.cfg
        h = base.rms_norm(x, lp["ln_attn"], cfg.norm_eps)
        attn_out, new_cache = base.attn_apply(
            lp["attn"], h, ctx.fold(1), cfg, positions=positions,
            window=window, kv_cache=kv_cache, cache_len=cache_len,
            block_tables=block_tables)
        x = x + attn_out
        h = base.rms_norm(x, lp["ln_mlp"], cfg.norm_eps)
        if cfg.n_experts:
            mo, aux = moe_lib.moe_apply(lp["moe"], h, ctx.fold(2), cfg)
        else:
            mo, aux = base.mlp(lp["mlp"], h, ctx.fold(2), cfg), 0.0
        x = x + mo
        # residual stream D-sharded over model: saved scan carries (the
        # dominant remat memory) shrink by the TP degree; projections are
        # row-parallel from a D-sharded input (psum outputs, no gathers)
        x = shard(x, "data", None, "model")
        return x, aux, new_cache

    def hidden(self, params, batch, ctx: Ctx):
        """Full-sequence backbone -> (final hidden states, aux loss)."""
        cfg = self.cfg
        x = self._embed_in(params, batch)
        s_total = x.shape[1]
        positions = jnp.arange(s_total)[None, :]
        windows = jnp.asarray(self.layer_windows())
        lkeys = jax.random.split(ctx.key, cfg.n_layers)

        def body(carry, xs):
            x, aux = carry
            lp, lk, w = xs
            lctx = ctx.with_key(lk)
            x, a, _ = self._layer_apply(lp, x, lctx, w, positions=positions)
            return (x, aux + a), None

        body_fn = jax.checkpoint(body) if cfg.n_layers > 1 else body
        (x, aux), _ = jax.lax.scan(
            body_fn, (x, jnp.float32(0.0)),
            (params["layers"], lkeys, windows))

        x = base.rms_norm(x, params["ln_f"], cfg.norm_eps)
        if cfg.n_prefix_embeds:
            x = x[:, cfg.n_prefix_embeds:]
        return x, aux

    def forward(self, params, batch, ctx: Ctx):
        """Training/prefill-style full-sequence forward -> (logits, aux)."""
        x, aux = self.hidden(params, batch, ctx)
        logits = base.lm_logits(x, params["embed"], self.cfg.softcap_final)
        return shard(logits, "data", None, "model"), aux

    def loss(self, params, batch, ctx: Ctx):
        x, aux = self.hidden(params, batch, ctx)
        return base.fused_lm_loss(x, params["embed"], batch["labels"],
                                  self.cfg.softcap_final,
                                  self.cfg.vocab) + aux

    # ------------------------------------------------------------------
    # serving: KV cache, prefill, decode
    # ------------------------------------------------------------------
    def init_cache(self, batch_size: int, max_len: int, dtype=jnp.bfloat16,
                   kv_quant: str | None = None,
                   pages: tuple[int, int] | None = None):
        """Preallocated KV cache.  ``kv_quant="mixfp4"`` holds it packed:
        one 1-D-blocked QTensor per K/V whose children carry a leading
        layer axis ((L, B, S, Hkv, dh//2) payload + (..., dh//16) scale
        bytes, 4.5 bits/value in HBM) that ``lax.scan`` slices layer-by-
        layer; decode reads it through the fused Pallas attention kernel
        without ever materializing the dense tensor (docs/serving.md).

        ``pages=(num_pages, page_len)`` builds the *paged* layout instead
        (serving.kvpool): K/V children become physical page slabs
        ((L, P, page_len, Hkv, ...)) shared by every request, plus a
        ``"pages"`` block table (B, max_len//page_len) int32 mapping each
        batch lane's logical page order to slab rows.  The zeroed table
        points every lane at page 0, the pool's trash page."""
        cfg = self.cfg
        if pages is not None:
            if kv_quant != "mixfp4":
                raise ValueError("paged KV (pages=) requires "
                                 f"kv_quant='mixfp4', got {kv_quant!r}")
            num_pages, page_len = pages
            if page_len % 16 or max_len % page_len:
                raise ValueError(
                    f"page_len={page_len} must be a multiple of 16 and "
                    f"divide max_len={max_len}")
        shape = (cfg.n_layers, batch_size, max_len, cfg.n_kv_heads, cfg.dh)
        if kv_quant is None or kv_quant == "bf16":
            return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype)}
        if kv_quant != "mixfp4":
            raise ValueError(f"unknown kv_quant {kv_quant!r} "
                             "(expected None, 'bf16' or 'mixfp4')")
        if cfg.dh % 16:
            raise ValueError(
                f"kv_quant='mixfp4' needs head_dim % 16 == 0, got {cfg.dh}")

        rows = (shape[1:-1] if pages is None
                else (num_pages, page_len, cfg.n_kv_heads))

        def packed():
            # zero payload/scale bytes decode to exact zeros (scale 0)
            return qtensor.QTensor(
                jnp.zeros((cfg.n_layers, *rows, cfg.dh // 2), jnp.uint8),
                jnp.zeros((cfg.n_layers, *rows, cfg.dh // 16), jnp.uint8),
                # per-layer scale32 so scan slices it with the layer axis;
                # all rows share base.KV_SCALE32 (incremental row writes)
                jnp.full((cfg.n_layers,), base.KV_SCALE32, jnp.float32),
                method="mixfp4", layout=qtensor.BlockLayout1D(-1, 16),
                shape=(*rows, cfg.dh), dtype="float32")

        cache = {"k": packed(), "v": packed()}
        if pages is not None:
            cache["pages"] = jnp.zeros(
                (batch_size, max_len // page_len), jnp.int32)
        return cache

    def cache_specs(self):
        """Dense-cache PartitionSpecs for the dryrun serve cells: shard
        over *sequence* on the model axis — no head-padding waste for
        small GQA kv counts, flash-decoding style reads.

        The PACKED cache (``kv_quant="mixfp4"``) has no spec here yet:
        ``ServeEngine(mesh=...)`` replicates it (docs/serving.md).  The
        QTensor contract already admits the same sequence-axis sharding
        (S is a lead dim of the packed rows, docs/sharding.md); routing
        it through the fused decode-attention kernel is the open
        sharded-packed-KV ROADMAP item."""
        spec = P(None, "data", "model", None, None)
        return {"k": spec, "v": spec}

    def _run_layers_cached(self, params, x, ctx: Ctx, cache_k, cache_v,
                           cache_len, positions, block_tables=None):
        cfg = self.cfg
        windows = jnp.asarray(self.layer_windows())
        lkeys = jax.random.split(ctx.key, cfg.n_layers)

        def body(x, xs):
            lp, lk, w, ck, cv = xs
            lctx = ctx.with_key(lk)
            x, _, new_cache = self._layer_apply(
                lp, x, lctx, w, positions=positions,
                kv_cache=(ck, cv), cache_len=cache_len,
                block_tables=block_tables)  # scan-invariant (shared by L)
            return x, new_cache

        x, (new_k, new_v) = jax.lax.scan(
            body, x, (params["layers"], lkeys, windows, cache_k, cache_v))
        x = base.rms_norm(x, params["ln_f"], cfg.norm_eps)
        return x, new_k, new_v

    def prefill(self, params, batch, ctx: Ctx, cache):
        """Write the prompt into the cache; returns (last-pos logits, cache)."""
        cfg = self.cfg
        x = self._embed_in(params, batch)
        positions = jnp.arange(x.shape[1])[None, :]
        x, nk, nv = self._run_layers_cached(
            params, x, ctx, cache["k"], cache["v"], 0, positions)
        logits = base.lm_logits(x[:, -1], params["embed"], cfg.softcap_final,
                                vocab=cfg.vocab)
        return logits, {"k": nk, "v": nv}

    def reset_slot(self, cache, i: int):
        """Zero slot ``i``'s cache rows so a freshly admitted request starts
        from position 0 with no stale K/V (continuous batching).  On the
        packed cache this zeroes the slot's payload/scale *bytes* (zero
        bytes decode to exact zeros; scale32 is shared, untouched).  On the
        *paged* cache only the lane's block-table row is cleared (-> the
        trash page): pool bytes are never zeroed — stale rows are unreachable
        once unmapped, and every mapped row is either freshly written or a
        shared immutable prefix page (serving.kvpool)."""
        if "pages" in cache:
            return dict(cache, pages=cache["pages"].at[i].set(0))
        return base._map_slot_arrays(lambda a: a.at[:, i].set(0), cache)

    def slot_state(self, cache, i: int):
        """Snapshot slot ``i``'s cache rows (packed caches snapshot the
        slot's packed bytes; the returned QTensor is an opaque
        ``write_slot`` token, not a standalone logical tensor)."""
        return base._map_slot_arrays(lambda a: a[:, i], cache)

    def write_slot(self, cache, i: int, state):
        return base._map_slot_arrays(
            lambda a, s: a.at[:, i].set(s), cache, state)

    def prefill_slot(self, params, tokens, ctx: Ctx, cache, slot,
                     true_len=None, start_pos=None):
        """Batched single-slot prefill: run the whole prompt in ONE call.

        tokens (1, P) int32; ``slot`` selects the cache batch row.  The
        slot's cache is sliced to batch 1, the prompt runs through the
        full-sequence layer stack (projection GEMMs hit the W4A16 kernels
        at (P, K) prefill shapes instead of P single-token dispatches),
        every cache row is written at once, and only slot ``slot`` is
        touched — an admission is invisible to its batchmates with no
        snapshot/restore.  Embedding matches ``decode_step`` (engine
        requests carry tokens only — no VLM prefix path here).  Returns
        (last-position logits (1, V), updated full cache).

        ``true_len`` (dynamic int32) supports the engine's prompt-length
        bucketing: ``tokens`` is the prompt padded up the bucket ladder and
        the logits are taken at position ``true_len - 1``.  Causality makes
        the padded suffix invisible to every real position — suffix cache
        rows hold junk but are masked by the per-slot length at decode and
        overwritten row-by-row before ever becoming valid — so the result
        is bitwise the exact-length call's.

        On a *paged* cache (``"pages"`` in the cache dict) the pool slabs
        have no batch axis: the slot's view is its block-table ROW, prompt
        rows scatter straight into the request's own pages, and
        ``start_pos`` (dynamic int32, default 0) starts the prefill past a
        prefix already served from cached pages (serving.kvpool) —
        ``tokens`` then holds only the prompt *suffix* and positions /
        causality shift by ``start_pos``.
        """
        cfg = self.cfg
        p_len = tokens.shape[1]
        model = self
        if p_len > cfg.attn_chunk and p_len % cfg.attn_chunk:
            # chunked attention needs Sq % chunk == 0; fall back to one
            # unchunked block for awkward prompt lengths (P is a static
            # shape — each prompt length compiles its own prefill anyway)
            model = TransformerLM(cfg.replace(attn_chunk=p_len))
        paged = isinstance(cache, dict) and "pages" in cache
        start = jnp.int32(0) if start_pos is None \
            else jnp.asarray(start_pos, jnp.int32)
        x = params["embed"][tokens].astype(jnp.bfloat16)
        if cfg.emb_scale:
            x = x * math.sqrt(cfg.d_model)
        positions = start + jnp.arange(p_len)[None, :]
        if paged:
            btrow = jax.lax.dynamic_slice_in_dim(
                cache["pages"], slot, 1, axis=0)       # (1, max_pages)
            x, nk, nv = model._run_layers_cached(
                params, x, ctx, cache["k"], cache["v"], start, positions,
                block_tables=btrow)
        else:
            small = base.slot_take(cache, slot)
            x, nk, nv = model._run_layers_cached(
                params, x, ctx, small["k"], small["v"], start, positions)
        if true_len is None:
            x_last = x[:, -1]
        else:
            x_last = jax.lax.dynamic_index_in_dim(
                x, jnp.asarray(true_len, jnp.int32) - 1, axis=1,
                keepdims=False)
        logits = base.lm_logits(x_last, params["embed"], cfg.softcap_final,
                                vocab=cfg.vocab)
        if paged:  # pool writes landed in this request's pages directly
            return logits, {"k": nk, "v": nv, "pages": cache["pages"]}
        return logits, base.slot_put(cache, {"k": nk, "v": nv}, slot)

    def decode_step(self, params, tokens, ctx: Ctx, cache, cache_len):
        """One token for every sequence in the batch.

        tokens: (B,) int32; cache_len: () int32 shared length, or (B,) int32
        per-sequence lengths (continuous batching: each slot decodes at its
        own cache position).  Returns (logits (B, V), updated cache arrays).
        """
        cfg = self.cfg
        x = params["embed"][tokens[:, None]].astype(jnp.bfloat16)
        if cfg.emb_scale:
            x = x * math.sqrt(cfg.d_model)
        positions = base.decode_positions(cache_len, x.shape[0])
        paged = isinstance(cache, dict) and "pages" in cache
        x, nk, nv = self._run_layers_cached(
            params, x, ctx, cache["k"], cache["v"], cache_len, positions,
            block_tables=cache["pages"] if paged else None)
        logits = base.lm_logits(x[:, 0], params["embed"], cfg.softcap_final,
                                vocab=cfg.vocab)
        if paged:
            return logits, {"k": nk, "v": nv, "pages": cache["pages"]}
        return logits, {"k": nk, "v": nv}
