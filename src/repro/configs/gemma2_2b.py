"""Gemma-2-2B [arXiv:2408.00118].

26L d_model=2304 8H (kv=4, head_dim=256) d_ff=9216 vocab=256000.
Local(4096)/global alternating attention, attn softcap 50, final logit
softcap 30, GeGLU, sqrt(d) embedding scaling.  Global layers are full
attention -> long_500k SKIPPED (DESIGN.md §5)."""
from repro.models.base import ArchConfig


def config() -> ArchConfig:
    return ArchConfig(
        name="gemma2-2b", family="dense",
        n_layers=26, d_model=2304, n_heads=8, n_kv_heads=4, head_dim=256,
        d_ff=9216, vocab=256000, mlp_type="geglu",
        window=4096, local_global_period=2,
        softcap_attn=50.0, softcap_final=30.0, emb_scale=True,
    )


def smoke_config() -> ArchConfig:
    return ArchConfig(
        name="gemma2-smoke", family="dense",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
        d_ff=128, vocab=256, mlp_type="geglu",
        window=8, local_global_period=2,
        softcap_attn=50.0, softcap_final=30.0, emb_scale=True,
        attn_chunk=64,
    )
