"""Assigned input shapes and their applicability per architecture.

Four shapes per LM arch (seq_len x global_batch):
  train_4k     4,096 x 256   -> train_step
  prefill_32k  32,768 x 32   -> prefill_step (fwd + KV-cache write)
  decode_32k   32,768 x 128  -> serve_step (1 new token, cache of seq_len)
  long_500k    524,288 x 1   -> serve_step; needs sub-quadratic attention

long_500k runs only for SSM/hybrid/pure-SWA archs (falcon-mamba, zamba2,
h2o-danube); pure full/global-attention archs skip it (DESIGN.md §5).
Encoder-decoder archs run decode shapes on the decoder side.

Decode semantics: the cache holds seq_len-1 tokens; the step appends one
token at index seq_len-1 and attends over the full seq_len window.
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp

from repro.models.base import ArchConfig


@dataclass(frozen=True)
class Shape:
    name: str
    kind: str      # train | prefill | decode
    seq: int
    batch: int


SHAPES = {
    "train_4k": Shape("train_4k", "train", 4_096, 256),
    "prefill_32k": Shape("prefill_32k", "prefill", 32_768, 32),
    "decode_32k": Shape("decode_32k", "decode", 32_768, 128),
    "long_500k": Shape("long_500k", "decode", 524_288, 1),
}


def applicable(cfg: ArchConfig, shape_name: str) -> tuple[bool, str]:
    """(runs?, reason).  Mirrors DESIGN.md §5."""
    if shape_name != "long_500k":
        return True, "ok"
    if cfg.family in ("ssm", "hybrid"):
        return True, "ssm/hybrid: O(1)-state or linear-memory decode"
    if cfg.window and not cfg.local_global_period:
        return True, "pure SWA: bounded window cache"
    if cfg.local_global_period:
        return False, "alternating local/GLOBAL attention is quadratic at 500k"
    return False, "pure full attention is quadratic at 500k"


def token_inputs(cfg: ArchConfig, shape: Shape, *, reduced: bool = False):
    """ShapeDtypeStructs for the data-side inputs of the entry point.

    ``reduced`` shrinks seq/batch for CPU smoke use of the same code path.
    """
    s = min(shape.seq, 64) if reduced else shape.seq
    b = min(shape.batch, 2) if reduced else shape.batch
    i32 = jnp.int32
    sd = jax.ShapeDtypeStruct

    if shape.kind == "train":
        if cfg.family == "encdec":
            return {
                "src_embeds": sd((b, s, cfg.d_model), jnp.bfloat16),
                "tokens": sd((b, s), i32),
                "labels": sd((b, s), i32),
            }
        if cfg.n_prefix_embeds:
            st = s - cfg.n_prefix_embeds
            return {
                "tokens": sd((b, st), i32),
                "prefix": sd((b, cfg.n_prefix_embeds, cfg.d_model),
                             jnp.bfloat16),
                "labels": sd((b, st), i32),
            }
        return {"tokens": sd((b, s), i32), "labels": sd((b, s), i32)}

    if shape.kind == "prefill":
        if cfg.family == "encdec":
            return {
                "src_embeds": sd((b, s, cfg.d_model), jnp.bfloat16),
                "tokens": sd((b, s), i32),
            }
        if cfg.n_prefix_embeds:
            return {
                "tokens": sd((b, s - cfg.n_prefix_embeds), i32),
                "prefix": sd((b, cfg.n_prefix_embeds, cfg.d_model),
                             jnp.bfloat16),
            }
        return {"tokens": sd((b, s), i32)}

    # decode: one token per sequence
    return {"tokens": sd((b,), i32)}
