"""StarCoder2-15B [arXiv:2402.19173] — dense, GQA + RoPE, GeLU MLP.

40L d_model=6144 48H (kv=4, head_dim=128) d_ff=24576 vocab=49152."""
from repro.models.base import ArchConfig


def config() -> ArchConfig:
    return ArchConfig(
        name="starcoder2-15b", family="dense",
        n_layers=40, d_model=6144, n_heads=48, n_kv_heads=4, head_dim=128,
        d_ff=24576, vocab=49152, mlp_type="gelu",
    )


def smoke_config() -> ArchConfig:
    return ArchConfig(
        name="starcoder2-smoke", family="dense",
        n_layers=2, d_model=96, n_heads=6, n_kv_heads=2, head_dim=16,
        d_ff=192, vocab=256, mlp_type="gelu", attn_chunk=64,
    )
