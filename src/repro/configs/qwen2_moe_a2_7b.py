"""Qwen1.5-MoE-A2.7B [hf:Qwen/Qwen1.5-MoE-A2.7B].

24L d_model=2048 16H (kv=16) d_ff=1408/expert, 60 routed experts top-4 +
4 shared experts (shared intermediate 4*1408=5632), vocab 151936.
60 experts are not divisible by the 16-way model axis; the MoE layer pads
the expert dim to 64 for EP (dummy experts receive no tokens — 6% buffer
waste, recorded in DESIGN.md §4 / EXPERIMENTS.md §Perf)."""
from repro.models.base import ArchConfig


def config() -> ArchConfig:
    return ArchConfig(
        name="qwen2-moe-a2.7b", family="moe",
        n_layers=24, d_model=2048, n_heads=16, n_kv_heads=16,
        d_ff=1408, vocab=151936, rope_theta=1_000_000.0,
        n_experts=60, top_k=4, d_ff_expert=1408, shared_expert_ff=5632,
        ep_mode="expert",
    )


def smoke_config() -> ArchConfig:
    return ArchConfig(
        name="qwen2-moe-smoke", family="moe",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
        d_ff=96, vocab=256,
        n_experts=6, top_k=2, d_ff_expert=96, shared_expert_ff=128,
        ep_mode="ffn", attn_chunk=64,
    )
