"""H2O-Danube3-4B [arXiv:2401.16818] — llama+mistral mix with SWA.

24L d_model=3840 32H (kv=8, head_dim=120) d_ff=10240 vocab=32000,
sliding window 4096.  Windowed KV cache is bounded -> this arch RUNS
long_500k (sub-quadratic decode)."""
from repro.models.base import ArchConfig


def config() -> ArchConfig:
    return ArchConfig(
        name="h2o-danube-3-4b", family="dense",
        n_layers=24, d_model=3840, n_heads=32, n_kv_heads=8, head_dim=120,
        d_ff=10240, vocab=32000, window=4096,
    )


def smoke_config() -> ArchConfig:
    return ArchConfig(
        name="h2o-danube-smoke", family="dense",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
        d_ff=128, vocab=256, window=8, attn_chunk=64,
    )
