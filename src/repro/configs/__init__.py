"""Architecture registry: one module per assigned arch (+ the paper's own
pretraining models).  Each module exposes ``config()`` (the exact published
configuration) and ``smoke_config()`` (a reduced same-family config for CPU
smoke tests)."""
from __future__ import annotations

import importlib

ARCH_IDS = [
    "qwen2_moe_a2_7b",
    "qwen3_moe_30b_a3b",
    "internvl2_2b",
    "falcon_mamba_7b",
    "seamless_m4t_medium",
    "phi3_medium_14b",
    "starcoder2_15b",
    "gemma2_2b",
    "h2o_danube_3_4b",
    "zamba2_1_2b",
]

PAPER_IDS = ["mixfp4_114m", "mixfp4_476m"]

_ALIAS = {i.replace("_", "-"): i for i in ARCH_IDS + PAPER_IDS}


def get_arch(name: str):
    """Return the config module for an arch id (dash or underscore form)."""
    mod_name = _ALIAS.get(name, name).replace("-", "_").replace(".", "_")
    return importlib.import_module(f"repro.configs.{mod_name}")


def full_config(name: str):
    return get_arch(name).config()


def smoke_config(name: str):
    return get_arch(name).smoke_config()
