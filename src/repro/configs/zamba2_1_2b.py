"""Zamba2-1.2B [arXiv:2411.15242] — Mamba-2 backbone + shared attn blocks.

38L d_model=2048, ssm_state=64 (Mamba-2/SSD, head_dim 64), with a SHARED
transformer block (32H, head_dim=128, d_ff=8192 on concat(x, x_embed))
applied every 6 layers, vocab=32000.  Hybrid -> RUNS long_500k (SSM states
+ linear-memory shared-attn KV)."""
from repro.models.base import ArchConfig


def config() -> ArchConfig:
    return ArchConfig(
        name="zamba2-1.2b", family="hybrid",
        n_layers=38, d_model=2048, n_heads=32, n_kv_heads=32, head_dim=128,
        d_ff=8192, vocab=32000,
        ssm_state=64, ssm_conv=4, ssm_expand=2, ssm_version=2,
        ssm_head_dim=64, ssm_chunk=64, attn_period=6,
    )


def smoke_config() -> ArchConfig:
    return ArchConfig(
        name="zamba2-smoke", family="hybrid",
        n_layers=3, d_model=64, n_heads=4, n_kv_heads=4, head_dim=32,
        d_ff=128, vocab=256,
        ssm_state=8, ssm_conv=4, ssm_expand=2, ssm_version=2,
        ssm_head_dim=16, ssm_chunk=16, attn_period=2, attn_chunk=64,
    )
