"""Falcon-Mamba-7B [arXiv:2410.05355] — attention-free Mamba-1 stack.

64L d_model=4096, d_inner=8192 (expand 2), ssm_state=16, conv 4,
vocab=65024.  MixFP4 applies to the projection GEMMs; the selective-scan
recurrence is not a GEMM and stays bf16/f32 (DESIGN.md §Arch-applicability).
SSM => O(1)-state decode: this arch RUNS long_500k."""
from repro.models.base import ArchConfig


def config() -> ArchConfig:
    return ArchConfig(
        name="falcon-mamba-7b", family="ssm",
        n_layers=64, d_model=4096, n_heads=1, n_kv_heads=1,
        d_ff=0, vocab=65024,
        ssm_state=16, ssm_conv=4, ssm_expand=2, ssm_version=1,
        ssm_chunk=128,
    )


def smoke_config() -> ArchConfig:
    return ArchConfig(
        name="falcon-mamba-smoke", family="ssm",
        n_layers=2, d_model=64, n_heads=1, n_kv_heads=1,
        d_ff=0, vocab=256,
        ssm_state=4, ssm_conv=4, ssm_expand=2, ssm_version=1,
        ssm_chunk=16,
    )
