"""Qwen3-30B-A3B [hf:Qwen/Qwen3-30B-A3B].

48L d_model=2048 32H (kv=4, head_dim=128) d_ff=768/expert, 128 experts
top-8, QK-norm, vocab 151936.  128 % 16 == 0 -> true expert parallelism."""
from repro.models.base import ArchConfig


def config() -> ArchConfig:
    return ArchConfig(
        name="qwen3-moe-30b-a3b", family="moe",
        n_layers=48, d_model=2048, n_heads=32, n_kv_heads=4, head_dim=128,
        d_ff=768, vocab=151936, rope_theta=1_000_000.0, qk_norm=True,
        n_experts=128, top_k=8, d_ff_expert=768,
        ep_mode="expert",
    )


def smoke_config() -> ArchConfig:
    return ArchConfig(
        name="qwen3-moe-smoke", family="moe",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
        d_ff=96, vocab=256, qk_norm=True,
        n_experts=8, top_k=2, d_ff_expert=96,
        ep_mode="expert", attn_chunk=64,
    )
