"""The paper's 476M Qwen3-style pretraining model (§4.2, Fig. 11).

hidden 1024, 16 query heads, 4 kv heads, intermediate 4096, 18 layers."""
from repro.models.base import ArchConfig


def config() -> ArchConfig:
    return ArchConfig(
        name="mixfp4-476m", family="dense",
        n_layers=18, d_model=1024, n_heads=16, n_kv_heads=4,
        d_ff=4096, vocab=151936, qk_norm=True,
    )


def smoke_config() -> ArchConfig:
    return ArchConfig(
        name="mixfp4-476m-smoke", family="dense",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
        d_ff=128, vocab=256, qk_norm=True, attn_chunk=64,
    )
