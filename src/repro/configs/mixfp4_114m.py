"""The paper's 114M Qwen3-style pretraining model (§4.2, Fig. 10).

hidden 512, 8 query heads, 4 kv heads, intermediate 2048, 9 layers,
QK-norm, RoPE, SwiGLU; seq 2048, global batch 256 in the paper."""
from repro.models.base import ArchConfig


def config() -> ArchConfig:
    return ArchConfig(
        name="mixfp4-114m", family="dense",
        n_layers=9, d_model=512, n_heads=8, n_kv_heads=4,
        d_ff=2048, vocab=151936, qk_norm=True,
    )


def smoke_config() -> ArchConfig:
    return ArchConfig(
        name="mixfp4-114m-smoke", family="dense",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
        d_ff=128, vocab=256, qk_norm=True, attn_chunk=64,
    )
