"""InternVL2-2B [arXiv:2404.16821] — InternViT + InternLM2 backbone.

The brief specifies the transformer BACKBONE only; the vision frontend is a
stub (input_specs provides precomputed patch embeddings).
24L d_model=2048 16H (kv=8) d_ff=8192 vocab=92553."""
from repro.models.base import ArchConfig

N_PATCHES = 256  # precomputed ViT patch embeddings prepended to the text


def config() -> ArchConfig:
    return ArchConfig(
        name="internvl2-2b", family="vlm",
        n_layers=24, d_model=2048, n_heads=16, n_kv_heads=8,
        d_ff=8192, vocab=92553, rope_theta=1_000_000.0,
        n_prefix_embeds=N_PATCHES, frontend="vision",
    )


def smoke_config() -> ArchConfig:
    return ArchConfig(
        name="internvl2-smoke", family="vlm",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
        d_ff=128, vocab=256,
        n_prefix_embeds=8, frontend="vision", attn_chunk=64,
    )
