"""SeamlessM4T-medium [arXiv:2308.11596] — encoder-decoder, multimodal.

12L encoder + 12L decoder, d_model=1024, 16H (kv=16), d_ff=4096,
vocab=256206.  The audio frontend is a stub: input_specs provides
precomputed frame embeddings as encoder input."""
from repro.models.base import ArchConfig


def config() -> ArchConfig:
    return ArchConfig(
        name="seamless-m4t-medium", family="encdec",
        n_layers=12, n_dec_layers=12, d_model=1024,
        n_heads=16, n_kv_heads=16, d_ff=4096, vocab=256206,
        frontend="audio",
    )


def smoke_config() -> ArchConfig:
    return ArchConfig(
        name="seamless-smoke", family="encdec",
        n_layers=2, n_dec_layers=2, d_model=64,
        n_heads=4, n_kv_heads=4, d_ff=128, vocab=256,
        frontend="audio", attn_chunk=64,
    )
