"""Phi-3-medium-14B [arXiv:2404.14219] — dense, RoPE + SwiGLU + GQA.

40L d_model=5120 40H (kv=10, head_dim=128) d_ff=17920 vocab=100352.
40 heads on a 16-way model axis: GSPMD pads activation head dims (DESIGN.md
§3); KV caches shard over sequence so no cache padding."""
from repro.models.base import ArchConfig


def config() -> ArchConfig:
    return ArchConfig(
        name="phi3-medium-14b", family="dense",
        n_layers=40, d_model=5120, n_heads=40, n_kv_heads=10, head_dim=128,
        d_ff=17920, vocab=100352,
    )


def smoke_config() -> ArchConfig:
    return ArchConfig(
        name="phi3-smoke", family="dense",
        n_layers=2, d_model=80, n_heads=5, n_kv_heads=5, head_dim=16,
        d_ff=160, vocab=256, attn_chunk=64,
    )
