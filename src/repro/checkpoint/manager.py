"""Fault-tolerant checkpointing: atomic, async, keep-N, mesh-agnostic.

Design points for 1000+-node operation (DESIGN.md §8):

  * **Atomic**: each checkpoint is written to ``step_XXXX.tmp/`` then renamed;
    a ``manifest.json`` with per-leaf checksums is written LAST, so a crash
    mid-save can never produce a checkpoint that ``latest_step`` will pick.
  * **Async**: ``save`` snapshots device arrays to host then hands the write
    to a background thread — the train loop continues immediately.
  * **Keep-N**: old checkpoints are garbage-collected after a successful
    save.
  * **Mesh-agnostic / elastic**: leaves are stored as full logical arrays
    (npz per leaf group); ``restore`` re-shards onto whatever mesh/sharding
    the *current* job uses — so a run checkpointed on data=16 resumes on
    data=8 (elastic scaling; tested in tests/test_substrate.py).
    On a real multi-host fleet each host would write its addressable shards
    with the same manifest protocol; the logic below is the single-host
    realisation of that design.
"""
from __future__ import annotations

import hashlib
import json
import os
import shutil
import threading
import time

import jax
import ml_dtypes
import numpy as np

__all__ = ["CheckpointManager", "packed_checksums", "verify_packed_tree"]


# ---------------------------------------------------------------------------
# Packed-tree integrity.  The MixFP4 wire format keeps the per-block
# micro-format bit in the SIGN of the E4M3 scale byte, so a single
# corrupted scale byte silently flips a block between E1M2/INT4 decode —
# integrity must be checked per *array*, not just per flattened leaf.
# 0x80 (negative-zero E4M3) is additionally non-canonical by construction:
# the packers never emit it (a zero-magnitude scale byte never carries the
# type bit — the PR-4 canonicalization), so its presence in a scale plane
# is proof of corruption even when the checksum of the corrupted bytes
# self-consistently "verifies".
# ---------------------------------------------------------------------------
_NEG_ZERO_E4M3 = 0x80


def _named_qtensors(tree):
    """Yield ('a/b/c', QTensor) pairs for every QTensor in a nested-dict
    parameter tree (the packed serve/checkpoint layout)."""
    from repro.core import qtensor

    def walk(node, path):
        if isinstance(node, qtensor.QTensor):
            yield "/".join(path) or "<root>", node
        elif isinstance(node, dict):
            for k in sorted(node):
                yield from walk(node[k], path + [str(k)])
        elif isinstance(node, (list, tuple)):
            for j, v in enumerate(node):
                yield from walk(v, path + [str(j)])
    yield from walk(tree, [])


def _sha16(arr) -> str:
    return hashlib.sha256(
        np.asarray(jax.device_get(arr)).tobytes()).hexdigest()[:16]


def packed_checksums(tree) -> dict:
    """Per-array payload/scale digests: {'path': {'payload': sha16,
    'scales': sha16, 'scale32': sha16}} over every QTensor in ``tree``."""
    out = {}
    for name, qt in _named_qtensors(tree):
        entry = {"payload": _sha16(qt.payload), "scales": _sha16(qt.scales)}
        if qt.scale32 is not None:
            entry["scale32"] = _sha16(qt.scale32)
        out[name] = entry
    return out


def verify_packed_tree(tree, checksums: dict | None = None):
    """Validate a restored packed tree.

    * Every scale plane is scanned for the non-canonical 0x80
      negative-zero E4M3 byte (format-bit invariant) — raises ValueError
      naming the offending array.
    * When per-array ``checksums`` (from a ``save_packed`` manifest) are
      given, each array's payload/scale digests are recomputed and
      compared — raises IOError naming the first mismatching array.
    """
    for name, qt in _named_qtensors(tree):
        scales = np.asarray(jax.device_get(qt.scales))
        if scales.dtype == np.uint8 and np.any(scales == _NEG_ZERO_E4M3):
            raise ValueError(
                f"corrupt scale plane in packed array {name!r}: contains "
                f"the non-canonical 0x80 negative-zero E4M3 byte (the "
                "MixFP4 packers never emit it — a zero-magnitude scale "
                "byte never carries the type-in-sign format bit), so the "
                "block would misdecode as the wrong micro-format")
        if checksums is not None:
            want = checksums.get(name)
            if want is None:
                continue        # array added after the checkpoint was cut
            got = {"payload": _sha16(qt.payload), "scales": _sha16(qt.scales)}
            if qt.scale32 is not None and "scale32" in want:
                got["scale32"] = _sha16(qt.scale32)
            for plane, digest in got.items():
                if want.get(plane, digest) != digest:
                    raise IOError(
                        f"packed checksum mismatch on array {name!r} "
                        f"({plane} plane): manifest {want[plane]} != "
                        f"restored {digest}")

# numpy can't round-trip the ML dtypes through .npy; leaves are stored as
# flat uint8 with (shape, dtype) in the manifest.
_DTYPES = {
    "bfloat16": ml_dtypes.bfloat16,
    "float8_e4m3fn": ml_dtypes.float8_e4m3fn,
    "float8_e5m2": ml_dtypes.float8_e5m2,
}


def _resolve_dtype(name: str):
    return _DTYPES.get(name, np.dtype(name))


def _flatten(tree):
    leaves, treedef = jax.tree.flatten(tree)
    return leaves, treedef


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3):
        self.dir = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)
        self._thread: threading.Thread | None = None

    # ------------------------------------------------------------------
    def _step_dir(self, step: int) -> str:
        return os.path.join(self.dir, f"step_{step:010d}")

    def latest_step(self) -> int | None:
        steps = []
        for name in os.listdir(self.dir):
            if name.startswith("step_") and not name.endswith(".tmp"):
                manifest = os.path.join(self.dir, name, "manifest.json")
                if os.path.exists(manifest):
                    steps.append(int(name.split("_")[1]))
        return max(steps) if steps else None

    # ------------------------------------------------------------------
    def save(self, step: int, tree, *, extra: dict | None = None,
             blocking: bool = False):
        """Snapshot to host memory, then write in the background."""
        leaves, treedef = _flatten(tree)
        host_leaves = [np.asarray(jax.device_get(x)) for x in leaves]
        self.wait()  # only one in-flight save

        def write():
            tmp = self._step_dir(step) + ".tmp"
            final = self._step_dir(step)
            os.makedirs(tmp, exist_ok=True)
            manifest = {"step": step, "extra": extra or {}, "leaves": []}
            for i, arr in enumerate(host_leaves):
                path = os.path.join(tmp, f"leaf_{i:05d}.npy")
                raw = np.frombuffer(arr.tobytes(), np.uint8)
                np.save(path, raw)
                digest = hashlib.sha256(arr.tobytes()).hexdigest()[:16]
                manifest["leaves"].append(
                    {"i": i, "shape": list(arr.shape),
                     "dtype": str(arr.dtype), "sha": digest})
            # manifest last => atomicity marker
            with open(os.path.join(tmp, "manifest.json"), "w") as f:
                json.dump(manifest, f)
            if os.path.exists(final):
                shutil.rmtree(final)
            os.rename(tmp, final)
            self._gc()

        if blocking:
            write()
        else:
            self._thread = threading.Thread(target=write, daemon=True)
            self._thread.start()

    def wait(self):
        if self._thread is not None and self._thread.is_alive():
            self._thread.join()
        self._thread = None

    def _gc(self):
        steps = sorted(
            int(n.split("_")[1]) for n in os.listdir(self.dir)
            if n.startswith("step_") and not n.endswith(".tmp")
            and os.path.exists(os.path.join(self.dir, n, "manifest.json")))
        for s in steps[:-self.keep]:
            shutil.rmtree(self._step_dir(s), ignore_errors=True)

    # ------------------------------------------------------------------
    def restore(self, step: int, like_tree, *, shardings=None,
                verify: bool = True):
        """Load ``step`` into the structure of ``like_tree``; if
        ``shardings`` (a matching tree of jax.sharding.Sharding) is given,
        leaves are placed sharded — onto ANY mesh, not necessarily the one
        that saved them (elastic restore)."""
        d = self._step_dir(step)
        with open(os.path.join(d, "manifest.json")) as f:
            manifest = json.load(f)
        leaves, treedef = _flatten(like_tree)
        assert len(leaves) == len(manifest["leaves"]), \
            f"checkpoint has {len(manifest['leaves'])} leaves, tree {len(leaves)}"
        out = []
        shard_leaves = (jax.tree.flatten(shardings)[0]
                        if shardings is not None else [None] * len(leaves))
        for i, meta in enumerate(manifest["leaves"]):
            raw = np.load(os.path.join(d, f"leaf_{i:05d}.npy"))
            arr = np.frombuffer(raw.tobytes(),
                                _resolve_dtype(meta["dtype"])
                                ).reshape(meta["shape"])
            if verify:
                digest = hashlib.sha256(arr.tobytes()).hexdigest()[:16]
                if digest != meta["sha"]:
                    raise IOError(f"checksum mismatch on leaf {i} @ step {step}")
            if shard_leaves[i] is not None:
                out.append(jax.device_put(arr, shard_leaves[i]))
            else:
                out.append(jax.numpy.asarray(arr))
        return jax.tree.unflatten(treedef, out), manifest["extra"]

    def restore_latest(self, like_tree, **kw):
        step = self.latest_step()
        if step is None:
            return None, None, None
        tree, extra = self.restore(step, like_tree, **kw)
        return step, tree, extra

    # ------------------------------------------------------------------
    # Packed-weight checkpoints.  A QTensor is an ordinary pytree, so its
    # payload/scales/scale32 children flow through save/restore like any
    # other leaves; what `restore` cannot invent is the *structure* (layout
    # metadata, dict nesting).  `save_packed` persists that structure as a
    # JSON spec in the manifest, so `restore_packed` rebuilds the full
    # QTensor tree with no caller-provided template — a cold serving
    # process loads 4.5-bit weights straight from disk.
    # ------------------------------------------------------------------
    def save_packed(self, step: int, tree, *, extra: dict | None = None,
                    blocking: bool = True):
        from repro.core import qtensor
        extra = dict(extra or {})
        extra["pytree_spec"] = qtensor.tree_spec(tree)
        # per-ARRAY payload/scale digests (the flat per-leaf shas above
        # can't name which projection went bad)
        extra["packed_checksums"] = packed_checksums(tree)
        self.save(step, tree, extra=extra, blocking=blocking)

    def packed_spec(self, step: int | None = None) -> tuple[int, dict]:
        """(step, JSON pytree spec) from a packed checkpoint's manifest —
        structure only, no leaf bytes read.  A sharded serving process
        uses this to derive per-child ``NamedSharding``s (via
        ``qtensor.tree_like`` + ``distributed.sharding``) *before*
        restoring, so leaves land directly in the sharded layout."""
        if step is None:
            step = self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoint in {self.dir}")
        d = self._step_dir(step)
        with open(os.path.join(d, "manifest.json")) as f:
            spec = json.load(f)["extra"].get("pytree_spec")
        if spec is None:
            raise ValueError(f"step {step} was not written by save_packed "
                             "(no pytree_spec in manifest)")
        return step, spec

    def packed_fingerprint(self, step: int | None = None) -> str:
        """Content fingerprint of a packed checkpoint: sha256 over the
        manifest's per-array ``packed_checksums`` (canonical JSON), or
        over the flat per-leaf shas for pre-packed manifests.  The
        serving journal pins this next to its request records so crash
        recovery can refuse to resume streams against different weight
        bytes (journal <-> checkpoint step pinning)."""
        if step is None:
            step = self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoint in {self.dir}")
        with open(os.path.join(self._step_dir(step),
                               "manifest.json")) as f:
            manifest = json.load(f)
        basis = manifest["extra"].get("packed_checksums") \
            or [leaf["sha"] for leaf in manifest["leaves"]]
        blob = json.dumps(basis, sort_keys=True).encode()
        return hashlib.sha256(blob).hexdigest()[:16]

    def restore_packed(self, step: int | None = None, *,
                       verify_packed: bool = True, **kw):
        """Restore a packed QTensor tree from the manifest spec alone.
        ``shardings=`` (a matching tree, e.g. from
        ``distributed.sharding.packed_restore_shardings``) places each
        payload/scales leaf straight onto its mesh shard.

        ``verify_packed`` (default on) re-derives each array's
        payload/scale digests against the manifest's ``packed_checksums``
        and scans every scale plane for the non-canonical 0x80
        negative-zero E4M3 byte — a corruption class the digests alone
        cannot catch when the corrupt bytes were what got checksummed."""
        from repro.core import qtensor
        step, spec = self.packed_spec(step)
        like = qtensor.tree_like(spec)
        tree, extra = self.restore(step, like, **kw)
        extra.pop("pytree_spec", None)
        if verify_packed:
            verify_packed_tree(tree, extra.pop("packed_checksums", None))
        else:
            extra.pop("packed_checksums", None)
        return tree, extra
