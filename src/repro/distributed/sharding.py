"""Sharding utilities: spec rewriting, sharding assembly, packed serving.

Three families of helpers live here (docs/sharding.md is the guide):

* **Spec rewriting** for the multi-pod production mesh —
  :func:`prepend_pod` rewrites every occurrence of the logical ``'data'``
  axis to ``('pod', 'data')`` so data parallelism spans pods while
  model/TP stays in-pod on ICI; :func:`sanitize_specs` makes a spec tree
  safe for *explicit* ``jit`` in_shardings, which (unlike internal
  ``with_sharding_constraint``s, where GSPMD pads) demand exact
  divisibility: any dim whose size is not divisible by the product of its
  assigned mesh axes is replicated, over-long specs are truncated to the
  leaf's rank, and short specs are right-padded with ``None``.
* **Train-step assembly** — :func:`make_train_shardings` turns (param
  specs, a batch template) into ``NamedSharding`` trees.
* **Packed serving** — :func:`serve_packed_specs` derives the engine's
  default TP layout for a packed weight tree (column-parallel N-sharding
  for 2-D QTensor stacks, expert-sharding for scan-stacked MoE stacks:
  both keep decode bitwise-identical to single-device, unlike K/row
  sharding which reassociates the reduction), and
  :func:`shard_packed_tree` / :func:`packed_restore_shardings` place a
  live tree / a checkpoint-restore skeleton under those specs with
  payload and scales co-sharded at 16-lane block granularity
  (``QTensor.with_sharding`` enforces the invariant).

:func:`shard_map` is the one version-compat wrapper every packed-operand
collective path uses (``jax.shard_map`` with ``check_vma`` on new jax,
``jax.experimental.shard_map`` with ``check_rep`` on 0.4.x).
"""
from __future__ import annotations

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.core import qtensor

__all__ = [
    "prepend_pod",
    "batch_spec",
    "make_train_shardings",
    "sanitize_specs",
    "shard_map",
    "serve_packed_specs",
    "shard_packed_tree",
    "packed_restore_shardings",
]


def shard_map(f, *, mesh, in_specs, out_specs):
    """Replication-check-off ``shard_map`` across jax versions.

    Every in-repo use replicates operands over the axes a spec omits (the
    bodies are deterministic, so outputs really are replicated there), but
    the static replication checker cannot always prove it — so it is
    disabled, under whichever keyword this jax spells it.
    """
    if hasattr(jax, "shard_map"):  # jax >= 0.6: top-level, check_vma
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=False)
    from jax.experimental.shard_map import shard_map as _shard_map
    return _shard_map(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                      check_rep=False)


def prepend_pod(spec_tree):
    """Rewrite specs for the multi-pod mesh: every occurrence of the 'data'
    axis becomes ('pod', 'data') so DP spans pods.  Model/TP stays in-pod
    (ICI); only gradient reduction crosses the pod axis (DCI)."""
    def rw(spec):
        if spec is None:
            return spec
        parts = []
        for p in spec:
            if p == "data":
                parts.append(("pod", "data"))
            elif isinstance(p, tuple) and "data" in p:
                parts.append(tuple(
                    a for q in p for a in (("pod", "data") if q == "data"
                                           else (q,))))
            else:
                parts.append(p)
        return P(*parts)
    return jax.tree.map(rw, spec_tree,
                        is_leaf=lambda x: isinstance(x, P) or x is None)


def sanitize_specs(spec_tree, sds_tree, mesh):
    """Make a spec tree safe for explicit jit in_shardings against
    ``mesh``: replicate any dim whose size is not divisible by the product
    of its assigned mesh axes (explicit in_shardings demand exact
    divisibility, unlike internal constraints which GSPMD pads), truncate
    spec entries beyond the leaf's rank, and right-pad short specs with
    ``None``.  Tuple entries like ``('pod', 'data')`` divide by the axis
    product; ``None`` specs become fully-replicated ``P()``."""
    sizes = dict(mesh.shape)

    def axis_size(p):
        if p is None:
            return 1
        if isinstance(p, tuple):
            n = 1
            for a in p:
                n *= sizes[a]
            return n
        return sizes[p]

    def fix(spec, sd):
        if spec is None:
            return P()
        parts = list(spec)[: len(sd.shape)]
        parts += [None] * (len(sd.shape) - len(parts))
        for i, p in enumerate(parts):
            if p is not None and sd.shape[i] % axis_size(p) != 0:
                parts[i] = None
        return P(*parts)

    return jax.tree.map(fix, spec_tree, sds_tree,
                        is_leaf=lambda x: isinstance(x, P) or x is None)


def batch_spec(batch_like, multi_pod: bool = False):
    """Shard every batch leaf on dim 0 over the DP axes."""
    axes = ("pod", "data") if multi_pod else ("data",)
    def spec(x):
        return P(axes, *([None] * (x.ndim - 1)))
    return jax.tree.map(spec, batch_like)


def make_train_shardings(mesh, param_specs, batch_like, multi_pod=False):
    """NamedShardings for (params, batch) on ``mesh``."""
    pspecs = prepend_pod(param_specs) if multi_pod else param_specs
    to_sh = lambda s: NamedSharding(mesh, s if s is not None else P())
    param_sh = jax.tree.map(to_sh, pspecs,
                            is_leaf=lambda x: isinstance(x, P) or x is None)
    batch_sh = jax.tree.map(to_sh, batch_spec(batch_like, multi_pod))
    return param_sh, batch_sh


# ---------------------------------------------------------------------------
# Packed serving layout (docs/sharding.md)
# ---------------------------------------------------------------------------
_is_qt = lambda x: isinstance(x, qtensor.QTensor)


def serve_packed_specs(tree, mesh, *, model_axis: str = "model"):
    """Default TP layout for a packed serving weight tree: a logical
    ``PartitionSpec`` per QTensor leaf (``P()`` — replicated — for dense
    leaves: embeddings/norms are the paper's quantization exclusions).

    The layout is chosen so sharded decode stays *bitwise-identical* to
    the single-device packed path:

    * 2-D weight (stacks): shard the **N** (output) dim over
      ``model_axis`` — column-parallel; output columns are independent and
      the K tiling is unchanged, so no reduction is reassociated.  K/row
      sharding is supported by the contract (``qmm_sharded`` psums the
      partials) but not chosen by default, precisely because the psum
      reassociates the K reduction.
    * scan-stacked MoE expert stacks (≥2 leading batch dims on the
      children, ``(L, E, K, N)``): shard the **expert** dim — each device
      holds whole packed experts, K/N untouched.

    Dims that would violate 16-lane block granularity (or expert counts
    the axis does not divide) fall back to replication rather than error —
    the same leniency :func:`sanitize_specs` applies to dense specs.
    """
    msize = dict(mesh.shape).get(model_axis, 1)

    def qt_spec(qt):
        nb = qt._n_batch_dims()
        if not isinstance(qt.layout, qtensor.BlockLayout2D):
            return P()  # 1-D (KV-cache style) sharding: open ROADMAP item
        if nb >= 2:  # (L, E, K, N) expert stacks: shard whole experts
            if qt.payload.shape[nb - 1] % msize == 0:
                return P(*[None] * (nb - 1), model_axis, None, None)
            return P()
        np_ = qt.payload.shape[-1]
        if np_ % (msize * qt.layout.bn) == 0:
            return P(*[None] * nb, None, model_axis)
        return P()

    return jax.tree.map(lambda x: qt_spec(x) if _is_qt(x) else P(),
                        tree, is_leaf=_is_qt)


def shard_packed_tree(tree, spec_tree, mesh):
    """Place a packed weight tree onto ``mesh``: QTensor leaves via
    :meth:`QTensor.with_sharding` (payload/scales get co-sharded
    ``NamedSharding``s and the logical spec is recorded in the aux for
    mesh-aware ``qmm`` dispatch), dense leaves replicated (spec ``None``)
    or per their spec."""
    def place(leaf, spec):
        if _is_qt(leaf):
            return leaf.with_sharding(mesh, spec)
        return jax.device_put(
            leaf, NamedSharding(mesh, spec if spec is not None else P()))
    return jax.tree.map(place, tree, spec_tree, is_leaf=_is_qt)


def packed_restore_shardings(like_tree, spec_tree, mesh):
    """Shardings tree for restoring a packed checkpoint *directly* into
    the sharded layout (no replicated intermediate): ``like_tree`` is the
    :func:`repro.core.qtensor.tree_like` skeleton (ShapeDtypeStruct
    children), and every leaf position gets a ``NamedSharding`` —
    QTensor leaves the co-sharded child shardings, dense leaves their
    spec (replicated when ``None``)."""
    def sh(leaf, spec):
        if _is_qt(leaf):
            return leaf.shardings(mesh, spec)
        return NamedSharding(mesh, spec if spec is not None else P())
    return jax.tree.map(sh, like_tree, spec_tree, is_leaf=_is_qt)
