"""Sharding utilities: spec rewriting for the multi-pod mesh and the
train-step sharding assembly."""
from __future__ import annotations

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

__all__ = ["prepend_pod", "batch_spec", "make_train_shardings"]


def prepend_pod(spec_tree):
    """Rewrite specs for the multi-pod mesh: every occurrence of the 'data'
    axis becomes ('pod', 'data') so DP spans pods.  Model/TP stays in-pod
    (ICI); only gradient reduction crosses the pod axis (DCI)."""
    def rw(spec):
        if spec is None:
            return spec
        parts = []
        for p in spec:
            if p == "data":
                parts.append(("pod", "data"))
            elif isinstance(p, tuple) and "data" in p:
                parts.append(tuple(
                    a for q in p for a in (("pod", "data") if q == "data"
                                           else (q,))))
            else:
                parts.append(p)
        return P(*parts)
    return jax.tree.map(rw, spec_tree,
                        is_leaf=lambda x: isinstance(x, P) or x is None)


def sanitize_specs(spec_tree, sds_tree, mesh):
    """Replicate any dim whose size is not divisible by its assigned mesh
    axes (explicit jit in_shardings demand exact divisibility, unlike
    internal constraints which GSPMD pads).  Rank-mismatched trailing spec
    entries are dropped."""
    sizes = dict(mesh.shape)

    def axis_size(p):
        if p is None:
            return 1
        if isinstance(p, tuple):
            n = 1
            for a in p:
                n *= sizes[a]
            return n
        return sizes[p]

    def fix(spec, sd):
        if spec is None:
            return P()
        parts = list(spec)[: len(sd.shape)]
        parts += [None] * (len(sd.shape) - len(parts))
        for i, p in enumerate(parts):
            if p is not None and sd.shape[i] % axis_size(p) != 0:
                parts[i] = None
        return P(*parts)

    return jax.tree.map(fix, spec_tree, sds_tree,
                        is_leaf=lambda x: isinstance(x, P) or x is None)


def batch_spec(batch_like, multi_pod: bool = False):
    """Shard every batch leaf on dim 0 over the DP axes."""
    axes = ("pod", "data") if multi_pod else ("data",)
    def spec(x):
        return P(axes, *([None] * (x.ndim - 1)))
    return jax.tree.map(spec, batch_like)


def make_train_shardings(mesh, param_specs, batch_like, multi_pod=False):
    """NamedShardings for (params, batch) on ``mesh``."""
    pspecs = prepend_pod(param_specs) if multi_pod else param_specs
    to_sh = lambda s: NamedSharding(mesh, s if s is not None else P())
    param_sh = jax.tree.map(to_sh, pspecs,
                            is_leaf=lambda x: isinstance(x, P) or x is None)
    batch_sh = jax.tree.map(to_sh, batch_spec(batch_like, multi_pod))
    return param_sh, batch_sh
