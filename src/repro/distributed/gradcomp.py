"""MixFP4-compressed cross-pod gradient reduction with error feedback.

Beyond-paper distributed-optimization feature (DESIGN.md §9.4): the paper's
own wire format — block-scaled 4-bit payloads + E4M3 scales with the type
bit in the sign position, 4.5 bits/value — is reused to compress the
*cross-pod* hop of gradient all-reduce, the slowest link in a multi-pod
fleet (DCI, not ICI).  Error feedback keeps the quantization bias from
accumulating: the residual (g - Q(g)) is added to the next step's gradient
before compression, which restores convergence to O(exact-SGD) rates.

Under SPMD we express the hierarchical reduce as: in-pod psum (full
precision, cheap ICI) -> MixFP4 QDQ at the pod boundary -> cross-pod psum of
the *quantized* tensor.  The QDQ before the 'pod' psum is what a bandwidth-
limited fabric would ship; collective-bytes accounting in the roofline
counts the pod-axis collective at 4.5/16 of bf16 bytes (see
benchmarks/roofline.py, which rescales pod-axis collective traffic when the
train step declares compression).
"""
from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

from repro.core import quantize as Q

__all__ = ["GradCompressionState", "gradcomp_init", "compressed_grad_reduce",
           "WIRE_BITS_PER_VALUE"]

WIRE_BITS_PER_VALUE = 4.5  # 4-bit payload + 8-bit scale per 16 values


class GradCompressionState(NamedTuple):
    residual: Any  # error-feedback residuals, same tree as grads


def gradcomp_init(grads_like) -> GradCompressionState:
    return GradCompressionState(
        jax.tree.map(lambda g: jnp.zeros(g.shape, jnp.float32), grads_like))


def _qdq_grad(g: jax.Array, key: jax.Array, method: str) -> jax.Array:
    """Block-quantize a gradient leaf for the wire (SR keeps it unbiased)."""
    flat = g.reshape(1, -1).astype(jnp.float32)
    out = Q.qdq(flat, method, block=16, axis=-1, rounding="sr", key=key)
    return out.reshape(g.shape)


def compressed_grad_reduce(grads, state: GradCompressionState,
                           key: jax.Array, *, method: str = "mixfp4",
                           pod_axis: str | None = "pod"):
    """Apply error feedback + MixFP4 QDQ at the pod boundary.

    Inside jit/SPMD the actual psum is implicit (gradients come out of
    jax.grad already summed over DP by the partitioner); what this models —
    and what the wire would carry — is the quantized tensor.  Returns
    (reduced_grads, new_state).
    """
    leaves, treedef = jax.tree.flatten(grads)
    res_leaves = jax.tree.leaves(state.residual)
    out, new_res = [], []
    for i, (g, r) in enumerate(zip(leaves, res_leaves)):
        gc = g.astype(jnp.float32) + r
        gq = _qdq_grad(gc, jax.random.fold_in(key, i), method)
        out.append(gq.astype(g.dtype))
        new_res.append(gc - gq)
    return (jax.tree.unflatten(treedef, out),
            GradCompressionState(jax.tree.unflatten(treedef, new_res)))
