from repro.distributed.gradcomp import (GradCompressionState,
                                        compressed_grad_reduce,
                                        gradcomp_init)
from repro.distributed.sharding import (batch_spec, make_train_shardings,
                                        prepend_pod)
