"""Parse collective traffic out of compiled HLO, trip-count aware.

``cost_analysis()`` reports FLOPs/bytes with while-loop bodies counted ONCE,
and collective bytes not at all.  This module walks the optimized HLO text:

  1. split the module into named computations,
  2. find every while op, extract its trip count from the condition
     computation (scan loops compare the induction variable against a
     constant), and its body/condition computation names,
  3. propagate execution multipliers from ENTRY through while bodies
     (nested loops multiply) and conditional branches (counted once —
     upper bound),
  4. sum operand bytes of every all-gather / all-reduce / reduce-scatter /
     all-to-all / collective-permute, weighted by its computation's
     multiplier, attributing each op to the mesh axes it spans via
     ``replica_groups`` partition size.

The same multiplier map also scales per-computation FLOPs when the caller
supplies them (see launch/costprobe.py for the FLOPs-side accounting).
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field

__all__ = ["CollectiveStats", "parse_collectives", "computation_multipliers",
           "HW"]

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

_SHAPE_RE = re.compile(r"(f64|f32|f16|bf16|f8e4m3fn|f8e5m2|s64|u64|s32|u32|"
                       r"s16|u16|s8|u8|pred|c64|c128)\[([0-9,]*)\]")

_COMP_HDR = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\(")
_WHILE_RE = re.compile(r"while\(.*?\)+.*?condition=%?([\w.\-]+).*?body=%?([\w.\-]+)")
_CALL_RE = re.compile(r"(?:call|fusion)\(.*?\).*?(?:to_apply|calls)=%?([\w.\-]+)")
_COND_RE = re.compile(r"conditional\(")
_BRANCH_RE = re.compile(r"branch_computations=\{([^}]*)\}")
_TO_APPLY = re.compile(r"(?:true_computation|false_computation)=%?([\w.\-]+)")
_GROUPS_RE = re.compile(r"replica_groups=\{\{([^}]*)\}")
# iota form: replica_groups=[n_groups,group_size]<=[N](T(...))?
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]<=")
_KINDS = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
          "collective-permute")


def _shape_bytes(type_str: str) -> int:
    total = 0
    for m in _SHAPE_RE.finditer(type_str):
        n = 1
        if m.group(2):
            for d in m.group(2).split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[m.group(1)]
    return total


def _split_computations(hlo: str) -> dict[str, list[str]]:
    """Computation header lines are top-level (no indent), end with '{', and
    contain '->'; bodies are indented; '}' at column 0 closes."""
    comps: dict[str, list[str]] = {}
    cur = None
    for line in hlo.splitlines():
        stripped = line.strip()
        if (not line.startswith((" ", "\t", "}"))
                and stripped.endswith("{") and "->" in stripped):
            m = _COMP_HDR.match(stripped)
            if m:
                cur = m.group(1)
                comps[cur] = []
                continue
        if stripped == "}" and not line.startswith(" "):
            cur = None
        elif cur is not None:
            comps[cur].append(line)
    return comps


def _entry_name(hlo: str, comps) -> str | None:
    m = re.search(r"^ENTRY\s+%?([\w.\-]+)", hlo, re.M)
    if m:
        return m.group(1)
    return next(iter(comps)) if comps else None


def _trip_count(cond_lines: list[str]) -> int:
    """Scan conditions compare the induction var with a constant bound."""
    consts = []
    for ln in cond_lines:
        for m in re.finditer(r"constant\((\d+)\)", ln):
            consts.append(int(m.group(1)))
    candidates = [c for c in consts if c > 1]
    return max(candidates) if candidates else 1


def computation_multipliers(hlo: str) -> dict[str, float]:
    """comp name -> expected executions per program run."""
    comps = _split_computations(hlo)
    entry = _entry_name(hlo, comps)
    mult: dict[str, float] = {}

    def visit(name: str, m: float):
        if name not in comps:
            return
        mult[name] = mult.get(name, 0.0) + m
        for ln in comps[name]:
            w = _WHILE_RE.search(ln)
            if w:
                cond, body = w.group(1), w.group(2)
                trips = _trip_count(comps.get(cond, []))
                visit(cond, m * (trips + 1))
                visit(body, m * trips)
                continue
            c = _CALL_RE.search(ln)
            if c:
                visit(c.group(1), m)
            b = _BRANCH_RE.search(ln)
            if b:
                for name2 in b.group(1).split(","):
                    visit(name2.strip().lstrip("%"), m)
            for t in _TO_APPLY.finditer(ln):
                visit(t.group(1), m)

    if entry:
        visit(entry, 1.0)
    return mult


@dataclass
class CollectiveStats:
    #: op kind -> executed payload bytes (per device)
    bytes_by_kind: dict = field(default_factory=dict)
    count_by_kind: dict = field(default_factory=dict)
    #: bytes split by the participating group size ("groupsize:N")
    bytes_by_groupsize: dict = field(default_factory=dict)
    total_bytes: int = 0

    def add(self, kind: str, nbytes: float, gsize: int, mult: float):
        b = nbytes * mult
        self.bytes_by_kind[kind] = self.bytes_by_kind.get(kind, 0) + b
        self.count_by_kind[kind] = self.count_by_kind.get(kind, 0) + mult
        key = f"group{gsize}"
        self.bytes_by_groupsize[key] = self.bytes_by_groupsize.get(key, 0) + b
        self.total_bytes += b


def parse_collectives(hlo_text: str) -> CollectiveStats:
    """Trip-count-weighted per-device collective payload bytes."""
    comps = _split_computations(hlo_text)
    mult = computation_multipliers(hlo_text)
    stats = CollectiveStats()
    for cname, lines in comps.items():
        m = mult.get(cname, 0.0)
        if m <= 0:
            continue
        for ln in lines:
            ls = ln.strip()
            mm = re.match(
                r"[%\w.\-]+\s*=\s*(.*?)\s*(all-reduce|all-gather|"
                r"reduce-scatter|all-to-all|collective-permute)"
                r"(-start)?\(", ls)
            if not mm:
                continue
            nbytes = _shape_bytes(mm.group(1))
            if not nbytes:
                continue
            gi = _GROUPS_IOTA_RE.search(ls)
            if gi:
                gsize = int(gi.group(2))
            else:
                g = _GROUPS_RE.search(ls)
                gsize = len(g.group(1).split(",")) if g else 0
            stats.add(mm.group(2), nbytes, gsize, m)
    stats.total_bytes = int(stats.total_bytes)
    return stats


@dataclass(frozen=True)
class HW:
    """TPU v5e per-chip constants (given in the brief)."""
    peak_flops: float = 197e12      # bf16 FLOP/s
    hbm_bw: float = 819e9           # B/s
    ici_bw: float = 50e9            # B/s per link
