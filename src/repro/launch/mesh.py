"""Mesh construction: the TPU-v5e production meshes and the host mesh.

Production (TPU v5e target):

  Single-pod : (data=16, model=16)            = 256 chips
  Multi-pod  : (pod=2, data=16, model=16)     = 512 chips

DP spans pod x data (gradient reduction hierarchical: reduce-scatter in-pod
over ICI, all-reduce across pods over DCI — optionally MixFP4-compressed,
see distributed/gradcomp.py).  TP/EP live on the in-pod 'model' axis.
Multi-pod specs are NOT written by hand — model code says 'data' and
``distributed.sharding.prepend_pod`` rewrites it to ('pod', 'data'), so DP
spans pods while model/TP stays in-pod; specs destined for explicit jit
in_shardings then pass ``distributed.sharding.sanitize_specs``, which
replicates any dim the mesh axes don't divide exactly (GSPMD pads internal
constraints, explicit in_shardings don't).

The host mesh is the same (data, model) axis naming over whatever devices
this host actually has — the mesh for tests, examples, elastic restarts,
and the docs/sharding.md cookbook: code written against
``make_host_mesh(model=N)`` (e.g. sharded packed serving,
``ServeEngine(mesh=...)``) moves to ``make_production_mesh()`` unchanged
because every spec names the same axes.  On CPU, fake N devices with
``XLA_FLAGS=--xla_force_host_platform_device_count=N`` *before* jax
initializes (``launch/serve.py --force-host-devices N`` does this).

Everything here is a FUNCTION so importing this module never touches jax
device state (the dry-run sets XLA_FLAGS before any jax initialisation).
"""
from __future__ import annotations

import jax

__all__ = ["make_production_mesh", "make_host_mesh"]


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(data: int | None = None, model: int = 1):
    """Small (data, model) mesh over whatever devices this host actually
    has (tests, examples, elastic restarts on fewer chips).  ``model=N``
    carves an N-way model axis for host-scale TP — the sharded packed
    serving path (docs/sharding.md) — and the data axis absorbs the
    rest."""
    n = jax.device_count()
    if model < 1 or model > n or n % model:
        raise ValueError(
            f"host has {n} device(s); cannot carve a {model}-way model "
            f"axis (on CPU, fake devices with XLA_FLAGS="
            f"--xla_force_host_platform_device_count=N before jax "
            f"initializes — launch/serve.py --force-host-devices N)")
    if data is None:
        data = n // model
    return jax.make_mesh((data, model), ("data", "model"))
