"""Production meshes (TPU v5e target).

Single-pod : (data=16, model=16)            = 256 chips
Multi-pod  : (pod=2, data=16, model=16)     = 512 chips

DP spans pod x data (gradient reduction hierarchical: reduce-scatter in-pod
over ICI, all-reduce across pods over DCI — optionally MixFP4-compressed,
see distributed/gradcomp.py).  TP/EP live on the in-pod 'model' axis.

Defined as a FUNCTION so importing this module never touches jax device
state (the dry-run sets XLA_FLAGS before any jax initialisation).
"""
from __future__ import annotations

import jax

__all__ = ["make_production_mesh", "make_host_mesh"]


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(data: int | None = None, model: int = 1):
    """Small mesh over whatever devices this host actually has (tests,
    examples, elastic restarts on fewer chips)."""
    n = jax.device_count()
    if data is None:
        data = n // model
    return jax.make_mesh((data, model), ("data", "model"))
