"""Entry-point builders: train_step / prefill_step / serve_step.

These close over (model, cfg, mesh) and are what both the real drivers
(launch/train.py, launch/serve.py) and the dry-run (launch/dryrun.py) lower.
"""
from __future__ import annotations

import functools
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.models.base import ArchConfig, Ctx, build_model
from repro.optim import AdamWConfig, adamw_init, adamw_update, warmup_cosine
from repro.optim.adamw import AdamWState

__all__ = ["TrainState", "make_train_step", "make_prefill_step",
           "make_serve_step", "train_state_specs"]


class TrainState(NamedTuple):
    params: Any
    opt: AdamWState
    step: jax.Array
    key: jax.Array


def train_state_specs(param_specs, *, zero1: bool = False,
                      data_axes=("data",)):
    from repro.optim.adamw import zero1_specs
    mspecs = zero1_specs(param_specs, data_axes) if zero1 else param_specs
    return TrainState(
        params=param_specs,
        opt=AdamWState(step=P(), mu=mspecs, nu=mspecs),
        step=P(), key=P())


def make_train_step(cfg: ArchConfig, mesh=None, *,
                    opt: AdamWConfig = AdamWConfig(),
                    max_lr: float = 1e-3, warmup: int = 100,
                    total_steps: int = 10_000,
                    data_axes=("data",)):
    model = build_model(cfg)

    def train_step(state: TrainState, batch):
        step_key = jax.random.fold_in(state.key, state.step)
        ctx = Ctx(step_key, cfg.quant, mesh=mesh, data_axes=data_axes)
        loss, grads = jax.value_and_grad(
            lambda p: model.loss(p, batch, ctx))(state.params)
        lr = warmup_cosine(state.step, max_lr=max_lr, warmup=warmup,
                           total=total_steps)
        new_params, new_opt, gnorm = adamw_update(
            opt, state.params, state.opt, grads, lr)
        new_state = TrainState(new_params, new_opt, state.step + 1, state.key)
        metrics = {"loss": loss, "grad_norm": gnorm, "lr": lr}
        return new_state, metrics

    return model, train_step


def make_init_state(model, cfg: ArchConfig, seed: int = 0):
    params, specs = model.init(jax.random.PRNGKey(seed))
    state = TrainState(params, adamw_init(params),
                       jnp.zeros((), jnp.int32), jax.random.PRNGKey(seed + 1))
    return state, specs


def make_prefill_step(cfg: ArchConfig, mesh=None, data_axes=("data",)):
    model = build_model(cfg)

    def prefill_step(params, batch, cache):
        ctx = Ctx(jax.random.PRNGKey(0), cfg.quant, mesh=mesh,
                  data_axes=data_axes)
        return model.prefill(params, batch, ctx, cache)

    return model, prefill_step


def make_serve_step(cfg: ArchConfig, mesh=None, data_axes=("data",),
                    *, greedy: bool = True):
    model = build_model(cfg)

    def serve_step(params, tokens, cache, cache_len):
        """One decode step for the whole batch -> (next_tokens, cache)."""
        ctx = Ctx(jax.random.PRNGKey(0), cfg.quant, mesh=mesh,
                  data_axes=data_axes)
        logits, new_cache = model.decode_step(params, tokens, ctx, cache,
                                              cache_len)
        nxt = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return nxt, new_cache

    return model, serve_step
