"""Serving driver: bring up an arch on the local mesh and serve batched
requests through the continuous-batching engine (packed MixFP4 weights).

Usage (CPU demo):
  PYTHONPATH=src python -m repro.launch.serve --arch gemma2-2b --smoke \
      --requests 4 --new-tokens 8
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro import configs
from repro.core.qgemm import QuantConfig
from repro.models.base import build_model, param_count
from repro.serving.engine import Request, ServeEngine


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma2-2b")
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced same-family config (CPU)")
    ap.add_argument("--quant", default="mixfp4")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--max-len", type=int, default=128)
    ap.add_argument("--requests", type=int, default=4)
    ap.add_argument("--new-tokens", type=int, default=8)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--no-pack", action="store_true",
                    help="serve dense bf16 weights through the simulated "
                         "qdq path instead of packed QTensors")
    ap.add_argument("--kv-quant", default=None, choices=["bf16", "mixfp4"],
                    help="hold the KV cache packed (mixfp4: 4.5 bits/value, "
                         "decode through the fused attention kernel); "
                         "default bf16")
    ap.add_argument("--save-weights", default=None, metavar="DIR",
                    help="write the packed QTensor weight tree as a "
                         "checkpoint and exit")
    args = ap.parse_args(argv)

    cfg = (configs.smoke_config(args.arch) if args.smoke
           else configs.full_config(args.arch))
    cfg = cfg.replace(quant=QuantConfig(method=args.quant))
    model = build_model(cfg)
    params, _ = model.init(jax.random.PRNGKey(args.seed))
    print(f"[serve] {cfg.name}: {param_count(params)/1e6:.1f}M params, "
          f"quant={args.quant}")

    engine = ServeEngine(cfg, params, batch_size=args.batch,
                         max_len=args.max_len,
                         pack_weights=not args.no_pack,
                         kv_quant=args.kv_quant)
    del params  # projections now live ONLY as packed QTensors in the engine
    if engine.packed_bytes:
        print(f"[serve] projection weights held as packed QTensors: "
              f"{engine.packed_bytes / 1024:.0f} KiB "
              f"({engine.compression:.2f}x smaller than bf16), served "
              f"through qmm -> W4A16 kernels")
    if engine.kv_quant == "mixfp4":
        # bf16 equivalent: K and V tensors at 2 bytes/value
        bf16_kib = (2 * 2 * engine.batch_size * engine.max_len
                    * cfg.n_layers * cfg.n_kv_heads * cfg.dh) / 1024
        print(f"[serve] packed MixFP4 KV cache: "
              f"{engine.kv_cache_bytes() / 1024:.0f} KiB "
              f"(bf16 would be {bf16_kib:.0f} KiB), decode reads it "
              f"through the fused attention kernel")
    if args.save_weights:
        if args.no_pack:
            ap.error("--save-weights requires packed weights; drop --no-pack "
                     "(the checkpoint format is the packed QTensor tree)")
        engine.save_weights(args.save_weights)
        print(f"[serve] packed QTensor weights checkpointed to "
              f"{args.save_weights}")
        return

    rng = np.random.RandomState(args.seed)
    pending = [Request(uid=i,
                       prompt=rng.randint(0, cfg.vocab, 6).astype(np.int32),
                       max_new_tokens=args.new_tokens)
               for i in range(args.requests)]
    t0, n_tok, active = time.time(), 0, 0
    while pending or active:
        while pending and engine.add_request(pending[0]):
            pending.pop(0)
        out = engine.step()
        n_tok += len(out)
        active = sum(s is not None for s in engine.slots)
    dt = time.time() - t0
    print(f"[serve] {args.requests} requests, {n_tok} tokens, "
          f"{n_tok/max(dt,1e-9):.1f} tok/s")


if __name__ == "__main__":
    main()
