"""Serving driver: bring up an arch on the local mesh and serve batched
requests through the continuous-batching engine (packed MixFP4 weights).

Usage (CPU demo):
  PYTHONPATH=src python -m repro.launch.serve --arch gemma2-2b --smoke \
      --requests 4 --new-tokens 8

W4A4 serving (docs/serving.md) — activations quantized on the fly, every
projection through the W4A4 kernel (both operands on the wire format):
  PYTHONPATH=src python -m repro.launch.serve --arch gemma2-2b --smoke \
      --act-quant mixfp4

Sharded packed serving dryrun (docs/sharding.md) — projections held as
model-axis-sharded QTensors, decode bitwise-identical to single-device:
  PYTHONPATH=src python -m repro.launch.serve --arch gemma2-2b --smoke \
      --force-host-devices 2 --model-parallel 2
"""
from __future__ import annotations

import os
import sys

# --force-host-devices must take effect BEFORE jax initializes (device
# count locks at first init), so it is peeked off argv here — same pattern
# as launch/dryrun.py's module-top XLA_FLAGS override.  Both argparse
# spellings ('--force-host-devices 2' and '--force-host-devices=2') are
# accepted; malformed values are left for argparse to report properly.
def _peek_force_host_devices(argv) -> int | None:
    for i, a in enumerate(argv):
        val = None
        if a == "--force-host-devices" and i + 1 < len(argv):
            val = argv[i + 1]
        elif a.startswith("--force-host-devices="):
            val = a.split("=", 1)[1]
        if val is not None:
            try:
                return int(val)
            except ValueError:
                return None
    return None


_n = _peek_force_host_devices(sys.argv)
if _n is not None:
    os.environ["XLA_FLAGS"] = (
        f"--xla_force_host_platform_device_count={_n} "
        + os.environ.get("XLA_FLAGS", ""))

import argparse
import signal
import threading
import time

import jax
import numpy as np

from repro import configs
from repro.core import qtensor
from repro.core.qgemm import QuantConfig
from repro.launch.mesh import make_host_mesh
from repro.models.base import build_model, param_count
from repro.serving.engine import QueueFullError, Request, ServeEngine
from repro.serving.faults import parse_faults


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma2-2b")
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced same-family config (CPU)")
    ap.add_argument("--quant", default="mixfp4")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--max-len", type=int, default=128)
    ap.add_argument("--requests", type=int, default=4)
    ap.add_argument("--new-tokens", type=int, default=8)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--no-pack", action="store_true",
                    help="serve dense bf16 weights through the simulated "
                         "qdq path instead of packed QTensors")
    ap.add_argument("--kv-quant", default=None, choices=["bf16", "mixfp4"],
                    help="hold the KV cache packed (mixfp4: 4.5 bits/value, "
                         "decode through the fused attention kernel); "
                         "default bf16")
    ap.add_argument("--act-quant", default=None,
                    choices=["bf16", "mixfp4", "mixfp4-2pass",
                             "mixfp4-2pass-rowscale", "mixfp4-qdq"],
                    help="W4A4 serving: quantize decode/prefill activations "
                         "on the fly (type-in-sign E4M3 block scales) and "
                         "run every projection through the W4A4 kernel — "
                         "both GEMM operands on the wire format.  'mixfp4' "
                         "fuses the PER-ROW quantizer into the kernel "
                         "prologue (ONE dispatch per projection; each "
                         "output row a pure function of its own "
                         "activations); 'mixfp4-2pass-rowscale' is the "
                         "explicit quantize_rows(per_row=True)->GEMM "
                         "composition it is bitwise-identical to; "
                         "'mixfp4-2pass' is the legacy per-tensor-scale "
                         "composition (batch-coupled; kept as the A/B "
                         "baseline); 'mixfp4-qdq' is the "
                         "dequantize-then-W4A16 debugging oracle; default "
                         "bf16 (W4A16)")
    ap.add_argument("--act-rht", action="store_true",
                    help="grouped random Hadamard transform on BOTH W4A4 "
                         "GEMM operands (weights rotated at pack time, "
                         "activations in the fused prologue — same "
                         "deterministic signs, so the rotation cancels in "
                         "the dot product while flattening quantization "
                         "outliers; requires --act-quant mixfp4 or "
                         "mixfp4-2pass-rowscale)")
    ap.add_argument("--kv-pool", type=int, default=0, metavar="PAGES",
                    help="serve the packed KV cache as a PAGES-page pool "
                         "with per-request block tables, copy-on-write "
                         "prefix caching (transformers) and LRU eviction "
                         "(serving.kvpool; requires --kv-quant mixfp4). "
                         "Page 0 is the trash page, so usable pages are "
                         "PAGES-1")
    ap.add_argument("--kv-page-len", type=int, default=16, metavar="ROWS",
                    help="rows per KV page (multiple of 16 — the MixFP4 "
                         "block — and must divide --max-len)")
    ap.add_argument("--prefill-buckets", default="auto",
                    choices=["auto", "pow2-64", "off"],
                    help="pad prompts up a pow-2/64-step length ladder so "
                         "admissions reuse one compiled prefill per bucket "
                         "instead of compiling per distinct prompt length "
                         "(transformer families; 'auto' enables it there "
                         "and disables it for SSM/hybrid)")
    ap.add_argument("--prefill-chunk", type=int, default=0, metavar="TOKENS",
                    help="chunked-prefill scheduling (serving.scheduler): "
                         "split each admission's prefill into TOKENS-token "
                         "chunks interleaved with decode steps, so long "
                         "prompts never stall the decode batch by more "
                         "than the chunk budget (transformer families; "
                         "bitwise-identical to whole-prompt prefill and "
                         "ONE compiled prefill shape total; replaces "
                         "--prefill-buckets)")
    ap.add_argument("--http-port", type=int, default=None, metavar="PORT",
                    help="serve over HTTP instead of running the local "
                         "demo drive: POST /generate streams SSE token "
                         "frames (client disconnect cancels the request), "
                         "GET /metrics scrapes Prometheus text, "
                         "GET /healthz is liveness, GET /readyz readiness, "
                         "GET /resume/{uid} re-attaches to a recovered "
                         "stream (serving.server; port 0 binds an "
                         "ephemeral port).  SIGTERM drains gracefully")
    ap.add_argument("--journal-dir", default=None, metavar="DIR",
                    help="crash-safe serving: append-only CRC-per-record "
                         "request journal (admissions, per-step tokens, "
                         "terminal transitions) written through the "
                         "request state machine (serving.journal); a "
                         "restarted process passes --recover to rebuild "
                         "every in-flight stream bitwise")
    ap.add_argument("--journal-sync", default="batch",
                    choices=["always", "batch", "off"],
                    help="journal fsync policy: 'always' per record, "
                         "'batch' once per engine step (default), 'off' "
                         "OS-buffered.  Greedy decode re-derives tokens "
                         "lost to an unsynced tail, so 'batch' still "
                         "resumes bitwise")
    ap.add_argument("--recover", action="store_true",
                    help="replay --journal-dir on startup: every "
                         "non-terminal journaled request re-prefills its "
                         "prompt + token history into fresh slots/pages "
                         "and continues decode bitwise identical to the "
                         "uninterrupted run; clients re-attach at "
                         "GET /resume/{uid}")
    ap.add_argument("--drain-deadline-ms", type=float, default=10000.0,
                    metavar="MS",
                    help="graceful-drain budget on SIGTERM/Ctrl-C: stop "
                         "admissions (readyz flips to 'draining'), let "
                         "in-flight requests finish within MS, then "
                         "journal the ledger snapshot and exit")
    ap.add_argument("--startup-budget-s", type=float, default=60.0,
                    metavar="S",
                    help="exit nonzero if the engine worker never reaches "
                         "'ready' (answering calls) within S seconds of "
                         "HTTP bind — so an orchestrator's restart loop "
                         "sees a wedged startup instead of hanging")
    ap.add_argument("--max-queue", type=int, default=64, metavar="N",
                    help="bounded admission queue: submissions beyond N "
                         "waiting requests are rejected with backpressure "
                         "(typed reason 'queue_full') instead of growing "
                         "an unbounded backlog")
    ap.add_argument("--deadline-ms", type=float, default=None, metavar="MS",
                    help="default per-request deadline: a request not "
                         "FINISHED within MS of submission lands EXPIRED "
                         "(typed reason 'deadline'), its slot and pool "
                         "pages released")
    ap.add_argument("--ttft-budget-ms", type=float, default=None,
                    metavar="MS",
                    help="default time-to-first-token budget: a request "
                         "with no first token within MS lands EXPIRED "
                         "(reason 'ttft_deadline')")
    ap.add_argument("--inject-faults", default=None, metavar="SEED:SPEC",
                    help="deterministic seeded fault injection at the "
                         "engine's host/device boundaries, e.g. "
                         "'7:decode=nan@3,pool_acquire=deny@p0.1' "
                         "(serving.faults.parse_faults; sites prefill/"
                         "decode/cow_copy/pool_acquire/checkpoint_read/"
                         "journal_write/process_crash, "
                         "kinds error/transient/nan/slow/dispatch/deny). "
                         "The engine then runs on the injector's virtual "
                         "clock")
    ap.add_argument("--save-weights", default=None, metavar="DIR",
                    help="write the packed QTensor weight tree as a "
                         "checkpoint and exit")
    ap.add_argument("--model-parallel", type=int, default=0, metavar="N",
                    help="serve SHARDED packed weights on an N-way model "
                         "axis of the host mesh: payload/scales carry "
                         "model-axis NamedShardings, decode runs the W4A16 "
                         "kernel per shard (docs/sharding.md)")
    ap.add_argument("--force-host-devices", type=int, default=0, metavar="N",
                    help="fake N host devices (CPU demo of the sharded "
                         "path; consumed before jax init, see module top)")
    args = ap.parse_args(argv)

    # flag-conflict checks BEFORE the (expensive) model init
    if args.recover and not args.journal_dir:
        ap.error("--recover replays a request journal; give --journal-dir")
    if args.no_pack:
        if args.model_parallel:
            ap.error("--model-parallel serves sharded PACKED weights; "
                     "drop --no-pack")
        if args.act_quant in ("mixfp4", "mixfp4-2pass",
                              "mixfp4-2pass-rowscale", "mixfp4-qdq"):
            ap.error("--act-quant mixfp4 is the W4A4 path (both operands "
                     "packed); drop --no-pack")
    if args.act_rht and args.act_quant not in ("mixfp4",
                                               "mixfp4-2pass-rowscale"):
        ap.error("--act-rht rotates both W4A4 operands and needs the "
                 "per-row scales; use --act-quant mixfp4 or "
                 "mixfp4-2pass-rowscale")
        if args.save_weights:
            ap.error("--save-weights requires packed weights; drop --no-pack "
                     "(the checkpoint format is the packed QTensor tree)")

    cfg = (configs.smoke_config(args.arch) if args.smoke
           else configs.full_config(args.arch))
    cfg = cfg.replace(quant=QuantConfig(method=args.quant))
    model = build_model(cfg)
    params, _ = model.init(jax.random.PRNGKey(args.seed))
    print(f"[serve] {cfg.name}: {param_count(params)/1e6:.1f}M params, "
          f"quant={args.quant}")

    mesh = None
    if args.model_parallel:
        mesh = make_host_mesh(model=args.model_parallel)
        print(f"[serve] host mesh {dict(mesh.shape)}: sharded packed "
              f"serving (column-parallel projections, expert-sharded MoE "
              f"stacks; decode bitwise-identical to single-device)")
    injector = (parse_faults(args.inject_faults)
                if args.inject_faults else None)
    engine = ServeEngine(cfg, params, batch_size=args.batch,
                         max_len=args.max_len,
                         pack_weights=not args.no_pack,
                         kv_quant=args.kv_quant, act_quant=args.act_quant,
                         act_rht=args.act_rht,
                         mesh=mesh, prefill_buckets=args.prefill_buckets,
                         prefill_chunk=args.prefill_chunk or None,
                         kv_pool=args.kv_pool or None,
                         kv_page_len=args.kv_page_len,
                         max_queue=args.max_queue,
                         deadline_ms=args.deadline_ms,
                         ttft_budget_ms=args.ttft_budget_ms,
                         journal_dir=args.journal_dir,
                         journal_sync=args.journal_sync,
                         faults=injector)
    if args.journal_dir:
        stats = engine.journal.stats
        print(f"[serve] request journal at {args.journal_dir} "
              f"(sync={args.journal_sync}): {stats['records']} record(s) "
              f"on disk, {stats.get('truncated_bytes', 0)} torn/corrupt "
              f"byte(s) truncated")
    if injector is not None:
        print(f"[serve] fault injection armed: seed {injector.seed}, "
              f"{len(injector.rules)} rule(s); engine on the injector's "
              "virtual clock")
    del params  # projections now live ONLY as packed QTensors in the engine
    if mesh is not None:
        shards = sorted({
            str(leaf.payload.sharding.spec)
            for leaf in jax.tree.leaves(
                engine.params,
                is_leaf=lambda x: isinstance(x, qtensor.QTensor))
            if isinstance(leaf, qtensor.QTensor)})
        print(f"[serve] QTensor payload/scales NamedSharding specs: "
              f"{shards}")
    if engine.packed_bytes:
        kern = ("W4A4" if engine.act_quant in ("mixfp4", "mixfp4-2pass",
                                               "mixfp4-2pass-rowscale")
                else "W4A16")
        print(f"[serve] projection weights held as packed QTensors: "
              f"{engine.packed_bytes / 1024:.0f} KiB "
              f"({engine.compression:.2f}x smaller than bf16), served "
              f"through qmm -> {kern} kernels")
    if engine.act_quant == "mixfp4":
        print("[serve] W4A4 fused: the PER-ROW quantizer runs in the W4A4 "
              "kernel's prologue — ONE Pallas dispatch per projection, "
              "full FP4xFP4 MMA analog; each output row is a pure "
              "function of its own activations")
    elif engine.act_quant == "mixfp4-2pass-rowscale":
        print("[serve] W4A4 two-dispatch (per-row scales): "
              "quantize_rows(per_row=True) onto each weight's packed K "
              "grid, then the packed-operand W4A4 kernel (the fused "
              "path's bitwise oracle)")
    elif engine.act_quant == "mixfp4-2pass":
        print("[serve] W4A4 two-dispatch (LEGACY per-tensor scale): "
              "quantize_rows onto each weight's packed K grid, then the "
              "packed-operand W4A4 kernel — batch-coupled; kept as the "
              "A/B baseline for the per-row modes")
    elif engine.act_quant == "mixfp4-qdq":
        print("[serve] W4A4 qdq oracle: same wire bytes, decoded back to "
              "dense rows and served W4A16")
    if engine.act_rht:
        print("[serve] grouped RHT on both W4A4 operands: weights rotated "
              "at pack time, activations in the fused prologue (shared "
              "deterministic signs — the rotation cancels in the dot "
              "product, only quantization statistics change)")
    if engine.kv_quant == "mixfp4":
        # bf16 equivalent: K and V tensors at 2 bytes/value
        bf16_kib = (2 * 2 * engine.batch_size * engine.max_len
                    * cfg.n_layers * cfg.n_kv_heads * cfg.dh) / 1024
        print(f"[serve] packed MixFP4 KV cache: "
              f"{engine.kv_cache_bytes() / 1024:.0f} KiB "
              f"(bf16 would be {bf16_kib:.0f} KiB), decode reads it "
              f"through the fused attention kernel")
    if engine.prefill_chunk:
        print(f"[serve] chunked-prefill scheduler armed: admissions "
              f"prefill {engine.prefill_chunk} tokens/step interleaved "
              f"with decode (ONE compiled prefill shape; bitwise vs "
              f"whole-prompt)")
    if args.save_weights:
        engine.save_weights(args.save_weights)
        print(f"[serve] packed QTensor weights checkpointed to "
              f"{args.save_weights}")
        return

    if args.recover:
        rep = engine.recover()
        print(f"[serve] journal recovery: {rep['replayed_records']} "
              f"record(s) -> {rep['requests']} request(s) "
              f"({rep['already_terminal']} already terminal, "
              f"{rep['resumed']} resumed, {rep['finalized']} finalized "
              f"from history); resumed streams continue bitwise — "
              f"clients re-attach at GET /resume/{{uid}}")

    if args.http_port is not None:
        from repro.serving.server import ServingServer
        with ServingServer(engine, port=args.http_port) as srv:
            # startup budget: the worker loop must be spinning AND the
            # engine must answer a call (first step may be compiling)
            # before we call this process 'ready'; a wedged init exits
            # nonzero so a restart loop can see it
            t0 = time.time()
            ok = srv.worker.ready.wait(args.startup_budget_s)
            if ok:
                try:
                    srv.worker.call(
                        lambda eng: True,
                        timeout=max(0.1, args.startup_budget_s
                                    - (time.time() - t0)))
                except TimeoutError:
                    ok = False
            if not ok:
                print(f"[serve] FATAL: engine not ready within "
                      f"{args.startup_budget_s:.0f}s startup budget")
                sys.exit(1)
            print(f"[serve] HTTP front-end on http://127.0.0.1:{srv.port} "
                  f"— POST /generate (SSE token stream), GET /metrics "
                  f"(Prometheus), GET /healthz (liveness), GET /readyz "
                  f"(readiness), GET /resume/{{uid}}; SIGTERM or Ctrl-C "
                  f"drains gracefully")
            stop = threading.Event()
            signal.signal(signal.SIGTERM, lambda *_: stop.set())
            try:
                while not stop.wait(0.2):
                    pass
            except KeyboardInterrupt:
                pass
            print(f"[serve] draining (deadline "
                  f"{args.drain_deadline_ms:.0f} ms): admissions "
                  f"stopped, in-flight requests finishing")
            rep = srv.drain(args.drain_deadline_ms)
            status = "complete" if rep["drained"] else "hit deadline"
            print(f"[serve] drain {status}: {rep['completed']} request(s) "
                  f"finished, {len(rep['survivors'])} survivor(s) "
                  f"journaled for recovery")
        return

    rng = np.random.RandomState(args.seed)
    # pooled demos share a page-sized "system prompt" across requests so
    # the pool report below actually shows prefix hits
    shared = (rng.randint(0, cfg.vocab, args.kv_page_len).astype(np.int32)
              if args.kv_pool else np.zeros((0,), np.int32))
    pending = [Request(uid=i,
                       prompt=np.concatenate(
                           [shared,
                            rng.randint(0, cfg.vocab, 6).astype(np.int32)]),
                       max_new_tokens=args.new_tokens)
               for i in range(args.requests)]
    t0, n_tok = time.time(), 0
    # requests ride the bounded admission queue: submit until backpressure,
    # then step (step() itself pumps QUEUED requests into free slots,
    # expires deadlines, and crosses the fault boundaries)
    while pending or engine.has_work():
        while pending:
            try:
                engine.submit(pending[0])
            except QueueFullError:
                break
            pending.pop(0)
        n_tok += len(engine.step())
    dt = time.time() - t0
    print(f"[serve] {args.requests} requests, {n_tok} tokens, "
          f"{n_tok/max(dt,1e-9):.1f} tok/s")
    print(f"[serve] prefill compile cache: {engine.admissions} admissions "
          f"-> {engine.prefill_compiles} compiled lengths, "
          f"{engine.prefill_cache_hits} shape-cache hits "
          f"(buckets={engine.prefill_buckets or 'off'})")
    rep = engine.pool_report()
    if rep is not None:
        print(f"[serve] KV pool: {rep['pages_total']} pages x "
              f"{rep['page_len']} rows, peak concurrency "
              f"{engine.max_concurrent}; prefix hits {rep['prefix_hits']} "
              f"pages / {rep['prefix_hit_tokens']} tokens skipped, "
              f"{rep['cow_copies']} COW copies, {rep['evictions']} "
              f"evictions, {rep['alloc_failures']} admission deferrals; "
              f"final occupancy {rep['occupancy']:.2f} "
              f"({rep['pages_cached']} cached / {rep['pages_free']} free)")
    rob = engine.robustness_report()
    states = rob["request_states"]
    print(f"[serve] lifecycle: {states} "
          f"(queue bound {rob['queue']['max_queue']}, deadline "
          f"{args.deadline_ms or 'off'} ms, ttft budget "
          f"{args.ttft_budget_ms or 'off'} ms)")
    notable = {k: v for k, v in rob["counters"].items()
               if k.split(":")[0] in ("failed", "expired", "cancelled",
                                      "rejected") or k.startswith(
                   ("retries", "degraded", "deferred", "injected"))}
    if notable:
        print(f"[serve] robustness counters: {notable}")
    if injector is not None:
        print(f"[serve] injector fired {len(injector.log)} event(s): "
              f"{injector.summary()['by_kind']}")


if __name__ == "__main__":
    main()
