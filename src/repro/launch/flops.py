"""Exact-trip-count FLOP accounting from the jaxpr.

XLA's ``cost_analysis()`` counts while-loop bodies once, which silently
undercounts scanned-layer programs by ~L.  The jaxpr retains structured
control flow with known lengths (lax.scan carries ``length``; lax.map is a
scan), so walking it gives exact FLOP totals for our programs — matmuls at
2*M*N*K, elementwise/reduction/transcendental ops at 1 FLOP/element (the
quantization simulation is elementwise-heavy, so these matter for the
useful-FLOP ratio of EXPERIMENTS.md §Roofline).

Shapes in the outer jaxpr are GLOBAL; shard_map bodies see per-shard shapes
and execute on every device, so their counts are scaled by the mesh size.
The result is the global FLOPs of one step; per-device = total / n_devices
under perfect sharding (documented approximation).
"""
from __future__ import annotations

import math

import jax
import numpy as np

__all__ = ["count_flops", "entry_flops"]

_ELEMENTWISE = {
    "add", "sub", "mul", "div", "rem", "max", "min", "neg", "abs", "sign",
    "exp", "exp2", "log", "log1p", "expm1", "tanh", "logistic", "erf",
    "rsqrt", "sqrt", "pow", "integer_pow", "floor", "ceil", "round",
    "is_finite", "and", "or", "not", "xor", "shift_left",
    "shift_right_logical", "shift_right_arithmetic", "select_n", "clamp",
    "nextafter", "sin", "cos", "atan2", "square",
}
_COMPARE = {"eq", "ne", "lt", "le", "gt", "ge"}
_REDUCE = {"reduce_sum", "reduce_max", "reduce_min", "reduce_prod",
           "reduce_and", "reduce_or", "argmax", "argmin",
           "cumsum", "cumlogsumexp", "cummax", "cumprod", "logsumexp"}
_FREE = {
    "reshape", "broadcast_in_dim", "transpose", "slice", "dynamic_slice",
    "dynamic_update_slice", "concatenate", "convert_element_type",
    "bitcast_convert_type", "gather", "scatter", "scatter-add", "pad",
    "squeeze", "rev", "iota", "copy", "stop_gradient", "device_put",
    "sharding_constraint", "split", "pjit_sharding_constraint", "real",
    "imag", "reduce_precision", "random_seed", "random_wrap", "random_bits",
    "random_unwrap", "random_fold_in", "random_clone", "threefry2x32",
    "rng_bit_generator", "expand_dims", "squeeze", "select_and_scatter_add",
}


def _size(v) -> int:
    try:
        return int(np.prod(v.aval.shape))
    except Exception:
        return 0


def _subjaxprs(params):
    for key in ("jaxpr", "call_jaxpr", "fun_jaxpr", "cond_jaxpr"):
        if key in params:
            sub = params[key]
            yield getattr(sub, "jaxpr", sub)
    if "branches" in params:
        for b in params["branches"]:
            yield getattr(b, "jaxpr", b)
    if "body_jaxpr" in params:
        yield params["body_jaxpr"].jaxpr


def count_flops(jaxpr) -> float:
    total = 0.0
    for eqn in jaxpr.eqns:
        p = eqn.primitive.name
        params = eqn.params
        if p == "dot_general":
            (lc, _), _ = params["dimension_numbers"]
            lhs = eqn.invars[0].aval
            k = 1
            for d in lc:
                k *= lhs.shape[d]
            total += 2.0 * _size(eqn.outvars[0]) * k
        elif p == "conv_general_dilated":
            rhs = eqn.invars[1].aval
            total += 2.0 * _size(eqn.outvars[0]) * int(np.prod(rhs.shape[1:]))
        elif p == "scan":
            inner = count_flops(params["jaxpr"].jaxpr)
            total += params["length"] * inner
        elif p == "while":
            total += count_flops(params["body_jaxpr"].jaxpr)  # lower bound
        elif p == "cond":
            total += max((count_flops(getattr(b, "jaxpr", b))
                          for b in params["branches"]), default=0.0)
        elif p == "shard_map":
            mesh = params.get("mesh")
            n = int(np.prod(list(mesh.shape.values()))) if mesh is not None \
                else 1
            total += n * count_flops(params["jaxpr"])
        elif p in ("sort",):
            n = _size(eqn.invars[0])
            total += n * max(math.log2(max(n, 2)), 1.0)
        elif p in _ELEMENTWISE or p in _COMPARE:
            total += max((_size(o) for o in eqn.outvars), default=0)
        elif p in _REDUCE or p.startswith("reduce_"):
            total += _size(eqn.invars[0])
        elif p in _FREE:
            pass
        else:
            # unknown structured primitive: recurse into any sub-jaxprs
            found = False
            for sub in _subjaxprs(params):
                total += count_flops(sub)
                found = True
            if not found:
                total += max((_size(o) for o in eqn.outvars), default=0)
    return total


def entry_flops(fn, *args) -> float:
    """Global FLOPs of one call of ``fn(*args)`` (args may be SDS)."""
    jaxpr = jax.make_jaxpr(fn)(*args)
    return count_flops(jaxpr.jaxpr)
