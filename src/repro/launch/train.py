"""Production training driver: pjit + checkpoint/restart + elastic resume.

Fault-tolerance story (DESIGN.md §8):
  * --resume auto restores the latest valid checkpoint (atomic manifests
    mean a crash mid-save can never be picked up),
  * SIGTERM/SIGINT trigger a final blocking checkpoint (preemption-safe),
  * the data pipeline is deterministic in (seed, step, shard) — the restored
    step index IS the data cursor, so restarts do not replay or skip data,
  * checkpoints are mesh-agnostic: a run saved on one mesh resumes on
    whatever mesh the restarted job builds (elastic scaling after losing
    nodes),
  * a step-time watchdog flags straggling steps (on a real fleet this feeds
    the controller that evicts slow hosts and triggers the elastic path;
    input stalls are absorbed by the Prefetcher queue).

Usage (CPU example, small config):
  PYTHONPATH=src python -m repro.launch.train --arch mixfp4-114m-smoke \
      --steps 50 --batch 8 --seq 128 --ckpt-dir /tmp/ckpt --resume auto
"""
from __future__ import annotations

import argparse
import os
import signal
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro import configs
from repro.checkpoint import CheckpointManager
from repro.core.qgemm import QuantConfig
from repro.data import DataConfig, make_stream
from repro.data.pipeline import Prefetcher
from repro.distributed.sharding import sanitize_specs
from repro.launch import steps as steps_lib
from repro.launch.mesh import make_host_mesh
from repro.models.base import param_count
from repro.optim import AdamWConfig, adamw_init


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="mixfp4-114m-smoke")
    ap.add_argument("--quant", default="mixfp4")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--warmup", type=int, default=20)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=25)
    ap.add_argument("--resume", default="auto", choices=["auto", "none"])
    ap.add_argument("--data-parallel", type=int, default=0,
                    help="0 = all local devices")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--straggler-factor", type=float, default=2.0)
    ap.add_argument("--log-every", type=int, default=5)
    args = ap.parse_args(argv)

    if args.arch.endswith("-smoke") or args.arch.endswith("_smoke"):
        cfg = configs.smoke_config(args.arch.replace("-smoke", "_smoke")
                                   .replace("_smoke", ""))
    else:
        try:
            cfg = configs.smoke_config(args.arch)
        except Exception:
            cfg = configs.full_config(args.arch)
    cfg = cfg.replace(quant=QuantConfig(method=args.quant))

    mesh = make_host_mesh(data=args.data_parallel or None)
    print(f"[train] arch={cfg.name} quant={args.quant} mesh={dict(mesh.shape)}")

    model, train_step = steps_lib.make_train_step(
        cfg, mesh, opt=AdamWConfig(), max_lr=args.lr, warmup=args.warmup,
        total_steps=args.steps)

    with mesh:
        params, param_specs = model.init(jax.random.PRNGKey(args.seed))
        state = steps_lib.TrainState(
            params, adamw_init(params), jnp.zeros((), jnp.int32),
            jax.random.PRNGKey(args.seed + 1))
        print(f"[train] {param_count(params)/1e6:.1f}M params")

        state_specs = steps_lib.train_state_specs(param_specs, zero1=True)
        state_sds = jax.tree.map(
            lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), state)
        state_sh = jax.tree.map(
            lambda s: NamedSharding(mesh, s),
            sanitize_specs(state_specs, state_sds, mesh),
            is_leaf=lambda x: isinstance(x, P))
        state = jax.tree.map(jax.device_put, state, state_sh)

        step_fn = jax.jit(train_step, in_shardings=(state_sh, None),
                          out_shardings=(state_sh, None),
                          donate_argnums=(0,))

        ckpt = CheckpointManager(args.ckpt_dir, keep=3)
        start_step = 0
        if args.resume == "auto":
            last, restored, extra = ckpt.restore_latest(
                state, shardings=state_sh)
            if last is not None:
                state, start_step = restored, last
                print(f"[train] resumed from step {last} "
                      f"(mesh-agnostic restore)")

        stream = make_stream(DataConfig(
            vocab=cfg.vocab, seq_len=args.seq, batch_per_shard=args.batch,
            seed=args.seed))
        prefetch = Prefetcher(stream, start_step)

        stop = {"now": False}

        def _sig(_s, _f):
            stop["now"] = True
            print("[train] signal received -> checkpoint + exit", flush=True)

        signal.signal(signal.SIGTERM, _sig)
        signal.signal(signal.SIGINT, _sig)

        step_times = []
        step = start_step
        try:
            while step < args.steps and not stop["now"]:
                t0 = time.time()
                step, batch = prefetch.next()
                batch = {k: jnp.asarray(v) for k, v in batch.items()}
                state, metrics = step_fn(state, batch)
                metrics = jax.device_get(metrics)
                dt = time.time() - t0
                step_times.append(dt)
                med = float(np.median(step_times[-20:]))
                if dt > args.straggler_factor * med and len(step_times) > 5:
                    print(f"[train][watchdog] step {step} took {dt:.2f}s "
                          f"(median {med:.2f}s) — straggler flagged",
                          flush=True)
                if step % args.log_every == 0:
                    print(f"[train] step {step} loss={metrics['loss']:.4f} "
                          f"gnorm={metrics['grad_norm']:.3f} "
                          f"lr={metrics['lr']:.2e} {dt:.2f}s", flush=True)
                if args.ckpt_every and (step + 1) % args.ckpt_every == 0:
                    ckpt.save(int(step) + 1, state)
                step += 1
        finally:
            prefetch.close()
            ckpt.save(int(step), state, blocking=True)
            ckpt.wait()
            print(f"[train] checkpointed at step {step}; done", flush=True)


if __name__ == "__main__":
    main()
