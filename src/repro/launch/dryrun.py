import os
os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=512 "
                           + os.environ.get("XLA_EXTRA_FLAGS", ""))
# ^ MUST precede every other import (jax locks device count at first init).

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each cell this lowers the real entry point (train_step / prefill_step /
serve_step) with production shardings on the 16x16 single-pod mesh and the
2x16x16 multi-pod mesh, compiles it, and records:

  * memory_analysis()  — proves the cell fits per-device HBM,
  * cost_analysis()    — HLO FLOPs / bytes for the roofline,
  * collective payload bytes parsed from the optimized HLO,

into artifacts/dryrun/<arch>__<shape>__<mesh>[__<quant>].json, consumed by
benchmarks/roofline.py and EXPERIMENTS.md.

Usage:
  python -m repro.launch.dryrun --arch gemma2-2b --shape train_4k
  python -m repro.launch.dryrun --all [--mesh single,multi] [--quant mixfp4]
"""
import argparse
import json
import math
import time
import traceback

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro import configs
from repro.configs import shapes as shp
from repro.core.qgemm import QuantConfig
from repro.distributed.sharding import prepend_pod, sanitize_specs
from repro.serving.engine import engine_robustness_spec
from repro.launch import steps as steps_lib
from repro.launch.flops import entry_flops
from repro.launch.hlo_analysis import parse_collectives
from repro.launch.mesh import make_production_mesh
from repro.core import qtensor
from repro.models import base as model_base
from repro.models.base import build_model
from repro.optim.adamw import AdamWState

ARTIFACTS = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                         "artifacts", "dryrun")

_is_spec = lambda x: isinstance(x, P)


def _abstract_init(model):
    """(param ShapeDtypeStructs, specs) without allocating."""
    box = {}

    def f():
        v, s = model.init(jax.random.PRNGKey(0))
        box["specs"] = s
        return v

    sds = jax.eval_shape(f)
    return sds, box["specs"]


def _shardings(mesh, spec_tree, sds_tree, multi_pod: bool):
    spec_tree = prepend_pod(spec_tree) if multi_pod else spec_tree
    spec_tree = sanitize_specs(spec_tree, sds_tree, mesh)
    return jax.tree.map(lambda s: NamedSharding(mesh, s),
                        spec_tree, is_leaf=_is_spec)


def _batch_specs(batch_sds, data_axes, data_size: int):
    def spec(sd):
        if sd.shape and sd.shape[0] % data_size == 0:
            return P(data_axes, *([None] * (len(sd.shape) - 1)))
        return P(*([None] * len(sd.shape)))  # e.g. batch=1 long_500k
    return jax.tree.map(spec, batch_sds)


def _f32_like(sds_tree):
    return jax.tree.map(
        lambda x: jax.ShapeDtypeStruct(x.shape, jnp.float32), sds_tree)


def packed_weight_report(arch: str, quant_method: str = "mixfp4",
                         overrides: dict | None = None,
                         model_shards: int = 16) -> dict:
    """Abstract (no-allocation) HBM accounting for the serving weight path:
    bytes for the projection weights dense at bf16 vs held as packed 2-D
    QTensors (what ServeEngine actually stores), plus the per-device share
    under the sharded serve layout.  The shard-or-replicate decision per
    leaf is made by ``distributed.sharding.serve_packed_specs`` itself —
    the same function the engine calls — on an abstract skeleton, so the
    report cannot drift from the layout the engine places
    (``model_shards`` is the model-axis TP degree; 16 on the production
    mesh).

    The ``act_quant`` sub-report covers the W4A4 serving mode
    (``ServeEngine(act_quant="mixfp4")``, docs/serving.md): per decoded
    token, the activation rows entering the packable projection GEMMs at
    dense bf16 (W4A16) vs on the wire format ``quantize_rows`` emits on
    each weight's padded K grid (Kp/2 payload + Kp/16 scale bytes + 4 B
    per-tensor scale), and the resulting GEMM arithmetic-intensity
    (FLOP/byte over per-token weight + activation traffic) delta — the
    roofline story of routing both operands through the W4A4 kernel.
    Scan-stacked MoE expert stacks (4-D leaves) count only the
    ``top_k``-routed fraction of their experts per token, for weight
    traffic, activation rows and FLOPs alike (all experts still count
    toward the resident-HBM numbers above)."""
    import types

    from repro.distributed.sharding import serve_packed_specs

    cfg = configs.full_config(arch).replace(
        quant=QuantConfig(method=quant_method))
    if overrides:
        cfg = cfg.replace(**overrides)
    params_sds, _ = _abstract_init(build_model(cfg))
    mesh = types.SimpleNamespace(shape={"model": model_shards})
    stats = {"packed": 0, "dense": 0, "per_device": 0, "replicated": 0,
             "act_bf16": 0.0, "act_packed": 0.0, "flops": 0.0,
             "w_traffic": 0.0}
    proj_grids: set = set()   # distinct packed (Kp, Np) projection grids

    def walk(node):
        if not isinstance(node, dict):
            return
        for k, v in node.items():
            # selection shares pack_projections' predicate so the report
            # counts exactly the leaves ServeEngine converts
            if model_base.is_packable_projection(k, v):
                n_mats = int(math.prod(v.shape[:-2]))
                kdim, ndim = v.shape[-2:]
                struct = qtensor.packed_struct_for_shape(v.shape)
                # the engine's activation grid: qlinear quantizes rows with
                # pad_to = 2 * w.payload.shape[-2], so derive Kp from the
                # same skeleton (one owner for the child-shape math)
                kp = 2 * struct.payload.shape[-2]
                proj_grids.add((kp, struct.payload.shape[-1]))
                leaf = n_mats * qtensor.packed_nbytes_for_shape(
                    (kdim, ndim), qtensor.BlockLayout2D())
                stats["packed"] += leaf
                stats["dense"] += int(math.prod(v.shape)) * 2
                # per-token GEMM traffic: one activation row per matrix
                # (decode batch 1) — except expert stacks ((L, E, K, N),
                # 4-D leaves), where a token routes through top_k of the
                # stored E experts
                active = n_mats
                if v.ndim >= 4 and cfg.top_k:
                    active = n_mats * cfg.top_k / v.shape[-3]
                stats["act_bf16"] += active * kdim * 2
                stats["act_packed"] += active * (kp // 2 + kp // 16 + 4)
                stats["flops"] += active * 2 * kdim * ndim
                stats["w_traffic"] += leaf * (active / n_mats)
                spec = serve_packed_specs({"w": struct}, mesh)["w"]
                if any(e is not None for e in spec):
                    stats["per_device"] += leaf // model_shards
                else:
                    stats["per_device"] += leaf
                    stats["replicated"] += leaf
            else:
                walk(v)

    walk(params_sds)
    packed, dense = stats["packed"], stats["dense"]
    fb16 = stats["flops"] / max(stats["w_traffic"] + stats["act_bf16"], 1)
    f4 = stats["flops"] / max(stats["w_traffic"] + stats["act_packed"], 1)

    # GEMM-path report: kernel dispatches per projection per decoded token
    # (the fused quantize+GEMM prologue folds the W4A4 path to one), plus
    # the cost-model tiler's choices for every distinct packed projection
    # grid at decode (m=1) and prefill (m=512) row counts, for BOTH tuner
    # groups — "w4a16" (default dense-activation serving) and "w4a4" (the
    # act_quant modes) are scored/cached separately and can differ — via
    # the same select_tiles calls qmm makes at serve time, so the report
    # cannot drift.
    from repro.kernels import tuning
    tile_report = {}
    for kp, np_ in sorted(proj_grids):
        for m, tag in ((1, "decode"), (512, "prefill")):
            for path in ("w4a16", "w4a4"):
                ch = tuning.select_tiles(path, m, kp, np_)
                tile_report[f"{tag}_{path}_m{m}_k{kp}_n{np_}"] = {
                    "bm": ch.bm, "bn": ch.bn, "bk": ch.bk,
                    "k_pad": ch.k_pad, "n_pad": ch.n_pad}
    gemm_path = {
        "dispatches_per_projection": {
            "w4a16": 1, "w4a4_fused": 1, "w4a4_2pass": 2},
        "tuned_tiles": tile_report,
    }
    return {"proj_dense_bf16": dense, "proj_packed_qtensor": packed,
            "gemm_path": gemm_path,
            "compression": round(dense / packed, 3) if packed else 1.0,
            "model_shards": model_shards,
            "proj_packed_per_device": stats["per_device"],
            "proj_packed_replicated": stats["replicated"],
            "act_quant": {
                "act_bf16_bytes_per_token": round(stats["act_bf16"]),
                "act_packed_bytes_per_token": round(stats["act_packed"]),
                "act_compression": round(
                    stats["act_bf16"] / stats["act_packed"], 3)
                if stats["act_packed"] else 1.0,
                "proj_flops_per_token": round(stats["flops"]),
                "proj_weight_traffic_per_token": round(stats["w_traffic"]),
                "flop_per_byte_w4a16": round(fb16, 3),
                "flop_per_byte_w4a4": round(f4, 3),
            }}


def kv_pool_report(arch: str, quant_method: str = "mixfp4",
                   overrides: dict | None = None, *, batch: int = 8,
                   max_len: int = 512, num_pages: int | None = None,
                   page_len: int = 16) -> dict | None:
    """Abstract HBM accounting for the paged packed KV pool
    (``ServeEngine(kv_pool=...)``, serving.kvpool): bytes for the KV cache
    dense at bf16, packed per-slot (the fixed-slot engine), and as pool
    page slabs + per-request block tables — plus the capacity story: pages
    per worst-case request and how many such requests the pool can hold
    concurrently (page 0 is the reserved trash page).  ``num_pages``
    defaults to matching the fixed-slot engine's row capacity exactly, so
    the default report isolates the layout cost (table bytes) from any
    over/under-provisioning.  Returns None for families without an
    attention KV cache."""
    cfg = configs.full_config(arch).replace(
        quant=QuantConfig(method=quant_method))
    if overrides:
        cfg = cfg.replace(**overrides)
    if cfg.family in ("dense", "moe", "vlm"):
        n_axis, hkv = cfg.n_layers, cfg.n_kv_heads
    elif cfg.family == "hybrid" and cfg.attn_period:
        n_axis, hkv = build_model(cfg).n_attn_apps(), cfg.n_heads
    else:
        return None
    max_len -= max_len % page_len
    if num_pages is None:
        num_pages = batch * (max_len // page_len) + 1  # +1: trash page
    row = hkv * (cfg.dh // 2 + cfg.dh // 16)      # packed bytes per KV row
    fixed = 2 * (n_axis * batch * max_len * row + 4 * n_axis)
    slabs = 2 * (n_axis * num_pages * page_len * row + 4 * n_axis)
    table = batch * (max_len // page_len) * 4
    bf16 = 2 * n_axis * batch * max_len * hkv * cfg.dh * 2
    per_req = -(-max_len // page_len)             # worst-case request
    return {
        "page_len": page_len, "num_pages": num_pages,
        "kv_bf16_bytes": bf16,
        "kv_packed_fixed_bytes": fixed,
        "kv_pool_bytes": slabs + table,
        "block_table_bytes": table,
        "pool_vs_fixed": round((slabs + table) / fixed, 4) if fixed else 1.0,
        "pages_per_max_len_request": per_req,
        "max_concurrent_max_len_requests": (num_pages - 1) // per_req,
    }


def build_cell(arch: str, shape_name: str, mesh, multi_pod: bool,
               quant_method: str = "mixfp4", overrides: dict | None = None):
    """Returns ((jitted_fn, arg_sds), entry_tag) or (None, skip_reason)."""
    cfg = configs.full_config(arch).replace(
        quant=QuantConfig(method=quant_method))
    if overrides:
        cfg = cfg.replace(**overrides)
    shape = shp.SHAPES[shape_name]
    ok, reason = shp.applicable(cfg, shape_name)
    if not ok:
        return None, reason

    # Sharding regimes (DESIGN.md §4):
    #  * single-pod train: FSDP — global batch 256 shards over all 256
    #    chips (data x model); weights stay model-sharded, gathered per
    #    layer (ZeRO-3 pattern).
    #  * multi-pod train: the pod axis extends DP (batch 256 over
    #    pod x data = 32) and TP keeps the in-pod model axis — batch is
    #    exhausted, so FSDP cannot span 512 chips.
    #  * serving: TP/SP over model; DP over (pod x) data.
    fsdp = shape.kind == "train" and not multi_pod
    model_base.set_fsdp(fsdp)
    model_base.set_sp(shape.kind == "prefill")
    data_axes = ("pod", "data") if multi_pod else ("data",)
    if fsdp:
        data_axes = data_axes + ("model",)
    data_size = mesh.shape["data"] * mesh.shape.get("pod", 1) * (
        mesh.shape["model"] if fsdp else 1)
    model = build_model(cfg)
    params_sds, param_specs = _abstract_init(model)
    batch_sds = shp.token_inputs(cfg, shape)
    batch_specs = _batch_specs(batch_sds, data_axes, data_size)
    batch_sh = jax.tree.map(lambda s: NamedSharding(mesh, s), batch_specs,
                            is_leaf=_is_spec)

    if shape.kind == "train":
        _, train_step = steps_lib.make_train_step(
            cfg, mesh, data_axes=data_axes)
        state_sds = steps_lib.TrainState(
            params=params_sds,
            opt=AdamWState(jax.ShapeDtypeStruct((), jnp.int32),
                           _f32_like(params_sds), _f32_like(params_sds)),
            step=jax.ShapeDtypeStruct((), jnp.int32),
            key=jax.ShapeDtypeStruct((2,), jnp.uint32))
        state_specs = steps_lib.train_state_specs(
            param_specs, zero1=True, data_axes=data_axes)
        in_sh = (_shardings(mesh, state_specs, state_sds, False), batch_sh)
        fn = jax.jit(train_step, in_shardings=in_sh, donate_argnums=(0,))
        return (fn, (state_sds, batch_sds)), "train_step"

    b = shape.batch
    param_sh = _shardings(mesh, param_specs, params_sds, multi_pod)
    cache_sds = jax.eval_shape(lambda: model.init_cache(b, shape.seq))
    cache_sh = _shardings(mesh, model.cache_specs(), cache_sds, multi_pod)

    if shape.kind == "prefill":
        _, prefill_step = steps_lib.make_prefill_step(
            cfg, mesh, data_axes=data_axes)
        in_sh = (param_sh, batch_sh, cache_sh)
        fn = jax.jit(prefill_step, in_shardings=in_sh, donate_argnums=(2,))
        return (fn, (params_sds, batch_sds, cache_sds)), "prefill_step"

    # decode
    _, serve_step = steps_lib.make_serve_step(cfg, mesh, data_axes=data_axes)
    tok_sds = jax.ShapeDtypeStruct((b,), jnp.int32)
    len_sds = jax.ShapeDtypeStruct((), jnp.int32)
    tok_spec = P(data_axes) if b % data_size == 0 else P(None)
    in_sh = (param_sh, NamedSharding(mesh, tok_spec), cache_sh,
             NamedSharding(mesh, P()))
    fn = jax.jit(serve_step, in_shardings=in_sh, donate_argnums=(2,))
    return (fn, (params_sds, tok_sds, cache_sds, len_sds)), "serve_step"


def run_cell(arch: str, shape_name: str, mesh_kind: str,
             quant_method: str = "mixfp4", out_dir: str | None = None,
             overrides: dict | None = None, suffix: str = "",
             kv_pool: int | None = None, kv_page_len: int = 16):
    multi_pod = mesh_kind == "multi"
    mesh = make_production_mesh(multi_pod=multi_pod)
    t0 = time.time()
    with mesh:
        built, tag = build_cell(arch, shape_name, mesh, multi_pod,
                                quant_method, overrides)
        if built is None:
            rec = {"arch": arch, "shape": shape_name, "mesh": mesh_kind,
                   "status": "skipped", "reason": tag, "quant": quant_method}
            _write(rec, out_dir)
            print(f"[dryrun] SKIP {arch} {shape_name} {mesh_kind}: {tag}",
                  flush=True)
            return rec
        fn, args = built
        try:
            flops_exact = float(entry_flops(fn, *args))
        except Exception as e:
            print(f"[dryrun] flops-count failed: {e}", flush=True)
            flops_exact = -1.0
        t_flops = time.time() - t0
        lowered = fn.lower(*args)
        t_lower = time.time() - t0 - t_flops
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_flops - t_lower

        mem = compiled.memory_analysis()
        cost = compiled.cost_analysis()
        hlo = compiled.as_text()
        coll = parse_collectives(hlo)

    n_dev = 512 if multi_pod else 256
    mem_rec = {k: int(getattr(mem, k, 0)) for k in
               ["argument_size_in_bytes", "output_size_in_bytes",
                "temp_size_in_bytes", "generated_code_size_in_bytes"]}
    rec = {
        "arch": arch, "shape": shape_name, "mesh": mesh_kind,
        "entry": tag, "quant": quant_method, "status": "ok",
        "suffix": suffix, "overrides": overrides or {},
        "n_devices": n_dev,
        "lower_s": round(t_lower, 1), "compile_s": round(t_compile, 1),
        "flops_hlo_once": float(cost.get("flops", -1)),
        "flops_exact": flops_exact,
        "bytes_accessed_total": float(cost.get("bytes accessed", -1)),
        "memory": mem_rec,
        "collectives": {
            "bytes_by_kind": coll.bytes_by_kind,
            "count_by_kind": coll.count_by_kind,
            "bytes_by_groupsize": coll.bytes_by_groupsize,
            "total_bytes": coll.total_bytes,
        },
        "weight_bytes": packed_weight_report(arch, quant_method, overrides),
        "kv_pool": kv_pool_report(
            arch, quant_method, overrides,
            batch=shp.SHAPES[shape_name].batch,
            max_len=max(shp.SHAPES[shape_name].seq, kv_page_len),
            num_pages=kv_pool, page_len=kv_page_len),
        # request-lifecycle configuration a production engine of this cell
        # would run under: queue bounds, deadline defaults, and which
        # degradation-ladder rungs are armed (serving.engine)
        "robustness": engine_robustness_spec(kv_pool=kv_pool),
    }
    _write(rec, out_dir)
    print(f"[dryrun] OK {arch} {shape_name} {mesh_kind} "
          f"flops={rec['flops_exact']:.3e} "
          f"coll={coll.total_bytes / 1e6:.1f}MB "
          f"lower={t_lower:.0f}s compile={t_compile:.0f}s", flush=True)
    return rec


def _write(rec, out_dir=None):
    d = os.path.abspath(out_dir or ARTIFACTS)
    os.makedirs(d, exist_ok=True)
    name = f"{rec['arch']}__{rec['shape']}__{rec['mesh']}"
    if rec.get("quant", "mixfp4") != "mixfp4":
        name += f"__{rec['quant']}"
    if rec.get("suffix"):
        name += f"__{rec['suffix']}"
    with open(os.path.join(d, name + ".json"), "w") as f:
        json.dump(rec, f, indent=1)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", default="single,multi")
    ap.add_argument("--quant", default="mixfp4")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default=None)
    ap.add_argument("--set", default="", help="cfg overrides k=v,k=v")
    ap.add_argument("--suffix", default="", help="artifact name suffix")
    ap.add_argument("--kv-pool", type=int, default=0, metavar="PAGES",
                    help="size the paged-KV-pool accounting report "
                         "(kv_pool record field) at PAGES physical pages; "
                         "default sizes the pool to match the fixed-slot "
                         "cache's row capacity")
    ap.add_argument("--kv-page-len", type=int, default=16, metavar="ROWS",
                    help="rows per KV page for the kv_pool report "
                         "(multiple of 16)")
    args = ap.parse_args()
    overrides = {}
    for kv in args.set.split(","):
        if "=" in kv:
            k, v = kv.split("=", 1)
            overrides[k] = type(getattr(
                configs.full_config("gemma2-2b"), k))(eval(v))

    archs = configs.ARCH_IDS if (args.all or not args.arch) \
        else [args.arch.replace("-", "_").replace(".", "_")]
    shapes = list(shp.SHAPES) if (args.all or not args.shape) \
        else [args.shape]
    meshes = args.mesh.split(",")

    failures = []
    for arch in archs:
        for shape_name in shapes:
            for mesh_kind in meshes:
                try:
                    run_cell(arch, shape_name, mesh_kind, args.quant,
                             args.out, overrides, args.suffix,
                             kv_pool=args.kv_pool or None,
                             kv_page_len=args.kv_page_len)
                except Exception as e:
                    traceback.print_exc()
                    failures.append((arch, shape_name, mesh_kind, str(e)))
                    _write({"arch": arch, "shape": shape_name,
                            "mesh": mesh_kind, "status": "error",
                            "quant": args.quant,
                            "error": str(e)[:2000]}, args.out)
    if failures:
        print(f"[dryrun] {len(failures)} FAILURES:")
        for f in failures:
            print("   ", f)
        raise SystemExit(1)
    print("[dryrun] all cells OK")


if __name__ == "__main__":
    main()
