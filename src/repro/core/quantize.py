"""Block-scaled adaptive quantization — Algorithm 1 (MixFP4) and baselines.

One engine implements every format in the paper:

  nvfp4     : candidates = [E2M1]                      (Abecassis et al.)
  nvint4    : candidates = [INT4]                      (paper §2.1 definition)
  four_six  : candidates = [E2M1(6), E2M1(4)]          (Cook et al. 4/6)
  mixfp4    : candidates = [E2M1(6), E1M2]             (the paper)
  mixfp4_e3 : candidates = [E2M1(6), E1M2, E3M0]       (Fig. 4/5 ablation)
  nvfp4_e3  : candidates = [E2M1(6), E3M0]             (Fig. 4 ablation)

Per block (size g along the GEMM reduction axis — or a 2-D tile for weights),
each candidate micro-format is evaluated under its own E4M3 scale
(blockmax / amax_target) and the lowest-MSE candidate wins (Alg. 1 lines 7-23).
The winning index is the type bit T, stored in the sign bit of the E4M3 scale
byte by ``core.pack`` — zero metadata overhead.

Blocks are laid along the *reduction* dimension of the consuming GEMM so that
the block scale factors out of the dot product (Eq. 35): activations/grads are
blocked 1-D along their contraction axis; weights are blocked 2-D (16x16,
Fig. 7) so W and W^T share tiles.

NOTE: the tuple-returning ``block_quantize_1d/2d`` + ``core.pack`` round
trips are superseded by ``core.qtensor.quantize`` -> ``QTensor`` for any
code that *holds* quantized tensors; this module remains the numeric engine
underneath (and the home of ``qdq``/``qdq_2d``, the simulated training
boundary that also covers the non-wire-encodable ablation methods).
"""
from __future__ import annotations

import functools
from typing import NamedTuple, Sequence

import jax
import jax.numpy as jnp

from repro.core import formats, scaling
from repro.core.formats import FP4Format

__all__ = [
    "METHODS",
    "BlockQuantized",
    "adaptive_block_quantize",
    "block_quantize_1d",
    "block_quantize_2d",
    "dequantize_1d",
    "dequantize_2d",
    "qdq",
    "qdq_2d",
    "method_candidates",
]

# method name -> candidate micro-format list (selection order = type-bit value)
METHODS: dict[str, tuple[FP4Format, ...]] = {
    "nvfp4": (formats.E2M1,),
    "nvint4": (formats.INT4,),
    "four_six": (formats.E2M1, formats.E2M1_4),
    "mixfp4": (formats.E2M1, formats.E1M2),
    "mixfp4_e3": (formats.E2M1, formats.E1M2, formats.E3M0),
    "nvfp4_e3": (formats.E2M1, formats.E3M0),
}


def method_candidates(method: str) -> tuple[FP4Format, ...]:
    try:
        return METHODS[method]
    except KeyError:
        raise ValueError(f"unknown quantization method {method!r}; "
                         f"one of {sorted(METHODS)} or 'bf16'") from None


class BlockQuantized(NamedTuple):
    """A block-quantized tensor in structure-of-arrays form.

    values     (..., nblocks, g) — codebook levels (signed), f32
    scale8     (..., nblocks)    — per-block E4M3 scale (f32-valued)
    scale32    ()                — per-tensor FP32 scale
    type_bits  (..., nblocks)    — winning candidate index (uint8)
    """

    values: jax.Array
    scale8: jax.Array
    scale32: jax.Array
    type_bits: jax.Array

    def dequantize(self) -> jax.Array:
        return (self.values * self.scale8[..., None]) * self.scale32


def _quantize_values(y: jax.Array, fmt: FP4Format, rounding: str, key):
    if rounding == "rne":
        return formats.quantize_to_codebook(y, fmt)
    if rounding == "sr":
        if key is None:
            raise ValueError("stochastic rounding requires a PRNG key")
        return formats.stochastic_round_to_codebook(y, fmt, key)
    raise ValueError(f"unknown rounding {rounding!r}")


def adaptive_block_quantize(
    xb: jax.Array,
    candidates: Sequence[FP4Format],
    *,
    rounding: str = "rne",
    key: jax.Array | None = None,
    scale32: jax.Array | None = None,
) -> BlockQuantized:
    """Algorithm 1 on pre-blocked data ``xb`` of shape (..., nblocks, g).

    ``scale32`` may be passed in (e.g. computed on the unpadded tensor);
    otherwise it is derived from ``xb`` itself.
    """
    xb = xb.astype(jnp.float32)
    if scale32 is None:
        scale32 = scaling.tensor_scale(xb)
    # scale applications are reciprocal multiplies, not divides: jit rewrites
    # divides into rcp-multiplies, so divides would make this eager oracle
    # disagree with the jitted Pallas quantizer by 1 ulp at tie boundaries.
    xs = xb * (1.0 / scale32)             # Alg.1 line 5 ("X_FP8" range)
    absmax = jnp.max(jnp.abs(xs), axis=-1)

    qs, s8s, errs = [], [], []
    for i, fmt in enumerate(candidates):
        s8 = scaling.block_scale_e4m3(absmax, fmt.amax_target)
        y = xs * (1.0 / s8)[..., None]
        k = None if key is None else jax.random.fold_in(key, i)
        q = _quantize_values(y, fmt, rounding, k)
        deq = q * s8[..., None]
        err = jnp.mean(jnp.square(deq - xs), axis=-1)
        qs.append(q)
        s8s.append(s8)
        errs.append(err)

    if len(candidates) == 1:
        return BlockQuantized(
            qs[0], s8s[0], scale32,
            jnp.zeros(absmax.shape, jnp.uint8),
        )

    err_stack = jnp.stack(errs)            # (C, ..., nblocks)
    sel = jnp.argmin(err_stack, axis=0)    # ties -> lowest index (E2M1 first)
    q_stack = jnp.stack(qs)
    s8_stack = jnp.stack(s8s)
    q_sel = jnp.take_along_axis(q_stack, sel[None, ..., None], axis=0)[0]
    s8_sel = jnp.take_along_axis(s8_stack, sel[None], axis=0)[0]
    return BlockQuantized(q_sel, s8_sel, scale32, sel.astype(jnp.uint8))


# ---------------------------------------------------------------------------
# 1-D blocking along an arbitrary axis (activations / gradients).
# ---------------------------------------------------------------------------
def _to_blocks_1d(x: jax.Array, block: int, axis: int):
    x = jnp.moveaxis(x, axis, -1)
    n = x.shape[-1]
    pad = (-n) % block
    if pad:
        x = jnp.pad(x, [(0, 0)] * (x.ndim - 1) + [(0, pad)])
    nb = x.shape[-1] // block
    return x.reshape(*x.shape[:-1], nb, block), n, pad


def _from_blocks_1d(xb: jax.Array, orig_n: int, axis: int):
    x = xb.reshape(*xb.shape[:-2], xb.shape[-2] * xb.shape[-1])
    x = x[..., :orig_n]
    return jnp.moveaxis(x, -1, axis)


def block_quantize_1d(
    x: jax.Array,
    method: str,
    *,
    block: int = 16,
    axis: int = -1,
    rounding: str = "rne",
    key: jax.Array | None = None,
) -> tuple[BlockQuantized, int, int]:
    """Quantize with 1-D blocks of size ``block`` along ``axis``.

    Returns (BlockQuantized, original axis length, axis) for dequantization.
    """
    candidates = method_candidates(method)
    s32 = scaling.tensor_scale(x)
    xb, n, _pad = _to_blocks_1d(x, block, axis)
    bq = adaptive_block_quantize(
        xb, candidates, rounding=rounding, key=key, scale32=s32
    )
    return bq, n, axis


def dequantize_1d(bq: BlockQuantized, orig_n: int, axis: int) -> jax.Array:
    return _from_blocks_1d(bq.dequantize(), orig_n, axis)


# ---------------------------------------------------------------------------
# 2-D tile blocking (weights; Fig. 7 "2D block quantization").  A (bm x bn)
# tile shares one scale + one type bit, so W and W^T quantize identically.
# ---------------------------------------------------------------------------
def _to_blocks_2d(w: jax.Array, bm: int, bn: int):
    m, n = w.shape
    pm, pn = (-m) % bm, (-n) % bn
    if pm or pn:
        w = jnp.pad(w, ((0, pm), (0, pn)))
    gm, gn = w.shape[0] // bm, w.shape[1] // bn
    t = w.reshape(gm, bm, gn, bn).transpose(0, 2, 1, 3)  # (gm, gn, bm, bn)
    return t.reshape(gm, gn, bm * bn), (m, n)


def _from_blocks_2d(tb: jax.Array, shape, bm: int, bn: int):
    gm, gn = tb.shape[0], tb.shape[1]
    t = tb.reshape(gm, gn, bm, bn).transpose(0, 2, 1, 3).reshape(gm * bm, gn * bn)
    return t[: shape[0], : shape[1]]


def block_quantize_2d(
    w: jax.Array,
    method: str,
    *,
    block: tuple[int, int] = (16, 16),
    rounding: str = "rne",
    key: jax.Array | None = None,
):
    """Quantize a 2-D weight matrix with (bm x bn) tiles sharing scale + T."""
    assert w.ndim == 2, "block_quantize_2d expects a matrix"
    candidates = method_candidates(method)
    bm, bn = block
    s32 = scaling.tensor_scale(w)
    tb, shape = _to_blocks_2d(w, bm, bn)
    bq = adaptive_block_quantize(
        tb, candidates, rounding=rounding, key=key, scale32=s32
    )
    return bq, shape, block


def dequantize_2d(bq: BlockQuantized, shape, block) -> jax.Array:
    bm, bn = block
    return _from_blocks_2d(bq.dequantize(), shape, bm, bn)


# ---------------------------------------------------------------------------
# Quantize-dequantize ("fake quant") — the GEMM-boundary simulation of Fig. 7.
# ---------------------------------------------------------------------------
def qdq(
    x: jax.Array,
    method: str,
    *,
    block: int = 16,
    axis: int = -1,
    rounding: str = "rne",
    key: jax.Array | None = None,
) -> jax.Array:
    """Quantize-dequantize ``x`` with 1-D blocks; identity for method='bf16'
    (cast through bf16, the paper's high-precision operand dtype)."""
    if method == "bf16":
        return x.astype(jnp.bfloat16).astype(x.dtype)
    bq, n, ax = block_quantize_1d(
        x, method, block=block, axis=axis, rounding=rounding, key=key
    )
    return dequantize_1d(bq, n, ax).astype(x.dtype)


def qdq_2d(
    w: jax.Array,
    method: str,
    *,
    block: tuple[int, int] = (16, 16),
    rounding: str = "rne",
    key: jax.Array | None = None,
    col_chunk: int = 4096,
) -> jax.Array:
    """2-D tile quantize-dequantize for weight matrices.

    Wide matrices are processed in column chunks under lax.map so the ~6
    f32-sized candidate intermediates never materialise for the full matrix
    (bounds per-layer quantization temps on big-FFN archs); the per-tensor
    scale stays global (computed once over w)."""
    if method == "bf16":
        return w.astype(jnp.bfloat16).astype(w.dtype)
    m, n = w.shape
    if n <= col_chunk or n % col_chunk:
        bq, shape, blk = block_quantize_2d(
            w, method, block=block, rounding=rounding, key=key)
        return dequantize_2d(bq, shape, blk).astype(w.dtype)

    candidates = method_candidates(method)
    s32 = scaling.tensor_scale(w)
    nc = n // col_chunk
    bm, bn = block

    def one(i):
        wc = jax.lax.dynamic_slice_in_dim(w, i * col_chunk, col_chunk, axis=1)
        tb, shape = _to_blocks_2d(wc, bm, bn)
        k = None if key is None else jax.random.fold_in(key, i)
        bq = adaptive_block_quantize(tb, candidates, rounding=rounding,
                                     key=k, scale32=s32)
        return _from_blocks_2d(bq.dequantize(), shape, bm, bn).astype(w.dtype)

    chunks = jax.lax.map(one, jnp.arange(nc))       # (nc, m, col_chunk)
    return jnp.moveaxis(chunks, 0, 1).reshape(m, n)
