"""Quantized linear layer with the paper's Fig. 7 training flow.

The three GEMMs of a linear layer run in simulated MixFP4 (green paths of
Fig. 7) while the surrounding tensors stay high-precision:

  FPROP :  Y  = Q(X) @ Q(W)            X blocked 1-D along K, W blocked 2-D
  DGRAD :  dX = Q(dY) @ Q(W)^T         dY blocked 1-D along N; W's 2-D tiles
                                        serve W and W^T identically
  WGRAD :  dW = Q(RHT X)^T @ Q(RHT dY)  RHT with *shared* signs along the
                                        token (contraction) axis; exact in
                                        infinite precision, reshapes block
                                        statistics at 4-bit (Fig. 5)

Gradients are quantized with stochastic rounding (Appendix D); weights use a
2-D (16x16) tile so FPROP and DGRAD see the same quantized weight.  Master
weights are FP32 (kept by the optimizer); GEMM operands are cast to bf16 with
f32 accumulation, modelling the FP4 tensor core's FP32 accumulate.

`method='bf16'` degrades to a plain mixed-precision matmul (the BF16 baseline
of Figs. 10/11).
"""
from __future__ import annotations

import functools
from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import hadamard, quantize as Q

__all__ = ["QuantConfig", "qgemm", "quantized_matmul"]


@dataclass(frozen=True)
class QuantConfig:
    """Static configuration of the quantized GEMM boundary (hashable)."""

    method: str = "mixfp4"          # 'bf16'|'nvfp4'|'nvint4'|'four_six'|'mixfp4'|...
    block: int = 16                  # 1-D block for activations/gradients
    weight_block: tuple = (16, 16)   # 2-D weight tile (Fig. 7)
    fwd_rounding: str = "rne"
    grad_rounding: str = "sr"        # stochastic rounding on gradients (App. D)
    wgrad_rht: bool = True           # RHT on both WGRAD inputs (Fig. 7)
    rht_group: int = 16

    @property
    def is_quantized(self) -> bool:
        return self.method != "bf16"


def _mm(a: jax.Array, b: jax.Array) -> jax.Array:
    """bf16 x bf16 -> f32-accumulated matmul (tensor-core model)."""
    return jax.lax.dot_general(
        a.astype(jnp.bfloat16),
        b.astype(jnp.bfloat16),
        (((a.ndim - 1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )


def _rht_tokens(x: jax.Array, signs: jax.Array, group: int) -> jax.Array:
    """RHT along axis 0 (tokens), zero-padding to a multiple of ``group``.

    Zero rows stay zero under the block-diagonal transform only if padding is
    aligned to whole groups; padded rows sit in their own groups when M is
    group-aligned after padding, and any mixing among padded-zero rows is
    still zero — so the padded region contributes nothing to the dot product.
    """
    m = x.shape[0]
    pad = (-m) % group
    if pad:
        x = jnp.pad(x, ((0, pad),) + ((0, 0),) * (x.ndim - 1))
    return hadamard.rht(x, signs, axis=0, group=group)


@functools.partial(jax.custom_vjp, nondiff_argnums=(0,))
def qgemm(cfg: QuantConfig, x: jax.Array, w: jax.Array, key: jax.Array):
    """y = x @ w through the quantized GEMM boundary.

    x: (..., K) activations (bf16/f32);  w: (K, N) master weight (f32);
    key: PRNG key consumed by stochastic rounding / RHT signs in the backward
    pass (ignored for 'bf16' or pure-RNE configs).
    """
    y, _ = _qgemm_fwd(cfg, x, w, key)
    return y


def _fwd_quantize(cfg: QuantConfig, x, w):
    # cast the FP32 master weight to bf16 at the boundary BEFORE quantizing:
    # under FSDP the per-layer weight all-gather then moves bf16, not f32
    # (negligible vs 4-bit rounding; recorded in EXPERIMENTS.md §Perf)
    w16 = w.astype(jnp.bfloat16)
    if not cfg.is_quantized:
        return x, w16
    xq = Q.qdq(x, cfg.method, block=cfg.block, axis=-1, rounding=cfg.fwd_rounding)
    wq = Q.qdq_2d(w16, cfg.method, block=cfg.weight_block, rounding=cfg.fwd_rounding)
    return xq, wq


def _qgemm_fwd(cfg: QuantConfig, x, w, key):
    xq, wq = _fwd_quantize(cfg, x, w)
    y = _mm(xq, wq).astype(x.dtype)
    return y, (x, w, key)


def _qgemm_bwd(cfg: QuantConfig, res, dy):
    x, w, key = res
    kd, kw1, kw2, ks = jax.random.split(jax.random.fold_in(key, 0x6D78), 4)

    if not cfg.is_quantized:
        dx = jax.lax.dot_general(
            dy.astype(jnp.bfloat16), w.astype(jnp.bfloat16).T,
            (((dy.ndim - 1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32).astype(x.dtype)
        xf = x.reshape(-1, x.shape[-1])
        dyf = dy.reshape(-1, dy.shape[-1])
        dw = _mm(xf.T, dyf).astype(w.dtype)
        return dx, dw, _int_zero(key)

    # ---- DGRAD: dX = Q_sr(dY) @ Q(W)^T  (contraction over N) -------------
    dyq = Q.qdq(dy, cfg.method, block=cfg.block, axis=-1,
                rounding=cfg.grad_rounding, key=kd)
    wq = Q.qdq_2d(w.astype(jnp.bfloat16), cfg.method, block=cfg.weight_block,
                  rounding=cfg.fwd_rounding)
    dx = jax.lax.dot_general(
        dyq.astype(jnp.bfloat16), wq.astype(jnp.bfloat16).T,
        (((dy.ndim - 1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32).astype(x.dtype)

    # ---- WGRAD: dW = Q(RHT X)^T @ Q_sr(RHT dY)  (contraction over tokens) -
    xf = x.reshape(-1, x.shape[-1]).astype(jnp.float32)
    dyf = dy.reshape(-1, dy.shape[-1]).astype(jnp.float32)
    if cfg.wgrad_rht:
        m_pad = xf.shape[0] + ((-xf.shape[0]) % cfg.rht_group)
        signs = hadamard.rht_signs(ks, m_pad)
        xf = _rht_tokens(xf, signs, cfg.rht_group)
        dyf = _rht_tokens(dyf, signs, cfg.rht_group)
    xfq = Q.qdq(xf, cfg.method, block=cfg.block, axis=0,
                rounding=cfg.fwd_rounding)
    dyfq = Q.qdq(dyf, cfg.method, block=cfg.block, axis=0,
                 rounding=cfg.grad_rounding, key=kw2)
    dw = _mm(xfq.T, dyfq).astype(w.dtype)
    return dx, dw, _int_zero(key)


def _int_zero(key):
    """float0 cotangent for the integer PRNG key argument."""
    return np.zeros(np.shape(key), dtype=jax.dtypes.float0)


qgemm.defvjp(_qgemm_fwd, _qgemm_bwd)


def quantized_matmul(x, w, key, cfg: QuantConfig):
    """Convenience wrapper with arguments in data-first order.

    Accepts either a dense master weight (training: the Fig. 7 qdq boundary
    above) or a packed :class:`~repro.core.qtensor.QTensor` (serving: routes
    to ``qtensor.qmm`` and the W4A16/W4A4 Pallas kernels — forward only)."""
    from repro.core import qtensor
    if isinstance(w, qtensor.QTensor):
        return qtensor.qmm(x, w)
    return qgemm(cfg, x, w, key)
