"""Bit-exact packed storage for MixFP4 tensors (Fig. 1 wire format).

Storage layout per 1-D block of g=16 values:
  - payload: 16 x 4-bit nibbles, packed two per byte (8 bytes)
  - scale:   1 byte = {T | e4m3[6:0]}   (type bit in the sign position, §B.3)
  - plus one FP32 per-tensor scale.

Total: 4.5 bits/value + 4 bytes/tensor — identical to NVFP4, proving the
paper's zero-metadata claim at the bit level.  ``unpack`` runs the paper's
Fig. 9 decoder (E2M1 shift path vs E1M2 LUT path selected by T).

DEPRECATED as a public surface: ``core.qtensor.QTensor`` carries the same
wire format with layout metadata attached and is what new code should hold;
``pack_blocks``/``unpack_blocks`` remain as the low-level encoder the
QTensor 1-D path is built on (and for external compatibility).
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core import formats, scaling
from repro.core.quantize import BlockQuantized

__all__ = ["PackedMixFP4", "pack_blocks", "unpack_blocks", "packed_nbytes"]


class PackedMixFP4(NamedTuple):
    """Packed block-quantized tensor (structure-of-arrays).

    payload  (..., nblocks, g//2) uint8 — two FP4 nibbles per byte (lo=even idx)
    scales   (..., nblocks)       uint8 — {T, e4m3[6:0]}
    scale32  ()                   f32   — per-tensor scale
    """

    payload: jax.Array
    scales: jax.Array
    scale32: jax.Array


def pack_blocks(bq: BlockQuantized) -> PackedMixFP4:
    """Encode a BlockQuantized (MixFP4/NVFP4-family) into the wire format.

    ``bq.values`` must lie on the candidate codebook selected by
    ``bq.type_bits`` (0 -> E2M1 lattice, 1 -> effective INT lattice).
    """
    t = bq.type_bits[..., None]  # broadcast over block elements
    nib_e2m1 = formats.e2m1_encode(bq.values)
    nib_e1m2 = formats.e1m2_encode(bq.values)
    nib = jnp.where(t.astype(bool), nib_e1m2, nib_e2m1)
    lo = nib[..., 0::2]
    hi = nib[..., 1::2]
    payload = (lo | (hi << 4)).astype(jnp.uint8)
    scales = scaling.pack_scale_with_type(bq.scale8, bq.type_bits)
    return PackedMixFP4(payload, scales, bq.scale32.astype(jnp.float32))


def unpack_blocks(p: PackedMixFP4, dtype=jnp.float32) -> jax.Array:
    """Fig. 9 decode: nibbles + block-shared T -> unified values; then apply
    the two-level scales.  Returns dequantized blocks (..., nblocks, g)."""
    lo = p.payload & 0xF
    hi = (p.payload >> 4) & 0xF
    nib = jnp.stack([lo, hi], axis=-1).reshape(*p.payload.shape[:-1],
                                               p.payload.shape[-1] * 2)
    scale8, t = scaling.unpack_scale_and_type(p.scales)
    vals = formats.decode_to_e2m2(nib, t[..., None], dtype=jnp.float32)
    out = vals * scale8[..., None] * p.scale32
    return out.astype(dtype)


def packed_nbytes(p: PackedMixFP4) -> int:
    """Wire bytes (payload + block scales + tensor scale)."""
    return int(p.payload.size) + int(p.scales.size) + 4
