"""Fast Walsh-Hadamard transform and the random Hadamard transform (RHT).

The paper applies an RHT to both inputs of the weight-gradient GEMM (Fig. 7,
following the NVFP4 pretraining recipe) and studies RHT's effect on format
selection (Fig. 5).  We implement the transform as a block-diagonal orthogonal
operator: the target axis is split into groups of ``group`` elements (a power
of two, matching the quantization block by default) and each group is hit by
sign-randomized H_g / sqrt(g).

Orthogonality gives exactness of the mixed GEMM in infinite precision:
    (H D x)^T (H D y) = x^T y        for the SAME D and H on both operands,
so the RHT only reshapes the *quantization* statistics (crest factors), which
is precisely the paper's point.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

__all__ = ["fwht", "rht", "rht_signs", "serve_signs"]


def fwht(x: jax.Array, *, axis: int = -1, normalize: bool = True) -> jax.Array:
    """Fast Walsh-Hadamard transform along ``axis`` (length must be 2^k).

    O(n log n) butterfly; the loop unrolls at trace time (log2(n) stages).
    """
    x = jnp.moveaxis(x, axis, -1)
    n = x.shape[-1]
    if n & (n - 1):
        raise ValueError(f"FWHT length must be a power of two, got {n}")
    lead = x.shape[:-1]
    h = 1
    while h < n:
        x = x.reshape(*lead, n // (2 * h), 2, h)
        a = x[..., 0, :]
        b = x[..., 1, :]
        x = jnp.concatenate([(a + b)[..., None, :], (a - b)[..., None, :]],
                            axis=-2).reshape(*lead, n)
        h *= 2
    if normalize:
        x = x * (n ** -0.5)
    return jnp.moveaxis(x, -1, axis)


def rht_signs(key: jax.Array, n: int, dtype=jnp.float32) -> jax.Array:
    """Random +-1 diagonal for the RHT (one sign per position along the axis)."""
    return jax.random.rademacher(key, (n,), dtype=dtype)


@functools.lru_cache(maxsize=None)
def _serve_signs_np(n: int, seed: int) -> np.ndarray:
    rng = np.random.default_rng((seed << 32) | n)
    return np.where(rng.integers(0, 2, n) > 0, 1.0, -1.0).astype(np.float32)


def serve_signs(n: int, seed: int = 0x5147) -> jax.Array:
    """Deterministic ±1 diagonal for the SERVE-TIME activation RHT
    (``act_rht=`` in the engine): a pure function of the packed K length,
    so the weight packer (``pack_projections(act_rht=True)``), ``qlinear``'s
    fused prologue, benchmarks and checkpoints all reconstruct the same
    ``D`` without threading state — any two projections with the same
    padded K share one diagonal, which is harmless (orthogonality cancels
    per GEMM, not across GEMMs).  Host-side numpy so it is reproducible
    across jax versions/backends and never traced."""
    return jnp.asarray(_serve_signs_np(int(n), int(seed)))


def rht(
    x: jax.Array,
    signs: jax.Array,
    *,
    axis: int = -1,
    group: int = 16,
) -> jax.Array:
    """Grouped random Hadamard transform along ``axis``.

    ``signs`` has shape (axis_len,) and MUST be shared by both GEMM operands
    for the transform to cancel in the dot product.  ``group`` is the
    Hadamard size (defaults to the quantization block size g=16).
    """
    x = jnp.moveaxis(x, axis, -1)
    n = x.shape[-1]
    if n % group:
        raise ValueError(f"axis length {n} not divisible by RHT group {group}")
    x = x * signs.astype(x.dtype)
    xg = x.reshape(*x.shape[:-1], n // group, group)
    xg = fwht(xg, axis=-1)
    x = xg.reshape(*x.shape[:-1], n)
    return jnp.moveaxis(x, -1, axis)
