"""Statistical analysis tools: crest factor, QSNR, the Appendix-A crossover.

Reproduces:
  - the crest-factor metric of Fig. 2/3 (per-block peak / RMS),
  - QSNR (Eq. 4),
  - the NVINT4-vs-NVFP4 QSNR crossover kappa* = 2.224277301764024 (Appendix A,
    Eq. 30-33) via the exact closed forms and a numeric root find,
  - per-block format-selection statistics (Fig. 5 machinery).
"""
from __future__ import annotations

import math
from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np
from scipy import optimize, special

from repro.core import quantize as Q

__all__ = [
    "crest_factor",
    "qsnr",
    "r_nvint4",
    "r_nvfp4",
    "qsnr_crossover",
    "selection_fractions",
]


def crest_factor(x: jax.Array, *, block: int = 16, axis: int = -1) -> jax.Array:
    """Within-block crest factor kappa = max|x| / RMS(x) (Eq. 3), per block."""
    xb, _, _ = Q._to_blocks_1d(jnp.asarray(x, jnp.float32), block, axis)
    peak = jnp.max(jnp.abs(xb), axis=-1)
    rms = jnp.sqrt(jnp.mean(jnp.square(xb), axis=-1))
    return jnp.where(rms > 0, peak / rms, 0.0)


def qsnr(x: jax.Array, x_hat: jax.Array) -> jax.Array:
    """QSNR in dB (Eq. 4): -10 log10(||x - x_hat||^2 / ||x||^2)."""
    num = jnp.sum(jnp.square(x - x_hat))
    den = jnp.sum(jnp.square(x))
    return -10.0 * jnp.log10(num / den)


# ---------------------------------------------------------------------------
# Appendix A: analytic relative-MSE models under the Gaussian block assumption.
# ---------------------------------------------------------------------------
_G = 16          # block size
_Q_INT = 7       # exact symmetric INT4 max code (Eq. 7)
_ALPHA = 1.0 / 96.0     # Eq. 18 (M=1)
_BETA = 1.0 / 1728.0    # Eq. 22


def _phi(z: float) -> float:
    return math.exp(-0.5 * z * z) / math.sqrt(2.0 * math.pi)


def _Phi(z: float) -> float:
    return 0.5 * (1.0 + special.erf(z / math.sqrt(2.0)))


def r_nvint4(kappa: float, g: int = _G, q: int = _Q_INT) -> float:
    """Eq. 12: relative MSE of NVINT4 with exact Q=7 and the (g-1)/g refinement."""
    return (kappa / q) ** 2 / 12.0 * (g - 1) / g


def r_nvfp4(kappa: float, g: int = _G) -> float:
    """Eq. 24 with the closed forms of Eq. 26/29 (t = kappa/6)."""
    t = kappa / 6.0
    w_norm = 2.0 * (t * _phi(t) + 1.0 - _Phi(t))       # Eq. 29
    p_sub = 2.0 * _Phi(t) - 1.0                        # Eq. 26
    return _ALPHA * (w_norm - kappa * kappa / g) + _BETA * kappa * kappa * p_sub


def qsnr_crossover(g: int = _G) -> tuple[float, float, float]:
    """Solve Eq. 30 for kappa*; returns (kappa*, R*, QSNR* dB).

    The paper reports kappa* = 2.224277301764024, R* = 0.007888089150418761,
    QSNR* = 21.03028189684982 dB for g=16, Q=7.
    """
    f = lambda k: r_nvint4(k, g) - r_nvfp4(k, g)
    kstar = optimize.brentq(f, 0.5, 6.0, xtol=1e-15, rtol=8.9e-16)
    rstar = r_nvint4(kstar, g)
    return kstar, rstar, -10.0 * math.log10(rstar)


# ---------------------------------------------------------------------------
# Format-selection statistics (Fig. 5): fraction of blocks picking each format.
# ---------------------------------------------------------------------------
def selection_fractions(
    x: jax.Array,
    method: str = "mixfp4",
    *,
    block: int = 16,
    axis: int = -1,
) -> np.ndarray:
    """Quantize ``x`` and return the fraction of blocks selecting each
    candidate format (in METHODS[method] order).

    Wire-packable methods read the type bits straight out of the packed
    scale bytes of a :class:`~repro.core.qtensor.QTensor` (the paper's
    zero-metadata claim, exercised end-to-end); methods with >2 candidates
    or non-encodable lattices fall back to the unpacked engine."""
    from repro.core import qtensor
    ncand = len(Q.method_candidates(method))
    if method in qtensor.PACKABLE_METHODS:
        qt = qtensor.quantize(
            x, qtensor.QuantSpec(method, qtensor.BlockLayout1D(axis, block)))
        sel = (np.asarray(qt.scales) >> 7).ravel()
    else:
        bq, _, _ = Q.block_quantize_1d(x, method, block=block, axis=axis)
        sel = np.asarray(bq.type_bits).ravel()
    return np.bincount(sel, minlength=ncand) / sel.size
