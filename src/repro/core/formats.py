"""FP4 micro-format codebooks and rounding primitives (paper §2.1, §3.1, Table 1).

Every format is described by its *magnitude codebook* — the non-negative values
representable by the 3 payload bits (sign handled separately).  The paper's
micro-formats:

  E2M1 (bias 1)  : {0, 0.5, 1, 1.5, 2, 3, 4, 6}          — NVFP4 payload
  E2M1(4)        : same lattice but AbsMax maps to 4      — Four-over-Six variant
  E1M2 (bias 0)  : {0, 0.5, 1.0, 1.5, 2.0, 2.5, 3.0, 3.5} — uniform; x2 remap == INT4
  E3M0 (bias 3)  : {0, 0.25, 0.5, 1, 2, 4, 8, 16}         — power-of-two levels
  INT4 symmetric : {0, 1, 2, 3, 4, 5, 6, 7}               — NVINT4 payload

Encodings follow Table 1 bit layouts exactly (S.E.M with subnormals at E=0), which
the packing tests verify bit-for-bit.
"""
from __future__ import annotations

import functools
from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np

__all__ = [
    "FP4Format",
    "E2M1",
    "E2M1_4",
    "E1M2",
    "E3M0",
    "INT4",
    "quantize_to_codebook",
    "stochastic_round_to_codebook",
    "e2m1_encode",
    "e2m1_decode",
    "e1m2_encode",
    "e1m2_decode",
    "decode_to_e2m2",
    "E4M3_MAX",
    "E4M3_MAX_E1M2_PATH",
    "PER_TENSOR_DENOM",
    "round_to_e4m3",
    "e4m3_to_bits",
    "bits_to_e4m3",
]

# ---------------------------------------------------------------------------
# E4M3 constants (per-block scale format).  448 = 1.75 * 2^8 is the max finite
# E4M3 magnitude; 384 = 1.5 * 2^8 is used for the E1M2 branch so that
# 6 * 448 == 7 * 384 == 2688 (Algorithm 1, line 4).
# ---------------------------------------------------------------------------
E4M3_MAX = 448.0
E4M3_MAX_E1M2_PATH = 384.0
PER_TENSOR_DENOM = 2688.0  # = 6 * 448 = 7 * 384


@dataclass(frozen=True)
class FP4Format:
    """A 4-bit micro-format: magnitude codebook + AbsMax anchor value."""

    name: str
    #: sorted non-negative representable magnitudes (8 entries incl. 0)
    levels: tuple
    #: block AbsMax maps to this value when computing the per-block scale
    amax_target: float

    @property
    def max_level(self) -> float:
        return self.levels[-1]

    def levels_array(self, dtype=jnp.float32) -> jax.Array:
        return jnp.asarray(self.levels, dtype=dtype)


E2M1 = FP4Format("e2m1", (0.0, 0.5, 1.0, 1.5, 2.0, 3.0, 4.0, 6.0), 6.0)
# Four-over-Six: identical lattice, but the block max is mapped to 4 (values
# above 4 saturate to 6 only via scale rounding).  Used as the "4" candidate of
# the 4/6 baseline (Cook et al., 2025).
E2M1_4 = FP4Format("e2m1_4", (0.0, 0.5, 1.0, 1.5, 2.0, 3.0, 4.0, 6.0), 4.0)
# E1M2 stored magnitudes are {0 .. 3.5}; the fixed x2 decode remap (paper §3.1,
# Fig. 6) makes the *effective* lattice {0 .. 7}.  We work in the effective
# (remapped) domain everywhere outside bit-packing, so levels are integers.
E1M2 = FP4Format("e1m2", (0.0, 1.0, 2.0, 3.0, 4.0, 5.0, 6.0, 7.0), 7.0)
E3M0 = FP4Format("e3m0", (0.0, 0.25, 0.5, 1.0, 2.0, 4.0, 8.0, 16.0), 16.0)
INT4 = FP4Format("int4", (0.0, 1.0, 2.0, 3.0, 4.0, 5.0, 6.0, 7.0), 7.0)


# ---------------------------------------------------------------------------
# Rounding onto a codebook.
# ---------------------------------------------------------------------------
def _midpoints(levels: jax.Array) -> jax.Array:
    return 0.5 * (levels[1:] + levels[:-1])


def quantize_to_codebook(x: jax.Array, fmt: FP4Format) -> jax.Array:
    """Round-to-nearest (ties toward the even *index*, matching hardware RNE on
    the uniform lattices) of |x| onto ``fmt.levels``, preserving sign, with
    saturation at the max level.

    Uses searchsorted over the 7 midpoints — exact for arbitrary (non-uniform)
    codebooks like E2M1/E3M0.
    """
    levels = fmt.levels_array(x.dtype)
    mags = jnp.abs(x)
    mids = _midpoints(levels)
    # side='right' => value exactly at a midpoint rounds DOWN; we fix ties to
    # even below.
    idx = jnp.searchsorted(mids, mags, side="left")
    # tie handling: if mag == midpoint[k], choose the even index of {k, k+1}
    lo = jnp.clip(idx, 0, 6)
    is_tie = mags == mids[lo]
    tie_up = (lo % 2) == 1  # lower index odd -> upper index even -> round up
    idx = jnp.where(is_tie & tie_up, lo + 1, idx)
    idx = jnp.clip(idx, 0, 7)
    q = levels[idx]
    return jnp.sign(x) * q


def stochastic_round_to_codebook(
    x: jax.Array, fmt: FP4Format, key: jax.Array
) -> jax.Array:
    """Stochastic rounding onto ``fmt.levels`` (Appendix D).

    |x| lands between levels L[k] <= |x| <= L[k+1]; round up with probability
    (|x|-L[k]) / (L[k+1]-L[k]).  Unbiased: E[q] == clamp(|x|).
    """
    levels = fmt.levels_array(x.dtype)
    mags = jnp.clip(jnp.abs(x), 0.0, fmt.max_level)
    # index of the lower level: largest k with L[k] <= mags
    k = jnp.clip(jnp.searchsorted(levels, mags, side="right") - 1, 0, 6)
    lo = levels[k]
    hi = levels[k + 1]
    frac = jnp.where(hi > lo, (mags - lo) / (hi - lo), 0.0)
    u = jax.random.uniform(key, x.shape, dtype=x.dtype)
    q = jnp.where(u < frac, hi, lo)
    return jnp.sign(x) * q


# ---------------------------------------------------------------------------
# Bit-level encode/decode (Table 1).  Payload convention: [s | p2 p1 p0].
#   E2M1: e = p2 p1, m = p0, bias 1
#   E1M2: e = p2,    m = p1 p0, bias 0
# These are used by core/pack.py and kernels/; numerics elsewhere operate on
# decoded values.
# ---------------------------------------------------------------------------
_E2M1_DECODE = np.array([0.0, 0.5, 1.0, 1.5, 2.0, 3.0, 4.0, 6.0], np.float32)
# stored E1M2 magnitudes (pre-remap): index == payload
_E1M2_STORED = np.array([0.0, 0.25, 0.5, 0.75, 1.0, 1.25, 1.5, 1.75], np.float32) * 2.0
# effective (x2-remapped) magnitudes used by the compute path
_E1M2_DECODE = np.array([0.0, 1.0, 2.0, 3.0, 4.0, 5.0, 6.0, 7.0], np.float32)


def e2m1_encode(values: jax.Array) -> jax.Array:
    """Signed values already on the E2M1 lattice -> uint8 nibbles [s|p2p1p0]."""
    mags = jnp.abs(values)
    levels = jnp.asarray(_E2M1_DECODE, values.dtype)
    payload = jnp.argmin(jnp.abs(mags[..., None] - levels), axis=-1).astype(jnp.uint8)
    sign = (values < 0).astype(jnp.uint8)
    return (sign << 3) | payload


def e2m1_decode(nibbles: jax.Array, dtype=jnp.float32) -> jax.Array:
    payload = nibbles & 0x7
    sign = (nibbles >> 3) & 0x1
    mags = jnp.asarray(_E2M1_DECODE, dtype)[payload]
    return jnp.where(sign == 1, -mags, mags)


def e1m2_encode(values: jax.Array) -> jax.Array:
    """Signed values on the *effective* (x2-remapped) E1M2 lattice {0..7} ->
    uint8 nibbles.  The stored payload is the E1M2 bit pattern of value/2,
    which by Table 1 is simply the integer level itself.
    """
    mags = jnp.abs(values)
    payload = jnp.clip(jnp.round(mags), 0, 7).astype(jnp.uint8)
    sign = (values < 0).astype(jnp.uint8)
    return (sign << 3) | payload


def e1m2_decode(nibbles: jax.Array, dtype=jnp.float32) -> jax.Array:
    payload = nibbles & 0x7
    sign = (nibbles >> 3) & 0x1
    mags = jnp.asarray(_E1M2_DECODE, dtype)[payload]
    return jnp.where(sign == 1, -mags, mags)


def decode_to_e2m2(nibbles: jax.Array, type_bit: jax.Array, dtype=jnp.float32) -> jax.Array:
    """The paper's Fig. 9 unified decoder: payload + block-shared T -> one
    internal representation.  T=0 -> E2M1 (zero-pad mantissa / shift path),
    T=1 -> E1M2 (LUT path incl. the x2 remap).  Every output is exactly
    representable in E2M2 (and hence in bf16, our TPU internal format).

    ``type_bit`` broadcasts against ``nibbles`` (block-shared).
    """
    v_e2m1 = e2m1_decode(nibbles, dtype)
    v_e1m2 = e1m2_decode(nibbles, dtype)
    return jnp.where(type_bit.astype(bool), v_e1m2, v_e2m1)


# ---------------------------------------------------------------------------
# E4M3 per-block scale helpers.  We lean on jnp.float8_e4m3fn for the rounding
# (XLA convert = RNE with saturation to +-448, no inf) and bitcast for packing.
# ---------------------------------------------------------------------------
def round_to_e4m3(x: jax.Array) -> jax.Array:
    """Round to nearest E4M3 value, returned in f32 (saturating at 448)."""
    return x.astype(jnp.float8_e4m3fn).astype(jnp.float32)


def e4m3_to_bits(x: jax.Array) -> jax.Array:
    """f32 values (assumed E4M3-representable) -> uint8 bit patterns."""
    return jax.lax.bitcast_convert_type(
        x.astype(jnp.float8_e4m3fn), jnp.uint8
    )


def bits_to_e4m3(bits: jax.Array) -> jax.Array:
    """uint8 bit patterns -> f32 values."""
    return jax.lax.bitcast_convert_type(
        bits.astype(jnp.uint8), jnp.float8_e4m3fn
    ).astype(jnp.float32)
