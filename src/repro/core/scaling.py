"""Two-level block scaling (paper §2.1, Fig. 1) and zero-overhead type packing (§B.3).

Level 2: per-tensor FP32 scale   s32 = max|X| / 2688          (Alg. 1 line 4)
Level 1: per-block  E4M3 scale   s8  = E4M3(blockmax / amax_target)

The E4M3 scale is positive by construction, so its sign bit is free — MixFP4
repurposes it as the block-shared format-type bit T (0 = E2M1, 1 = E1M2):

    scale_packed = {T, e4m3_bits[6:0]}          (Eq. 39: decode forces sign=0)
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import formats

__all__ = [
    "tensor_scale",
    "row_scale",
    "block_scale_e4m3",
    "pack_scale_with_type",
    "unpack_scale_and_type",
    "E4M3_MIN_SUBNORMAL",
]

# smallest positive E4M3 value (subnormal): 2^-9.  Used to guard blocks whose
# scale would round to zero (tiny blockmax relative to the tensor max).
E4M3_MIN_SUBNORMAL = 2.0**-9


def tensor_scale(x: jax.Array, denom: float = formats.PER_TENSOR_DENOM) -> jax.Array:
    """Per-tensor FP32 scale s32 = max|X| / denom (Alg. 1 line 4).

    Guarded so an all-zero tensor yields scale 1 (quantizes to zeros).

    Computed as a reciprocal multiply (not a divide): XLA rewrites
    divisions into rcp-multiplies inside jit but not in eager mode, so a
    divide here would make the jitted Pallas quantizer and the eager oracle
    disagree by 1 ulp — the multiply form is identical under both.
    """
    amax = jnp.max(jnp.abs(x)).astype(jnp.float32)
    return jnp.where(amax > 0, amax * jnp.float32(1.0 / denom), 1.0)


def row_scale(x: jax.Array, denom: float = formats.PER_TENSOR_DENOM) -> jax.Array:
    """Per-ROW FP32 scale: s32[i] = max|X[i, :]| / denom, shape (M,).

    The activation-side deviation from Alg. 1 line 4 (+4 B/row of wire
    overhead) that makes each quantized row a pure function of that row —
    the per-tensor reduction couples a row's bytes to its batchmates and
    to padded suffix rows, which breaks bitwise batch independence in
    W4A4 serving.  Same guard (all-zero row -> scale 1 -> zero codes) and
    the same reciprocal-multiply form as :func:`tensor_scale`, so a
    single-row batch gets bit-identical bytes under either scale kind.
    """
    amax = jnp.max(jnp.abs(x), axis=-1).astype(jnp.float32)
    return jnp.where(amax > 0, amax * jnp.float32(1.0 / denom), 1.0)


def block_scale_e4m3(block_absmax: jax.Array, amax_target: float) -> jax.Array:
    """Per-block E4M3 scale (Alg. 1 lines 7 / 12), f32-valued, E4M3-representable.

    E4M3 rounding saturates at 448 and flushes tiny values toward 0; blocks with
    a non-zero max whose scale would round to 0 are clamped to the minimum E4M3
    subnormal so dequantization never divides by zero.  All-zero blocks get
    scale 1 (their payload is all zeros regardless).
    """
    # reciprocal multiply, not divide — keeps jit (rcp-rewritten) and eager
    # execution bit-identical; see tensor_scale.
    raw = block_absmax.astype(jnp.float32) * jnp.float32(1.0 / amax_target)
    # XLA's f8e4m3fn cast maps values beyond ~464 to NaN (no inf encoding);
    # saturate explicitly at the E4M3 max (matters for the 4/6 baseline whose
    # blockmax/4 scale can reach 672).
    raw = jnp.clip(raw, 0.0, formats.E4M3_MAX)
    s = formats.round_to_e4m3(raw)
    s = jnp.where((block_absmax > 0) & (s <= 0), E4M3_MIN_SUBNORMAL, s)
    s = jnp.where(block_absmax > 0, s, 1.0)
    return s


def pack_scale_with_type(scale_f32: jax.Array, type_bits: jax.Array) -> jax.Array:
    """Pack a positive E4M3-representable scale and a per-block type bit into one
    uint8: bit 7 carries T, bits [6:0] the E4M3 magnitude bits.

    Zero extra storage relative to NVFP4's unsigned E4M3 scale byte (§B.3).

    Canonicalized: a zero-magnitude scale byte never carries the type bit
    (byte 0x80 would be a negative-zero E4M3 scale, which the type-in-sign
    decoder reads as an E1M2 block — a zero scale decodes every payload to
    0 regardless of type, so the canonical dead-block byte is 0x00).  Kept
    bit-identical to the Pallas quantizer's ``_pack_scale``.
    """
    bits = formats.e4m3_to_bits(scale_f32)
    mag = bits & 0x7F
    t = (type_bits.astype(jnp.uint8) & 1) << 7
    return jnp.where(mag == 0, mag, mag | t).astype(jnp.uint8)


def unpack_scale_and_type(packed: jax.Array):
    """Inverse of :func:`pack_scale_with_type` (Eq. 39: force sign to 0).

    Returns ``(scale_f32, type_bits uint8)``.
    """
    t = (packed >> 7) & 1
    scale = formats.bits_to_e4m3(packed & 0x7F)
    return scale, t.astype(jnp.uint8)
