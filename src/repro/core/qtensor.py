"""First-class quantized tensors: the one object every MixFP4 path speaks.

``QTensor`` is a frozen dataclass registered as a JAX pytree that carries the
paper's wire format (Fig. 1) directly:

  payload  uint8 — two 4-bit codes per byte
  scales   uint8 — {T | e4m3[6:0]}: per-block E4M3 scale with the type bit in
                   the sign position (§B.3, zero metadata overhead)
  scale32  f32   — per-tensor scale (Alg. 1 line 4)

plus *static* layout metadata (method, 1-D vs 2-D blocking, logical shape and
dtype).  It subsumes the three historical representations — ``BlockQuantized``
(+ positional ``(bq, n, axis)`` / ``(bq, shape, block)`` tuples),
``PackedMixFP4``, and the loose ``(payload, scales, scale32)`` triples the
Pallas kernels take — behind one API:

  qt = quantize(x, QuantSpec("mixfp4", BlockLayout1D(axis=-1)))
  x~ = qt.dequantize()
  y  = qmm(x, qt)            # dispatches to the Pallas kernels or the
                             # qdq-simulated fallback; padding/tiling inside

Because the dynamic children are exactly the packed arrays, a ``QTensor``
costs 4.5 bits/value in HBM wherever it flows — jit, scan (stacked per-layer
weights slice layer-by-layer through the pytree machinery), checkpoints, and
the serving engine all carry the wire format, never a dense copy.

Array layouts (match the kernels in ``kernels/mixfp4_gemm.py``):

  1-D (activations/grads, blocks of ``g`` along ``axis``):
      payload (*lead, Kp//2)  scales (*lead, Kp//g)      Kp = pad16(K)
      (``lead`` = logical shape with ``axis`` moved last, then dropped)
  2-D (weights, (bm x bn) tiles on a (K, N) matrix):
      payload (Kp//2, Np)     scales (Kp//bm, Np//bn)
      two K-consecutive nibbles per byte — the W4A16/W4A4 operand layout.

Methods whose candidate set is wider than {E2M1, E1M2} (``mixfp4_e3``,
``nvfp4_e3``) or whose lattice is not nibble-encodable under the two Fig. 9
decode paths (``four_six``'s max-4 branch, bare ``nvint4``) cannot be
expressed in the wire format; ``quantize`` rejects them — use
``core.quantize.qdq`` for those simulation-only ablations.

Sharding (docs/sharding.md): a QTensor also carries a *logical*
``PartitionSpec`` (``pspec``, static aux).  ``spec()`` derives consistent
child specs for payload/scales/scale32 from a logical weight spec —
payload and scales are always co-sharded, and a spec that would split a
16-lane scale block is rejected — ``with_sharding()`` places the children
under the derived ``NamedSharding``s, and ``qmm_sharded`` runs the W4A16
kernel per shard under ``shard_map`` so TP serving never gathers or
dequantizes a full weight.
"""
from __future__ import annotations

import dataclasses
import math
from dataclasses import dataclass
from typing import Any, Mapping, Sequence, Union

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.core import formats, pack as pack_lib, quantize as Q, scaling

__all__ = [
    "BlockLayout1D",
    "BlockLayout2D",
    "QuantSpec",
    "QTensor",
    "PACKABLE_METHODS",
    "quantize",
    "quantize_rows",
    "from_packed_rows",
    "qmm",
    "qmm_sharded",
    "kn_partitions",
    "stack",
    "packed_nbytes_for_shape",
    "packed_struct_for_shape",
    "tree_spec",
    "tree_like",
]

_G = 16  # paper block size g

# Methods expressible in the 2-path wire format (type bit selects E2M1/E1M2).
PACKABLE_METHODS = ("nvfp4", "mixfp4")


def _pad_to(n: int, m: int) -> int:
    return ((n + m - 1) // m) * m


# ---------------------------------------------------------------------------
# Layout metadata (static / hashable — lives in the pytree aux data)
# ---------------------------------------------------------------------------
@dataclass(frozen=True)
class BlockLayout1D:
    """1-D blocks of ``block`` values along ``axis`` of the logical tensor
    (activations and gradients: blocks lie along the GEMM reduction axis)."""

    axis: int = -1
    block: int = _G


@dataclass(frozen=True)
class BlockLayout2D:
    """(bm x bn) tiles sharing one scale + type bit (weights, Fig. 7): W and
    W^T quantize identically, so FPROP and DGRAD see the same weight."""

    bm: int = _G
    bn: int = _G


BlockLayout = Union[BlockLayout1D, BlockLayout2D]


@dataclass(frozen=True)
class QuantSpec:
    """Static description of a quantization: what ``quantize`` needs beyond
    the data itself."""

    method: str = "mixfp4"
    layout: BlockLayout = BlockLayout1D()
    rounding: str = "rne"


# ---------------------------------------------------------------------------
# QTensor
# ---------------------------------------------------------------------------
@jax.tree_util.register_pytree_node_class
@dataclass(frozen=True)
class QTensor:
    """A packed block-quantized tensor (see module docstring for layouts).

    Extra *leading* batch dimensions on the children (ahead of the layout's
    own dims) are allowed and broadcast through ``dequantize`` — that is what
    makes a stack of per-layer weights a single QTensor that ``lax.scan``
    slices layer-by-layer.
    """

    payload: jax.Array
    scales: jax.Array
    scale32: jax.Array
    method: str = "mixfp4"
    layout: BlockLayout = dataclasses.field(default_factory=BlockLayout1D)
    shape: tuple = ()           # logical (unpadded) shape
    dtype: str = "float32"      # dequantize output dtype
    # Logical PartitionSpec (static aux; see docs/sharding.md).  One entry
    # per payload dim: leading batch dims first, then the layout dims in
    # LOGICAL axis order (for BlockLayout1D the blocked axis is named at its
    # logical position; spec() moves it last to match the children).  Set by
    # with_sharding(); None = no sharding declared.
    pspec: Any = None

    # -- pytree protocol ------------------------------------------------
    def tree_flatten(self):
        return ((self.payload, self.scales, self.scale32),
                (self.method, self.layout, self.shape, self.dtype,
                 self.pspec))

    @classmethod
    def tree_unflatten(cls, aux, children):
        payload, scales, scale32 = children
        method, layout, shape, dtype, pspec = aux
        return cls(payload, scales, scale32, method, layout, shape, dtype,
                   pspec)

    # -- storage accounting ---------------------------------------------
    @property
    def nbytes(self) -> int:
        """Wire bytes: payload + block-scale bytes + 4B/tensor scale."""
        return (int(self.payload.size) + int(self.scales.size)
                + 4 * max(int(self.scale32.size), 1))

    @property
    def bits_per_value(self) -> float:
        n = max(int(math.prod(self.shape)), 1) * self._batch_size()
        return 8.0 * self.nbytes / n

    def _batch_size(self) -> int:
        nb = self._n_batch_dims()
        return int(math.prod(self.payload.shape[:nb])) if nb else 1

    def _n_batch_dims(self) -> int:
        expected = (len(self.shape) if isinstance(self.layout, BlockLayout1D)
                    else 2)
        return self.payload.ndim - expected

    # -- sharding (docs/sharding.md) -------------------------------------
    def _norm_entries(self, pspec) -> list:
        """Logical spec entries, one per payload dim (trailing ``None``s
        filled in, over-long specs rejected)."""
        entries = [] if pspec is None else list(pspec)
        want = self.payload.ndim
        if len(entries) > want:
            raise ValueError(
                f"spec {pspec} has {len(entries)} entries but this QTensor "
                f"has {want} dims ({self._n_batch_dims()} batch + layout)")
        return entries + [None] * (want - len(entries))

    def spec(self, pspec, *, axis_sizes: Mapping[str, int] | None = None
             ) -> dict:
        """Derive consistent child ``PartitionSpec``s from a logical spec.

        ``pspec`` names mesh axes for the *logical* dims (batch dims first);
        the result co-shards ``payload`` and ``scales`` identically —
        sharding a blocked dim moves whole scale blocks, never nibbles —
        and maps the batch dims onto ``scale32``.  With ``axis_sizes``
        (mesh axis name -> size) the block-granularity invariant is
        enforced: a spec whose shard boundary would split a 16-lane scale
        block raises ``ValueError``.  Returns
        ``{"payload": P, "scales": P, "scale32": P}``.
        """
        entries = self._norm_entries(pspec)
        nb = self._n_batch_dims()
        batch = entries[:nb]
        if isinstance(self.layout, BlockLayout2D):
            k_e, n_e = entries[nb], entries[nb + 1]
            if axis_sizes is not None:
                kp = 2 * self.payload.shape[-2]
                np_ = self.payload.shape[-1]
                _check_block_granularity(k_e, kp, self.layout.bm, "K",
                                         axis_sizes)
                _check_block_granularity(n_e, np_, self.layout.bn, "N",
                                         axis_sizes)
            body = [k_e, n_e]
        else:
            logical = entries[nb:]
            bidx = self.layout.axis % len(self.shape)
            blocked = logical[bidx]
            if axis_sizes is not None:
                kp = 2 * self.payload.shape[-1]
                _check_block_granularity(blocked, kp, self.layout.block,
                                         f"axis {self.layout.axis}",
                                         axis_sizes)
            body = logical[:bidx] + logical[bidx + 1:] + [blocked]
        return {"payload": P(*batch, *body),
                "scales": P(*batch, *body),
                "scale32": P(*batch[:self.scale32.ndim])}

    def shardings(self, mesh, pspec) -> "QTensor":
        """``spec()`` materialized against ``mesh``: a QTensor-shaped
        template whose children are ``NamedSharding``s (usable wherever a
        matching pytree of shardings is expected, e.g. checkpoint
        restore)."""
        sp = self.spec(pspec, axis_sizes=dict(mesh.shape))
        return QTensor(NamedSharding(mesh, sp["payload"]),
                       NamedSharding(mesh, sp["scales"]),
                       NamedSharding(mesh, sp["scale32"]),
                       self.method, self.layout, self.shape, self.dtype,
                       P(*self._norm_entries(pspec)))

    def with_sharding(self, mesh, pspec) -> "QTensor":
        """Place the packed children onto ``mesh`` under the child
        shardings derived from logical ``pspec`` (validated at block
        granularity), and record the normalized ``pspec`` in the static
        aux so ``qmm``/``qlinear`` can dispatch mesh-aware."""
        sh = self.shardings(mesh, pspec)
        return QTensor(jax.device_put(self.payload, sh.payload),
                       jax.device_put(self.scales, sh.scales),
                       jax.device_put(self.scale32, sh.scale32),
                       self.method, self.layout, self.shape, self.dtype,
                       sh.pspec)

    # -- decode ----------------------------------------------------------
    def dequantize(self, dtype=None) -> jax.Array:
        """Fig. 9 decode + two-level scaling back to the logical tensor
        (bit-identical to the historical ``unpack_blocks`` path)."""
        out_dtype = jnp.dtype(dtype or self.dtype)
        if isinstance(self.layout, BlockLayout2D):
            x = self._dequantize_2d()
        else:
            x = self._dequantize_1d()
        return x.astype(out_dtype)

    def _scale32_bcast(self, ndim: int) -> jax.Array:
        s = jnp.asarray(self.scale32, jnp.float32)
        return s.reshape(s.shape + (1,) * (ndim - s.ndim))

    def _dequantize_2d(self) -> jax.Array:
        bm, bn = self.layout.bm, self.layout.bn
        lo = self.payload & 0xF
        hi = (self.payload >> 4) & 0xF
        k2, n = self.payload.shape[-2:]
        nib = jnp.stack([lo, hi], axis=-2).reshape(
            *self.payload.shape[:-2], 2 * k2, n)
        s8, t = scaling.unpack_scale_and_type(self.scales)
        s_full = jnp.repeat(jnp.repeat(s8, bm, axis=-2), bn, axis=-1)
        t_full = jnp.repeat(jnp.repeat(t, bm, axis=-2), bn, axis=-1)
        vals = formats.decode_to_e2m2(nib, t_full)
        x = vals * s_full * self._scale32_bcast(nib.ndim)
        m, nn = self.shape
        return x[..., :m, :nn]

    def _dequantize_1d(self) -> jax.Array:
        g = self.layout.block
        lo = self.payload & 0xF
        hi = (self.payload >> 4) & 0xF
        nib = jnp.stack([lo, hi], axis=-1).reshape(
            *self.payload.shape[:-1], 2 * self.payload.shape[-1])
        s8, t = scaling.unpack_scale_and_type(self.scales)
        vals = formats.decode_to_e2m2(nib, jnp.repeat(t, g, axis=-1))
        x = vals * jnp.repeat(s8, g, axis=-1) * self._scale32_bcast(nib.ndim)
        axis = self.layout.axis
        n = self.shape[axis]
        x = x[..., :n]
        # restore the blocked axis to its logical position (negative index so
        # leading batch dims pass through untouched)
        dest = axis if axis < 0 else axis - len(self.shape)
        return jnp.moveaxis(x, -1, dest)


# ---------------------------------------------------------------------------
# quantize: the single entry point
# ---------------------------------------------------------------------------
def _check_packable(method: str):
    if method not in PACKABLE_METHODS:
        raise ValueError(
            f"method {method!r} is not expressible in the MixFP4 wire format "
            f"(packable: {PACKABLE_METHODS}); use core.quantize.qdq for "
            f"simulation-only ablations")


def quantize(x: jax.Array, spec: QuantSpec = QuantSpec(), *,
             key: jax.Array | None = None) -> QTensor:
    """Quantize ``x`` per ``spec`` into the packed wire format.

    Replaces the ``block_quantize_1d/2d`` + ``pack_blocks`` round trips:
    handles padding internally and records the logical shape, so
    ``quantize(x, spec).dequantize()`` is total.
    """
    _check_packable(spec.method)
    if isinstance(spec.layout, BlockLayout2D):
        return _quantize_2d(x, spec, key)
    return _quantize_1d(x, spec, key)


def _quantize_1d(x: jax.Array, spec: QuantSpec, key) -> QTensor:
    lay = spec.layout
    bq, n, axis = Q.block_quantize_1d(
        x, spec.method, block=lay.block, axis=lay.axis,
        rounding=spec.rounding, key=key)
    p = pack_lib.pack_blocks(bq)
    lead = p.scales.shape[:-1]
    nb = p.scales.shape[-1]
    payload = p.payload.reshape(*lead, nb * lay.block // 2)
    axis_neg = lay.axis if lay.axis < 0 else lay.axis - x.ndim
    return QTensor(payload, p.scales, p.scale32,
                   method=spec.method,
                   layout=BlockLayout1D(axis_neg, lay.block),
                   shape=tuple(x.shape), dtype=str(x.dtype))


def _quantize_2d(w: jax.Array, spec: QuantSpec, key) -> QTensor:
    assert w.ndim == 2, "BlockLayout2D expects a (K, N) matrix"
    lay = spec.layout
    bm, bn = lay.bm, lay.bn
    bq, shape, _ = Q.block_quantize_2d(
        w, spec.method, block=(bm, bn), rounding=spec.rounding, key=key)
    gm, gn = bq.type_bits.shape
    # values back on the PADDED (Kp, Np) grid, nibbles packed along K
    vals = bq.values.reshape(gm, gn, bm, bn).transpose(0, 2, 1, 3)
    vals = vals.reshape(gm * bm, gn * bn)
    t_full = jnp.repeat(jnp.repeat(bq.type_bits, bm, axis=0), bn, axis=1)
    nib_e2m1 = formats.e2m1_encode(vals)
    nib_e1m2 = formats.e1m2_encode(vals)
    nib = jnp.where(t_full.astype(bool), nib_e1m2, nib_e2m1)
    payload = (nib[0::2, :] | (nib[1::2, :] << 4)).astype(jnp.uint8)
    scales = scaling.pack_scale_with_type(bq.scale8, bq.type_bits)
    return QTensor(payload, scales, bq.scale32,
                   method=spec.method, layout=BlockLayout2D(bm, bn),
                   shape=tuple(shape), dtype=str(w.dtype))


def quantize_rows(x: jax.Array, *, interpret: bool | None = None,
                  scale32: jax.Array | float | None = None,
                  pad_to: int | None = None,
                  per_row: bool = False) -> QTensor:
    """Fused-kernel 1-D row quantizer (mixfp4/RNE, blocks along the last
    axis of a (M, K) matrix) returning a QTensor — the W4A4 activation
    producer for ``qmm``.  ``scale32`` pins the per-tensor scale (see
    ``kernels.ops.quantize_rows``) for incremental producers like the
    packed KV cache.

    ``pad_to`` zero-pads K up to a target packed grid before quantizing
    (default: the next multiple of 16) while the *logical* shape stays
    ``x.shape`` — this is how W4A4 serving quantizes activations straight
    onto a packed weight's ``Kp`` grid (``pad_to=2*w.payload.shape[-2]``):
    padded lanes quantize to zero codes and decode to exact zeros, the same
    zero terms the dense W4A16 dispatcher's internal padding contributes,
    and a zero tail never moves a block's absmax, so the real lanes' bytes
    are unchanged.

    ``per_row=True`` derives (or pins, via an (M,) ``scale32``) a ROW-LOCAL
    level-2 scale instead of the per-tensor Alg. 1 reduction — the
    resulting QTensor carries an (M,) ``scale32`` vector and each row's
    bytes are a pure function of that row (the W4A4 serving
    batch-independence contract; ``qmm``/``dequantize`` broadcast the
    vector).  Zero K-padding still cannot move a row's amax."""
    from repro.kernels import ops  # deferred: kernels import core

    assert x.ndim == 2, "quantize_rows expects (M, K)"
    m, k = x.shape
    kp = _pad_to(k, _G) if pad_to is None else int(pad_to)
    if kp < k or kp % _G:
        raise ValueError(
            f"quantize_rows: pad_to={pad_to} must be a multiple of {_G} "
            f">= K={k}")
    x32 = x.astype(jnp.float32)
    if kp != k:
        x32 = jnp.pad(x32, ((0, 0), (0, kp - k)))
    kw = {} if interpret is None else {"interpret": interpret}
    if scale32 is not None:
        kw["scale32"] = scale32
    if per_row:
        kw["per_row"] = True
    payload, scales, s32 = ops.quantize_rows(x32, **kw)
    return QTensor(payload, scales, s32, method="mixfp4",
                   layout=BlockLayout1D(-1, _G),
                   shape=(m, k), dtype=str(x.dtype))


def from_packed_rows(payload: jax.Array, scales: jax.Array,
                     scale32: jax.Array | float = 1.0, *,
                     dtype: str = "float32") -> QTensor:
    """Wrap already-packed 1-D rows (g=16 blocks along the last axis) as a
    QTensor: payload (..., K//2) u8 + scales (..., K//16) u8 + per-tensor
    scale.  The one constructor for row-wise wire data produced outside
    :func:`quantize` — e.g. the packed KV cache (models/base) and the kernel
    references (kernels/ref) — so the layout cannot drift between them."""
    return QTensor(
        payload, scales, jnp.asarray(scale32, jnp.float32),
        method="mixfp4", layout=BlockLayout1D(-1, _G),
        shape=(*payload.shape[:-1], payload.shape[-1] * 2), dtype=dtype)


def stack(qts: Sequence[QTensor]) -> QTensor:
    """Stack same-layout QTensors along a new leading batch dimension
    (per-layer weights -> one scan-sliceable pytree)."""
    first = qts[0]
    for qt in qts[1:]:
        if (qt.method, qt.layout, qt.shape, qt.dtype) != \
           (first.method, first.layout, first.shape, first.dtype):
            raise ValueError("stack() requires identical QTensor metadata")
    return QTensor(jnp.stack([qt.payload for qt in qts]),
                   jnp.stack([qt.scales for qt in qts]),
                   jnp.stack([jnp.asarray(qt.scale32) for qt in qts]),
                   first.method, first.layout, first.shape, first.dtype)


# ---------------------------------------------------------------------------
# qmm: dispatching quantized matmul
# ---------------------------------------------------------------------------
def _mm_bf16(a: jax.Array, b: jax.Array) -> jax.Array:
    return jax.lax.dot_general(
        a.astype(jnp.bfloat16), b.astype(jnp.bfloat16),
        (((a.ndim - 1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)


def _pad_weight_operands(w: "QTensor", ch) -> tuple:
    """Zero-pad the packed weight payload/scales from the storage (Kp, Np)
    grid up to the tuner's (k_pad, n_pad) tile grid.  Zero payload bytes
    under zero scale bytes decode to exact zeros, so padded lanes/columns
    contribute nothing; the cost model charges the copy this creates, so
    padding only happens when escaping degenerate tiles is worth it."""
    kp2, np_ = w.payload.shape
    kp = 2 * kp2
    wp, ws = w.payload, w.scales
    if ch.k_pad != kp or ch.n_pad != np_:
        wp = jnp.pad(wp, ((0, (ch.k_pad - kp) // 2), (0, ch.n_pad - np_)))
        ws = jnp.pad(ws, ((0, (ch.k_pad - kp) // _G),
                          (0, (ch.n_pad - np_) // _G)))
    return wp, ws


def prepad_for_tiles(w: "QTensor", group: str, m: int,
                     max_iters: int = 4) -> "QTensor":
    """Pre-pad a 2-D packed weight's children onto the tuner's tile grid
    so ``qmm`` dispatches stop re-padding the packed bytes inside every
    jitted call (``_pad_weight_operands`` becomes a no-op for the target
    ``(group, m)`` shape — e.g. the serving engine's decode batch).

    Runs ``select_tiles`` to a fixed point: padding the storage dims can
    itself change the tuner's choice, so iterate pad -> re-select until
    ``(k_pad, n_pad)`` equals storage (k_pad/n_pad never shrink below
    storage, so this converges, and other ``m`` shapes still pad safely at
    dispatch).  Zero payload bytes under zero scale bytes decode to exact
    zeros, and ``QTensor.shape`` keeps the logical dims, so dequantize /
    GEMM results are unchanged — only the storage grid grows.  Stacked
    (scan-sliced) weights and non-2-D layouts pass through untouched.
    """
    from repro.kernels import tuning  # deferred: kernels import core

    if not (isinstance(w, QTensor) and isinstance(w.layout, BlockLayout2D)
            and w.payload.ndim == 2):
        return w
    wp, ws = w.payload, w.scales
    for _ in range(max_iters):
        kp, np_ = 2 * wp.shape[0], wp.shape[1]
        ch = tuning.select_tiles(group, m, kp, np_)
        if ch.k_pad == kp and ch.n_pad == np_:
            break
        wp = jnp.pad(wp, ((0, (ch.k_pad - kp) // 2), (0, ch.n_pad - np_)))
        ws = jnp.pad(ws, ((0, (ch.k_pad - kp) // _G),
                          (0, (ch.n_pad - np_) // _G)))
    if wp is w.payload:
        return w
    return QTensor(wp, ws, w.scale32, w.method, w.layout, w.shape,
                   w.dtype, w.pspec)


def _act_scale32_like_quantize_rows(x2: jax.Array,
                                    per_row: bool = False) -> jax.Array:
    """The activation scale exactly as ``mixfp4_quant_rows`` derives it
    (one owner: ``scaling.tensor_scale`` / ``scaling.row_scale``, which the
    quantizer kernel matches bit-for-bit); zero K-padding cannot change
    either reduction, so computing it on the unpadded rows is equivalent.
    ``per_row=True`` returns the (M,) row-local vector (all-zero rows —
    including M-padding — get scale 1 and quantize to zero codes)."""
    x2 = x2.astype(jnp.float32)
    return scaling.row_scale(x2) if per_row else scaling.tensor_scale(x2)


def qmm(x: Union[jax.Array, QTensor], w: Union[jax.Array, QTensor], *,
        interpret: bool | None = None, allow_fallback: bool = True,
        fuse_act_quant: bool = False,
        act_scale32: jax.Array | float | None = None,
        per_row_act: bool = False,
        act_rht_signs: jax.Array | None = None) -> jax.Array:
    """y = x @ w with quantized operands, f32 output.

    Dispatch rules (docs/qtensor.md):
      * ``x`` dense, ``w`` 2-D QTensor  -> Pallas W4A16 kernel (serving
        decode: weight HBM traffic is 4.5 bits/value).
      * ``x`` dense + ``fuse_act_quant=True`` -> Pallas W4A4 kernel with
        the row quantizer fused into the prologue: ONE dispatch per GEMM,
        bitwise-identical to ``quantize_rows(x, pad_to=Kp)`` -> ``qmm``
        (which remains the oracle).  ``act_scale32`` pins the per-tensor
        activation scale (sharded row-parallel shards must share the
        global scale); default derives it exactly as ``quantize_rows``.
        ``per_row_act=True`` switches the fused prologue to the per-row
        scale contract (oracle: ``quantize_rows(per_row=True)`` -> W4A4),
        and ``act_rht_signs`` (a ±1 vector on the weight's packed Kp grid)
        additionally fuses the grouped RHT ahead of the quantizer — the
        weight must have been RHT-transformed along K with the SAME signs
        at pack time (``models.base.pack_projections(act_rht=True)``).
      * ``x`` 1-D QTensor (last axis), ``w`` 2-D QTensor -> Pallas W4A4.
        An (M,)-vector ``x.scale32`` (from ``quantize_rows(per_row=True)``)
        dispatches the per-row GEMM; padded rows ride under scale 1.
      * anything else (1-D weights, stacked batch dims, K mismatch) ->
        qdq-simulated fallback: dequantize + bf16 matmul w/ f32 accum.

    Padding to the packed (Kp, Np) grid and kernel tile selection happen
    here — callers never pad.  Tiles come from the cost-model autotuner
    (``kernels.tuning``): M rounds up the fixed ``bm`` ladder (so decode-
    batch wobble reuses one compiled kernel) and K/N pad up to the chosen
    tile multiples instead of collapsing to 16-wide divisor tiles.
    ``interpret`` defaults to the backend rule (native on TPU, interpret
    elsewhere).
    """
    from repro.kernels import ops, tuning  # deferred: kernels import core

    if interpret is None:
        interpret = ops.default_interpret()

    w_is_qt = isinstance(w, QTensor)
    x_is_qt = isinstance(x, QTensor)
    w_kernel_ok = (w_is_qt and isinstance(w.layout, BlockLayout2D)
                   and w.payload.ndim == 2)

    if fuse_act_quant and x_is_qt:
        raise ValueError("qmm: fuse_act_quant quantizes a DENSE activation "
                         "in the kernel prologue; the operand is already "
                         "packed — drop the flag or pass the dense rows")
    if fuse_act_quant and not w_kernel_ok:
        raise ValueError("qmm: fuse_act_quant needs a kernel-dispatchable "
                         "2-D QTensor weight (scan slices stacks first); "
                         "silently falling back would drop the W4A4 "
                         "semantics the caller asked for")

    def fallback():
        if not allow_fallback:
            raise ValueError("qmm: operands not kernel-dispatchable and "
                             "allow_fallback=False")
        xd = x.dequantize() if x_is_qt else x
        wd = w.dequantize() if w_is_qt else w
        if wd.ndim != 2:
            raise ValueError(f"qmm: weight must be 2-D, got {wd.shape}")
        return _mm_bf16(xd, wd)

    if not w_kernel_ok:
        return fallback()

    kp2, np_ = w.payload.shape
    kp = 2 * kp2
    k_logical, n_logical = w.shape

    if x_is_qt:
        if x.shape[-1] != k_logical:
            raise ValueError(
                f"qmm: x K={x.shape[-1]} vs weight K={k_logical}")
        ok = (isinstance(x.layout, BlockLayout1D)
              and x.layout.axis in (-1, len(x.shape) - 1)
              and x.layout.block == _G
              and x.payload.ndim == 2
              and x.payload.shape[1] * 2 == kp)
        if not ok:
            return fallback()
        m = x.payload.shape[0]
        ch = tuning.select_tiles("w4a4", m, kp, np_)
        xp, xs = x.payload, x.scales
        x32 = x.scale32
        per_row = getattr(x32, "ndim", 0) == 1
        if ch.m_pad != m or ch.k_pad != kp:
            # padded rows/lanes: zero payload + zero scale bytes decode to
            # exact zeros, the same terms the fused prologue contributes
            xp = jnp.pad(xp, ((0, ch.m_pad - m), (0, (ch.k_pad - kp) // 2)))
            xs = jnp.pad(xs, ((0, ch.m_pad - m), (0, (ch.k_pad - kp) // _G)))
            if per_row:
                # padded rows carry scale 1 (all-zero rows' guard value)
                x32 = jnp.pad(x32, (0, ch.m_pad - m), constant_values=1.0)
        y = ops.gemm_w4a4(xp, xs, x32, *_pad_weight_operands(w, ch),
                          w.scale32, bm=ch.bm, bn=ch.bn, bk=ch.bk,
                          interpret=interpret, per_row=per_row)
        return y[:m, :n_logical]

    if x.shape[-1] != k_logical:
        raise ValueError(f"qmm: x K={x.shape[-1]} vs weight K={k_logical}")
    lead = x.shape[:-1]
    m = int(math.prod(lead)) if lead else 1
    x2 = x.reshape(m, k_logical)

    if fuse_act_quant:
        # Fused quantize+GEMM prologue (W4A4 in one dispatch): the scale is
        # derived (or pinned) here, the dense rows are zero-padded onto the
        # tuner grid, and the kernel quantizes tile-by-tile in VMEM.
        if act_rht_signs is not None and not per_row_act:
            raise ValueError("qmm: act_rht_signs requires per_row_act=True "
                             "(the RHT lever rides the row-local scale "
                             "contract)")
        ch = tuning.select_tiles("w4a4_fused", m, kp, np_)
        # rows cast to f32 HERE, before padding/streaming — exactly where
        # the composition's quantize_rows casts (see mixfp4_gemm_w4a4_fused:
        # moving the convert can change XLA's fusion of the surrounding
        # graph and flip the dual-format select at near-ties)
        x2p = x2.astype(jnp.float32)
        if ch.m_pad != m or ch.k_pad != k_logical:
            x2p = jnp.pad(x2p, ((0, ch.m_pad - m),
                                (0, ch.k_pad - k_logical)))
        signs_p = None
        if act_rht_signs is not None:
            if act_rht_signs.shape != (kp,):
                raise ValueError(
                    f"qmm: act_rht_signs must live on the weight's packed "
                    f"Kp grid ({kp},), got {act_rht_signs.shape}")
            # extend with +1 onto the tuner grid: the tail groups are
            # all-zero in both operands, so they transform to zero
            signs_p = jnp.pad(act_rht_signs.astype(jnp.float32),
                              (0, ch.k_pad - kp), constant_values=1.0)
        if act_scale32 is not None:
            s32x = jnp.asarray(act_scale32, jnp.float32)
            if per_row_act and ch.m_pad != m:
                s32x = jnp.pad(s32x.reshape(-1), (0, ch.m_pad - m),
                               constant_values=1.0)
        elif per_row_act:
            # row-local scale from the SAME values the prologue quantizes:
            # the (already padded) rows, RHT-transformed when signs ride
            # along (shared fwht_rows_math — bit-identical to in-kernel).
            # Padded rows are all-zero -> guard scale 1 -> zero codes.
            from repro.kernels.fwht import fwht_rows_math  # deferred
            xt = (fwht_rows_math(x2p, signs_p, _G)
                  if signs_p is not None else x2p)
            s32x = _act_scale32_like_quantize_rows(xt, per_row=True)
        else:
            s32x = _act_scale32_like_quantize_rows(x2)
        wp, ws = _pad_weight_operands(w, ch)
        y = ops.gemm_w4a4_fused(x2p, s32x, wp, ws, w.scale32,
                                bm=ch.bm, bn=ch.bn, bk=ch.bk,
                                interpret=interpret, per_row=per_row_act,
                                rht_signs=signs_p)
        return y[:m, :n_logical].reshape(*lead, n_logical)

    ch = tuning.select_tiles("w4a16", m, kp, np_)
    if ch.k_pad != k_logical:  # padded weight K: zero-pad x (padded W rows
        x2 = jnp.pad(x2, ((0, 0), (0, ch.k_pad - k_logical)))  # decode to 0)
    if ch.m_pad != m:       # pad M up the bm ladder rather than letting a
        x2 = jnp.pad(x2, ((0, ch.m_pad - m), (0, 0)))  # prime M degrade
    wp, ws = _pad_weight_operands(w, ch)
    y = ops.gemm_w4a16(x2, wp, ws, w.scale32,
                       bm=ch.bm, bn=ch.bn, bk=ch.bk, interpret=interpret)
    return y[:m, :n_logical].reshape(*lead, n_logical)


# ---------------------------------------------------------------------------
# Sharded qmm: packed-operand tensor parallelism (docs/sharding.md)
# ---------------------------------------------------------------------------
def _axes_size(entry, axis_sizes: Mapping[str, int]) -> int:
    """Total shard count a spec entry assigns (product over tuple axes)."""
    if entry is None:
        return 1
    names = entry if isinstance(entry, tuple) else (entry,)
    n = 1
    for a in names:
        if a not in axis_sizes:
            raise ValueError(f"spec names mesh axis {a!r}, mesh has "
                             f"{sorted(axis_sizes)}")
        n *= axis_sizes[a]
    return n


def _check_block_granularity(entry, padded_dim: int, block: int, dim_name,
                             axis_sizes: Mapping[str, int]):
    """Reject a spec whose shard boundary would land inside a scale block:
    the payload/scales co-sharding invariant needs every shard of a blocked
    dim to be a whole number of ``block``-lane blocks."""
    size = _axes_size(entry, axis_sizes)
    if size > 1 and padded_dim % (size * block):
        raise ValueError(
            f"sharding {dim_name} (padded {padded_dim}) over {entry!r} "
            f"({size} shards) would split a {block}-lane scale block; "
            f"shards must hold whole blocks "
            f"(need {dim_name} % {size * block} == 0)")


def kn_partitions(qt: QTensor) -> tuple:
    """(K entry, N entry) of a 2-D QTensor's logical ``pspec`` — the last
    two entries, so a scan-sliced stack (whose leading batch entries are
    ``None``) reads the same as the unstacked weight."""
    if qt.pspec is None:
        return (None, None)
    e = list(qt.pspec)
    e = [None] * (2 - len(e)) + e
    return e[-2], e[-1]


def qmm_sharded(x: Union[jax.Array, QTensor], w: QTensor, *, mesh,
                interpret: bool | None = None,
                fuse_act_quant: bool = False,
                per_row_act: bool = False,
                act_rht_signs: jax.Array | None = None) -> jax.Array:
    """``qmm`` for a model-parallel packed weight: the kernel runs per
    shard under ``shard_map``, so the payload/scale bytes are never
    gathered or dequantized to a full dense weight.

    ``x`` is either dense (W4A16 per shard) or a 2-D 1-D-row-blocked
    QTensor on the weight's packed ``Kp`` grid — produced by
    ``quantize_rows(x2, pad_to=2*w.payload.shape[-2])`` — the W4A4
    serving path, where BOTH operands stay on the wire format inside
    every shard.

    The weight's logical ``pspec`` (see :meth:`QTensor.with_sharding`)
    selects the plan:

      * N sharded (column-parallel, the serving default): ``x`` is
        replicated over the model axis — for W4A4 the activation rows
        are quantized ONCE and their packed bytes replicate — and every
        shard computes its output columns.  Bitwise-identical to the
        single-device kernel, since output columns are independent and
        the K tiling is unchanged.
      * K sharded (row-parallel): ``x`` is split along K and partial
        products ``psum`` in f32 over the model axis.  For W4A4 the
        payload/scale bytes split at 16-lane block granularity — block
        quantization is K-slice-local under the shared per-tensor scale,
        so each shard's bytes equal what quantizing its own K slice
        under that scale32 would produce.  NOT bitwise-identical to
        single-device (the psum reassociates the K reduction), which is
        why the engine's default serve layout avoids it
        (docs/sharding.md).

    ``fuse_act_quant=True`` (dense ``x`` only) runs the fused quantize+GEMM
    W4A4 kernel per shard in ONE dispatch: the per-tensor activation scale
    is derived OUTSIDE ``shard_map`` from the full rows and pinned into
    every shard's prologue, so a K shard quantizes its slice under the
    global Alg. 1 scale — the same bytes the quantize-once-and-split
    composition produces.  Column-parallel stays bitwise-identical to the
    single-device fused kernel: the tuner picks ``bk`` independently of N,
    so every shard keeps the single-device K tiling.

    ``per_row_act=True`` pins the (M,) ROW-LOCAL scale vector into every
    shard instead (replicated — row amax is a full-K reduction computed
    here, outside the split), and ``act_rht_signs`` splits along K with
    the weight (the transform is 16-lane-group-local and shard boundaries
    land on 16-lane blocks, so each shard transforms exactly its slice).
    """
    from repro.distributed.sharding import shard_map  # deferred: layering

    if not (isinstance(w.layout, BlockLayout2D) and w.payload.ndim == 2):
        raise ValueError("qmm_sharded expects an unbatched 2-D-tiled "
                         "QTensor weight (scan slices stacks first)")
    kp2, np_ = w.payload.shape
    kp = 2 * kp2
    k_log, n_log = w.shape
    x_is_qt = isinstance(x, QTensor)
    if x_is_qt:
        ok = (isinstance(x.layout, BlockLayout1D)
              and x.layout.axis in (-1, len(x.shape) - 1)
              and x.layout.block == _G
              and x.payload.ndim == 2
              and x.payload.shape[-1] * 2 == kp)
        if not ok:
            raise ValueError(
                "qmm_sharded: a QTensor activation must be 1-D g=16 "
                "row-blocked on the weight's packed K grid — produce it "
                "with quantize_rows(x2, pad_to=2*w.payload.shape[-2])")
    if x.shape[-1] != k_log:
        raise ValueError(f"qmm_sharded: x K={x.shape[-1]} vs weight "
                         f"K={k_log}")
    if fuse_act_quant and x_is_qt:
        raise ValueError("qmm_sharded: fuse_act_quant quantizes a DENSE "
                         "activation in the kernel prologue; the operand "
                         "is already packed")
    k_e, n_e = kn_partitions(w)
    if k_e is None and n_e is None:
        return qmm(x, w, interpret=interpret,
                   fuse_act_quant=fuse_act_quant,
                   per_row_act=per_row_act,
                   act_rht_signs=act_rht_signs)
    sizes = dict(mesh.shape)
    ks, ns = _axes_size(k_e, sizes), _axes_size(n_e, sizes)
    _check_block_granularity(k_e, kp, w.layout.bm, "K", sizes)
    _check_block_granularity(n_e, np_, w.layout.bn, "N", sizes)
    n_loc = np_ // ns
    w_spec = P(k_e, n_e)

    if x_is_qt:
        # The rows were quantized once on the padded Kp grid; a K shard
        # slices whole 16-lane blocks of payload AND scale bytes (the
        # granularity check above covers both, payload at 8 bytes/block
        # and scales at 1), and the per-tensor scale32 replicates.
        x_args = (x.payload, x.scales, x.scale32)
        x_specs = (P(None, k_e), P(None, k_e), P())
        lead_specs = (None,)
    else:
        # pad x to the packed Kp grid OUTSIDE shard_map so a K shard is
        # exact (padded weight rows decode to exact zeros — same zero
        # terms, in the same order, as the unsharded dispatcher's
        # internal padding)
        xk = x
        if kp != k_log:
            xk = jnp.pad(x, [(0, 0)] * (x.ndim - 1) + [(0, kp - k_log)])
        x_args = (xk,)
        x_specs = (P(*[None] * (x.ndim - 1), k_e),)
        lead_specs = (None,) * (x.ndim - 1)
        if fuse_act_quant:
            x2full = xk.reshape(-1, kp)
            if act_rht_signs is not None:
                if not per_row_act:
                    raise ValueError(
                        "qmm_sharded: act_rht_signs requires per_row_act")
                if act_rht_signs.shape != (kp,):
                    raise ValueError(
                        f"qmm_sharded: act_rht_signs must live on the "
                        f"packed Kp grid ({kp},), got {act_rht_signs.shape}")
                from repro.kernels.fwht import fwht_rows_math  # deferred
                x2full = fwht_rows_math(
                    x2full.astype(jnp.float32),
                    act_rht_signs.astype(jnp.float32), _G)
            # global activation scale, derived from the FULL (transformed)
            # rows before the K split and pinned into every shard's
            # prologue — per-row vectors replicate like the scalar
            s32x = _act_scale32_like_quantize_rows(x2full,
                                                   per_row=per_row_act)
            x_args = x_args + (s32x,)
            x_specs = x_specs + (P(),)
            if act_rht_signs is not None:
                x_args = x_args + (act_rht_signs.astype(jnp.float32),)
                x_specs = x_specs + (P(k_e),)

    def body(x_parts, wp, ws, w32):
        k_loc = 2 * wp.shape[0]   # local K, padded-as-logical (see above)
        qt_w = QTensor(wp, ws, w32, w.method, w.layout,
                       (k_loc, n_loc if n_e is not None else n_log),
                       w.dtype)
        if x_is_qt:
            xp, xs, x32 = x_parts
            xl = QTensor(xp, xs, x32, x.method, BlockLayout1D(-1, _G),
                         (xp.shape[0], k_loc), x.dtype)
            y = qmm(xl, qt_w, interpret=interpret)
        elif fuse_act_quant:
            xl, s32_local = x_parts[0], x_parts[1]
            signs_local = x_parts[2] if len(x_parts) > 2 else None
            y = qmm(xl, qt_w, interpret=interpret, fuse_act_quant=True,
                    act_scale32=s32_local, per_row_act=per_row_act,
                    act_rht_signs=signs_local)
        else:
            (xl,) = x_parts
            y = qmm(xl, qt_w, interpret=interpret)   # f32 out on all paths
        if k_e is not None:
            y = jax.lax.psum(
                y, k_e if isinstance(k_e, tuple) else (k_e,))
        return y

    out = shard_map(body, mesh=mesh,
                    in_specs=(x_specs, w_spec, w_spec, P()),
                    out_specs=P(*lead_specs, n_e))(
        x_args, w.payload, w.scales, w.scale32)
    return out[..., :n_log] if n_e is not None else out


# ---------------------------------------------------------------------------
# Storage math (abstract — no arrays needed; used by dryrun reports)
# ---------------------------------------------------------------------------
def packed_nbytes_for_shape(shape: Sequence[int],
                            layout: BlockLayout = BlockLayout2D()) -> int:
    """Wire bytes a QTensor of logical ``shape`` would occupy."""
    if isinstance(layout, BlockLayout2D):
        k, n = shape
        kp, np_ = _pad_to(k, layout.bm), _pad_to(n, layout.bn)
        return kp * np_ // 2 + (kp // layout.bm) * (np_ // layout.bn) + 4
    n = shape[layout.axis]
    lead = int(math.prod(shape)) // n
    npad = _pad_to(n, layout.block)
    return lead * (npad // 2 + npad // layout.block) + 4


def packed_struct_for_shape(shape: Sequence[int],
                            layout: BlockLayout | None = None, *,
                            method: str = "mixfp4",
                            dtype: str = "float32") -> QTensor:
    """ShapeDtypeStruct-children skeleton of the QTensor that
    :func:`quantize` / ``models.base.pack_projections`` would build for a
    dense tensor of ``shape`` — for 2-D layouts, dims ahead of the
    trailing (K, N) matrix become QTensor batch dims, exactly as
    ``pack_projections`` stacks them.  The abstract counterpart of
    :func:`packed_nbytes_for_shape`: no-allocation layout decisions
    (dryrun reports, serve-spec derivation) work on this skeleton through
    the same code paths the engine uses on real trees, so the child-shape
    math has one owner."""
    layout = layout or BlockLayout2D()
    sds = jax.ShapeDtypeStruct
    if isinstance(layout, BlockLayout2D):
        lead, (k, n) = tuple(shape[:-2]), shape[-2:]
        kp, np_ = _pad_to(k, layout.bm), _pad_to(n, layout.bn)
        return QTensor(
            sds((*lead, kp // 2, np_), jnp.uint8),
            sds((*lead, kp // layout.bm, np_ // layout.bn), jnp.uint8),
            sds(lead, jnp.float32),
            method=method, layout=layout, shape=(k, n), dtype=dtype)
    n = shape[layout.axis]
    lead = list(shape)
    del lead[layout.axis % len(shape)]
    npad = _pad_to(n, layout.block)
    axis_neg = (layout.axis if layout.axis < 0
                else layout.axis - len(shape))
    return QTensor(
        sds((*lead, npad // 2), jnp.uint8),
        sds((*lead, npad // layout.block), jnp.uint8),
        sds((), jnp.float32),
        method=method, layout=BlockLayout1D(axis_neg, layout.block),
        shape=tuple(shape), dtype=dtype)


# ---------------------------------------------------------------------------
# JSON-able pytree specs (checkpointing: rebuild structure without arrays)
# ---------------------------------------------------------------------------
def _layout_to_json(layout: BlockLayout) -> dict:
    if isinstance(layout, BlockLayout2D):
        return {"kind": "2d", "bm": layout.bm, "bn": layout.bn}
    return {"kind": "1d", "axis": layout.axis, "block": layout.block}


def _layout_from_json(d: dict) -> BlockLayout:
    if d["kind"] == "2d":
        return BlockLayout2D(d["bm"], d["bn"])
    return BlockLayout1D(d["axis"], d["block"])


def _pspec_to_json(pspec) -> list | None:
    if pspec is None:
        return None
    return [list(e) if isinstance(e, tuple) else e for e in pspec]


def _pspec_from_json(entries) -> Any:
    if entries is None:
        return None
    return P(*[tuple(e) if isinstance(e, list) else e for e in entries])


def tree_spec(tree) -> Any:
    """JSON-able structural spec of a (nested dict/list) tree whose leaves
    are arrays or QTensors — enough to rebuild a restore skeleton.  QTensor
    entries record the child shapes/dtypes (batch dims included) and the
    logical ``pspec``, so a restore target can derive per-child
    ``NamedSharding``s before any leaf bytes are read."""
    if isinstance(tree, QTensor):
        return {"__qtensor__": {
            "method": tree.method,
            "layout": _layout_to_json(tree.layout),
            "shape": list(tree.shape),
            "dtype": tree.dtype,
            "pspec": _pspec_to_json(tree.pspec),
            "children": {
                name: {"shape": list(getattr(tree, name).shape),
                       "dtype": str(getattr(tree, name).dtype)}
                for name in ("payload", "scales", "scale32")},
        }}
    if isinstance(tree, dict):
        return {"__dict__": {k: tree_spec(v) for k, v in tree.items()}}
    if isinstance(tree, (list, tuple)):
        return {"__list__": [tree_spec(v) for v in tree],
                "tuple": isinstance(tree, tuple)}
    return {"__leaf__": None}


def tree_like(spec: Any):
    """Inverse of :func:`tree_spec`: a placeholder tree with the same pytree
    structure.  QTensor children become ``ShapeDtypeStruct``s when the spec
    recorded their shapes (so sharding derivation works on the skeleton);
    specs written before child shapes were recorded fall back to dummy
    ``0`` leaves — checkpoint restore only needs the structure either way."""
    if "__qtensor__" in spec:
        m = spec["__qtensor__"]
        kids = m.get("children")
        if kids:
            children = [jax.ShapeDtypeStruct(tuple(kids[n]["shape"]),
                                             jnp.dtype(kids[n]["dtype"]))
                        for n in ("payload", "scales", "scale32")]
        else:
            children = [0, 0, 0]
        return QTensor(*children, method=m["method"],
                       layout=_layout_from_json(m["layout"]),
                       shape=tuple(m["shape"]), dtype=m["dtype"],
                       pspec=_pspec_from_json(m.get("pspec")))
    if "__dict__" in spec:
        return {k: tree_like(v) for k, v in spec["__dict__"].items()}
    if "__list__" in spec:
        seq = [tree_like(v) for v in spec["__list__"]]
        return tuple(seq) if spec.get("tuple") else seq
    return 0
