"""AdamW with FP32 master weights (paper Fig. 7 blue path).

The params tree IS the FP32 master copy: the bf16 cast happens inside the
quantized GEMM boundary (core/qgemm), which is exactly the paper's dataflow
(master weights FP32, GEMM operands quantized per step).  Optimizer moments
can be sharded over the data axis on top of the model sharding (ZeRO-1) via
``zero1_specs`` — divides optimizer memory by the DP degree.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

__all__ = ["AdamWConfig", "AdamWState", "adamw_init", "adamw_update",
           "zero1_specs", "global_norm", "clip_by_global_norm"]


@dataclass(frozen=True)
class AdamWConfig:
    b1: float = 0.9
    b2: float = 0.95           # paper §4.2
    eps: float = 1e-8
    weight_decay: float = 0.1  # paper §4.2
    clip_norm: float = 1.0     # paper §4.2


class AdamWState(NamedTuple):
    step: jax.Array
    mu: Any
    nu: Any


def adamw_init(params) -> AdamWState:
    zeros = lambda t: jax.tree.map(lambda x: jnp.zeros_like(x, jnp.float32), t)
    return AdamWState(jnp.zeros((), jnp.int32), zeros(params), zeros(params))


def global_norm(tree) -> jax.Array:
    return jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                        for g in jax.tree.leaves(tree)))


def clip_by_global_norm(grads, max_norm: float):
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / (norm + 1e-9))
    return jax.tree.map(lambda g: g * scale, grads), norm


def adamw_update(cfg: AdamWConfig, params, state: AdamWState, grads, lr):
    """One AdamW step on the FP32 master params.

    Returns (new_params, new_state, grad_norm)."""
    grads = jax.tree.map(lambda g: g.astype(jnp.float32), grads)
    if cfg.clip_norm:
        grads, gnorm = clip_by_global_norm(grads, cfg.clip_norm)
    else:
        gnorm = global_norm(grads)
    step = state.step + 1
    b1c = 1 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1 - cfg.b2 ** step.astype(jnp.float32)

    mu = jax.tree.map(lambda m, g: cfg.b1 * m + (1 - cfg.b1) * g,
                      state.mu, grads)
    nu = jax.tree.map(lambda v, g: cfg.b2 * v + (1 - cfg.b2) * g * g,
                      state.nu, grads)

    def upd(w, m, v):
        w32 = w.astype(jnp.float32)
        mhat = m / b1c
        vhat = v / b2c
        return (w32 - lr * (mhat / (jnp.sqrt(vhat) + cfg.eps)
                            + cfg.weight_decay * w32)).astype(w.dtype)

    new_params = jax.tree.map(upd, params, mu, nu)
    return new_params, AdamWState(step, mu, nu), gnorm


def zero1_specs(param_specs, data_axes=("data",)):
    """ZeRO-1: shard optimizer-moment leaves additionally over the data axis
    on their first unsharded dimension (falls back to the param spec when no
    free dim exists)."""
    def reshard(spec):
        if spec is None:
            return None  # replicated leaves (scalars etc.) stay replicated
        parts = list(spec)
        used = {a for p in parts if p is not None
                for a in (p if isinstance(p, tuple) else (p,))}
        axes = tuple(a for a in data_axes if a not in used)
        if not axes:
            return spec
        for i, p in enumerate(parts):
            if p is None:
                parts[i] = axes
                return P(*parts)
        return spec
    return jax.tree.map(reshard, param_specs,
                        is_leaf=lambda x: isinstance(x, P) or x is None)
