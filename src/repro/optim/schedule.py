"""LR schedules: warmup + cosine to a minimum ratio (paper §4.2 uses
max 1e-3 -> min 1e-4 for the 114M run, i.e. min ratio 0.1)."""
from __future__ import annotations

import jax.numpy as jnp


def warmup_cosine(step, *, max_lr: float, warmup: int, total: int,
                  min_ratio: float = 0.1):
    step = jnp.asarray(step, jnp.float32)
    warm = max_lr * step / jnp.maximum(warmup, 1)
    t = jnp.clip((step - warmup) / jnp.maximum(total - warmup, 1), 0.0, 1.0)
    cos = max_lr * (min_ratio + (1 - min_ratio) * 0.5 * (1 + jnp.cos(jnp.pi * t)))
    return jnp.where(step < warmup, warm, cos)
