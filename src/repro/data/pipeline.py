"""Deterministic, shardable, resumable synthetic data pipeline.

No datasets ship in this offline container, so the pipeline generates
structured synthetic language: a fixed per-stream Markov transition table
(so models have real statistical structure to learn — pretraining-loss
curves in benchmarks/ separate BF16/NVFP4/MixFP4 on it) plus span-copy
structure (induction heads).  Properties a production pipeline needs and
tests exercise:

  * deterministic as a function of (seed, step, shard) — restart-safe,
  * shard-aware: host i of n draws disjoint per-step substreams,
  * resumable via a cursor (the step index IS the cursor; checkpoints store
    it),
  * background prefetch with a bounded queue so input never serialises
    steps (straggler mitigation lever #1 — see launch/train.py).
"""
from __future__ import annotations

import queue
import threading
from dataclasses import dataclass

import numpy as np

__all__ = ["DataConfig", "SyntheticLMStream", "make_stream", "Prefetcher"]


@dataclass(frozen=True)
class DataConfig:
    vocab: int
    seq_len: int
    batch_per_shard: int
    seed: int = 0
    shard: int = 0
    n_shards: int = 1
    markov_states: int = 64
    copy_span: int = 16


class SyntheticLMStream:
    """Markov-chain tokens with periodic span copies; next-token labels."""

    def __init__(self, cfg: DataConfig):
        self.cfg = cfg
        rng = np.random.RandomState(cfg.seed)
        k = min(cfg.markov_states, cfg.vocab)
        # sparse-ish row-stochastic transition over k "hub" tokens
        logits = rng.randn(k, k) * 2.0
        self._trans = np.exp(logits) / np.exp(logits).sum(1, keepdims=True)
        self._hubs = rng.choice(cfg.vocab, size=k, replace=False)
        self._k = k

    def batch(self, step: int) -> dict:
        cfg = self.cfg
        rng = np.random.RandomState(
            (cfg.seed * 1_000_003 + step * 977 + cfg.shard * 7919) % 2**31)
        b, s = cfg.batch_per_shard, cfg.seq_len
        states = rng.randint(0, self._k, size=b)
        toks = np.empty((b, s), np.int32)
        cum = np.cumsum(self._trans, axis=1)
        for t in range(s):
            u = rng.rand(b)
            states = (cum[states] > u[:, None]).argmax(1)
            toks[:, t] = self._hubs[states]
        # induction structure: copy a span forward
        span = min(cfg.copy_span, s // 4)
        if span > 1:
            src = rng.randint(0, s // 2 - span, size=b)
            dst = rng.randint(s // 2, s - span, size=b)
            for i in range(b):
                toks[i, dst[i]:dst[i] + span] = toks[i, src[i]:src[i] + span]
        labels = np.concatenate([toks[:, 1:], np.full((b, 1), -1, np.int32)],
                                axis=1)
        return {"tokens": toks, "labels": labels}


def make_stream(cfg: DataConfig) -> SyntheticLMStream:
    return SyntheticLMStream(cfg)


class Prefetcher:
    """Background thread filling a bounded queue of batches."""

    def __init__(self, stream: SyntheticLMStream, start_step: int,
                 depth: int = 4):
        self._stream = stream
        self._q: queue.Queue = queue.Queue(maxsize=depth)
        self._step = start_step
        self._stop = threading.Event()
        self._thread = threading.Thread(target=self._run, daemon=True)
        self._thread.start()

    def _run(self):
        step = self._step
        while not self._stop.is_set():
            batch = self._stream.batch(step)
            while not self._stop.is_set():
                try:
                    self._q.put((step, batch), timeout=0.2)
                    break
                except queue.Full:
                    continue
            step += 1

    def next(self) -> tuple[int, dict]:
        return self._q.get()

    def close(self):
        self._stop.set()
        self._thread.join(timeout=2.0)
