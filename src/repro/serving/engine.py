"""Batched serving engine over packed MixFP4 weights.

Production-shaped serving loop: requests join a continuous batch; weights
are stored in the paper's wire format (4-bit payloads + type-in-sign E4M3
scale bytes = 4.5 bits/value in HBM, a ~3.55x weight-memory and bandwidth
saving over bf16 for the decode-bound regime); the KV cache can optionally
be MixFP4-quantized per (head, 16-value block) as well.

On CPU the packed path runs through the interpret-mode Pallas kernels; on
TPU the same `kernels/ops.py` entry points compile natively.  The engine is
what examples/serve.py drives and what the decode dry-run shapes model.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import pack as pack_lib, quantize as Q
from repro.kernels import ops
from repro.models.base import ArchConfig, Ctx, build_model


@dataclasses.dataclass
class Request:
    uid: int
    prompt: np.ndarray           # (len,) int32
    max_new_tokens: int = 16
    generated: list = dataclasses.field(default_factory=list)
    done: bool = False


class ServeEngine:
    """Greedy continuous-batching decoder for the transformer families."""

    def __init__(self, cfg: ArchConfig, params, *, batch_size: int = 8,
                 max_len: int = 512, pack_weights: bool = True):
        self.cfg = cfg
        self.model = build_model(cfg)
        self.params = params
        self.batch_size = batch_size
        self.max_len = max_len
        self.ctx = Ctx(jax.random.PRNGKey(0), cfg.quant)
        self.packed_bytes = 0
        self.dense_bytes = 0
        if pack_weights:
            self._pack_report()
        self.cache = self.model.init_cache(batch_size, max_len)
        self.lengths = np.zeros((batch_size,), np.int32)
        self.slots: list[Request | None] = [None] * batch_size
        self._decode = jax.jit(
            lambda p, t, c, l: self.model.decode_step(p, t, self.ctx, c, l))

    # ------------------------------------------------------------------
    def _pack_report(self):
        """Pack every projection weight into the MixFP4 wire format and
        record the storage saving (weights are kept dequantized for the
        simulated path; the packed tensors are what HBM would hold)."""
        leaves = jax.tree.leaves(self.params)
        for w in leaves:
            if w.ndim == 2 and w.shape[0] % 16 == 0 and w.shape[1] % 16 == 0:
                bq, shape, blk = Q.block_quantize_2d(np.asarray(w), "mixfp4")
                p = pack_lib.pack_blocks(bq)
                self.packed_bytes += pack_lib.packed_nbytes(p)
                self.dense_bytes += w.size * 2  # bf16 baseline
        if self.dense_bytes:
            self.compression = self.dense_bytes / self.packed_bytes
        else:
            self.compression = 1.0

    # ------------------------------------------------------------------
    def add_request(self, req: Request) -> bool:
        for i, slot in enumerate(self.slots):
            if slot is None:
                self.slots[i] = req
                self._prefill_slot(i, req)
                return True
        return False

    def _prefill_slot(self, i: int, req: Request):
        """Single-slot prefill: run the prompt through decode steps (slot-
        level prefill keeps the engine simple; batch prefill is the
        prefill_32k dry-run path)."""
        toks = np.zeros((self.batch_size,), np.int32)
        for t, tok in enumerate(req.prompt):
            toks[i] = tok
            logits, self.cache = self._decode(
                self.params, jnp.asarray(toks), self.cache,
                jnp.int32(int(self.lengths[i])))
            self.lengths[i] += 1
        req._next = int(jnp.argmax(logits[i]))

    def step(self) -> list[tuple[int, int]]:
        """One decode step for all active slots; returns (uid, token)."""
        toks = np.zeros((self.batch_size,), np.int32)
        active = []
        for i, req in enumerate(self.slots):
            if req is None or req.done:
                continue
            toks[i] = req._next if not req.generated else req.generated[-1]
            active.append(i)
        if not active:
            return []
        cache_len = int(self.lengths[active[0]])
        logits, self.cache = self._decode(
            self.params, jnp.asarray(toks), self.cache, jnp.int32(cache_len))
        out = []
        for i in active:
            tok = int(jnp.argmax(logits[i]))
            req = self.slots[i]
            req.generated.append(tok)
            self.lengths[i] += 1
            out.append((req.uid, tok))
            if len(req.generated) >= req.max_new_tokens:
                req.done = True
                self.slots[i] = None
        return out


# ---------------------------------------------------------------------------
# MixFP4-quantized KV cache (beyond-paper, DESIGN.md §9.3): stores K/V as
# packed payload + scale bytes per (token, head, 16-lane block).  Decode
# memory traffic drops ~3.5x on the cache — the dominant term of decode_32k.
# ---------------------------------------------------------------------------
def quantize_kv(kv: jax.Array):
    """kv: (..., dh) bf16 -> (payload (..., dh//2) u8, scales (..., dh//16) u8,
    per-tensor f32)."""
    shape = kv.shape
    flat = kv.reshape(-1, shape[-1]).astype(jnp.float32)
    payload, scales, s32 = ops.quantize_rows(flat)
    return (payload.reshape(*shape[:-1], shape[-1] // 2),
            scales.reshape(*shape[:-1], shape[-1] // 16), s32)


def dequantize_kv(payload, scales, s32, dtype=jnp.bfloat16):
    from repro.core import formats, scaling
    lo = payload & 0xF
    hi = (payload >> 4) & 0xF
    nib = jnp.stack([lo, hi], axis=-1).reshape(*payload.shape[:-1],
                                               payload.shape[-1] * 2)
    s8, t = scaling.unpack_scale_and_type(scales)
    g = 16
    vals = formats.decode_to_e2m2(
        nib, jnp.repeat(t, g, axis=-1), dtype=jnp.float32)
    full_s = jnp.repeat(s8, g, axis=-1)
    return (vals * full_s * s32).astype(dtype)
