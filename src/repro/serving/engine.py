"""Batched serving engine over packed MixFP4 weights and (optionally) a
packed MixFP4 KV cache.

Production-shaped serving loop: requests join a continuous batch and the
projection weights are held ONLY as packed :class:`~repro.core.qtensor.QTensor`
pytrees — the paper's wire format (4-bit payloads + type-in-sign E4M3 scale
bytes = 4.5 bits/value in HBM, a ~3.55x weight-memory and bandwidth saving
over bf16 in the decode-bound regime).  Every decode step runs through
``qmm`` -> the W4A16 Pallas kernel (interpret mode on CPU, native on TPU),
decoding tiles in VMEM; no dense bf16 copy of a projection weight is
retained anywhere in the engine.

Three hot paths run over packed data end-to-end (docs/serving.md):

* ``kv_quant="mixfp4"`` carries the transformer KV cache as 1-D
  ``BlockLayout1D`` QTensors; every decode step scatters the new token's
  packed K/V bytes in place and reads the cache through the fused Pallas
  decode-attention kernel (``kernels.mixfp4_attn``) — the cache's dense
  bf16 form never exists at decode time, so the dominant decode_32k
  traffic term shrinks ~3.55x too.
* ``act_quant="mixfp4"`` (W4A4) quantizes decode AND prefill activations on
  the fly — in the W4A4 kernel's fused prologue, ONE Pallas dispatch per
  projection — using the same type-in-sign E4M3 block-scale wire encoding,
  the paper's full FP4xFP4 MMA analog (Fig. 9 decode on BOTH operands),
  for the dense, MoE, SSM and hybrid families, under PER-ROW level-2
  activation scales (+4 B/row vs Alg. 1's per-tensor reduction): each
  token row's quantized bytes are a pure function of that row, so a
  request's stream is bitwise-independent of its batchmates, of bucket
  padding, and of chunked-vs-whole prefill.
  ``"mixfp4-2pass-rowscale"`` is the explicit
  ``quantize_rows(per_row=True)`` -> W4A4-kernel two-dispatch composition
  the fused path is bitwise-identical to (the serving-level oracle and
  the degradation-ladder target); ``"mixfp4-2pass"`` is the legacy
  per-tensor two-dispatch baseline (batch-coupled, A/B only) and
  ``"mixfp4-qdq"`` its dequantize-then-W4A16 debugging oracle.
  ``act_rht=True`` additionally applies the grouped random Hadamard
  transform to activations inside the same fused prologue (signs shared
  with the pack-time weight transform, so ``D``/``H`` cancel in every
  dot product) — the serve-time outlier lever from the paper's training
  recipe.
* Admissions prefill through the models' batched ``prefill_slot`` entry:
  the whole prompt runs in ONE jit call at (P, K) prefill shapes through
  the W4A16 kernels, writing all cache rows at once, instead of the
  historical O(prompt_len) token-by-token decode replay (which also needed
  a snapshot/restore dance to keep recurrent batchmates unperturbed).
  For the transformer families, prompts additionally pad up a pow-2/64-step
  length ladder (``prefill_buckets``) so admissions stop compiling one
  prefill executable per distinct prompt length: padded suffix rows are
  causally invisible to the real positions, masked at decode until
  overwritten, and the last-position logits index the true length — the
  emitted stream is bitwise-identical to the unbucketed engine's, under
  W4A16 AND the per-row W4A4 modes (a padded suffix row quantizes under
  its own scale and cannot move a real row's bytes).
  ``prefill_compiles`` / ``prefill_cache_hits`` count the effect.

With ``mesh=`` the engine serves *sharded* packed weights
(docs/sharding.md): every projection QTensor is placed under model-axis
``NamedSharding``s derived by ``distributed.sharding.serve_packed_specs``
(column-parallel N-sharding; MoE expert stacks shard whole experts), decode
runs the W4A16 — or, with ``act_quant="mixfp4"``, the W4A4 — kernel per
shard via ``qmm_sharded``/``shard_map`` (W4A4 quantizes the replicated
activation rows ONCE and replicates the packed bytes), and the layout is
chosen so the output stream stays bitwise-identical to the single-device
packed path.  ``load_weights`` restores a packed checkpoint
straight into the sharded layout.  The KV cache is replicated for now —
its PartitionSpec story is the open ROADMAP item (docs/serving.md).
"""
from __future__ import annotations

import collections
import contextlib
import dataclasses
import enum
import os
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint.manager import CheckpointManager
from repro.core import qtensor
from repro.distributed import sharding as dist_sharding
from repro.models.base import ArchConfig, Ctx, build_model, pack_projections
from repro.serving.faults import InjectedFault, SystemClock
from repro.serving.journal import JournalError, RequestJournal, replay
from repro.serving.kvpool import KVPool
from repro.serving.metrics import MetricsRegistry
from repro.serving.scheduler import ChunkedPrefillScheduler
from repro.serving.watchdog import StepWatchdog

_TRANSFORMER_FAMILIES = ("dense", "moe", "vlm")


class RequestState(str, enum.Enum):
    """Explicit request lifecycle.  QUEUED -> PREFILLING -> RUNNING is the
    happy path; the four terminal states are mutually exclusive and each
    lands with a typed ``finish_reason`` in ``engine.counters``."""
    QUEUED = "QUEUED"
    PREFILLING = "PREFILLING"
    RUNNING = "RUNNING"
    FINISHED = "FINISHED"
    FAILED = "FAILED"
    CANCELLED = "CANCELLED"
    EXPIRED = "EXPIRED"

    def __str__(self) -> str:          # "FINISHED", not "RequestState...."
        return self.value

    @property
    def terminal(self) -> bool:
        return self in (RequestState.FINISHED, RequestState.FAILED,
                        RequestState.CANCELLED, RequestState.EXPIRED)


# Typed rejection reasons (request never entered the queue) --------------
REJECT_EMPTY_PROMPT = "empty_prompt"
REJECT_BAD_MAX_NEW = "bad_max_new_tokens"
REJECT_TOO_LONG = "too_long"
REJECT_OVER_POOL_CAPACITY = "over_pool_capacity"
REJECT_QUEUE_FULL = "queue_full"
REJECT_DRAINING = "draining"

# Typed terminal reasons -------------------------------------------------
REASON_MAX_NEW = "max_new_tokens"          # FINISHED
REASON_NAN_LOGITS = "nan_logits"           # FAILED: poisoned/overflowed row
REASON_INJECTED = "injected_fault"         # FAILED: injected fatal fault
REASON_PREFILL_ERROR = "prefill_error"     # FAILED: admission prefill raised
REASON_COW_ERROR = "cow_error"             # FAILED: COW page copy raised
REASON_POOL_ERROR = "pool_error"           # FAILED: page acquisition raised
REASON_RETRIES = "retries_exhausted"       # FAILED: transient never cleared
REASON_DEADLINE = "deadline"               # EXPIRED: total deadline passed
REASON_TTFT = "ttft_deadline"              # EXPIRED: no first token in budget
REASON_CANCELLED = "user_cancel"           # CANCELLED
REASON_SLOW_CLIENT = "slow_client"         # CANCELLED: sink queue overflow
REASON_WATCHDOG = "watchdog_timeout"       # FAILED: hung-step budget blown


class RequestValidationError(ValueError):
    """A request rejected before touching any engine state (slot, pool
    page, prefix tree).  Subclasses ValueError so historical callers'
    ``except ValueError`` handling keeps working."""

    def __init__(self, reason: str, message: str):
        super().__init__(message)
        self.reason = reason


class QueueFullError(RuntimeError):
    """Backpressure: the bounded admission queue is full.  Callers should
    shed load or retry later; the engine state is untouched."""

    reason = REJECT_QUEUE_FULL


class EngineDrainingError(RuntimeError):
    """The engine is draining (``begin_drain()``): admissions are closed
    while in-flight requests finish.  Clients should retry against a
    replacement instance; the engine state is untouched."""

    reason = REJECT_DRAINING


@dataclasses.dataclass
class Request:
    uid: int
    prompt: np.ndarray           # (len,) int32
    max_new_tokens: int = 16
    generated: list = dataclasses.field(default_factory=list)
    done: bool = False
    # First greedy token, produced by the admission prefill and emitted by
    # the first step() — None until the request has been admitted.  (It
    # used to be injected dynamically by _prefill_slot, so step() on a
    # request that skipped prefill raised AttributeError.)
    _next: int | None = None
    # lifecycle ----------------------------------------------------------
    deadline_ms: float | None = None       # total budget from submission
    ttft_budget_ms: float | None = None    # budget to the FIRST token
    state: RequestState = RequestState.QUEUED
    finish_reason: str | None = None
    error: Exception | None = dataclasses.field(default=None, repr=False)
    submitted_at: float | None = None      # engine-clock seconds
    first_token_at: float | None = None
    _last_token_at: float | None = None    # ITL anchor (metrics)
    _deferrals: int = 0                    # pool-exhaustion re-queues
    _retry_at: float = 0.0                 # backoff gate for re-admission

    def ttft_ms(self) -> float | None:
        if self.submitted_at is None or self.first_token_at is None:
            return None
        return (self.first_token_at - self.submitted_at) * 1e3


def engine_robustness_spec(*, max_queue: int = 64,
                           deadline_ms: float | None = None,
                           ttft_budget_ms: float | None = None,
                           degrade_after_deferrals: int | None = None,
                           kv_pool: int | None = None,
                           act_quant: str | None = None) -> dict:
    """Pure description of an engine's robustness configuration — the
    queue bound, deadline defaults, and which rungs of the degradation
    ladder are armed.  Used by the launch dryrun report (no engine
    build) and mirrored live by ``ServeEngine.robustness_report``."""
    ladder = []
    if act_quant == "mixfp4":
        ladder.append({"from": "fused W4A4 GEMM",
                       "to": "2-pass W4A4 (per-row scales)",
                       "trigger": "failed fused dispatch",
                       "bitwise_preserving": True})
    if kv_pool is not None:
        ladder.append({"from": "paged attention", "to": "fixed-slot",
                       "trigger": (f"admission deferred "
                                   f">= {degrade_after_deferrals} times"
                                   if degrade_after_deferrals
                                   else "disarmed (degrade_after_deferrals"
                                        "=None)"),
                       "bitwise_preserving": kv_pool is not None})
    return {
        "queue": {"max_queue": max_queue},
        "deadlines": {"deadline_ms": deadline_ms,
                      "ttft_budget_ms": ttft_budget_ms},
        "degradation_ladder": ladder,
        "states": [s.value for s in RequestState],
    }


def _prepad_group(act_quant: str) -> str:
    """Tuner path whose tile grid the engine pre-pads packed weights onto.
    Both W4A4 spellings share one tuner cache entry ('w4a4'), so the fused
    kernel and the 2-pass composition see identical storage — preserving
    their bitwise-comparability."""
    return ("w4a4" if act_quant in ("mixfp4", "mixfp4-2pass",
                                    "mixfp4-2pass-rowscale") else "w4a16")


def _prepad_tree(params, group: str, m: int):
    """Pre-pad every 2-D packed projection onto the tuner grid for ``m``
    decode rows (qtensor.prepad_for_tiles), so the per-step ``qmm``
    dispatch stops re-padding packed bytes inside every jitted call."""
    is_qt = lambda x: isinstance(x, qtensor.QTensor)
    return jax.tree.map(
        lambda l: qtensor.prepad_for_tiles(l, group, m) if is_qt(l) else l,
        params, is_leaf=is_qt)


def _packed_stats(tree) -> tuple[int, int]:
    """(wire bytes, bf16-equivalent bytes) over the QTensor leaves of a
    parameter tree — same accounting as models.base.pack_projections."""
    packed = dense = 0
    for leaf in jax.tree.leaves(
            tree, is_leaf=lambda x: isinstance(x, qtensor.QTensor)):
        if isinstance(leaf, qtensor.QTensor):
            packed += leaf.nbytes
            dense += int(np.prod(leaf.shape)) * leaf._batch_size() * 2
    return packed, dense


class ServeEngine:
    """Greedy continuous-batching decoder for the transformer families."""

    def __init__(self, cfg: ArchConfig, params, *, batch_size: int = 8,
                 max_len: int = 512, pack_weights: bool = True,
                 method: str = "mixfp4", kv_quant: str | None = None,
                 act_quant: str | None = None, act_rht: bool = False,
                 mesh=None,
                 prefill_buckets: str | None = "auto",
                 prefill_chunk: int | None = None,
                 kv_pool: int | None = None, kv_page_len: int = 16,
                 max_queue: int = 64, deadline_ms: float | None = None,
                 ttft_budget_ms: float | None = None, faults=None,
                 clock=None, degrade_after_deferrals: int | None = None,
                 retry_max: int = 3, retry_base_ms: float = 10.0,
                 retry_cap_ms: float = 1000.0,
                 journal_dir: str | None = None,
                 journal_sync: str = "batch",
                 hung_step_budget_ms: float | None = None,
                 watchdog_fail_after: int = 2):
        if max_queue < 1:
            raise ValueError(f"max_queue={max_queue} must be >= 1")
        if degrade_after_deferrals is not None and degrade_after_deferrals < 1:
            raise ValueError("degrade_after_deferrals must be None "
                             "(disarmed) or >= 1")
        if cfg.family == "encdec":
            raise ValueError(
                "ServeEngine has no source-encoding path (requests carry "
                "tokens only); an encdec model would cross-attend an "
                "all-zero memory. Drive encdec decoding through "
                "model.prefill(src_embeds)/decode_step directly.")
        if kv_quant not in (None, "bf16", "mixfp4"):
            raise ValueError(f"unknown kv_quant {kv_quant!r} "
                             "(expected None, 'bf16' or 'mixfp4')")
        has_kv = (cfg.family in _TRANSFORMER_FAMILIES
                  or (cfg.family == "hybrid" and cfg.attn_period))
        if kv_quant == "mixfp4" and not has_kv:
            raise ValueError(
                f"kv_quant='mixfp4' packs the attention KV cache; family "
                f"{cfg.family!r} has no KV cache to pack (transformers and "
                "the shared-attention hybrid do)")
        if kv_pool is not None:
            if kv_quant != "mixfp4":
                raise ValueError(
                    "kv_pool= is the paged *packed* KV path; it requires "
                    f"kv_quant='mixfp4' (got {kv_quant!r})")
            if mesh is not None:
                raise ValueError(
                    "kv_pool= with mesh= is not wired yet: the paged "
                    "attention kernel's block-table prefetch has no "
                    "shard_map spec (the fixed-slot packed cache serves "
                    "sharded engines)")
            if kv_page_len % 16 or max_len % kv_page_len:
                raise ValueError(
                    f"kv_page_len={kv_page_len} must be a multiple of 16 "
                    f"(the MixFP4 block) and divide max_len={max_len}")
        if act_quant not in (None, "bf16", "mixfp4", "mixfp4-2pass",
                             "mixfp4-2pass-rowscale", "mixfp4-qdq"):
            raise ValueError(
                f"unknown act_quant {act_quant!r} (expected None, 'bf16', "
                "'mixfp4' (fused per-row quantize+GEMM), "
                "'mixfp4-2pass-rowscale' (its two-dispatch bitwise oracle), "
                "'mixfp4-2pass' (the legacy per-tensor composition), or "
                "the 'mixfp4-qdq' debugging oracle)")
        if act_quant in ("mixfp4", "mixfp4-2pass", "mixfp4-2pass-rowscale",
                         "mixfp4-qdq") and not pack_weights:
            raise ValueError(
                "act_quant='mixfp4' is the W4A4 path — both GEMM operands "
                "on the wire format — which needs packed weights; drop "
                "pack_weights=False")
        if act_rht:
            if act_quant not in ("mixfp4", "mixfp4-2pass-rowscale"):
                raise ValueError(
                    "act_rht=True rotates activations AND packed weights "
                    "with a shared grouped Hadamard, which only the "
                    "per-row W4A4 modes consume; it requires "
                    "act_quant='mixfp4' or 'mixfp4-2pass-rowscale' "
                    f"(got {act_quant!r})")
            if not pack_weights:
                raise ValueError(
                    "act_rht=True transforms the weights at pack time "
                    "(pack_projections(act_rht=True)); drop "
                    "pack_weights=False")
        if prefill_buckets not in (None, "off", "auto", "pow2-64"):
            raise ValueError(
                f"unknown prefill_buckets {prefill_buckets!r} (expected "
                "None/'off', 'auto', or 'pow2-64')")
        if prefill_buckets == "pow2-64" \
                and cfg.family not in _TRANSFORMER_FAMILIES:
            raise ValueError(
                "prefill_buckets pads the prompt with suffix tokens, which "
                "is only sound for the transformer families (KV rows "
                "beyond the true length are masked/overwritten); the SSM "
                f"recurrent state of family {cfg.family!r} advances for "
                "every padded token")
        if prefill_chunk is not None:
            if cfg.family not in _TRANSFORMER_FAMILIES:
                raise ValueError(
                    "prefill_chunk= splits an admission's prefill into "
                    "fixed-token-budget chunks interleaved with decode, "
                    "which is only sound for the transformer families "
                    "(KV rows quantize write-order-independently and the "
                    "padded final chunk is masked); the SSM recurrent "
                    f"state of family {cfg.family!r} advances per token "
                    "and has no start_pos resume path (ROADMAP "
                    "carry-over: needs state checkpoints at chunk "
                    "boundaries)")
            if prefill_chunk < 1:
                raise ValueError(
                    f"prefill_chunk={prefill_chunk} must be >= 1 token")
            if prefill_buckets == "pow2-64":
                raise ValueError(
                    "prefill_chunk= already runs every chunk at ONE "
                    "static shape (the chunk budget); it replaces the "
                    "prefill_buckets ladder — drop "
                    "prefill_buckets='pow2-64'")
        if mesh is not None and not pack_weights:
            raise ValueError(
                "mesh serving is the sharded *packed* path (QTensor "
                "payload/scales under model-axis NamedShardings); "
                "pack_weights=False has no sharded serve layout")
        self.cfg = cfg
        self.model = build_model(cfg)
        self.batch_size = batch_size
        self.max_len = max_len
        self.kv_quant = kv_quant or "bf16"
        self.act_quant = act_quant or "bf16"
        self.act_rht = act_rht
        self.mesh = mesh
        self.ctx = Ctx(jax.random.PRNGKey(0), cfg.quant, mesh=mesh,
                       act_quant=self.act_quant, act_rht=act_rht)
        if pack_weights:
            # Projection weights become packed QTensors; the dense leaves
            # are dropped from this tree (callers should release their own
            # reference if they want the full HBM saving).
            self.params, self.packed_bytes, self.dense_bytes = \
                pack_projections(params, method=method, act_rht=act_rht)
            if mesh is not None:
                # model-axis TP placement: payload/scales co-sharded at
                # block granularity, logical pspec recorded in the aux so
                # qlinear dispatches qmm_sharded; dense leaves (embed,
                # norms — the paper's exclusions) replicate
                self.weight_specs = dist_sharding.serve_packed_specs(
                    self.params, mesh)
                self.params = dist_sharding.shard_packed_tree(
                    self.params, self.weight_specs, mesh)
        else:
            self.params = params
            self.packed_bytes = self.dense_bytes = 0
        self.compression = (self.dense_bytes / self.packed_bytes
                            if self.packed_bytes else 1.0)
        if pack_weights and mesh is None:
            # pre-pad packed projections onto the decode-shape tuner grid
            # (storage only; stats above keep the logical wire bytes)
            self.params = _prepad_tree(
                self.params, _prepad_group(self.act_quant), batch_size)
        # paged KV pool (kv_pool = number of physical pages; page 0 is the
        # pool's trash page).  Prefix caching needs suffix prefill to be
        # bitwise-equal to full prefill, i.e. ROW-INDEPENDENT prefill:
        # the hybrid's SSM state recurs over the whole prompt, and MoE's
        # capacity router couples rows (cap = f(token count), so a short
        # suffix competes for different expert capacity than the full
        # prompt did).  Only the dense transformer family qualifies; the
        # others ride the pool as a plain page allocator.
        self.kv_pool_pages = kv_pool
        self.kv_page_len = kv_page_len
        if kv_pool is not None:
            self.kv_pool = KVPool(
                kv_pool, kv_page_len,
                enable_prefix=cfg.family == "dense")
            self.cache = self.model.init_cache(
                batch_size, max_len, kv_quant="mixfp4",
                pages=(kv_pool, kv_page_len))
            self.block_tables = np.zeros(
                (batch_size, max_len // kv_page_len), np.int32)
            self._slot_pages: list = [None] * batch_size
            self._copy_page = jax.jit(self._cow_copy)
        else:
            self.kv_pool = None
            if self.kv_quant == "mixfp4":
                self.cache = self.model.init_cache(batch_size, max_len,
                                                   kv_quant="mixfp4")
            else:
                self.cache = self.model.init_cache(batch_size, max_len)
        self.lengths = np.zeros((batch_size,), np.int32)
        self.slots: list[Request | None] = [None] * batch_size
        self.prefill_dispatches = 0   # jit dispatches spent on admissions
        self.admissions = 0
        self.max_concurrent = 0       # peak active slots seen by step()
        # request lifecycle: bounded admission queue, deadline defaults,
        # seeded fault injector (None in production), retry policy.  With
        # an injector installed the engine runs on ITS clock (a virtual
        # clock by default), so deadlines / TTFT / backoff are pure
        # functions of the fault schedule.
        self.max_queue = max_queue
        self.deadline_ms = deadline_ms
        self.ttft_budget_ms = ttft_budget_ms
        self.faults = faults
        if clock is not None:
            self.clock = clock
        elif faults is not None:
            self.clock = faults.clock
        else:
            self.clock = SystemClock()
        self.degrade_after_deferrals = degrade_after_deferrals
        self.retry_max = retry_max
        self.retry_base_ms = retry_base_ms
        self.retry_cap_ms = retry_cap_ms
        self.queue: collections.deque[Request] = collections.deque()
        self.requests: dict[int, Request] = {}   # uid -> every seen request
        self.counters: collections.Counter = collections.Counter()
        self._step_poison: set = set()
        # prompt-length bucketing (transformer families): pad prompts up a
        # pow-2/64-step ladder so admissions reuse one compiled prefill per
        # bucket instead of compiling per distinct length
        if prefill_buckets == "auto":
            prefill_buckets = ("pow2-64"
                               if cfg.family in _TRANSFORMER_FAMILIES
                               and prefill_chunk is None
                               else None)
        self.prefill_buckets = (None if prefill_buckets in (None, "off")
                                else prefill_buckets)
        self.prefill_compiles = 0      # distinct prefill shapes traced
        self.prefill_cache_hits = 0    # admissions that reused a shape
        self._prefill_lens: set = set()
        self._paged_suffix = (self.kv_pool is not None
                              and self.kv_pool.enable_prefix)
        # chunked-prefill scheduler (serving.scheduler): admissions enqueue
        # a PrefillJob instead of prefilling inline, and step() spends at
        # most prefill_chunk prompt tokens per step before decoding
        self.prefill_chunk = prefill_chunk
        self.scheduler = (ChunkedPrefillScheduler(prefill_chunk)
                          if prefill_chunk is not None else None)
        # observability (serving.metrics): the engine worker is the only
        # writer; readers take snapshot dicts via metrics_report()
        self.metrics = MetricsRegistry()
        self._step_prefill_tokens = 0   # prompt tokens spent this step
        self.max_prefill_tokens_per_step = 0
        # durability: append-only request journal (admission prompts,
        # emitted tokens, terminal transitions) + graceful-drain flag +
        # hung-step watchdog.  The journal writes THROUGH the existing
        # state machine (submit/_mark_terminal/step), so replaying it
        # reconstructs exactly the lifecycle the counters saw.
        self.journal_sync = journal_sync
        self.journal = (RequestJournal(journal_dir, sync=journal_sync)
                        if journal_dir is not None else None)
        self.draining = False
        self.recovered_uids: list[int] = []
        self._weights_pin: dict | None = None   # journal <-> ckpt pinning
        self.watchdog = (StepWatchdog(hung_step_budget_ms,
                                      fail_after=watchdog_fail_after)
                         if hung_step_budget_ms is not None else None)
        self._build_jits()

    def _build_jits(self):
        """(Re)build the decode/prefill jit closures for the engine's
        CURRENT ``ctx``/``_paged_suffix``.  Called at init and again by the
        degradation rungs (fused -> 2-pass rebinds ctx.act_quant; paged ->
        fixed-slot drops the block-table operand)."""
        self._decode = jax.jit(
            lambda p, t, c, l: self.model.decode_step(p, t, self.ctx, c, l))
        # prefix-caching prefills take the suffix start as a dynamic
        # operand (prefix-cached admissions prefill only tokens[shared:]);
        # plain-allocator pools (hybrid/MoE) always start at 0
        paged_sfx = self._paged_suffix
        if self.prefill_buckets and paged_sfx:
            self._prefill = jax.jit(
                lambda p, t, c, i, n, s0: self.model.prefill_slot(
                    p, t, self.ctx, c, i, true_len=n, start_pos=s0))
        elif self.prefill_buckets:
            self._prefill = jax.jit(
                lambda p, t, c, i, n: self.model.prefill_slot(
                    p, t, self.ctx, c, i, true_len=n))
        elif paged_sfx:
            self._prefill = jax.jit(
                lambda p, t, c, i, s0: self.model.prefill_slot(
                    p, t, self.ctx, c, i, start_pos=s0))
        else:
            # one dispatch per admission; recompiles per distinct prompt
            # length (prefill shapes)
            self._prefill = jax.jit(
                lambda p, t, c, i: self.model.prefill_slot(
                    p, t, self.ctx, c, i))
        # chunked prefill always rides true_len (the final partial chunk
        # pads up to the budget) + start_pos (the chunk cursor) — ONE
        # compiled prefill executable for the whole engine
        if getattr(self, "scheduler", None) is not None:
            self._chunk_prefill = jax.jit(
                lambda p, t, c, i, n, s0: self.model.prefill_slot(
                    p, t, self.ctx, c, i, true_len=n, start_pos=s0))

    # ------------------------------------------------------------------
    # paged-pool device helpers
    # ------------------------------------------------------------------
    def _cow_copy(self, cache, src, dst):
        """Copy page ``src``'s packed bytes into page ``dst`` in both K and
        V slabs — the eager copy-on-write step of a partial prefix hit
        (serving.kvpool).  Page axis is axis 1 of every child (behind the
        layer/app axis)."""
        def cp(qt):
            return qtensor.QTensor(
                qt.payload.at[:, dst].set(qt.payload[:, src]),
                qt.scales.at[:, dst].set(qt.scales[:, src]),
                qt.scale32, qt.method, qt.layout, qt.shape, qt.dtype)
        return dict(cache, k=cp(cache["k"]), v=cp(cache["v"]))

    def _mesh_ctx(self):
        """Ambient-mesh context for jit traces: activates the models'
        ``shard()`` constraints and the mesh-aware ``qlinear`` dispatch
        (no-op for single-device engines)."""
        return self.mesh if self.mesh is not None else contextlib.nullcontext()

    # ------------------------------------------------------------------
    # storage accounting
    # ------------------------------------------------------------------
    def kv_cache_bytes(self) -> int:
        """HBM bytes held by the KV/state cache (QTensor leaves count their
        wire bytes — 4.5 bits/value instead of bf16's 16)."""
        total = 0
        for leaf in jax.tree.leaves(
                self.cache, is_leaf=lambda x: isinstance(x, qtensor.QTensor)):
            total += int(leaf.nbytes)
        return total

    # ------------------------------------------------------------------
    # packed-weight checkpointing: the QTensor pytree round-trips through
    # CheckpointManager (payload/scales/scale32 are ordinary leaves; the
    # static layout metadata travels in the manifest spec).
    # ------------------------------------------------------------------
    def save_weights(self, directory: str, step: int = 0):
        mgr = CheckpointManager(directory)
        mgr.save_packed(step, self.params, blocking=True)
        self._pin_weights(directory, step, mgr)

    def _pin_weights(self, directory: str, step: int, mgr):
        """Record the packed-checkpoint pin (step + manifest fingerprint)
        on the engine AND in the journal, so ``recover()`` can refuse to
        resume journaled streams against different weight bytes."""
        try:
            fp = mgr.packed_fingerprint(step)
        except (OSError, ValueError, KeyError):
            fp = None
        self._weights_pin = {"dir": str(directory), "step": int(step),
                             "fingerprint": fp}
        self._journal_append({"t": "ckpt", "dir": str(directory),
                              "step": int(step), "fp": fp})
        self._journal_flush()

    def load_weights(self, directory: str, step: int | None = None):
        """Restore a packed checkpoint; a mesh engine restores each leaf
        *directly* into the sharded serve layout (per-child NamedShardings
        derived from the manifest's structural spec before any leaf bytes
        are read — no replicated intermediate tree)."""
        mgr = CheckpointManager(directory)
        if self.mesh is None:
            # checkpoint-restore I/O is the canonical transient failure
            # (flaky network filesystems): capped-backoff retries behind
            # the 'checkpoint_read' fault boundary
            restored, _ = self._with_retries(
                "checkpoint_read", lambda: mgr.restore_packed(step),
                retryable=(OSError,))
        else:
            step, spec = mgr.packed_spec(step)
            like = qtensor.tree_like(spec)
            qt_leaves = [l for l in jax.tree.leaves(
                like, is_leaf=lambda x: isinstance(x, qtensor.QTensor))
                if isinstance(l, qtensor.QTensor)]
            if all(isinstance(q.payload, jax.ShapeDtypeStruct)
                   for q in qt_leaves):
                # manifest records child shapes: derive per-child
                # NamedShardings up front and restore each leaf straight
                # onto its shards (no replicated intermediate)
                specs = dist_sharding.serve_packed_specs(like, self.mesh)
                shardings = dist_sharding.packed_restore_shardings(
                    like, specs, self.mesh)
                restored, _ = self._with_retries(
                    "checkpoint_read",
                    lambda: mgr.restore_packed(step, shardings=shardings),
                    retryable=(OSError,))
            else:
                # pre-child-shape manifest (dummy-leaf skeleton): restore
                # replicated first, then derive the layout from the
                # concrete tree and move the leaves
                restored, _ = mgr.restore_packed(step)
                specs = dist_sharding.serve_packed_specs(restored, self.mesh)
            # re-placing is a no-op move for already-placed leaves; it
            # restamps each QTensor's aux pspec to THIS engine's layout
            # (the checkpoint may have been saved under a different one)
            restored = dist_sharding.shard_packed_tree(restored, specs,
                                                       self.mesh)
            self.weight_specs = specs
        if self.act_rht and not (isinstance(restored, dict)
                                 and "rht_signs" in restored):
            raise ValueError(
                "act_rht=True engine restored a checkpoint with no "
                "'rht_signs' entry: the packed weights were not "
                "Hadamard-transformed at pack time "
                "(pack_projections(act_rht=True)), so the activation RHT "
                "would no longer cancel in the GEMM")
        self.params = restored
        # recompute storage stats from what was actually restored (a cold
        # engine built with pack_weights=False would otherwise keep 0/1.0)
        self.packed_bytes, self.dense_bytes = _packed_stats(restored)
        self.compression = (self.dense_bytes / self.packed_bytes
                            if self.packed_bytes else 1.0)
        if self.mesh is None:
            self.params = _prepad_tree(
                self.params, _prepad_group(self.act_quant), self.batch_size)
        if step is None:
            step = mgr.latest_step()
        if step is not None:
            self._pin_weights(directory, step, mgr)

    # ------------------------------------------------------------------
    # request lifecycle: validation, bounded queue, admission, faults
    # ------------------------------------------------------------------
    def _validate(self, req: Request):
        """Reject malformed requests BEFORE any engine state is touched —
        no slot, no pool page, no prefix-tree refcount.  (The over-pool-
        capacity check in particular used to be discovered only inside
        ``kv_pool.acquire``, i.e. after walking the prefix tree.)"""
        if len(req.prompt) == 0:
            self.counters[f"rejected:{REJECT_EMPTY_PROMPT}"] += 1
            raise RequestValidationError(
                REJECT_EMPTY_PROMPT,
                "empty prompt: a request must carry at least one prompt "
                "token")
        if req.max_new_tokens < 1:
            self.counters[f"rejected:{REJECT_BAD_MAX_NEW}"] += 1
            raise RequestValidationError(
                REJECT_BAD_MAX_NEW,
                "max_new_tokens must be >= 1 (the prefill itself produces "
                "the first token)")
        # the final generated token is emitted but never fed back, so the
        # highest cache position written is prompt + max_new - 2
        if len(req.prompt) + req.max_new_tokens - 1 > self.max_len:
            self.counters[f"rejected:{REJECT_TOO_LONG}"] += 1
            raise RequestValidationError(
                REJECT_TOO_LONG,
                f"request {req.uid} needs {len(req.prompt)} prompt + "
                f"{req.max_new_tokens} new tokens but the cache holds "
                f"max_len={self.max_len}")
        if self.kv_pool is not None:
            need = self.kv_pool.pages_needed(len(req.prompt),
                                             req.max_new_tokens)
            if need > self.kv_pool.pages_total:
                self.counters[f"rejected:{REJECT_OVER_POOL_CAPACITY}"] += 1
                raise RequestValidationError(
                    REJECT_OVER_POOL_CAPACITY,
                    f"request {req.uid} needs {need} pool pages but the "
                    f"pool holds {self.kv_pool.pages_total} (deferring it "
                    "would livelock: no amount of draining frees enough)")

    def submit(self, req: Request):
        """Enqueue a request on the bounded admission queue (strict FIFO).
        Raises :class:`RequestValidationError` / :class:`QueueFullError` /
        :class:`EngineDrainingError` with a typed reason; on success the
        request is QUEUED and will be admitted by a later ``step()`` as
        slots and pool pages free up."""
        self._validate(req)
        if self.draining:
            self.counters[f"rejected:{REJECT_DRAINING}"] += 1
            raise EngineDrainingError(
                "engine is draining: admissions are closed while "
                "in-flight requests finish (retry against a replacement "
                "instance)")
        if len(self.queue) >= self.max_queue:
            self.counters[f"rejected:{REJECT_QUEUE_FULL}"] += 1
            raise QueueFullError(
                f"admission queue is full ({self.max_queue} requests); "
                "shed load or retry after a drain")
        req.state = RequestState.QUEUED
        req.submitted_at = self.clock()
        self.requests[req.uid] = req
        self.queue.append(req)
        self.counters["submitted"] += 1
        self._journal_submit(req)

    def cancel(self, uid: int, reason: str = REASON_CANCELLED) -> bool:
        """Cancel a queued or in-flight request.  Returns True if the
        request transitioned to CANCELLED (slot and pool pages released);
        False if it is unknown or already terminal.  ``reason`` types the
        terminal verdict (``user_cancel`` by default; the HTTP front-end
        passes ``slow_client`` for sink-overflow evictions)."""
        req = self.requests.get(uid)
        if req is None or req.state.terminal:
            return False
        if req.state is RequestState.QUEUED:
            with contextlib.suppress(ValueError):
                self.queue.remove(req)
            self._mark_terminal(req, RequestState.CANCELLED, reason)
            return True
        i = next(i for i, s in enumerate(self.slots) if s is req)
        self._finish_request(i, RequestState.CANCELLED, reason)
        return True

    def has_work(self) -> bool:
        return bool(self.queue) or any(
            s is not None and not s.done for s in self.slots)

    # -- journal write-through -----------------------------------------
    def _journal_append(self, rec: dict):
        """Append one record behind the ``journal_write`` fault boundary.
        Transients (and real OSErrors) retry with capped backoff; a fatal
        failure DISABLES journaling and keeps serving (fail-open: losing
        durability is a counter + alert, not an outage) — recovery then
        resumes from the last committed record, which greedy determinism
        makes safe (the re-decoded tokens are bitwise the lost ones)."""
        if self.journal is None:
            return
        try:
            self._with_retries("journal_write",
                               lambda: self.journal.append(rec),
                               retryable=(OSError,))
        except (InjectedFault, OSError) as e:
            self.counters["journal_write_failed"] += 1
            self.counters["journal_disabled"] = 1
            with contextlib.suppress(Exception):
                self.journal.close()
            self.journal = None
            del e

    def _journal_submit(self, req: Request):
        if self.journal is None:
            return
        rec = {"t": "submit", "uid": req.uid,
               "prompt": [int(t) for t in np.asarray(req.prompt).ravel()],
               "max_new_tokens": int(req.max_new_tokens)}
        if req.deadline_ms is not None:
            rec["deadline_ms"] = req.deadline_ms
        if req.ttft_budget_ms is not None:
            rec["ttft_budget_ms"] = req.ttft_budget_ms
        self._journal_append(rec)

    def _journal_flush(self):
        """Step-boundary flush: under ``journal_sync='batch'`` this is the
        one fsync that commits the whole step's token records."""
        if self.journal is not None:
            try:
                self.journal.flush()
            except OSError:
                self.counters["journal_write_failed"] += 1
                self.counters["journal_disabled"] = 1
                with contextlib.suppress(Exception):
                    self.journal.close()
                self.journal = None

    # -- fault hooks / clock -------------------------------------------
    def _fire(self, site: str, *, uid: int | None = None, scoped=True):
        """Cross one injector boundary.  ``scoped`` sites victimize the
        request passed as ``uid``; the decode site victimizes among all
        active requests.  Returns the FaultAction (or None)."""
        if self.faults is None:
            return None
        active = () if scoped else tuple(
            r.uid for r in self.slots if r is not None and not r.done)
        act = self.faults.fire(site, uid=uid, active_uids=active)
        if act.delay_ms:
            self.counters["injected_slow_ms"] += int(act.delay_ms)
        return act

    def _sleep(self, seconds: float):
        """Backoff sleep on the engine clock: a virtual clock advances
        deterministically, the system clock really sleeps (capped)."""
        self.clock.sleep(seconds)

    def _backoff_s(self, attempt: int) -> float:
        """Capped exponential backoff: base * 2^(attempt-1), in seconds."""
        return min(self.retry_base_ms * 2.0 ** max(attempt - 1, 0),
                   self.retry_cap_ms) / 1e3

    def _with_retries(self, site: str, fn, *, uid=None, retryable=()):
        """Run ``fn`` behind the ``site`` fault boundary with capped
        exponential backoff on transient failures (injected transients and
        any real exception type in ``retryable``, e.g. OSError for
        checkpoint reads).  Non-transient faults propagate immediately;
        exhausting the budget re-raises the last failure."""
        attempt = 0
        while True:
            try:
                act = self._fire(site, uid=uid)
                if act is not None and act.error is not None:
                    raise act.error
                return fn() if fn is not None else act
            except InjectedFault as e:
                if not e.transient:
                    raise
                last = e
            except retryable as e:
                last = e
            attempt += 1
            if attempt > self.retry_max:
                self.counters[f"retries_exhausted:{site}"] += 1
                raise last
            self.counters[f"retries:{site}"] += 1
            self._sleep(self._backoff_s(attempt))

    # -- admission ------------------------------------------------------
    def add_request(self, req: Request) -> bool:
        """Direct (queue-bypassing) admission — the historical API.
        Returns True when the request was CONSUMED (admitted, or failed
        terminally by an injected admission fault), False when the caller
        should retry later (no free slot / pool exhausted)."""
        self._validate(req)
        if req.submitted_at is None:
            req.submitted_at = self.clock()
        if req.uid not in self.requests:
            self._journal_submit(req)    # once per uid across re-tries
        self.requests[req.uid] = req
        res = self._try_admit(req)
        if res == "deferred":
            req._deferrals += 1
            self.counters["deferred_admissions"] += 1
        return res in ("admitted", "failed")

    def _try_admit(self, req: Request) -> str:
        """Try to place ``req`` in a free slot: 'admitted', 'no_slot',
        'deferred' (pool exhausted — retryable), or 'failed' (a fatal
        admission fault consumed the request; its slot and pages were
        rolled back and it is terminally FAILED)."""
        free = next((i for i, s in enumerate(self.slots) if s is None), None)
        if free is None:
            return "no_slot"
        i = free
        if req.generated:
            # a recovered request resumes mid-stream: re-prefill its full
            # token history instead of just the prompt
            return self._resume_admit(i, req)
        if self.kv_pool is None:
            self.slots[i] = req
            req.state = RequestState.PREFILLING
            # a reused slot starts over at position 0 with zeroed cache
            # rows — no KV / SSM state leaks from the previous occupant
            self.lengths[i] = 0
            self.cache = self.model.reset_slot(self.cache, i)
            if self.scheduler is not None:
                # chunked admission: the slot is held but no prefill runs
                # here — step() drains the job one chunk at a time.  While
                # PREFILLING, lengths[i] tracks the chunk cursor so the
                # batched decode's junk scatter for this lane always lands
                # at the NEXT chunk's start row, where it is masked until
                # overwritten by that chunk's real write.
                self.scheduler.enqueue(req.uid, i, req, len(req.prompt))
                return "admitted"
            if not self._guarded_prefill(i, req):
                return "failed"
            req.state = RequestState.RUNNING
            return "admitted"
        # paged path: admit by PAGE availability too — map cached prefix
        # pages, allocate the rest (evicting LRU cached pages as needed).
        # A pool that cannot cover the request defers it.
        act = self._fire("pool_acquire", uid=req.uid)
        if act is not None and act.error is not None:
            if act.error.transient:
                return "deferred"      # backs off like real exhaustion
            self._mark_terminal(req, RequestState.FAILED, REASON_POOL_ERROR,
                                error=act.error)
            return "failed"
        denied = act is not None and act.deny
        adm = None if denied else self.kv_pool.acquire(req.prompt,
                                                       req.max_new_tokens)
        if denied:
            self.counters["injected_pool_denials"] += 1
        if adm is None:
            return "deferred"
        self.slots[i] = req
        req.state = RequestState.PREFILLING
        self.lengths[i] = 0
        self.cache = self.model.reset_slot(self.cache, i)
        self._slot_pages[i] = adm.pages
        row = np.zeros((self.block_tables.shape[1],), np.int32)
        row[:len(adm.pages)] = adm.pages
        self.block_tables[i] = row
        self.cache = dict(self.cache,
                          pages=jnp.asarray(self.block_tables))
        if adm.cow is not None:
            cow_act = self._fire("cow_copy", uid=req.uid)
            if cow_act is not None and cow_act.error is not None:
                # pool-page failure mid-COW: quarantine via the same
                # rollback as any admission fault — _finish_slot releases
                # the acquired pages (kvpool.release unwinds refcounts for
                # pages never registered in the tree too)
                self._finish_request(i, RequestState.FAILED,
                                     REASON_COW_ERROR, error=cow_act.error)
                return "failed"
            src, dst = adm.cow
            self.cache = self._copy_page(self.cache, jnp.int32(src),
                                         jnp.int32(dst))
        if self.scheduler is not None:
            # chunked admission (pages mapped, prefix COW done): prefill
            # starts at the cached-prefix cursor; kv_pool.insert is
            # DEFERRED to job completion — no page may be registered for
            # prefix hits until its bytes are final.
            self.scheduler.enqueue(req.uid, i, req, len(req.prompt),
                                   start_pos=adm.shared_len)
            self.lengths[i] = adm.shared_len
            return "admitted"
        if not self._guarded_prefill(i, req, start_pos=adm.shared_len):
            return "failed"
        # register the prompt's pages for future prefix hits (their
        # bytes are final now: eager COW means no shared page is ever
        # written after this point)
        self.kv_pool.insert(req.prompt, adm.pages)
        req.state = RequestState.RUNNING
        return "admitted"

    def _resume_admit(self, i: int, req: Request) -> str:
        """Admit a request that already holds generated tokens (recovery
        after a restart): re-prefill its full history
        ``prompt ++ generated[:-1]`` into slot ``i`` — the same replay
        the paged->fixed-slot degradation rung uses, value-preserving
        under greedy decode and *bitwise* under W4A16 and the per-row
        W4A4 modes (the pinned ``KV_SCALE32`` write-order contract makes
        every cache row a pure function of the token history).  Decode
        then continues by feeding ``generated[-1]`` at the history
        length, exactly where the pre-crash engine stopped.

        The history runs in ONE prefill dispatch even on chunked-prefill
        engines (chunked prefill is bitwise whole-prefill, so skipping
        the chunk ledger changes cost, not bytes).  On paged engines the
        pages stay anonymous (not prefix-registered): the trailing page
        is still being written by decode, and a restarted pool has no
        sharers to serve anyway."""
        hist_tail = np.asarray(req.generated[:-1], np.int32)
        history = np.asarray(req.prompt, np.int32)
        if hist_tail.size:
            history = np.concatenate([history, hist_tail])
        # same final cache footprint as the original request:
        # len(history) + shim_new - 1 == len(prompt) + max_new - 1
        shim_new = req.max_new_tokens - max(len(req.generated) - 1, 0)
        shim = Request(uid=req.uid, prompt=history,
                       max_new_tokens=shim_new)
        if self.kv_pool is not None:
            act = self._fire("pool_acquire", uid=req.uid)
            if act is not None and act.error is not None:
                if act.error.transient:
                    return "deferred"
                self._mark_terminal(req, RequestState.FAILED,
                                    REASON_POOL_ERROR, error=act.error)
                return "failed"
            if act is not None and act.deny:
                self.counters["injected_pool_denials"] += 1
                return "deferred"
            adm = self.kv_pool.acquire(history, shim_new)
            if adm is None:
                return "deferred"
            self.slots[i] = req
            req.state = RequestState.PREFILLING
            self.lengths[i] = 0
            self.cache = self.model.reset_slot(self.cache, i)
            self._slot_pages[i] = adm.pages
            row = np.zeros((self.block_tables.shape[1],), np.int32)
            row[:len(adm.pages)] = adm.pages
            self.block_tables[i] = row
            self.cache = dict(self.cache,
                              pages=jnp.asarray(self.block_tables))
            if adm.cow is not None:
                cow_act = self._fire("cow_copy", uid=req.uid)
                if cow_act is not None and cow_act.error is not None:
                    self._finish_request(i, RequestState.FAILED,
                                         REASON_COW_ERROR,
                                         error=cow_act.error)
                    return "failed"
                src, dst = adm.cow
                self.cache = self._copy_page(self.cache, jnp.int32(src),
                                             jnp.int32(dst))
            start_pos = adm.shared_len
        else:
            self.slots[i] = req
            req.state = RequestState.PREFILLING
            self.lengths[i] = 0
            self.cache = self.model.reset_slot(self.cache, i)
            start_pos = 0
        try:
            self._with_retries("prefill", None, uid=req.uid)
            self._prefill_slot(i, shim, start_pos=start_pos)
        except InjectedFault as e:
            reason = REASON_RETRIES if e.transient else REASON_INJECTED
            self._finish_request(i, RequestState.FAILED, reason, error=e)
            return "failed"
        except Exception as e:
            self._finish_request(i, RequestState.FAILED,
                                 REASON_PREFILL_ERROR, error=e)
            raise
        # lengths[i] = len(history) (set by _prefill_slot); the resumed
        # decode feeds generated[-1] there next step, exactly where the
        # pre-crash engine stopped.  (A request with NO emitted tokens
        # never lands here — it re-admits through the ordinary
        # prompt-prefill path, which stages the first token itself.)
        req.state = RequestState.RUNNING
        self.counters["resumed"] += 1
        return "admitted"

    def _guarded_prefill(self, i: int, req: Request, start_pos: int = 0):
        """Admission prefill behind the 'prefill' fault boundary.  On a
        fatal fault the slot is quarantined (pages released, prefix-tree
        refcounts unwound, block-table row pointed at the trash page) and
        the request lands FAILED with a typed reason; a REAL prefill
        exception additionally propagates after the same rollback, so the
        engine never holds a half-admitted slot."""
        try:
            self._with_retries("prefill", None, uid=req.uid)
            self._prefill_slot(i, req, start_pos=start_pos)
            return True
        except InjectedFault as e:
            reason = REASON_RETRIES if e.transient else REASON_INJECTED
            self._finish_request(i, RequestState.FAILED, reason, error=e)
            return False
        except Exception as e:
            self._finish_request(i, RequestState.FAILED,
                                 REASON_PREFILL_ERROR, error=e)
            raise

    # -- queue pump / deadlines ----------------------------------------
    def _pump(self):
        """Admit from the bounded queue in strict FIFO order.  A deferred
        head (pool exhausted) backs off exponentially; while it backs off
        nothing behind it is admitted (FIFO fairness).  An IDLE engine
        sleeps the clock up to the head's retry gate instead of spinning —
        with a virtual clock this is what makes deferred admissions
        livelock-free."""
        while self.queue:
            head = self.queue[0]
            if head.state is not RequestState.QUEUED:
                self.queue.popleft()       # cancelled/expired while queued
                continue
            now = self.clock()
            if head._retry_at > now:
                if any(s is not None for s in self.slots):
                    return                 # let the batch drain first
                self._sleep(head._retry_at - now)
                continue
            res = self._try_admit(head)
            if res in ("admitted", "failed"):
                self.queue.popleft()
                continue
            if res == "no_slot":
                return
            # deferred: pool exhausted past what a drain may free
            head._deferrals += 1
            self.counters["deferred_admissions"] += 1
            if (self.degrade_after_deferrals is not None
                    and head._deferrals >= self.degrade_after_deferrals
                    and self.kv_pool is not None):
                self._degrade_to_fixed_slot()
                continue                   # re-admit on the fixed path
            head._retry_at = self.clock() + self._backoff_s(head._deferrals)
            return

    def _deadline_for(self, req: Request) -> float | None:
        return req.deadline_ms if req.deadline_ms is not None \
            else self.deadline_ms

    def _ttft_for(self, req: Request) -> float | None:
        return req.ttft_budget_ms if req.ttft_budget_ms is not None \
            else self.ttft_budget_ms

    def _expire_deadlines(self):
        """Expire queued and in-flight requests past their total deadline,
        and first-token-less requests past their TTFT budget."""
        now = self.clock()

        def over(req, budget_ms):
            return (budget_ms is not None and req.submitted_at is not None
                    and (now - req.submitted_at) * 1e3 > budget_ms)

        for req in [r for r in self.queue
                    if r.state is RequestState.QUEUED]:
            if over(req, self._deadline_for(req)) \
                    or over(req, self._ttft_for(req)):
                reason = (REASON_DEADLINE
                          if over(req, self._deadline_for(req))
                          else REASON_TTFT)
                with contextlib.suppress(ValueError):
                    self.queue.remove(req)
                self._mark_terminal(req, RequestState.EXPIRED, reason)
        for i, req in enumerate(self.slots):
            if req is None or req.done:
                continue
            if over(req, self._deadline_for(req)):
                self._finish_request(i, RequestState.EXPIRED,
                                     REASON_DEADLINE)
            elif req.first_token_at is None and over(req, self._ttft_for(req)):
                self._finish_request(i, RequestState.EXPIRED, REASON_TTFT)

    # -- graceful degradation ------------------------------------------
    def _degrade_fused(self, err=None):
        """Fused W4A4 dispatch failed: fall back to the explicit
        quantize_rows(per_row=True) -> W4A4-kernel two-dispatch
        composition ('mixfp4-2pass-rowscale').  The fused path is
        bitwise-identical to it by construction (PR 5/9, shared 'w4a4'
        tuner group + prepadded storage + the same per-row scale
        derivation), so the stream is preserved exactly — only dispatch
        count and latency change.  ``act_rht`` carries over: the 2-pass
        composition applies the same grouped Hadamard before quantizing."""
        if self.act_quant != "mixfp4":
            raise RuntimeError(
                "fused-dispatch degradation requested but the engine is "
                f"not on the fused W4A4 path (act_quant={self.act_quant!r})"
            ) from err
        self.act_quant = "mixfp4-2pass-rowscale"
        self.ctx = Ctx(jax.random.PRNGKey(0), self.cfg.quant, mesh=self.mesh,
                       act_quant=self.act_quant, act_rht=self.act_rht)
        self._prefill_lens.clear()
        self._build_jits()
        self.counters["degraded_fused_to_2pass"] += 1

    def _degrade_to_fixed_slot(self):
        """Pool exhaustion past the deferral budget: abandon the paged
        pool for the fixed-slot packed KV cache.  Every in-flight request
        is migrated by re-prefilling its full token history
        (prompt ++ generated[:-1]) into the fresh cache — greedy decode
        makes that replay value-preserving (bitwise for the dense family,
        the one with prefix sharing enabled; PR 2/6 replay-bitwise
        property), and the invariant lengths = p_len + len(generated) - 1
        is exactly the history length."""
        live = [(i, r) for i, r in enumerate(self.slots) if r is not None]
        self.kv_pool = None
        self.kv_pool_pages = None
        self._paged_suffix = False
        self.cache = self.model.init_cache(self.batch_size, self.max_len,
                                           kv_quant="mixfp4")
        self._prefill_lens.clear()
        self._build_jits()
        self.counters["degraded_paged_to_fixed"] += 1
        for i, req in live:
            if (self.scheduler is not None
                    and req.state is RequestState.PREFILLING):
                # a mid-prefill chunk job restarts from position 0 on the
                # fresh fixed-slot cache (its cached-prefix rows lived in
                # the abandoned pool pages)
                self.cache = self.model.reset_slot(self.cache, i)
                self.lengths[i] = 0
                self.scheduler.restart(req.uid, 0)
                continue
            history = np.asarray(req.prompt, np.int32)
            if req.generated:
                history = np.concatenate(
                    [history, np.asarray(req.generated[:-1], np.int32)])
            self.cache = self.model.reset_slot(self.cache, i)
            shim = Request(uid=req.uid, prompt=history,
                           max_new_tokens=req.max_new_tokens)
            self._prefill_slot(i, shim)     # lengths[i] = len(history)
            if not req.generated:
                req._next = shim._next      # first token not emitted yet

    def robustness_report(self) -> dict:
        """Live robustness state: queue depth/bounds, deadline config,
        degradation ladder position, lifecycle counters, and terminal
        state totals.  The static shape mirrors
        :func:`engine_robustness_spec`."""
        spec = engine_robustness_spec(
            max_queue=self.max_queue, deadline_ms=self.deadline_ms,
            ttft_budget_ms=self.ttft_budget_ms,
            degrade_after_deferrals=self.degrade_after_deferrals,
            kv_pool=self.kv_pool_pages, act_quant=self.act_quant)
        states = collections.Counter(
            str(r.state) for r in self.requests.values())
        spec["queue"]["depth"] = len(self.queue)
        spec["counters"] = dict(self.counters)
        spec["request_states"] = dict(states)
        spec["act_quant"] = self.act_quant
        spec["paged"] = self.kv_pool is not None
        spec["draining"] = self.draining
        spec["journaled"] = self.journal is not None
        if self.watchdog is not None:
            spec["watchdog"] = self.watchdog.report()
        return spec

    # -- graceful drain / crash recovery -------------------------------
    def begin_drain(self):
        """Close admissions: ``submit()`` now rejects with the typed
        ``draining`` reason while in-flight (and already-queued) requests
        keep stepping to completion.  Idempotent."""
        if not self.draining:
            self.draining = True
            self.counters["drain_begun"] = 1

    def finish_drain(self) -> dict:
        """Snapshot the ledger after the drain loop stops: one ``ledger``
        journal record (counters, per-request final states, any
        mid-prefill cursors) committed with a forced fsync — whatever the
        steady-state ``journal_sync`` policy, the drain snapshot itself
        is durable.  Requests still live at the drain deadline stay
        non-terminal in the journal: the NEXT process recovers them."""
        survivors = [uid for uid, r in self.requests.items()
                     if not r.state.terminal]
        if self.journal is not None:
            rec = {"t": "ledger",
                   "counters": {k: float(v)
                                for k, v in self.counters.items()},
                   "requests": {str(uid): {"state": str(r.state),
                                           "reason": r.finish_reason,
                                           "n_tokens": len(r.generated)}
                                for uid, r in self.requests.items()},
                   "survivors": survivors}
            if self.scheduler is not None:
                rec["prefill_jobs"] = self.scheduler.jobs_report()
            self._journal_append(rec)
            if self.journal is not None:
                try:
                    self.journal.flush(force_sync=True)
                except OSError:
                    self.counters["journal_write_failed"] += 1
        terminal = len(self.requests) - len(survivors)
        return {"drained": not survivors, "completed": terminal,
                "survivors": survivors}

    def drain(self, deadline_ms: float | None = None,
              max_steps: int = 10000) -> dict:
        """Blocking graceful drain: close admissions, step until the
        batch and queue empty or ``deadline_ms`` passes (on the engine
        clock), then snapshot the ledger.  Returns the
        :meth:`finish_drain` report plus the steps spent.  The HTTP
        worker drives the same three phases non-blockingly
        (serving.server)."""
        self.begin_drain()
        t0 = self.clock()
        steps = 0
        while self.has_work() and steps < max_steps:
            if deadline_ms is not None \
                    and (self.clock() - t0) * 1e3 > deadline_ms:
                break
            self.step()
            steps += 1
        report = self.finish_drain()
        report["steps"] = steps
        return report

    def recover(self, journal_dir: str | None = None) -> dict:
        """Rebuild every non-terminal journaled request into THIS (fresh)
        engine and continue decode.

        Each live request is reconstructed with its journaled prompt and
        token history and re-enters the batch through the resume
        admission path (:meth:`_resume_admit`): the full history
        ``prompt ++ generated[:-1]`` re-prefills into a fresh slot/pool
        pages — bitwise the pre-crash cache rows under the pinned
        ``KV_SCALE32`` contract — and decode resumes by feeding
        ``generated[-1]`` exactly where the dead process stopped.  Under
        greedy decode the resumed stream is bitwise-identical to the
        uninterrupted run (W4A16 and the per-row W4A4 modes;
        tests/test_recovery.py property-tests fixed-slot, paged and
        chunked-prefill engines), and tokens that were emitted but lost
        to an unsynced journal tail are simply re-derived and re-emitted.

        A journal that pins packed weights (``ckpt`` record) refuses to
        resume unless this engine restored the same step with the same
        manifest fingerprint — bitwise resume is only promised under the
        same weight bytes.  Requests whose token count already reached
        ``max_new_tokens`` (terminal record lost in the tail) are
        finalized FINISHED without re-admission."""
        if journal_dir is not None:
            if self.journal is None:
                self.journal = RequestJournal(journal_dir,
                                              sync=self.journal_sync)
            elif os.path.abspath(self.journal.dir) \
                    != os.path.abspath(journal_dir):
                raise JournalError(
                    f"engine already journals to {self.journal.dir}; "
                    f"refusing to recover from {journal_dir}")
        if self.journal is None:
            raise JournalError(
                "recover() needs a journal: pass journal_dir= or "
                "construct the engine with journal_dir=")
        state = replay(self.journal.records)
        ck = state.checkpoint
        if ck is not None:
            pin = self._weights_pin
            if pin is None:
                raise JournalError(
                    f"journal pins packed weights to step {ck['step']} "
                    f"of {ck['dir']} but this engine never restored a "
                    "checkpoint; load_weights() that step first — "
                    "bitwise resume is only promised under the same "
                    "weight bytes")
            if ck.get("fingerprint") and pin.get("fingerprint") \
                    and ck["fingerprint"] != pin["fingerprint"]:
                raise JournalError(
                    f"journal pins packed weights to manifest "
                    f"fingerprint {ck['fingerprint']} (step "
                    f"{ck['step']}) but this engine restored "
                    f"{pin['fingerprint']} (step {pin['step']})")
            if ck.get("step") != pin.get("step"):
                raise JournalError(
                    f"journal pins packed weights to step {ck['step']} "
                    f"but this engine restored step {pin['step']}")
        report = {"replayed_records": len(self.journal.records),
                  "requests": len(state.requests),
                  "already_terminal": 0, "resumed": 0, "finalized": 0,
                  "dangling_tokens": state.dangling_tokens,
                  "truncated_bytes":
                      self.journal.stats.get("truncated_bytes", 0),
                  "corrupt_record_index":
                      self.journal.stats.get("corrupt_record_index")}
        now = self.clock()
        for rr in state.requests.values():
            if rr.terminal:
                report["already_terminal"] += 1
                continue
            req = Request(uid=rr.uid,
                          prompt=np.asarray(rr.prompt, np.int32),
                          max_new_tokens=rr.max_new_tokens,
                          generated=list(rr.tokens),
                          deadline_ms=rr.deadline_ms,
                          ttft_budget_ms=rr.ttft_budget_ms)
            # deadline anchors restart at recovery: the dead process's
            # wall time is gone and a recovered stream should not expire
            # the instant it resumes
            req.submitted_at = now
            if rr.tokens:
                req.first_token_at = now
                req._last_token_at = now
            self.requests[req.uid] = req
            self.recovered_uids.append(req.uid)
            if len(rr.tokens) >= rr.max_new_tokens:
                self._mark_terminal(req, RequestState.FINISHED,
                                    REASON_MAX_NEW)
                report["finalized"] += 1
                continue
            req.state = RequestState.QUEUED
            self.queue.append(req)
            report["resumed"] += 1
            self.counters["recovered"] += 1
        # place as many as fit now; the rest re-admit as slots free up
        # (recovery may requeue past max_queue — repopulation, not load)
        self._pump()
        self._journal_flush()
        return report

    # -- terminal transitions ------------------------------------------
    def _mark_terminal(self, req: Request, state: RequestState, reason: str,
                       error: Exception | None = None):
        req.state = state
        req.finish_reason = reason
        req.error = error
        req.done = True
        self.counters[f"{state.value.lower()}:{reason}"] += 1
        self._journal_append({"t": "terminal", "uid": req.uid,
                              "state": state.value, "reason": reason})

    def _finish_request(self, i: int, state: RequestState, reason: str,
                        error: Exception | None = None):
        """Terminal transition for the request in slot ``i`` + slot
        quarantine/rollback: pool pages released (prefix-tree refcounts
        unwound for registered pages, free-listed for anonymous ones) and
        the block-table row pointed at the trash page."""
        req = self.slots[i]
        self._mark_terminal(req, state, reason, error=error)
        if self.scheduler is not None:
            self.scheduler.drop(req.uid)   # forget any mid-prefill cursor
        self._finish_slot(i)

    @staticmethod
    def bucket_len(p_len: int, max_len: int) -> int:
        """The pow-2/64-step prompt-length ladder: next power of two below
        64, then 64-step rungs, clamped to the cache length."""
        b = 8
        while b < min(p_len, 64):
            b *= 2
        if p_len > 64:
            b = -(-p_len // 64) * 64
        return min(b, max_len)

    def _prefill_slot(self, i: int, req: Request, start_pos: int = 0):
        """Single-slot batched prefill: ONE jit dispatch runs the whole
        prompt through ``model.prefill_slot`` at (1, P) shapes, writing all
        of slot ``i``'s cache rows at once.  Other slots' batch rows are
        never touched (the model slices/scatters only row ``i``), so an
        admission is invisible to its batchmates for all families with no
        snapshot/restore.

        With ``prefill_buckets`` active the prompt pads up the length
        ladder (suffix zeros) and the true length rides along as a dynamic
        operand, so nearby prompt lengths share one compiled prefill; the
        emitted token and the real cache rows are bitwise those of the
        exact-length call.

        ``start_pos > 0`` (paged transformers only) is a prefix-cache hit:
        the first ``start_pos`` prompt tokens are already served by mapped
        pool pages, so only the prompt *suffix* runs — the admission's
        prefill cost shrinks by the shared prefix."""
        p_len = len(req.prompt)
        toks = np.asarray(req.prompt, np.int32)[start_pos:]
        s_len = len(toks)  # >= 1: the pool's match stops at p_len - 1
        if self.prefill_buckets:
            pb = self.bucket_len(s_len, self.max_len - start_pos)
            if pb > s_len:
                toks = np.pad(toks, (0, pb - s_len))
        shape_key = len(toks)
        if shape_key in self._prefill_lens:
            self.prefill_cache_hits += 1
        else:
            self._prefill_lens.add(shape_key)
            self.prefill_compiles += 1
        tokens = jnp.asarray(toks[None, :])
        with self._mesh_ctx():
            if self.prefill_buckets and self._paged_suffix:
                logits, self.cache = self._prefill(
                    self.params, tokens, self.cache, jnp.int32(i),
                    jnp.int32(s_len), jnp.int32(start_pos))
            elif self.prefill_buckets:
                logits, self.cache = self._prefill(
                    self.params, tokens, self.cache, jnp.int32(i),
                    jnp.int32(s_len))
            elif self._paged_suffix:
                logits, self.cache = self._prefill(
                    self.params, tokens, self.cache, jnp.int32(i),
                    jnp.int32(start_pos))
            else:
                logits, self.cache = self._prefill(
                    self.params, tokens, self.cache, jnp.int32(i))
        self.lengths[i] = p_len
        req._next = int(jnp.argmax(logits[0]))
        self.prefill_dispatches += 1
        self.admissions += 1
        # per-step prefill-token ledger: without the chunk scheduler a
        # whole prompt lands in one step — this is exactly the decode
        # stall the frontend benchmark quantifies
        self._step_prefill_tokens += s_len

    def _finish_slot(self, i: int):
        """Free slot ``i``.  A paged engine also releases the request's
        pages back to the pool (tree-registered pages park in the LRU,
        still servable as prefix hits) and points the slot's block-table
        row at the trash page — the inactive lane's decode scatters must
        never land in pages the pool may re-grant."""
        self.slots[i] = None
        if self.kv_pool is not None:
            pages = self._slot_pages[i]
            if pages:
                self.kv_pool.release(pages)
            self._slot_pages[i] = None
            self.block_tables[i] = 0
            self.lengths[i] = 0
            self.cache = dict(
                self.cache, pages=self.cache["pages"].at[i].set(0))

    def pool_report(self) -> dict | None:
        """Pool occupancy / prefix-hit / eviction counters (None when the
        engine is not paged)."""
        return None if self.kv_pool is None else self.kv_pool.stats()

    # -- chunked prefill (serving.scheduler) ---------------------------
    def _sched_run_chunk(self):
        """Spend this step's chunk budget on the FIFO-head prefill job:
        ONE jit dispatch runs ``chunk`` prompt tokens from the job cursor
        (the final partial chunk pads up to the budget and rides
        ``true_len`` masking, so every chunk shares one compiled
        executable).  Runs behind the 'prefill' fault boundary with the
        same quarantine/rollback as the whole-prompt path.  On job
        completion the request flips RUNNING with its first token staged
        in ``_next`` — the emit loop right after this call emits it, so a
        chunked admission's stream is positioned exactly like an
        unchunked one's."""
        job = self.scheduler.head()
        if job is None:
            return
        req, i = job.req, job.slot
        start = job.cursor
        n_real = min(self.scheduler.chunk, job.p_len - start)
        # never let start + chunk cross max_len: dynamic_update_slice
        # CLAMPS out-of-range starts, which would silently shift rows
        pad_to = min(self.scheduler.chunk, self.max_len - start)
        toks = np.asarray(req.prompt, np.int32)[start:start + n_real]
        if pad_to > n_real:
            toks = np.pad(toks, (0, pad_to - n_real))
        if len(toks) in self._prefill_lens:
            self.prefill_cache_hits += 1
        else:
            self._prefill_lens.add(len(toks))
            self.prefill_compiles += 1
        try:
            self._with_retries("prefill", None, uid=req.uid)
        except InjectedFault as e:
            reason = REASON_RETRIES if e.transient else REASON_INJECTED
            self._finish_request(i, RequestState.FAILED, reason, error=e)
            return
        tokens = jnp.asarray(toks[None, :])
        try:
            with self._mesh_ctx():
                logits, self.cache = self._chunk_prefill(
                    self.params, tokens, self.cache, jnp.int32(i),
                    jnp.int32(n_real), jnp.int32(start))
        except Exception as e:
            self._finish_request(i, RequestState.FAILED,
                                 REASON_PREFILL_ERROR, error=e)
            raise
        self.prefill_dispatches += 1
        self._step_prefill_tokens += n_real
        if self.scheduler.advance(job, n_real):
            self.lengths[i] = job.p_len
            req._next = int(jnp.argmax(logits[0]))
            if self.kv_pool is not None:
                # pages are final now — register them for prefix hits
                # (deferred from _try_admit; no-op for plain allocators)
                self.kv_pool.insert(req.prompt, self._slot_pages[i])
            req.state = RequestState.RUNNING
            self.admissions += 1
        else:
            # mid-prefill: lengths tracks the cursor so this lane's junk
            # decode scatter lands at the next chunk's start row
            self.lengths[i] = job.cursor

    def _note_step(self, decode_rows: int):
        """End-of-step bookkeeping: the prefill-token ledger (counters +
        scheduler step_log) and the live metrics gauges.  The ledger
        resets HERE, not at step start: direct ``add_request`` calls
        between steps prefill outside ``step()`` and their tokens belong
        to the step whose decode they delayed (the next one)."""
        spent = self._step_prefill_tokens
        self._step_prefill_tokens = 0
        self.max_prefill_tokens_per_step = max(
            self.max_prefill_tokens_per_step, spent)
        if spent:
            self.counters["prefill_tokens"] += spent
        self.counters["max_prefill_tokens_per_step"] = \
            self.max_prefill_tokens_per_step
        if self.scheduler is not None:
            self.scheduler.note_step(spent, decode_rows)
        m = self.metrics
        m.set_gauge("queue_depth", len(self.queue))
        m.set_gauge("active_slots", float(
            sum(s is not None and not s.done for s in self.slots)))
        if self.kv_pool is not None:
            st = self.kv_pool.stats()
            for key in ("pages_active", "prefix_hit_tokens"):
                if key in st:
                    m.set_gauge(f"kv_pool.{key}", st[key])

    def metrics_report(self) -> dict:
        """One JSON-able observability snapshot: lifecycle counters
        (merged with the registry's), live gauges, TTFT/ITL histogram
        percentiles, pool stats and the scheduler ledger.  This is what
        ``GET /metrics`` renders (serving.metrics.render_prometheus) and
        what the frontend benchmark asserts against."""
        snap = self.metrics.snapshot()
        counters = dict(self.counters)
        counters.update(snap["counters"])
        gauges = dict(snap["gauges"])
        gauges.update({
            "queue_depth": float(len(self.queue)),
            "active_slots": float(
                sum(s is not None and not s.done for s in self.slots)),
            "max_queue": float(self.max_queue),
            "prefill_compiles": float(self.prefill_compiles),
            "prefill_cache_hits": float(self.prefill_cache_hits),
            "max_prefill_tokens_per_step":
                float(self.max_prefill_tokens_per_step),
        })
        gauges["draining"] = float(self.draining)
        report = {"counters": counters, "gauges": gauges,
                  "histograms": snap["histograms"]}
        if self.kv_pool is not None:
            report["kv_pool"] = self.kv_pool.stats()
        if self.scheduler is not None:
            report["scheduler"] = self.scheduler.report()
        if self.journal is not None:
            report["journal"] = self.journal.report()
        if self.watchdog is not None:
            report["watchdog"] = self.watchdog.report()
        return report

    def step(self) -> list[tuple[int, int]]:
        """One decode step for all active slots (each at its own cache
        position); returns (uid, token).

        A freshly prefilled slot first emits ``_next`` — the prefill's own
        argmax IS the first generated token (it used to be fed back but
        never emitted, shifting the stream by one) — then decodes.

        Lifecycle work rides the same call: deadlines expire first, then
        the bounded queue pumps admissions into free slots, then the
        decode dispatch crosses the 'decode' fault boundary (injected
        slow/transient/dispatch faults; poisoned rows).  A row whose
        logits are non-finite — really overflowed or injector-poisoned —
        quarantines ITS slot only: the victim lands FAILED(nan_logits)
        with no token emitted and the survivors' streams are untouched
        (decode is row-independent, so they stay bitwise-identical to a
        fault-free run under W4A16)."""
        t0 = self.clock()
        # the process_crash boundary fires BEFORE any state mutation: a
        # "crash between steps" leaves exactly the journal the previous
        # step's flush committed, which is what a SIGKILL leaves too
        act = self._fire("process_crash", scoped=False)
        if act is not None and act.error is not None:
            raise act.error
        self._expire_deadlines()
        self._pump()
        if self.scheduler is not None:
            self._sched_run_chunk()
        toks = np.zeros((self.batch_size,), np.int32)
        out = []
        active = []
        n_live = sum(r is not None for r in self.slots)
        self.max_concurrent = max(self.max_concurrent, n_live)
        for i, req in enumerate(self.slots):
            if req is None or req.done:
                continue
            if req.state is RequestState.PREFILLING:
                # a chunked admission mid-prefill holds its slot but does
                # not decode; the batched dispatch's scatter for this lane
                # writes a junk row at lengths[i] (= the chunk cursor),
                # which the NEXT chunk overwrites before it is ever read
                continue
            if not req.generated:
                if req._next is None:
                    raise RuntimeError(
                        f"request {req.uid} occupies slot {i} but was never "
                        "prefilled (requests enter the batch via "
                        "add_request, which runs the admission prefill)")
                req.first_token_at = self.clock()
                req._last_token_at = req.first_token_at
                self.metrics.observe("ttft_ms", req.ttft_ms())
                req.generated.append(req._next)
                out.append((req.uid, req._next))
                self._journal_append({"t": "token", "uid": req.uid,
                                      "tok": int(req._next)})
                if len(req.generated) >= req.max_new_tokens:
                    self._finish_request(i, RequestState.FINISHED,
                                         REASON_MAX_NEW)
                    continue
            toks[i] = req.generated[-1]
            active.append(i)
        if not active:
            self._note_step(0)
            self._finish_step(t0)
            return out
        logits = self._guarded_decode(toks, active)
        # one vectorized argmax + host transfer per step, not one per
        # slot; the finiteness reduction rides the same device round-trip
        next_toks = np.asarray(jnp.argmax(logits, axis=-1))
        nan_rows = np.asarray(jnp.any(~jnp.isfinite(logits), axis=-1))
        now = self.clock()
        for i in active:
            req = self.slots[i]
            if req is None or req.done:
                continue               # quarantined by a mid-step fault
            if req.uid in self._step_poison or bool(nan_rows[i]):
                self._finish_request(i, RequestState.FAILED,
                                     REASON_NAN_LOGITS)
                continue
            tok = int(next_toks[i])
            req.generated.append(tok)
            self.lengths[i] += 1
            out.append((req.uid, tok))
            self._journal_append({"t": "token", "uid": req.uid,
                                  "tok": tok})
            if req._last_token_at is not None:
                self.metrics.observe("itl_ms",
                                     (now - req._last_token_at) * 1e3)
            req._last_token_at = now
            if len(req.generated) >= req.max_new_tokens:
                self._finish_request(i, RequestState.FINISHED,
                                     REASON_MAX_NEW)
        self._note_step(len(active))
        self._finish_step(t0)
        return out

    def _finish_step(self, t0: float):
        """Step-boundary durability + liveness work: one journal flush
        commits the step's token records (the ``journal_sync='batch'``
        fsync point), then the watchdog hears the step's heartbeat and
        its verdicts run the degradation ladder."""
        self._journal_flush()
        if self.watchdog is None:
            return
        verdict = self.watchdog.beat((self.clock() - t0) * 1e3)
        if verdict == "degrade":
            # first strikes ride the existing bitwise-preserving ladder
            # when a rung is armed; otherwise the strike just counts
            if self.act_quant == "mixfp4":
                self._degrade_fused()
            self.counters["watchdog_degrades"] += 1
        elif verdict == "fail":
            self._watchdog_fail()

    def _watchdog_fail(self):
        """Past the degradation rung: fail the most starved in-flight
        request (longest since its last token — the one the hung steps
        are starving hardest) with the typed ``watchdog_timeout`` reason,
        releasing its slot and pool pages instead of wedging the batch."""
        live = [(i, r) for i, r in enumerate(self.slots)
                if r is not None and not r.done]
        if not live:
            return

        def anchor(req):
            if req._last_token_at is not None:
                return req._last_token_at
            return req.submitted_at if req.submitted_at is not None else 0.0

        i, _ = min(live, key=lambda ir: anchor(ir[1]))
        self._finish_request(i, RequestState.FAILED, REASON_WATCHDOG)
        self.counters["watchdog_fails"] += 1

    def _guarded_decode(self, toks, active):
        """The decode dispatch behind the 'decode' fault boundary.
        Injected 'slow' faults advance the clock (TTFT/deadline pressure),
        'dispatch' faults trigger the fused -> 2-pass degradation (or a
        backoff retry off the fused path), transients back off and retry,
        and a fatal fault quarantines its victim's slot, then decodes the
        survivors."""
        self._step_poison = set()
        attempt = 0
        spins = 0
        while True:
            spins += 1
            if spins > self.retry_max + self.batch_size + 8:
                raise RuntimeError(
                    "decode fault boundary did not converge (a schedule "
                    "that fires fatally on every occurrence can starve "
                    "the dispatch); refusing to spin")
            act = self._fire("decode", scoped=False)
            if act is not None:
                self._step_poison |= set(act.poison_uids)
                err = act.error
                if err is not None:
                    if err.kind == "dispatch" and self.act_quant == "mixfp4":
                        self._degrade_fused(err)
                        continue
                    if err.kind == "dispatch" or err.transient:
                        attempt += 1
                        if attempt > self.retry_max:
                            self.counters["retries_exhausted:decode"] += 1
                            raise err
                        self.counters["retries:decode"] += 1
                        self._sleep(self._backoff_s(attempt))
                        continue
                    # fatal, request-scoped (an injected host-transfer
                    # failure): quarantine the victim, decode the rest
                    victim = next(
                        (i for i in active
                         if self.slots[i] is not None
                         and self.slots[i].uid == err.uid), None)
                    if victim is not None:
                        self._finish_request(victim, RequestState.FAILED,
                                             REASON_INJECTED, error=err)
                    continue
            with self._mesh_ctx():
                logits, self.cache = self._decode(
                    self.params, jnp.asarray(toks), self.cache,
                    jnp.asarray(self.lengths.copy()))
            return logits
