"""Batched serving engine over packed MixFP4 weights.

Production-shaped serving loop: requests join a continuous batch and the
projection weights are held ONLY as packed :class:`~repro.core.qtensor.QTensor`
pytrees — the paper's wire format (4-bit payloads + type-in-sign E4M3 scale
bytes = 4.5 bits/value in HBM, a ~3.55x weight-memory and bandwidth saving
over bf16 in the decode-bound regime).  Every decode step runs through
``qmm`` -> the W4A16 Pallas kernel (interpret mode on CPU, native on TPU),
decoding tiles in VMEM; no dense bf16 copy of a projection weight is
retained anywhere in the engine.

The KV cache can optionally be MixFP4-quantized per (head, 16-value block)
as well (``quantize_kv``/``dequantize_kv`` below).
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint.manager import CheckpointManager
from repro.core import qtensor
from repro.kernels import ops
from repro.models.base import ArchConfig, Ctx, build_model, pack_projections


def _packed_stats(tree) -> tuple[int, int]:
    """(wire bytes, bf16-equivalent bytes) over the QTensor leaves of a
    parameter tree — same accounting as models.base.pack_projections."""
    packed = dense = 0
    for leaf in jax.tree.leaves(
            tree, is_leaf=lambda x: isinstance(x, qtensor.QTensor)):
        if isinstance(leaf, qtensor.QTensor):
            packed += leaf.nbytes
            dense += int(np.prod(leaf.shape)) * leaf._batch_size() * 2
    return packed, dense


@dataclasses.dataclass
class Request:
    uid: int
    prompt: np.ndarray           # (len,) int32
    max_new_tokens: int = 16
    generated: list = dataclasses.field(default_factory=list)
    done: bool = False


class ServeEngine:
    """Greedy continuous-batching decoder for the transformer families."""

    def __init__(self, cfg: ArchConfig, params, *, batch_size: int = 8,
                 max_len: int = 512, pack_weights: bool = True,
                 method: str = "mixfp4"):
        if cfg.family == "encdec":
            raise ValueError(
                "ServeEngine has no source-encoding path (requests carry "
                "tokens only); an encdec model would cross-attend an "
                "all-zero memory. Drive encdec decoding through "
                "model.prefill(src_embeds)/decode_step directly.")
        self.cfg = cfg
        self.model = build_model(cfg)
        self.batch_size = batch_size
        self.max_len = max_len
        self.ctx = Ctx(jax.random.PRNGKey(0), cfg.quant)
        if pack_weights:
            # Projection weights become packed QTensors; the dense leaves
            # are dropped from this tree (callers should release their own
            # reference if they want the full HBM saving).
            self.params, self.packed_bytes, self.dense_bytes = \
                pack_projections(params, method=method)
        else:
            self.params = params
            self.packed_bytes = self.dense_bytes = 0
        self.compression = (self.dense_bytes / self.packed_bytes
                            if self.packed_bytes else 1.0)
        self.cache = self.model.init_cache(batch_size, max_len)
        self.lengths = np.zeros((batch_size,), np.int32)
        self.slots: list[Request | None] = [None] * batch_size
        self._decode = jax.jit(
            lambda p, t, c, l: self.model.decode_step(p, t, self.ctx, c, l))

    # ------------------------------------------------------------------
    # packed-weight checkpointing: the QTensor pytree round-trips through
    # CheckpointManager (payload/scales/scale32 are ordinary leaves; the
    # static layout metadata travels in the manifest spec).
    # ------------------------------------------------------------------
    def save_weights(self, directory: str, step: int = 0):
        CheckpointManager(directory).save_packed(step, self.params,
                                                blocking=True)

    def load_weights(self, directory: str, step: int | None = None):
        restored, _ = CheckpointManager(directory).restore_packed(step)
        self.params = restored
        # recompute storage stats from what was actually restored (a cold
        # engine built with pack_weights=False would otherwise keep 0/1.0)
        self.packed_bytes, self.dense_bytes = _packed_stats(restored)
        self.compression = (self.dense_bytes / self.packed_bytes
                            if self.packed_bytes else 1.0)

    # ------------------------------------------------------------------
    def add_request(self, req: Request) -> bool:
        if len(req.prompt) == 0:
            raise ValueError("empty prompt: a request must carry at least "
                             "one prompt token")
        if req.max_new_tokens < 1:
            raise ValueError("max_new_tokens must be >= 1 (the prefill "
                             "itself produces the first token)")
        # the final generated token is emitted but never fed back, so the
        # highest cache position written is prompt + max_new - 2
        if len(req.prompt) + req.max_new_tokens - 1 > self.max_len:
            raise ValueError(
                f"request {req.uid} needs {len(req.prompt)} prompt + "
                f"{req.max_new_tokens} new tokens but the cache holds "
                f"max_len={self.max_len}")
        for i, slot in enumerate(self.slots):
            if slot is None:
                self.slots[i] = req
                # a reused slot starts over at position 0 with zeroed cache
                # rows — no KV / SSM state leaks from the previous occupant
                self.lengths[i] = 0
                self.cache = self.model.reset_slot(self.cache, i)
                self._prefill_slot(i, req)
                return True
        return False

    def _prefill_slot(self, i: int, req: Request):
        """Single-slot prefill: run the prompt through decode steps (slot-
        level prefill keeps the engine simple; batch prefill is the
        prefill_32k dry-run path).

        Other ACTIVE slots observe dummy token-0 steps during this loop.
        Positional KV rows would be overwritten at their next real step,
        but recurrent SSM state advances irreversibly for every batch row —
        so snapshot every other active slot and restore it afterwards; an
        admission is bitwise-invisible to its batchmates for all families."""
        others = [j for j, s in enumerate(self.slots)
                  if s is not None and j != i]
        saved = {j: self.model.slot_state(self.cache, j) for j in others}
        logits = None
        for tok in req.prompt:
            # fresh host buffers per dispatch: the decode runs async and may
            # alias numpy memory — never hand it a buffer we later mutate
            toks = np.zeros((self.batch_size,), np.int32)
            toks[i] = tok
            logits, self.cache = self._decode(
                self.params, jnp.asarray(toks), self.cache,
                jnp.asarray(self.lengths.copy()))
            self.lengths[i] += 1
        req._next = int(jnp.argmax(logits[i]))
        for j, state in saved.items():
            self.cache = self.model.write_slot(self.cache, j, state)

    def step(self) -> list[tuple[int, int]]:
        """One decode step for all active slots (each at its own cache
        position); returns (uid, token).

        A freshly prefilled slot first emits ``_next`` — the prefill's own
        argmax IS the first generated token (it used to be fed back but
        never emitted, shifting the stream by one) — then decodes."""
        toks = np.zeros((self.batch_size,), np.int32)
        out = []
        active = []
        for i, req in enumerate(self.slots):
            if req is None or req.done:
                continue
            if not req.generated:
                req.generated.append(req._next)
                out.append((req.uid, req._next))
                if len(req.generated) >= req.max_new_tokens:
                    req.done = True
                    self.slots[i] = None
                    continue
            toks[i] = req.generated[-1]
            active.append(i)
        if not active:
            return out
        logits, self.cache = self._decode(
            self.params, jnp.asarray(toks), self.cache,
            jnp.asarray(self.lengths.copy()))
        # one vectorized argmax + host transfer per step, not one per slot
        next_toks = np.asarray(jnp.argmax(logits, axis=-1))
        for i in active:
            tok = int(next_toks[i])
            req = self.slots[i]
            req.generated.append(tok)
            self.lengths[i] += 1
            out.append((req.uid, tok))
            if len(req.generated) >= req.max_new_tokens:
                req.done = True
                self.slots[i] = None
        return out


# ---------------------------------------------------------------------------
# MixFP4-quantized KV cache (beyond-paper, DESIGN.md §9.3): stores K/V as
# packed payload + scale bytes per (token, head, 16-lane block).  Decode
# memory traffic drops ~3.5x on the cache — the dominant term of decode_32k.
# (Follow-on: carry these as 1-D QTensors so the cache flows through the
# same pytree machinery as the weights.)
# ---------------------------------------------------------------------------
def quantize_kv(kv: jax.Array):
    """kv: (..., dh) bf16 -> (payload (..., dh//2) u8, scales (..., dh//16) u8,
    per-tensor f32)."""
    shape = kv.shape
    flat = kv.reshape(-1, shape[-1]).astype(jnp.float32)
    payload, scales, s32 = ops.quantize_rows(flat)
    return (payload.reshape(*shape[:-1], shape[-1] // 2),
            scales.reshape(*shape[:-1], shape[-1] // 16), s32)


def dequantize_kv(payload, scales, s32, dtype=jnp.bfloat16):
    qt = qtensor.QTensor(
        payload, scales, s32, method="mixfp4",
        layout=qtensor.BlockLayout1D(-1, 16),
        shape=(*payload.shape[:-1], payload.shape[-1] * 2), dtype="float32")
    return qt.dequantize(dtype)
