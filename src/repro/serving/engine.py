"""Batched serving engine over packed MixFP4 weights and (optionally) a
packed MixFP4 KV cache.

Production-shaped serving loop: requests join a continuous batch and the
projection weights are held ONLY as packed :class:`~repro.core.qtensor.QTensor`
pytrees — the paper's wire format (4-bit payloads + type-in-sign E4M3 scale
bytes = 4.5 bits/value in HBM, a ~3.55x weight-memory and bandwidth saving
over bf16 in the decode-bound regime).  Every decode step runs through
``qmm`` -> the W4A16 Pallas kernel (interpret mode on CPU, native on TPU),
decoding tiles in VMEM; no dense bf16 copy of a projection weight is
retained anywhere in the engine.

Three hot paths run over packed data end-to-end (docs/serving.md):

* ``kv_quant="mixfp4"`` carries the transformer KV cache as 1-D
  ``BlockLayout1D`` QTensors; every decode step scatters the new token's
  packed K/V bytes in place and reads the cache through the fused Pallas
  decode-attention kernel (``kernels.mixfp4_attn``) — the cache's dense
  bf16 form never exists at decode time, so the dominant decode_32k
  traffic term shrinks ~3.55x too.
* ``act_quant="mixfp4"`` (W4A4) quantizes decode AND prefill activations on
  the fly — in the W4A4 kernel's fused prologue, ONE Pallas dispatch per
  projection — using the same type-in-sign E4M3 block-scale wire encoding,
  the paper's full FP4xFP4 MMA analog (Fig. 9 decode on BOTH operands),
  for the dense, MoE, SSM and hybrid families.  ``"mixfp4-2pass"`` is the
  explicit ``quantize_rows`` -> W4A4-kernel two-dispatch composition the
  fused path is bitwise-identical to (the serving-level oracle and the A/B
  baseline); ``"mixfp4-qdq"`` is the dequantize-then-W4A16 debugging
  oracle over the same wire bytes.
* Admissions prefill through the models' batched ``prefill_slot`` entry:
  the whole prompt runs in ONE jit call at (P, K) prefill shapes through
  the W4A16 kernels, writing all cache rows at once, instead of the
  historical O(prompt_len) token-by-token decode replay (which also needed
  a snapshot/restore dance to keep recurrent batchmates unperturbed).
  For the transformer families, prompts additionally pad up a pow-2/64-step
  length ladder (``prefill_buckets``) so admissions stop compiling one
  prefill executable per distinct prompt length: padded suffix rows are
  causally invisible to the real positions, masked at decode until
  overwritten, and the last-position logits index the true length — the
  emitted stream is bitwise-identical to the unbucketed engine's under
  W4A16 (dense-activation) serving.  Caveat: under the W4A4 modes the
  per-tensor *prefill* activation scale spans the padded suffix rows too,
  so a bucketed W4A4 prefill can differ from the exact-length one within
  the documented per-tensor-coupling bounds (docs/serving.md); oracle
  comparisons stay exact because both engines bucket identically.
  ``prefill_compiles`` / ``prefill_cache_hits`` count the effect.

With ``mesh=`` the engine serves *sharded* packed weights
(docs/sharding.md): every projection QTensor is placed under model-axis
``NamedSharding``s derived by ``distributed.sharding.serve_packed_specs``
(column-parallel N-sharding; MoE expert stacks shard whole experts), decode
runs the W4A16 — or, with ``act_quant="mixfp4"``, the W4A4 — kernel per
shard via ``qmm_sharded``/``shard_map`` (W4A4 quantizes the replicated
activation rows ONCE and replicates the packed bytes), and the layout is
chosen so the output stream stays bitwise-identical to the single-device
packed path.  ``load_weights`` restores a packed checkpoint
straight into the sharded layout.  The KV cache is replicated for now —
its PartitionSpec story is the open ROADMAP item (docs/serving.md).
"""
from __future__ import annotations

import contextlib
import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint.manager import CheckpointManager
from repro.core import qtensor
from repro.distributed import sharding as dist_sharding
from repro.models.base import ArchConfig, Ctx, build_model, pack_projections
from repro.serving.kvpool import KVPool

_TRANSFORMER_FAMILIES = ("dense", "moe", "vlm")


def _prepad_group(act_quant: str) -> str:
    """Tuner path whose tile grid the engine pre-pads packed weights onto.
    Both W4A4 spellings share one tuner cache entry ('w4a4'), so the fused
    kernel and the 2-pass composition see identical storage — preserving
    their bitwise-comparability."""
    return "w4a4" if act_quant in ("mixfp4", "mixfp4-2pass") else "w4a16"


def _prepad_tree(params, group: str, m: int):
    """Pre-pad every 2-D packed projection onto the tuner grid for ``m``
    decode rows (qtensor.prepad_for_tiles), so the per-step ``qmm``
    dispatch stops re-padding packed bytes inside every jitted call."""
    is_qt = lambda x: isinstance(x, qtensor.QTensor)
    return jax.tree.map(
        lambda l: qtensor.prepad_for_tiles(l, group, m) if is_qt(l) else l,
        params, is_leaf=is_qt)


def _packed_stats(tree) -> tuple[int, int]:
    """(wire bytes, bf16-equivalent bytes) over the QTensor leaves of a
    parameter tree — same accounting as models.base.pack_projections."""
    packed = dense = 0
    for leaf in jax.tree.leaves(
            tree, is_leaf=lambda x: isinstance(x, qtensor.QTensor)):
        if isinstance(leaf, qtensor.QTensor):
            packed += leaf.nbytes
            dense += int(np.prod(leaf.shape)) * leaf._batch_size() * 2
    return packed, dense


@dataclasses.dataclass
class Request:
    uid: int
    prompt: np.ndarray           # (len,) int32
    max_new_tokens: int = 16
    generated: list = dataclasses.field(default_factory=list)
    done: bool = False
    # First greedy token, produced by the admission prefill and emitted by
    # the first step() — None until the request has been admitted.  (It
    # used to be injected dynamically by _prefill_slot, so step() on a
    # request that skipped prefill raised AttributeError.)
    _next: int | None = None


class ServeEngine:
    """Greedy continuous-batching decoder for the transformer families."""

    def __init__(self, cfg: ArchConfig, params, *, batch_size: int = 8,
                 max_len: int = 512, pack_weights: bool = True,
                 method: str = "mixfp4", kv_quant: str | None = None,
                 act_quant: str | None = None, mesh=None,
                 prefill_buckets: str | None = "auto",
                 kv_pool: int | None = None, kv_page_len: int = 16):
        if cfg.family == "encdec":
            raise ValueError(
                "ServeEngine has no source-encoding path (requests carry "
                "tokens only); an encdec model would cross-attend an "
                "all-zero memory. Drive encdec decoding through "
                "model.prefill(src_embeds)/decode_step directly.")
        if kv_quant not in (None, "bf16", "mixfp4"):
            raise ValueError(f"unknown kv_quant {kv_quant!r} "
                             "(expected None, 'bf16' or 'mixfp4')")
        has_kv = (cfg.family in _TRANSFORMER_FAMILIES
                  or (cfg.family == "hybrid" and cfg.attn_period))
        if kv_quant == "mixfp4" and not has_kv:
            raise ValueError(
                f"kv_quant='mixfp4' packs the attention KV cache; family "
                f"{cfg.family!r} has no KV cache to pack (transformers and "
                "the shared-attention hybrid do)")
        if kv_pool is not None:
            if kv_quant != "mixfp4":
                raise ValueError(
                    "kv_pool= is the paged *packed* KV path; it requires "
                    f"kv_quant='mixfp4' (got {kv_quant!r})")
            if mesh is not None:
                raise ValueError(
                    "kv_pool= with mesh= is not wired yet: the paged "
                    "attention kernel's block-table prefetch has no "
                    "shard_map spec (the fixed-slot packed cache serves "
                    "sharded engines)")
            if kv_page_len % 16 or max_len % kv_page_len:
                raise ValueError(
                    f"kv_page_len={kv_page_len} must be a multiple of 16 "
                    f"(the MixFP4 block) and divide max_len={max_len}")
        if act_quant not in (None, "bf16", "mixfp4", "mixfp4-2pass",
                             "mixfp4-qdq"):
            raise ValueError(
                f"unknown act_quant {act_quant!r} (expected None, 'bf16', "
                "'mixfp4' (fused quantize+GEMM), 'mixfp4-2pass' (the "
                "two-dispatch composition), or the 'mixfp4-qdq' debugging "
                "oracle)")
        if act_quant in ("mixfp4", "mixfp4-2pass", "mixfp4-qdq") \
                and not pack_weights:
            raise ValueError(
                "act_quant='mixfp4' is the W4A4 path — both GEMM operands "
                "on the wire format — which needs packed weights; drop "
                "pack_weights=False")
        if prefill_buckets not in (None, "off", "auto", "pow2-64"):
            raise ValueError(
                f"unknown prefill_buckets {prefill_buckets!r} (expected "
                "None/'off', 'auto', or 'pow2-64')")
        if prefill_buckets == "pow2-64" \
                and cfg.family not in _TRANSFORMER_FAMILIES:
            raise ValueError(
                "prefill_buckets pads the prompt with suffix tokens, which "
                "is only sound for the transformer families (KV rows "
                "beyond the true length are masked/overwritten); the SSM "
                f"recurrent state of family {cfg.family!r} advances for "
                "every padded token")
        if mesh is not None and not pack_weights:
            raise ValueError(
                "mesh serving is the sharded *packed* path (QTensor "
                "payload/scales under model-axis NamedShardings); "
                "pack_weights=False has no sharded serve layout")
        self.cfg = cfg
        self.model = build_model(cfg)
        self.batch_size = batch_size
        self.max_len = max_len
        self.kv_quant = kv_quant or "bf16"
        self.act_quant = act_quant or "bf16"
        self.mesh = mesh
        self.ctx = Ctx(jax.random.PRNGKey(0), cfg.quant, mesh=mesh,
                       act_quant=self.act_quant)
        if pack_weights:
            # Projection weights become packed QTensors; the dense leaves
            # are dropped from this tree (callers should release their own
            # reference if they want the full HBM saving).
            self.params, self.packed_bytes, self.dense_bytes = \
                pack_projections(params, method=method)
            if mesh is not None:
                # model-axis TP placement: payload/scales co-sharded at
                # block granularity, logical pspec recorded in the aux so
                # qlinear dispatches qmm_sharded; dense leaves (embed,
                # norms — the paper's exclusions) replicate
                self.weight_specs = dist_sharding.serve_packed_specs(
                    self.params, mesh)
                self.params = dist_sharding.shard_packed_tree(
                    self.params, self.weight_specs, mesh)
        else:
            self.params = params
            self.packed_bytes = self.dense_bytes = 0
        self.compression = (self.dense_bytes / self.packed_bytes
                            if self.packed_bytes else 1.0)
        if pack_weights and mesh is None:
            # pre-pad packed projections onto the decode-shape tuner grid
            # (storage only; stats above keep the logical wire bytes)
            self.params = _prepad_tree(
                self.params, _prepad_group(self.act_quant), batch_size)
        # paged KV pool (kv_pool = number of physical pages; page 0 is the
        # pool's trash page).  Prefix caching needs suffix prefill to be
        # bitwise-equal to full prefill, i.e. ROW-INDEPENDENT prefill:
        # the hybrid's SSM state recurs over the whole prompt, and MoE's
        # capacity router couples rows (cap = f(token count), so a short
        # suffix competes for different expert capacity than the full
        # prompt did).  Only the dense transformer family qualifies; the
        # others ride the pool as a plain page allocator.
        self.kv_pool_pages = kv_pool
        self.kv_page_len = kv_page_len
        if kv_pool is not None:
            self.kv_pool = KVPool(
                kv_pool, kv_page_len,
                enable_prefix=cfg.family == "dense")
            self.cache = self.model.init_cache(
                batch_size, max_len, kv_quant="mixfp4",
                pages=(kv_pool, kv_page_len))
            self.block_tables = np.zeros(
                (batch_size, max_len // kv_page_len), np.int32)
            self._slot_pages: list = [None] * batch_size
            self._copy_page = jax.jit(self._cow_copy)
        else:
            self.kv_pool = None
            if self.kv_quant == "mixfp4":
                self.cache = self.model.init_cache(batch_size, max_len,
                                                   kv_quant="mixfp4")
            else:
                self.cache = self.model.init_cache(batch_size, max_len)
        self.lengths = np.zeros((batch_size,), np.int32)
        self.slots: list[Request | None] = [None] * batch_size
        self.prefill_dispatches = 0   # jit dispatches spent on admissions
        self.admissions = 0
        self.max_concurrent = 0       # peak active slots seen by step()
        # prompt-length bucketing (transformer families): pad prompts up a
        # pow-2/64-step ladder so admissions reuse one compiled prefill per
        # bucket instead of compiling per distinct length
        if prefill_buckets == "auto":
            prefill_buckets = ("pow2-64"
                               if cfg.family in _TRANSFORMER_FAMILIES
                               else None)
        self.prefill_buckets = (None if prefill_buckets in (None, "off")
                                else prefill_buckets)
        self.prefill_compiles = 0      # distinct prefill shapes traced
        self.prefill_cache_hits = 0    # admissions that reused a shape
        self._prefill_lens: set = set()
        self._decode = jax.jit(
            lambda p, t, c, l: self.model.decode_step(p, t, self.ctx, c, l))
        # prefix-caching prefills take the suffix start as a dynamic
        # operand (prefix-cached admissions prefill only tokens[shared:]);
        # plain-allocator pools (hybrid/MoE) always start at 0
        paged_sfx = (self.kv_pool is not None
                     and self.kv_pool.enable_prefix)
        if self.prefill_buckets and paged_sfx:
            self._prefill = jax.jit(
                lambda p, t, c, i, n, s0: self.model.prefill_slot(
                    p, t, self.ctx, c, i, true_len=n, start_pos=s0))
        elif self.prefill_buckets:
            self._prefill = jax.jit(
                lambda p, t, c, i, n: self.model.prefill_slot(
                    p, t, self.ctx, c, i, true_len=n))
        elif paged_sfx:
            self._prefill = jax.jit(
                lambda p, t, c, i, s0: self.model.prefill_slot(
                    p, t, self.ctx, c, i, start_pos=s0))
        else:
            # one dispatch per admission; recompiles per distinct prompt
            # length (prefill shapes)
            self._prefill = jax.jit(
                lambda p, t, c, i: self.model.prefill_slot(
                    p, t, self.ctx, c, i))
        self._paged_suffix = paged_sfx

    # ------------------------------------------------------------------
    # paged-pool device helpers
    # ------------------------------------------------------------------
    def _cow_copy(self, cache, src, dst):
        """Copy page ``src``'s packed bytes into page ``dst`` in both K and
        V slabs — the eager copy-on-write step of a partial prefix hit
        (serving.kvpool).  Page axis is axis 1 of every child (behind the
        layer/app axis)."""
        def cp(qt):
            return qtensor.QTensor(
                qt.payload.at[:, dst].set(qt.payload[:, src]),
                qt.scales.at[:, dst].set(qt.scales[:, src]),
                qt.scale32, qt.method, qt.layout, qt.shape, qt.dtype)
        return dict(cache, k=cp(cache["k"]), v=cp(cache["v"]))

    def _mesh_ctx(self):
        """Ambient-mesh context for jit traces: activates the models'
        ``shard()`` constraints and the mesh-aware ``qlinear`` dispatch
        (no-op for single-device engines)."""
        return self.mesh if self.mesh is not None else contextlib.nullcontext()

    # ------------------------------------------------------------------
    # storage accounting
    # ------------------------------------------------------------------
    def kv_cache_bytes(self) -> int:
        """HBM bytes held by the KV/state cache (QTensor leaves count their
        wire bytes — 4.5 bits/value instead of bf16's 16)."""
        total = 0
        for leaf in jax.tree.leaves(
                self.cache, is_leaf=lambda x: isinstance(x, qtensor.QTensor)):
            total += int(leaf.nbytes)
        return total

    # ------------------------------------------------------------------
    # packed-weight checkpointing: the QTensor pytree round-trips through
    # CheckpointManager (payload/scales/scale32 are ordinary leaves; the
    # static layout metadata travels in the manifest spec).
    # ------------------------------------------------------------------
    def save_weights(self, directory: str, step: int = 0):
        CheckpointManager(directory).save_packed(step, self.params,
                                                blocking=True)

    def load_weights(self, directory: str, step: int | None = None):
        """Restore a packed checkpoint; a mesh engine restores each leaf
        *directly* into the sharded serve layout (per-child NamedShardings
        derived from the manifest's structural spec before any leaf bytes
        are read — no replicated intermediate tree)."""
        mgr = CheckpointManager(directory)
        if self.mesh is None:
            restored, _ = mgr.restore_packed(step)
        else:
            step, spec = mgr.packed_spec(step)
            like = qtensor.tree_like(spec)
            qt_leaves = [l for l in jax.tree.leaves(
                like, is_leaf=lambda x: isinstance(x, qtensor.QTensor))
                if isinstance(l, qtensor.QTensor)]
            if all(isinstance(q.payload, jax.ShapeDtypeStruct)
                   for q in qt_leaves):
                # manifest records child shapes: derive per-child
                # NamedShardings up front and restore each leaf straight
                # onto its shards (no replicated intermediate)
                specs = dist_sharding.serve_packed_specs(like, self.mesh)
                shardings = dist_sharding.packed_restore_shardings(
                    like, specs, self.mesh)
                restored, _ = mgr.restore_packed(step, shardings=shardings)
            else:
                # pre-child-shape manifest (dummy-leaf skeleton): restore
                # replicated first, then derive the layout from the
                # concrete tree and move the leaves
                restored, _ = mgr.restore_packed(step)
                specs = dist_sharding.serve_packed_specs(restored, self.mesh)
            # re-placing is a no-op move for already-placed leaves; it
            # restamps each QTensor's aux pspec to THIS engine's layout
            # (the checkpoint may have been saved under a different one)
            restored = dist_sharding.shard_packed_tree(restored, specs,
                                                       self.mesh)
            self.weight_specs = specs
        self.params = restored
        # recompute storage stats from what was actually restored (a cold
        # engine built with pack_weights=False would otherwise keep 0/1.0)
        self.packed_bytes, self.dense_bytes = _packed_stats(restored)
        self.compression = (self.dense_bytes / self.packed_bytes
                            if self.packed_bytes else 1.0)
        if self.mesh is None:
            self.params = _prepad_tree(
                self.params, _prepad_group(self.act_quant), self.batch_size)

    # ------------------------------------------------------------------
    def add_request(self, req: Request) -> bool:
        if len(req.prompt) == 0:
            raise ValueError("empty prompt: a request must carry at least "
                             "one prompt token")
        if req.max_new_tokens < 1:
            raise ValueError("max_new_tokens must be >= 1 (the prefill "
                             "itself produces the first token)")
        # the final generated token is emitted but never fed back, so the
        # highest cache position written is prompt + max_new - 2
        if len(req.prompt) + req.max_new_tokens - 1 > self.max_len:
            raise ValueError(
                f"request {req.uid} needs {len(req.prompt)} prompt + "
                f"{req.max_new_tokens} new tokens but the cache holds "
                f"max_len={self.max_len}")
        free = next((i for i, s in enumerate(self.slots) if s is None), None)
        if free is None:
            return False
        i = free
        if self.kv_pool is not None:
            # admit by PAGE availability too: map cached prefix pages,
            # allocate the rest (evicting LRU cached pages as needed).  A
            # pool that cannot cover the request leaves it unadmitted.
            adm = self.kv_pool.acquire(req.prompt, req.max_new_tokens)
            if adm is None:
                return False
            self.slots[i] = req
            self.lengths[i] = 0
            self.cache = self.model.reset_slot(self.cache, i)
            self._slot_pages[i] = adm.pages
            row = np.zeros((self.block_tables.shape[1],), np.int32)
            row[:len(adm.pages)] = adm.pages
            self.block_tables[i] = row
            self.cache = dict(self.cache,
                              pages=jnp.asarray(self.block_tables))
            if adm.cow is not None:
                src, dst = adm.cow
                self.cache = self._copy_page(self.cache, jnp.int32(src),
                                             jnp.int32(dst))
            self._prefill_slot(i, req, start_pos=adm.shared_len)
            # register the prompt's pages for future prefix hits (their
            # bytes are final now: eager COW means no shared page is ever
            # written after this point)
            self.kv_pool.insert(req.prompt, adm.pages)
            return True
        self.slots[i] = req
        # a reused slot starts over at position 0 with zeroed cache
        # rows — no KV / SSM state leaks from the previous occupant
        self.lengths[i] = 0
        self.cache = self.model.reset_slot(self.cache, i)
        self._prefill_slot(i, req)
        return True

    @staticmethod
    def bucket_len(p_len: int, max_len: int) -> int:
        """The pow-2/64-step prompt-length ladder: next power of two below
        64, then 64-step rungs, clamped to the cache length."""
        b = 8
        while b < min(p_len, 64):
            b *= 2
        if p_len > 64:
            b = -(-p_len // 64) * 64
        return min(b, max_len)

    def _prefill_slot(self, i: int, req: Request, start_pos: int = 0):
        """Single-slot batched prefill: ONE jit dispatch runs the whole
        prompt through ``model.prefill_slot`` at (1, P) shapes, writing all
        of slot ``i``'s cache rows at once.  Other slots' batch rows are
        never touched (the model slices/scatters only row ``i``), so an
        admission is invisible to its batchmates for all families with no
        snapshot/restore.

        With ``prefill_buckets`` active the prompt pads up the length
        ladder (suffix zeros) and the true length rides along as a dynamic
        operand, so nearby prompt lengths share one compiled prefill; the
        emitted token and the real cache rows are bitwise those of the
        exact-length call.

        ``start_pos > 0`` (paged transformers only) is a prefix-cache hit:
        the first ``start_pos`` prompt tokens are already served by mapped
        pool pages, so only the prompt *suffix* runs — the admission's
        prefill cost shrinks by the shared prefix."""
        p_len = len(req.prompt)
        toks = np.asarray(req.prompt, np.int32)[start_pos:]
        s_len = len(toks)  # >= 1: the pool's match stops at p_len - 1
        if self.prefill_buckets:
            pb = self.bucket_len(s_len, self.max_len - start_pos)
            if pb > s_len:
                toks = np.pad(toks, (0, pb - s_len))
        shape_key = len(toks)
        if shape_key in self._prefill_lens:
            self.prefill_cache_hits += 1
        else:
            self._prefill_lens.add(shape_key)
            self.prefill_compiles += 1
        tokens = jnp.asarray(toks[None, :])
        with self._mesh_ctx():
            if self.prefill_buckets and self._paged_suffix:
                logits, self.cache = self._prefill(
                    self.params, tokens, self.cache, jnp.int32(i),
                    jnp.int32(s_len), jnp.int32(start_pos))
            elif self.prefill_buckets:
                logits, self.cache = self._prefill(
                    self.params, tokens, self.cache, jnp.int32(i),
                    jnp.int32(s_len))
            elif self._paged_suffix:
                logits, self.cache = self._prefill(
                    self.params, tokens, self.cache, jnp.int32(i),
                    jnp.int32(start_pos))
            else:
                logits, self.cache = self._prefill(
                    self.params, tokens, self.cache, jnp.int32(i))
        self.lengths[i] = p_len
        req._next = int(jnp.argmax(logits[0]))
        self.prefill_dispatches += 1
        self.admissions += 1

    def _finish_slot(self, i: int):
        """Free slot ``i``.  A paged engine also releases the request's
        pages back to the pool (tree-registered pages park in the LRU,
        still servable as prefix hits) and points the slot's block-table
        row at the trash page — the inactive lane's decode scatters must
        never land in pages the pool may re-grant."""
        self.slots[i] = None
        if self.kv_pool is not None:
            pages = self._slot_pages[i]
            if pages:
                self.kv_pool.release(pages)
            self._slot_pages[i] = None
            self.block_tables[i] = 0
            self.lengths[i] = 0
            self.cache = dict(
                self.cache, pages=self.cache["pages"].at[i].set(0))

    def pool_report(self) -> dict | None:
        """Pool occupancy / prefix-hit / eviction counters (None when the
        engine is not paged)."""
        return None if self.kv_pool is None else self.kv_pool.stats()

    def step(self) -> list[tuple[int, int]]:
        """One decode step for all active slots (each at its own cache
        position); returns (uid, token).

        A freshly prefilled slot first emits ``_next`` — the prefill's own
        argmax IS the first generated token (it used to be fed back but
        never emitted, shifting the stream by one) — then decodes."""
        toks = np.zeros((self.batch_size,), np.int32)
        out = []
        active = []
        n_live = sum(r is not None for r in self.slots)
        self.max_concurrent = max(self.max_concurrent, n_live)
        for i, req in enumerate(self.slots):
            if req is None or req.done:
                continue
            if not req.generated:
                if req._next is None:
                    raise RuntimeError(
                        f"request {req.uid} occupies slot {i} but was never "
                        "prefilled (requests enter the batch via "
                        "add_request, which runs the admission prefill)")
                req.generated.append(req._next)
                out.append((req.uid, req._next))
                if len(req.generated) >= req.max_new_tokens:
                    req.done = True
                    self._finish_slot(i)
                    continue
            toks[i] = req.generated[-1]
            active.append(i)
        if not active:
            return out
        with self._mesh_ctx():
            logits, self.cache = self._decode(
                self.params, jnp.asarray(toks), self.cache,
                jnp.asarray(self.lengths.copy()))
        # one vectorized argmax + host transfer per step, not one per slot
        next_toks = np.asarray(jnp.argmax(logits, axis=-1))
        for i in active:
            tok = int(next_toks[i])
            req = self.slots[i]
            req.generated.append(tok)
            self.lengths[i] += 1
            out.append((req.uid, tok))
            if len(req.generated) >= req.max_new_tokens:
                req.done = True
                self._finish_slot(i)
        return out
