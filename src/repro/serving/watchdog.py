"""Step watchdog: a hung-step budget over the engine's heartbeat.

The engine worker is one thread and the engine is one state machine — a
decode dispatch that stops returning (a wedged interpreter, a runaway
injected ``slow`` schedule, a pathological retry storm) would silently
freeze every stream with no typed outcome.  The watchdog turns "the step
took too long" into the same ladder the engine already uses for every
other failure:

* Each ``engine.step()`` reports its duration (on the ENGINE clock — a
  virtual clock under fault injection, so hung-step behavior is a pure
  function of the seed).
* A step over ``budget_ms`` is a **strike**; a step back under budget
  clears the count (sustained slowness is the signal, not one outlier).
* The first strike answers ``"degrade"`` — the engine fires its existing
  degradation ladder (fused W4A4 -> the 2-pass per-row composition,
  bitwise-preserving), trading dispatch count for simpler kernels.
* ``fail_after`` consecutive strikes answer ``"fail"`` — the engine
  fails the *most starved* in-flight request (longest since its last
  token) with the typed ``watchdog_timeout`` reason, releasing its slot
  and pool pages instead of wedging the whole batch behind it.

The watchdog never touches the engine itself: it is pure host-side
accounting (no jax, no threads), and the engine applies the verdicts so
its counters and journal see every transition first.
"""
from __future__ import annotations

__all__ = ["StepWatchdog"]


class StepWatchdog:
    """Consecutive-overrun escalation over per-step heartbeats.

    ``beat(elapsed_ms)`` returns ``None`` (healthy), ``"degrade"`` (first
    strikes), or ``"fail"`` (``fail_after``-th consecutive strike; the
    strike count resets so the next verdict needs sustained slowness
    again, not one more slow step)."""

    def __init__(self, budget_ms: float, *, fail_after: int = 2):
        if budget_ms <= 0:
            raise ValueError(f"hung-step budget must be positive, got "
                             f"{budget_ms}")
        if fail_after < 1:
            raise ValueError(f"fail_after must be >= 1, got {fail_after}")
        self.budget_ms = float(budget_ms)
        self.fail_after = int(fail_after)
        self.strikes = 0
        self.beats = 0
        self.overruns = 0
        self.degrades = 0
        self.fails = 0
        self.last_ms = 0.0
        self.worst_ms = 0.0

    def beat(self, elapsed_ms: float) -> str | None:
        self.beats += 1
        self.last_ms = float(elapsed_ms)
        self.worst_ms = max(self.worst_ms, self.last_ms)
        if elapsed_ms <= self.budget_ms:
            self.strikes = 0
            return None
        self.strikes += 1
        self.overruns += 1
        if self.strikes >= self.fail_after:
            self.strikes = 0
            self.fails += 1
            return "fail"
        self.degrades += 1
        return "degrade"

    def report(self) -> dict:
        """Flat scalar snapshot for ``metrics_report()["watchdog"]``."""
        return {
            "budget_ms": self.budget_ms,
            "fail_after": self.fail_after,
            "beats": self.beats,
            "strikes": self.strikes,
            "overruns": self.overruns,
            "degrades": self.degrades,
            "fails": self.fails,
            "last_step_ms": self.last_ms,
            "worst_step_ms": self.worst_ms,
        }
