"""Chunked-prefill scheduler: long admissions never stall the decode batch.

The engine's historical admission path runs the WHOLE prompt through one
``prefill_slot`` dispatch inside ``_try_admit`` — a 2k-token prompt costs a
2k-token prefill before the next ``step()`` can decode, so every in-flight
request's inter-token latency spikes by the full prompt length (the classic
"prefill stall").  This module is the host-side accounting for the fix:
admissions are split into fixed token-budget *chunks* interleaved with
decode steps — each ``engine.step()`` spends at most ``chunk_tokens``
prompt tokens of prefill work, then decodes the running batch as usual, so
the decode cadence is bounded by the chunk budget instead of the longest
prompt in the queue.

Why chunking is *exact* (the property tests pin it bitwise):

* KV rows quantize under the pinned ``KV_SCALE32`` contract, so a row's
  packed bytes are a pure function of its values — write order (one chunk
  at a time vs the whole prompt at once) cannot change them.  The same
  holds trivially for the bf16 dense cache and for the paged pool slabs
  (the same contract that makes prefix sharing exact, serving.kvpool).
* ``prefill_slot(start_pos=s0)`` shifts positions/causality by ``s0`` and
  attends over the already-written cache rows ``[0, s0)`` with the same
  masked full-cache attention the whole-prompt call uses, so per-query
  softmax reductions run over the identical key set in the identical
  order — the last chunk's final-position logits are bitwise the
  whole-prompt call's, hence the same first token.

Chunks run at ONE static shape (the token budget, final partial chunk
padded up with ``true_len`` masking — the bucketing argument from PR 5),
so a chunked engine compiles one prefill executable total instead of one
per prompt-length bucket.

SSM / hybrid families are rejected: their recurrent state advances for
every padded token AND ``prefill_slot`` has no ``start_pos`` resume path
(the state would need checkpointing at chunk boundaries — the documented
ROADMAP carry-over), so the engine refuses ``prefill_chunk=`` for them
with a typed error instead of silently corrupting slot state.

This module is pure Python (no jax): the engine owns the device work and
calls in here for job order, cursors, and the per-step token ledger that
the fairness tests and ``BENCH_serving.json["frontend"]`` assert against.
"""
from __future__ import annotations

import collections
import dataclasses

__all__ = ["ChunkedPrefillScheduler", "PrefillJob"]


@dataclasses.dataclass
class PrefillJob:
    """One admission being prefilled chunk-by-chunk.  ``cursor`` is the
    next prompt position to prefill (starts at the prefix-cache
    ``shared_len`` for paged prefix hits); the job completes when it
    reaches ``p_len``."""
    uid: int
    slot: int
    req: object                 # serving.engine.Request
    p_len: int
    cursor: int = 0
    chunks_done: int = 0

    @property
    def remaining(self) -> int:
        return self.p_len - self.cursor


class ChunkedPrefillScheduler:
    """FIFO chunked-prefill scheduler with a per-step token ledger.

    The engine enqueues one :class:`PrefillJob` per chunked admission and
    calls :meth:`head` each step to learn which job gets this step's chunk
    budget; after running the device work it reports back through
    :meth:`advance` (and :meth:`note_step` once the step's decode ran).
    Jobs progress strictly in admission order — one job prefills at a
    time, so a burst of admissions cannot multiply the per-step prefill
    work past the budget.

    ``step_log`` records ``{"prefill_tokens", "decode_rows", "backlog"}``
    per engine step — the deterministic, wall-clock-free evidence that no
    decode step was delayed by more than ``chunk_tokens`` (the fairness
    test and the frontend benchmark's stall-free assertion both read it).
    """

    def __init__(self, chunk_tokens: int):
        if chunk_tokens < 1:
            raise ValueError(
                f"prefill chunk budget must be >= 1 token, got {chunk_tokens}")
        self.chunk = int(chunk_tokens)
        self._jobs: collections.OrderedDict[int, PrefillJob] = \
            collections.OrderedDict()
        self.step_log: list[dict] = []
        self.chunks_run = 0
        self.tokens_prefilled = 0
        self.jobs_completed = 0

    # -- job lifecycle -----------------------------------------------------
    def enqueue(self, uid: int, slot: int, req, p_len: int,
                start_pos: int = 0) -> PrefillJob:
        if uid in self._jobs:
            raise ValueError(f"request {uid} already has a prefill job")
        job = PrefillJob(uid=uid, slot=slot, req=req, p_len=p_len,
                         cursor=start_pos)
        self._jobs[uid] = job
        return job

    def head(self) -> PrefillJob | None:
        """The job that gets this step's chunk budget (FIFO), or None."""
        for job in self._jobs.values():
            return job
        return None

    def get(self, uid: int) -> PrefillJob | None:
        return self._jobs.get(uid)

    def drop(self, uid: int) -> bool:
        """Remove a job (cancel / expiry / fault quarantine).  The engine
        owns the slot/page rollback; this only forgets the cursor."""
        return self._jobs.pop(uid, None) is not None

    def restart(self, uid: int, start_pos: int = 0) -> None:
        """Reset a job's cursor (the paged -> fixed-slot degradation
        migrates mid-prefill jobs by starting them over on the fresh
        cache, where no prefix pages exist)."""
        job = self._jobs[uid]
        job.cursor = start_pos
        job.chunks_done = 0

    def advance(self, job: PrefillJob, n_tokens: int) -> bool:
        """Record one executed chunk of ``n_tokens`` real prompt tokens.
        Returns True when the job just completed (the engine then flips
        the request RUNNING and registers pool pages)."""
        job.cursor += n_tokens
        job.chunks_done += 1
        self.chunks_run += 1
        self.tokens_prefilled += n_tokens
        if job.cursor >= job.p_len:
            del self._jobs[job.uid]
            self.jobs_completed += 1
            return True
        return False

    # -- per-step ledger ---------------------------------------------------
    def note_step(self, prefill_tokens: int, decode_rows: int) -> None:
        self.step_log.append({
            "prefill_tokens": int(prefill_tokens),
            "decode_rows": int(decode_rows),
            "backlog": self.backlog_tokens(),
        })

    def backlog_tokens(self) -> int:
        return sum(j.remaining for j in self._jobs.values())

    @property
    def pending_jobs(self) -> int:
        return len(self._jobs)

    def max_prefill_tokens_per_step(self) -> int:
        return max((s["prefill_tokens"] for s in self.step_log), default=0)

    def jobs_report(self) -> list[dict]:
        """Per-job cursor snapshot (FIFO order) — the drain ledger
        journals it so a post-restart operator can see exactly which
        admissions died mid-prefill (recovery re-prefills them from
        position 0; the cursors are forensic, not replayed)."""
        return [{"uid": j.uid, "slot": j.slot, "p_len": j.p_len,
                 "cursor": j.cursor, "chunks_done": j.chunks_done}
                for j in self._jobs.values()]

    def report(self) -> dict:
        """Ledger summary for ``metrics_report()`` / the frontend bench."""
        return {
            "chunk_tokens": self.chunk,
            "pending_jobs": self.pending_jobs,
            "backlog_tokens": self.backlog_tokens(),
            "chunks_run": self.chunks_run,
            "tokens_prefilled": self.tokens_prefilled,
            "jobs_completed": self.jobs_completed,
            "steps_logged": len(self.step_log),
            "max_prefill_tokens_per_step":
                self.max_prefill_tokens_per_step(),
        }
