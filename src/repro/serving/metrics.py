"""Serving metrics: counters / gauges / histograms + Prometheus text rendering.

The engine already keeps typed lifecycle counters (``engine.counters``) and
ad-hoc reports (``pool_report``, ``robustness_report``).  This module is the
uniform observability layer on top: a small registry the engine feeds every
``step()`` — queue depth, active slots, KV-pool occupancy / prefix-hit rate,
TTFT and inter-token-latency samples — exposed two ways:

* ``engine.metrics_report()`` — one JSON-able dict (counters + gauges +
  histogram percentile snapshots + scheduler ledger), consumed by the
  frontend benchmark and the tests;
* ``render_prometheus(report)`` — Prometheus text exposition for the HTTP
  server's ``GET /metrics`` (serving.server).

Everything here is host-side pure Python with no locking requirements
beyond the GIL: the engine worker thread is the only writer, and readers
(the HTTP thread) only ever see snapshot dicts.

Histograms keep a bounded reservoir of raw samples (latest ``maxlen``) so
percentiles are exact over the recent window rather than bucket-estimated —
at serving-bench scale (hundreds of requests) the window covers the whole
run, which keeps the seeded benchmarks deterministic.
"""
from __future__ import annotations

import collections
from typing import Iterable

__all__ = ["MetricsRegistry", "Histogram", "render_prometheus", "percentile"]


def percentile(samples: Iterable[float], q: float) -> float:
    """Nearest-rank percentile (q in [0, 100]) — matches the convention in
    serving.faults / benchmarks so p50/p99 agree across reports."""
    xs = sorted(samples)
    if not xs:
        return 0.0
    rank = max(0, min(len(xs) - 1, int(round(q / 100.0 * (len(xs) - 1)))))
    return float(xs[rank])


class Histogram:
    """Bounded-reservoir histogram: keeps the most recent ``maxlen``
    samples plus lifetime count/sum, snapshots exact percentiles over
    the window."""

    def __init__(self, maxlen: int = 4096):
        self._window: collections.deque[float] = collections.deque(
            maxlen=maxlen)
        self.count = 0
        self.total = 0.0

    def observe(self, value: float) -> None:
        v = float(value)
        self._window.append(v)
        self.count += 1
        self.total += v

    def snapshot(self) -> dict:
        w = list(self._window)
        return {
            "count": self.count,
            "sum": self.total,
            "mean": (self.total / self.count) if self.count else 0.0,
            "p50": percentile(w, 50),
            "p90": percentile(w, 90),
            "p99": percentile(w, 99),
            "max": max(w) if w else 0.0,
        }


class MetricsRegistry:
    """Name -> counter/gauge/histogram.  Names are dotted lowercase
    (``requests.finished``, ``ttft_ms``); the Prometheus renderer
    sanitizes them.  Creation is implicit on first touch so call sites
    stay one-liners."""

    def __init__(self, histogram_window: int = 4096):
        self.counters: collections.Counter[str] = collections.Counter()
        self.gauges: dict[str, float] = {}
        self.histograms: dict[str, Histogram] = {}
        self._hist_window = histogram_window

    def inc(self, name: str, value: float = 1.0) -> None:
        self.counters[name] += value

    def set_gauge(self, name: str, value: float) -> None:
        self.gauges[name] = float(value)

    def observe(self, name: str, value: float) -> None:
        hist = self.histograms.get(name)
        if hist is None:
            hist = self.histograms[name] = Histogram(self._hist_window)
        hist.observe(value)

    def snapshot(self) -> dict:
        return {
            "counters": dict(self.counters),
            "gauges": dict(self.gauges),
            "histograms": {k: h.snapshot()
                           for k, h in self.histograms.items()},
        }


def _prom_name(prefix: str, name: str) -> str:
    out = []
    for ch in name:
        out.append(ch if (ch.isalnum() or ch == "_") else "_")
    return f"{prefix}_{''.join(out)}"


def render_prometheus(report: dict, prefix: str = "mixfp4") -> str:
    """Render a ``metrics_report()`` dict as Prometheus text exposition.

    Counters/gauges map 1:1; histogram snapshots become ``*_count``,
    ``*_sum``, and ``{quantile=...}`` gauge lines (summary-style).  Any
    extra top-level sub-dicts of scalars (``kv_pool``, ``scheduler``)
    flatten to gauges so the scrape carries the whole report.
    """
    lines: list[str] = []

    def emit(kind: str, name: str, value, labels: str = "") -> None:
        if isinstance(value, bool):
            value = int(value)
        if not isinstance(value, (int, float)):
            return
        pn = _prom_name(prefix, name)
        lines.append(f"# TYPE {pn} {kind}")
        lines.append(f"{pn}{labels} {value}")

    for name, value in sorted(report.get("counters", {}).items()):
        emit("counter", name, value)
    for name, value in sorted(report.get("gauges", {}).items()):
        emit("gauge", name, value)
    for name, snap in sorted(report.get("histograms", {}).items()):
        pn = _prom_name(prefix, name)
        lines.append(f"# TYPE {pn} summary")
        for q in ("p50", "p90", "p99"):
            lines.append(
                f'{pn}{{quantile="0.{q[1:]}"}} {snap.get(q, 0.0)}')
        lines.append(f"{pn}_count {snap.get('count', 0)}")
        lines.append(f"{pn}_sum {snap.get('sum', 0.0)}")
    for section in ("kv_pool", "scheduler", "journal", "watchdog"):
        sub = report.get(section)
        if isinstance(sub, dict):
            for name, value in sorted(sub.items()):
                emit("gauge", f"{section}.{name}", value)
    return "\n".join(lines) + "\n"
