"""Deterministic, seeded fault injection for the serving engine.

The engine's failure handling has to be *deterministic*, not just
"doesn't crash": the MixFP4 format bit lives in the sign of the E4M3
scale byte, so a single corrupted byte silently flips a block's
micro-format.  Since PR 9 the W4A4 activation path quantizes under
per-ROW scales, so a request's bytes are a pure function of its own
activations — batchmates (including injected poison victims) cannot
move them.  That turns the sweep's headline check into a hard claim:
every unaffected stream must be **bitwise-identical** to the
fault-free run, under W4A16 AND W4A4 alike.  The only way to pin that
is to make the faults themselves reproducible.

This module is pure host-side machinery (no jax):

* :class:`FaultRule` — one fault at one engine boundary (*site*), fired
  either at explicit occurrence indices or with a per-occurrence
  probability, both deterministic functions of ``(seed, site, n)``.
* :class:`FaultInjector` — the seeded schedule.  The engine calls
  ``fire(site, ...)`` at each of its host/device boundaries —
  ``prefill``, ``decode``, ``cow_copy``, ``pool_acquire``,
  ``checkpoint_read`` — and the injector answers with a
  :class:`FaultAction`: raise a typed error, poison a victim's logits
  (NaN), deny a pool-page acquisition, or advance the clock (a "slow"
  step).  Every fired event lands in ``injector.log``.  A ``dispatch``
  fault degrades the fused W4A4 path to its two-dispatch per-row
  composition (``mixfp4-2pass-rowscale``) — bitwise-preserving by
  construction, which the sweep verifies rather than assumes.
* :class:`VirtualClock` — deterministic time.  When an injector is
  installed the engine's deadlines, TTFT accounting, and retry backoff
  all run on this clock, so "p99 TTFT under injected slow steps" is a
  pure function of the seed.
* :func:`drive` / :func:`chaos_sweep` — the chaos harness: sweep seeded
  fault schedules against the fault-free oracle engine and assert the
  lifecycle invariants (ISSUE 7/9): unaffected streams
  bitwise-identical to the fault-free oracle (full identity, not
  "within coupling bounds" — the per-row scales make the W4A4 run an
  exact oracle too), affected streams a strict prefix, every fatal
  fault resolving to exactly one terminal state, and no pool page /
  prefix-tree refcount leaks after drain.

CLI (the CI ``chaos-smoke`` leg)::

    PYTHONPATH=src python -m repro.serving.faults \
        --families dense,moe,ssm,hybrid --seeds 0,1,2
    PYTHONPATH=src python -m repro.serving.faults \
        --families dense,ssm --seeds 0,1 --act-quant mixfp4
"""
from __future__ import annotations

import dataclasses
import hashlib
import time

__all__ = [
    "SITES", "KINDS", "FaultRule", "FaultAction", "InjectedFault",
    "FaultInjector", "VirtualClock", "SystemClock", "parse_faults",
    "drive", "schedule_for_seed", "chaos_sweep", "crash_restart_sweep",
]

# Engine host/device boundaries an injector can hook.  ``journal_write``
# guards every request-journal append (serving.journal); ``process_crash``
# fires at the top of ``engine.step()`` — an 'error' there simulates
# SIGKILL between steps (the harness abandons the engine un-flushed and
# recovers a fresh one from the journal).
SITES = ("prefill", "decode", "cow_copy", "pool_acquire",
         "checkpoint_read", "journal_write", "process_crash")

# What a fired fault does:
#   error     - raise InjectedFault (fatal for the request at that site)
#   transient - raise InjectedFault(transient=True); succeeds on retry
#   nan       - poison the victim request's logits (host-side NaN)
#   slow      - advance the clock by delay_ms (an injected slow step)
#   dispatch  - raise a failed-kernel-dispatch error (the engine degrades
#               fused -> 2-pass per-row W4A4 when it can, bitwise)
#   deny      - pool_acquire only: the pool pretends to be exhausted
KINDS = ("error", "transient", "nan", "slow", "dispatch", "deny")


class InjectedFault(RuntimeError):
    """A fault raised at an engine boundary by the injector."""

    def __init__(self, site: str, kind: str, occurrence: int,
                 uid: int | None = None):
        super().__init__(f"injected {kind} fault at {site}"
                         f"[{occurrence}]"
                         + (f" (uid={uid})" if uid is not None else ""))
        self.site = site
        self.kind = kind
        self.occurrence = occurrence
        self.uid = uid

    @property
    def transient(self) -> bool:
        return self.kind == "transient"


@dataclasses.dataclass(frozen=True)
class FaultRule:
    """One fault at one site.  Fires at the occurrence indices in ``at``
    and/or with probability ``prob`` per occurrence (deterministic in
    ``(seed, site, occurrence)``); ``times`` caps total fires.  ``uid``
    pins the victim request for nan/error faults (None = the injector
    picks deterministically among the active requests)."""
    site: str
    kind: str
    at: tuple = ()
    prob: float = 0.0
    uid: int | None = None
    delay_ms: float = 50.0
    times: int | None = None

    def __post_init__(self):
        if self.site not in SITES:
            raise ValueError(f"unknown fault site {self.site!r} "
                             f"(expected one of {SITES})")
        if self.kind not in KINDS:
            raise ValueError(f"unknown fault kind {self.kind!r} "
                             f"(expected one of {KINDS})")
        if self.kind == "deny" and self.site != "pool_acquire":
            raise ValueError("'deny' faults only make sense at the "
                             "pool_acquire site")


@dataclasses.dataclass
class FaultAction:
    """What the engine must do after a boundary check: raise ``error``
    (after applying ``delay_ms`` / counters), treat an acquisition as
    denied, and/or poison ``poison_uids``' logits rows."""
    fired: tuple = ()               # FaultRule instances that fired
    error: InjectedFault | None = None
    deny: bool = False
    poison_uids: frozenset = frozenset()
    delay_ms: float = 0.0


class VirtualClock:
    """Deterministic monotonic clock: advances only when told to (injected
    slow steps, retry backoff).  ``__call__`` -> seconds."""

    def __init__(self, t0: float = 0.0):
        self.t = float(t0)

    def __call__(self) -> float:
        return self.t

    def advance(self, seconds: float) -> None:
        self.t += float(seconds)

    def sleep(self, seconds: float) -> None:     # no real sleeping
        self.advance(seconds)


class SystemClock:
    """Wall clock (time.monotonic) with a real — but capped — sleep, so a
    mis-configured backoff can never hang a serving process for long."""

    MAX_SLEEP_S = 0.25

    def __call__(self) -> float:
        return time.monotonic()

    def sleep(self, seconds: float) -> None:
        time.sleep(min(max(seconds, 0.0), self.MAX_SLEEP_S))


def _unit(seed: int, site: str, n: int, salt: str = "") -> float:
    """Deterministic uniform [0,1) from (seed, site, occurrence) — stable
    across platforms/processes (blake2b, not Python's randomized hash)."""
    h = hashlib.blake2b(f"{seed}:{site}:{n}:{salt}".encode(),
                        digest_size=8).digest()
    return int.from_bytes(h, "big") / 2.0 ** 64


class FaultInjector:
    """Seeded fault schedule over the engine's boundaries.

    The injector counts every ``fire(site, ...)`` call per site; whether a
    rule fires at occurrence ``n`` depends only on ``(seed, site, n)`` and
    the rule itself — never on wall time or dict order — so a schedule
    replays exactly as long as the engine is driven the same way."""

    def __init__(self, seed: int, rules, clock: VirtualClock | None = None):
        self.seed = int(seed)
        self.rules = tuple(rules)
        self.clock = clock if clock is not None else VirtualClock()
        self.counts = {site: 0 for site in SITES}
        self.fires = {id(r): 0 for r in self.rules}
        self.log: list[dict] = []

    # ------------------------------------------------------------------
    def _rule_fires(self, rule: FaultRule, n: int) -> bool:
        if rule.times is not None and self.fires[id(rule)] >= rule.times:
            return False
        if n in rule.at:
            return True
        return bool(rule.prob) and \
            _unit(self.seed, rule.site, n, rule.kind) < rule.prob

    def fire(self, site: str, *, uid: int | None = None,
             active_uids=()) -> FaultAction:
        """One boundary crossing at ``site``.  Returns the action; the
        ENGINE raises ``action.error`` (so its counters see it first)."""
        n = self.counts[site]
        self.counts[site] = n + 1
        act = FaultAction()
        fired = []
        for rule in self.rules:
            if rule.site != site or not self._rule_fires(rule, n):
                continue
            self.fires[id(rule)] += 1
            victim = rule.uid
            if victim is None and rule.kind in ("nan", "error"):
                pool = list(active_uids) if active_uids else (
                    [uid] if uid is not None else [])
                if pool:
                    victim = pool[int(_unit(self.seed, site, n, "victim")
                                     * len(pool)) % len(pool)]
            if rule.kind == "slow":
                act.delay_ms += rule.delay_ms
            elif rule.kind == "deny":
                act.deny = True
            elif rule.kind == "nan":
                if victim is not None:
                    act.poison_uids = act.poison_uids | {victim}
            else:   # error / transient / dispatch
                if act.error is None:
                    act.error = InjectedFault(site, rule.kind, n, uid=victim)
            fired.append(rule)
            self.log.append({"site": site, "occurrence": n,
                             "kind": rule.kind, "uid": victim,
                             "t": self.clock()})
        act.fired = tuple(fired)
        if act.delay_ms:
            self.clock.advance(act.delay_ms / 1e3)
        return act

    # ------------------------------------------------------------------
    def fatal_victims(self) -> set:
        """Distinct request uids hit by a request-fatal fault (nan/error
        at a request-scoped site) — each must resolve to exactly one
        terminal FAILED state."""
        return {e["uid"] for e in self.log
                if e["kind"] in ("nan", "error") and e["uid"] is not None}

    def summary(self) -> dict:
        return {
            "seed": self.seed,
            "rules": [dataclasses.asdict(r) for r in self.rules],
            "occurrences": dict(self.counts),
            "events": len(self.log),
            "by_kind": _count_by(self.log, "kind"),
            "by_site": _count_by(self.log, "site"),
        }


def _count_by(log, key):
    out: dict = {}
    for e in log:
        out[e[key]] = out.get(e[key], 0) + 1
    return out


# ---------------------------------------------------------------------------
# Spec parsing: "--inject-faults SEED:site=kind[:ms][@when][#uid],..."
# ---------------------------------------------------------------------------
def parse_faults(spec: str) -> FaultInjector:
    """Parse ``"SEED:site=kind[:ms][@when][#uid],..."`` into an injector.

    ``when`` is either an occurrence index (``@3``), a probability
    (``@p0.1``), or absent (= every occurrence).  Examples::

        7:decode=nan@3
        7:decode=slow:25@p0.2,pool_acquire=deny@p0.1
        0:prefill=transient@0#4,checkpoint_read=transient@0
    """
    head, sep, body = spec.partition(":")
    if not sep or not head.strip().lstrip("-").isdigit():
        raise ValueError(
            f"bad fault spec {spec!r}: expected 'SEED:site=kind[@when],...'")
    seed = int(head)
    rules = []
    for part in filter(None, (p.strip() for p in body.split(","))):
        try:
            site, rhs = part.split("=", 1)
            uid = None
            if "#" in rhs:
                rhs, uid_s = rhs.rsplit("#", 1)
                uid = int(uid_s)
            when = None
            if "@" in rhs:
                rhs, when = rhs.rsplit("@", 1)
            kind, _, ms = rhs.partition(":")
            at, prob = (), 0.0
            if when is None:
                prob = 1.0
            elif when.startswith("p"):
                prob = float(when[1:])
            else:
                at = (int(when),)
            rules.append(FaultRule(
                site=site.strip(), kind=kind.strip(), at=at, prob=prob,
                uid=uid, delay_ms=float(ms) if ms else 50.0))
        except (ValueError, TypeError) as e:
            if isinstance(e, ValueError) and ("fault site" in str(e)
                                              or "fault kind" in str(e)):
                raise
            raise ValueError(f"bad fault rule {part!r} in {spec!r}: "
                             "expected 'site=kind[:ms][@when][#uid]'") from e
    return FaultInjector(seed, rules)


# ---------------------------------------------------------------------------
# Chaos harness: drive engines under a schedule and check the invariants
# ---------------------------------------------------------------------------
def drive(engine, prompts, *, max_new_tokens=4, deadline_ms=None,
          ttft_budget_ms=None, max_steps: int = 2000) -> dict:
    """Submit one request per prompt through the engine's bounded queue and
    step to drain.  Returns per-uid streams plus terminal states/reasons.
    ``max_steps`` guards against livelock — a stuck engine is a finding,
    not a hang."""
    from repro.serving.engine import Request
    reqs = [Request(uid=i, prompt=_np_prompt(p),
                    max_new_tokens=max_new_tokens, deadline_ms=deadline_ms,
                    ttft_budget_ms=ttft_budget_ms)
            for i, p in enumerate(prompts)]
    streams: dict = {r.uid: [] for r in reqs}
    for r in reqs:
        engine.submit(r)
    steps = 0
    while engine.has_work():
        for uid, tok in engine.step():
            streams[uid].append(tok)
        steps += 1
        if steps > max_steps:
            raise RuntimeError(
                f"engine made no progress after {max_steps} steps "
                f"(queue={len(engine.queue)}, "
                f"active={sum(s is not None for s in engine.slots)})")
    return {
        "streams": streams,
        "states": {r.uid: r.state for r in reqs},
        "reasons": {r.uid: r.finish_reason for r in reqs},
        "ttft_ms": {r.uid: r.ttft_ms() for r in reqs},
        "steps": steps,
    }


def _np_prompt(p):
    import numpy as np
    return np.asarray(p, np.int32)


def schedule_for_seed(seed: int, *, n_requests: int) -> list:
    """A mixed deterministic schedule for the CI sweep: one NaN poisoning
    (victim picked deterministically among the then-active requests, so
    the fault always lands on a live stream), one fatal prefill error on
    a later admission, sporadic slow decode steps, and occasional denied
    page acquisitions (no-ops for unpaged engines) — all pure functions
    of the seed."""
    later = (seed % n_requests + 1 + seed // n_requests) % n_requests
    return [
        FaultRule("decode", "nan", at=(2 + seed % 3,)),
        FaultRule("prefill", "error", at=(later,)),
        FaultRule("decode", "slow", prob=0.15, delay_ms=20.0),
        FaultRule("pool_acquire", "deny", prob=0.1, times=2),
    ]


def check_invariants(oracle: dict, got: dict, injector,
                     pool_stats: dict | None) -> list:
    """The chaos-sweep assertions.  Full *bitwise* identity against the
    fault-free oracle for every FINISHED stream and strict-prefix for
    every interrupted one — under W4A16 and, since the per-row W4A4
    scales (PR 9), under ``act_quant='mixfp4'`` too (no per-tensor
    batch coupling left to excuse a byte of drift).  Returns a list of
    violation strings (empty = pass)."""
    bad = []
    fatal = injector.fatal_victims()
    for uid, stream in got["streams"].items():
        state = got["states"][uid]
        want = oracle["streams"][uid]
        if str(state) == "FINISHED":
            if stream != want:
                bad.append(f"uid {uid} FINISHED but stream != oracle: "
                           f"{stream} vs {want}")
        else:
            if stream != want[:len(stream)]:
                bad.append(f"uid {uid} {state}: stream is not a prefix of "
                           f"the oracle's: {stream} vs {want}")
            if got["reasons"][uid] is None:
                bad.append(f"uid {uid} terminal {state} without a typed "
                           "reason")
    failed = {uid for uid, s in got["states"].items()
              if str(s) == "FAILED"}
    if fatal != failed:
        bad.append(f"fatal-fault victims {sorted(fatal)} != FAILED set "
                   f"{sorted(failed)}: every injected fatal fault must "
                   "resolve to exactly one terminal FAILED request")
    if pool_stats is not None:
        if pool_stats["pages_active"] != 0:
            bad.append(f"pool leaked {pool_stats['pages_active']} active "
                       "pages after drain")
    return bad


def crash_restart_sweep(make_engine, prompts, *, journal_root,
                        max_new_tokens=4, crash_stride=1,
                        max_crashes=32) -> dict:
    """Kill-and-recover chaos: crash at EVERY step boundary, recover,
    assert survivor streams bitwise.

    For each boundary ``k`` (strided), a seeded ``process_crash`` fault
    fires at the top of step ``k``; the harness abandons that engine
    exactly as a SIGKILL would (no flush, no close — the journal holds
    what its sync policy committed; ``make_engine`` should journal with
    ``journal_sync='always'`` so the crash point, not buffering, decides
    what survives), builds a FRESH engine over the same journal dir,
    calls ``engine.recover()`` and drives it to drain.  The invariant is
    the tentpole claim: for every request, pre-crash tokens ++
    post-recovery tokens must be **bitwise** the fault-free oracle's
    stream, every request must still reach a terminal state, and a paged
    pool must end with ``pages_active == 0`` (no leaked pages or
    prefix-tree refcounts).

    ``make_engine(faults=..., journal_dir=...)`` must build a fresh
    engine (same config/weights) each call; ``journal_dir=None`` means
    no journal (the oracle run).  Raises AssertionError listing every
    violation; returns a report dict otherwise."""
    import os

    oracle = drive(make_engine(faults=None, journal_dir=None), prompts,
                   max_new_tokens=max_new_tokens)
    report: dict = {"oracle_steps": oracle["steps"], "crashes": []}
    violations: list[str] = []
    boundaries = list(range(1, oracle["steps"] + 1, crash_stride))
    boundaries = boundaries[:max_crashes]
    from repro.serving.engine import Request
    for k in boundaries:
        jd = os.path.join(journal_root, f"crash_{k:04d}")
        inj = FaultInjector(k, [FaultRule("process_crash", "error",
                                          at=(k,))])
        eng = make_engine(faults=inj, journal_dir=jd)
        reqs = [Request(uid=i, prompt=_np_prompt(p),
                        max_new_tokens=max_new_tokens)
                for i, p in enumerate(prompts)]
        pre: dict = {r.uid: [] for r in reqs}
        for r in reqs:
            eng.submit(r)
        crashed = False
        steps = 0
        while eng.has_work():
            try:
                out = eng.step()
            except InjectedFault as e:
                if e.site != "process_crash":
                    raise
                crashed = True
                break
            for uid, tok in out:
                pre[uid].append(tok)
            steps += 1
            if steps > 2000:
                raise RuntimeError("crash harness livelocked pre-crash")
        if not crashed:
            # the schedule outran the run (admission timing shifted the
            # step count); nothing to recover — skip the boundary
            report["crashes"].append({"boundary": k, "skipped": True})
            continue
        # abandoned: eng is dropped with whatever the journal committed
        eng2 = make_engine(faults=None, journal_dir=jd)
        rec = eng2.recover()
        post: dict = {}
        steps = 0
        while eng2.has_work():
            for uid, tok in eng2.step():
                post.setdefault(uid, []).append(tok)
            steps += 1
            if steps > 2000:
                raise RuntimeError("crash harness livelocked post-crash")
        for uid, want in oracle["streams"].items():
            full = pre.get(uid, []) + post.get(uid, [])
            if full != want:
                violations.append(
                    f"boundary {k}: uid {uid} resumed stream != oracle: "
                    f"pre={pre.get(uid)} post={post.get(uid)} "
                    f"want={want}")
            req = eng2.requests.get(uid)
            pre_req = eng.requests.get(uid)
            terminal = (req is not None and req.state.terminal) or \
                (req is None and pre_req is not None
                 and pre_req.state.terminal)
            if not terminal:
                violations.append(
                    f"boundary {k}: uid {uid} never reached a terminal "
                    "state after recovery")
        pool = eng2.pool_report()
        if pool is not None and pool["pages_active"] != 0:
            violations.append(
                f"boundary {k}: pool leaked {pool['pages_active']} "
                "active pages after recovery drain")
        report["crashes"].append({
            "boundary": k, "skipped": False,
            "recovered": rec["resumed"], "finalized": rec["finalized"],
            "already_terminal": rec["already_terminal"],
        })
    report["ok"] = not violations
    if violations:
        raise AssertionError("crash-restart sweep violations:\n  "
                             + "\n  ".join(violations))
    return report


def chaos_sweep(make_engine, prompts, seeds, *, max_new_tokens=4,
                schedule=None) -> dict:
    """Sweep seeded schedules against the fault-free oracle.

    ``make_engine(faults=...)`` must build a FRESH engine (same config and
    weights) each call; ``schedule`` overrides :func:`schedule_for_seed`.
    Returns a report; raises AssertionError listing every violation."""
    oracle_eng = make_engine(faults=None)
    oracle = drive(oracle_eng, prompts, max_new_tokens=max_new_tokens)
    report: dict = {"oracle_steps": oracle["steps"], "schedules": []}
    violations = []
    for seed in seeds:
        rules = (schedule(seed) if schedule is not None
                 else schedule_for_seed(seed, n_requests=len(prompts)))
        inj = FaultInjector(seed, rules)
        eng = make_engine(faults=inj)
        got = drive(eng, prompts, max_new_tokens=max_new_tokens)
        bad = check_invariants(oracle, got, inj, eng.pool_report())
        report["schedules"].append({
            "seed": seed, "events": len(inj.log),
            "states": {u: str(s) for u, s in got["states"].items()},
            "violations": bad,
            "counters": dict(eng.counters),
        })
        violations.extend(f"seed {seed}: {v}" for v in bad)
    report["ok"] = not violations
    if violations:
        raise AssertionError("chaos sweep violations:\n  "
                             + "\n  ".join(violations))
    return report


# ---------------------------------------------------------------------------
# CLI: the CI chaos-smoke leg
# ---------------------------------------------------------------------------
def _family_cfg(family: str):
    from repro.core.qgemm import QuantConfig
    from repro.models.base import ArchConfig
    if family == "dense":
        return ArchConfig(name="chaos-dense", family="dense", n_layers=2,
                          d_model=64, n_heads=2, n_kv_heads=2, d_ff=128,
                          vocab=64, attn_chunk=64,
                          quant=QuantConfig(method="mixfp4")), 0
    if family == "moe":
        from repro import configs
        return configs.smoke_config("qwen3-moe-30b-a3b").replace(
            quant=QuantConfig(method="mixfp4")), 5
    if family == "ssm":
        return ArchConfig(name="chaos-ssm", family="ssm", n_layers=2,
                          d_model=64, vocab=64, ssm_state=8, ssm_expand=2,
                          quant=QuantConfig(method="mixfp4")), 3
    if family == "hybrid":
        return ArchConfig(name="chaos-hyb", family="hybrid", n_layers=2,
                          d_model=64, vocab=64, n_heads=2, n_kv_heads=2,
                          d_ff=128, ssm_state=8, ssm_expand=2,
                          ssm_version=2, ssm_head_dim=32, attn_period=2,
                          attn_chunk=64,
                          quant=QuantConfig(method="mixfp4")), 2
    raise ValueError(f"unknown family {family!r}")


def main(argv=None) -> int:
    import argparse

    import jax
    import numpy as np

    from repro.models.base import build_model
    from repro.serving.engine import ServeEngine

    ap = argparse.ArgumentParser(
        description="seeded chaos sweep over the serving engine (the CI "
                    "chaos-smoke leg)")
    ap.add_argument("--families", default="dense,moe,ssm,hybrid")
    ap.add_argument("--seeds", default="0,1,2")
    ap.add_argument("--requests", type=int, default=4)
    ap.add_argument("--new-tokens", type=int, default=4)
    ap.add_argument("--act-quant", default=None,
                    help="engine act_quant= for the sweep (e.g. 'mixfp4' "
                         "runs the fused per-row W4A4 path — the bitwise "
                         "invariants hold there too, and a 'dispatch' "
                         "fault exercises the fused->2-pass degradation)")
    ap.add_argument("--crash", action="store_true",
                    help="also run the kill-and-recover sweep: crash at "
                         "every step boundary, recover from the journal, "
                         "assert resumed streams bitwise the oracle")
    ap.add_argument("--crash-stride", type=int, default=1,
                    help="crash every Nth boundary (CI time knob)")
    args = ap.parse_args(argv)
    seeds = [int(s) for s in args.seeds.split(",") if s]
    ok = True
    for family in filter(None, args.families.split(",")):
        cfg, init_seed = _family_cfg(family)
        params, _ = build_model(cfg).init(jax.random.PRNGKey(init_seed))
        rng = np.random.RandomState(init_seed)
        prompts = [rng.randint(0, cfg.vocab, 3 + i % 3)
                   for i in range(args.requests)]
        # MoE stays at batch 2: the capacity router's rank-within-expert
        # competition can couple rows once B*top_k choices on one expert
        # can exceed cap (>= 4), so the bitwise oracle holds below that
        batch = 2
        kw: dict = dict(batch_size=batch, max_len=32)
        if args.act_quant:
            kw.update(act_quant=args.act_quant)
        if family == "dense":
            kw.update(kv_quant="mixfp4", kv_pool=2 * batch * 2 + 1,
                      kv_page_len=16)

        def make_engine(faults=None, journal_dir=None,
                        _cfg=cfg, _p=params, _kw=kw):
            jkw = dict(_kw)
            if journal_dir is not None:
                # 'always' so the crash point, not fsync batching,
                # decides what the recovery run sees on disk
                jkw.update(journal_dir=journal_dir, journal_sync="always")
            return ServeEngine(_cfg, _p, faults=faults, **jkw)

        try:
            rep = chaos_sweep(make_engine, prompts, seeds,
                              max_new_tokens=args.new_tokens)
            print(f"[chaos] {family}: OK "
                  f"({len(rep['schedules'])} schedules, "
                  f"{sum(s['events'] for s in rep['schedules'])} events)")
        except AssertionError as e:
            print(f"[chaos] {family}: FAIL\n{e}")
            ok = False
        if args.crash:
            import tempfile
            with tempfile.TemporaryDirectory() as root:
                try:
                    crep = crash_restart_sweep(
                        make_engine, prompts, journal_root=root,
                        max_new_tokens=args.new_tokens,
                        crash_stride=args.crash_stride)
                    ran = [c for c in crep["crashes"]
                           if not c.get("skipped")]
                    print(f"[chaos] {family}: crash-restart OK "
                          f"({len(ran)}/{len(crep['crashes'])} boundaries, "
                          f"{crep['oracle_steps']} oracle steps)")
                except AssertionError as e:
                    print(f"[chaos] {family}: crash-restart FAIL\n{e}")
                    ok = False
    print("[chaos] sweep", "OK" if ok else "FAILED")
    return 0 if ok else 1


if __name__ == "__main__":
    # re-enter through the canonical module so InjectedFault is the SAME
    # class object the engine's except-clauses are bound to (`python -m`
    # loads this file as __main__, a second module instance otherwise)
    from repro.serving.faults import main as _main
    raise SystemExit(_main())
