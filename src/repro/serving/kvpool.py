"""Paged packed-KV pool: block tables, prefix caching, COW, LRU eviction.

The fixed-slot engine ties one ``max_len`` cache stripe to each batch lane,
so concurrency is capped at ``batch_size`` and every admission re-prefills
shared system prompts from scratch.  This module is the host-side
bookkeeping for the paged alternative: the packed KV cache becomes a pool
of physical *pages* — ``(P, page_len, Hkv, ...)`` payload/scale slabs, a
page being ``page_len`` packed rows — and each request holds a block table
mapping its logical page order to slab rows.  MixFP4's wire format makes
this unusually cheap: a page is raw payload + scale bytes that move with
zero requantization, and the pinned ``KV_SCALE32`` contract makes a page's
bytes *write-order independent*, so a page prefilled by one request is
bit-for-bit the page any other request would have produced for the same
tokens — the property that makes prefix sharing exact.

What lives here is pure Python/numpy accounting (no jax): the device-side
pieces — the page-slab cache layout, the block-table scatter/gather, the
paged flash kernel — live in ``models.transformer`` / ``kernels``.

Sharing model
-------------
* **Prefix tree.**  Nodes are pages keyed by token-id chunks: a *full*
  chunk is ``page_len`` prompt tokens; the prompt's tail registers as a
  terminal *partial* chunk.  ``acquire`` walks the tree root-down matching
  full chunks exactly, then takes the longest common prefix with a child
  for the tail.  Matched full pages are mapped into the new request's
  block table directly (refcount++, zero prefill work).
* **Copy-on-write, taken eagerly.**  A partial hit copies the source
  page's bytes into a fresh page *at admission* (the engine issues the
  device copy).  Eager COW means no shared page is ever written after
  registration: full-chunk pages hold only immutable prompt rows, and
  partial-chunk pages are only ever *read* (rows ``[0, len(chunk))``,
  written before registration) by sharers.  Decode therefore needs no
  write barrier — every write lands in a page owned by exactly one
  request.
* **LRU eviction, recompute-on-miss.**  Pages whose refcount drops to
  zero but that are tree-registered park in an LRU instead of the free
  list.  When the free list runs dry, the oldest *leaf* (no tree
  children) is evicted and its node removed — a later admission with that
  prefix simply misses and re-prefills (the quantized bytes it recomputes
  are bitwise the evicted ones, by the pinned-scale contract).

``enable_prefix=False`` degenerates to a plain page allocator (used for
the hybrid family, whose SSM state needs the full prompt run regardless).

Page 0 is the **trash page**: never allocated, the target of every unused
block-table entry (so a zeroed table row is valid), and the scatter sink
for inactive batch lanes.  Its bytes are junk; every read of it is masked
by per-request lengths.
"""
from __future__ import annotations

import collections
import dataclasses

__all__ = ["KVPool", "Admission"]

_ROOT = -1  # parent id of top-level prefix-tree nodes


@dataclasses.dataclass
class Admission:
    """What ``acquire`` grants: the request's block table in logical page
    order, how many leading prompt tokens are already cached (the engine
    prefills only ``tokens[shared_len:]``), and an optional eager-COW
    device copy the engine must issue before prefill."""
    pages: list[int]
    shared_len: int = 0
    cow: tuple[int, int] | None = None  # (src_page, dst_page) byte copy


class KVPool:
    """Reference-counted pool of packed KV pages with prefix caching."""

    def __init__(self, num_pages: int, page_len: int,
                 *, enable_prefix: bool = True):
        if num_pages < 2:
            raise ValueError("num_pages must be >= 2 (page 0 is the trash "
                             f"page), got {num_pages}")
        if page_len < 1:
            raise ValueError(f"page_len must be >= 1, got {page_len}")
        self.num_pages = num_pages
        self.page_len = page_len
        self.enable_prefix = enable_prefix
        self._free = list(range(num_pages - 1, 0, -1))  # pop() -> page 1 first
        self._ref = [0] * num_pages
        # prefix tree: page -> (parent, chunk); (parent, chunk) -> page
        self._parent: dict[int, int] = {}
        self._chunk: dict[int, tuple] = {}
        self._children: dict[tuple, int] = {}
        self._kids: dict[int, set] = {}
        self._lru = collections.OrderedDict()  # ref-0 tree pages, old first
        self.prefix_hits = 0        # pages served from cache
        self.prefix_hit_tokens = 0  # prompt tokens whose prefill was skipped
        self.evictions = 0
        self.cow_copies = 0
        self.alloc_failures = 0

    # -- capacity ----------------------------------------------------------
    @property
    def pages_total(self) -> int:
        return self.num_pages - 1  # page 0 reserved

    @property
    def pages_free(self) -> int:
        return len(self._free)

    @property
    def pages_cached(self) -> int:
        return len(self._lru)

    @property
    def pages_active(self) -> int:
        return self.pages_total - self.pages_free - self.pages_cached

    def pages_needed(self, prompt_len: int, max_new_tokens: int) -> int:
        """Pages a request writes: rows 0..prompt+max_new-2 (the engine's
        highest written position)."""
        rows = prompt_len + max(max_new_tokens, 1) - 1
        return -(-rows // self.page_len)

    # -- allocation --------------------------------------------------------
    def _evict_one(self) -> int | None:
        """Evict the LRU tree page with no children; recompute-on-miss."""
        for page in self._lru:
            if not self._kids.get(page):
                break
        else:
            return None
        del self._lru[page]
        parent = self._parent.pop(page)
        chunk = self._chunk.pop(page)
        del self._children[(parent, chunk)]
        kids = self._kids.get(parent)
        if kids is not None:
            kids.discard(page)
        self._kids.pop(page, None)
        self.evictions += 1
        return page

    def _alloc(self) -> int | None:
        if self._free:
            return self._free.pop()
        return self._evict_one()

    # -- prefix matching ---------------------------------------------------
    def _match(self, tokens: tuple):
        """Walk the tree over ``tokens[:-1]`` (at least one suffix token
        always prefills, so the admission has logits to sample from).
        Returns (full_pages, shared_len, partial=(src_page, rows)|None)."""
        limit = len(tokens) - 1
        full, pos, node = [], 0, _ROOT
        while pos + self.page_len <= limit:
            page = self._children.get((node, tuple(tokens[pos:pos + self.page_len])))
            if page is None:
                break
            full.append(page)
            pos += self.page_len
            node = page
        best = None
        for kid in self._kids.get(node, ()):  # longest common partial tail
            chunk = self._chunk[kid]
            r = 0
            cap = min(len(chunk), limit - pos)
            while r < cap and chunk[r] == tokens[pos + r]:
                r += 1
            if r > 0 and (best is None or r > best[1]):
                best = (kid, r)
        return full, pos, best

    # -- request lifecycle -------------------------------------------------
    def acquire(self, tokens, max_new_tokens: int) -> Admission | None:
        """Admit a request: map cached prefix pages, allocate the rest.
        Returns None (and counts an alloc failure) if the pool cannot
        cover the request even after eviction — nothing is consumed."""
        tokens = tuple(int(t) for t in tokens)
        n_total = self.pages_needed(len(tokens), max_new_tokens)
        full, shared, partial = (self._match(tokens) if self.enable_prefix
                                 else ([], 0, None))
        # Pin matched pages first so eviction during allocation below can
        # never reclaim them out from under this admission.
        for page in full:
            self._ref[page] += 1
            self._lru.pop(page, None)
        fresh = []
        while len(fresh) < n_total - len(full):
            page = self._alloc()
            if page is None:
                for p in fresh:
                    self._ref[p] = 0
                    self._free.append(p)
                for p in full:
                    self._ref[p] -= 1
                    if self._ref[p] == 0:
                        self._lru[p] = None
                self.alloc_failures += 1
                return None
            self._ref[page] = 1
            fresh.append(page)
        cow = None
        if partial is not None and fresh:
            src, rows = partial
            cow = (src, fresh[0])
            shared += rows
            self.cow_copies += 1
        if shared:
            self.prefix_hits += len(full) + (1 if cow else 0)
            self.prefix_hit_tokens += shared
        return Admission(pages=full + fresh, shared_len=shared, cow=cow)

    def insert(self, tokens, pages: list[int]) -> None:
        """Register a prefilled prompt's pages in the prefix tree (full
        chunks plus the terminal partial).  Existing nodes win: a page
        whose (parent, chunk) is already claimed stays untracked and is
        simply freed on release."""
        if not self.enable_prefix:
            return
        tokens = tuple(int(t) for t in tokens)
        node, pos, idx = _ROOT, 0, 0
        while pos < len(tokens):
            chunk = tuple(tokens[pos:pos + self.page_len])
            page = pages[idx]
            have = self._children.get((node, chunk))
            if have is not None:
                node = have
            elif page not in self._parent and self._ref[page] > 0:
                self._children[(node, chunk)] = page
                self._parent[page] = node
                self._chunk[page] = chunk
                self._kids.setdefault(node, set()).add(page)
                node = page
            else:  # page already registered under another chunk, or freed
                break
            pos += self.page_len
            idx += 1

    def release(self, pages: list[int]) -> None:
        """Drop a finished request's references.  Tree-registered pages
        park in the LRU (still servable as prefix hits); anonymous pages
        return to the free list."""
        for page in pages:
            self._ref[page] -= 1
            assert self._ref[page] >= 0, f"double release of page {page}"
            if self._ref[page] == 0:
                if page in self._parent:
                    self._lru[page] = None
                    self._lru.move_to_end(page)
                else:
                    self._free.append(page)

    # -- reporting ---------------------------------------------------------
    def stats(self) -> dict:
        return {
            "pages_total": self.pages_total,
            "page_len": self.page_len,
            "pages_free": self.pages_free,
            "pages_cached": self.pages_cached,
            "pages_active": self.pages_active,
            "occupancy": 1.0 - self.pages_free / max(self.pages_total, 1),
            "prefix_hits": self.prefix_hits,
            "prefix_hit_tokens": self.prefix_hit_tokens,
            "evictions": self.evictions,
            "cow_copies": self.cow_copies,
            "alloc_failures": self.alloc_failures,
        }
