"""Asyncio HTTP front-end streaming tokens per-request over the engine.

This is the transport that gives the packed-MixFP4 engine "the shape of a
real service" (ROADMAP direction 1): clients POST a prompt and read the
response token-by-token as SSE-style frames, cancellation follows the TCP
connection (client hangs up => ``engine.cancel(uid)`` releases the slot and
pool pages), and the whole observability surface — lifecycle counters,
TTFT/ITL percentiles, pool occupancy, scheduler ledger — scrapes at
``GET /metrics`` in Prometheus text format.

Stdlib only (asyncio + sockets + json + threading): the container bakes in
jax, nothing else — no fastapi/uvicorn/aiohttp.  The HTTP/1.1 surface is
deliberately tiny (three routes, chunked transfer encoding) and every
route is exercised by tests/test_server.py and the CI frontend-smoke leg.

Threading model — the part worth reading twice:

* ``EngineWorker`` owns a dedicated daemon thread, and that thread is the
  ONLY one that touches the engine (jax dispatch, numpy host state, the
  KV pool's refcounts — none of it is locked, so none of it may be
  shared).  Other threads talk to it through a command queue
  (``submit_async`` / ``cancel_async`` / ``call``) and receive tokens
  through per-uid sink callables the worker invokes as it drains
  ``engine.step()``.
* The asyncio loop runs in the caller's thread (or a second daemon thread
  under :class:`ServingServer`).  Sinks bridge worker -> loop via
  ``loop.call_soon_threadsafe`` pushing frames onto per-request
  ``asyncio.Queue``s — the handler coroutine just awaits the queue and
  writes chunks.
* Client disconnects surface as EOF on the connection's read side; each
  streaming handler keeps a concurrent ``reader.read()`` watch task and
  fires ``cancel_async(uid)`` the moment it completes early.

Frame protocol (SSE-compatible, one JSON object per ``data:`` line):

    data: {"type": "token", "uid": 3, "token": 17, "index": 0}
    data: {"type": "done",  "uid": 3, "finish_reason": "max_new_tokens",
           "state": "FINISHED", "n_tokens": 8}
    data: {"type": "error", "uid": 3, "finish_reason": "nan_logits",
           "state": "FAILED"}

Exactly one terminal frame (``done`` | ``error``) closes every stream:
FINISHED and CANCELLED land as ``done`` (a cancel is a client verdict,
not a server failure), FAILED and EXPIRED as ``error`` — with the typed
``finish_reason`` the engine counters use, so the chaos tests can assert
"exactly one typed error frame for the poisoned request" end to end.
"""
from __future__ import annotations

import asyncio
import json
import queue
import socket
import threading

from repro.serving.engine import (EngineDrainingError, QueueFullError,
                                  REASON_SLOW_CLIENT, Request, RequestState,
                                  RequestValidationError, ServeEngine)
from repro.serving.metrics import render_prometheus

__all__ = ["EngineWorker", "ServingServer", "stream_generate",
           "resume_stream", "scrape_metrics", "get_json"]

import numpy as np


# ---------------------------------------------------------------------------
# engine worker thread
# ---------------------------------------------------------------------------
class EngineWorker:
    """Single-threaded executor around a :class:`ServeEngine`.

    All engine access funnels through one daemon thread: commands arrive on
    a queue, tokens leave through per-uid sink callables.  A sink receives
    ``("token", token_int)`` per generated token and exactly one terminal
    ``("done" | "error", request)`` when the request leaves the batch; it
    runs ON the worker thread, so sinks must be cheap and thread-safe
    (the server's sinks just ``call_soon_threadsafe`` into the loop).
    """

    _POLL_S = 0.002   # idle poll for new commands when the batch is empty

    def __init__(self, engine: ServeEngine):
        self.engine = engine
        self._cmds: queue.Queue = queue.Queue()
        self._sinks: dict[int, object] = {}
        self._emitted: dict[int, int] = {}
        self._uid_gen = iter(range(1 << 30))
        self._stop = threading.Event()
        self._thread = threading.Thread(target=self._run, daemon=True,
                                        name="mixfp4-engine-worker")
        self.steps = 0
        # readiness: set once the worker loop is actually spinning (the
        # /readyz split — 'starting' until then, 'draining' after a drain
        # begins, 'ready' in between)
        self.ready = threading.Event()

    @property
    def phase(self) -> str:
        if not self._thread.is_alive() and not self.ready.is_set():
            return "starting"
        if getattr(self.engine, "draining", False):
            return "draining"
        return "ready" if self.ready.is_set() else "starting"

    @property
    def alive(self) -> bool:
        return self._thread.is_alive()

    # -- lifecycle -----------------------------------------------------
    def start(self) -> "EngineWorker":
        self._thread.start()
        return self

    def stop(self, timeout: float = 10.0):
        self._stop.set()
        self._thread.join(timeout=timeout)

    # -- cross-thread API ----------------------------------------------
    def next_uid(self) -> int:
        return next(self._uid_gen)

    def submit_async(self, req: Request, sink) -> None:
        """Enqueue a submit; ``sink`` receives this request's frames.
        Submission errors (validation / backpressure) surface through the
        sink as an ``error`` event — the caller never blocks."""
        self._cmds.put(("submit", req, sink))

    def cancel_async(self, uid: int, reason: str | None = None) -> None:
        """Enqueue a cancel; ``reason`` (e.g. ``slow_client``) lands in the
        request's typed ``finish_reason`` and the engine counters."""
        self._cmds.put(("cancel", uid, reason))

    def call(self, fn, timeout: float = 30.0):
        """Run ``fn(engine)`` on the worker thread and return its result —
        the safe way to snapshot ``metrics_report()`` / ``pool_report()``
        from the HTTP thread."""
        done = threading.Event()
        box: list = [None, None]

        def wrap(engine):
            try:
                box[0] = fn(engine)
            except Exception as e:        # noqa: BLE001 — relayed below
                box[1] = e
            done.set()

        self._cmds.put(("call", wrap, None))
        if not done.wait(timeout):
            raise TimeoutError("engine worker did not answer in "
                               f"{timeout}s (wedged step?)")
        if box[1] is not None:
            raise box[1]
        return box[0]

    # -- worker loop ----------------------------------------------------
    def _drain_cmds(self):
        while True:
            try:
                kind, a, b = self._cmds.get_nowait()
            except queue.Empty:
                return
            if kind == "submit":
                req, sink = a, b
                try:
                    self.engine.submit(req)
                except (RequestValidationError, QueueFullError,
                        EngineDrainingError) as e:
                    reason = getattr(e, "reason", "rejected")
                    sink(("error", _terminal_info(req, reason=reason,
                                                  state="REJECTED")))
                    continue
                self._sinks[req.uid] = sink
                self._emitted[req.uid] = 0
            elif kind == "cancel":
                if b is None:
                    self.engine.cancel(a)
                else:
                    self.engine.cancel(a, reason=b)
            elif kind == "call":
                a(self.engine)

    def _emit(self, uid: int, token: int):
        sink = self._sinks.get(uid)
        if sink is None:
            return
        idx = self._emitted.get(uid, 0)
        self._emitted[uid] = idx + 1
        sink(("token", {"token": int(token), "index": idx}))

    def _flush_terminal(self):
        """Exactly-once terminal frames: any sink whose request reached a
        terminal state gets its ``done``/``error`` event and is dropped."""
        for uid in list(self._sinks):
            req = self.engine.requests.get(uid)
            if req is None or not req.state.terminal:
                continue
            sink = self._sinks.pop(uid)
            self._emitted.pop(uid, None)
            kind = ("done" if req.state in (RequestState.FINISHED,
                                            RequestState.CANCELLED)
                    else "error")
            sink((kind, _terminal_info(req)))

    def attach_resume(self, uid: int, sink, timeout: float = 30.0):
        """Attach ``sink`` to an in-flight (possibly recovered) request and
        return ``(tokens_so_far, terminal_info | None)``.  Runs on the
        worker thread between steps, so the snapshot and the attach are
        atomic w.r.t. token emission: the caller replays ``tokens_so_far``
        itself, then live frames follow with consecutive indices.  For a
        request already terminal, no sink is installed and the terminal
        info comes back for the caller to send.  Returns None for an
        unknown uid."""
        def attach(engine):
            req = engine.requests.get(uid)
            if req is None:
                return None
            toks = [int(t) for t in req.generated]
            if req.state.terminal:
                return toks, _terminal_info(req)
            self._sinks[uid] = sink
            self._emitted[uid] = len(toks)
            return toks, None
        return self.call(attach, timeout=timeout)

    def _run(self):
        while not self._stop.is_set():
            self.ready.set()
            self._drain_cmds()
            if not self.engine.has_work():
                self._flush_terminal()
                self._stop.wait(self._POLL_S)
                continue
            for uid, tok in self.engine.step():
                self._emit(uid, tok)
            self.steps += 1
            self._flush_terminal()


def _terminal_info(req: Request, reason: str | None = None,
                   state: str | None = None) -> dict:
    info = {
        "uid": req.uid,
        "state": state or str(req.state),
        "finish_reason": reason or req.finish_reason,
        "n_tokens": len(req.generated),
    }
    ttft = req.ttft_ms()
    if ttft is not None:
        info["ttft_ms"] = ttft
    return info


# ---------------------------------------------------------------------------
# minimal HTTP/1.1 plumbing (stdlib asyncio streams)
# ---------------------------------------------------------------------------
_MAX_HEADER = 64 * 1024
_MAX_BODY = 4 * 1024 * 1024


async def _read_request(reader) -> tuple[str, str, dict, bytes] | None:
    try:
        head = await reader.readuntil(b"\r\n\r\n")
    except (asyncio.IncompleteReadError, asyncio.LimitOverrunError,
            ConnectionError):
        return None
    if len(head) > _MAX_HEADER:
        return None
    lines = head.decode("latin-1").split("\r\n")
    parts = lines[0].split()
    if len(parts) != 3:
        return None
    method, target = parts[0].upper(), parts[1]
    headers = {}
    for line in lines[1:]:
        if ":" in line:
            k, v = line.split(":", 1)
            headers[k.strip().lower()] = v.strip()
    body = b""
    n = int(headers.get("content-length", "0") or "0")
    if n:
        if n > _MAX_BODY:
            return None
        body = await reader.readexactly(n)
    return method, target, headers, body


def _response_head(status: str, ctype: str, *, chunked: bool = False,
                   length: int | None = None) -> bytes:
    lines = [f"HTTP/1.1 {status}",
             f"Content-Type: {ctype}",
             "Connection: close"]
    if chunked:
        lines.append("Transfer-Encoding: chunked")
    if length is not None:
        lines.append(f"Content-Length: {length}")
    return ("\r\n".join(lines) + "\r\n\r\n").encode()


async def _send_plain(writer, status: str, payload: bytes,
                      ctype: str = "application/json"):
    writer.write(_response_head(status, ctype, length=len(payload)))
    writer.write(payload)
    await writer.drain()


async def _send_chunk(writer, data: bytes):
    writer.write(f"{len(data):x}\r\n".encode() + data + b"\r\n")
    await writer.drain()


def _sse(obj: dict) -> bytes:
    return b"data: " + json.dumps(obj).encode() + b"\n\n"


# ---------------------------------------------------------------------------
# the server
# ---------------------------------------------------------------------------
class ServingServer:
    """HTTP front-end over an :class:`EngineWorker`.

    Routes:

    * ``POST /generate`` — body ``{"prompt": [int...], "max_new_tokens": N,
      "deadline_ms"?: F, "ttft_budget_ms"?: F}``; streams SSE frames
      (chunked transfer), one terminal frame, then closes.  A client that
      hangs up mid-stream cancels its request — slot and pool pages are
      released (tests/test_server.py pins the regression).
    * ``GET /metrics`` — Prometheus text rendering of
      ``engine.metrics_report()``.
    * ``GET /healthz`` — liveness: 200 while the process is up, with the
      lifecycle phase (``starting`` / ``ready`` / ``draining``).
    * ``GET /readyz`` — readiness: 200 only when ``ready`` and the engine
      thread answers, with queue/slot/pool gauges inline; 503 otherwise.
    * ``GET /resume/{uid}`` — re-attach to an in-flight (typically
      journal-recovered) stream: replays all tokens so far, then live
      frames — bitwise the uninterrupted stream.

    Use as a context manager (binds an ephemeral loopback port by
    default, runs the asyncio loop in a daemon thread)::

        with ServingServer(engine) as srv:
            for frame in stream_generate("127.0.0.1", srv.port, [1, 2, 3]):
                ...
    """

    def __init__(self, engine: ServeEngine, host: str = "127.0.0.1",
                 port: int = 0, *, max_sink_frames: int = 256,
                 sndbuf: int | None = None):
        self.worker = EngineWorker(engine)
        self.host = host
        self.port = port          # 0 => ephemeral, resolved on start
        # per-stream frame-queue bound: a client that stops reading lets
        # the handler's queue grow unboundedly while the engine keeps
        # decoding for it — past this many undelivered frames the stream
        # gets one typed `slow_client` error frame and the request is
        # cancelled (slot + pool pages released)
        self.max_sink_frames = int(max_sink_frames)
        # test knob: shrink each connection's kernel send buffer so a
        # stalled reader backs the handler up in milliseconds, not MBs
        self.sndbuf = sndbuf
        self._loop: asyncio.AbstractEventLoop | None = None
        self._thread: threading.Thread | None = None
        self._server: asyncio.base_events.Server | None = None
        self._started = threading.Event()

    def _bounded_sink(self, loop, frames: asyncio.Queue, uid: int):
        """Worker-thread -> loop bridge with the slow-client bound.  Runs
        ON the worker thread; ``frames.qsize()`` is a GIL-safe read.  On
        overflow it enqueues the single typed terminal itself and drops
        everything after (including the engine's own cancel terminal), so
        exactly one terminal frame goes on the wire."""
        state = {"over": False}

        def sink(event):
            if state["over"]:
                return
            kind, payload = event
            if kind == "token" and frames.qsize() >= self.max_sink_frames:
                state["over"] = True
                n = payload.get("index", 0)
                loop.call_soon_threadsafe(frames.put_nowait, ("error", {
                    "uid": uid, "state": str(RequestState.CANCELLED),
                    "finish_reason": REASON_SLOW_CLIENT, "n_tokens": n}))
                self.worker.cancel_async(uid, REASON_SLOW_CLIENT)
                return
            loop.call_soon_threadsafe(frames.put_nowait, event)

        return sink

    # -- request handlers ----------------------------------------------
    async def _handle_generate(self, reader, writer, body: bytes):
        try:
            spec = json.loads(body.decode() or "{}")
            prompt = np.asarray(spec["prompt"], np.int32)
            if prompt.ndim != 1:
                raise ValueError("prompt must be a flat token list")
        except (ValueError, KeyError, TypeError) as e:
            await _send_plain(writer, "400 Bad Request", json.dumps(
                {"error": f"bad request body: {e}"}).encode())
            return
        uid = int(spec.get("uid", self.worker.next_uid()))
        req = Request(uid=uid, prompt=prompt,
                      max_new_tokens=int(spec.get("max_new_tokens", 16)),
                      deadline_ms=spec.get("deadline_ms"),
                      ttft_budget_ms=spec.get("ttft_budget_ms"))
        loop = asyncio.get_running_loop()
        frames: asyncio.Queue = asyncio.Queue()
        sink = self._bounded_sink(loop, frames, uid)
        writer.write(_response_head("200 OK", "text/event-stream",
                                    chunked=True))
        await writer.drain()
        self.worker.submit_async(req, sink)
        await self._pump_frames(reader, writer, frames, uid)

    async def _pump_frames(self, reader, writer, frames: asyncio.Queue,
                           uid: int):
        """Shared streaming loop for /generate and /resume: forward frames
        until the single terminal, cancel on client EOF."""
        # EOF watch: the request line + body are fully read, so the next
        # (and only) read completing means the client went away
        eof_watch = asyncio.ensure_future(reader.read(1))
        try:
            while True:
                frame_task = asyncio.ensure_future(frames.get())
                await asyncio.wait({frame_task, eof_watch},
                                   return_when=asyncio.FIRST_COMPLETED)
                if not frame_task.done():
                    # client disconnected mid-stream
                    frame_task.cancel()
                    self.worker.cancel_async(uid)
                    return
                kind, payload = frame_task.result()
                if kind == "token":
                    await _send_chunk(writer, _sse(
                        {"type": "token", "uid": uid, **payload}))
                else:
                    await _send_chunk(writer, _sse(
                        {"type": kind, **payload}))
                    await _send_chunk(writer, b"")   # final 0-chunk
                    return
        except ConnectionError:
            self.worker.cancel_async(uid)
        finally:
            eof_watch.cancel()

    async def _handle_resume(self, reader, writer, uid: int):
        """GET /resume/{uid}: re-attach to an in-flight (typically
        journal-recovered) request.  Replays every token generated so far
        with its original index, then streams live frames — the
        concatenation is bitwise the uninterrupted stream (the journal
        recovery property), which tools/restart_smoke.py asserts over a
        real SIGKILL."""
        loop = asyncio.get_running_loop()
        frames: asyncio.Queue = asyncio.Queue()
        sink = self._bounded_sink(loop, frames, uid)
        try:
            res = self.worker.attach_resume(uid, sink)
        except TimeoutError:
            await _send_plain(writer, "503 Service Unavailable",
                              b'{"error": "engine stalled"}')
            return
        if res is None:
            await _send_plain(writer, "404 Not Found", json.dumps(
                {"error": f"unknown uid {uid}"}).encode())
            return
        toks, terminal = res
        writer.write(_response_head("200 OK", "text/event-stream",
                                    chunked=True))
        await writer.drain()
        for i, tok in enumerate(toks):
            await _send_chunk(writer, _sse(
                {"type": "token", "uid": uid, "token": tok, "index": i,
                 "replayed": True}))
        if terminal is not None:
            kind = ("done" if terminal.get("state") in
                    (str(RequestState.FINISHED), str(RequestState.CANCELLED))
                    else "error")
            await _send_chunk(writer, _sse({"type": kind, **terminal}))
            await _send_chunk(writer, b"")
            return
        await self._pump_frames(reader, writer, frames, uid)

    async def _handle_metrics(self, writer):
        report = self.worker.call(lambda eng: eng.metrics_report())
        await _send_plain(writer, "200 OK",
                          render_prometheus(report).encode(),
                          ctype="text/plain; version=0.0.4")

    async def _handle_healthz(self, writer):
        """Liveness: 200 while the process serves HTTP at all.  Reports
        the lifecycle phase but never touches the engine thread — a
        wedged step must not fail liveness (that is /readyz's job)."""
        await _send_plain(writer, "200 OK", json.dumps(
            {"ok": self.worker.alive, "phase": self.worker.phase,
             "steps": self.worker.steps}).encode())

    async def _handle_readyz(self, writer):
        """Readiness: 200 only in phase 'ready' with the engine thread
        answering; 503 while starting, draining, or stalled.  Carries the
        queue/slot/pool gauges inline so an orchestrator's readiness
        probe doubles as a cheap load snapshot."""
        phase = self.worker.phase
        body: dict = {"phase": phase, "steps": self.worker.steps}
        status = "200 OK"
        if phase != "ready" or not self.worker.alive:
            status = "503 Service Unavailable"
        else:
            def _gauges(eng):
                pool = eng.pool_report()
                return {
                    "queue_depth": len(eng.queue),
                    "active_slots":
                        sum(s is not None for s in eng.slots),
                    "batch_size": eng.batch_size,
                    "pool": None if pool is None else {
                        k: pool[k] for k in ("pages_total", "pages_free",
                                             "pages_active")},
                }
            try:
                body.update(self.worker.call(_gauges, timeout=2.0))
            except TimeoutError:
                status = "503 Service Unavailable"
                body["phase"] = "stalled"
        body["ready"] = status.startswith("200")
        await _send_plain(writer, status, json.dumps(body).encode())

    async def _handle_conn(self, reader, writer):
        try:
            if self.sndbuf is not None:
                sock = writer.get_extra_info("socket")
                if sock is not None:
                    sock.setsockopt(socket.SOL_SOCKET, socket.SO_SNDBUF,
                                    self.sndbuf)
                # the asyncio transport buffers ~64KB before drain()
                # blocks; shrink it too, or the kernel buffer knob alone
                # never back-pressures the handler
                writer.transport.set_write_buffer_limits(
                    high=self.sndbuf, low=0)
            parsed = await _read_request(reader)
            if parsed is None:
                return
            method, target, _headers, body = parsed
            if method == "POST" and target == "/generate":
                await self._handle_generate(reader, writer, body)
            elif method == "GET" and target == "/metrics":
                await self._handle_metrics(writer)
            elif method == "GET" and target == "/healthz":
                await self._handle_healthz(writer)
            elif method == "GET" and target == "/readyz":
                await self._handle_readyz(writer)
            elif method == "GET" and target.startswith("/resume/"):
                try:
                    uid = int(target[len("/resume/"):])
                except ValueError:
                    await _send_plain(writer, "400 Bad Request",
                                      b'{"error": "bad uid"}')
                    return
                await self._handle_resume(reader, writer, uid)
            else:
                await _send_plain(writer, "404 Not Found",
                                  b'{"error": "no such route"}')
        except ConnectionError:
            pass
        finally:
            try:
                writer.close()
                await writer.wait_closed()
            except (ConnectionError, OSError):
                pass

    # -- loop / thread management --------------------------------------
    async def _serve(self):
        self._server = await asyncio.start_server(
            self._handle_conn, self.host, self.port)
        self.port = self._server.sockets[0].getsockname()[1]
        self._started.set()
        async with self._server:
            await self._server.serve_forever()

    def _run_loop(self):
        self._loop = asyncio.new_event_loop()
        asyncio.set_event_loop(self._loop)
        try:
            self._loop.run_until_complete(self._serve())
        except asyncio.CancelledError:
            pass
        finally:
            self._loop.close()

    def start(self) -> "ServingServer":
        self.worker.start()
        self._thread = threading.Thread(target=self._run_loop, daemon=True,
                                        name="mixfp4-http")
        self._thread.start()
        if not self._started.wait(10.0):
            raise RuntimeError("HTTP server failed to bind in 10s")
        return self

    def drain(self, deadline_ms: float | None = None,
              poll_s: float = 0.01) -> dict:
        """Graceful drain (the SIGTERM path): stop admissions — /readyz
        flips to 503 'draining', new submits get a typed ``draining``
        rejection — let in-flight requests run to their terminals within
        ``deadline_ms``, then journal the ledger snapshot.  Returns the
        engine's ``finish_drain()`` report."""
        import time as _time
        self.worker.call(lambda eng: eng.begin_drain())
        deadline = (None if deadline_ms is None
                    else _time.monotonic() + deadline_ms / 1000.0)
        while self.worker.call(lambda eng: eng.has_work()):
            if deadline is not None and _time.monotonic() >= deadline:
                break
            _time.sleep(poll_s)
        return self.worker.call(lambda eng: eng.finish_drain())

    def stop(self):
        if self._loop is not None and self._server is not None:
            def _shutdown():
                self._server.close()
                for task in asyncio.all_tasks(self._loop):
                    task.cancel()
            self._loop.call_soon_threadsafe(_shutdown)
        if self._thread is not None:
            self._thread.join(timeout=10.0)
        self.worker.stop()

    def __enter__(self) -> "ServingServer":
        return self.start()

    def __exit__(self, *exc):
        self.stop()


# ---------------------------------------------------------------------------
# blocking clients (tests / benchmarks / docs examples)
# ---------------------------------------------------------------------------
def stream_generate(host: str, port: int, prompt, *, max_new_tokens: int = 8,
                    uid: int | None = None, deadline_ms: float | None = None,
                    ttft_budget_ms: float | None = None,
                    timeout: float = 120.0, abort_after: int | None = None):
    """POST /generate and yield decoded SSE frames (dicts) as they arrive.

    ``abort_after=N`` closes the socket right after the N-th token frame —
    the client-disconnect path the cancel regression test drives."""
    spec: dict = {"prompt": [int(t) for t in np.asarray(prompt).ravel()],
                  "max_new_tokens": max_new_tokens}
    if uid is not None:
        spec["uid"] = uid
    if deadline_ms is not None:
        spec["deadline_ms"] = deadline_ms
    if ttft_budget_ms is not None:
        spec["ttft_budget_ms"] = ttft_budget_ms
    body = json.dumps(spec).encode()
    with socket.create_connection((host, port), timeout=timeout) as sock:
        sock.sendall(
            b"POST /generate HTTP/1.1\r\n"
            b"Host: " + host.encode() + b"\r\n"
            b"Content-Type: application/json\r\n"
            b"Content-Length: " + str(len(body)).encode() + b"\r\n"
            b"\r\n" + body)
        yield from _sse_frames(sock, host, port, timeout,
                               abort_after=abort_after)


def resume_stream(host: str, port: int, uid: int, *,
                  timeout: float = 120.0):
    """GET /resume/{uid} and yield decoded SSE frames: every token
    generated so far (``"replayed": true``) followed by live frames, so
    the full index sequence is the uninterrupted stream."""
    with socket.create_connection((host, port), timeout=timeout) as sock:
        sock.sendall(f"GET /resume/{int(uid)} HTTP/1.1\r\n"
                     f"Host: {host}\r\n\r\n".encode())
        yield from _sse_frames(sock, host, port, timeout)


def _sse_frames(sock, host, port, timeout, *, abort_after=None):
    buf = b""
    head_done = False
    tokens_seen = 0
    while True:
        try:
            data = sock.recv(65536)
        except TimeoutError:
            raise TimeoutError(
                f"no frame from {host}:{port} in {timeout}s")
        if not data:
            return
        buf += data
        if not head_done:
            if b"\r\n\r\n" not in buf:
                continue
            head, buf = buf.split(b"\r\n\r\n", 1)
            status = head.split(b"\r\n", 1)[0].decode("latin-1")
            if " 200 " not in status + " ":
                # error responses are small JSON bodies; surface them
                yield {"type": "http_error", "status": status,
                       "body": buf.decode("utf-8", "replace")}
                return
            head_done = True
        # chunked-encoding SSE: frames are "data: {...}\n\n"; chunk
        # framing never splits our search because we re-scan the
        # buffer — strip chunk-size lines lazily by searching for
        # the SSE delimiter in the raw stream
        while b"\n\n" in buf:
            raw, buf = buf.split(b"\n\n", 1)
            start = raw.find(b"data: ")
            if start < 0:
                continue
            frame = json.loads(raw[start + len(b"data: "):])
            yield frame
            if frame.get("type") in ("done", "error"):
                return
            if frame.get("type") == "token":
                tokens_seen += 1
                if abort_after is not None \
                        and tokens_seen >= abort_after:
                    # hard-close mid-stream: the server's EOF watch
                    # turns this into cancel(uid)
                    sock.close()
                    return


def get_json(host: str, port: int, path: str,
             timeout: float = 30.0) -> tuple[int, dict]:
    """GET a JSON route (/healthz, /readyz) -> (status_code, body)."""
    with socket.create_connection((host, port), timeout=timeout) as sock:
        sock.sendall(f"GET {path} HTTP/1.1\r\nHost: {host}\r\n\r\n"
                     .encode())
        buf = b""
        while True:
            data = sock.recv(65536)
            if not data:
                break
            buf += data
    head, _, body = buf.partition(b"\r\n\r\n")
    code = int(head.split(b"\r\n", 1)[0].split()[1])
    return code, json.loads(body.decode() or "{}")


def scrape_metrics(host: str, port: int, timeout: float = 30.0) -> str:
    """GET /metrics and return the Prometheus text body."""
    with socket.create_connection((host, port), timeout=timeout) as sock:
        sock.sendall(b"GET /metrics HTTP/1.1\r\nHost: " + host.encode()
                     + b"\r\n\r\n")
        buf = b""
        while True:
            data = sock.recv(65536)
            if not data:
                break
            buf += data
    head, _, body = buf.partition(b"\r\n\r\n")
    assert b" 200 " in head.split(b"\r\n", 1)[0], head[:200]
    return body.decode()


# ---------------------------------------------------------------------------
# CLI selftest (CI frontend-smoke leg)
# ---------------------------------------------------------------------------
def _selftest(families: list[str], *, prefill_chunk: int | None = 4,
              new_tokens: int = 4) -> dict:
    """Start a loopback server per family, stream one request through
    HTTP, scrape /metrics, and cross-check the stream against a direct
    drive of an identical engine.  Returns {family: n_tokens}."""
    import jax

    from repro.models.base import build_model
    from repro.serving.faults import _family_cfg

    out = {}
    for family in families:
        cfg, seed = _family_cfg(family)
        params, _ = build_model(cfg).init(jax.random.PRNGKey(seed))
        chunk = (prefill_chunk
                 if cfg.family in ("dense", "moe", "vlm") else None)
        engine = ServeEngine(cfg, params, batch_size=2, max_len=64,
                             prefill_chunk=chunk)
        prompt = list(range(1, 9))
        with ServingServer(engine) as srv:
            frames = list(stream_generate("127.0.0.1", srv.port, prompt,
                                          max_new_tokens=new_tokens))
            metrics_text = scrape_metrics("127.0.0.1", srv.port)
        toks = [f["token"] for f in frames if f["type"] == "token"]
        assert frames[-1]["type"] == "done", frames[-1]
        assert frames[-1]["finish_reason"] == "max_new_tokens", frames[-1]
        assert len(toks) == new_tokens, (family, toks)
        assert "mixfp4_ttft_ms_count" in metrics_text, metrics_text[:400]
        assert "mixfp4_queue_depth" in metrics_text
        # oracle: direct drive of a fresh identical engine
        params2, _ = build_model(cfg).init(jax.random.PRNGKey(seed))
        oracle = ServeEngine(cfg, params2, batch_size=2, max_len=64,
                             prefill_chunk=chunk)
        req = Request(uid=0, prompt=np.asarray(prompt, np.int32),
                      max_new_tokens=new_tokens)
        oracle.submit(req)
        got = []
        while oracle.has_work():
            got.extend(t for _, t in oracle.step())
        assert toks == got, (family, toks, got)
        out[family] = len(toks)
        print(f"frontend selftest[{family}]: {len(toks)} tokens streamed, "
              f"metrics scraped OK")
    return out


def main(argv=None):
    import argparse
    parser = argparse.ArgumentParser(
        description="loopback HTTP serving selftest (CI frontend-smoke)")
    parser.add_argument("--families", default="dense",
                        help="comma-separated: dense,moe,ssm,hybrid")
    parser.add_argument("--prefill-chunk", type=int, default=4)
    parser.add_argument("--new-tokens", type=int, default=4)
    args = parser.parse_args(argv)
    _selftest(args.families.split(","), prefill_chunk=args.prefill_chunk,
              new_tokens=args.new_tokens)
    print("frontend selftest OK")


if __name__ == "__main__":
    main()
