from repro.serving.engine import ServeEngine
from repro.serving.metrics import MetricsRegistry, render_prometheus
from repro.serving.scheduler import ChunkedPrefillScheduler
