"""Append-only request journal: the durability layer under the engine.

A serving process is a single point of total loss without one: a crash or
a deploy drops every in-flight stream, the prefix tree, and all pool
accounting.  The journal fixes the *requests* half of that — weights are
already durable (checkpoint.manager), and the KV cache never needs to be:
the pinned ``KV_SCALE32`` write-order contract makes every cache row a
pure function of the token history, so a restarted engine rebuilds byte-
identical KV state by re-prefilling ``prompt ++ generated[:-1]`` (the
same history-replay the paged->fixed-slot degradation rung uses).  What
must survive the crash is therefore tiny and append-only: admission
prompts, per-step emitted tokens, and terminal transitions.

Record format (binary, CRC-per-record)::

    <u32 payload_len> <u32 crc32(payload)> <payload: compact JSON, utf-8>

* **Torn tail**: a crash mid-append leaves a final record whose header or
  payload hits EOF early.  ``scan_journal`` detects it (the bytes simply
  run out) and the writer truncates it on open — the committed prefix is
  untouched.  Losing unsynced tail *tokens* is harmless by construction:
  greedy decode is deterministic, so recovery re-derives exactly the
  tokens the lost records held.
* **Mid-record corruption**: a complete record whose CRC mismatches (bit
  rot, a torn *overwrite*) is not silently skippable — everything after
  an untrusted length field is untrusted.  ``scan_journal`` raises
  :class:`JournalCorruption` naming the record index and byte offset and
  carrying the good prefix; the writer (``repair=True``, the engine's
  posture) truncates to that prefix and records what was dropped.
* **fsync batching** (``sync=``): ``"always"`` fsyncs per append,
  ``"batch"`` (default) pushes records to the OS every ``flush()`` (the
  engine flushes at each step boundary) but fsyncs only every
  ``sync_every`` flushes — a crash loses at most ``sync_every`` steps of
  tail records, every one of which greedy recovery re-derives bitwise,
  so the amortization costs durability nothing — and ``"off"`` leaves
  flushing to the OS entirely (benchmark baseline).  ``flush(
  force_sync=True)`` fsyncs under every policy (the drain ledger).

Record kinds (the ``"t"`` field):

* ``submit``   — uid, prompt tokens, max_new_tokens, deadline knobs
* ``token``    — one emitted token for uid
* ``terminal`` — uid reached FINISHED/FAILED/CANCELLED/EXPIRED (+reason)
* ``ckpt``     — packed-weight pin: checkpoint dir, step, manifest
  fingerprint.  Recovery refuses to resume against different weights
  (a bitwise-identical stream is only promised under the same bytes).
* ``ledger``   — drain snapshot: counters + per-request final states.

:func:`replay` folds a record list into per-request
:class:`ReplayedRequest` states; ``engine.recover()`` re-prefills every
non-terminal one and continues decode bitwise.
"""
from __future__ import annotations

import dataclasses
import json
import os
import struct
import zlib

__all__ = [
    "JournalError", "JournalCorruption", "RequestJournal", "scan_journal",
    "replay", "ReplayedRequest", "JournalState", "SYNC_MODES",
]

_HEADER = struct.Struct("<II")
SYNC_MODES = ("always", "batch", "off")
JOURNAL_NAME = "requests.journal"


class JournalError(RuntimeError):
    """Journal-layer failure (bad config, checkpoint-pin mismatch)."""


class JournalCorruption(JournalError):
    """A complete record whose CRC (or JSON payload) does not verify.

    Carries everything a recovery path needs: the 0-based ``index`` of
    the bad record, its byte ``offset``, and ``records`` — the good
    prefix scanned before it (safe to replay)."""

    def __init__(self, path: str, index: int, offset: int, reason: str,
                 records: list):
        super().__init__(
            f"corrupt journal record [{index}] at byte {offset} of "
            f"{path}: {reason} ({len(records)} good records precede it)")
        self.path = path
        self.index = index
        self.offset = offset
        self.reason = reason
        self.records = records


def scan_journal(path: str) -> tuple[list[dict], dict]:
    """Read every committed record of ``path``.

    Returns ``(records, stats)``.  A torn tail (header or payload cut
    short by a crash mid-append) is tolerated: scanning stops at the last
    complete record and ``stats["torn_tail_bytes"]`` reports the dangling
    byte count with ``stats["valid_bytes"]`` the truncation point.  A
    CRC/JSON failure on a COMPLETE record raises
    :class:`JournalCorruption` naming the record.  A missing or empty
    file is a clean cold start (no records)."""
    records: list[dict] = []
    stats = {"records": 0, "bytes": 0, "valid_bytes": 0,
             "torn_tail_bytes": 0}
    if not os.path.exists(path):
        return records, stats
    with open(path, "rb") as f:
        blob = f.read()
    stats["bytes"] = len(blob)
    off = 0
    while off < len(blob):
        if off + _HEADER.size > len(blob):
            stats["torn_tail_bytes"] = len(blob) - off
            break
        length, crc = _HEADER.unpack_from(blob, off)
        payload = blob[off + _HEADER.size: off + _HEADER.size + length]
        if len(payload) < length:
            stats["torn_tail_bytes"] = len(blob) - off
            break
        if zlib.crc32(payload) & 0xFFFFFFFF != crc:
            raise JournalCorruption(path, len(records), off,
                                    "crc32 mismatch", records)
        try:
            rec = json.loads(payload.decode("utf-8"))
        except (UnicodeDecodeError, json.JSONDecodeError) as e:
            raise JournalCorruption(
                path, len(records), off,
                f"payload verifies but does not parse: {e}",
                records) from e
        records.append(rec)
        off += _HEADER.size + length
    stats["records"] = len(records)
    stats["valid_bytes"] = off
    return records, stats


class RequestJournal:
    """Append-only CRC-framed journal writer over one directory.

    Opening scans the existing file first: the torn tail of a crashed
    writer is truncated away, and (with ``repair=True``, the serving
    default) a corrupt suffix is truncated to the last good record —
    ``stats`` records what was dropped so recovery can surface it.  With
    ``repair=False`` corruption raises :class:`JournalCorruption` (the
    strict posture for tests and forensics).  The committed records seen
    at open stay available on ``self.records`` for ``replay``."""

    def __init__(self, directory: str, *, sync: str = "batch",
                 sync_every: int = 128, repair: bool = True):
        if sync not in SYNC_MODES:
            raise JournalError(f"unknown journal_sync {sync!r} "
                               f"(expected one of {SYNC_MODES})")
        if sync_every < 1:
            raise JournalError(
                f"sync_every must be >= 1, got {sync_every}")
        self.dir = directory
        self.sync = sync
        self.sync_every = int(sync_every)
        os.makedirs(directory, exist_ok=True)
        self.path = os.path.join(directory, JOURNAL_NAME)
        try:
            self.records, self.stats = scan_journal(self.path)
        except JournalCorruption as e:
            if not repair:
                raise
            self.records = e.records
            self.stats = {"records": len(e.records),
                          "valid_bytes": e.offset,
                          "corrupt_record_index": e.index,
                          "corrupt_reason": e.reason}
        valid = self.stats.get("valid_bytes", 0)
        on_disk = os.path.getsize(self.path) \
            if os.path.exists(self.path) else 0
        if on_disk > valid:
            # torn tail and/or corrupt suffix: truncate to the committed
            # prefix before appending (never append after garbage)
            with open(self.path, "r+b") as f:
                f.truncate(valid)
            self.stats["truncated_bytes"] = on_disk - valid
        self._f = open(self.path, "ab")
        self.appended = 0
        self._unflushed = 0
        self._flushes_since_sync = 0
        self.fsyncs = 0

    # ------------------------------------------------------------------
    def append(self, rec: dict) -> None:
        payload = json.dumps(rec, separators=(",", ":")).encode("utf-8")
        self._f.write(_HEADER.pack(len(payload),
                                   zlib.crc32(payload) & 0xFFFFFFFF))
        self._f.write(payload)
        self.appended += 1
        self._unflushed += 1
        if self.sync == "always":
            self._f.flush()
            os.fsync(self._f.fileno())
            self.fsyncs += 1
            self._unflushed = 0

    def flush(self, *, force_sync: bool = False) -> None:
        """Push buffered records to the OS; under ``"batch"`` fsync every
        ``sync_every``-th flush (the unsynced tail is bounded and greedy
        recovery re-derives it bitwise).  ``force_sync`` fsyncs under
        EVERY policy — the drain snapshot must be durable regardless of
        the steady-state one."""
        if self._f.closed:
            return
        self._f.flush()
        self._flushes_since_sync += 1
        due = (self.sync == "batch"
               and self._flushes_since_sync >= self.sync_every)
        if self._unflushed and (due or force_sync):
            os.fsync(self._f.fileno())
            self.fsyncs += 1
            self._unflushed = 0
        if due or force_sync:
            self._flushes_since_sync = 0

    def close(self) -> None:
        if not self._f.closed:
            self.flush(force_sync=True)
            self._f.close()

    def report(self) -> dict:
        """Flat scalar snapshot for ``metrics_report()["journal"]``."""
        return {
            "appended": self.appended,
            "fsyncs": self.fsyncs,
            "replayed_records": len(self.records),
            "truncated_bytes": self.stats.get("truncated_bytes", 0),
            "corrupt_record_index":
                self.stats.get("corrupt_record_index", -1),
            "sync_always": self.sync == "always",
        }


# ---------------------------------------------------------------------------
# Replay: fold records into per-request states
# ---------------------------------------------------------------------------
@dataclasses.dataclass
class ReplayedRequest:
    """One request's journaled history, folded for recovery."""
    uid: int
    prompt: list
    max_new_tokens: int
    deadline_ms: float | None = None
    ttft_budget_ms: float | None = None
    tokens: list = dataclasses.field(default_factory=list)
    state: str | None = None          # terminal state name, or None (live)
    reason: str | None = None

    @property
    def terminal(self) -> bool:
        return self.state is not None


@dataclasses.dataclass
class JournalState:
    """Everything :func:`replay` derives from a record list: requests in
    submission order, the latest packed-checkpoint pin (or None), and the
    count of drain-ledger snapshots seen."""
    requests: dict            # uid -> ReplayedRequest, insertion-ordered
    checkpoint: dict | None = None
    ledgers: int = 0
    dangling_tokens: int = 0  # token records for unknown uids (skipped)

    def live(self) -> list:
        """Non-terminal requests in submission order — what recovery
        re-prefills."""
        return [r for r in self.requests.values() if not r.terminal]


def replay(records: list) -> JournalState:
    """Fold journal ``records`` into a :class:`JournalState`.  Unknown
    record kinds are skipped (forward compatibility); token/terminal
    records for a uid with no submit record are counted but dropped (the
    submit record was lost to a truncated prefix — without the prompt
    the request cannot be rebuilt, and its client will resubmit)."""
    state = JournalState(requests={})
    for rec in records:
        kind = rec.get("t")
        if kind == "submit":
            uid = rec["uid"]
            state.requests[uid] = ReplayedRequest(
                uid=uid, prompt=list(rec["prompt"]),
                max_new_tokens=int(rec["max_new_tokens"]),
                deadline_ms=rec.get("deadline_ms"),
                ttft_budget_ms=rec.get("ttft_budget_ms"))
        elif kind == "token":
            rr = state.requests.get(rec["uid"])
            if rr is None:
                state.dangling_tokens += 1
            else:
                rr.tokens.append(int(rec["tok"]))
        elif kind == "terminal":
            rr = state.requests.get(rec["uid"])
            if rr is not None:
                rr.state = rec["state"]
                rr.reason = rec.get("reason")
        elif kind == "ckpt":
            state.checkpoint = {"dir": rec.get("dir"),
                                "step": rec.get("step"),
                                "fingerprint": rec.get("fp")}
        elif kind == "ledger":
            state.ledgers += 1
    return state
