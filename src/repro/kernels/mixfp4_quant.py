"""Pallas TPU kernel: fused dual-format MixFP4 block quantizer (Algorithm 1).

One pass over the data computes, per g=16 block:
  - the block absmax (shared by both candidate branches),
  - both candidate E4M3 scales (blockmax/6 for E2M1, blockmax/7 for E1M2),
  - both candidate quantizations + their MSEs (branchless RNE, no gathers),
  - the argmin select, the packed 4-bit payload (2/byte) and the scale byte
    with the type bit in the sign position.

This fuses what the naive QDQ path does in two passes (one per candidate)
into a single HBM read + two small writes — the quantizer is the per-step
hot spot of MixFP4 training (it runs on W, X and dY of every GEMM).

Tiling: grid over row-tiles of (bm, K); the full K extent of a tile lives in
VMEM (K * bm * 4B; bm=256, K=8192 -> 8 MiB, within v5e's 16 MiB VMEM between
double buffering — bm is auto-shrunk for wider K).  All lane math is
8/16/32-bit elementwise VPU work; no MXU use.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["mixfp4_quant_rows", "quant_block_kernel_math"]

_G = 16  # block size (paper g=16); fixed for the kernel


def _rne_e2m1(a: jax.Array) -> jax.Array:
    """Branchless RNE onto the E2M1 magnitude lattice {0,.5,1,1.5,2,3,4,6}.

    Piecewise-uniform regions: step .5 below 2, step 1 in [2,4), step 2 in
    [4,6]; jnp.round is round-half-even, matching IEEE RNE on each region and
    the generic searchsorted oracle (tie-to-even-mantissa).
    """
    a = jnp.clip(a, 0.0, 6.0)
    lo = jnp.round(a * 2.0) * 0.5
    mid = jnp.round(a)
    hi = jnp.round(a * 0.5) * 2.0
    return jnp.where(a < 2.0, lo, jnp.where(a < 4.0, mid, hi))


def _rne_int(a: jax.Array, qmax: float) -> jax.Array:
    """RNE onto the uniform lattice {0..qmax} (E1M2 effective / INT4)."""
    return jnp.clip(jnp.round(a), 0.0, qmax)


def _e4m3_rne(x: jax.Array) -> jax.Array:
    """Round to E4M3 via hardware convert (saturating clamp applied first)."""
    x = jnp.clip(x, 0.0, 448.0)
    return x.astype(jnp.float8_e4m3fn).astype(jnp.float32)


def quant_block_kernel_math(xs: jax.Array):
    """Shared per-tile math (also reused by tests): xs is the tile already
    divided by the per-tensor scale, shape (bm, nb, 16), f32.

    Returns (values, scale8, type_bits) exactly as core.quantize would.
    """
    absmax = jnp.max(jnp.abs(xs), axis=-1)                     # (bm, nb)

    # Reciprocal multiplies (not divides) throughout, mirroring the
    # core.quantize oracle exactly: XLA rewrites divides to rcp-multiplies
    # inside jit but not eagerly, so divides would cost 1 ulp of
    # kernel-vs-oracle disagreement at rounding-tie boundaries.
    # --- E2M1 branch (Alg.1 lines 7-10) --------------------------------
    s_e2 = _e4m3_rne(absmax * (1.0 / 6.0))
    s_e2 = jnp.where((absmax > 0) & (s_e2 <= 0), 2.0**-9, s_e2)
    s_e2 = jnp.where(absmax > 0, s_e2, 1.0)
    y2 = xs * (1.0 / s_e2)[..., None]
    q2 = jnp.sign(y2) * _rne_e2m1(jnp.abs(y2))
    err2 = jnp.mean(jnp.square(q2 * s_e2[..., None] - xs), axis=-1)

    # --- E1M2 branch (Alg.1 lines 12-15; effective INT lattice) --------
    s_e1 = _e4m3_rne(absmax * (1.0 / 7.0))
    s_e1 = jnp.where((absmax > 0) & (s_e1 <= 0), 2.0**-9, s_e1)
    s_e1 = jnp.where(absmax > 0, s_e1, 1.0)
    y1 = xs * (1.0 / s_e1)[..., None]
    q1 = jnp.sign(y1) * _rne_int(jnp.abs(y1), 7.0)
    err1 = jnp.mean(jnp.square(q1 * s_e1[..., None] - xs), axis=-1)

    # --- select (ties -> E2M1, matching argmin-first in the oracle) ----
    t = (err1 < err2).astype(jnp.uint8)                         # (bm, nb)
    q = jnp.where(t[..., None].astype(bool), q1, q2)
    s8 = jnp.where(t.astype(bool), s_e1, s_e2)
    return q, s8, t


def _encode_nibbles(q: jax.Array, t: jax.Array) -> jax.Array:
    """values-on-lattice + type -> 4-bit codes [s|p2p1p0], branchless."""
    sign = (q < 0).astype(jnp.uint8) << 3
    a = jnp.abs(q)
    # E2M1 payload index: 2*a below 2 (codes 0..4 at idx a/0.5), then 4+ (a-2)
    # for {2,3,4}->{4,5,6}, then 7 for 6.  Derived from the lattice layout.
    idx2 = jnp.where(a < 2.0, a * 2.0, jnp.where(a < 6.0, a + 2.0, 7.0))
    # E1M2 effective payload == integer level itself (x2 remap built in)
    idx1 = a
    payload = jnp.where(t[..., None].astype(bool), idx1, idx2).astype(jnp.uint8)
    return sign | payload


def _pack_scale(s8: jax.Array, t: jax.Array) -> jax.Array:
    bits = jax.lax.bitcast_convert_type(
        s8.astype(jnp.float8_e4m3fn), jnp.uint8)
    mag = bits & 0x7F
    # Canonicalize: a zero-magnitude scale byte must not carry the type
    # bit.  Byte 0x80 is a *negative-zero* E4M3 scale that the type-in-sign
    # decoder would read as an E1M2 block; a zero scale makes the type
    # moot (every payload decodes to 0), so the canonical encoding of a
    # dead block is 0x00.  The branch guards in quant_block_kernel_math
    # keep s8 > 0 today (all-zero blocks get scale 1.0) — this makes the
    # invariant structural rather than incidental.
    return jnp.where(mag == 0, mag, mag | (t << 7)).astype(jnp.uint8)


def _quant_kernel(s32_ref, x_ref, payload_ref, scale_ref, *,
                  per_row: bool = False):
    if per_row:
        # (bm, 1) row-local scales broadcast over the K extent; the
        # reciprocal-then-multiply sequence matches the scalar branch (and
        # the fused GEMM prologue) op for op, so a given row's bytes are
        # identical whichever entry quantizes it.
        x = x_ref[...].astype(jnp.float32) * (1.0 / s32_ref[...])
    else:
        s32 = s32_ref[0, 0]
        x = x_ref[...].astype(jnp.float32) * (1.0 / s32)
    bm, k = x.shape
    xs = x.reshape(bm, k // _G, _G)
    q, s8, t = quant_block_kernel_math(xs)
    nib = _encode_nibbles(q, t).reshape(bm, k)
    payload_ref[...] = (nib[:, 0::2] | (nib[:, 1::2] << 4)).astype(jnp.uint8)
    scale_ref[...] = _pack_scale(s8, t)


def _pick_bm(m: int, k: int) -> int:
    """Row-tile height: keep the f32 tile + candidates under ~6 MiB VMEM."""
    budget = 6 * 1024 * 1024 // (4 * 4)   # 4 live f32 copies of the tile
    bm = max(8, min(256, budget // max(k, 1)))
    while m % bm and bm > 1:
        bm //= 2
    return max(bm, 1)


@functools.partial(jax.jit, static_argnames=("interpret", "bm", "per_row"))
def mixfp4_quant_rows(
    x: jax.Array,
    *,
    bm: int | None = None,
    interpret: bool = False,
    scale32: jax.Array | float | None = None,
    per_row: bool = False,
):
    """Quantize (M, K) with 1-D g=16 blocks along K (MixFP4, RNE).

    Returns (payload (M, K//2) uint8, scales (M, K//16) uint8, scale32 f32).
    The per-tensor scale is a global reduction, computed outside the kernel
    (a cheap fused max) and passed in SMEM-style as a (1,1) operand.
    ``scale32`` pins it instead — incremental producers (the packed KV
    cache writes rows at different decode steps) need every row quantized
    under one shared per-tensor scale, not a per-call data-dependent one.

    ``per_row=True`` switches the level-2 scale to a row-local reduction
    (``scaling.row_scale``): the returned scale32 is an (M,) vector and
    each row's bytes depend only on that row — the W4A4 serving contract
    that breaks batch coupling.  ``scale32`` may then pin an (M,) vector.
    """
    m, k = x.shape
    assert k % _G == 0, f"K={k} must be a multiple of {_G}"
    if per_row:
        if scale32 is None:
            amax = jnp.max(jnp.abs(x), axis=-1).astype(jnp.float32)
            # matches scaling.row_scale bit-for-bit (reciprocal multiply)
            s32 = jnp.where(amax > 0, amax * (1.0 / 2688.0), 1.0)
        else:
            s32 = jnp.asarray(scale32, jnp.float32)
        s32 = jnp.broadcast_to(s32.reshape(-1), (m,)).reshape(m, 1)
    elif scale32 is None:
        amax = jnp.max(jnp.abs(x)).astype(jnp.float32)
        # matches scaling.tensor_scale bit-for-bit (reciprocal multiply)
        s32 = jnp.where(amax > 0, amax * (1.0 / 2688.0), 1.0).reshape(1, 1)
    else:
        s32 = jnp.asarray(scale32, jnp.float32).reshape(1, 1)

    if bm is None:
        bm = _pick_bm(m, k)
    grid = (pl.cdiv(m, bm),)

    s32_spec = (pl.BlockSpec((bm, 1), lambda i: (i, 0)) if per_row
                else pl.BlockSpec((1, 1), lambda i: (0, 0)))
    payload, scales = pl.pallas_call(
        functools.partial(_quant_kernel, per_row=per_row),
        grid=grid,
        in_specs=[
            s32_spec,
            pl.BlockSpec((bm, k), lambda i: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((bm, k // 2), lambda i: (i, 0)),
            pl.BlockSpec((bm, k // _G), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((m, k // 2), jnp.uint8),
            jax.ShapeDtypeStruct((m, k // _G), jnp.uint8),
        ],
        interpret=interpret,
    )(s32, x)
    return payload, scales, (s32[:, 0] if per_row else s32[0, 0])
