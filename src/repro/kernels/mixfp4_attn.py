"""Pallas TPU kernel: fused decode attention over a packed MixFP4 KV cache.

The serving engine's dominant decode traffic term is the KV cache read
(ROADMAP "decode_32k").  Holding the cache in the paper's wire format
(4-bit payload + type-in-sign E4M3 scale bytes, 4.5 bits/value) only pays
off if the packed representation is consumed *directly* by the attention
read — dequantizing the whole cache back to bf16 in HBM before every step
would spend the saved bandwidth immediately.  This kernel streams the
packed K/V blocks HBM->VMEM, runs the same branch-free Fig. 9 dual-codebook
decode as ``mixfp4_gemm`` (shared ``_decode_scales``/``_decode_nibbles``)
on 16-lane blocks in VMEM, and computes masked online-softmax attention
(flash-decoding) for one query token per sequence.  No dense bf16 copy of
the cache ever exists in HBM.

Layout (matches the 1-D ``BlockLayout1D(-1, 16)`` QTensor KV cache built by
``models.transformer.init_cache(kv_quant="mixfp4")``):

  q          (B, H, dh)          bf16/f32 — the RoPE'd decode-step query
  k/v payload(B, S, Hkv, dh//2)  uint8    — two dh-consecutive nibbles/byte
  k/v scales (B, S, Hkv, dh//16) uint8    — {T | e4m3[6:0]} per 16-lane block
  lengths    (B,)                int32    — valid rows per sequence
                                           (the current token's row included)

Grid: (B, S/bs) with the key-block loop innermost; the running
(max, sum, acc) flash state lives in VMEM scratch across the key loop and
the output row is emitted on the last block.  GQA queries reshape to
(Hkv, group, dh) so each kv head's packed blocks are decoded exactly once
per step.  Masking covers ragged per-slot lengths, sliding windows and the
S padding the ``ops`` entry may add; ``softcap`` is a compile-time constant
(it is an arch property, not a per-layer one).

``mixfp4_attn_decode_paged`` is the same flash loop over a *paged* pool
(``serving/kvpool.py``): K/V slabs are (P, page_len, Hkv, ...) physical
pages and a per-sequence block table maps logical key-block j to physical
pages via ``pltpu.PrefetchScalarGridSpec`` scalar prefetch — the block
table is read at *index-map* time, so each grid step DMAs exactly the
pages it needs and the kernel body never sees the indirection.  Both
kernels share ``_flash_step``, so a paged read over the same logical rows
runs literally the same arithmetic as the fixed-slot kernel (the bitwise
paged==fixed contract the serving tests pin).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.mixfp4_gemm import _decode_nibbles, _decode_scales

__all__ = ["mixfp4_attn_decode", "mixfp4_attn_decode_paged"]

_G = 16
_NEG_INF = -1e30


def _decode_kv_block(payload, scales, s32):
    """(bs, Hkv, dh//2) packed + (bs, Hkv, dh//16) scale bytes -> f32
    (bs, Hkv, dh) with block scales and the per-tensor scale fused."""
    bs, hkv, dh2 = payload.shape
    dh = 2 * dh2
    nb = dh // _G
    lo = payload & 0xF
    hi = (payload >> 4) & 0xF
    nib = jnp.stack([lo, hi], axis=-1).reshape(bs, hkv, dh)
    s, t = _decode_scales(scales)
    s_full = jnp.broadcast_to(
        s[..., None], (bs, hkv, nb, _G)).reshape(bs, hkv, dh)
    t_full = jnp.broadcast_to(
        t[..., None], (bs, hkv, nb, _G)).reshape(bs, hkv, dh)
    vals = _decode_nibbles(nib, t_full)
    return vals * s_full * s32


def _flash_step(q_ref, kp, ks, vp, vs, kv_len, win, s32_ref,
                o_ref, acc_ref, m_ref, l_ref, *, softcap: float):
    """One key-block step of the flash-decoding loop, shared verbatim by
    the fixed-slot and paged kernels: decode the packed (bs, Hkv, ...)
    K/V block, fold it into the running (max, sum, acc) scratch state, and
    emit the normalized output row on the last block.  Keeping both
    kernels on this one body is what makes paged==fixed a *bitwise*
    contract rather than an allclose one."""
    s_idx = pl.program_id(1)
    ns = pl.num_programs(1)

    @pl.when(s_idx == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, _NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    bs, hkv, dh2 = kp.shape
    dh = 2 * dh2
    h = q_ref.shape[1]
    g = h // hkv

    k = _decode_kv_block(kp, ks, s32_ref[0, 0])                # (bs,Hkv,dh)
    q = q_ref[0].astype(jnp.float32).reshape(hkv, g, dh)
    # scores: per kv head, (g, dh) x (dh, bs) -> (Hkv, g, bs)
    s = jax.lax.dot_general(
        q, jnp.transpose(k, (1, 0, 2)),
        dimension_numbers=(((2,), (2,)), ((0,), (0,))),
        preferred_element_type=jnp.float32) * (dh ** -0.5)
    if softcap:
        s = softcap * jnp.tanh(s * (1.0 / softcap))

    # decode-position masking: the query sits at position kv_len - 1
    kpos = s_idx * bs + jax.lax.broadcasted_iota(jnp.int32, (1, 1, bs), 2)
    mask = kpos < kv_len
    mask &= jnp.where(win > 0, kpos > kv_len - 1 - win, True)
    s = jnp.where(mask, s, _NEG_INF)

    # online-softmax update (flash-decoding running state in scratch)
    m_prev = m_ref[...].reshape(hkv, g, 1)
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.where(mask, jnp.exp(s - m_new), 0.0)
    l_new = l_ref[...].reshape(hkv, g, 1) * alpha \
        + jnp.sum(p, axis=-1, keepdims=True)

    v = _decode_kv_block(vp, vs, s32_ref[0, 1])                # (bs,Hkv,dh)
    # (Hkv, g, bs) x (bs, dh) batched over Hkv -> (Hkv, g, dh)
    pv = jax.lax.dot_general(
        p, jnp.transpose(v, (1, 0, 2)),
        dimension_numbers=(((2,), (1,)), ((0,), (0,))),
        preferred_element_type=jnp.float32)
    acc_new = acc_ref[...].reshape(hkv, g, dh) * alpha + pv

    m_ref[...] = m_new.reshape(h, 1)
    l_ref[...] = l_new.reshape(h, 1)
    acc_ref[...] = acc_new.reshape(h, dh)

    @pl.when(s_idx == ns - 1)
    def _emit():
        l = l_ref[...]
        o_ref[0] = acc_ref[...] / jnp.where(l > 0, l, 1.0)


def _attn_decode_kernel(len_ref, win_ref, s32_ref,
                        q_ref, kp_ref, ks_ref, vp_ref, vs_ref,
                        o_ref, acc_ref, m_ref, l_ref, *, softcap: float):
    _flash_step(q_ref, kp_ref[0], ks_ref[0], vp_ref[0], vs_ref[0],
                len_ref[0, 0], win_ref[0, 0], s32_ref,
                o_ref, acc_ref, m_ref, l_ref, softcap=softcap)


def _attn_decode_paged_kernel(bt_ref, len_ref, win_ref, s32_ref, q_ref,
                              *refs, softcap: float, n_sub: int):
    """Paged flash step: the grid's index maps already gathered the right
    physical pages (via the prefetched block table), so the body only has
    to stitch the ``n_sub`` page-sized sub-blocks back into one logical
    (bs, Hkv, ...) key block.  Packed bytes concatenate before decode ==
    decode-then-concatenate (the Fig. 9 decode is element-wise per row)."""
    del bt_ref  # consumed by the index maps
    kv, (o_ref, acc_ref, m_ref, l_ref) = refs[:-4], refs[-4:]
    assert len(kv) == 4 * n_sub

    def cat(sub_refs):
        blocks = [r[0] for r in sub_refs]
        return blocks[0] if n_sub == 1 else jnp.concatenate(blocks, axis=0)

    kp = cat(kv[0 * n_sub:1 * n_sub])
    ks = cat(kv[1 * n_sub:2 * n_sub])
    vp = cat(kv[2 * n_sub:3 * n_sub])
    vs = cat(kv[3 * n_sub:4 * n_sub])
    _flash_step(q_ref, kp, ks, vp, vs, len_ref[0, 0], win_ref[0, 0],
                s32_ref, o_ref, acc_ref, m_ref, l_ref, softcap=softcap)


@functools.partial(
    jax.jit, static_argnames=("softcap", "bs", "interpret"))
def mixfp4_attn_decode(
    q: jax.Array,
    k_payload: jax.Array,
    k_scales: jax.Array,
    v_payload: jax.Array,
    v_scales: jax.Array,
    lengths: jax.Array,
    *,
    window: jax.Array | int = 0,
    k_scale32: jax.Array | float = 1.0,
    v_scale32: jax.Array | float = 1.0,
    softcap: float = 0.0,
    bs: int | None = None,
    interpret: bool = False,
) -> jax.Array:
    """One decode-attention step over the packed KV cache -> (B, H, dh) f32.

    ``lengths`` counts the valid cache rows per sequence (including the
    current token's just-written row); ``window`` (0 = full causal) and the
    per-tensor scales are dynamic operands so the per-layer ``lax.scan`` in
    the model can trace them.  S is padded to a multiple of the key-block
    tile here; padded rows are masked, so callers never pad.  ``bs=None``
    asks the cost-model tuner (``kernels.tuning.select_attn_key_block``)
    for the key-block rows per flash step — sized against the same VMEM /
    traffic model the GEMM tiles use.
    """
    b, h, dh = q.shape
    s, hkv, dh2 = k_payload.shape[1:]
    assert dh == 2 * dh2, f"q dh={dh} vs packed payload dh={2 * dh2}"
    assert dh % _G == 0, f"dh={dh} must be a multiple of {_G}"
    assert h % hkv == 0, f"H={h} not a multiple of Hkv={hkv}"
    assert k_scales.shape == (b, s, hkv, dh // _G)

    if bs is None:
        from repro.kernels import tuning  # deferred: keep module deps flat
        bs = tuning.select_attn_key_block(s, hkv, dh)
    bs = min(bs, max(s, 1))
    sp = -(-s // bs) * bs
    if sp != s:  # padded rows are masked by `kpos < lengths`
        pad = ((0, 0), (0, sp - s), (0, 0), (0, 0))
        k_payload = jnp.pad(k_payload, pad)
        k_scales = jnp.pad(k_scales, pad)
        v_payload = jnp.pad(v_payload, pad)
        v_scales = jnp.pad(v_scales, pad)

    lengths = jnp.broadcast_to(
        jnp.asarray(lengths, jnp.int32), (b,)).reshape(b, 1)
    win = jnp.asarray(window, jnp.int32).reshape(1, 1)
    s32 = jnp.stack([jnp.asarray(k_scale32, jnp.float32).reshape(()),
                     jnp.asarray(v_scale32, jnp.float32).reshape(())]
                    ).reshape(1, 2)

    grid = (b, sp // bs)
    kv_spec = pl.BlockSpec((1, bs, hkv, dh2), lambda i, j: (i, j, 0, 0))
    sc_spec = pl.BlockSpec((1, bs, hkv, dh // _G), lambda i, j: (i, j, 0, 0))

    return pl.pallas_call(
        functools.partial(_attn_decode_kernel, softcap=softcap),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1), lambda i, j: (i, 0)),      # lengths
            pl.BlockSpec((1, 1), lambda i, j: (0, 0)),      # window
            pl.BlockSpec((1, 2), lambda i, j: (0, 0)),      # scale32s
            pl.BlockSpec((1, h, dh), lambda i, j: (i, 0, 0)),
            kv_spec, sc_spec, kv_spec, sc_spec,
        ],
        out_specs=pl.BlockSpec((1, h, dh), lambda i, j: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b, h, dh), jnp.float32),
        scratch_shapes=[
            pltpu.VMEM((h, dh), jnp.float32),   # acc
            pltpu.VMEM((h, 1), jnp.float32),    # running max
            pltpu.VMEM((h, 1), jnp.float32),    # running sum
        ],
        interpret=interpret,
    )(lengths, win, s32, q, k_payload, k_scales, v_payload, v_scales)


@functools.partial(
    jax.jit, static_argnames=("softcap", "bs", "interpret"))
def mixfp4_attn_decode_paged(
    q: jax.Array,
    k_payload: jax.Array,
    k_scales: jax.Array,
    v_payload: jax.Array,
    v_scales: jax.Array,
    block_tables: jax.Array,
    lengths: jax.Array,
    *,
    window: jax.Array | int = 0,
    k_scale32: jax.Array | float = 1.0,
    v_scale32: jax.Array | float = 1.0,
    softcap: float = 0.0,
    bs: int | None = None,
    interpret: bool = False,
) -> jax.Array:
    """Decode attention over the *paged* packed KV pool -> (B, H, dh) f32.

    K/V children are physical page slabs ``(P, page_len, Hkv, ...)`` and
    ``block_tables`` (B, max_pages) int32 maps each sequence's logical page
    order to slab rows (page 0 is the pool's trash page: unused table tail
    entries point there and are masked by ``lengths``).  The table rides
    ``PrefetchScalarGridSpec`` scalar prefetch so the page gather happens
    in the BlockSpec index maps — per grid step the kernel DMAs only the
    pages of that key block, and the body is the same ``_flash_step`` as
    the fixed-slot kernel.  With ``bs`` equal to the fixed path's tuner
    choice for the same logical S (the serving engine guarantees this by
    requiring ``max_len % page_len == 0``), the paged output is
    bitwise-identical to ``mixfp4_attn_decode`` on the gathered rows.
    """
    b, h, dh = q.shape
    n_pages, page_len, hkv, dh2 = k_payload.shape
    assert dh == 2 * dh2, f"q dh={dh} vs packed payload dh={2 * dh2}"
    assert dh % _G == 0, f"dh={dh} must be a multiple of {_G}"
    assert page_len % _G == 0, f"page_len={page_len} not a multiple of {_G}"
    assert h % hkv == 0, f"H={h} not a multiple of Hkv={hkv}"
    assert k_scales.shape == (n_pages, page_len, hkv, dh // _G)
    assert block_tables.ndim == 2 and block_tables.shape[0] == b

    max_pages = block_tables.shape[1]
    s_logical = max_pages * page_len
    if bs is None:
        from repro.kernels import tuning  # deferred: keep module deps flat
        bs = tuning.select_attn_key_block(s_logical, hkv, dh)
    bs = min(bs, max(s_logical, 1))
    # The grid needs bs and page_len commensurate so each key block is a
    # whole number of (partial) pages; power-of-two page lengths always
    # satisfy this for the tuner's power-of-two bs choices.
    if bs >= page_len:
        bs -= bs % page_len
    elif page_len % bs:
        bs = page_len

    sp = -(-s_logical // bs) * bs
    if sp != s_logical:  # pad table columns with the trash page (masked)
        block_tables = jnp.pad(
            block_tables, ((0, 0), (0, sp // page_len - max_pages)))
    block_tables = jnp.asarray(block_tables, jnp.int32)

    lengths = jnp.broadcast_to(
        jnp.asarray(lengths, jnp.int32), (b,)).reshape(b, 1)
    win = jnp.asarray(window, jnp.int32).reshape(1, 1)
    s32 = jnp.stack([jnp.asarray(k_scale32, jnp.float32).reshape(()),
                     jnp.asarray(v_scale32, jnp.float32).reshape(())]
                    ).reshape(1, 2)

    grid = (b, sp // bs)
    if bs >= page_len:
        n_sub, rows = bs // page_len, page_len

        def _page_map(t):
            return lambda i, j, bt: (bt[i, j * n_sub + t], 0, 0, 0)

        maps = [_page_map(t) for t in range(n_sub)]
    else:
        n_sub, rows = 1, bs
        ipb = page_len // bs
        maps = [lambda i, j, bt: (bt[i, j // ipb], j % ipb, 0, 0)]

    kv_specs = [pl.BlockSpec((1, rows, hkv, dh2), m) for m in maps]
    sc_specs = [pl.BlockSpec((1, rows, hkv, dh // _G), m) for m in maps]

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1), lambda i, j, bt: (i, 0)),    # lengths
            pl.BlockSpec((1, 1), lambda i, j, bt: (0, 0)),    # window
            pl.BlockSpec((1, 2), lambda i, j, bt: (0, 0)),    # scale32s
            pl.BlockSpec((1, h, dh), lambda i, j, bt: (i, 0, 0)),
            *kv_specs, *sc_specs, *kv_specs, *sc_specs,
        ],
        out_specs=pl.BlockSpec((1, h, dh), lambda i, j, bt: (i, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((h, dh), jnp.float32),   # acc
            pltpu.VMEM((h, 1), jnp.float32),    # running max
            pltpu.VMEM((h, 1), jnp.float32),    # running sum
        ],
    )
    return pl.pallas_call(
        functools.partial(
            _attn_decode_paged_kernel, softcap=softcap, n_sub=n_sub),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, h, dh), jnp.float32),
        interpret=interpret,
    )(block_tables, lengths, win, s32, q,
      *([k_payload] * n_sub), *([k_scales] * n_sub),
      *([v_payload] * n_sub), *([v_scales] * n_sub))
