"""Public kernel entry points with automatic interpret-mode fallback.

On TPU the Pallas kernels compile natively; on CPU (this container) they run
in interpret mode, which executes the kernel body in Python/XLA-CPU and is
what the per-kernel allclose tests exercise.  ``pack_weight_qt`` /
``quantize_rows`` are the packing producers shared by serving and tests.

``count_dispatches`` wraps a trace and counts GEMM-path kernel entries —
how the serving bench proves the fused W4A4 path costs ONE dispatch per
projection where the quantize_rows -> gemm composition costs two.
"""
from __future__ import annotations

import contextlib

import jax
import jax.numpy as jnp

from repro.kernels import ref
from repro.kernels.fwht import fwht_rows
from repro.kernels.mixfp4_attn import (mixfp4_attn_decode,
                                       mixfp4_attn_decode_paged)
from repro.kernels.mixfp4_gemm import (mixfp4_gemm_w4a4,
                                       mixfp4_gemm_w4a4_fused,
                                       mixfp4_gemm_w4a16)
from repro.kernels.mixfp4_quant import mixfp4_quant_rows

__all__ = [
    "default_interpret",
    "quantize_rows",
    "pack_weight_qt",
    "gemm_w4a16",
    "gemm_w4a4",
    "gemm_w4a4_fused",
    "attn_decode_packed",
    "attn_decode_paged",
    "rht_rows",
    "count_dispatches",
]


def default_interpret() -> bool:
    return jax.default_backend() != "tpu"


# ---------------------------------------------------------------------------
# GEMM-path dispatch accounting (trace-time): every kernel entry below ticks
# the active counter, so tracing e.g. a decode step under count_dispatches()
# reports exactly how many Pallas launches each projection costs.
# ---------------------------------------------------------------------------
_DISPATCHES: dict | None = None


@contextlib.contextmanager
def count_dispatches():
    """Collect per-entry GEMM-path kernel launch counts for the enclosed
    trace (e.g. ``jax.eval_shape`` of a decode step).  Yields the dict that
    accumulates ``{entry_name: count}``."""
    global _DISPATCHES
    prev, _DISPATCHES = _DISPATCHES, {}
    try:
        yield _DISPATCHES
    finally:
        _DISPATCHES = prev


def _tick(name: str):
    if _DISPATCHES is not None:
        _DISPATCHES[name] = _DISPATCHES.get(name, 0) + 1


def quantize_rows(x: jax.Array, **kw):
    """Fused MixFP4 row quantizer (payload, scales, scale32).

    Pass ``scale32=`` to pin the per-tensor scale instead of deriving it
    from the data — required for incremental producers like the packed KV
    cache, where rows quantized at different decode steps must share one
    per-tensor scale.
    """
    _tick("quantize_rows")
    kw.setdefault("interpret", default_interpret())
    return mixfp4_quant_rows(x, **kw)


# pack_weight_kn (the deprecated positional-triple shim) is gone: use
# pack_weight_qt / qtensor.quantize and route GEMMs through qtensor.qmm
# (docs/qtensor.md migration table).  The numeric reference it fronted
# lives on as ref.ref_pack_weight_kn, the kernel-test oracle.


def pack_weight_qt(w: jax.Array, method: str = "mixfp4",
                   block: tuple[int, int] = (16, 16)):
    """Quantize+pack a (K, N) weight into a 2-D-tiled QTensor (the ``qmm``
    weight operand)."""
    from repro.core import qtensor
    return qtensor.quantize(
        w, qtensor.QuantSpec(method, qtensor.BlockLayout2D(*block)))


def gemm_w4a16(x, payload, scales, scale32, **kw):
    _tick("gemm_w4a16")
    kw.setdefault("interpret", default_interpret())
    return mixfp4_gemm_w4a16(x, payload, scales, scale32, **kw)


def gemm_w4a4(xp, xs, xs32, payload, scales, scale32, **kw):
    _tick("gemm_w4a4")
    kw.setdefault("interpret", default_interpret())
    return mixfp4_gemm_w4a4(xp, xs, xs32, payload, scales, scale32, **kw)


def gemm_w4a4_fused(x, x_scale32, payload, scales, scale32, **kw):
    """W4A4 GEMM with the row quantizer fused into the kernel prologue:
    ONE Pallas dispatch where ``quantize_rows`` + ``gemm_w4a4`` costs two,
    bitwise-identical to that composition on the same tile grid."""
    _tick("gemm_w4a4_fused")
    kw.setdefault("interpret", default_interpret())
    return mixfp4_gemm_w4a4_fused(x, x_scale32, payload, scales, scale32,
                                  **kw)


def attn_decode_packed(q, k_payload, k_scales, v_payload, v_scales,
                       lengths, **kw):
    """Fused decode attention over the packed KV cache (flash-decoding with
    in-VMEM Fig. 9 decode); see ``kernels.mixfp4_attn``.  Returns
    (B, H, dh) f32 without materializing a dense bf16 cache in HBM.  The
    key-block size defaults to the cost-model tuner's choice
    (``kernels.tuning.select_attn_key_block``)."""
    kw.setdefault("interpret", default_interpret())
    return mixfp4_attn_decode(q, k_payload, k_scales, v_payload, v_scales,
                              lengths, **kw)


def attn_decode_paged(q, k_payload, k_scales, v_payload, v_scales,
                      block_tables, lengths, **kw):
    """Fused decode attention over the *paged* packed KV pool
    (``serving.kvpool``): K/V children are physical page slabs
    (P, page_len, Hkv, ...) and ``block_tables`` (B, max_pages) maps each
    sequence's logical page order to slab rows via scalar-prefetch index
    maps.  Same ``_flash_step`` body as ``attn_decode_packed`` — with the
    engine's matched key-block size the paged read is bitwise-identical
    to the fixed-slot kernel on the gathered rows."""
    kw.setdefault("interpret", default_interpret())
    return mixfp4_attn_decode_paged(q, k_payload, k_scales, v_payload,
                                    v_scales, block_tables, lengths, **kw)


def rht_rows(x, signs, **kw):
    kw.setdefault("interpret", default_interpret())
    return fwht_rows(x, signs, **kw)
