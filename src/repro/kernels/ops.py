"""Public kernel entry points with automatic interpret-mode fallback.

On TPU the Pallas kernels compile natively; on CPU (this container) they run
in interpret mode, which executes the kernel body in Python/XLA-CPU and is
what the per-kernel allclose tests exercise.  ``pack_weight_qt`` /
``quantize_rows`` are the packing producers shared by serving and tests.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import ref
from repro.kernels.fwht import fwht_rows
from repro.kernels.mixfp4_attn import mixfp4_attn_decode
from repro.kernels.mixfp4_gemm import mixfp4_gemm_w4a4, mixfp4_gemm_w4a16
from repro.kernels.mixfp4_quant import mixfp4_quant_rows

__all__ = [
    "default_interpret",
    "quantize_rows",
    "pack_weight_qt",
    "gemm_w4a16",
    "gemm_w4a4",
    "attn_decode_packed",
    "rht_rows",
]


def default_interpret() -> bool:
    return jax.default_backend() != "tpu"


def quantize_rows(x: jax.Array, **kw):
    """Fused MixFP4 row quantizer (payload, scales, scale32).

    Pass ``scale32=`` to pin the per-tensor scale instead of deriving it
    from the data — required for incremental producers like the packed KV
    cache, where rows quantized at different decode steps must share one
    per-tensor scale.
    """
    kw.setdefault("interpret", default_interpret())
    return mixfp4_quant_rows(x, **kw)


# pack_weight_kn (the deprecated positional-triple shim) is gone: use
# pack_weight_qt / qtensor.quantize and route GEMMs through qtensor.qmm
# (docs/qtensor.md migration table).  The numeric reference it fronted
# lives on as ref.ref_pack_weight_kn, the kernel-test oracle.


def pack_weight_qt(w: jax.Array, method: str = "mixfp4",
                   block: tuple[int, int] = (16, 16)):
    """Quantize+pack a (K, N) weight into a 2-D-tiled QTensor (the ``qmm``
    weight operand)."""
    from repro.core import qtensor
    return qtensor.quantize(
        w, qtensor.QuantSpec(method, qtensor.BlockLayout2D(*block)))


def gemm_w4a16(x, payload, scales, scale32, **kw):
    kw.setdefault("interpret", default_interpret())
    return mixfp4_gemm_w4a16(x, payload, scales, scale32, **kw)


def gemm_w4a4(xp, xs, xs32, payload, scales, scale32, **kw):
    kw.setdefault("interpret", default_interpret())
    return mixfp4_gemm_w4a4(xp, xs, xs32, payload, scales, scale32, **kw)


def attn_decode_packed(q, k_payload, k_scales, v_payload, v_scales,
                       lengths, **kw):
    """Fused decode attention over the packed KV cache (flash-decoding with
    in-VMEM Fig. 9 decode); see ``kernels.mixfp4_attn``.  Returns
    (B, H, dh) f32 without materializing a dense bf16 cache in HBM."""
    kw.setdefault("interpret", default_interpret())
    return mixfp4_attn_decode(q, k_payload, k_scales, v_payload, v_scales,
                              lengths, **kw)


def rht_rows(x, signs, **kw):
    kw.setdefault("interpret", default_interpret())
    return fwht_rows(x, signs, **kw)
