"""Public kernel entry points with automatic interpret-mode fallback.

On TPU the Pallas kernels compile natively; on CPU (this container) they run
in interpret mode, which executes the kernel body in Python/XLA-CPU and is
what the per-kernel allclose tests exercise.  ``pack_weight_kn`` /
``quantize_rows`` are the packing producers shared by serving and tests.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import ref
from repro.kernels.fwht import fwht_rows
from repro.kernels.mixfp4_gemm import mixfp4_gemm_w4a4, mixfp4_gemm_w4a16
from repro.kernels.mixfp4_quant import mixfp4_quant_rows

__all__ = [
    "default_interpret",
    "quantize_rows",
    "pack_weight_kn",
    "pack_weight_qt",
    "gemm_w4a16",
    "gemm_w4a4",
    "rht_rows",
]


def default_interpret() -> bool:
    return jax.default_backend() != "tpu"


def quantize_rows(x: jax.Array, **kw):
    """Fused MixFP4 row quantizer (payload, scales, scale32)."""
    kw.setdefault("interpret", default_interpret())
    return mixfp4_quant_rows(x, **kw)


def pack_weight_kn(w: jax.Array, method: str = "mixfp4",
                   block: tuple[int, int] = (16, 16)):
    """Quantize+pack a (K, N) weight for the GEMM kernels (oracle-produced;
    packing is offline/per-checkpoint, not a hot path).

    Positional-triple shim; new code should use :func:`pack_weight_qt` /
    ``repro.core.qtensor.quantize`` and route GEMMs through ``qtensor.qmm``.
    """
    return ref.ref_pack_weight_kn(w, method, block)


def pack_weight_qt(w: jax.Array, method: str = "mixfp4",
                   block: tuple[int, int] = (16, 16)):
    """Quantize+pack a (K, N) weight into a 2-D-tiled QTensor (the ``qmm``
    weight operand)."""
    from repro.core import qtensor
    return qtensor.quantize(
        w, qtensor.QuantSpec(method, qtensor.BlockLayout2D(*block)))


def gemm_w4a16(x, payload, scales, scale32, **kw):
    kw.setdefault("interpret", default_interpret())
    return mixfp4_gemm_w4a16(x, payload, scales, scale32, **kw)


def gemm_w4a4(xp, xs, xs32, payload, scales, scale32, **kw):
    kw.setdefault("interpret", default_interpret())
    return mixfp4_gemm_w4a4(xp, xs, xs32, payload, scales, scale32, **kw)


def rht_rows(x, signs, **kw):
    kw.setdefault("interpret", default_interpret())
    return fwht_rows(x, signs, **kw)
