"""Pallas TPU kernel: block-scaled MixFP4 GEMM with in-VMEM Fig. 9 decode.

TPU adaptation of the paper's tensor-core datapath (§3.3, DESIGN.md §2):
the packed FP4 payload and type-in-sign scale bytes stream HBM->VMEM; a
branch-free dual-codebook decoder (E2M1 shift path / E1M2 integer path,
selected by the block-shared T bit) expands them to bf16 *with the block
scale fused on the VPU*, and the MXU performs the matmul with f32
accumulation.  Eq. 35's factored-scale dot is restructured to scale-before-
MXU because the 128x128 systolic array cannot emit per-16-element partials.

Two entry points:
  mixfp4_gemm_w4a16 : bf16 activations x packed weight  (serving decode path;
                      weight HBM traffic is 4.5 bits/value instead of 16)
  mixfp4_gemm_w4a4  : packed activations x packed weight (full FP4 MMA analog)

Weight layout (from ``pack_weight_qt``): payload (K//2, N) uint8 with two
K-consecutive nibbles per byte; scales (K//16, N//16) uint8 for the paper's
2-D 16x16 weight tiles.  Activation layout (W4A4): payload (M, K//2), scales
(M, K//16) — 1-D blocks along the contraction axis.

Grid: (M/bm, N/bn, K/bk), K innermost; the f32 output block is revisited
across the K loop and used as the accumulator (standard Pallas reduction
pattern), initialised at k==0.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["mixfp4_gemm_w4a16", "mixfp4_gemm_w4a4"]

_G = 16


def _decode_scales(scale_bytes: jax.Array):
    """scale byte {T | e4m3[6:0]} -> (f32 scale, bool T)."""
    t = (scale_bytes >> 7).astype(jnp.uint8)
    s = jax.lax.bitcast_convert_type(
        (scale_bytes & 0x7F).astype(jnp.uint8), jnp.float8_e4m3fn
    ).astype(jnp.float32)
    return s, t


def _decode_nibbles(nib: jax.Array, t_full: jax.Array) -> jax.Array:
    """Fig. 9 unified decode, gather-free.

    E2M1 path: value = (1 + m/2) * 2^(e-1), subnormal m/2 at e=0 — computed
    with two selects and an exp2 (the 'shift path').
    E1M2 path: effective value == integer payload (the x2 remap folds in).
    """
    sign = 1.0 - 2.0 * ((nib >> 3) & 1).astype(jnp.float32)
    p = (nib & 0x7).astype(jnp.float32)
    e = jnp.floor(p * 0.5)          # payload >> 1, as float
    mbit = p - 2.0 * e              # payload & 1
    v_e2m1 = jnp.where(
        p < 2.0, 0.5 * mbit,
        jnp.exp2(e - 1.0) * (1.0 + 0.5 * mbit),
    )
    v = jnp.where(t_full.astype(bool), p, v_e2m1)
    return sign * v


def _expand_weight_tile(wp, ws, bk: int, bn: int):
    """Decode a packed weight tile: payload (bk//2, bn) + scales
    (bk//16, bn//16) -> bf16 (bk, bn) with scales fused (sans scale32)."""
    lo = wp & 0xF
    hi = (wp >> 4) & 0xF
    nib = jnp.stack([lo, hi], axis=1).reshape(bk, bn)
    s, t = _decode_scales(ws)
    # broadcast per-tile scale/type over the 16x16 tile extent
    s_full = jnp.broadcast_to(
        s[:, None, :, None], (bk // _G, _G, bn // _G, _G)).reshape(bk, bn)
    t_full = jnp.broadcast_to(
        t[:, None, :, None], (bk // _G, _G, bn // _G, _G)).reshape(bk, bn)
    vals = _decode_nibbles(nib, t_full)
    return (vals * s_full).astype(jnp.bfloat16)


def _expand_act_tile(xp, xs, bm: int, bk: int):
    """Decode packed activations: payload (bm, bk//2) + scales (bm, bk//16)
    -> bf16 (bm, bk) with 1-D block scales fused (sans scale32)."""
    lo = xp & 0xF
    hi = (xp >> 4) & 0xF
    nib = jnp.stack([lo, hi], axis=-1).reshape(bm, bk)
    s, t = _decode_scales(xs)
    s_full = jnp.broadcast_to(s[:, :, None], (bm, bk // _G, _G)).reshape(bm, bk)
    t_full = jnp.broadcast_to(t[:, :, None], (bm, bk // _G, _G)).reshape(bm, bk)
    vals = _decode_nibbles(nib, t_full)
    return (vals * s_full).astype(jnp.bfloat16)


# ---------------------------------------------------------------------------
# W4A16
# ---------------------------------------------------------------------------
def _w4a16_kernel(s32_ref, x_ref, wp_ref, ws_ref, o_ref):
    k_idx = pl.program_id(2)

    @pl.when(k_idx == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    bk2, bn = wp_ref.shape
    w = _expand_weight_tile(wp_ref[...], ws_ref[...], 2 * bk2, bn)
    x = x_ref[...].astype(jnp.bfloat16)
    acc = jax.lax.dot(x, w, preferred_element_type=jnp.float32)
    o_ref[...] += acc * s32_ref[0, 0]


@functools.partial(
    jax.jit, static_argnames=("bm", "bn", "bk", "interpret"))
def mixfp4_gemm_w4a16(
    x: jax.Array,
    payload: jax.Array,
    scales: jax.Array,
    scale32: jax.Array,
    *,
    bm: int = 128,
    bn: int = 256,
    bk: int = 256,
    interpret: bool = False,
) -> jax.Array:
    """y = x @ dequant(packed W); x (M, K) bf16/f32, returns (M, N) f32."""
    m, k = x.shape
    n = payload.shape[1]
    assert payload.shape == (k // 2, n) and scales.shape == (k // _G, n // _G)
    bm = min(bm, m)
    bn = min(bn, n)
    bk = min(bk, k)
    assert m % bm == 0 and n % bn == 0 and k % bk == 0
    assert bk % _G == 0 and bn % _G == 0
    grid = (m // bm, n // bn, k // bk)
    s32 = scale32.reshape(1, 1).astype(jnp.float32)

    return pl.pallas_call(
        _w4a16_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1), lambda i, j, kk: (0, 0)),
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk // 2, bn), lambda i, j, kk: (kk, j)),
            pl.BlockSpec((bk // _G, bn // _G), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=interpret,
    )(s32, x, payload, scales)


# ---------------------------------------------------------------------------
# W4A4
# ---------------------------------------------------------------------------
def _w4a4_kernel(s32_ref, xp_ref, xs_ref, wp_ref, ws_ref, o_ref):
    k_idx = pl.program_id(2)

    @pl.when(k_idx == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    bm, bk2 = xp_ref.shape
    bk = 2 * bk2
    bn = wp_ref.shape[1]
    x = _expand_act_tile(xp_ref[...], xs_ref[...], bm, bk)
    w = _expand_weight_tile(wp_ref[...], ws_ref[...], bk, bn)
    acc = jax.lax.dot(x, w, preferred_element_type=jnp.float32)
    o_ref[...] += acc * s32_ref[0, 0]


@functools.partial(
    jax.jit, static_argnames=("bm", "bn", "bk", "interpret"))
def mixfp4_gemm_w4a4(
    x_payload: jax.Array,
    x_scales: jax.Array,
    x_scale32: jax.Array,
    payload: jax.Array,
    scales: jax.Array,
    scale32: jax.Array,
    *,
    bm: int = 128,
    bn: int = 256,
    bk: int = 256,
    interpret: bool = False,
) -> jax.Array:
    """y = dequant(packed X) @ dequant(packed W), f32 out."""
    m = x_payload.shape[0]
    k = x_payload.shape[1] * 2
    n = payload.shape[1]
    assert payload.shape == (k // 2, n) and scales.shape == (k // _G, n // _G)
    assert x_scales.shape == (m, k // _G)
    bm = min(bm, m)
    bn = min(bn, n)
    bk = min(bk, k)
    assert m % bm == 0 and n % bn == 0 and k % bk == 0
    grid = (m // bm, n // bn, k // bk)
    s32 = (x_scale32.astype(jnp.float32)
           * scale32.astype(jnp.float32)).reshape(1, 1)

    return pl.pallas_call(
        _w4a4_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1), lambda i, j, kk: (0, 0)),
            pl.BlockSpec((bm, bk // 2), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bm, bk // _G), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk // 2, bn), lambda i, j, kk: (kk, j)),
            pl.BlockSpec((bk // _G, bn // _G), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=interpret,
    )(s32, x_payload, x_scales, payload, scales)
