"""Pallas TPU kernel: block-scaled MixFP4 GEMM with in-VMEM Fig. 9 decode.

TPU adaptation of the paper's tensor-core datapath (§3.3, DESIGN.md §2):
the packed FP4 payload and type-in-sign scale bytes stream HBM->VMEM; a
branch-free dual-codebook decoder (E2M1 shift path / E1M2 integer path,
selected by the block-shared T bit) expands them to bf16 *with the block
scale fused on the VPU*, and the MXU performs the matmul with f32
accumulation.  Eq. 35's factored-scale dot is restructured to scale-before-
MXU because the 128x128 systolic array cannot emit per-16-element partials.

Three entry points:
  mixfp4_gemm_w4a16      : bf16 activations x packed weight  (serving decode
                           path; weight HBM traffic is 4.5 bits/value)
  mixfp4_gemm_w4a4       : packed activations x packed weight (full FP4 MMA
                           analog; the two-dispatch composition's GEMM half)
  mixfp4_gemm_w4a4_fused : bf16/f32 activations quantized to MixFP4 rows IN
                           THE KERNEL PROLOGUE (Alg. 1 via the shared
                           ``quant_block_kernel_math``), then the same dual-
                           decode MMA — serve-time W4A4 in ONE dispatch per
                           projection instead of quantize_rows + GEMM.

Weight layout (from ``pack_weight_qt``): payload (K//2, N) uint8 with two
K-consecutive nibbles per byte; scales (K//16, N//16) uint8 for the paper's
2-D 16x16 weight tiles.  Activation layout (W4A4): payload (M, K//2), scales
(M, K//16) — 1-D blocks along the contraction axis.

Grid and streaming: the grid is (M/bm, N/bn) with the K loop INSIDE the
kernel.  Packed weight payload/scale slabs (and the activation tile) live
in HBM (`memory_space=ANY`) and are streamed into two VMEM slots with
manual async copies — the next K slab's DMA is issued before the current
slab is consumed (double buffering), and the f32 accumulator block never
leaves VMEM scratch, replacing the historical 3-D-grid output-revisit
pattern.  Accumulation remains K-ordered (`acc += dot(x_k, w_k) * s32` per
K step), so the fused and two-dispatch paths are bitwise-comparable.

The fused prologue is bitwise-identical to the composition by construction:
``quant_block_kernel_math`` returns values already ON the 4-bit lattice and
an E4M3-valued block scale, the nibble encode/decode round trip is exact on
both lattices, and the scale byte's pack/unpack is a bitcast — so
``(q * s8).astype(bfloat16)`` equals what ``_expand_act_tile`` reconstructs
from the packed bytes, element for element.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.fwht import fwht_rows_math
from repro.kernels.mixfp4_quant import quant_block_kernel_math

__all__ = ["mixfp4_gemm_w4a16", "mixfp4_gemm_w4a4", "mixfp4_gemm_w4a4_fused"]

_G = 16


def _decode_scales(scale_bytes: jax.Array):
    """scale byte {T | e4m3[6:0]} -> (f32 scale, bool T)."""
    t = (scale_bytes >> 7).astype(jnp.uint8)
    s = jax.lax.bitcast_convert_type(
        (scale_bytes & 0x7F).astype(jnp.uint8), jnp.float8_e4m3fn
    ).astype(jnp.float32)
    return s, t


def _decode_nibbles(nib: jax.Array, t_full: jax.Array) -> jax.Array:
    """Fig. 9 unified decode, gather-free.

    E2M1 path: value = (1 + m/2) * 2^(e-1), subnormal m/2 at e=0 — computed
    with two selects and an exp2 (the 'shift path').
    E1M2 path: effective value == integer payload (the x2 remap folds in).
    """
    sign = 1.0 - 2.0 * ((nib >> 3) & 1).astype(jnp.float32)
    p = (nib & 0x7).astype(jnp.float32)
    e = jnp.floor(p * 0.5)          # payload >> 1, as float
    mbit = p - 2.0 * e              # payload & 1
    v_e2m1 = jnp.where(
        p < 2.0, 0.5 * mbit,
        jnp.exp2(e - 1.0) * (1.0 + 0.5 * mbit),
    )
    v = jnp.where(t_full.astype(bool), p, v_e2m1)
    return sign * v


def _expand_weight_tile(wp, ws, bk: int, bn: int):
    """Decode a packed weight tile: payload (bk//2, bn) + scales
    (bk//16, bn//16) -> bf16 (bk, bn) with scales fused (sans scale32)."""
    lo = wp & 0xF
    hi = (wp >> 4) & 0xF
    nib = jnp.stack([lo, hi], axis=1).reshape(bk, bn)
    s, t = _decode_scales(ws)
    # broadcast per-tile scale/type over the 16x16 tile extent
    s_full = jnp.broadcast_to(
        s[:, None, :, None], (bk // _G, _G, bn // _G, _G)).reshape(bk, bn)
    t_full = jnp.broadcast_to(
        t[:, None, :, None], (bk // _G, _G, bn // _G, _G)).reshape(bk, bn)
    vals = _decode_nibbles(nib, t_full)
    return (vals * s_full).astype(jnp.bfloat16)


def _expand_act_tile(xp, xs, bm: int, bk: int):
    """Decode packed activations: payload (bm, bk//2) + scales (bm, bk//16)
    -> bf16 (bm, bk) with 1-D block scales fused (sans scale32)."""
    lo = xp & 0xF
    hi = (xp >> 4) & 0xF
    nib = jnp.stack([lo, hi], axis=-1).reshape(bm, bk)
    s, t = _decode_scales(xs)
    s_full = jnp.broadcast_to(s[:, :, None], (bm, bk // _G, _G)).reshape(bm, bk)
    t_full = jnp.broadcast_to(t[:, :, None], (bm, bk // _G, _G)).reshape(bm, bk)
    vals = _decode_nibbles(nib, t_full)
    return (vals * s_full).astype(jnp.bfloat16)


def _quantize_act_tile(x: jax.Array, inv_s32: jax.Array, bm: int, bk: int):
    """Fused prologue: quantize a dense f32 x tile to MixFP4 rows in-VMEM
    (Alg. 1 dual-format select via the shared ``quant_block_kernel_math``)
    and emit the SAME bf16 values the packed decode path reconstructs —
    ``q`` is exactly decode(encode(q)) on both lattices and ``s8`` is
    already E4M3-valued, so ``(q * s8).astype(bf16)`` is bitwise what
    ``_expand_act_tile`` returns for the two-dispatch composition."""
    xs = (x.astype(jnp.float32) * inv_s32).reshape(bm, bk // _G, _G)
    q, s8, _t = quant_block_kernel_math(xs)
    vals = (q * s8[..., None]).reshape(bm, bk)
    return vals.astype(jnp.bfloat16)


# ---------------------------------------------------------------------------
# Shared double-buffered kernel body
# ---------------------------------------------------------------------------
def _stream_gemm_body(mode: str, nk: int, bm: int, bn: int, bk: int,
                      per_row: bool, group: int,
                      s32_ref, signs_hbm, x_refs, wp_hbm, ws_hbm, o_ref,
                      x_slabs, wp_slab, ws_slab, sg_slab, acc_ref, sem):
    """Grid cell (i, j): stream K slabs of the packed operands HBM->VMEM
    through two buffer slots, overlapping the next slab's DMA with the
    current slab's decode + MXU work; the f32 accumulator stays in VMEM
    scratch and is written to the output block once, after the K loop.

    ``per_row=True`` reads the scale operand as an (bm, w) row-tile slab
    instead of the (1, w) scalar row: column 0 carries the combined output
    scale (x_row * w per-tensor), column 1 (fused mode) the row's
    activation scale for the prologue, so every output row is scaled by a
    function of that row alone.  The scalar branch below is untouched —
    per-tensor callers keep their exact historical op sequence.

    ``signs_hbm`` (fused mode only) streams the RHT sign diagonal in the
    same K slabs as the activation and applies the grouped butterfly
    (``fwht_rows_math``) in VMEM ahead of the quantizer — the transform is
    group-local and ``bk % group == 0``, so slab-wise application equals
    the whole-row transform."""
    i = pl.program_id(0)
    j = pl.program_id(1)
    if per_row:
        s32 = s32_ref[...][:, 0:1]          # (bm, 1) combined row scales
    else:
        s32 = s32_ref[0, 0]

    def dmas(slot, kk):
        out = []
        if mode == "w4a4":
            xp_hbm, xs_hbm = x_refs
            xp_slab, xs_slab = x_slabs
            out.append(pltpu.make_async_copy(
                xp_hbm.at[pl.ds(i * bm, bm), pl.ds(kk * (bk // 2), bk // 2)],
                xp_slab.at[slot], sem.at[slot, 0]))
            out.append(pltpu.make_async_copy(
                xs_hbm.at[pl.ds(i * bm, bm), pl.ds(kk * (bk // _G), bk // _G)],
                xs_slab.at[slot], sem.at[slot, 1]))
        else:
            (x_hbm,) = x_refs
            (x_slab,) = x_slabs
            out.append(pltpu.make_async_copy(
                x_hbm.at[pl.ds(i * bm, bm), pl.ds(kk * bk, bk)],
                x_slab.at[slot], sem.at[slot, 0]))
            if signs_hbm is not None:
                # sem slot 1 is free in the dense-activation modes
                out.append(pltpu.make_async_copy(
                    signs_hbm.at[:, pl.ds(kk * bk, bk)],
                    sg_slab.at[slot], sem.at[slot, 1]))
        out.append(pltpu.make_async_copy(
            wp_hbm.at[pl.ds(kk * (bk // 2), bk // 2), pl.ds(j * bn, bn)],
            wp_slab.at[slot], sem.at[slot, 2]))
        out.append(pltpu.make_async_copy(
            ws_hbm.at[pl.ds(kk * (bk // _G), bk // _G),
                      pl.ds(j * (bn // _G), bn // _G)],
            ws_slab.at[slot], sem.at[slot, 3]))
        return out

    for dma in dmas(0, 0):
        dma.start()
    acc_ref[...] = jnp.zeros_like(acc_ref)

    if mode == "w4a4_fused":
        if per_row:
            inv_s32 = 1.0 / s32_ref[...][:, 1:2]   # (bm, 1) row scales
        else:
            inv_s32 = 1.0 / s32_ref[0, 1]   # x per-tensor scale (prologue)

    def body(kk, carry):
        cur = kk % 2
        nxt = (kk + 1) % 2

        @pl.when(kk + 1 < nk)
        def _prefetch():
            for dma in dmas(nxt, kk + 1):
                dma.start()

        for dma in dmas(cur, kk):
            dma.wait()

        if mode == "w4a16":
            x = x_slabs[0][cur].astype(jnp.bfloat16)
        elif mode == "w4a4":
            x = _expand_act_tile(x_slabs[0][cur], x_slabs[1][cur], bm, bk)
        else:
            xd = x_slabs[0][cur]
            if signs_hbm is not None:
                xd = fwht_rows_math(xd, sg_slab[cur], group)
            x = _quantize_act_tile(xd, inv_s32, bm, bk)
        w = _expand_weight_tile(wp_slab[cur], ws_slab[cur], bk, bn)
        acc = jax.lax.dot(x, w, preferred_element_type=jnp.float32)
        acc_ref[...] += acc * s32
        return carry

    jax.lax.fori_loop(0, nk, body, 0)
    o_ref[...] = acc_ref[...]


def _stream_gemm_call(mode: str, x_args: tuple, x_scratch: tuple,
                      s32: jax.Array, payload, scales,
                      m: int, n: int, k: int,
                      bm: int, bn: int, bk: int, interpret: bool,
                      per_row: bool = False,
                      signs: jax.Array | None = None, group: int = _G):
    assert m % bm == 0 and n % bn == 0 and k % bk == 0
    assert bk % _G == 0 and bn % _G == 0
    nk = k // bk
    grid = (m // bm, n // bn)
    any_spec = pl.BlockSpec(memory_space=pltpu.TPUMemorySpace.ANY)
    kernel = functools.partial(
        _split_refs_kernel, mode=mode, nk=nk, bm=bm, bn=bn, bk=bk,
        n_x=len(x_args), per_row=per_row, has_signs=signs is not None,
        group=group)
    if per_row:
        w = s32.shape[1]
        s32_spec = pl.BlockSpec((bm, w), lambda i, j: (i, 0))
    else:
        s32_spec = pl.BlockSpec(s32.shape, lambda i, j: (0, 0))
    in_specs = [s32_spec] + [any_spec] * (len(x_args) + 2)
    inputs = (s32, *x_args, payload, scales)
    scratch = [*x_scratch,
               pltpu.VMEM((2, bk // 2, bn), jnp.uint8),
               pltpu.VMEM((2, bk // _G, bn // _G), jnp.uint8)]
    if signs is not None:
        in_specs.append(any_spec)
        inputs = inputs + (signs,)
        scratch.append(pltpu.VMEM((2, 1, bk), jnp.float32))
    scratch += [pltpu.VMEM((bm, bn), jnp.float32),
                pltpu.SemaphoreType.DMA((2, 4))]
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=in_specs,
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        scratch_shapes=scratch,
        interpret=interpret,
    )(*inputs)


def _split_refs_kernel(s32_ref, *refs, mode: str, nk: int,
                       bm: int, bn: int, bk: int, n_x: int,
                       per_row: bool, has_signs: bool, group: int):
    x_refs = refs[:n_x]
    wp_hbm, ws_hbm = refs[n_x:n_x + 2]
    idx = n_x + 2
    signs_hbm = refs[idx] if has_signs else None
    idx += 1 if has_signs else 0
    o_ref = refs[idx]
    idx += 1
    x_slabs = refs[idx:idx + n_x]
    wp_slab, ws_slab = refs[idx + n_x:idx + n_x + 2]
    idx += n_x + 2
    sg_slab = refs[idx] if has_signs else None
    idx += 1 if has_signs else 0
    acc_ref, sem = refs[idx:idx + 2]
    _stream_gemm_body(mode, nk, bm, bn, bk, per_row, group, s32_ref,
                      signs_hbm, x_refs, wp_hbm, ws_hbm, o_ref, x_slabs,
                      wp_slab, ws_slab, sg_slab, acc_ref, sem)


# ---------------------------------------------------------------------------
# W4A16
# ---------------------------------------------------------------------------
@functools.partial(
    jax.jit, static_argnames=("bm", "bn", "bk", "interpret"))
def mixfp4_gemm_w4a16(
    x: jax.Array,
    payload: jax.Array,
    scales: jax.Array,
    scale32: jax.Array,
    *,
    bm: int = 128,
    bn: int = 256,
    bk: int = 256,
    interpret: bool = False,
) -> jax.Array:
    """y = x @ dequant(packed W); x (M, K) bf16/f32, returns (M, N) f32."""
    m, k = x.shape
    n = payload.shape[1]
    assert payload.shape == (k // 2, n) and scales.shape == (k // _G, n // _G)
    bm = min(bm, m)
    bn = min(bn, n)
    bk = min(bk, k)
    s32 = scale32.reshape(1, 1).astype(jnp.float32)
    xb = x.astype(jnp.bfloat16)     # same single rne rounding as in-kernel
    return _stream_gemm_call(
        "w4a16", (xb,), (pltpu.VMEM((2, bm, bk), jnp.bfloat16),),
        s32, payload, scales, m, n, k, bm, bn, bk, interpret)


# ---------------------------------------------------------------------------
# W4A4 (packed activations: the two-dispatch composition's GEMM half)
# ---------------------------------------------------------------------------
@functools.partial(
    jax.jit, static_argnames=("bm", "bn", "bk", "interpret", "per_row"))
def mixfp4_gemm_w4a4(
    x_payload: jax.Array,
    x_scales: jax.Array,
    x_scale32: jax.Array,
    payload: jax.Array,
    scales: jax.Array,
    scale32: jax.Array,
    *,
    bm: int = 128,
    bn: int = 256,
    bk: int = 256,
    interpret: bool = False,
    per_row: bool = False,
) -> jax.Array:
    """y = dequant(packed X) @ dequant(packed W), f32 out.

    ``per_row=True`` reads ``x_scale32`` as an (M,) row-scale vector (the
    ``quantize_rows(per_row=True)`` contract): the combined output scale
    becomes an (M, 1) operand tiled with the row grid, so each output row
    is a pure function of its own activation row.
    """
    m = x_payload.shape[0]
    k = x_payload.shape[1] * 2
    n = payload.shape[1]
    assert payload.shape == (k // 2, n) and scales.shape == (k // _G, n // _G)
    assert x_scales.shape == (m, k // _G)
    bm = min(bm, m)
    bn = min(bn, n)
    bk = min(bk, k)
    if per_row:
        xs32 = jnp.broadcast_to(
            jnp.asarray(x_scale32, jnp.float32).reshape(-1), (m,))
        s32 = (xs32 * scale32.astype(jnp.float32)).reshape(m, 1)
    else:
        s32 = (x_scale32.astype(jnp.float32)
               * scale32.astype(jnp.float32)).reshape(1, 1)
    return _stream_gemm_call(
        "w4a4", (x_payload, x_scales),
        (pltpu.VMEM((2, bm, bk // 2), jnp.uint8),
         pltpu.VMEM((2, bm, bk // _G), jnp.uint8)),
        s32, payload, scales, m, n, k, bm, bn, bk, interpret,
        per_row=per_row)


# ---------------------------------------------------------------------------
# W4A4 with fused quantize prologue (one dispatch per projection)
# ---------------------------------------------------------------------------
@functools.partial(
    jax.jit,
    static_argnames=("bm", "bn", "bk", "interpret", "per_row", "rht_group"))
def mixfp4_gemm_w4a4_fused(
    x: jax.Array,
    x_scale32: jax.Array,
    payload: jax.Array,
    scales: jax.Array,
    scale32: jax.Array,
    *,
    bm: int = 128,
    bn: int = 256,
    bk: int = 256,
    interpret: bool = False,
    per_row: bool = False,
    rht_signs: jax.Array | None = None,
    rht_group: int = _G,
) -> jax.Array:
    """y = dequant(quant(X)) @ dequant(packed W), f32 out — the W4A4 MMA
    with the activation row quantizer fused into the kernel prologue.

    ``x`` is the DENSE (M, K) activation, already zero-padded onto the
    weight's packed K grid (the ``qmm`` dispatcher does this); it is
    quantized tile-by-tile in VMEM under the pinned per-tensor scale
    ``x_scale32`` — which the caller derives exactly as ``quantize_rows``
    would (max|x| / 2688), or pins (KV-cache style) — and the result is
    bitwise-identical to ``quantize_rows(x) -> mixfp4_gemm_w4a4`` run on
    the same (bm, bn, bk) grid.  Zero-padded rows/lanes quantize to zero
    codes and contribute the same exact-zero terms as the composition's
    padded bytes.

    The f32 cast happens HERE, outside the kernel, on purpose: streaming
    bf16 slabs and converting in the prologue is mathematically exact but
    puts a convert inside the kernel body, and XLA's differing fusion of
    that body (vs the standalone quantizer's, which sees f32) can flip
    the dual-format ``err1 < err2`` select at near-ties — observed as a
    non-bitwise MoE stream under ``lax.scan``/``lax.map``.  Halving the
    activation slab traffic is a TPU-side follow-on that needs the select
    pinned first.

    ``per_row=True`` reads ``x_scale32`` as an (M,) row-scale vector — the
    prologue quantizes row i under scale32[i] and the output row is scaled
    by ``scale32[i] * w_scale32``, making it a pure function of activation
    row i (the serve-time batch-independence contract).

    ``rht_signs`` (with ``per_row``) fuses the grouped random Hadamard
    transform (``core.hadamard.rht`` semantics, shared ``fwht_rows_math``
    butterfly) ahead of the quantizer in the same VMEM pass: signs stream
    in the activation's K slabs, the transform is group-local and
    ``bk % rht_group == 0``, so the result is bitwise what
    ``fwht_rows -> quantize_rows(per_row=True) -> mixfp4_gemm_w4a4`` would
    compute on the same grid.  The caller derives the per-row scale from
    the TRANSFORMED rows (it is the transformed values being quantized)
    and must have applied the same ``D``/``H`` to the packed weight's K
    axis at pack time for the transform to cancel in the dot product.
    """
    m, k = x.shape
    n = payload.shape[1]
    assert payload.shape == (k // 2, n) and scales.shape == (k // _G, n // _G)
    bm = min(bm, m)
    bn = min(bn, n)
    bk = min(bk, k)
    signs = None
    if rht_signs is not None:
        if rht_group <= 0 or rht_group & (rht_group - 1):
            raise ValueError(
                f"rht_group must be a power of two, got {rht_group}")
        if bk % rht_group or k % rht_group:
            raise ValueError(
                f"rht_group={rht_group} must divide bk={bk} and K={k} so "
                f"K-slab boundaries align with transform groups")
        if rht_signs.shape != (k,):
            raise ValueError(
                f"rht_signs must have shape ({k},), got {rht_signs.shape}")
        signs = rht_signs.astype(jnp.float32).reshape(1, k)
    if per_row:
        xs32 = jnp.broadcast_to(
            jnp.asarray(x_scale32, jnp.float32).reshape(-1), (m,))
        # (M, 2): [combined output scale, row scale for the prologue]
        s32 = jnp.stack(
            [xs32 * scale32.astype(jnp.float32).reshape(()), xs32], axis=1)
    else:
        xs32 = jnp.asarray(x_scale32, jnp.float32).reshape(())
        # (1, 2): [combined output scale, x per-tensor scale (prologue)]
        s32 = jnp.stack([xs32 * scale32.astype(jnp.float32).reshape(()),
                         xs32]).reshape(1, 2)
    return _stream_gemm_call(
        "w4a4_fused", (x.astype(jnp.float32),),
        (pltpu.VMEM((2, bm, bk), jnp.float32),),
        s32, payload, scales, m, n, k, bm, bn, bk, interpret,
        per_row=per_row, signs=signs, group=rht_group)
