"""Cost-model kernel autotuner for the packed Pallas hot path.

Replaces the divisor-only tile rule the GEMM dispatcher used through PR 4
(``largest tile <= cap that divides the padded dim``), which collapses to
16-wide tiles the moment a padded dimension has no large divisor — e.g.
``Np = 272 = 17 * 16`` served every projection with ``bn = 16`` grid tiles,
two orders of magnitude more grid cells than the hardware wants.  This is
exactly the "promise vs. performance" gap of naive FP4 tiling: the kernel
is bandwidth-bound, and tiny tiles multiply both the per-cell launch
overhead and the number of times the activation panel is re-streamed.

The tuner scores ``(bm, bn, bk)`` candidates with an arithmetic-intensity /
VMEM-footprint model of the double-buffered GEMM in
``kernels/mixfp4_gemm.py`` and returns a :class:`TileChoice` that also
carries the padded problem dims — K and N are padded *up* to tile multiples
(the dispatcher zero-pads the packed operands; zero payload/scale bytes
decode to exact zeros) the same way M already was, so no dimension ever
degrades to 16-wide tiles.

Contracts the selection upholds (tested in ``tests/test_tuning.py``):

* every choice's :func:`vmem_footprint` fits :data:`VMEM_BUDGET`,
* a padded dim >= 64 never gets a tile below 64 lanes (``MIN_WIDE``),
* ``bk`` is chosen independently of N, so a column-parallel shard of a
  weight keeps the single-device K tiling — the bitwise-identity contract
  of ``qmm_sharded`` (docs/sharding.md) survives autotuning,
* activation rows round up a fixed ``bm`` ladder (:func:`round_up_rows`),
  so continuous-batching batch-size wobble (m = 3, 4, 5, ...) lands on one
  padded M and reuses one compiled kernel instead of re-jitting per m.

Choices are cached per ``(path, padded shape)`` in a process-level table;
:func:`save_profile` / :func:`load_profile` persist it as JSON (auto-loaded
from ``$MIXFP4_TUNING_PROFILE`` on first use), so a serving process can pin
the exact tiling a profiling run validated.

This module is pure Python on purpose (no jax import): it is consulted at
trace time from ``core/qtensor.py`` and ``kernels/mixfp4_attn.py`` and must
never add dispatch-path work or import cycles.
"""
from __future__ import annotations

import dataclasses
import json
import os
import threading

__all__ = [
    "TileChoice",
    "select_tiles",
    "select_attn_key_block",
    "round_up_rows",
    "divisor_tile",
    "vmem_footprint",
    "attn_vmem_footprint",
    "VMEM_BUDGET",
    "MIN_WIDE",
    "BM_LADDER",
    "clear_cache",
    "cache_info",
    "save_profile",
    "load_profile",
    "PROFILE_ENV",
]

_G = 16          # paper block size g (scale granularity)

# ---------------------------------------------------------------------------
# Hardware model (v5e-class).  Absolute numbers only matter relative to each
# other — the tuner ranks candidates, it does not predict wall time.
# ---------------------------------------------------------------------------
PEAK_FLOPS = 1.97e14     # bf16 MXU FLOP/s
HBM_BW = 8.1e11          # HBM bytes/s
VPU_OPS = 2.0e13         # elementwise op/s (Fig. 9 decode + quant prologue)
VMEM_BYTES = 16 * 2 ** 20
VMEM_BUDGET = int(VMEM_BYTES * 0.70)   # leave headroom for Mosaic spills
DMA_SETUP_S = 1.0e-6     # per-transfer latency: favors fat slabs
GRID_CELL_S = 1.5e-6     # per grid cell launch/bookkeeping overhead

MIN_WIDE = 64            # padded dims never collapse below 64 lanes
BM_LADDER = (8, 16, 32, 64, 128)
_BN_CHOICES = (16, 32, 64, 128, 256, 512)
_BK_CHOICES = (16, 32, 64, 128, 256, 512)
_SINGLE_TILE_CAP = 512   # whole-dim single tile allowed up to this width

# VPU op counts per value (coarse: selects/shifts/multiplies per element)
_DECODE_OPS = 12.0       # Fig. 9 dual-codebook decode
_QUANT_OPS = 40.0        # fused prologue: dual-candidate quantize + argmin

_PATHS = ("w4a16", "w4a4", "w4a4_fused")
PROFILE_ENV = "MIXFP4_TUNING_PROFILE"


def _pad(d: int, t: int) -> int:
    return -(-d // t) * t


def round_up_rows(m: int, cap: int = 128) -> int:
    """Activation-row tile from the fixed ladder: the smallest ladder entry
    >= m (``cap`` for larger m).  Rounding m up this ladder inside the
    dispatcher is what stops decode-batch wobble re-jitting the kernel per
    distinct small m."""
    for b in BM_LADDER:
        if m <= b:
            return min(b, cap)
    return cap


def divisor_tile(dim: int, cap: int, mult: int = _G) -> int:
    """The historical PR-1 rule (largest divisor <= cap), kept verbatim for
    the tuner A/B benchmark: this is what collapses prime-ish dims to
    ``mult``-wide tiles."""
    t = min(cap, dim)
    t -= t % mult
    while t > mult and dim % t:
        t -= mult
    return max(t, mult) if dim % mult == 0 else 1


@dataclasses.dataclass(frozen=True)
class TileChoice:
    """A tuned GEMM tiling plus the padded problem it runs on."""

    bm: int
    bn: int
    bk: int
    m_pad: int
    k_pad: int
    n_pad: int

    def astuple(self) -> tuple:
        return dataclasses.astuple(self)


# ---------------------------------------------------------------------------
# VMEM footprint of one grid cell of the double-buffered kernel
# ---------------------------------------------------------------------------
def _x_slab_bytes(path: str, bm: int, bk: int) -> int:
    if path == "w4a16":
        return bm * bk * 2                      # bf16 rows
    if path == "w4a4":
        return bm * (bk // 2 + bk // _G)        # packed payload + scales
    return bm * bk * 4                          # fused: f32 rows


def _w_slab_bytes(bk: int, bn: int) -> int:
    return bk * bn // 2 + (bk // _G) * max(bn // _G, 1)


def vmem_footprint(path: str, bm: int, bn: int, bk: int) -> int:
    """Live VMEM model for one grid cell of the streamed GEMM: two slots per
    double-buffered operand, the decoded bf16 x/w tiles, the f32
    accumulator, the (pipeline double-buffered) output block, and — on the
    fused path — the quantizer's candidate working set (~3 extra f32 copies
    of the x tile, mirroring ``mixfp4_quant._pick_bm``'s budget rule)."""
    x = 2 * _x_slab_bytes(path, bm, bk)
    w = 2 * _w_slab_bytes(bk, bn)
    decoded = bk * bn * 2 + bm * bk * 2
    acc = bm * bn * 4
    out = 2 * bm * bn * 4
    quant = 3 * bm * bk * 4 if path == "w4a4_fused" else 0
    return x + w + decoded + acc + out + quant


# ---------------------------------------------------------------------------
# Cost model
# ---------------------------------------------------------------------------
def _x_value_bytes(path: str) -> float:
    if path == "w4a16":
        return 2.0
    if path == "w4a4":
        return 0.5 + 1.0 / _G
    return 4.0


def _n_dmas(path: str) -> int:
    # transfers per K step: x slab(s) + weight payload + weight scales
    return 4 if path == "w4a4" else 3


def _cell_time(path: str, m: int, kp: int, np_: int,
               bm: int, bn: int, bk: int) -> float:
    """Predicted time of the whole GEMM under (bm, bn, bk): max of the
    compute, HBM-traffic and VPU (decode/quant) roofs, plus grid-cell and
    DMA-setup overheads.  Padding waste enters through the padded dims;
    re-padding a weight operand that does not already sit on the tile grid
    costs one extra packed copy (read + write)."""
    mp, kpp, npp = _pad(m, bm), _pad(kp, bk), _pad(np_, bn)
    gm, gn, nk = mp // bm, npp // bn, kpp // bk

    flops = 2.0 * mp * kpp * npp
    w_bytes = kpp * npp / 2 + (kpp // _G) * (npp // _G)
    x_traffic = mp * kpp * _x_value_bytes(path) * gn   # x re-streamed per j
    w_traffic = w_bytes * gm                           # w re-streamed per i
    out_traffic = mp * npp * 4.0
    pad_copy = 2.0 * w_bytes if (kpp != kp or npp != np_) else 0.0
    traffic = x_traffic + w_traffic + out_traffic + pad_copy

    decode = _DECODE_OPS * kpp * npp * gm          # weight decode per revisit
    if path == "w4a4":
        decode += _DECODE_OPS * mp * kpp * gn      # packed-x decode per j
    elif path == "w4a4_fused":
        decode += _QUANT_OPS * mp * kpp * gn       # in-kernel quant per j

    t = max(flops / PEAK_FLOPS, traffic / HBM_BW, decode / VPU_OPS)
    t += gm * gn * GRID_CELL_S
    t += gm * gn * nk * _n_dmas(path) * DMA_SETUP_S
    return t


def _tile_candidates(dim: int, choices: tuple) -> list:
    """Tile widths for a (16-aligned) padded dim: below ``MIN_WIDE`` the
    single exact tile; otherwise the >= MIN_WIDE ladder entries plus the
    whole dim as a single tile when it is not absurdly wide (kills padding
    waste for e.g. 272 = 17*16)."""
    if dim < MIN_WIDE:
        return [dim]
    cands = [c for c in choices if MIN_WIDE <= c <= max(dim, MIN_WIDE)]
    if dim <= _SINGLE_TILE_CAP and dim not in cands:
        cands.append(dim)
    return cands or [dim]


def _select_bk(path: str, m: int, kp: int, bm: int) -> int:
    """K tile, scored against a NOMINAL N so the choice is independent of
    the real N — a column-parallel shard must keep the single-device K
    tiling for the ``qmm_sharded`` bitwise contract."""
    n_nom, bn_nom = 256, 128
    # the fused kernel and the packed composition share this choice, so
    # feasibility uses the larger (fused: f32 slab + quant workspace)
    # footprint of the two
    feas = "w4a4_fused" if path == "w4a4" else path
    best, best_t = None, None
    for bk in _tile_candidates(kp, _BK_CHOICES):
        if vmem_footprint(feas, bm, MIN_WIDE, bk) > VMEM_BUDGET:
            continue
        t = _cell_time(path, m, kp, n_nom, bm, bn_nom, bk)
        if best_t is None or t < best_t - 1e-12 or \
                (abs(t - best_t) <= 1e-12 and bk > best):
            best, best_t = bk, t
    return best if best is not None else _G


def _select_bn(path: str, m: int, kp: int, np_: int, bm: int, bk: int) -> int:
    feas = "w4a4_fused" if path == "w4a4" else path
    best, best_t = None, None
    for bn in _tile_candidates(np_, _BN_CHOICES):
        if vmem_footprint(feas, bm, bn, bk) > VMEM_BUDGET:
            continue
        t = _cell_time(path, m, kp, np_, bm, bn, bk)
        if best_t is None or t < best_t - 1e-12 or \
                (abs(t - best_t) <= 1e-12 and bn > best):
            best, best_t = bn, t
    return best if best is not None else min(np_, _G)


# ---------------------------------------------------------------------------
# Process-level cache + optional on-disk profile
# ---------------------------------------------------------------------------
_LOCK = threading.Lock()
_CACHE: dict = {}
_STATS = {"hits": 0, "misses": 0}
_PROFILE_CHECKED = False


def _maybe_autoload():
    global _PROFILE_CHECKED
    if _PROFILE_CHECKED:
        return
    _PROFILE_CHECKED = True
    path = os.environ.get(PROFILE_ENV)
    if path and os.path.exists(path):
        load_profile(path)


def clear_cache():
    global _PROFILE_CHECKED
    with _LOCK:
        _CACHE.clear()
        _STATS["hits"] = _STATS["misses"] = 0
        _PROFILE_CHECKED = True  # an explicit clear opts out of autoload


def cache_info() -> dict:
    with _LOCK:
        return {"entries": len(_CACHE), **_STATS}


def save_profile(path: str | None = None):
    """Persist the tuned choices as JSON (``key -> TileChoice tuple``)."""
    path = path or os.environ.get(PROFILE_ENV)
    if not path:
        raise ValueError(f"save_profile needs a path (or ${PROFILE_ENV})")
    with _LOCK:
        blob = {"|".join(map(str, k)): list(v.astuple() if
                                            isinstance(v, TileChoice)
                                            else (v,))
                for k, v in _CACHE.items()}
    with open(path, "w") as f:
        json.dump(blob, f, indent=1, sort_keys=True)


def load_profile(path: str | None = None):
    """Load a saved profile into the process cache (entries win over fresh
    scoring: a profiled deployment pins its validated tiling)."""
    path = path or os.environ.get(PROFILE_ENV)
    if not path:
        raise ValueError(f"load_profile needs a path (or ${PROFILE_ENV})")
    with open(path) as f:
        blob = json.load(f)
    with _LOCK:
        for key_s, vals in blob.items():
            parts = key_s.split("|")
            key = tuple(int(p) if p.lstrip("-").isdigit() else p
                        for p in parts)
            _CACHE[key] = (TileChoice(*vals) if len(vals) == 6
                           else int(vals[0]))


# ---------------------------------------------------------------------------
# Public selection entry points
# ---------------------------------------------------------------------------
def select_tiles(path: str, m: int, kp: int, np_: int) -> TileChoice:
    """Tiles + padded dims for a GEMM of ``m`` activation rows against a
    packed ``(kp, np_)`` weight grid (both already 16-aligned).

    ``path`` is one of ``"w4a16"`` (dense rows), ``"w4a4"`` (packed rows)
    or ``"w4a4_fused"`` (dense rows quantized in the kernel prologue) —
    the two W4A4 spellings share one cache entry so the fused kernel and
    the two-dispatch composition always run the SAME grid, which is what
    makes them bitwise-comparable."""
    if path not in _PATHS:
        raise ValueError(f"unknown path {path!r} (expected one of {_PATHS})")
    if kp % _G or np_ % _G:
        raise ValueError(f"select_tiles expects 16-aligned packed dims, "
                         f"got K={kp} N={np_}")
    group = "w4a4" if path.startswith("w4a4") else "w4a16"
    bm = round_up_rows(m)
    mp = _pad(m, bm)
    key = (group, mp, kp, np_)
    _maybe_autoload()
    with _LOCK:
        hit = _CACHE.get(key)
        if hit is not None:
            _STATS["hits"] += 1
            return hit
    bk = _select_bk(group, mp, kp, bm)
    bn = _select_bn(group, mp, kp, np_, bm, bk)
    ch = TileChoice(bm, bn, bk, mp, _pad(kp, bk), _pad(np_, bn))
    with _LOCK:
        _STATS["misses"] += 1
        _CACHE[key] = ch
    return ch


_ATTN_BS_CHOICES = (16, 32, 64, 128, 256, 512)


def attn_vmem_footprint(bs: int, hkv: int, dh: int) -> int:
    """VMEM model for one key block of the packed decode-attention kernel:
    double-buffered packed K and V slabs (payload + scale bytes) plus the
    decoded f32 blocks and flash state."""
    packed = bs * hkv * (dh // 2 + dh // _G)
    decoded = bs * hkv * dh * 4
    return 2 * 2 * packed + 2 * decoded + 4 * hkv * dh * 4


def select_attn_key_block(s: int, hkv: int, dh: int) -> int:
    """Key-block rows per flash-decoding step of ``mixfp4_attn``: the
    largest block that fits the VMEM model and doesn't waste more in S
    padding than it saves in per-block overhead."""
    s = max(int(s), 1)
    key = ("attn", s, hkv, dh)
    _maybe_autoload()
    with _LOCK:
        hit = _CACHE.get(key)
        if hit is not None:
            _STATS["hits"] += 1
            return hit
    best, best_t = _G, None
    bytes_per_row = hkv * (dh // 2 + dh // _G) * 2     # packed K + V
    for bs in _ATTN_BS_CHOICES:
        if attn_vmem_footprint(bs, hkv, dh) > VMEM_BUDGET:
            continue
        sp = _pad(s, bs)
        t = sp * bytes_per_row / HBM_BW \
            + (sp // bs) * GRID_CELL_S \
            + _DECODE_OPS * 2 * sp * hkv * dh / VPU_OPS
        if best_t is None or t < best_t - 1e-15 or \
                (abs(t - best_t) <= 1e-15 and bs > best):
            best, best_t = bs, t
    with _LOCK:
        _STATS["misses"] += 1
        _CACHE[key] = best
    return best
