"""Pure-jnp oracles for every Pallas kernel in this package.

Each kernel test sweeps shapes/dtypes and asserts allclose (or bit-equality
for packed outputs) against these references, which are built from the
``repro.core`` numerics already validated against the paper.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import formats, hadamard, pack, quantize as Q, scaling

__all__ = [
    "ref_quant_pack_rows",
    "ref_pack_weight_kn",
    "ref_dequant_weight_kn",
    "ref_gemm_w4a16",
    "ref_gemm_w4a4",
    "ref_fwht_rows",
]


def ref_quant_pack_rows(x: jax.Array, method: str = "mixfp4", block: int = 16):
    """Quantize (M, K) row-major with 1-D blocks along K and pack.

    Returns (payload (M, K//2) uint8, scales (M, K//block) uint8, scale32 f32).
    """
    assert x.ndim == 2 and x.shape[1] % block == 0
    bq, _, _ = Q.block_quantize_1d(x, method, block=block, axis=-1)
    p = pack.pack_blocks(bq)
    m, k = x.shape
    payload = p.payload.reshape(m, k // 2)
    return payload, p.scales, p.scale32


def ref_pack_weight_kn(w: jax.Array, method: str = "mixfp4",
                       block: tuple[int, int] = (16, 16)):
    """Quantize a (K, N) weight with 2-D tiles and lay the payload out packed
    along K (two K-consecutive nibbles per byte), matching the GEMM kernel's
    operand layout.

    Returns (payload (K//2, N) uint8, scales (K//bm, N//bn) uint8, scale32).
    """
    k, n = w.shape
    bm, bn = block
    assert k % bm == 0 and n % bn == 0 and k % 2 == 0
    bq, shape, blk = Q.block_quantize_2d(w, method, block=block)
    # values back on the (K, N) grid
    vals = Q._from_blocks_2d(bq.values, shape, bm, bn)
    # type/scale per tile on the (K//bm, N//bn) grid
    t_grid = bq.type_bits
    nib_e2m1 = formats.e2m1_encode(vals)
    nib_e1m2 = formats.e1m2_encode(vals)
    t_full = jnp.repeat(jnp.repeat(t_grid, bm, axis=0), bn, axis=1)
    nib = jnp.where(t_full.astype(bool), nib_e1m2, nib_e2m1)
    payload = (nib[0::2, :] | (nib[1::2, :] << 4)).astype(jnp.uint8)
    scales = scaling.pack_scale_with_type(bq.scale8, t_grid)
    return payload, scales, bq.scale32


def ref_dequant_weight_kn(payload, scales, scale32,
                          block: tuple[int, int] = (16, 16)) -> jax.Array:
    """Decode the (K//2, N) packed weight back to f32 (Fig. 9 decode)."""
    bm, bn = block
    lo = payload & 0xF
    hi = (payload >> 4) & 0xF
    k2, n = payload.shape
    nib = jnp.stack([lo, hi], axis=1).reshape(2 * k2, n)
    s8, t = scaling.unpack_scale_and_type(scales)
    t_full = jnp.repeat(jnp.repeat(t, bm, axis=0), bn, axis=1)
    s_full = jnp.repeat(jnp.repeat(s8, bm, axis=0), bn, axis=1)
    vals = formats.decode_to_e2m2(nib, t_full)
    return vals * s_full * scale32


def ref_gemm_w4a16(x, payload, scales, scale32,
                   block: tuple[int, int] = (16, 16)) -> jax.Array:
    """W4A16 GEMM oracle: bf16 activations x packed MixFP4 weight -> f32."""
    w = ref_dequant_weight_kn(payload, scales, scale32, block)
    return jax.lax.dot(x.astype(jnp.bfloat16),
                       w.astype(jnp.bfloat16),
                       preferred_element_type=jnp.float32)


def ref_gemm_w4a4(xp, xs, xs32, payload, scales, scale32,
                  block: tuple[int, int] = (16, 16),
                  act_block: int = 16) -> jax.Array:
    """W4A4 GEMM oracle: packed activations (rows) x packed weight."""
    m = xp.shape[0]
    k = xp.shape[1] * 2
    lo = xp & 0xF
    hi = (xp >> 4) & 0xF
    nib = jnp.stack([lo, hi], axis=-1).reshape(m, k)
    s8, t = scaling.unpack_scale_and_type(xs)
    vals = formats.decode_to_e2m2(nib, jnp.repeat(t, act_block, axis=1))
    x = vals * jnp.repeat(s8, act_block, axis=1) * xs32
    return ref_gemm_w4a16(x, payload, scales, scale32, block)


def ref_fwht_rows(x: jax.Array, signs: jax.Array, group: int = 16) -> jax.Array:
    """Grouped RHT along the last axis (rows independent)."""
    return hadamard.rht(x, signs, axis=-1, group=group)
