"""Pure-jnp oracles for every Pallas kernel in this package.

Each kernel test sweeps shapes/dtypes and asserts allclose (or bit-equality
for packed outputs) against these references, which are built from the
``repro.core`` numerics already validated against the paper.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import hadamard, qtensor
from repro.core.qtensor import BlockLayout1D, BlockLayout2D, QuantSpec

__all__ = [
    "ref_quant_pack_rows",
    "ref_pack_weight_kn",
    "ref_dequant_weight_kn",
    "ref_dequant_kv",
    "ref_gemm_w4a16",
    "ref_gemm_w4a4",
    "ref_attn_decode_packed",
    "ref_fwht_rows",
]


def ref_quant_pack_rows(x: jax.Array, method: str = "mixfp4", block: int = 16):
    """Quantize (M, K) row-major with 1-D blocks along K and pack.

    Thin shim over :func:`repro.core.qtensor.quantize` kept for the kernel
    tests' positional-triple interface.
    Returns (payload (M, K//2) uint8, scales (M, K//block) uint8, scale32 f32).
    """
    assert x.ndim == 2 and x.shape[1] % block == 0
    qt = qtensor.quantize(x, QuantSpec(method, BlockLayout1D(-1, block)))
    return qt.payload, qt.scales, qt.scale32


def ref_pack_weight_kn(w: jax.Array, method: str = "mixfp4",
                       block: tuple[int, int] = (16, 16)):
    """Quantize a (K, N) weight with 2-D tiles and lay the payload out packed
    along K (two K-consecutive nibbles per byte), matching the GEMM kernel's
    operand layout.  Thin shim over :func:`repro.core.qtensor.quantize`.

    Returns (payload (K//2, N) uint8, scales (K//bm, N//bn) uint8, scale32).
    """
    k, n = w.shape
    bm, bn = block
    assert k % bm == 0 and n % bn == 0 and k % 2 == 0
    qt = qtensor.quantize(w, QuantSpec(method, BlockLayout2D(bm, bn)))
    return qt.payload, qt.scales, qt.scale32


def ref_dequant_weight_kn(payload, scales, scale32,
                          block: tuple[int, int] = (16, 16)) -> jax.Array:
    """Decode the (K//2, N) packed weight back to f32 (Fig. 9 decode)."""
    qt = qtensor.QTensor(
        payload, scales, scale32, method="mixfp4",
        layout=BlockLayout2D(*block),
        shape=(payload.shape[0] * 2, payload.shape[1]), dtype="float32")
    return qt.dequantize()


def ref_gemm_w4a16(x, payload, scales, scale32,
                   block: tuple[int, int] = (16, 16)) -> jax.Array:
    """W4A16 GEMM oracle: bf16 activations x packed MixFP4 weight -> f32."""
    w = ref_dequant_weight_kn(payload, scales, scale32, block)
    return jax.lax.dot(x.astype(jnp.bfloat16),
                       w.astype(jnp.bfloat16),
                       preferred_element_type=jnp.float32)


def ref_gemm_w4a4(xp, xs, xs32, payload, scales, scale32,
                  block: tuple[int, int] = (16, 16),
                  act_block: int = 16) -> jax.Array:
    """W4A4 GEMM oracle: packed activations (rows) x packed weight."""
    m = xp.shape[0]
    k = xp.shape[1] * 2
    qx = qtensor.QTensor(xp, xs, xs32, method="mixfp4",
                         layout=BlockLayout1D(-1, act_block),
                         shape=(m, k), dtype="float32")
    return ref_gemm_w4a16(qx.dequantize(), payload, scales, scale32, block)


def ref_dequant_kv(payload: jax.Array, scales: jax.Array,
                   scale32=1.0) -> jax.Array:
    """Decode packed KV rows (..., dh//2 payload + dh//16 scale bytes, 1-D
    g=16 blocks along the head dim) back to f32 (..., dh)."""
    return qtensor.from_packed_rows(payload, scales, scale32).dequantize()


def ref_attn_decode_packed(
    q: jax.Array,
    k_payload: jax.Array,
    k_scales: jax.Array,
    v_payload: jax.Array,
    v_scales: jax.Array,
    lengths: jax.Array,
    *,
    window: jax.Array | int = 0,
    k_scale32=1.0,
    v_scale32=1.0,
    softcap: float = 0.0,
    block_tables: jax.Array | None = None,
) -> jax.Array:
    """Decode-attention oracle: dequantize the packed cache and run the
    masked softmax.V in plain f32 jnp (mirrors ``models.base.attention``
    decode semantics: the query sits at position ``lengths - 1``).

    q (B, H, dh); packed K/V (B, S, Hkv, ...); lengths () or (B,) int32.
    With ``block_tables`` (B, max_pages) int32 the K/V children are paged
    pool slabs (P, page_len, Hkv, ...) and the oracle first gathers each
    sequence's pages into the logical (B, max_pages*page_len, Hkv, ...)
    view — the reference semantics for ``ops.attn_decode_paged``.
    Returns (B, H, dh) f32.
    """
    if block_tables is not None:
        def _gather(a):
            g = a[block_tables]          # (B, max_pages, page_len, Hkv, x)
            return g.reshape(g.shape[0], -1, *g.shape[3:])
        k_payload, k_scales = _gather(k_payload), _gather(k_scales)
        v_payload, v_scales = _gather(v_payload), _gather(v_scales)
    b, h, dh = q.shape
    s, hkv = k_payload.shape[1:3]
    g = h // hkv
    k = ref_dequant_kv(k_payload, k_scales, k_scale32)      # (B,S,Hkv,dh)
    v = ref_dequant_kv(v_payload, v_scales, v_scale32)
    qr = q.astype(jnp.float32).reshape(b, hkv, g, dh)
    scores = jnp.einsum("bkgd,bskd->bkgs", qr, k) * (dh ** -0.5)
    if softcap:
        scores = softcap * jnp.tanh(scores / softcap)
    kv_len = jnp.broadcast_to(jnp.asarray(lengths, jnp.int32), (b,))
    win = jnp.asarray(window, jnp.int32)
    kpos = jnp.arange(s)
    mask = kpos[None, :] < kv_len[:, None]
    mask &= jnp.where(win > 0,
                      kpos[None, :] > (kv_len - 1 - win)[:, None], True)
    scores = jnp.where(mask[:, None, None], scores, -1e30)
    p = jax.nn.softmax(scores, axis=-1)
    o = jnp.einsum("bkgs,bskd->bkgd", p, v)
    return o.reshape(b, h, dh)


def ref_fwht_rows(x: jax.Array, signs: jax.Array, group: int = 16) -> jax.Array:
    """Grouped RHT along the last axis (rows independent)."""
    return hadamard.rht(x, signs, axis=-1, group=group)
