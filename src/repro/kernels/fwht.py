"""Pallas TPU kernel: grouped random Hadamard transform (RHT hot path).

The WGRAD RHT (Fig. 7) touches both GEMM inputs every backward pass; fusing
sign-flip + the log2(g) butterfly stages into one VMEM pass avoids g
intermediate HBM round-trips.  Groups (default 16, the quantization block)
transform independently, so the kernel tiles rows and keeps the full feature
extent resident.

``fwht_rows_math`` is the shared sign-flip + butterfly body: the standalone
kernel, the fused W4A4 GEMM prologue (``mixfp4_gemm_w4a4_fused(rht_signs=)``)
and the serve-time per-row scale derivation in ``core.qtensor`` all call it,
so the transformed values — and therefore the dual-format select and the
row amax — cannot drift between the fused and composed paths.  Every op in
it is an elementwise f32 add/sub/multiply (no reductions, no FMA
contraction), so in-kernel and plain-jnp evaluations are bit-identical.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["fwht_rows", "fwht_rows_math"]


def fwht_rows_math(x: jax.Array, signs: jax.Array, group: int) -> jax.Array:
    """Sign flip + grouped FWHT butterfly on f32 rows: x (bm, k), signs
    broadcastable to (1, k).  Mirrors ``core.hadamard.rht`` stage for stage
    (same adds/subs, same ``group ** -0.5`` normalization)."""
    bm, k = x.shape
    x = x * signs.reshape(1, k)
    x = x.reshape(bm, k // group, group)
    h = 1
    while h < group:
        x = x.reshape(bm, k // group, group // (2 * h), 2, h)
        a = x[..., 0, :]
        b = x[..., 1, :]
        x = jnp.concatenate(
            [(a + b)[..., None, :], (a - b)[..., None, :]], axis=-2
        ).reshape(bm, k // group, group)
        h *= 2
    x = x * (group ** -0.5)
    return x.reshape(bm, k)


def _fwht_kernel(x_ref, s_ref, o_ref, *, group: int):
    x = fwht_rows_math(x_ref[...].astype(jnp.float32),
                       s_ref[...].astype(jnp.float32), group)
    o_ref[...] = x.astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("group", "bm", "interpret"))
def fwht_rows(
    x: jax.Array,
    signs: jax.Array,
    *,
    group: int = 16,
    bm: int | None = None,
    interpret: bool = False,
) -> jax.Array:
    """Grouped RHT along the last axis of (M, K); signs shape (K,)."""
    m, k = x.shape
    if group <= 0 or group & (group - 1):
        # mirror core.hadamard.fwht: a non-power-of-two group has no
        # butterfly factorization — the loop below would silently compute
        # a partial transform instead of H_g.
        raise ValueError(
            f"FWHT group must be a power of two, got {group}")
    if k % group:
        raise ValueError(
            f"axis length {k} not divisible by RHT group {group}")
    if signs.shape != (k,):
        raise ValueError(
            f"signs must have shape ({k},), got {signs.shape}")
    if bm is None:
        bm = max(1, min(256, (4 * 1024 * 1024 // 8) // max(k, 1)))
        while m % bm and bm > 1:
            bm //= 2
    assert m % bm == 0
    return pl.pallas_call(
        functools.partial(_fwht_kernel, group=group),
        grid=(m // bm,),
        in_specs=[
            pl.BlockSpec((bm, k), lambda i: (i, 0)),
            pl.BlockSpec((1, k), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((bm, k), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((m, k), x.dtype),
        interpret=interpret,
    )(x, signs.reshape(1, k))
