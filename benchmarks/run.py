"""Benchmark harness — one entry per paper table/figure + roofline.

Prints ``name,us_per_call,derived`` CSV rows.  The roofline section reads
whatever dry-run artifacts exist (run ``python -m repro.launch.dryrun --all``
first for the full table).
"""
from __future__ import annotations

import sys
import traceback


def main() -> None:
    from benchmarks import common
    print("name,us_per_call,derived")

    from benchmarks import (kernels_bench, paper_tables, pretrain_loss,
                            ptq_pipelines, roofline, serving_bench)
    sections = [
        ("appendixA", paper_tables.bench_appendix_a),
        ("fig2_crest", paper_tables.bench_fig2_crest_stats),
        ("fig4_5_selection", paper_tables.bench_fig45_format_selection),
        ("table5_blocksize", paper_tables.bench_table5_blocksize),
        ("table7_sr", paper_tables.bench_table7_sr),
        ("fig12_hw", paper_tables.bench_fig12_hardware_model),
        ("kernel_quant", kernels_bench.bench_quant_kernel),
        ("kernel_gemm", kernels_bench.bench_gemm_w4a16),
        ("kernel_fused_and_tuner", kernels_bench.bench_for_run),
        ("kernel_qdq_cost", kernels_bench.bench_qdq_cost_vs_single_format),
        ("serving", serving_bench.bench_for_run),
        ("table3_rtn", paper_tables.bench_table3_rtn_formats),
        ("table4_pipelines", ptq_pipelines.bench_table4_pipelines),
        ("fig10_pretrain", pretrain_loss.bench_fig10_pretrain),
        ("roofline", roofline.bench_roofline),
    ]

    failures = []
    for name, fn in sections:
        try:
            fn()
        except Exception as e:
            traceback.print_exc()
            failures.append(name)
            common.emit(f"{name}_FAILED", 0.0, repr(e)[:120])
    if failures:
        print(f"# {len(failures)} benchmark sections failed: {failures}",
              file=sys.stderr)
        raise SystemExit(1)


if __name__ == "__main__":
    main()
