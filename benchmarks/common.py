"""Shared benchmark utilities: timing + CSV emission + a tiny trained LM."""
from __future__ import annotations

import os
import time

import jax
import jax.numpy as jnp
import numpy as np

ROWS = []


def emit(name: str, us_per_call: float, derived: str):
    ROWS.append((name, us_per_call, derived))
    print(f"{name},{us_per_call:.1f},{derived}")


def time_fn(fn, *args, iters: int = 5, warmup: int = 1) -> float:
    """Median wall-time per call in microseconds (results blocked)."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts) * 1e6)


# ---------------------------------------------------------------------------
# tiny LM trained once per benchmark session (PTQ benches need a model whose
# logits mean something).  ~0.5M params, 60 quick steps on the synthetic
# Markov stream; cached in-process.
# ---------------------------------------------------------------------------
_TINY = {}


def tiny_lm(steps: int = 60, method: str = "bf16"):
    key = (steps, method)
    if key in _TINY:
        return _TINY[key]
    from repro.core.qgemm import QuantConfig
    from repro.data import DataConfig, make_stream
    from repro.models.base import ArchConfig, Ctx, build_model
    from repro.optim import AdamWConfig, adamw_init, adamw_update

    cfg = ArchConfig(name="tiny", family="dense", n_layers=2, d_model=128,
                     n_heads=4, n_kv_heads=2, d_ff=256, vocab=256,
                     qk_norm=True, attn_chunk=128,
                     quant=QuantConfig(method=method))
    model = build_model(cfg)
    params, _ = model.init(jax.random.PRNGKey(0))
    opt_cfg = AdamWConfig()
    opt = adamw_init(params)
    stream = make_stream(DataConfig(vocab=256, seq_len=64, batch_per_shard=8,
                                    seed=3))
    ctx = Ctx(jax.random.PRNGKey(1), cfg.quant)

    @jax.jit
    def step(params, opt, batch, k):
        c = Ctx(k, cfg.quant)
        loss, g = jax.value_and_grad(
            lambda p: model.loss(p, batch, c))(params)
        params, opt, _ = adamw_update(opt_cfg, params, opt, g, 3e-3)
        return params, opt, loss

    loss = None
    for i in range(steps):
        b = stream.batch(i)
        batch = {k: jnp.asarray(v) for k, v in b.items()}
        params, opt, loss = step(params, opt, batch,
                                 jax.random.PRNGKey(100 + i))
    _TINY[key] = (cfg, model, params, float(loss))
    return _TINY[key]


def eval_ppl(cfg, model, params, *, method: str | None = None,
             n_batches: int = 4, qparams=None):
    """Eval perplexity of the tiny LM on held-out synthetic batches, with
    weights optionally quantize-dequantized by ``method`` (RTN PTQ)."""
    from repro.core import quantize as Q
    from repro.data import DataConfig, make_stream
    from repro.models.base import Ctx
    from repro.core.qgemm import QuantConfig

    def q2d(w):
        if method == "bf16":
            return w
        if w.ndim == 2 and min(w.shape) >= 16:
            return Q.qdq_2d(w, method)
        if w.ndim == 3 and min(w.shape[1:]) >= 16:   # stacked layer weights
            return jax.vmap(lambda m: Q.qdq_2d(m, method))(w)
        return w

    p = qparams if qparams is not None else params
    if method is not None and qparams is None:
        p = jax.tree.map(q2d, params)
    ecfg = cfg.replace(quant=QuantConfig(method="bf16"))  # activations bf16
    from repro.models.base import build_model
    emodel = build_model(ecfg)
    ctx = Ctx(jax.random.PRNGKey(9), ecfg.quant)
    # held-out batches from the SAME stream (seed 3), disjoint step range
    stream = make_stream(DataConfig(vocab=cfg.vocab, seq_len=64,
                                    batch_per_shard=8, seed=3))
    tot = 0.0
    for i in range(n_batches):
        b = stream.batch(1000 + i)
        batch = {k: jnp.asarray(v) for k, v in b.items()}
        tot += float(emodel.loss(p, batch, ctx))
    return float(np.exp(tot / n_batches))
