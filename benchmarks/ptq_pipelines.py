"""Table 4: MixFP4 combined with PTQ front-ends (SmoothQuant, GPTQ, rotation).

Front-ends implemented on the tiny in-process LM:
  * SmoothQuant (Xiao et al.): per-channel scale migration s_j =
    max|X_j|^a / max|W_j|^(1-a), a=0.5 (paper App. C.1), folded between the
    pre-norm gain and the linear weight,
  * GPTQ (Frantar et al.): Hessian-based column-block error compensation
    with STATIC per-16-block format selection before compensation (paper
    App. C.2: formats frozen, then error propagation),
  * rotation (SpinQuant stand-in per App. C.3): a random Hadamard rotation of
    the hidden space folded into adjacent linears (the paper itself replaces
    learned rotations by RHT in its +RHT columns).

Validated claim: MixFP4 as the underlying 4-bit block format is complementary
to each front-end (ppl <= NVFP4's under the same front-end).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks import common
from repro.core import hadamard, quantize as Q
from repro.data import DataConfig, make_stream
from repro.models.base import Ctx


def _calib_acts(cfg, model, params, n=2):
    """Per-layer input absmax via a forward hook surrogate: use embedding
    stream stats (proxy: activations at the linear inputs share the hidden
    distribution)."""
    stream = make_stream(DataConfig(vocab=cfg.vocab, seq_len=64,
                                    batch_per_shard=4, seed=55))
    ctx = Ctx(jax.random.PRNGKey(0), cfg.quant)
    xs = []
    for i in range(n):
        b = stream.batch(i)
        x, _ = model.hidden(params, {k: jnp.asarray(v) for k, v in b.items()},
                            ctx)
        xs.append(np.asarray(x, np.float32).reshape(-1, x.shape[-1]))
    return np.concatenate(xs)


def _smoothquant(params, acts, alpha=0.5):
    """Scale-migrate every 2-D weight whose input dim matches the hidden."""
    d = acts.shape[-1]
    amax = np.maximum(np.abs(acts).max(0), 1e-5)

    def mig(w):
        if w.ndim == 2 and w.shape[0] == d:
            wmax = np.maximum(np.abs(np.asarray(w)).max(1), 1e-5)
            s = amax ** alpha / wmax ** (1 - alpha)
            return jnp.asarray(np.asarray(w) * s[:, None])
        if w.ndim == 3 and w.shape[1] == d:  # stacked (L, d, n)
            wmax = np.maximum(np.abs(np.asarray(w)).max(2), 1e-5)
            s = amax[None, :] ** alpha / wmax ** (1 - alpha)
            return jnp.asarray(np.asarray(w) * s[:, :, None])
        return w

    return jax.tree.map(mig, params)


def _gptq_quantize(w, X, method="mixfp4", block=16):
    """GPTQ with static per-block format selection (App. C.2).

    w: (K, N); X: (M, K) calibration inputs. Column-blockwise: quantize a
    16-column block (2-D 16x16 tiles across rows), then propagate the
    residual error through the inverse-Hessian to later columns.
    """
    w = np.asarray(w, np.float64).copy()
    k, n = w.shape
    H = (X.T @ X).astype(np.float64) / len(X) + 1e-2 * np.eye(k)
    Hinv = np.linalg.inv(H)
    Wq = w.copy()
    for i0 in range(0, k, block):
        i1 = min(i0 + block, k)
        blockw = Wq[i0:i1, :]
        qblock = np.asarray(Q.qdq_2d(jnp.asarray(blockw, jnp.float32),
                                     method), np.float64)
        err = blockw - qblock
        Wq[i0:i1, :] = qblock
        # propagate: dW_rest = -Hinv[rest, blk] @ inv(Hinv[blk, blk]) @ err
        Hbb = Hinv[i0:i1, i0:i1]
        Hrb = Hinv[i1:, i0:i1]
        if i1 < k:
            Wq[i1:, :] -= Hrb @ np.linalg.solve(Hbb, err)
    return jnp.asarray(Wq, np.float32)


def bench_table4_pipelines():
    cfg, model, params, _ = common.tiny_lm()
    acts = _calib_acts(cfg, model, params)
    base = common.eval_ppl(cfg, model, params)
    results = {"bf16": base}

    def rtn(p, method):
        def q(w):
            if w.ndim == 2 and min(w.shape) >= 16:
                return Q.qdq_2d(w, method)
            if w.ndim == 3 and min(w.shape[1:]) >= 16:
                return jax.vmap(lambda m: Q.qdq_2d(m, method))(w)
            return w
        return jax.tree.map(q, p)

    # --- SmoothQuant ---
    smooth = _smoothquant(params, acts)
    for m in ["nvfp4", "four_six", "mixfp4"]:
        ppl = common.eval_ppl(cfg, model, params, qparams=rtn(smooth, m))
        results[f"smooth_{m}"] = ppl
        common.emit(f"table4_smoothquant_{m}", 0.0, f"ppl={ppl:.4f}")

    # --- GPTQ (applied to hidden-dim matrices) ---
    d = acts.shape[-1]

    def gptq(p, method):
        def q(w):
            if w.ndim == 2 and w.shape[0] == d and min(w.shape) >= 16:
                return _gptq_quantize(w, acts[:256], method)
            if w.ndim == 3 and w.shape[1] == d and min(w.shape[1:]) >= 16:
                return jnp.stack([_gptq_quantize(w[i], acts[:256], method)
                                  for i in range(w.shape[0])])
            if w.ndim == 2 and min(w.shape) >= 16:
                return Q.qdq_2d(w, method)
            if w.ndim == 3 and min(w.shape[1:]) >= 16:
                return jax.vmap(lambda m: Q.qdq_2d(m, method))(w)
            return w
        return jax.tree.map(q, p)

    for m in ["nvfp4", "mixfp4"]:
        ppl = common.eval_ppl(cfg, model, params, qparams=gptq(params, m))
        results[f"gptq_{m}"] = ppl
        common.emit(f"table4_gptq_{m}", 0.0, f"ppl={ppl:.4f}")

    # --- rotation (RHT stand-in for SpinQuant, App. C.3 note): quantize in
    # the rotated domain, rotate back (QuaRot-style weight-only rotation;
    # rht(x) = H.D.x with H = H^T = H^-1, so the inverse is D.H) ---
    signs = hadamard.rht_signs(jax.random.PRNGKey(123), d)

    def rot_axis(w, axis):
        return hadamard.rht(w, signs, axis=axis, group=16)

    def unrot_axis(y, axis):
        h = hadamard.fwht(jnp.moveaxis(y, axis, -1).reshape(
            -1, y.shape[axis] // 16, 16), axis=-1)
        h = (h.reshape(-1, y.shape[axis]) * signs).reshape(
            jnp.moveaxis(y, axis, -1).shape)
        return jnp.moveaxis(h, -1, axis)

    def rotated_quant(p, method):
        def q(w):
            if w.ndim == 2 and w.shape[0] == d and min(w.shape) >= 16:
                wq = Q.qdq_2d(rot_axis(w, 0), method)
                return unrot_axis(wq, 0)
            if w.ndim == 3 and w.shape[1] == d and min(w.shape[1:]) >= 16:
                return jax.vmap(lambda m: unrot_axis(
                    Q.qdq_2d(rot_axis(m, 0), method), 0))(w)
            if w.ndim == 2 and min(w.shape) >= 16:
                return Q.qdq_2d(w, method)
            if w.ndim == 3 and min(w.shape[1:]) >= 16:
                return jax.vmap(lambda m: Q.qdq_2d(m, method))(w)
            return w
        return jax.tree.map(q, p)

    for m in ["nvfp4", "mixfp4"]:
        ppl = common.eval_ppl(cfg, model, params,
                              qparams=rotated_quant(params, m))
        results[f"rot_{m}"] = ppl
        common.emit(f"table4_rotation_{m}", 0.0, f"ppl={ppl:.4f}")

    ok = (results["smooth_mixfp4"] <= results["smooth_nvfp4"] + 1e-3
          and results["gptq_mixfp4"] <= results["gptq_nvfp4"] + 1e-3)
    common.emit("table4_complementary", 0.0, f"mixfp4<=nvfp4_under_frontends={ok}")
    return results
